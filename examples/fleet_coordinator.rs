//! Fleet coordination in ~60 lines: three simulated ExpertWeave
//! replicas behind the coordinator, six adapters competing for two
//! resident slots per replica, skewed traffic.
//!
//! No artifacts needed (sim backend):
//! ```text
//! cargo run --release --example fleet_coordinator
//! ```

use expertweave::adapters::generator::synth_fleet_adapters;
use expertweave::coordinator::{Coordinator, CoordinatorConfig, RoutingPolicy};
use expertweave::engine::{Engine, EngineOptions};
use expertweave::model::ModelConfig;
use expertweave::runtime::{SimPerf, Variant};
use expertweave::weights::StoreMode;
use expertweave::workload::trace::{Trace, TraceSpec};

fn main() -> anyhow::Result<()> {
    const REPLICAS: usize = 3;
    const CAPACITY: usize = 2;

    // 1. a sim-backend model geometry with room for CAPACITY adapters
    let mut cfg = ModelConfig::sim_default();
    cfg.max_adapters = CAPACITY;

    // 2. six Table-1-profile adapters fitted to it
    let adapters = synth_fleet_adapters(&cfg, 6, 42);

    // 3. a skewed trace: the first adapter gets most of the traffic
    let mut trace = Trace::generate(&TraceSpec {
        adapters: adapters
            .iter()
            .map(|ad| (ad.name.clone(), ad.domain.clone()))
            .collect(),
        lambda: 20.0,
        alpha: 0.3,
        horizon: 4.0,
        vocab: cfg.vocab,
        seed: 7,
    });
    trace.clip(64, 24);
    println!("trace: {} requests, per-adapter {:?}", trace.len(), trace.per_adapter_counts());

    // 4. replay through two routing policies over identical fleets
    for policy in [RoutingPolicy::RoundRobin, RoutingPolicy::AdapterAffinity] {
        let coord = Coordinator::launch(
            CoordinatorConfig {
                replicas: REPLICAS,
                policy,
                adapter_capacity: CAPACITY,
                queue_cap: 16,
                replicate_rps: 8.0, // replicate the hot adapter
                rate_halflife: 1.0,
                max_copies: 2,
            },
            |i| {
                let cfg = cfg.clone();
                Box::new(move || {
                    Engine::sim_weave(
                        &cfg,
                        SimPerf::default(),
                        &[], // the coordinator places adapters
                        Variant::Weave,
                        StoreMode::Virtual,
                        EngineOptions { page_size: 64 << 10, seed: i as u64, ..Default::default() },
                    )
                })
            },
            adapters.clone(),
        )?;
        let outcome = coord.replay(&trace)?;
        println!("\n{}", outcome.report.row(&format!("fleet/{policy}")));
        println!("  {}", outcome.stats.row());
        println!(
            "  goodput {:.2} req/s | TTFT p99 {:.0} ms",
            outcome.report.goodput(),
            outcome.report.ttft.p99 * 1e3
        );
    }
    Ok(())
}
