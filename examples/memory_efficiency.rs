//! Memory-efficiency walkthrough (the paper's section 4.2 mechanics, at
//! paper scale): run the *real* expert memory manager in accounting mode
//! against the 16B-model geometry on a simulated 64 GB device and watch
//! mapped pages vs padding vs per-adapter merged models as adapters load
//! and evict.
//!
//! ```text
//! cargo run --release --example memory_efficiency
//! ```

use expertweave::adapters::generator::{paper_adapter_profiles, synth_adapter};
use expertweave::bench::{fmt_bytes, Table};
use expertweave::memsim::{gib, DeviceMemory};
use expertweave::model::ModelConfig;
use expertweave::vmm::expert_manager::ExpertMemoryManager;
use expertweave::vmm::DEFAULT_PAGE_SIZE;

/// One accounting-mode manager per (layer, projection), like the real
/// weight store but at 16B scale with bf16 weights (paper deployment).
struct PaperStore {
    managers: Vec<ExpertMemoryManager>,
    cfg: ModelConfig,
}

const BF16: usize = 2;

impl PaperStore {
    fn new(device: std::sync::Arc<std::sync::Mutex<DeviceMemory>>) -> Self {
        let cfg = ModelConfig::paper16b();
        let expert_proj = cfg.hidden * cfg.expert_inter * BF16;
        let managers = (0..cfg.layers * 3)
            .map(|_| {
                ExpertMemoryManager::new_accounting(
                    expert_proj,
                    cfg.total_expert_slots(),
                    DEFAULT_PAGE_SIZE,
                    device.clone(),
                )
            })
            .collect();
        PaperStore { managers, cfg }
    }

    fn load_base(&mut self) -> anyhow::Result<()> {
        for m in &mut self.managers {
            m.load_range(0, self.cfg.num_experts)?;
        }
        Ok(())
    }

    fn load_adapter(&mut self, slot: usize, counts: &[usize], padded: bool) -> anyhow::Result<()> {
        let delta = self.cfg.adapter_slot_base(slot);
        for (l, &c) in counts.iter().enumerate() {
            let commit = if padded { self.cfg.e_max } else { c };
            if commit == 0 {
                continue;
            }
            for p in 0..3 {
                self.managers[l * 3 + p].load_range(delta, commit)?;
            }
        }
        Ok(())
    }

    fn mapped(&self) -> usize {
        self.managers.iter().map(|m| m.stats().mapped_bytes).sum()
    }
}

fn main() -> anyhow::Result<()> {
    let cfg = ModelConfig::paper16b();
    println!(
        "paper-scale model: {} layers x {} experts + {} adapter slots; \
         expert = {} per layer (bf16), device = 64 GiB",
        cfg.layers,
        cfg.num_experts,
        cfg.max_adapters * cfg.e_max,
        fmt_bytes(cfg.hidden * cfg.expert_inter * BF16 * 3),
    );

    // the three published adapters used by the paper's Fig. 9
    let names = ["gate-math", "token-math", "gate-intent"];
    let adapters: Vec<Vec<usize>> = paper_adapter_profiles()
        .iter()
        .filter(|p| names.contains(&p.name))
        .map(|p| {
            synth_adapter(p, cfg.layers, cfg.num_experts, 8, 4, 42)
                .layers
                .iter()
                .map(|l| l.expert_count())
                .collect()
        })
        .collect();

    let mut t = Table::new(&["event", "virtual (mapped)", "padding (mapped)", "saved"]);
    let dev_v = DeviceMemory::shared(gib(64));
    let dev_p = DeviceMemory::shared(gib(64));
    let mut virt = PaperStore::new(dev_v);
    let mut pad = PaperStore::new(dev_p);
    virt.load_base()?;
    pad.load_base()?;
    let base = virt.mapped();
    t.row(&["base model".into(), fmt_bytes(virt.mapped()), fmt_bytes(pad.mapped()), "-".into()]);

    for (i, counts) in adapters.iter().enumerate() {
        virt.load_adapter(i, counts, false)?;
        pad.load_adapter(i, counts, true)?;
        let (v, p) = (virt.mapped() - base, pad.mapped() - base);
        t.row(&[
            format!("+ {}", names[i]),
            fmt_bytes(v),
            fmt_bytes(p),
            format!("{:.1}%", (1.0 - v as f64 / p as f64) * 100.0),
        ]);
    }
    t.print("adapter weight memory at 16B scale (cumulative beyond base)");
    println!(
        "\nper-adapter merged deployment would cost {} EACH instead.",
        fmt_bytes(cfg.base_model_bytes() / 4 * BF16)
    );
    Ok(())
}
