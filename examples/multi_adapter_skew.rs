//! Skewed multi-adapter serving: the paper's Fig.-6 scenario in example
//! form. One shared ExpertWeave engine absorbs a power-law-skewed
//! workload across adapters; the same trace split across per-adapter
//! *merged* instances leaves the cold instances idle while the hot one
//! queues.
//!
//! ```text
//! cargo run --release --example multi_adapter_skew -- [--alpha 0.32]
//! ```

use expertweave::adapters::generator::{paper_adapter_profiles, synth_adapter};
use expertweave::engine::{Engine, EngineOptions};
use expertweave::runtime::{ArtifactSet, Variant};
use expertweave::server;
use expertweave::util::args::Args;
use expertweave::weights::StoreMode;
use expertweave::workload::power_law::power_law_shares;
use expertweave::workload::trace::{Trace, TraceSpec};
use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    let a = Args::new("multi_adapter_skew", "skewed workload: weave vs merged instances")
        .opt("config", Some("tiny"), "artifact config")
        .opt("alpha", Some("0.32"), "power-law skew (0.32 -> ~80/20)")
        .opt("lambda", Some("8"), "aggregate req/s")
        .opt("horizon", Some("10"), "horizon (s)")
        .parse_env()
        .map_err(anyhow::Error::msg)?;
    let dir = PathBuf::from("artifacts").join(a.get_or("config", "tiny"));
    let cfg_dir = dir.clone();
    let set = ArtifactSet::load(&dir)?;
    let cfg = set.config.clone();
    let alpha: f64 = a.get_f64("alpha").map_err(anyhow::Error::msg)?;

    let mk_adapter = |i: usize| {
        let mut p = paper_adapter_profiles()[i].clone();
        p.max_experts = p.max_experts.min(cfg.e_max);
        p.avg_experts = p.avg_experts.min(p.max_experts as f64);
        synth_adapter(&p, cfg.layers, cfg.num_experts, cfg.hidden, cfg.expert_inter, 42)
    };
    let ad0 = mk_adapter(0); // gate-math
    let ad1 = mk_adapter(2); // gate-intent

    let shares = power_law_shares(2, alpha);
    println!(
        "skew alpha={alpha}: {:.0}% -> {}, {:.0}% -> {}",
        shares[0] * 100.0,
        ad0.name,
        shares[1] * 100.0,
        ad1.name
    );

    let mut trace = Trace::generate(&TraceSpec {
        adapters: vec![
            (ad0.name.clone(), ad0.domain.clone()),
            (ad1.name.clone(), ad1.domain.clone()),
        ],
        lambda: a.get_f64("lambda").map_err(anyhow::Error::msg)?,
        alpha,
        horizon: a.get_f64("horizon").map_err(anyhow::Error::msg)?,
        vocab: cfg.vocab,
        seed: 1,
    });
    let max_prompt = cfg.buckets.last().copied().unwrap().min(cfg.kv_cap / 2);
    for e in &mut trace.events {
        e.prompt.truncate(max_prompt);
        e.max_new_tokens = e.max_new_tokens.clamp(1, (cfg.kv_cap / 16).max(1));
    }

    // --- ExpertWeave: one shared engine sees the whole trace -----------
    let mut weave = Engine::new_weave(
        &set,
        &[ad0.clone(), ad1.clone()],
        Variant::Weave,
        StoreMode::Virtual,
        EngineOptions::default(),
    )?;
    let w = server::replay(&mut weave, &trace)?;
    println!("{}", w.report.row("weave (shared)"));

    // --- Merged: one isolated instance per adapter, split trace --------
    let split = |name: &str| {
        let mut t = trace.clone();
        t.events.retain(|e| e.adapter.as_deref() == Some(name));
        t
    };
    let outcomes = server::replay_multi(vec![
        (
            {
                let set_dir = cfg_dir.clone();
                let ad = ad0.clone();
                Box::new(move || {
                    let set = ArtifactSet::load(&set_dir)?;
                    let half = EngineOptions { compute_share: 0.5, ..Default::default() };
                    Engine::new_merged(&set, ad, half)
                }) as Box<dyn FnOnce() -> anyhow::Result<Engine> + Send>
            },
            split(&ad0.name),
        ),
        (
            {
                let set_dir = cfg_dir.clone();
                let ad = ad1.clone();
                Box::new(move || {
                    let set = ArtifactSet::load(&set_dir)?;
                    let half = EngineOptions { compute_share: 0.5, ..Default::default() };
                    Engine::new_merged(&set, ad, half)
                }) as Box<dyn FnOnce() -> anyhow::Result<Engine> + Send>
            },
            split(&ad1.name),
        ),
    ])?;
    for (o, name) in outcomes.iter().zip([&ad0.name, &ad1.name]) {
        println!("{}", o.report.row(&format!("merged [{name}]")));
    }
    let agg = server::aggregate(&outcomes);
    println!("{}", agg.row("merged (aggregate)"));
    println!(
        "\nweave decode {:.1} tok/s vs merged aggregate {:.1} tok/s ({:+.1}%)",
        w.report.decode_throughput,
        agg.decode_throughput,
        (w.report.decode_throughput / agg.decode_throughput - 1.0) * 100.0
    );
    Ok(())
}
