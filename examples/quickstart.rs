//! Quickstart: serve two ESFT adapters + the base model over one shared
//! MoE deployment, end to end, in ~30 lines of API.
//!
//! ```text
//! make artifacts && cargo run --release --example quickstart
//! ```

use expertweave::adapters::generator::{paper_adapter_profiles, synth_adapter};
use expertweave::engine::{Engine, EngineOptions, RequestSpec};
use expertweave::runtime::{ArtifactSet, Variant};
use expertweave::sampler::SamplingParams;
use expertweave::weights::StoreMode;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    // 1. load the AOT artifacts (HLO text + ABI) for the test model
    let set = ArtifactSet::load(Path::new("artifacts/tiny"))?;
    let cfg = set.config.clone();

    // 2. synthesize two Table-1-profile ESFT adapters for this geometry
    let adapters: Vec<_> = paper_adapter_profiles()[..2]
        .iter()
        .map(|p| {
            let mut p = p.clone();
            p.max_experts = p.max_experts.min(cfg.e_max);
            p.avg_experts = p.avg_experts.min(p.max_experts as f64);
            synth_adapter(&p, cfg.layers, cfg.num_experts, cfg.hidden, cfg.expert_inter, 42)
        })
        .collect();

    // 3. one ExpertWeave engine: shared base + both adapters behind the
    //    virtual weight tensor and the fused batched-rerouting kernel
    let mut engine = Engine::new_weave(
        &set,
        &adapters,
        Variant::Weave,
        StoreMode::Virtual,
        EngineOptions::default(),
    )?;

    // 4. batch requests across adapters and the base model
    for (i, who) in [Some("gate-math"), Some("token-math"), None].iter().enumerate() {
        engine.submit(RequestSpec {
            adapter: who.map(str::to_string),
            prompt: (1..=8 + i as i32).collect(),
            max_new_tokens: 6,
            sampling: SamplingParams::greedy(),
        })?;
    }

    // 5. run them to completion — tokens of all three requests are packed
    //    into the same steps; rerouting sends each to its own experts
    for c in engine.run_to_completion()? {
        println!(
            "request {} ({}) -> {:?}  (TTFT {:.1} ms)",
            c.id,
            c.adapter.as_deref().unwrap_or("<base>"),
            c.output,
            c.record.ttft.as_secs_f64() * 1e3,
        );
    }
    println!("\n{}", engine.report().row("quickstart/tiny"));
    Ok(())
}
