//! End-to-end driver (the repository's headline validation run): load the
//! ~120M-parameter `small` MoE model, register 5 ESFT adapters, and serve
//! a 60-second multi-adapter online workload with continuous batching +
//! chunked prefill, reporting the paper's four metrics (prefill/decode
//! throughput, TTFT, TPOT) plus engine telemetry.
//!
//! ```text
//! make artifacts && cargo run --release --example serve_e2e
//!   [--config small] [--adapters 5] [--lambda 0.4] [--horizon 60]
//! ```
//!
//! Results are recorded in EXPERIMENTS.md §End-to-end.

use expertweave::adapters::generator::{paper_adapter_profiles, synth_adapter};
use expertweave::bench::{fmt_bytes, fmt_time, Table};
use expertweave::engine::{Engine, EngineOptions};
use expertweave::runtime::{ArtifactSet, Variant};
use expertweave::server;
use expertweave::util::args::Args;
use expertweave::weights::StoreMode;
use expertweave::workload::trace::{Trace, TraceSpec};
use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    let a = Args::new("serve_e2e", "end-to-end multi-adapter serving run")
        .opt("config", Some("small"), "artifact config")
        .opt("adapters", Some("5"), "adapters to load")
        .opt("lambda", Some("0.4"), "aggregate req/s (testbed-scaled)")
        .opt("alpha", Some("1.0"), "adapter skew (1 = uniform)")
        .opt("horizon", Some("60"), "trace horizon (s)")
        .opt("seed", Some("0"), "workload seed")
        .parse_env()
        .map_err(anyhow::Error::msg)?;

    let dir = PathBuf::from("artifacts").join(a.get_or("config", "small"));
    let set = ArtifactSet::load(&dir)?;
    let cfg = set.config.clone();
    let n: usize = a.get_usize("adapters").map_err(anyhow::Error::msg)?;

    println!(
        "model {}: {} params (f32), {} layers x {} experts (top-{}), G = {} slots",
        cfg.name,
        fmt_bytes(cfg.base_model_bytes()),
        cfg.layers,
        cfg.num_experts,
        cfg.top_k,
        cfg.total_expert_slots()
    );

    let profiles = paper_adapter_profiles();
    let adapters: Vec<_> = (0..n)
        .map(|i| {
            let mut p = profiles[i % profiles.len()].clone();
            p.max_experts = p.max_experts.min(cfg.e_max);
            p.avg_experts = p.avg_experts.min(p.max_experts as f64);
            synth_adapter(&p, cfg.layers, cfg.num_experts, cfg.hidden, cfg.expert_inter, 42 + i as u64)
        })
        .collect();

    let t0 = std::time::Instant::now();
    let mut engine = Engine::new_weave(
        &set,
        &adapters,
        Variant::Weave,
        StoreMode::Virtual,
        EngineOptions::default(),
    )?;
    println!(
        "engine up in {} ({} adapters resident; weights mapped on device)",
        fmt_time(t0.elapsed().as_secs_f64()),
        n
    );

    let mut trace = Trace::generate(&TraceSpec {
        adapters: adapters.iter().map(|ad| (ad.name.clone(), ad.domain.clone())).collect(),
        lambda: a.get_f64("lambda").map_err(anyhow::Error::msg)?,
        alpha: a.get_f64("alpha").map_err(anyhow::Error::msg)?,
        horizon: a.get_f64("horizon").map_err(anyhow::Error::msg)?,
        vocab: cfg.vocab,
        seed: a.get_usize("seed").map_err(anyhow::Error::msg)? as u64,
    });
    let max_prompt = cfg.buckets.last().copied().unwrap().min(cfg.kv_cap / 2);
    for e in &mut trace.events {
        e.prompt.truncate(max_prompt);
        e.max_new_tokens = e.max_new_tokens.clamp(1, cfg.kv_cap / 16);
    }
    println!(
        "trace: {} requests over {:.0}s ({:?})",
        trace.len(),
        a.get_f64("horizon").map_err(anyhow::Error::msg)?,
        trace.per_adapter_counts()
    );

    let outcome = server::replay(&mut engine, &trace)?;
    let r = &outcome.report;
    let mut t = Table::new(&["metric", "value"]);
    t.row(&["requests completed".into(), r.requests.to_string()]);
    t.row(&["prefill throughput".into(), format!("{:.1} tok/s", r.prefill_throughput)]);
    t.row(&["decode throughput".into(), format!("{:.1} tok/s", r.decode_throughput)]);
    t.row(&["TTFT p50 / p99".into(), format!("{} / {}", fmt_time(r.ttft.median), fmt_time(r.ttft.p99))]);
    t.row(&["TPOT p50 / p99".into(), format!("{} / {}", fmt_time(r.tpot.median), fmt_time(r.tpot.p99))]);
    t.row(&["e2e p50".into(), fmt_time(r.e2e.median)]);
    t.row(&["engine steps".into(), engine.metrics.step_count.to_string()]);
    t.row(&["mean step".into(), fmt_time(engine.metrics.step_time.mean())]);
    t.row(&["mean XLA execute".into(), fmt_time(engine.metrics.execute_time.mean())]);
    t.row(&["mean batched tokens".into(), format!("{:.1}", engine.metrics.batched_tokens.mean())]);
    t.print("serve_e2e");
    t.write_csv("serve_e2e").ok();
    Ok(())
}
