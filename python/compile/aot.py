"""AOT compile path: lower every (config, variant, token-bucket) step
function to **HLO text** and emit ``meta.json``, the artifact ABI consumed
by ``rust/src/runtime``.

HLO *text* (not ``HloModuleProto.serialize()``) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version behind the published ``xla`` crate) rejects; the text
parser reassigns ids and round-trips cleanly.

Usage (from ``python/``)::

    python -m compile.aot --out-dir ../artifacts [--configs tiny,small]
                          [--check]

Python runs only here — never on the request path. ``make artifacts``
invokes this once; the Rust binary is self-contained afterwards.
"""

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .configs import CONFIGS
from .model import VARIANTS, lower_step, param_spec, step_input_specs


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return sanitize_hlo_text(comp.as_hlo_text())


def sanitize_hlo_text(text: str) -> str:
    """Make jax-0.8-emitted HLO text parseable by xla_extension 0.5.1.

    The only incompatibility observed is the ``largest=true`` attribute on
    ``topk`` (added after 0.5.1; descending order was and is the
    behaviour). ``largest=false`` never occurs (we only lower
    ``lax.top_k``); assert so a future change cannot silently flip
    semantics.
    """
    assert "largest=false" not in text, "topk(largest=false) unsupported by old XLA"
    return text.replace(", largest=true", "")


def build_manifest(cfg, variant, bucket):
    """Full ordered input manifest for one executable."""
    params = [
        {"name": n, "shape": list(s), "dtype": "f32"}
        for n, s in param_spec(cfg, variant)
    ]
    inputs = [
        {"name": n, "shape": list(s), "dtype": dt}
        for n, s, dt in step_input_specs(cfg, variant, bucket)
    ]
    o = min(bucket, cfg.max_seqs)
    return {
        "variant": variant,
        "bucket": bucket,
        "out_rows": o,
        "gmm_block": cfg.gmm_block(bucket),
        "params": params,
        "inputs": inputs,
        # kv_cache is the first input after the flattened params tuple and
        # is donated (input_output_alias in the HLO).
        "donate_input_index": len(params),
        "outputs": [
            {"name": "logits", "shape": [o, cfg.vocab], "dtype": "f32"},
            {"name": "kv_cache",
             "shape": [cfg.layers, 2, cfg.kv_cap, cfg.kv_heads, cfg.head_dim],
             "dtype": "f32"},
        ],
    }


def self_check(cfg, variant, bucket, lowered):
    """Compile the lowered module and execute it with arbitrary inputs —
    catches manifest/ABI drift (input count/order/shape) at build time."""
    import numpy as np

    man = build_manifest(cfg, variant, bucket)
    rng = np.random.default_rng(0)
    params = tuple(
        (rng.normal(size=p["shape"]) * 0.02).astype(np.float32)
        for p in man["params"]
    )
    args = []
    for i in man["inputs"]:
        dt = np.float32 if i["dtype"] == "f32" else np.int32
        args.append(np.zeros(i["shape"], dt))
    logits, kv = lowered.compile()(params, *args)
    want = [tuple(o["shape"]) for o in man["outputs"]]
    got = [tuple(logits.shape), tuple(kv.shape)]
    assert got == want, f"self-check output shapes {got} != {want}"


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--configs", default="tiny,small")
    ap.add_argument("--variants", default=",".join(VARIANTS))
    ap.add_argument("--check", action="store_true",
                    help="compile+execute each tiny artifact as a smoke test")
    args = ap.parse_args()

    for cfg_name in args.configs.split(","):
        cfg = CONFIGS[cfg_name]
        if not cfg.buckets:
            print(f"[aot] {cfg_name}: accounting-only config, skipping")
            continue
        out_dir = os.path.join(args.out_dir, cfg.name)
        os.makedirs(out_dir, exist_ok=True)
        meta = {"config": cfg.to_json_dict(), "executables": []}
        for variant in args.variants.split(","):
            for bucket in cfg.buckets:
                lowered = lower_step(cfg, variant, bucket)
                text = to_hlo_text(lowered)
                fname = f"{variant}_t{bucket}.hlo.txt"
                path = os.path.join(out_dir, fname)
                with open(path, "w") as f:
                    f.write(text)
                entry = build_manifest(cfg, variant, bucket)
                entry["file"] = fname
                entry["sha256"] = hashlib.sha256(text.encode()).hexdigest()
                meta["executables"].append(entry)
                print(f"[aot] {cfg.name}/{fname}: {len(text)} chars")
                if args.check and cfg.name == "tiny":
                    self_check(cfg, variant, bucket, lowered)
                    print(f"[aot] {cfg.name}/{fname}: self-check OK")
        with open(os.path.join(out_dir, "meta.json"), "w") as f:
            json.dump(meta, f, indent=1)
        print(f"[aot] wrote {out_dir}/meta.json "
              f"({len(meta['executables'])} executables)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
