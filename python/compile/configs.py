"""Model/serving configurations shared by the AOT compile path and (via
``meta.json``) the Rust runtime.

A config fully determines the shapes of every artifact: the MoE transformer
dimensions, the adapter-slot geometry of the virtual weight tensor
(``M + N * E_max`` expert slots), the KV slot-pool capacity, and the token
buckets the scheduler may dispatch.

The paper's base model is the ESFT-vanilla 16B MoE (DeepSeek-V2-Lite
architecture: 26 MoE layers, M=64 routed experts, top-6, fine-grained
experts). ``small`` is a faithfully scaled-down sibling (~120M params) used
for end-to-end serving experiments on CPU PJRT; ``tiny`` is for tests.
``paper16b`` is *never compiled* — it exists so the memory-accounting
experiments (Fig. 9, Table 1) can run the real allocator at paper scale.
"""

from dataclasses import dataclass, field, asdict


@dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    hidden: int          # H
    layers: int          # L
    q_heads: int         # QH
    kv_heads: int        # KVH
    head_dim: int        # D
    num_experts: int     # M routed experts (router domain)
    top_k: int           # K experts activated per token
    expert_inter: int    # F per-expert FFN intermediate size
    shared_inter: int    # shared-expert intermediate size (0 = none)
    max_adapters: int    # N adapter slots in the virtual weight tensor
    e_max: int           # E_max adapter expert slots per adapter per layer
    kv_cap: int          # CAP KV slot-pool size
    max_seqs: int        # O rows of logits returned per step
    buckets: tuple = (4, 16, 64, 256)   # token buckets (sorted ascending)
    rope_theta: float = 10000.0
    rms_eps: float = 1e-6

    @property
    def total_expert_slots(self) -> int:
        """G: first-dimension size of the virtual weight tensor."""
        return self.num_experts + self.max_adapters * self.e_max

    def gmm_block(self, bucket: int) -> int:
        """Grouped-matmul row-block size for a given token bucket.

        Small buckets (decode-dominated) use small blocks so partially
        filled groups waste little compute; large prefill buckets amortize
        bigger blocks.
        """
        # tuned by sweep on the single-core testbed (EXPERIMENTS.md §Perf):
        # R<=256 -> 4, R<=1024 -> 8, else 32
        r = bucket * self.top_k
        if r <= 256:
            return 4
        if r <= 1024:
            return 8
        return 32

    def to_json_dict(self) -> dict:
        d = asdict(self)
        d["buckets"] = list(self.buckets)
        d["total_expert_slots"] = self.total_expert_slots
        d["gmm_blocks"] = {str(b): self.gmm_block(b) for b in self.buckets}
        return d


TINY = ModelConfig(
    name="tiny",
    vocab=128,
    hidden=32,
    layers=2,
    q_heads=2,
    kv_heads=1,
    head_dim=16,
    num_experts=8,
    top_k=2,
    expert_inter=16,
    shared_inter=32,
    max_adapters=3,
    e_max=3,
    kv_cap=64,
    max_seqs=8,
    buckets=(4, 16),
)

# ~120M parameters: 8 layers x 64 fine-grained experts (F=128), top-6,
# GQA attention. Same family as DeepSeek-V2-Lite modulo MLA->GQA (see
# DESIGN.md section 7).
# Buckets/caps are sized for the single-core CPU-PJRT testbed (see
# EXPERIMENTS.md "testbed scale" note): ~1 s worst-case prefill step.
SMALL = ModelConfig(
    name="small",
    vocab=8192,
    hidden=512,
    layers=8,
    q_heads=8,
    kv_heads=2,
    head_dim=64,
    num_experts=64,
    top_k=6,
    expert_inter=128,
    shared_inter=512,
    max_adapters=20,
    e_max=13,
    kv_cap=1024,
    max_seqs=32,
    buckets=(8, 32, 128, 512),
)

# Paper-scale geometry for memory accounting only (never lowered/compiled).
# DeepSeek-V2-Lite: 27 layers (26 MoE), H=2048, F=1408, M=64, top-6,
# 16B params; each NPU has 64 GB. Expert weight bytes per expert per layer:
# 3 * H * F * bytes.
PAPER16B = ModelConfig(
    name="paper16b",
    vocab=102400,
    hidden=2048,
    layers=26,
    q_heads=16,
    kv_heads=16,
    head_dim=128,
    num_experts=64,
    top_k=6,
    expert_inter=1408,
    shared_inter=2816,
    max_adapters=20,
    e_max=13,
    kv_cap=0,
    max_seqs=256,
    buckets=(),
)

CONFIGS = {c.name: c for c in (TINY, SMALL, PAPER16B)}
