"""Grouped Matrix Multiplication (GMM) — the MoE expert-computation
substrate (the role of ``torch_npu.npu_grouped_matmul`` in the paper).

Semantics: rows of ``x`` are sorted by expert; ``group_offsets[g]`` /
``group_offsets[g+1]`` delimit the rows owned by expert ``g``; each row
block is multiplied by its owner's weight matrix::

    out[offsets[g]:offsets[g+1]] = x[offsets[g]:offsets[g+1]] @ w[g]

The paper deliberately keeps this operator *unmodified* — ExpertWeave's
whole design (virtual weight tensor + batched rerouting) exists so the GMM
only ever sees one ordinary stacked ``[G, H_in, H_out]`` tensor and
ordinary expert IDs. We reproduce that property: the serving graph calls
the same GMM for base-model and adapter experts alike.

Two implementations:

* :func:`grouped_matmul` — the one used in the serving graph. A
  ``lax.while_loop`` walks (group, row) cursors and multiplies one
  ``blk``-row block per iteration, skipping empty groups with a real branch
  (``lax.cond``), so compute scales with *occupied* rows + one partial
  block per active group, never with ``G``. Trip count is data-dependent;
  shapes stay static. This mirrors how a ragged NPU GMM walks group
  descriptors.

* :func:`gmm_pallas` — a Pallas block-table formulation (grid over fixed
  blocks, one expert per block) matching how the kernel would be tiled for
  the TPU MXU: each grid step does a ``[blk, H_in] x [H_in, H_out]`` MXU
  matmul with both tiles VMEM-resident (see DESIGN.md section 6). Used by
  kernel tests and the TPU-design discussion; not in the CPU serving graph
  because interpret-mode cannot skip empty blocks.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


@functools.partial(jax.jit, static_argnames=("blk",))
def grouped_matmul(x_sorted, w, group_offsets, *, blk):
    """Ragged grouped matmul with data-dependent trip count.

    Args:
      x_sorted:      ``[R, H_in]`` rows sorted by owning expert.
      w:             ``[G, H_in, H_out]`` stacked expert weights (the
                     virtual weight tensor view).
      group_offsets: ``[G + 1]`` int32 row offsets (non-decreasing,
                     ``group_offsets[G] == R``).
      blk:           static row-block size.

    Returns:
      ``[R, H_out]`` with rows in the same (sorted) order.
    """
    r, h_in = x_sorted.shape
    g_total, _, h_out = w.shape
    # Pad rows so a block starting at the last row may overrun safely.
    xp = jnp.concatenate([x_sorted, jnp.zeros((blk, h_in), x_sorted.dtype)], 0)
    out0 = jnp.zeros((r + blk, h_out), x_sorted.dtype)

    def cond(state):
        g, _, _ = state
        return g < g_total

    def body(state):
        g, row, out = state
        end = group_offsets[g + 1]

        def compute(out):
            xb = jax.lax.dynamic_slice(xp, (row, 0), (blk, h_in))
            wg = jax.lax.dynamic_slice(w, (g, 0, 0), (1, h_in, h_out))[0]
            yb = xb @ wg
            # Rows past the group end belong to the next group; keep the
            # existing values there (they are rewritten when g advances).
            valid = (row + jnp.arange(blk)) < end
            cur = jax.lax.dynamic_slice(out, (row, 0), (blk, h_out))
            merged = jnp.where(valid[:, None], yb, cur)
            return jax.lax.dynamic_update_slice(out, merged, (row, 0))

        # Real branch: empty groups cost one cheap iteration, no matmul.
        out = jax.lax.cond(end > row, compute, lambda o: o, out)
        row_next = jnp.minimum(row + blk, end)
        g_next = jnp.where(row_next >= end, g + 1, g)
        return g_next, row_next, out

    _, _, out = jax.lax.while_loop(
        cond, body, (jnp.int32(0), jnp.int32(0), out0)
    )
    return out[:r]


def _gmm_block_kernel(block_expert_ref, block_start_ref, x_ref, w_ref, out_ref):
    """One fixed block: rows [start, start+blk) x w[expert] -> out rows."""
    b = pl.program_id(0)
    e = block_expert_ref[b]
    start = block_start_ref[b]
    blk = out_ref.shape[1]            # out block is [1, blk, H_out]
    h_in = x_ref.shape[1]
    xb = pl.load(x_ref, (pl.dslice(start, blk), pl.dslice(0, h_in)))
    wg = w_ref[e]
    out_ref[0, :, :] = xb @ wg


def gmm_pallas(x_sorted, w, block_expert, block_start, *, blk):
    """Block-table GMM as a Pallas kernel (TPU-tiled formulation).

    The caller supplies a *block table*: ``block_expert[b]`` owns rows
    ``[block_start[b], block_start[b] + blk)`` of ``x_sorted`` (blocks are
    group-aligned; partial blocks duplicate the preceding rows and are
    masked by the caller via row indices). Output block ``b`` holds the
    product for exactly those rows.

    Returns ``[NB, blk, H_out]`` per-block outputs; the caller scatters
    them back by row (see ``ref.gmm_blocktable_combine``).
    """
    nb = block_expert.shape[0]
    r, h_in = x_sorted.shape
    _, _, h_out = w.shape
    xp = jnp.concatenate([x_sorted, jnp.zeros((blk, h_in), x_sorted.dtype)], 0)
    return pl.pallas_call(
        _gmm_block_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec(block_expert.shape, lambda b: (0,)),
            pl.BlockSpec(block_start.shape, lambda b: (0,)),
            pl.BlockSpec(xp.shape, lambda b: (0, 0)),
            pl.BlockSpec(w.shape, lambda b: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk, h_out), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, blk, h_out), x_sorted.dtype),
        interpret=True,
    )(block_expert, block_start, xp, w)


def sort_by_expert(ids_flat, g_total):
    """Sort flattened top-k expert IDs and derive GMM group offsets.

    Args:
      ids_flat: ``[R]`` int32 expert IDs (already rerouted, in the
                ``G``-slot domain).
      g_total:  static number of expert slots ``G``.

    Returns:
      ``(perm, group_offsets)`` where ``perm`` is the stable argsort of
      ``ids_flat`` (``ids_flat[perm]`` is sorted) and ``group_offsets`` is
      the ``[G + 1]`` int32 offsets array for :func:`grouped_matmul`.
    """
    perm = jnp.argsort(ids_flat, stable=True)
    sorted_ids = ids_flat[perm]
    group_offsets = jnp.searchsorted(
        sorted_ids, jnp.arange(g_total + 1, dtype=ids_flat.dtype), side="left"
    ).astype(jnp.int32)
    return perm, group_offsets
