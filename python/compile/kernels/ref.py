"""Pure-jnp / numpy oracles for every kernel and for the MoE layer.

These are the correctness ground truth: deliberately simple, loop-based or
dense formulations with no performance tricks. ``python/tests`` sweeps the
real kernels against these with hypothesis.
"""

import numpy as np


def reroute_ref(ids, aid, expert_map):
    """Oracle for batched rerouting: plain advanced indexing."""
    ids = np.asarray(ids)
    aid = np.asarray(aid)
    emap = np.asarray(expert_map)
    return emap[aid[:, None] + 1, ids]


def gmm_ref(x_sorted, w, group_offsets):
    """Oracle for grouped matmul: per-group numpy loop."""
    x_sorted = np.asarray(x_sorted)
    w = np.asarray(w)
    offs = np.asarray(group_offsets)
    out = np.zeros((x_sorted.shape[0], w.shape[2]), x_sorted.dtype)
    for g in range(w.shape[0]):
        lo, hi = offs[g], offs[g + 1]
        if hi > lo:
            out[lo:hi] = x_sorted[lo:hi] @ w[g]
    return out


def build_block_table(group_offsets, blk):
    """Host/numpy construction of a group-aligned block table for
    :func:`compile.kernels.gmm.gmm_pallas`.

    Every group is covered by ``ceil(len/blk)`` blocks starting at the
    group start; the trailing block of a group may overrun into the next
    group, so a per-block row-validity count is returned for masking.

    Returns ``(block_expert, block_start, block_rows)`` numpy arrays.
    """
    offs = np.asarray(group_offsets)
    be, bs, brows = [], [], []
    for g in range(len(offs) - 1):
        lo, hi = int(offs[g]), int(offs[g + 1])
        row = lo
        while row < hi:
            be.append(g)
            bs.append(row)
            brows.append(min(blk, hi - row))
            row += blk
    return (
        np.asarray(be, np.int32),
        np.asarray(bs, np.int32),
        np.asarray(brows, np.int32),
    )


def gmm_blocktable_combine(block_out, block_start, block_rows, r):
    """Scatter per-block outputs back into ``[R, H_out]`` row order."""
    block_out = np.asarray(block_out)
    out = np.zeros((r, block_out.shape[2]), block_out.dtype)
    for b in range(block_out.shape[0]):
        n = int(block_rows[b])
        s = int(block_start[b])
        out[s : s + n] = block_out[b, :n]
    return out


def rms_norm_ref(x, gamma, eps):
    x = np.asarray(x, np.float32)
    var = np.mean(x * x, axis=-1, keepdims=True)
    return (x / np.sqrt(var + eps)) * np.asarray(gamma)


def silu_ref(x):
    x = np.asarray(x, np.float32)
    return x / (1.0 + np.exp(-x))


def moe_layer_ref(x, router_w, w_gate, w_up, w_down, top_k, aid=None, expert_map=None):
    """Oracle for a full MoE layer (router -> [reroute] -> experts -> combine).

    Dense per-token loop; ``w_*`` are stacked ``[G, .., ..]`` tensors.
    If ``aid``/``expert_map`` are given, applies ESFT rerouting between
    routing and expert computation (ExpertWeave semantics).
    """
    x = np.asarray(x, np.float32)
    t, _ = x.shape
    logits = x @ np.asarray(router_w)          # [T, M] — router over base experts
    e = np.exp(logits - logits.max(-1, keepdims=True))
    gate = e / e.sum(-1, keepdims=True)
    # stable top-k (ties broken by lower expert id, matching lax.top_k)
    idx = np.argsort(-gate, axis=-1, kind="stable")[:, :top_k]
    wts = np.take_along_axis(gate, idx, axis=-1)
    wts = wts / wts.sum(-1, keepdims=True)
    if expert_map is not None:
        idx = reroute_ref(idx.astype(np.int32), aid, expert_map)
    out = np.zeros_like(x)
    for ti in range(t):
        for k in range(top_k):
            g = int(idx[ti, k])
            h = silu_ref(x[ti] @ w_gate[g]) * (x[ti] @ w_up[g])
            out[ti] += wts[ti, k] * (h @ w_down[g])
    return out.astype(np.float32)


def attention_ref(q, k_cache, v_cache, q_pos, q_seg, cache_pos, cache_seg, scale):
    """Oracle for slot-pool GQA attention with segment+causal masking.

    q: [T, QH, D]; caches: [CAP, KVH, D]. Query head h attends to kv head
    ``h // (QH // KVH)``. Fully masked rows return zeros.
    """
    q = np.asarray(q, np.float32)
    k_cache = np.asarray(k_cache, np.float32)
    v_cache = np.asarray(v_cache, np.float32)
    t, qh, d = q.shape
    cap, kvh, _ = k_cache.shape
    groups = qh // kvh
    out = np.zeros_like(q)
    for ti in range(t):
        for h in range(qh):
            kvhead = h // groups
            scores = (k_cache[:, kvhead] @ q[ti, h]) * scale
            mask = (
                (np.asarray(cache_seg) == q_seg[ti])
                & (np.asarray(cache_pos) <= q_pos[ti])
                & (np.asarray(cache_seg) >= 0)
            )
            if not mask.any() or q_seg[ti] < 0:
                continue
            scores = np.where(mask, scores, -1e30)
            w = np.exp(scores - scores.max())
            w = w / w.sum()
            out[ti, h] = w @ v_cache[:, kvhead]
    return out
