"""L1: the paper's fused **batched rerouting** kernel (section 4.3).

After the MoE router emits base-model top-k expert IDs, every ID belonging
to a token of adapter ``i`` must be redirected to its fine-tuned counterpart
in the virtual weight tensor, via the per-layer ESFT expert map::

    TopK'(x) = { Pi[A(x), j] : j in TopK(x) }

where ``A(x)`` is the token's adapter ID (AID, -1 = base model) and
``Pi[i, j]`` is either ``j`` (expert not fine-tuned by adapter ``i``) or
``Delta_i + delta_ij`` (slot of the fine-tuned copy).

The paper implements this as a fused kernel on Ascend vector cores to avoid
the launch overhead + HBM round-trips of a chain of canonical ops
(broadcast AID, compute offsets, gather). We express the same fusion as a
single Pallas kernel: one VMEM-resident pass, grid tiled over tokens.
``ExpertWeave-SingleOp`` (the paper's unfused baseline, Fig. 7) is
reproduced by :func:`reroute_singleop`, whose stages are separated with
``optimization_barrier`` so XLA cannot re-fuse them.

Conventions:
  * AID ``-1`` denotes the base model. The expert map is stored with a
    leading identity row so row index = ``aid + 1``.
  * ``expert_map`` has shape ``[N + 1, M]`` (int32); output IDs index the
    virtual weight tensor's ``G = M + N * E_max`` expert slots.

TPU mapping (DESIGN.md section 6): the whole map (``(N+1) * M`` int32,
<= 21*64*4 B = 5.2 KB for the paper geometry) fits in VMEM alongside a
``[T_blk, K]`` ID tile; the kernel is a pure vector-unit pass with no MXU
involvement and no intermediate HBM traffic.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows per grid step of the fused kernel. 256 rows x K<=8 int32 = 8 KB of
# VMEM for the ID tile; the full expert map rides along in every step.
_TOKEN_BLOCK = 256


def _reroute_kernel(ids_ref, aid_ref, emap_ref, out_ref):
    """One fused pass: broadcast AID, compute flat offsets, gather."""
    ids = ids_ref[...]                    # [Tb, K] int32 base-expert IDs
    aid = aid_ref[...]                    # [Tb]    int32 adapter IDs (-1 = base)
    emap = emap_ref[...]                  # [N+1, M] int32
    m = emap.shape[1]
    # row 0 of emap is the identity (base model); adapter i -> row i+1.
    flat = (aid[:, None] + 1) * m + ids   # [Tb, K] flat offsets into emap
    out_ref[...] = jnp.take(emap.reshape(-1), flat.reshape(-1), axis=0).reshape(ids.shape)


@functools.partial(jax.jit, static_argnames=())
def reroute_fused(ids, aid, expert_map):
    """Fused batched rerouting (ExpertWeave).

    Args:
      ids:        ``[T, K]`` int32 router top-k base-expert IDs.
      aid:        ``[T]`` int32 adapter ID per token, ``-1`` = base model.
      expert_map: ``[N + 1, M]`` int32 ESFT expert map with identity row 0.

    Returns:
      ``[T, K]`` int32 expert slots in the virtual weight tensor.
    """
    t, k = ids.shape
    blk = min(_TOKEN_BLOCK, t)
    if t % blk != 0:  # buckets are powers of two; this is for odd test shapes
        blk = t
    grid = (t // blk,)
    return pl.pallas_call(
        _reroute_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk, k), lambda i: (i, 0)),
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec(expert_map.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((blk, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, k), jnp.int32),
        interpret=True,  # CPU-PJRT execution path (see DESIGN.md section 6)
    )(ids, aid, expert_map)


def reroute_singleop(ids, aid, expert_map):
    """Unfused batched rerouting (ExpertWeave-SingleOp baseline, Fig. 7).

    The canonical-operator implementation the paper benchmarks against:
    (1) broadcast the AID array, (2) compute offsets into the expert map,
    (3) gather. Each stage is fenced with ``optimization_barrier`` so it
    stays a separate materialized op, modelling the per-kernel launch
    overhead and intermediate HBM round-trips of the PyTorch version.
    """
    t, k = ids.shape
    m = expert_map.shape[1]
    # stage 1: broadcast AID across the top-k dimension
    aid_b = jax.lax.optimization_barrier(jnp.broadcast_to(aid[:, None], (t, k)))
    # stage 2: offsets inside the ESFT expert map
    flat = jax.lax.optimization_barrier((aid_b + 1) * m + ids)
    # stage 3: gather
    out = jnp.take(expert_map.reshape(-1), flat.reshape(-1), axis=0).reshape(t, k)
    return jax.lax.optimization_barrier(out)


def build_expert_map(num_experts, e_max, adapter_experts):
    """Host-side construction of the ESFT expert map ``Pi`` for one layer.

    ``adapter_experts`` is a list over adapter slots; entry ``i`` is the
    (possibly empty) sorted list of base-expert IDs fine-tuned by adapter
    ``i`` in this layer. Mirrors ``rust/src/adapters/expert_map.rs``; used
    by tests and the AOT self-check.

    Returns an ``[N + 1, M]`` int32 array with identity row 0 and
    ``Pi[i + 1, j] = Delta_i + delta_ij`` for fine-tuned experts, where
    ``Delta_i = M + i * E_max``.
    """
    import numpy as np

    n = len(adapter_experts)
    m = num_experts
    pi = np.tile(np.arange(m, dtype=np.int32), (n + 1, 1))
    for i, experts in enumerate(adapter_experts):
        assert len(experts) <= e_max, "adapter exceeds E_max"
        delta = m + i * e_max
        for off, j in enumerate(sorted(experts)):
            pi[i + 1, j] = delta + off
    return jnp.asarray(pi)
