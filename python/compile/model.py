"""L2: the MoE transformer step function (JAX, build-time only).

One *step* executes a packed token batch (mixed chunked-prefill + decode
tokens, possibly from requests to different ESFT adapters) through the full
model, updating a device-resident KV slot-pool cache:

    step(params, kv_cache, token_ids, positions, seg_ids, slot_idx,
         cache_seg, cache_pos, out_rows[, aid, expert_maps])
      -> (logits[O, V], kv_cache')

* ``kv_cache`` ``[L, 2, CAP, KVH, D]`` is donated: the lowered HLO carries
  ``input_output_alias`` so PJRT updates it in place and the Rust runtime
  chains the output buffer into the next step (no host round-trip).
* Attention is GQA over the whole slot pool with a
  ``(same segment) and (cache_pos <= q_pos)`` mask — functional
  slot-granularity paged attention. New K/V are scattered at ``slot_idx``
  (out-of-range index = dropped ⇒ padding tokens write nothing).
* The MoE path is: router over the M *base* experts → **batched
  rerouting** (L1 Pallas kernel; `weave` variant) → sort by expert →
  unmodified grouped matmul over the stacked ``[G, ..]`` expert tensor →
  weighted combine. The `base` variant skips rerouting (G = M); the
  `singleop` variant uses the unfused rerouting baseline (Fig. 7).
* ``out_rows`` selects which token rows get logits (last token of each
  live sequence); the LM head runs only on those O rows.

Weights arrive as a flat, *named*, ordered tuple — the order is the
artifact ABI recorded in ``meta.json`` and consumed by
``rust/src/runtime``.
"""

import functools

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels.gmm import grouped_matmul, sort_by_expert
from .kernels.reroute import reroute_fused, reroute_singleop

VARIANTS = ("base", "weave", "singleop")


# ---------------------------------------------------------------------------
# Parameter manifest (the artifact ABI)
# ---------------------------------------------------------------------------

def param_spec(cfg: ModelConfig, variant: str):
    """Ordered list of ``(name, shape)`` for every weight tensor.

    `weave`/`singleop` size the stacked expert tensors with
    ``G = M + N * E_max`` slots (the virtual weight tensor); `base` uses
    ``G = M`` (a merged or base-only deployment).
    """
    assert variant in VARIANTS
    g = cfg.num_experts if variant == "base" else cfg.total_expert_slots
    h, v = cfg.hidden, cfg.vocab
    qd = cfg.q_heads * cfg.head_dim
    kd = cfg.kv_heads * cfg.head_dim
    f, s, m = cfg.expert_inter, cfg.shared_inter, cfg.num_experts
    spec = [("embed", (v, h))]
    for l in range(cfg.layers):
        p = f"layer{l}."
        spec += [
            (p + "ln_attn", (h,)),
            (p + "wq", (h, qd)),
            (p + "wk", (h, kd)),
            (p + "wv", (h, kd)),
            (p + "wo", (qd, h)),
            (p + "ln_ffn", (h,)),
            (p + "router", (h, m)),
            (p + "w_gate", (g, h, f)),
            (p + "w_up", (g, h, f)),
            (p + "w_down", (g, f, h)),
            (p + "shared_gate", (h, s)),
            (p + "shared_up", (h, s)),
            (p + "shared_down", (s, h)),
        ]
    spec += [("ln_final", (h,)), ("lm_head", (h, v))]
    return spec


def init_params(cfg: ModelConfig, variant: str, seed: int = 0):
    """Random-init weights following :func:`param_spec` (tests / examples)."""
    key = jax.random.PRNGKey(seed)
    out = []
    for name, shape in param_spec(cfg, variant):
        key, sub = jax.random.split(key)
        if name.endswith(("ln_attn", "ln_ffn", "ln_final")):
            arr = jnp.ones(shape, jnp.float32)
        else:
            fan_in = shape[-2] if len(shape) > 1 else shape[-1]
            arr = jax.random.normal(sub, shape, jnp.float32) / jnp.sqrt(fan_in)
        out.append(arr)
    return tuple(out)


class _P:
    """Name-based accessor over the flat ordered parameter tuple."""

    def __init__(self, cfg, variant, params):
        names = [n for n, _ in param_spec(cfg, variant)]
        assert len(names) == len(params), (len(names), len(params))
        self._d = dict(zip(names, params))

    def __call__(self, name):
        return self._d[name]


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------

def rms_norm(x, gamma, eps):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)) * gamma


def rope_tables(positions, d, theta):
    """cos/sin tables for RoPE — layer-invariant, computed once per step."""
    half = d // 2
    freqs = jnp.arange(half, dtype=jnp.float32) * (-2.0 / d)
    inv = jnp.power(theta, freqs)                      # [half]
    ang = positions.astype(jnp.float32)[:, None] * inv[None, :]   # [T, half]
    return jnp.cos(ang)[:, None, :], jnp.sin(ang)[:, None, :]


def rope(x, positions, theta):
    """Rotary embedding, GPT-NeoX (half-split) style. x: [T, H, D]."""
    cos, sin = rope_tables(positions, x.shape[-1], theta)
    return rope_apply(x, cos, sin)


def rope_apply(x, cos, sin):
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def attention_mask(positions, seg_ids, cache_seg, cache_pos):
    """`[T, CAP]` (same segment) ∧ (causal) ∧ (slot live) mask plus the
    per-row any-valid flag — layer-invariant, computed once per step."""
    mask = (
        (cache_seg[None, :] == seg_ids[:, None])
        & (cache_pos[None, :] <= positions[:, None])
        & (cache_seg[None, :] >= 0)
    )
    any_valid = jnp.any(mask, axis=-1)
    return mask, any_valid


def attention(q, k_cache, v_cache, positions, seg_ids, cache_seg, cache_pos, cfg,
              mask=None, any_valid=None):
    """Slot-pool GQA attention. q: [T, QH, D]; caches: [CAP, KVH, D]."""
    t = q.shape[0]
    groups = cfg.q_heads // cfg.kv_heads
    qg = q.reshape(t, cfg.kv_heads, groups, cfg.head_dim)
    scale = 1.0 / jnp.sqrt(jnp.float32(cfg.head_dim))
    scores = jnp.einsum("tkgd,ckd->tkgc", qg, k_cache) * scale
    if mask is None:
        mask, any_valid = attention_mask(positions, seg_ids, cache_seg, cache_pos)
    scores = jnp.where(mask[:, None, None, :], scores, -1e30)
    attn = jax.nn.softmax(scores, axis=-1)
    # Zero fully-masked (padding) rows instead of emitting a uniform mix.
    attn = jnp.where(any_valid[:, None, None, None], attn, 0.0)
    out = jnp.einsum("tkgc,ckd->tkgd", attn, v_cache)
    return out.reshape(t, cfg.q_heads * cfg.head_dim)


def top_k_stable(gate, k):
    """Descending top-k via a stable variadic sort.

    ``lax.top_k`` lowers to the ``topk`` HLO instruction whose text syntax
    changed after xla_extension 0.5.1 (the version behind the Rust `xla`
    crate); a stable ``sort`` is plain HLO that round-trips. Ties break
    toward the lower expert ID, matching the numpy oracle.
    """
    t, m = gate.shape
    idx = jax.lax.broadcasted_iota(jnp.int32, (t, m), 1)
    neg_sorted, idx_sorted = jax.lax.sort((-gate, idx), num_keys=1, is_stable=True)
    return -neg_sorted[:, :k], idx_sorted[:, :k]


def moe_layer(h, router_w, w_gate, w_up, w_down, cfg, variant,
              aid=None, expert_map=None, *, blk):
    """Router → [batched rerouting] → sort → GMM → weighted combine.

    ``h`` is the post-norm hidden state ``[T, H]``. Returns the routed-
    expert output ``[T, H]`` (shared expert handled by the caller).
    """
    t = h.shape[0]
    k = cfg.top_k
    g_total = cfg.num_experts if variant == "base" else cfg.total_expert_slots

    gate = jax.nn.softmax(h @ router_w, axis=-1)        # [T, M]
    top_w, top_i = top_k_stable(gate, k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)

    if variant == "weave":
        ids = reroute_fused(top_i, aid, expert_map)
    elif variant == "singleop":
        ids = reroute_singleop(top_i, aid, expert_map)
    else:
        ids = top_i

    r = t * k
    perm, offsets = sort_by_expert(ids.reshape(r), g_total)
    rows = h[perm // k]                                  # [R, H] sorted by expert
    act = jax.nn.silu(grouped_matmul(rows, w_gate, offsets, blk=blk))
    act = act * grouped_matmul(rows, w_up, offsets, blk=blk)
    y_sorted = grouped_matmul(act, w_down, offsets, blk=blk)
    # unsort by gathering through the inverse permutation — a row gather
    # is markedly cheaper than a [R, H] row scatter on CPU (§Perf)
    inv = jnp.zeros((r,), jnp.int32).at[perm].set(jnp.arange(r, dtype=jnp.int32))
    y = y_sorted[inv]
    return jnp.sum(y.reshape(t, k, cfg.hidden) * top_w[..., None], axis=1)


# ---------------------------------------------------------------------------
# The step function
# ---------------------------------------------------------------------------

def make_step(cfg: ModelConfig, variant: str, bucket: int):
    """Build the step function for one (variant, token-bucket) pair."""
    assert variant in VARIANTS
    blk = cfg.gmm_block(bucket)

    def step(params, kv_cache, token_ids, positions, seg_ids, slot_idx,
             cache_seg, cache_pos, out_rows, aid=None, expert_maps=None):
        p = _P(cfg, variant, params)
        x = p("embed")[token_ids]                        # [T, H]
        t = x.shape[0]
        # layer-invariant tables, computed once (§Perf: hoisted out of the
        # layer loop — XLA did not CSE them across the cache scatters)
        cos, sin = rope_tables(positions, cfg.head_dim, cfg.rope_theta)
        mask, any_valid = attention_mask(positions, seg_ids, cache_seg, cache_pos)

        for l in range(cfg.layers):
            pre = f"layer{l}."
            h = rms_norm(x, p(pre + "ln_attn"), cfg.rms_eps)
            q = (h @ p(pre + "wq")).reshape(t, cfg.q_heads, cfg.head_dim)
            kk = (h @ p(pre + "wk")).reshape(t, cfg.kv_heads, cfg.head_dim)
            vv = (h @ p(pre + "wv")).reshape(t, cfg.kv_heads, cfg.head_dim)
            q = rope_apply(q, cos, sin)
            kk = rope_apply(kk, cos, sin)
            # Scatter new K/V into the slot pool; OOB slot (= padding) drops.
            kv_cache = kv_cache.at[l, 0, slot_idx].set(kk, mode="drop")
            kv_cache = kv_cache.at[l, 1, slot_idx].set(vv, mode="drop")
            o = attention(q, kv_cache[l, 0], kv_cache[l, 1],
                          positions, seg_ids, cache_seg, cache_pos, cfg,
                          mask=mask, any_valid=any_valid)
            x = x + o @ p(pre + "wo")

            h = rms_norm(x, p(pre + "ln_ffn"), cfg.rms_eps)
            emap_l = None if variant == "base" else expert_maps[l]
            y = moe_layer(h, p(pre + "router"), p(pre + "w_gate"),
                          p(pre + "w_up"), p(pre + "w_down"), cfg, variant,
                          aid=aid, expert_map=emap_l, blk=blk)
            shared = (jax.nn.silu(h @ p(pre + "shared_gate"))
                      * (h @ p(pre + "shared_up"))) @ p(pre + "shared_down")
            x = x + y + shared

        hf = rms_norm(x, p("ln_final"), cfg.rms_eps)
        sel = hf[jnp.clip(out_rows, 0, t - 1)]           # [O, H]
        logits = sel @ p("lm_head")                      # [O, V]
        return logits, kv_cache

    return step


def step_input_specs(cfg: ModelConfig, variant: str, bucket: int):
    """Ordered ``(name, shape, dtype)`` for the step's non-param inputs.

    Must match the argument order of :func:`make_step`'s ``step`` exactly —
    this is the other half of the artifact ABI.
    """
    t = bucket
    o = min(bucket, cfg.max_seqs)
    specs = [
        ("kv_cache", (cfg.layers, 2, cfg.kv_cap, cfg.kv_heads, cfg.head_dim), "f32"),
        ("token_ids", (t,), "i32"),
        ("positions", (t,), "i32"),
        ("seg_ids", (t,), "i32"),
        ("slot_idx", (t,), "i32"),
        ("cache_seg", (cfg.kv_cap,), "i32"),
        ("cache_pos", (cfg.kv_cap,), "i32"),
        ("out_rows", (o,), "i32"),
    ]
    if variant != "base":
        specs += [
            ("aid", (t,), "i32"),
            ("expert_maps",
             (cfg.layers, cfg.max_adapters + 1, cfg.num_experts), "i32"),
        ]
    return specs


def lower_step(cfg: ModelConfig, variant: str, bucket: int):
    """Lower one step function; returns the jax ``Lowered`` object.

    ``kv_cache`` (the argument right after the params tuple) is donated so
    the HLO carries the input→output alias for in-place cache update.
    """
    step = make_step(cfg, variant, bucket)
    p_shapes = [jax.ShapeDtypeStruct(s, jnp.float32)
                for _, s in param_spec(cfg, variant)]
    arg_shapes = []
    for _, shape, dt in step_input_specs(cfg, variant, bucket):
        dtype = jnp.float32 if dt == "f32" else jnp.int32
        arg_shapes.append(jax.ShapeDtypeStruct(shape, dtype))
    return jax.jit(step, donate_argnums=(1,)).lower(tuple(p_shapes), *arg_shapes)
