"""L2/L1 perf-pass harness: time the compiled step function on CPU and
attribute cost to components (GMM block size sweep, attention, reroute
variants), guiding the optimization log in EXPERIMENTS.md §Perf.

Usage (from python/):

    python -m compile.profile_step --config small --bucket 128 [--sweep-blk]

The timings here use the *same* XLA CPU backend the Rust runtime runs on,
so deltas transfer directly (wall-clock parity was verified against the
Rust engine's execute_time metric).
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from .configs import CONFIGS
from .kernels.gmm import grouped_matmul, sort_by_expert
from .kernels.reroute import reroute_fused, reroute_singleop
from .model import make_step, init_params, step_input_specs


def timeit(fn, *args, reps=5, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def step_args(cfg, variant, bucket, seed=0):
    rng = np.random.default_rng(seed)
    params = init_params(cfg, variant, seed=1)
    args = []
    for name, shape, dt in step_input_specs(cfg, variant, bucket):
        if name == "kv_cache":
            args.append(jnp.zeros(shape, jnp.float32))
        elif name == "token_ids":
            args.append(jnp.asarray(rng.integers(0, cfg.vocab, shape), jnp.int32))
        elif name == "positions":
            args.append(jnp.arange(bucket, dtype=jnp.int32))
        elif name == "seg_ids":
            args.append(jnp.zeros(shape, jnp.int32))
        elif name == "slot_idx":
            args.append(jnp.arange(bucket, dtype=jnp.int32))
        elif name == "cache_seg":
            a = np.full(shape, -1, np.int32)
            a[:bucket] = 0
            args.append(jnp.asarray(a))
        elif name == "cache_pos":
            a = np.zeros(shape, np.int32)
            a[:bucket] = np.arange(bucket)
            args.append(jnp.asarray(a))
        elif name == "out_rows":
            args.append(jnp.zeros(shape, jnp.int32))
        elif name == "aid":
            args.append(jnp.zeros(shape, jnp.int32))  # all adapter 0
        elif name == "expert_maps":
            m = np.tile(np.arange(cfg.num_experts, dtype=np.int32),
                        (cfg.layers, cfg.max_adapters + 1, 1))
            args.append(jnp.asarray(m))
        else:
            raise KeyError(name)
    return params, args


def profile_full_step(cfg, variant, bucket):
    step = jax.jit(make_step(cfg, variant, bucket), donate_argnums=())
    params, args = step_args(cfg, variant, bucket)
    t = timeit(step, params, *args)
    print(f"[step] {variant} bucket={bucket}: {t*1e3:8.1f} ms")
    return t


def profile_gmm_sweep(cfg, bucket):
    """GMM block-size sweep at this bucket's R = bucket * top_k."""
    rng = np.random.default_rng(0)
    r = bucket * cfg.top_k
    g = cfg.total_expert_slots
    h, f = cfg.hidden, cfg.expert_inter
    x = jnp.asarray(rng.normal(size=(r, h)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(g, h, f)).astype(np.float32) * 0.05)
    # realistic routing: top-6 of 64 experts, concentrated
    ids = rng.choice(cfg.num_experts, size=r, p=_concentrated(cfg.num_experts))
    ids = jnp.asarray(np.sort(ids).astype(np.int32))
    perm, offs = sort_by_expert(ids, g)
    xs = x[perm]
    for blk in (4, 8, 16, 32, 64, 128):
        if blk > max(4, r):
            continue
        fn = jax.jit(lambda a, b, c, blk=blk: grouped_matmul(a, b, c, blk=blk))
        t = timeit(fn, xs, w, offs)
        ideal = r * h * f * 2
        print(f"[gmm]  bucket={bucket} blk={blk:4d}: {t*1e3:7.2f} ms "
              f"({ideal/t/1e9:6.2f} GF/s effective)")


def profile_reroute(cfg, bucket):
    rng = np.random.default_rng(0)
    t_, k = bucket, cfg.top_k
    ids = jnp.asarray(rng.integers(0, cfg.num_experts, (t_, k)).astype(np.int32))
    aid = jnp.asarray(rng.integers(-1, cfg.max_adapters, (t_,)).astype(np.int32))
    emap = jnp.asarray(
        np.tile(np.arange(cfg.num_experts, dtype=np.int32),
                (cfg.max_adapters + 1, 1)))
    tf = timeit(jax.jit(reroute_fused), ids, aid, emap)
    ts = timeit(jax.jit(reroute_singleop), ids, aid, emap)
    print(f"[reroute] bucket={bucket}: fused {tf*1e6:7.1f} us  "
          f"singleop {ts*1e6:7.1f} us")


def _concentrated(m):
    p = np.ones(m)
    p[: m // 4] = 6.0
    return p / p.sum()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", default="small")
    ap.add_argument("--bucket", type=int, default=0, help="0 = all buckets")
    ap.add_argument("--sweep-blk", action="store_true")
    ap.add_argument("--variants", default="base,weave")
    args = ap.parse_args()
    cfg = CONFIGS[args.config]
    buckets = [args.bucket] if args.bucket else list(cfg.buckets)
    for b in buckets:
        for v in args.variants.split(","):
            profile_full_step(cfg, v, b)
        profile_reroute(cfg, b)
        if args.sweep_blk:
            profile_gmm_sweep(cfg, b)


if __name__ == "__main__":
    main()
