"""AOT pipeline tests: HLO text emission, sanitizer, manifest/ABI
consistency, and donation aliasing presence."""

import numpy as np
import pytest

from compile.aot import build_manifest, sanitize_hlo_text, to_hlo_text
from compile.configs import CONFIGS, TINY
from compile.model import VARIANTS, lower_step, param_spec, step_input_specs


def test_sanitizer_strips_topk_largest():
    txt = "x = topk(y), k=2, largest=true\nz = add(a, b)"
    out = sanitize_hlo_text(txt)
    assert "largest" not in out
    assert "k=2" in out


def test_sanitizer_rejects_largest_false():
    with pytest.raises(AssertionError):
        sanitize_hlo_text("topk(y), k=2, largest=false")


@pytest.mark.parametrize("variant", VARIANTS)
def test_hlo_text_emitted_and_parseable_header(variant):
    lowered = lower_step(TINY, variant, TINY.buckets[0])
    text = to_hlo_text(lowered)
    assert text.startswith("HloModule")
    # donation alias must survive to the text (kv cache in-place update)
    assert "input_output_alias" in text.splitlines()[0]
    # no new-style topk attribute (old XLA cannot parse it)
    assert "largest=" not in text


def test_manifest_matches_lowered_input_count():
    for variant in VARIANTS:
        man = build_manifest(TINY, variant, TINY.buckets[0])
        lowered = lower_step(TINY, variant, TINY.buckets[0])
        text = to_hlo_text(lowered)
        # entry computation parameters == params + inputs
        want = len(man["params"]) + len(man["inputs"])
        header = text.splitlines()[0]
        # entry_computation_layout={(p0, p1, ...)->...}
        args = header.split("entry_computation_layout={(")[1].split(")->")[0]
        depth = 0
        count = 1
        for c in args:
            if c in "([{":
                depth += 1
            elif c in ")]}":
                depth -= 1
            elif c == "," and depth == 0:
                count += 1
        assert count == want, f"{variant}: {count} != {want}"


def test_manifest_donation_index_points_at_kv():
    man = build_manifest(TINY, "weave", 4)
    assert man["donate_input_index"] == len(man["params"])
    assert man["inputs"][0]["name"] == "kv_cache"


def test_param_spec_order_is_stable():
    names = [n for n, _ in param_spec(TINY, "weave")]
    assert names[0] == "embed"
    assert names[-2:] == ["ln_final", "lm_head"]
    man = build_manifest(TINY, "weave", 4)
    assert [p["name"] for p in man["params"]] == names


def test_all_configs_have_valid_buckets():
    for cfg in CONFIGS.values():
        assert list(cfg.buckets) == sorted(cfg.buckets)
        for b in cfg.buckets:
            assert cfg.gmm_block(b) >= 1
        if cfg.buckets:
            assert cfg.max_seqs <= cfg.kv_cap


def test_input_specs_shapes_consistent():
    for variant in VARIANTS:
        for bucket in TINY.buckets:
            specs = step_input_specs(TINY, variant, bucket)
            d = {n: (s, dt) for n, s, dt in specs}
            assert d["token_ids"][0] == (bucket,)
            assert d["kv_cache"][0][2] == TINY.kv_cap
            assert d["out_rows"][0][0] == min(bucket, TINY.max_seqs)
            if variant == "base":
                assert "aid" not in d
            else:
                assert d["aid"][0] == (bucket,)
                assert d["expert_maps"][0] == (
                    TINY.layers,
                    TINY.max_adapters + 1,
                    TINY.num_experts,
                )


def test_weave_and_singleop_share_param_shapes():
    a = param_spec(TINY, "weave")
    b = param_spec(TINY, "singleop")
    assert a == b
    c = dict(param_spec(TINY, "base"))
    assert c["layer0.w_gate"][0] == TINY.num_experts
