"""L1 kernel tests: grouped matmul (while-loop serving op + Pallas block
formulation) against the numpy oracle, with hypothesis shape/dtype and
group-distribution sweeps."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.gmm import gmm_pallas, grouped_matmul, sort_by_expert
from compile.kernels.ref import (
    build_block_table,
    gmm_blocktable_combine,
    gmm_ref,
)


def _make_groups(rng, r, g):
    """Random non-negative group sizes summing to r (many zeros likely)."""
    cuts = np.sort(rng.integers(0, r + 1, size=g - 1))
    sizes = np.diff(np.concatenate([[0], cuts, [r]]))
    offs = np.concatenate([[0], np.cumsum(sizes)]).astype(np.int32)
    return offs


def _case(rng, r, g, h_in, h_out):
    x = rng.normal(size=(r, h_in)).astype(np.float32)
    w = rng.normal(size=(g, h_in, h_out)).astype(np.float32)
    offs = _make_groups(rng, r, g)
    return x, w, offs


def test_gmm_basic():
    rng = np.random.default_rng(0)
    x, w, offs = _case(rng, 32, 6, 16, 8)
    out = np.asarray(grouped_matmul(x, w, offs, blk=4))
    np.testing.assert_allclose(out, gmm_ref(x, w, offs), rtol=1e-5, atol=1e-5)


def test_gmm_single_group_owns_everything():
    rng = np.random.default_rng(1)
    x = rng.normal(size=(16, 8)).astype(np.float32)
    w = rng.normal(size=(4, 8, 4)).astype(np.float32)
    offs = np.array([0, 0, 16, 16, 16], np.int32)
    out = np.asarray(grouped_matmul(x, w, offs, blk=8))
    np.testing.assert_allclose(out, x @ w[1], rtol=1e-5, atol=1e-5)


def test_gmm_all_groups_empty_but_last():
    rng = np.random.default_rng(2)
    x = rng.normal(size=(8, 4)).astype(np.float32)
    w = rng.normal(size=(5, 4, 4)).astype(np.float32)
    offs = np.array([0, 0, 0, 0, 0, 8], np.int32)
    out = np.asarray(grouped_matmul(x, w, offs, blk=4))
    np.testing.assert_allclose(out, x @ w[4], rtol=1e-5, atol=1e-5)


def test_gmm_zero_rows():
    """R=0 is impossible in serving (buckets > 0) but blocks must not
    explode on empty groups in the middle."""
    rng = np.random.default_rng(3)
    x, w, _ = _case(rng, 8, 3, 4, 4)
    offs = np.array([0, 8, 8, 8], np.int32)
    out = np.asarray(grouped_matmul(x, w, offs, blk=16))  # blk > group size
    np.testing.assert_allclose(out, x @ w[0], rtol=1e-5, atol=1e-5)


@settings(max_examples=40, deadline=None)
@given(
    r=st.sampled_from([1, 4, 8, 32, 96, 128]),
    g=st.sampled_from([1, 3, 8, 17, 64]),
    h_in=st.sampled_from([1, 4, 16]),
    h_out=st.sampled_from([1, 8]),
    blk=st.sampled_from([1, 4, 8, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_gmm_matches_ref_hypothesis(r, g, h_in, h_out, blk, seed):
    rng = np.random.default_rng(seed)
    x, w, offs = _case(rng, r, g, h_in, h_out)
    out = np.asarray(grouped_matmul(x, w, offs, blk=blk))
    np.testing.assert_allclose(out, gmm_ref(x, w, offs), rtol=1e-4, atol=1e-4)


def test_sort_by_expert_offsets():
    ids = np.array([3, 1, 3, 0, 1, 1], np.int32)
    perm, offs = sort_by_expert(ids, 5)
    perm, offs = np.asarray(perm), np.asarray(offs)
    s = ids[perm]
    assert np.array_equal(s, np.sort(ids))
    # offsets bracket each group
    for g in range(5):
        lo, hi = offs[g], offs[g + 1]
        assert np.all(s[lo:hi] == g)
    assert offs[0] == 0 and offs[-1] == len(ids)


def test_sort_by_expert_stability():
    """Stable sort: rows of the same expert stay in token order — required
    so the combine step's scatter-by-perm is a bijection."""
    ids = np.array([2, 2, 2, 2], np.int32)
    perm, _ = sort_by_expert(ids, 3)
    assert np.array_equal(np.asarray(perm), np.arange(4))


@settings(max_examples=30, deadline=None)
@given(
    r=st.sampled_from([1, 16, 64, 257]),
    g=st.sampled_from([2, 8, 64, 324]),
    seed=st.integers(0, 2**31 - 1),
)
def test_sort_by_expert_hypothesis(r, g, seed):
    rng = np.random.default_rng(seed)
    ids = rng.integers(0, g, size=(r,)).astype(np.int32)
    perm, offs = sort_by_expert(ids, g)
    perm, offs = np.asarray(perm), np.asarray(offs)
    assert sorted(perm.tolist()) == list(range(r))  # bijection
    s = ids[perm]
    assert np.all(np.diff(s) >= 0)
    counts = np.bincount(ids, minlength=g)
    assert np.array_equal(np.diff(offs), counts)


def test_gmm_pallas_blocktable():
    rng = np.random.default_rng(7)
    r, g, h_in, h_out, blk = 48, 6, 8, 4, 8
    x, w, offs = _case(rng, r, g, h_in, h_out)
    be, bs, brows = build_block_table(offs, blk)
    if len(be) == 0:
        return
    block_out = np.asarray(gmm_pallas(x, w, be, bs, blk=blk))
    out = gmm_blocktable_combine(block_out, bs, brows, r)
    np.testing.assert_allclose(out, gmm_ref(x, w, offs), rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(
    r=st.sampled_from([8, 32, 64]),
    g=st.sampled_from([2, 5, 16]),
    blk=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_gmm_pallas_matches_ref_hypothesis(r, g, blk, seed):
    rng = np.random.default_rng(seed)
    x, w, offs = _case(rng, r, g, 8, 8)
    be, bs, brows = build_block_table(offs, blk)
    if len(be) == 0:
        return
    block_out = np.asarray(gmm_pallas(x, w, be, bs, blk=blk))
    out = gmm_blocktable_combine(block_out, bs, brows, r)
    np.testing.assert_allclose(out, gmm_ref(x, w, offs), rtol=1e-4, atol=1e-4)
