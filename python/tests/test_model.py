"""L2 model tests: building blocks vs oracles, step shapes, KV-cache
semantics, and the central ExpertWeave property — serving an adapter through
the virtual weight tensor + batched rerouting produces *identical* outputs
to serving the merged model."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile.configs import TINY
from compile.kernels.ref import attention_ref, moe_layer_ref, rms_norm_ref
from compile.kernels.reroute import build_expert_map
from compile.model import (
    _P,
    attention,
    init_params,
    make_step,
    moe_layer,
    param_spec,
    rms_norm,
    rope,
    step_input_specs,
)

CFG = TINY


# ---------------------------------------------------------------------------
# building blocks
# ---------------------------------------------------------------------------

def test_rms_norm_matches_ref():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(5, CFG.hidden)).astype(np.float32)
    g = rng.normal(size=(CFG.hidden,)).astype(np.float32)
    out = np.asarray(rms_norm(jnp.asarray(x), jnp.asarray(g), CFG.rms_eps))
    np.testing.assert_allclose(out, rms_norm_ref(x, g, CFG.rms_eps),
                               rtol=1e-5, atol=1e-5)


def test_rope_preserves_norm():
    rng = np.random.default_rng(1)
    x = rng.normal(size=(6, 2, 16)).astype(np.float32)
    pos = np.arange(6, dtype=np.int32) * 3
    out = np.asarray(rope(jnp.asarray(x), jnp.asarray(pos), 10000.0))
    np.testing.assert_allclose(np.linalg.norm(out, axis=-1),
                               np.linalg.norm(x, axis=-1), rtol=1e-5)


def test_rope_zero_position_is_identity():
    rng = np.random.default_rng(2)
    x = rng.normal(size=(3, 2, 16)).astype(np.float32)
    pos = np.zeros(3, np.int32)
    out = np.asarray(rope(jnp.asarray(x), jnp.asarray(pos), 10000.0))
    np.testing.assert_allclose(out, x, rtol=1e-6, atol=1e-6)


def test_attention_matches_ref():
    rng = np.random.default_rng(3)
    t, cap = 5, CFG.kv_cap
    q = rng.normal(size=(t, CFG.q_heads, CFG.head_dim)).astype(np.float32)
    kc = rng.normal(size=(cap, CFG.kv_heads, CFG.head_dim)).astype(np.float32)
    vc = rng.normal(size=(cap, CFG.kv_heads, CFG.head_dim)).astype(np.float32)
    pos = np.array([2, 0, 1, 5, 3], np.int32)
    seg = np.array([0, 1, 0, -1, 1], np.int32)
    cache_pos = rng.integers(0, 8, size=cap).astype(np.int32)
    cache_seg = rng.integers(-1, 3, size=cap).astype(np.int32)
    out = np.asarray(attention(jnp.asarray(q), jnp.asarray(kc), jnp.asarray(vc),
                               jnp.asarray(pos), jnp.asarray(seg),
                               jnp.asarray(cache_seg), jnp.asarray(cache_pos), CFG))
    ref = attention_ref(q, kc, vc, pos, seg, cache_pos, cache_seg,
                        1.0 / np.sqrt(CFG.head_dim))
    np.testing.assert_allclose(out, ref.reshape(t, -1), rtol=1e-4, atol=1e-4)


def _layer_weights(rng, variant):
    g = CFG.num_experts if variant == "base" else CFG.total_expert_slots
    h, f, m = CFG.hidden, CFG.expert_inter, CFG.num_experts
    return (
        rng.normal(size=(h, m)).astype(np.float32) / np.sqrt(h),
        rng.normal(size=(g, h, f)).astype(np.float32) / np.sqrt(h),
        rng.normal(size=(g, h, f)).astype(np.float32) / np.sqrt(h),
        rng.normal(size=(g, f, h)).astype(np.float32) / np.sqrt(f),
    )


def test_moe_layer_base_matches_ref():
    rng = np.random.default_rng(4)
    router, wg, wu, wd = _layer_weights(rng, "base")
    x = rng.normal(size=(9, CFG.hidden)).astype(np.float32)
    out = np.asarray(moe_layer(jnp.asarray(x), jnp.asarray(router),
                               jnp.asarray(wg), jnp.asarray(wu), jnp.asarray(wd),
                               CFG, "base", blk=4))
    ref = moe_layer_ref(x, router, wg, wu, wd, CFG.top_k)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("variant", ["weave", "singleop"])
def test_moe_layer_adapter_matches_ref(variant):
    rng = np.random.default_rng(5)
    router, wg, wu, wd = _layer_weights(rng, variant)
    x = rng.normal(size=(8, CFG.hidden)).astype(np.float32)
    aid = np.array([-1, 0, 1, 2, 0, -1, 1, 1], np.int32)
    adapter_experts = [[0, 3], [5], [1, 2, 7]]
    emap = build_expert_map(CFG.num_experts, CFG.e_max, adapter_experts)
    out = np.asarray(moe_layer(jnp.asarray(x), jnp.asarray(router),
                               jnp.asarray(wg), jnp.asarray(wu), jnp.asarray(wd),
                               CFG, variant, aid=jnp.asarray(aid),
                               expert_map=emap, blk=4))
    ref = moe_layer_ref(x, router, wg, wu, wd, CFG.top_k,
                        aid=aid, expert_map=np.asarray(emap))
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# the ExpertWeave equivalence property (Table 3's mechanism)
# ---------------------------------------------------------------------------

def _merged_params_from_weave(weave_params, adapter_idx, adapter_experts):
    """Build merged-model params: base expert rows overwritten with the
    adapter's fine-tuned rows from the virtual weight tensor region."""
    m, e_max = CFG.num_experts, CFG.e_max
    names = [n for n, _ in param_spec(CFG, "weave")]
    merged = []
    for name, arr in zip(names, weave_params):
        arr = np.asarray(arr)
        if name.split(".")[-1] in ("w_gate", "w_up", "w_down"):
            l = int(name.split(".")[0][len("layer"):])
            out = arr[:m].copy()
            delta = m + adapter_idx * e_max
            for off, j in enumerate(sorted(adapter_experts[l])):
                out[j] = arr[delta + off]
            merged.append(jnp.asarray(out))
        else:
            merged.append(jnp.asarray(arr))
    return tuple(merged)


def test_weave_equals_merged_end_to_end():
    """Core Table-3 property: a request served through ExpertWeave
    (shared base + adapter slots + rerouting) gets bit-for-bit the logits
    of the merged model."""
    bucket = 16  # enough tokens that the router hits the fine-tuned experts
    rng = np.random.default_rng(6)
    weave_params = init_params(CFG, "weave", seed=1)

    # adapter 0 fine-tunes these base experts per layer
    adapter_experts = [[1, 4], [2]]
    per_layer = [[adapter_experts[l], [], []] for l in range(CFG.layers)]
    emaps = jnp.stack([
        build_expert_map(CFG.num_experts, CFG.e_max, per_layer[l])
        for l in range(CFG.layers)
    ])
    # make the adapter rows differ from base so the test has teeth
    merged_params = _merged_params_from_weave(
        weave_params, 0, adapter_experts)

    t = bucket
    token_ids = rng.integers(0, CFG.vocab, size=t).astype(np.int32)
    positions = np.arange(t, dtype=np.int32)
    seg_ids = np.zeros(t, np.int32)
    slot_idx = np.arange(t, dtype=np.int32)
    cache_seg = np.full(CFG.kv_cap, -1, np.int32)
    cache_seg[:t] = 0
    cache_pos = np.zeros(CFG.kv_cap, np.int32)
    cache_pos[:t] = positions
    o = min(bucket, CFG.max_seqs)
    out_rows = np.full(o, t - 1, np.int32)
    kv = jnp.zeros((CFG.layers, 2, CFG.kv_cap, CFG.kv_heads, CFG.head_dim),
                   jnp.float32)

    weave_step = make_step(CFG, "weave", bucket)
    base_step = make_step(CFG, "base", bucket)

    aid = np.zeros(t, np.int32)  # all tokens belong to adapter 0
    logits_w, kv_w = weave_step(weave_params, kv, token_ids, positions,
                                seg_ids, slot_idx, cache_seg, cache_pos,
                                out_rows, jnp.asarray(aid), emaps)
    logits_m, kv_m = base_step(merged_params, kv, token_ids, positions,
                               seg_ids, slot_idx, cache_seg, cache_pos,
                               out_rows)
    np.testing.assert_allclose(np.asarray(logits_w), np.asarray(logits_m),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(kv_w), np.asarray(kv_m),
                               rtol=1e-5, atol=1e-5)

    # and base-model tokens (aid = -1) must NOT see adapter weights
    aid_base = np.full(t, -1, np.int32)
    logits_b, _ = weave_step(weave_params, kv, token_ids, positions,
                             seg_ids, slot_idx, cache_seg, cache_pos,
                             out_rows, jnp.asarray(aid_base), emaps)
    base_params = tuple(
        jnp.asarray(np.asarray(a)[:CFG.num_experts]) if n.split(".")[-1] in
        ("w_gate", "w_up", "w_down") else a
        for (n, _), a in zip(param_spec(CFG, "weave"), weave_params)
    )
    logits_pure, _ = base_step(base_params, kv, token_ids, positions,
                               seg_ids, slot_idx, cache_seg, cache_pos,
                               out_rows)
    np.testing.assert_allclose(np.asarray(logits_b), np.asarray(logits_pure),
                               rtol=1e-5, atol=1e-5)
    # adapter logits must differ from base logits (the adapter does something)
    assert not np.allclose(np.asarray(logits_w), np.asarray(logits_b))


# ---------------------------------------------------------------------------
# step mechanics
# ---------------------------------------------------------------------------

def _blank_batch(bucket):
    o = min(bucket, CFG.max_seqs)
    return dict(
        token_ids=np.zeros(bucket, np.int32),
        positions=np.zeros(bucket, np.int32),
        seg_ids=np.full(bucket, -1, np.int32),
        slot_idx=np.full(bucket, CFG.kv_cap, np.int32),  # OOB -> dropped
        cache_seg=np.full(CFG.kv_cap, -1, np.int32),
        cache_pos=np.zeros(CFG.kv_cap, np.int32),
        out_rows=np.zeros(o, np.int32),
    )


def test_step_shapes_and_padding_tokens_write_nothing():
    bucket = 4
    params = init_params(CFG, "base", seed=0)
    kv = jnp.full((CFG.layers, 2, CFG.kv_cap, CFG.kv_heads, CFG.head_dim),
                  7.0, jnp.float32)
    b = _blank_batch(bucket)
    step = make_step(CFG, "base", bucket)
    logits, kv2 = step(params, kv, **{k: jnp.asarray(v) for k, v in b.items()})
    assert logits.shape == (min(bucket, CFG.max_seqs), CFG.vocab)
    # all tokens were padding: the cache must be untouched
    np.testing.assert_array_equal(np.asarray(kv2), np.asarray(kv))


def test_step_kv_scatter_targets_only_slots():
    bucket = 4
    params = init_params(CFG, "base", seed=0)
    kv = jnp.zeros((CFG.layers, 2, CFG.kv_cap, CFG.kv_heads, CFG.head_dim),
                   jnp.float32)
    b = _blank_batch(bucket)
    b["seg_ids"] = np.array([0, 0, -1, -1], np.int32)
    b["slot_idx"] = np.array([3, 9, CFG.kv_cap, CFG.kv_cap], np.int32)
    b["token_ids"] = np.array([5, 6, 0, 0], np.int32)
    b["positions"] = np.array([0, 1, 0, 0], np.int32)
    b["cache_seg"][3] = 0
    b["cache_seg"][9] = 0
    b["cache_pos"][9] = 1
    step = make_step(CFG, "base", bucket)
    _, kv2 = step(params, kv, **{k: jnp.asarray(v) for k, v in b.items()})
    kv2 = np.asarray(kv2)
    touched = np.nonzero(np.abs(kv2).sum(axis=(0, 1, 3, 4)))[0]
    assert set(touched.tolist()) <= {3, 9}
    assert np.abs(kv2[:, :, 3]).sum() > 0 and np.abs(kv2[:, :, 9]).sum() > 0


def test_decode_equals_prefill_continuation():
    """Processing [t0 t1 t2] in one step then decoding t3 must equal
    processing [t0..t3] in one step (same cache-pool semantics)."""
    params = init_params(CFG, "base", seed=2)
    rng = np.random.default_rng(8)
    toks = rng.integers(0, CFG.vocab, size=4).astype(np.int32)
    kv0 = jnp.zeros((CFG.layers, 2, CFG.kv_cap, CFG.kv_heads, CFG.head_dim),
                    jnp.float32)
    step4 = make_step(CFG, "base", 4)

    # one-shot: all 4 tokens
    b = _blank_batch(4)
    b.update(token_ids=toks, positions=np.arange(4, dtype=np.int32),
             seg_ids=np.zeros(4, np.int32), slot_idx=np.arange(4, dtype=np.int32))
    b["cache_seg"][:4] = 0
    b["cache_pos"][:4] = np.arange(4)
    b["out_rows"] = np.full(4, 3, np.int32)
    logits_full, _ = step4(params, kv0, **{k: jnp.asarray(v) for k, v in b.items()})

    # split: prefill 3 then decode 1 (decode packed into the same bucket)
    b1 = _blank_batch(4)
    b1.update(token_ids=np.concatenate([toks[:3], [0]]).astype(np.int32),
              positions=np.array([0, 1, 2, 0], np.int32),
              seg_ids=np.array([0, 0, 0, -1], np.int32),
              slot_idx=np.array([0, 1, 2, CFG.kv_cap], np.int32))
    b1["cache_seg"][:3] = 0
    b1["cache_pos"][:3] = np.arange(3)
    _, kv1 = step4(params, kv0, **{k: jnp.asarray(v) for k, v in b1.items()})

    b2 = _blank_batch(4)
    b2.update(token_ids=np.array([toks[3], 0, 0, 0], np.int32),
              positions=np.array([3, 0, 0, 0], np.int32),
              seg_ids=np.array([0, -1, -1, -1], np.int32),
              slot_idx=np.array([3, CFG.kv_cap, CFG.kv_cap, CFG.kv_cap], np.int32))
    b2["cache_seg"][:4] = 0
    b2["cache_pos"][:4] = np.arange(4)
    b2["out_rows"] = np.zeros(4, np.int32)
    logits_split, _ = step4(params, kv1, **{k: jnp.asarray(v) for k, v in b2.items()})

    np.testing.assert_allclose(np.asarray(logits_full[0]),
                               np.asarray(logits_split[0]),
                               rtol=2e-4, atol=2e-4)


def test_param_spec_counts():
    spec_b = param_spec(CFG, "base")
    spec_w = param_spec(CFG, "weave")
    assert len(spec_b) == len(spec_w) == 3 + 13 * CFG.layers
    d = dict(spec_w)
    assert d["layer0.w_gate"][0] == CFG.total_expert_slots
    assert dict(spec_b)["layer0.w_gate"][0] == CFG.num_experts


def test_step_input_specs_variants():
    base = step_input_specs(CFG, "base", 4)
    weave = step_input_specs(CFG, "weave", 4)
    assert [s[0] for s in weave][-2:] == ["aid", "expert_maps"]
    assert len(weave) == len(base) + 2
