"""L1 kernel tests: batched rerouting (fused Pallas + singleop baseline)
against the numpy oracle, including hypothesis sweeps over shapes and
adapter configurations."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import reroute_ref
from compile.kernels.reroute import (
    build_expert_map,
    reroute_fused,
    reroute_singleop,
)


def _random_case(rng, t, k, n, m, e_max):
    ids = rng.integers(0, m, size=(t, k)).astype(np.int32)
    aid = rng.integers(-1, n, size=(t,)).astype(np.int32)
    adapter_experts = []
    for _ in range(n):
        cnt = min(int(rng.integers(0, e_max + 1)), m)
        adapter_experts.append(
            sorted(rng.choice(m, size=cnt, replace=False).tolist())
        )
    emap = np.asarray(build_expert_map(m, e_max, adapter_experts))
    return ids, aid, emap, adapter_experts


def test_identity_for_base_tokens():
    rng = np.random.default_rng(0)
    ids, aid, emap, _ = _random_case(rng, 16, 4, 3, 8, 2)
    aid[:] = -1  # all base-model tokens
    out = np.asarray(reroute_fused(ids, aid, emap))
    assert np.array_equal(out, ids)


def test_fused_matches_ref_basic():
    rng = np.random.default_rng(1)
    ids, aid, emap, _ = _random_case(rng, 32, 6, 4, 16, 5)
    out = np.asarray(reroute_fused(ids, aid, emap))
    assert np.array_equal(out, reroute_ref(ids, aid, emap))


def test_singleop_matches_fused():
    rng = np.random.default_rng(2)
    ids, aid, emap, _ = _random_case(rng, 64, 6, 4, 16, 5)
    a = np.asarray(reroute_fused(ids, aid, emap))
    b = np.asarray(reroute_singleop(ids, aid, emap))
    assert np.array_equal(a, b)


def test_fine_tuned_ids_point_into_adapter_region():
    """Rerouted IDs of adapter tokens must land in [Delta_i, Delta_i+e_i)."""
    rng = np.random.default_rng(3)
    m, e_max, n = 16, 4, 3
    ids, aid, emap, adapter_experts = _random_case(rng, 64, 4, n, m, e_max)
    out = np.asarray(reroute_fused(ids, aid, emap))
    for t in range(ids.shape[0]):
        i = aid[t]
        for k in range(ids.shape[1]):
            j, jj = int(ids[t, k]), int(out[t, k])
            if i < 0 or j not in adapter_experts[i]:
                assert jj == j  # untouched
            else:
                delta = m + i * e_max
                off = adapter_experts[i].index(j)
                assert jj == delta + off  # paper eq. for Pi[i, j]


@settings(max_examples=50, deadline=None)
@given(
    t=st.sampled_from([1, 4, 16, 128, 256, 300]),
    k=st.integers(1, 8),
    n=st.integers(1, 8),
    m=st.sampled_from([4, 8, 64]),
    e_max=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_matches_ref_hypothesis(t, k, n, m, e_max, seed):
    rng = np.random.default_rng(seed)
    ids, aid, emap, _ = _random_case(rng, t, k, n, m, e_max)
    out = np.asarray(reroute_fused(ids, aid, emap))
    assert np.array_equal(out, reroute_ref(ids, aid, emap))


@settings(max_examples=25, deadline=None)
@given(
    t=st.sampled_from([4, 16, 64]),
    k=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_singleop_matches_ref_hypothesis(t, k, seed):
    rng = np.random.default_rng(seed)
    ids, aid, emap, _ = _random_case(rng, t, k, 4, 16, 4)
    out = np.asarray(reroute_singleop(ids, aid, emap))
    assert np.array_equal(out, reroute_ref(ids, aid, emap))


def test_build_expert_map_rejects_overflow():
    with pytest.raises(AssertionError):
        build_expert_map(8, 2, [[0, 1, 2]])


def test_expert_map_identity_row():
    emap = np.asarray(build_expert_map(8, 2, [[1], [0, 7]]))
    assert np.array_equal(emap[0], np.arange(8))
    # adapter 0: expert 1 -> slot 8 + 0*2 + 0
    assert emap[1, 1] == 8
    # adapter 1: experts 0,7 -> slots 10, 11
    assert emap[2, 0] == 10 and emap[2, 7] == 11
