//! Figure 10 — fleet coordination under adapter skew: AdapterAffinity
//! vs JoinShortestQueue vs RoundRobin routing, against the merged
//! per-adapter baseline.
//!
//! Setup: `--replicas` ExpertWeave replicas (sim backend, identical
//! hardware model), each with room for `--capacity` resident adapters,
//! serving a power-law-skewed trace over `--adapters` distinct adapters
//! (default 8, alpha 0.25 — the hot adapter takes roughly half the
//! traffic). Every replica starts with `adapters/replicas` residents;
//! the rest of the lifecycle is the coordinator's problem: load-on-miss
//! (a load costs an adapter-swap weight re-sync that stalls the
//! replica), LRU eviction of idle residents, rate-triggered replication
//! of hot adapters, and bounded per-adapter queues.
//!
//! What the paper's scale argument predicts — and this figure measures:
//! * **RoundRobin** scatters every adapter across every replica, so a
//!   small residency budget turns into continuous swap churn; the fleet
//!   burns its capacity on weight uploads, queues grow, admission
//!   control sheds.
//! * **JoinShortestQueue** balances queue depth but stays adapter-blind
//!   — less queue variance than RR, same churn tax.
//! * **AdapterAffinity** keeps hot adapters resident (hit-dominant
//!   routing) and confines churn to the cold tail, so goodput holds and
//!   sheds stay near zero.
//! * **Merged per-adapter** (ESFT-style, one isolated engine per
//!   adapter on a static share of the same hardware,
//!   [`server::replay_multi`]) cannot rebalance at all: the hot
//!   adapter's instance saturates while cold instances idle.
//!
//! `cargo bench --bench fig10_coordinator [-- --horizon 5 --lambda 30]`

use expertweave::bench::Table;
use expertweave::coordinator::{CoordinatorConfig, RoutingPolicy};
use expertweave::engine::{Engine, EngineOptions};
use expertweave::model::ModelConfig;
use expertweave::runtime::{SimPerf, Variant};
use expertweave::server;
use expertweave::util::args::Args;
use expertweave::weights::StoreMode;
use expertweave::workload::power_law::power_law_shares;
use expertweave::workload::trace::{Trace, TraceSpec};
use std::collections::HashMap;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let a = Args::new("fig10_coordinator", "fleet routing policies under adapter skew")
        .opt("replicas", Some("4"), "fleet replicas")
        .opt("adapters", Some("8"), "distinct adapters")
        .opt("capacity", Some("3"), "resident adapters per replica")
        .opt("lambda", Some("24"), "aggregate req/s")
        .opt("alpha", Some("0.25"), "power-law skew (1 = uniform)")
        .opt("horizon", Some("4"), "trace horizon (s)")
        .opt("queue-cap", Some("32"), "per-adapter outstanding cap")
        .opt("replicate-rps", Some("5"), "hot-adapter replication threshold (req/s)")
        .opt("seed", Some("0"), "workload seed")
        .parse_env()
        .map_err(anyhow::Error::msg)?;
    let replicas: usize = a.get_usize("replicas").map_err(anyhow::Error::msg)?;
    let n_adapters: usize = a.get_usize("adapters").map_err(anyhow::Error::msg)?;
    let capacity: usize = a.get_usize("capacity").map_err(anyhow::Error::msg)?;
    let lambda: f64 = a.get_f64("lambda").map_err(anyhow::Error::msg)?;
    let alpha: f64 = a.get_f64("alpha").map_err(anyhow::Error::msg)?;
    let horizon: f64 = a.get_f64("horizon").map_err(anyhow::Error::msg)?;
    let queue_cap: usize = a.get_usize("queue-cap").map_err(anyhow::Error::msg)?;
    let replicate_rps: f64 = a.get_f64("replicate-rps").map_err(anyhow::Error::msg)?;
    let seed: u64 = a.get_usize("seed").map_err(anyhow::Error::msg)? as u64;

    // device model: near-saturation serving so placement quality shows.
    // A replica completes ~9 req/s (4-deep batches, ~45 steps of ~10 ms
    // per request); `replicas` of them against `lambda` req/s runs ~2/3
    // utilized when routing wastes nothing. An adapter swap stalls its
    // replica for 250 ms — ~25 decode steps of lost work per miss, the
    // cost the affinity policy exists to avoid: at a 50% miss rate the
    // swap tax alone exceeds the fleet's spare capacity.
    let perf = SimPerf {
        step_base: Duration::from_millis(8),
        per_token: Duration::from_micros(150),
        adapter_swap: Duration::from_millis(250),
    };
    let opts = EngineOptions {
        chunk: 64,
        max_seqs: 4,
        page_size: 64 << 10,
        ..Default::default()
    };

    let mut cfg = ModelConfig::sim_default();
    cfg.max_adapters = capacity;
    let adapters = expertweave::adapters::generator::synth_fleet_adapters(&cfg, n_adapters, 42);

    let shares = power_law_shares(n_adapters, alpha);
    let mut trace = Trace::generate(&TraceSpec {
        adapters: adapters
            .iter()
            .map(|ad| (ad.name.clone(), ad.domain.clone()))
            .collect(),
        lambda,
        alpha,
        horizon,
        vocab: cfg.vocab,
        seed,
    });
    trace.clip(96, 48);
    eprintln!(
        "[fig10] {} requests over {horizon}s | {n_adapters} adapters (hot share {:.0}%) | \
         {replicas} replicas x capacity {capacity}",
        trace.len(),
        shares[0] * 100.0
    );

    let mut t = Table::new(&[
        "system", "completed", "goodput req/s", "shed", "rejected", "TTFT p50 ms",
        "hit %", "loads", "evictions",
    ]);

    let offered = trace.len();
    let mut goodputs: HashMap<&'static str, f64> = HashMap::new();
    for policy in [
        RoutingPolicy::AdapterAffinity,
        RoutingPolicy::JoinShortestQueue,
        RoutingPolicy::RoundRobin,
    ] {
        eprintln!("[fig10] running fleet with {policy}...");
        let coord_cfg = CoordinatorConfig {
            replicas,
            policy,
            adapter_capacity: capacity,
            queue_cap,
            replicate_rps: if replicate_rps > 0.0 { replicate_rps } else { f64::INFINITY },
            rate_halflife: 2.0,
            max_copies: replicas.min(3),
            ..Default::default()
        };
        let cfg_spawn = cfg.clone();
        let opts_spawn = opts.clone();
        let outcome = server::replay_fleet(
            coord_cfg,
            move |i| {
                let cfg = cfg_spawn.clone();
                let opts = EngineOptions { seed: i as u64, ..opts_spawn.clone() };
                Box::new(move || {
                    Engine::sim_weave(
                        &cfg,
                        perf,
                        &[],
                        Variant::Weave,
                        StoreMode::Virtual,
                        opts,
                    )
                })
            },
            adapters.clone(),
            &trace,
        )?;
        let r = &outcome.report;
        t.row(&[
            format!("fleet/{policy}"),
            format!("{}/{offered}", r.requests),
            format!("{:.2}", r.goodput()),
            r.shed.to_string(),
            r.rejected.to_string(),
            format!("{:.1}", r.ttft.median * 1e3),
            format!("{:.0}", outcome.stats.hit_rate() * 100.0),
            outcome.stats.loads.to_string(),
            outcome.stats.evictions.to_string(),
        ]);
        eprintln!("[fig10]   {}", outcome.stats.row());
        goodputs.insert(policy.as_str(), r.goodput());
    }

    // merged per-adapter baseline: one isolated instance per adapter on
    // a static 1/n_adapters share of the same `replicas`-device testbed
    eprintln!("[fig10] running merged per-adapter baseline...");
    let share = (replicas as f64 / n_adapters as f64).min(1.0);
    let by_name: HashMap<String, _> = adapters
        .iter()
        .map(|ad| (ad.name.clone(), ad.clone()))
        .collect();
    let builders: Vec<(
        Box<dyn FnOnce() -> anyhow::Result<Engine> + Send>,
        Trace,
    )> = trace
        .split_by_adapter()
        .into_iter()
        .map(|(name, part)| {
            let ad = by_name[&name].clone();
            let cfg2 = cfg.clone();
            let opts2 = EngineOptions {
                compute_share: share,
                ..opts.clone()
            };
            (
                Box::new(move || Engine::sim_merged(&cfg2, perf, ad, opts2))
                    as Box<dyn FnOnce() -> anyhow::Result<Engine> + Send>,
                part,
            )
        })
        .collect();
    let merged = server::aggregate(&server::replay_multi(builders)?);
    t.row(&[
        format!("merged ({n_adapters} inst.)"),
        format!("{}/{offered}", merged.requests),
        format!("{:.2}", merged.goodput()),
        merged.shed.to_string(),
        merged.rejected.to_string(),
        format!("{:.1}", merged.ttft.median * 1e3),
        "-".into(),
        "-".into(),
        "-".into(),
    ]);

    t.print(
        "Figure 10 — adapter-aware fleet routing under skew \
         (affinity keeps hot adapters resident; rr/jsq pay the swap churn; \
         merged cannot rebalance)",
    );
    t.write_csv("fig10_coordinator").ok();

    let aff = goodputs["adapter-affinity"];
    let rr = goodputs["round-robin"];
    let jsq = goodputs["shortest-queue"];
    eprintln!(
        "[fig10] goodput: affinity {aff:.2} vs jsq {jsq:.2} vs rr {rr:.2} req/s \
         ({:+.0}% affinity over rr) | merged {:.2}",
        (aff / rr.max(1e-9) - 1.0) * 100.0,
        merged.goodput()
    );
    Ok(())
}
