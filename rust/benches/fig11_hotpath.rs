//! Figure 11 — step-pipeline hot path: steady-state decode steps/sec
//! (and, with the `alloc-counter` feature, allocations per step) for the
//! zero-allocation fast path (persistent `StepWorkspace` + greedy-token
//! read-off) vs the legacy-equivalent full-logits path
//! (`EngineOptions::sim_full_logits`, which materializes the whole
//! `out_rows × vocab` tensor every step like the pre-workspace pipeline
//! did).
//!
//! Runs on the sim backend with `SimPerf::instant()` — no latency
//! injection — so the measurement is pure pipeline overhead: scheduler
//! packing, KV slot allocation, fused batched reroute, output delivery.
//! A third series re-runs the fast path with the live-telemetry
//! registry disabled (`ObsRegistry::set_enabled(false)`) to isolate the
//! cost of always-on metric recording (a handful of relaxed atomic adds
//! per step — expected to be measurement noise). A fourth series drives
//! a mixed greedy + temperature + nucleus batch (one third each, seeded)
//! so every step takes the per-row sampling path — the production
//! sampling surface must stay allocation-free and within a small factor
//! of the all-greedy fast path.
//!
//! Emits `target/bench_results/BENCH_hotpath.json` — the first point of
//! the repo's perf trajectory; later PRs append comparable runs.
//!
//! `cargo bench --bench fig11_hotpath [-- --seqs 16 --steps 512 --reps 3]`

use expertweave::adapters::format::Adapter;
use expertweave::adapters::generator::synth_fleet_adapters;
use expertweave::bench::Table;
use expertweave::engine::{Engine, EngineOptions, RequestSpec};
use expertweave::model::ModelConfig;
use expertweave::runtime::{SimPerf, Variant};
use expertweave::sampler::SamplingParams;
use expertweave::util::args::Args;
use expertweave::util::json::{obj, Json};
use expertweave::weights::StoreMode;
use std::io::Write;
use std::time::Instant;

#[cfg(feature = "alloc-counter")]
mod counting {
    use expertweave::util::alloc_counter::{allocations as count, CountingAlloc};

    #[global_allocator]
    static GLOBAL: CountingAlloc = CountingAlloc;

    pub fn allocations() -> Option<u64> {
        Some(count())
    }
}

#[cfg(not(feature = "alloc-counter"))]
mod counting {
    pub fn allocations() -> Option<u64> {
        None
    }
}

struct RunResult {
    steps_per_sec: f64,
    ns_per_step: f64,
    allocs_per_step: Option<f64>,
}

/// Drive one engine into steady-state decode and time `steps` steps.
/// `obs` toggles the live-telemetry registry (always-on in production;
/// the off series isolates the recording cost — expected to be noise).
fn run_decode(
    cfg: &ModelConfig,
    adapters: &[Adapter],
    full_logits: bool,
    obs: bool,
    sampled: bool,
    seqs: usize,
    warmup: usize,
    steps: usize,
) -> anyhow::Result<RunResult> {
    let mut e = Engine::sim_weave(
        cfg,
        SimPerf::instant(),
        adapters,
        Variant::Weave,
        StoreMode::Virtual,
        EngineOptions {
            page_size: 64 << 10,
            sim_full_logits: full_logits,
            ..Default::default()
        },
    )?;
    e.obs().set_enabled(obs);
    e.metrics.reserve_steps(warmup + steps + 16);
    for i in 0..seqs {
        let who = (i % 2 == 0).then(|| adapters[0].name.clone());
        // the sampled mix mirrors the hotpath_alloc suite: a third
        // greedy, a third plain temperature, a third nucleus, all seeded
        let sampling = if sampled {
            match i % 3 {
                0 => SamplingParams::greedy(),
                1 => SamplingParams::temperature(0.8).with_seed(100 + i as u64),
                _ => SamplingParams::top_p(0.9, 0.8).with_seed(100 + i as u64),
            }
        } else {
            SamplingParams::greedy()
        };
        e.submit(RequestSpec {
            adapter: who,
            prompt: (1..=8).collect(),
            max_new_tokens: warmup + steps + 8,
            sampling,
        })?;
    }
    for _ in 0..warmup {
        e.step()?;
    }
    let (waiting, running) = e.queue_depth();
    anyhow::ensure!(
        waiting == 0 && running == seqs,
        "not in steady decode: {waiting} waiting, {running}/{seqs} running"
    );
    let a0 = counting::allocations();
    let t0 = Instant::now();
    for _ in 0..steps {
        e.step()?;
    }
    let dt = t0.elapsed().as_secs_f64().max(1e-12);
    let allocs_per_step = a0
        .zip(counting::allocations())
        .map(|(before, after)| (after - before) as f64 / steps as f64);
    e.run_to_completion()?;
    Ok(RunResult {
        steps_per_sec: steps as f64 / dt,
        ns_per_step: dt * 1e9 / steps as f64,
        allocs_per_step,
    })
}

fn main() -> anyhow::Result<()> {
    let a = Args::new("fig11_hotpath", "steady-state step pipeline microbench")
        .opt("seqs", Some("16"), "decoding sequences (= decode batch)")
        .opt("steps", Some("512"), "timed steps per run")
        .opt("warmup", Some("64"), "untimed steps before measuring")
        .opt("reps", Some("3"), "repetitions (best-of reported)")
        .parse_env()
        .map_err(anyhow::Error::msg)?;
    let seqs: usize = a.get_usize("seqs").map_err(anyhow::Error::msg)?;
    let steps: usize = a.get_usize("steps").map_err(anyhow::Error::msg)?;
    let warmup: usize = a.get_usize("warmup").map_err(anyhow::Error::msg)?;
    let reps: usize = a.get_usize("reps").map_err(anyhow::Error::msg)?.max(1);

    let mut cfg = ModelConfig::sim_default();
    cfg.max_seqs = cfg.max_seqs.max(seqs);
    // room for every sequence's full lifetime (conservative reservation)
    cfg.kv_cap = seqs * (8 + warmup + steps + 16);
    anyhow::ensure!(
        seqs <= *cfg.buckets.last().unwrap(),
        "--seqs exceeds the largest token bucket"
    );
    let adapters = synth_fleet_adapters(&cfg, 2, 42);

    let mut fast = None::<RunResult>;
    let mut obs_off = None::<RunResult>;
    let mut full = None::<RunResult>;
    let mut sampled = None::<RunResult>;
    for _ in 0..reps {
        // interleave so host drift cancels; "fastpath" records live
        // telemetry (the production default), "obs off" isolates it
        let f = run_decode(&cfg, &adapters, false, true, false, seqs, warmup, steps)?;
        let o = run_decode(&cfg, &adapters, false, false, false, seqs, warmup, steps)?;
        let l = run_decode(&cfg, &adapters, true, true, false, seqs, warmup, steps)?;
        let s = run_decode(&cfg, &adapters, false, true, true, seqs, warmup, steps)?;
        if fast.as_ref().is_none_or(|b| f.steps_per_sec > b.steps_per_sec) {
            fast = Some(f);
        }
        if obs_off.as_ref().is_none_or(|b| o.steps_per_sec > b.steps_per_sec) {
            obs_off = Some(o);
        }
        if full.as_ref().is_none_or(|b| l.steps_per_sec > b.steps_per_sec) {
            full = Some(l);
        }
        if sampled.as_ref().is_none_or(|b| s.steps_per_sec > b.steps_per_sec) {
            sampled = Some(s);
        }
    }
    let fast = fast.unwrap();
    let obs_off = obs_off.unwrap();
    let full = full.unwrap();
    let sampled = sampled.unwrap();
    anyhow::ensure!(fast.steps_per_sec > 0.0, "fast path measured zero steps/sec");
    let speedup = fast.steps_per_sec / full.steps_per_sec.max(1e-12);
    // recording cost per step (negative = noise; both are best-of-reps)
    let obs_overhead_ns = fast.ns_per_step - obs_off.ns_per_step;

    let fmt_allocs = |a: Option<f64>| match a {
        Some(v) => format!("{v:.2}"),
        None => "n/a (build with --features alloc-counter)".into(),
    };
    let mut t = Table::new(&["path", "steps/s", "ns/step", "allocs/step"]);
    t.row(&[
        "fastpath (obs on)".into(),
        format!("{:.0}", fast.steps_per_sec),
        format!("{:.0}", fast.ns_per_step),
        fmt_allocs(fast.allocs_per_step),
    ]);
    t.row(&[
        "fastpath (obs off)".into(),
        format!("{:.0}", obs_off.steps_per_sec),
        format!("{:.0}", obs_off.ns_per_step),
        fmt_allocs(obs_off.allocs_per_step),
    ]);
    t.row(&[
        "sampled mix (obs on)".into(),
        format!("{:.0}", sampled.steps_per_sec),
        format!("{:.0}", sampled.ns_per_step),
        fmt_allocs(sampled.allocs_per_step),
    ]);
    t.row(&[
        "full-logits (legacy-equiv)".into(),
        format!("{:.0}", full.steps_per_sec),
        format!("{:.0}", full.ns_per_step),
        fmt_allocs(full.allocs_per_step),
    ]);
    t.print(&format!(
        "Figure 11 — steady-state decode hot path ({seqs}-seq batch, \
         {steps} steps, no latency injection): {speedup:.1}x; \
         obs recording {obs_overhead_ns:+.0} ns/step"
    ));
    t.write_csv("fig11_hotpath").ok();
    if speedup < 5.0 {
        eprintln!("[fig11] WARNING: speedup {speedup:.1}x below the 5x target");
    }

    let json = obj(vec![
        ("bench", Json::Str("fig11_hotpath".into())),
        (
            "config",
            obj(vec![
                ("seqs", Json::Int(seqs as i64)),
                ("steps", Json::Int(steps as i64)),
                ("warmup", Json::Int(warmup as i64)),
                ("reps", Json::Int(reps as i64)),
                ("vocab", Json::Int(cfg.vocab as i64)),
                ("layers", Json::Int(cfg.layers as i64)),
                ("top_k", Json::Int(cfg.top_k as i64)),
            ]),
        ),
        (
            "fastpath",
            obj(vec![
                ("steps_per_sec", Json::Num(fast.steps_per_sec)),
                ("ns_per_step", Json::Num(fast.ns_per_step)),
                (
                    "allocs_per_step",
                    fast.allocs_per_step.map_or(Json::Null, Json::Num),
                ),
            ]),
        ),
        (
            "full_logits",
            obj(vec![
                ("steps_per_sec", Json::Num(full.steps_per_sec)),
                ("ns_per_step", Json::Num(full.ns_per_step)),
                (
                    "allocs_per_step",
                    full.allocs_per_step.map_or(Json::Null, Json::Num),
                ),
            ]),
        ),
        // obs-on vs obs-off series: "obs_on" is the same configuration
        // as "fastpath" (recording is the production default)
        (
            "obs_on",
            obj(vec![
                ("steps_per_sec", Json::Num(fast.steps_per_sec)),
                ("ns_per_step", Json::Num(fast.ns_per_step)),
                (
                    "allocs_per_step",
                    fast.allocs_per_step.map_or(Json::Null, Json::Num),
                ),
            ]),
        ),
        (
            "obs_off",
            obj(vec![
                ("steps_per_sec", Json::Num(obs_off.steps_per_sec)),
                ("ns_per_step", Json::Num(obs_off.ns_per_step)),
                (
                    "allocs_per_step",
                    obs_off.allocs_per_step.map_or(Json::Null, Json::Num),
                ),
            ]),
        ),
        // mixed greedy + seeded temperature/nucleus batch: every step
        // takes the per-row sampling path; flat keys are the CI contract
        (
            "sampled",
            obj(vec![
                ("steps_per_sec", Json::Num(sampled.steps_per_sec)),
                ("ns_per_step", Json::Num(sampled.ns_per_step)),
                (
                    "allocs_per_step",
                    sampled.allocs_per_step.map_or(Json::Null, Json::Num),
                ),
            ]),
        ),
        ("sampled_steps_per_s", Json::Num(sampled.steps_per_sec)),
        (
            "sampled_allocs_per_step",
            sampled.allocs_per_step.map_or(Json::Null, Json::Num),
        ),
        ("obs_overhead_ns_per_step", Json::Num(obs_overhead_ns)),
        ("speedup", Json::Num(speedup)),
    ]);
    let dir = std::path::Path::new("target/bench_results");
    std::fs::create_dir_all(dir)?;
    let path = dir.join("BENCH_hotpath.json");
    let mut f = std::fs::File::create(&path)?;
    writeln!(f, "{json}")?;
    eprintln!("[fig11] wrote {}", path.display());
    Ok(())
}
