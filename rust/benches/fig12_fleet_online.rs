//! Figure 12 — online fleet serving under deadline pressure: open-loop
//! Poisson load (arrivals never wait for completions, unlike trace
//! replay's bounded backlog) against the coordinated fleet, one run per
//! routing policy with the *identical* arrival process.
//!
//! What it measures: p50/p99 TTFT and the deadline-miss rate — requests
//! refused at the door as unmeetable plus requests that expired in
//! flight — for RoundRobin / JoinShortestQueue / AdapterAffinity /
//! DeadlineAware. The fleet runs near saturation, so placement quality
//! decides who meets deadlines: DeadlineAware routes by each replica's
//! published decode-step EWMA × queue depth and refuses requests no
//! replica can meet, while the load-blind policies stack queues and let
//! borderline requests expire.
//!
//! Emits `target/bench_results/BENCH_fleet_online.json`.
//!
//! `cargo bench --bench fig12_fleet_online [-- --rate 50 --horizon 4]`

use expertweave::bench::Table;
use expertweave::coordinator::RoutingPolicy;
use expertweave::util::args::Args;
use expertweave::workload::openloop::{
    fleet_online_json, sweep_fleet_policies, FleetLoadSpec, OpenLoopSpec,
};
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let a = Args::new("fig12_fleet_online", "open-loop fleet serving: deadline-miss per policy")
        .opt("replicas", Some("2"), "fleet replicas")
        .opt("adapters", Some("4"), "distinct adapters")
        .opt("capacity", Some("3"), "resident adapters per replica")
        .opt("rate", Some("50"), "offered arrival rate (req/s)")
        .opt("horizon", Some("4"), "arrival horizon (s)")
        .opt("deadline-ms", Some("300"), "per-request completion deadline")
        .opt("alpha", Some("0.5"), "power-law skew (1 = uniform)")
        .opt("seed", Some("0"), "arrival-process seed")
        .parse_env()
        .map_err(anyhow::Error::msg)?;
    let rate: f64 = a.get_f64("rate").map_err(anyhow::Error::msg)?;
    let horizon: f64 = a.get_f64("horizon").map_err(anyhow::Error::msg)?;
    let deadline_ms: f64 = a.get_f64("deadline-ms").map_err(anyhow::Error::msg)?;

    // perf comes from the shared near-saturation hardware model
    // (FleetLoadSpec::near_saturation_perf, via Default): ~25 req/s per
    // replica, so the default 50 req/s over two replicas leaves no
    // slack for bad placement
    let spec = FleetLoadSpec {
        replicas: a.get_usize("replicas").map_err(anyhow::Error::msg)?,
        n_adapters: a.get_usize("adapters").map_err(anyhow::Error::msg)?,
        adapter_capacity: a.get_usize("capacity").map_err(anyhow::Error::msg)?,
        queue_cap: 0,
        open_loop: OpenLoopSpec {
            rate,
            horizon,
            alpha: a.get_f64("alpha").map_err(anyhow::Error::msg)?,
            prompt_len: 24,
            max_new: 8,
            deadline: (deadline_ms > 0.0)
                .then(|| Duration::from_secs_f64(deadline_ms / 1e3)),
            seed: a.get_usize("seed").map_err(anyhow::Error::msg)? as u64,
            ..Default::default()
        },
        ..Default::default()
    };
    eprintln!(
        "[fig12] {} replicas | {} adapters | {rate} req/s x {horizon}s | deadline {deadline_ms} ms",
        spec.replicas, spec.n_adapters
    );

    let policies = [
        RoutingPolicy::DeadlineAware,
        RoutingPolicy::AdapterAffinity,
        RoutingPolicy::JoinShortestQueue,
        RoutingPolicy::RoundRobin,
    ];
    let rows = sweep_fleet_policies(&spec, &policies)?;

    let mut t = Table::new(&[
        "policy", "offered", "completed", "TTFT p50 ms", "TTFT p99 ms",
        "miss %", "door", "expired", "shed",
    ]);
    for r in &rows {
        t.row(&[
            r.policy.to_string(),
            r.outcome.offered.to_string(),
            r.outcome.completed.to_string(),
            format!("{:.1}", r.outcome.ttft.median * 1e3),
            format!("{:.1}", r.outcome.ttft.p99 * 1e3),
            format!("{:.1}", r.outcome.deadline_miss_rate() * 100.0),
            r.outcome.deadline_unmeetable.to_string(),
            r.outcome.deadline_expired.to_string(),
            r.stats.shed_total().to_string(),
        ]);
        eprintln!("[fig12]   {}", r.stats.row());
    }
    t.print(
        "Figure 12 — open-loop fleet serving: deadline-aware routing vs \
         load-blind policies at the same offered load",
    );
    t.write_csv("fig12_fleet_online").ok();

    let json = fleet_online_json(&spec, &rows);
    let dir = std::path::Path::new("target/bench_results");
    std::fs::create_dir_all(dir)?;
    let path = dir.join("BENCH_fleet_online.json");
    std::fs::write(&path, format!("{json}\n"))?;
    eprintln!("[fig12] wrote {}", path.display());

    let miss = |p: RoutingPolicy| {
        rows.iter()
            .find(|r| r.policy == p)
            .map(|r| r.outcome.deadline_miss_rate())
            .unwrap_or(f64::NAN)
    };
    let dl = miss(RoutingPolicy::DeadlineAware);
    let rr = miss(RoutingPolicy::RoundRobin);
    eprintln!(
        "[fig12] deadline-miss: deadline-aware {:.1}% vs round-robin {:.1}%",
        dl * 100.0,
        rr * 100.0
    );
    anyhow::ensure!(
        rows.iter().all(|r| r.outcome.offered > 0),
        "degenerate run: no load offered"
    );
    Ok(())
}
