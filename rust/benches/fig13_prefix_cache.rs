//! Figure 13 — paged KV cache with refcounted cross-request prefix
//! sharing: admitted concurrency and drain time at a fixed KV budget as
//! the workload's shared-prefix fraction grows.
//!
//! Every series runs the same request set on the sim backend with the
//! same `kv_cap`; only the cache policy differs. "flat" is the paged
//! allocator with sharing disabled (`EngineOptions::kv_share = false`,
//! private slots — the pre-paging baseline), "shared" enables the
//! per-adapter prefix index. Prompts draw their first `overlap`
//! fraction from the deterministic per-adapter preamble pool
//! (`workload::preamble_token`), the ESFT-style "identical task
//! preamble" pattern.
//!
//! Expected shape: parity at 0% overlap (nothing to share), and ≥2x
//! peak admitted concurrency at 95% overlap because the scheduler only
//! reserves the blocks a new sequence actually adds.
//!
//! Emits `target/bench_results/BENCH_prefix_cache.json`.
//!
//! `cargo bench --bench fig13_prefix_cache [-- --reqs 64 --prompt 128 --max-new 8]`

use expertweave::adapters::generator::synth_fleet_adapters;
use expertweave::bench::Table;
use expertweave::engine::{Engine, EngineOptions, RequestSpec};
use expertweave::model::ModelConfig;
use expertweave::runtime::{SimPerf, Variant};
use expertweave::sampler::SamplingParams;
use expertweave::util::args::Args;
use expertweave::util::json::{arr, obj, Json};
use expertweave::weights::StoreMode;
use expertweave::workload::preamble_token;
use std::io::Write;
use std::time::Instant;

struct SeriesResult {
    peak_running: usize,
    drain_steps: usize,
    mean_completion_step: f64,
    prefix_hits: u64,
    prefix_misses: u64,
    cow_copies: u64,
    peak_shared_pages: u64,
    wall_secs: f64,
}

/// One prompt of `len` tokens for request `i` on adapter slot `aid_ix`:
/// the first `shared` positions come from the adapter's preamble pool
/// (pool slot 0 so the overlap concentrates), the rest are a private
/// per-request stream drawn from the same hash with a disjoint key.
fn prompt_for(i: usize, aid_ix: u64, len: usize, shared: usize, vocab: usize) -> Vec<i32> {
    (0..len)
        .map(|p| {
            if p < shared {
                preamble_token(aid_ix, 0, p, vocab)
            } else {
                preamble_token(0x1000 + i as u64, 7, p, vocab)
            }
        })
        .collect()
}

fn run_series(
    cfg: &ModelConfig,
    share: bool,
    overlap: f64,
    reqs: usize,
    prompt_len: usize,
    max_new: usize,
) -> anyhow::Result<SeriesResult> {
    let adapters = synth_fleet_adapters(cfg, 2, 42);
    let mut e = Engine::sim_weave(
        cfg,
        SimPerf::instant(),
        &adapters,
        Variant::Weave,
        StoreMode::Virtual,
        EngineOptions {
            page_size: 64 << 10,
            kv_share: share,
            ..Default::default()
        },
    )?;
    let shared = ((prompt_len as f64) * overlap.clamp(0.0, 1.0)).round() as usize;
    let submit = |e: &mut Engine, i: usize| -> anyhow::Result<()> {
        let aid_ix = (i % 2) as u64;
        e.submit(RequestSpec {
            adapter: Some(adapters[aid_ix as usize].name.clone()),
            prompt: prompt_for(i, aid_ix, prompt_len, shared, cfg.vocab),
            max_new_tokens: max_new,
            sampling: SamplingParams::greedy(),
        })?;
        Ok(())
    };
    let mut peak_running = 0usize;
    let mut steps = 0usize;
    let mut done = 0usize;
    let mut completion_steps = 0u64;
    let t0 = Instant::now();
    // prefix sharing is an admission-time attach against blocks already
    // computed by live sequences, so stage the arrivals the way real
    // traffic does: one seed request per adapter prefills (and seals)
    // the preamble blocks, then the flood arrives against a warm cache.
    // The flat baseline runs the identical schedule.
    for i in 0..2.min(reqs) {
        submit(&mut e, i)?;
    }
    let mut seeded = false;
    while e.has_work() {
        let out = e.step()?;
        steps += 1;
        let (_, running) = e.queue_depth();
        peak_running = peak_running.max(running);
        if let Some(cs) = out {
            done += cs.len();
            completion_steps += cs.len() as u64 * steps as u64;
        }
        if !seeded {
            seeded = true;
            for i in 2.min(reqs)..reqs {
                submit(&mut e, i)?;
            }
        }
        anyhow::ensure!(steps < 1_000_000, "series failed to drain");
    }
    let wall_secs = t0.elapsed().as_secs_f64();
    anyhow::ensure!(done == reqs, "only {done}/{reqs} requests completed");
    let s = e.stats_snapshot();
    // shared-pages gauge reads 0 once drained; the metrics report holds
    // the in-flight peak
    let rep = e.report();
    Ok(SeriesResult {
        peak_running,
        drain_steps: steps,
        mean_completion_step: completion_steps as f64 / reqs.max(1) as f64,
        prefix_hits: s.kv_prefix_hits,
        prefix_misses: s.kv_prefix_misses,
        cow_copies: s.kv_pages_cow,
        peak_shared_pages: rep.kv_pages_shared as u64,
        wall_secs,
    })
}

fn main() -> anyhow::Result<()> {
    let a = Args::new("fig13_prefix_cache", "paged KV prefix sharing at fixed memory")
        .opt("reqs", Some("64"), "requests per series")
        .opt("prompt", Some("128"), "prompt tokens per request")
        .opt("max-new", Some("8"), "decode tokens per request")
        .opt("kv-cap", Some("2048"), "KV slots (fixed across all series)")
        .parse_env()
        .map_err(anyhow::Error::msg)?;
    let reqs = a.get_usize("reqs").map_err(anyhow::Error::msg)?;
    let prompt_len = a.get_usize("prompt").map_err(anyhow::Error::msg)?;
    let max_new = a.get_usize("max-new").map_err(anyhow::Error::msg)?.max(1);
    let kv_cap = a.get_usize("kv-cap").map_err(anyhow::Error::msg)?;

    let mut cfg = ModelConfig::sim_default();
    cfg.kv_cap = kv_cap;
    // KV memory is the resource under test: keep the sequence cap and
    // batch buckets from binding first.
    cfg.max_seqs = cfg.max_seqs.max(reqs);
    anyhow::ensure!(
        reqs <= *cfg.buckets.last().unwrap(),
        "--reqs exceeds the largest token bucket"
    );
    anyhow::ensure!(
        prompt_len + max_new <= kv_cap,
        "one request would exceed --kv-cap"
    );

    let overlaps = [0.0, 0.5, 0.95];
    let mut t = Table::new(&[
        "overlap", "policy", "peak running", "drain steps", "mean compl step",
        "hit toks", "miss toks", "cow", "shared pages",
    ]);
    let mut series = Vec::new();
    let mut flat_peak = Vec::new();
    let mut shared_peak = Vec::new();
    for &o in &overlaps {
        for share in [false, true] {
            let r = run_series(&cfg, share, o, reqs, prompt_len, max_new)?;
            let policy = if share { "shared" } else { "flat" };
            t.row(&[
                format!("{:.0}%", o * 100.0),
                policy.into(),
                r.peak_running.to_string(),
                r.drain_steps.to_string(),
                format!("{:.1}", r.mean_completion_step),
                r.prefix_hits.to_string(),
                r.prefix_misses.to_string(),
                r.cow_copies.to_string(),
                r.peak_shared_pages.to_string(),
            ]);
            if share {
                shared_peak.push(r.peak_running);
            } else {
                flat_peak.push(r.peak_running);
            }
            series.push(obj(vec![
                ("overlap", Json::Num(o)),
                ("policy", Json::Str(policy.into())),
                ("peak_running", Json::Int(r.peak_running as i64)),
                ("drain_steps", Json::Int(r.drain_steps as i64)),
                ("mean_completion_step", Json::Num(r.mean_completion_step)),
                ("prefix_hit_tokens", Json::Int(r.prefix_hits as i64)),
                ("prefix_miss_tokens", Json::Int(r.prefix_misses as i64)),
                ("cow_copies", Json::Int(r.cow_copies as i64)),
                ("peak_shared_pages", Json::Int(r.peak_shared_pages as i64)),
                ("wall_secs", Json::Num(r.wall_secs)),
            ]));
        }
    }
    let gain95 = shared_peak[2] as f64 / flat_peak[2].max(1) as f64;
    t.print(&format!(
        "Figure 13 — prefix sharing at fixed KV ({kv_cap} slots, {reqs} reqs x \
         {prompt_len}+{max_new} toks): {gain95:.1}x concurrency at 95% overlap"
    ));
    t.write_csv("fig13_prefix_cache").ok();

    // acceptance: sharing must not regress the no-overlap workload, and
    // must at least double admitted concurrency when 95% of every
    // prompt is a shared preamble
    anyhow::ensure!(
        shared_peak[0] >= flat_peak[0],
        "regression at 0% overlap: shared peak {} < flat peak {}",
        shared_peak[0],
        flat_peak[0]
    );
    anyhow::ensure!(
        gain95 >= 2.0,
        "95%-overlap concurrency gain {gain95:.2}x below the 2x target"
    );

    let json = obj(vec![
        ("bench", Json::Str("fig13_prefix_cache".into())),
        (
            "config",
            obj(vec![
                ("reqs", Json::Int(reqs as i64)),
                ("prompt", Json::Int(prompt_len as i64)),
                ("max_new", Json::Int(max_new as i64)),
                ("kv_cap", Json::Int(kv_cap as i64)),
                ("kv_block", Json::Int(EngineOptions::default().kv_block as i64)),
                ("adapters", Json::Int(2)),
            ]),
        ),
        ("series", arr(series)),
        ("concurrency_gain_95", Json::Num(gain95)),
    ]);
    let dir = std::path::Path::new("target/bench_results");
    std::fs::create_dir_all(dir)?;
    let path = dir.join("BENCH_prefix_cache.json");
    let mut f = std::fs::File::create(&path)?;
    writeln!(f, "{json}")?;
    eprintln!("[fig13] wrote {}", path.display());
    Ok(())
}
