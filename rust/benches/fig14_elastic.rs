//! Figure 14 — elastic fleet under membership change: open-loop Poisson
//! load against a 2-replica sim fleet while one replica is killed
//! mid-run (fault injection) and a fresh replica joins later
//! ([`Coordinator::add_replica`]).
//!
//! What it measures: per-phase throughput and TTFT p50/p99 —
//! **before** (2 healthy replicas), **during** (replica 0 killed at the
//! phase boundary's midpoint load: its in-flight work re-routed to the
//! survivor, which then runs the whole offered load alone), and
//! **after** (a newcomer joins and takes traffic again). Completions
//! are bucketed by *arrival* phase. The run fails loudly if the books
//! don't show exactly one retired replica and at least one re-routed
//! request — the whole point of the figure.
//!
//! Emits `target/bench_results/BENCH_elastic.json`.
//!
//! `cargo bench --bench fig14_elastic [-- --rate 30 --phase 2]`

use expertweave::adapters::generator::synth_fleet_adapters;
use expertweave::bench::Table;
use expertweave::coordinator::{Coordinator, CoordinatorConfig, RoutingPolicy};
use expertweave::engine::{Engine, EngineOptions};
use expertweave::model::ModelConfig;
use expertweave::runtime::{SimPerf, Variant};
use expertweave::sampler::SamplingParams;
use expertweave::serving::{RequestHandle, ServeRequest, ServingBackend, TokenEvent};
use expertweave::util::args::Args;
use expertweave::util::json::{arr, obj, Json};
use expertweave::util::rng::Pcg;
use expertweave::util::stats::Samples;
use expertweave::weights::StoreMode;
use expertweave::workload::openloop::FleetLoadSpec;
use std::time::{Duration, Instant};

const PHASES: [&str; 3] = ["before", "during", "after"];

/// One replica engine on the shared hardware model — same recipe for
/// the founders and the runtime joiner.
fn engine_for(
    cfg: &ModelConfig,
    perf: SimPerf,
    seed: u64,
) -> impl FnOnce() -> anyhow::Result<Engine> + Send + 'static {
    let cfg = cfg.clone();
    move || {
        Engine::sim_weave(
            &cfg,
            perf,
            &[],
            Variant::Weave,
            StoreMode::Virtual,
            EngineOptions { page_size: 64 << 10, max_seqs: 4, seed, ..Default::default() },
        )
    }
}

#[derive(Default)]
struct PhaseBucket {
    offered: usize,
    completed: usize,
    aborted: usize,
    shed: usize,
    ttft: Option<Samples>,
}

fn main() -> anyhow::Result<()> {
    let a = Args::new(
        "fig14_elastic",
        "fleet throughput/TTFT across a kill + runtime-join membership change",
    )
    .opt("adapters", Some("4"), "distinct adapters")
    .opt("capacity", Some("3"), "resident adapters per replica")
    .opt("rate", Some("30"), "offered arrival rate (req/s)")
    .opt("phase", Some("2"), "seconds per phase (before / during / after)")
    .opt("seed", Some("0"), "arrival-process seed")
    .parse_env()
    .map_err(anyhow::Error::msg)?;
    let rate: f64 = a.get_f64("rate").map_err(anyhow::Error::msg)?;
    let phase_s: f64 = a.get_f64("phase").map_err(anyhow::Error::msg)?;
    let n_adapters = a.get_usize("adapters").map_err(anyhow::Error::msg)?;
    let capacity = a.get_usize("capacity").map_err(anyhow::Error::msg)?.max(1);
    let seed = a.get_usize("seed").map_err(anyhow::Error::msg)? as u64;
    anyhow::ensure!(rate > 0.0 && phase_s > 0.0, "rate and phase must be positive");

    let mut cfg = ModelConfig::sim_default();
    cfg.max_adapters = capacity;
    let adapters = synth_fleet_adapters(&cfg, n_adapters, 42);
    let names: Vec<String> = adapters.iter().map(|a| a.name.clone()).collect();

    // the shared near-saturation hardware model: one replica sustains
    // ~25 req/s under this request shape, so the default 30 req/s is
    // comfortable for two replicas and overload for the lone survivor —
    // the "during" TTFT inflation is the signal, not an accident
    let perf = FleetLoadSpec::near_saturation_perf();
    let spawn_cfg = cfg.clone();
    let mut coord = Coordinator::launch(
        CoordinatorConfig {
            replicas: 2,
            policy: RoutingPolicy::AdapterAffinity,
            adapter_capacity: capacity,
            queue_cap: 0,
            max_copies: 2,
            ..Default::default()
        },
        move |i| Box::new(engine_for(&spawn_cfg, perf, i as u64)),
        adapters,
    )?;
    let started = Instant::now();
    eprintln!(
        "[fig14] 2 replicas | {n_adapters} adapters | {rate} req/s | \
         kill replica 0 @ {phase_s}s, join @ {:.0}s",
        2.0 * phase_s
    );

    let mut rng = Pcg::with_stream(seed, 1414);
    let mut buckets: Vec<PhaseBucket> = (0..3).map(|_| PhaseBucket::default()).collect();
    for b in &mut buckets {
        b.ttft = Some(Samples::new());
    }
    // (arrival phase, handle): completions credit the arrival's phase
    let mut live: Vec<(usize, RequestHandle)> = Vec::new();
    let total = 3.0 * phase_s;
    let start = Instant::now();
    let mut next_at = rng.exp(rate);
    let (mut killed, mut joined) = (false, false);
    let stall_limit = Duration::from_secs_f64(total + 120.0);

    loop {
        let now = start.elapsed().as_secs_f64();
        if !killed && now >= phase_s {
            assert!(coord.kill_replica(0), "replica 0 must be live to kill");
            eprintln!("[fig14] t={now:.2}s: killed replica 0");
            killed = true;
        }
        if !joined && now >= 2.0 * phase_s {
            let ix = coord.add_replica(Box::new(engine_for(&cfg, perf, 7)))?;
            eprintln!("[fig14] t={:.2}s: replica {ix} joined", start.elapsed().as_secs_f64());
            joined = true;
        }
        while next_at <= now && next_at <= total {
            let phase = ((next_at / phase_s) as usize).min(2);
            let name = &names[rng.below(names.len() as u64) as usize];
            let len = 12 + rng.below(24) as usize;
            let req = ServeRequest {
                adapter: Some(name.clone()),
                prompt: (0..len)
                    .map(|_| (1 + rng.below(cfg.vocab as u64 - 1)) as i32)
                    .collect(),
                max_new_tokens: 8,
                sampling: SamplingParams::greedy(),
                deadline: None,
                trace: None,
            };
            buckets[phase].offered += 1;
            match coord.submit(req) {
                Ok(h) => live.push((phase, h)),
                Err(_) => buckets[phase].shed += 1,
            }
            next_at += rng.exp(rate);
        }
        coord.pump()?;
        live.retain(|(phase, h)| {
            let mut open = true;
            for ev in h.drain_events() {
                match ev {
                    TokenEvent::Done { completion, .. } => {
                        open = false;
                        buckets[*phase].completed += 1;
                        if let Some(s) = buckets[*phase].ttft.as_mut() {
                            s.push(completion.record.ttft.as_secs_f64());
                        }
                    }
                    TokenEvent::Aborted { .. } => {
                        open = false;
                        buckets[*phase].aborted += 1;
                    }
                    TokenEvent::First { .. } | TokenEvent::Token { .. } => {}
                }
            }
            open
        });
        if next_at > total && live.is_empty() {
            break;
        }
        anyhow::ensure!(
            start.elapsed() <= stall_limit,
            "elastic run stalled: {} stream(s) never terminated",
            live.len()
        );
    }
    let wall = start.elapsed().as_secs_f64();
    ServingBackend::drain(&mut coord)?;
    let (per_replica, stats) = coord.finish(started)?;

    let mut t = Table::new(&[
        "phase", "offered", "completed", "aborted", "rps", "TTFT p50 ms", "TTFT p99 ms",
    ]);
    let mut rows = Vec::new();
    for (i, b) in buckets.iter_mut().enumerate() {
        let s = b.ttft.take().unwrap().summary();
        t.row(&[
            PHASES[i].to_string(),
            b.offered.to_string(),
            b.completed.to_string(),
            b.aborted.to_string(),
            format!("{:.1}", b.completed as f64 / phase_s),
            format!("{:.1}", s.median * 1e3),
            format!("{:.1}", s.p99 * 1e3),
        ]);
        rows.push(obj(vec![
            ("phase", Json::Str(PHASES[i].into())),
            ("offered", Json::Int(b.offered as i64)),
            ("completed", Json::Int(b.completed as i64)),
            ("aborted", Json::Int(b.aborted as i64)),
            ("shed", Json::Int(b.shed as i64)),
            ("throughput_rps", Json::Num(b.completed as f64 / phase_s)),
            ("ttft_p50_ms", Json::Num(s.median * 1e3)),
            ("ttft_p99_ms", Json::Num(s.p99 * 1e3)),
        ]));
    }
    t.print("Figure 14 — elastic fleet: throughput/TTFT across kill + runtime join");
    t.write_csv("fig14_elastic").ok();
    eprintln!("[fig14]   {}", stats.row());
    for (i, r) in per_replica.iter().enumerate() {
        eprintln!("[fig14]   {}", r.row(&format!("replica-{i}")));
    }

    let json = obj(vec![
        ("bench", Json::Str("elastic".into())),
        ("replicas", Json::Int(2)),
        ("adapters", Json::Int(n_adapters as i64)),
        ("rate_rps", Json::Num(rate)),
        ("phase_s", Json::Num(phase_s)),
        ("seed", Json::Int(seed as i64)),
        ("wall_s", Json::Num(wall)),
        ("requests_rerouted", Json::Int(stats.requests_rerouted as i64)),
        ("reroute_aborted", Json::Int(stats.reroute_aborted as i64)),
        ("replica_retired", Json::Int(stats.replica_retired as i64)),
        ("phases", arr(rows)),
    ]);
    let dir = std::path::Path::new("target/bench_results");
    std::fs::create_dir_all(dir)?;
    let path = dir.join("BENCH_elastic.json");
    std::fs::write(&path, format!("{json}\n"))?;
    eprintln!("[fig14] wrote {}", path.display());

    anyhow::ensure!(
        buckets.iter().all(|b| b.completed > 0),
        "degenerate run: a phase completed nothing"
    );
    anyhow::ensure!(stats.replica_retired == 1, "exactly one replica was killed: {stats:?}");
    anyhow::ensure!(
        stats.requests_rerouted >= 1,
        "the kill must land mid-flight and re-route work: {stats:?}"
    );
    Ok(())
}
