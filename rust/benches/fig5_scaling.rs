//! Figure 5 — end-to-end performance serving N ∈ {5, 10, 20} ESFT
//! adapters under uniform (α = 1) and skewed (α = 0.3, 0.1) workloads,
//! vs the vLLM-Ascend (Base-Only) baseline: prefill throughput, TTFT,
//! decode throughput, TPOT as the aggregate arrival rate λ sweeps.
//!
//! Testbed scale: the paper drives 8 Ascend NPUs at λ = 1..5 req/s; this
//! single-core CPU testbed is driven at proportionally scaled λ (see
//! EXPERIMENTS.md "testbed scale"). One weave engine (max adapters
//! resident) and one base-only engine are reused across all cells to
//! amortize PJRT compilation.
//!
//! `cargo bench --bench fig5_scaling [-- --config small --horizon 20
//!    --lambdas 0.2,0.4 --alphas 1.0,0.1 --ns 5,20]`

use expertweave::adapters::generator::{paper_adapter_profiles, synth_adapter};
use expertweave::bench::Table;
use expertweave::engine::{Engine, EngineOptions};
use expertweave::runtime::{ArtifactSet, Variant};
use expertweave::server;
use expertweave::util::args::Args;
use expertweave::weights::StoreMode;
use expertweave::workload::trace::{Trace, TraceSpec};
use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    let a = Args::new("fig5_scaling", "multi-adapter scaling vs base-only")
        .opt("config", Some("small"), "artifact config")
        .opt("horizon", Some("15"), "per-cell horizon (s)")
        .opt("lambdas", Some("0.4"), "aggregate req/s values")
        .opt("alphas", Some("1.0,0.1"), "skew values")
        .opt("ns", Some("5,10,20"), "adapter counts")
        .opt("seed", Some("0"), "workload seed")
        .parse_env()
        .map_err(anyhow::Error::msg)?;
    let dir = PathBuf::from("artifacts").join(a.get_or("config", "small"));
    let set = ArtifactSet::load(&dir)?;
    let cfg = set.config.clone();
    let horizon: f64 = a.get_f64("horizon").map_err(anyhow::Error::msg)?;
    let lambdas: Vec<f64> = a.get_list("lambdas").map_err(anyhow::Error::msg)?;
    let alphas: Vec<f64> = a.get_list("alphas").map_err(anyhow::Error::msg)?;
    let ns: Vec<usize> = a.get_list("ns").map_err(anyhow::Error::msg)?;
    let seed: u64 = a.get_usize("seed").map_err(anyhow::Error::msg)? as u64;

    let n_max = *ns.iter().max().unwrap();
    let profiles = paper_adapter_profiles();
    let adapters: Vec<_> = (0..n_max.min(cfg.max_adapters))
        .map(|i| {
            let mut p = profiles[i % profiles.len()].clone();
            // replicate beyond 10 adapters like the paper
            p.name = Box::leak(format!("{}-{}", p.name, i / profiles.len()).into_boxed_str());
            p.max_experts = p.max_experts.min(cfg.e_max);
            p.avg_experts = p.avg_experts.min(p.max_experts as f64);
            synth_adapter(&p, cfg.layers, cfg.num_experts, cfg.hidden, cfg.expert_inter, 42 + i as u64)
        })
        .collect();

    eprintln!("[fig5] building weave engine ({} adapters resident)...", adapters.len());
    let mut weave = Engine::new_weave(
        &set, &adapters, Variant::Weave, StoreMode::Virtual, EngineOptions::default())?;
    eprintln!("[fig5] building base-only engine...");
    let mut base = Engine::new_base_only(&set, EngineOptions::default())?;

    let mk_trace = |n: usize, lambda: f64, alpha: f64, base_only: bool| {
        let names: Vec<(String, String)> = adapters[..n]
            .iter()
            .map(|ad| (ad.name.clone(), ad.domain.clone()))
            .collect();
        let mut t = Trace::generate(&TraceSpec {
            adapters: names,
            lambda,
            alpha,
            horizon,
            vocab: cfg.vocab,
            seed,
        });
        let max_prompt = cfg.buckets.last().copied().unwrap().min(cfg.kv_cap / 2);
        for e in &mut t.events {
            e.prompt.truncate(max_prompt);
            e.max_new_tokens = e.max_new_tokens.clamp(1, (cfg.kv_cap / 16).max(1));
            if base_only {
                e.adapter = None; // same arrivals, base model only
            }
        }
        t
    };

    let mut t = Table::new(&[
        "system", "alpha", "lambda", "req", "prefill tok/s", "decode tok/s",
        "TTFT p50 ms", "TPOT p50 ms",
    ]);
    for &alpha in &alphas {
        for &lambda in &lambdas {
            // base-only reference for this (alpha, lambda)
            let trace = mk_trace(ns[0].min(adapters.len()), lambda, alpha, true);
            base.reset_session();
            let o = server::replay(&mut base, &trace)?;
            t.row(&[
                "base-only".into(),
                format!("{alpha}"),
                format!("{lambda}"),
                o.report.requests.to_string(),
                format!("{:.1}", o.report.prefill_throughput),
                format!("{:.1}", o.report.decode_throughput),
                format!("{:.1}", o.report.ttft.median * 1e3),
                format!("{:.1}", o.report.tpot.median * 1e3),
            ]);
            for &n in &ns {
                let n = n.min(adapters.len());
                let trace = mk_trace(n, lambda, alpha, false);
                weave.reset_session();
                let o = server::replay(&mut weave, &trace)?;
                t.row(&[
                    format!("weave N={n}"),
                    format!("{alpha}"),
                    format!("{lambda}"),
                    o.report.requests.to_string(),
                    format!("{:.1}", o.report.prefill_throughput),
                    format!("{:.1}", o.report.decode_throughput),
                    format!("{:.1}", o.report.ttft.median * 1e3),
                    format!("{:.1}", o.report.tpot.median * 1e3),
                ]);
                eprintln!(
                    "[fig5] alpha={alpha} lambda={lambda} N={n}: {}",
                    o.report.row("done")
                );
            }
        }
    }
    t.print("Figure 5 — scaling with N adapters vs base-only (paper: 4-11% latency overhead)");
    t.write_csv("fig5_scaling").ok();
    Ok(())
}
