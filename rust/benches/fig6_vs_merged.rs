//! Figure 6 — ExpertWeave vs per-adapter merged deployments under skew.
//!
//! Paper setup: 2 adapters (gate-math, gate-intent), fixed aggregate λ,
//! skew α sweeping so 80→95% of requests hit gate-math. ExpertWeave runs
//! one shared deployment; the merged baseline runs one isolated instance
//! per adapter with the trace split by domain — the hot instance
//! saturates and queues while the cold one idles, which is exactly the
//! imbalance the paper attributes the win to. Device partitioning is
//! emulated with `compute_share`: weave owns 2 NPUs (share 0.5 of the
//! testbed), each merged instance owns its own 2 NPUs (share 0.5 each,
//! 2x aggregate) — the paper's deliberately merged-favouring setup.
//!
//! `cargo bench --bench fig6_vs_merged [-- --config small --lambda 0.6]`

use expertweave::adapters::generator::{paper_adapter_profiles, synth_adapter};
use expertweave::bench::Table;
use expertweave::engine::{Engine, EngineOptions};
use expertweave::runtime::{ArtifactSet, Variant};
use expertweave::server;
use expertweave::util::args::Args;
use expertweave::weights::StoreMode;
use expertweave::workload::power_law::power_law_shares;
use expertweave::workload::trace::{Trace, TraceSpec};
use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    let a = Args::new("fig6_vs_merged", "shared weave vs merged instances under skew")
        .opt("config", Some("small"), "artifact config")
        .opt("lambda", Some("0.6"), "aggregate req/s")
        .opt("alphas", Some("0.32,0.19"), "skew values (0.32 ~ 80/20)")
        .opt("horizon", Some("15"), "horizon (s)")
        .opt("seed", Some("0"), "workload seed")
        .parse_env()
        .map_err(anyhow::Error::msg)?;
    let dir = PathBuf::from("artifacts").join(a.get_or("config", "small"));
    let set = ArtifactSet::load(&dir)?;
    let cfg = set.config.clone();
    let lambda: f64 = a.get_f64("lambda").map_err(anyhow::Error::msg)?;
    let alphas: Vec<f64> = a.get_list("alphas").map_err(anyhow::Error::msg)?;
    let horizon: f64 = a.get_f64("horizon").map_err(anyhow::Error::msg)?;
    let seed: u64 = a.get_usize("seed").map_err(anyhow::Error::msg)? as u64;

    let mk = |idx: usize| {
        let mut p = paper_adapter_profiles()[idx].clone();
        p.max_experts = p.max_experts.min(cfg.e_max);
        p.avg_experts = p.avg_experts.min(p.max_experts as f64);
        synth_adapter(&p, cfg.layers, cfg.num_experts, cfg.hidden, cfg.expert_inter, 42)
    };
    let ad0 = mk(0); // gate-math — receives the bulk of traffic
    let ad1 = mk(2); // gate-intent

    eprintln!("[fig6] building shared weave engine...");
    // weave owns "2 NPUs" = share 0.5; merged gets 0.5 per instance
    // (2x aggregate), mirroring the paper's merged-favouring allocation
    let mut weave = Engine::new_weave(
        &set,
        &[ad0.clone(), ad1.clone()],
        Variant::Weave,
        StoreMode::Virtual,
        EngineOptions { compute_share: 0.5, ..Default::default() },
    )?;

    let clip = |t: &mut Trace| {
        let max_prompt = cfg.buckets.last().copied().unwrap().min(cfg.kv_cap / 2);
        for e in &mut t.events {
            e.prompt.truncate(max_prompt);
            e.max_new_tokens = e.max_new_tokens.clamp(1, (cfg.kv_cap / 16).max(1));
        }
    };

    let mut t = Table::new(&[
        "alpha", "hot share", "system", "req", "prefill tok/s", "decode tok/s",
        "TTFT p50 ms", "TPOT p50 ms",
    ]);
    for &alpha in &alphas {
        let shares = power_law_shares(2, alpha);
        let mut trace = Trace::generate(&TraceSpec {
            adapters: vec![
                (ad0.name.clone(), ad0.domain.clone()),
                (ad1.name.clone(), ad1.domain.clone()),
            ],
            lambda,
            alpha,
            horizon,
            vocab: cfg.vocab,
            seed,
        });
        clip(&mut trace);

        // shared ExpertWeave deployment
        weave.reset_session();
        let w = server::replay(&mut weave, &trace)?;
        t.row(&[
            format!("{alpha}"),
            format!("{:.0}%", shares[0] * 100.0),
            "weave (shared)".into(),
            w.report.requests.to_string(),
            format!("{:.1}", w.report.prefill_throughput),
            format!("{:.1}", w.report.decode_throughput),
            format!("{:.1}", w.report.ttft.median * 1e3),
            format!("{:.1}", w.report.tpot.median * 1e3),
        ]);

        // merged: isolated per-adapter instances, domain-split traces
        let split = |name: &str| {
            let mut t = trace.clone();
            t.events.retain(|e| e.adapter.as_deref() == Some(name));
            t
        };
        let dir0 = dir.clone();
        let dir1 = dir.clone();
        let (a0, a1) = (ad0.clone(), ad1.clone());
        // each merged instance owns half the devices (paper setup); on
        // the one-core testbed that is a 0.5 compute share per instance —
        // a hot instance cannot borrow its idle neighbour's hardware.
        let half = EngineOptions { compute_share: 0.5, ..Default::default() };
        let (h0, h1) = (half.clone(), half);
        let outcomes = server::replay_multi(vec![
            (
                Box::new(move || {
                    Engine::new_merged(&ArtifactSet::load(&dir0)?, a0, h0)
                }) as Box<dyn FnOnce() -> anyhow::Result<Engine> + Send>,
                split(&ad0.name),
            ),
            (
                Box::new(move || {
                    Engine::new_merged(&ArtifactSet::load(&dir1)?, a1, h1)
                }) as Box<dyn FnOnce() -> anyhow::Result<Engine> + Send>,
                split(&ad1.name),
            ),
        ])?;
        let agg = server::aggregate(&outcomes);
        t.row(&[
            format!("{alpha}"),
            format!("{:.0}%", shares[0] * 100.0),
            "merged (2 inst.)".into(),
            agg.requests.to_string(),
            format!("{:.1}", agg.prefill_throughput),
            format!("{:.1}", agg.decode_throughput),
            format!("{:.1}", agg.ttft.median * 1e3),
            format!("{:.1}", agg.tpot.median * 1e3),
        ]);
        eprintln!(
            "[fig6] alpha={alpha}: weave {:.1} dec tok/s vs merged {:.1} ({:+.1}%)",
            w.report.decode_throughput,
            agg.decode_throughput,
            (w.report.decode_throughput / agg.decode_throughput.max(1e-9) - 1.0) * 100.0
        );
    }
    t.print("Figure 6 — shared ExpertWeave vs merged instances under skew (paper: +7-14% prefill, +14-18% decode)");
    t.write_csv("fig6_vs_merged").ok();
    Ok(())
}
