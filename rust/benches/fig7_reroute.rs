//! Figure 7 — impact of the fused batched-rerouting kernel: TTFT across
//! prompt lengths (prefill) and TPOT across batch sizes (decode) for
//! vLLM-Ascend (Merged) vs ExpertWeave-SingleOp vs ExpertWeave (fused).
//!
//! Offline microbenchmark (paper section 5.3): batch = 1 prefill of each
//! prompt length, repeated; decode of 32 steps at each batch size; median
//! reported. Uses the gate-math adapter + math prompts.
//!
//! `cargo bench --bench fig7_reroute [-- --config small --reps 5]`

use expertweave::adapters::generator::{paper_adapter_profiles, synth_adapter};
use expertweave::bench::Table;
use expertweave::engine::{Engine, EngineOptions, RequestSpec};
use expertweave::runtime::{ArtifactSet, Variant};
use expertweave::sampler::SamplingParams;
use expertweave::util::args::Args;
use expertweave::util::stats::Samples;
use expertweave::weights::StoreMode;
use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    let a = Args::new("fig7_reroute", "fused vs singleop rerouting microbench")
        .opt("config", Some("small"), "artifact config")
        .opt("reps", Some("3"), "repetitions per point")
        .opt("decode-steps", Some("16"), "decode steps per TPOT point")
        .parse_env()
        .map_err(anyhow::Error::msg)?;
    let dir = PathBuf::from("artifacts").join(a.get_or("config", "small"));
    let set = ArtifactSet::load(&dir)?;
    let cfg = set.config.clone();
    let reps: usize = a.get_usize("reps").map_err(anyhow::Error::msg)?;
    let decode_steps: usize = a.get_usize("decode-steps").map_err(anyhow::Error::msg)?;

    let mut p = paper_adapter_profiles()[0].clone(); // gate-math
    p.max_experts = p.max_experts.min(cfg.e_max);
    p.avg_experts = p.avg_experts.min(p.max_experts as f64);
    let adapter = synth_adapter(&p, cfg.layers, cfg.num_experts, cfg.hidden, cfg.expert_inter, 42);

    // prompt lengths / decode batch sizes scaled to the config's buckets
    let max_bucket = *cfg.buckets.last().unwrap();
    let mut prompt_lens: Vec<usize> = cfg
        .buckets
        .iter()
        .map(|&b| (b * 3 / 4).max(2))
        .filter(|&p| p <= max_bucket && p <= cfg.kv_cap / 2)
        .collect();
    prompt_lens.dedup();
    let mut batch_sizes: Vec<usize> = cfg
        .buckets
        .iter()
        .map(|&b| b.min(cfg.max_seqs))
        .take_while(|&b| b * 2 + 8 <= cfg.kv_cap)
        .collect();
    batch_sizes.dedup();

    // three systems: merged (no rerouting inputs), weave (fused pallas
    // kernel), singleop (unfused ops + barriers)
    let mut merged = Engine::new_merged(&set, adapter.clone(), EngineOptions::default())?;
    let mut weave = Engine::new_weave(
        &set, &[adapter.clone()], Variant::Weave, StoreMode::Virtual, EngineOptions::default())?;
    let mut single = Engine::new_weave(
        &set, &[adapter.clone()], Variant::SingleOp, StoreMode::Virtual, EngineOptions::default())?;

    let who = adapter.name.clone();
    let adapter_of = |e: &Engine| -> Option<String> {
        match e.variant() {
            Variant::Base => None,
            _ => Some(who.clone()),
        }
    };

    // --- TTFT vs prompt length (batch 1) --------------------------------
    let mut ttft_rows: Vec<(usize, [f64; 3])> = Vec::new();
    for &plen in &prompt_lens {
        // interleave systems per repetition so thermal/load drift cancels
        let mut samples = [Samples::new(), Samples::new(), Samples::new()];
        for _ in 0..reps {
            for (slot, engine) in [&mut merged, &mut single, &mut weave].into_iter().enumerate() {
                let who = adapter_of(engine);
                engine.reset_session();
                engine.submit(RequestSpec {
                    adapter: who.clone(),
                    prompt: (0..plen as i32).collect(),
                    max_new_tokens: 1,
                    sampling: SamplingParams::greedy(),
                })?;
                let done = engine.run_to_completion()?;
                samples[slot].push(done[0].record.ttft.as_secs_f64());
            }
        }
        let meds = [samples[0].median(), samples[1].median(), samples[2].median()];
        ttft_rows.push((plen, meds));
    }
    let mut t = Table::new(&["prompt len", "merged TTFT", "singleop", "fused (weave)", "singleop ovh", "fused ovh"]);
    for (plen, [m, s, w]) in &ttft_rows {
        t.row(&[
            plen.to_string(),
            format!("{:.1}ms", m * 1e3),
            format!("{:.1}ms", s * 1e3),
            format!("{:.1}ms", w * 1e3),
            format!("{:+.1}%", (s / m - 1.0) * 100.0),
            format!("{:+.1}%", (w / m - 1.0) * 100.0),
        ]);
    }
    t.print("Figure 7a — TTFT vs prompt length (paper: singleop ~+29%, fused <1%)");
    t.write_csv("fig7_ttft").ok();

    // --- TPOT vs decode batch size --------------------------------------
    let mut tpot_rows: Vec<(usize, [f64; 3])> = Vec::new();
    for &bs in &batch_sizes {
        let mut samples = [Samples::new(), Samples::new(), Samples::new()];
        for _ in 0..reps.div_ceil(2) {
            for (slot, engine) in [&mut merged, &mut single, &mut weave].into_iter().enumerate() {
                let who = adapter_of(engine);
                engine.reset_session();
                for _ in 0..bs {
                    engine.submit(RequestSpec {
                        adapter: who.clone(),
                        prompt: (0..2).collect(),
                        max_new_tokens: decode_steps,
                        sampling: SamplingParams::greedy(),
                    })?;
                }
                let done = engine.run_to_completion()?;
                for c in &done {
                    if let Some(tpot) = c.record.tpot {
                        samples[slot].push(tpot.as_secs_f64());
                    }
                }
            }
        }
        let meds = [samples[0].median(), samples[1].median(), samples[2].median()];
        tpot_rows.push((bs, meds));
    }
    let mut t = Table::new(&["batch", "merged TPOT", "singleop", "fused (weave)", "singleop ovh", "fused ovh"]);
    for (bs, [m, s, w]) in &tpot_rows {
        t.row(&[
            bs.to_string(),
            format!("{:.1}ms", m * 1e3),
            format!("{:.1}ms", s * 1e3),
            format!("{:.1}ms", w * 1e3),
            format!("{:+.1}%", (s / m - 1.0) * 100.0),
            format!("{:+.1}%", (w / m - 1.0) * 100.0),
        ]);
    }
    t.print("Figure 7b — TPOT vs decode batch size");
    t.write_csv("fig7_tpot").ok();
    Ok(())
}
