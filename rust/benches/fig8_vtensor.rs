//! Figure 8 — effect of the virtual weight tensor on inference latency:
//! ExpertWeave (virtual, page-mapped) vs ExpertWeave-Padding (fully
//! committed padded tensor), same fused rerouting, same adapter.
//!
//! The paper's claim: TTFT within 3% and TPOT within 1% — the VMM-based
//! store saves memory without slowing the GMM.
//!
//! `cargo bench --bench fig8_vtensor [-- --config small --reps 5]`

use expertweave::adapters::generator::{paper_adapter_profiles, synth_adapter};
use expertweave::bench::Table;
use expertweave::engine::{Engine, EngineOptions, RequestSpec};
use expertweave::runtime::{ArtifactSet, Variant};
use expertweave::sampler::SamplingParams;
use expertweave::util::args::Args;
use expertweave::util::stats::Samples;
use expertweave::weights::StoreMode;
use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    let a = Args::new("fig8_vtensor", "virtual weight tensor vs padding latency")
        .opt("config", Some("small"), "artifact config")
        .opt("reps", Some("3"), "repetitions per point")
        .opt("decode-steps", Some("16"), "decode steps per TPOT point")
        .parse_env()
        .map_err(anyhow::Error::msg)?;
    let dir = PathBuf::from("artifacts").join(a.get_or("config", "small"));
    let set = ArtifactSet::load(&dir)?;
    let cfg = set.config.clone();
    let reps: usize = a.get_usize("reps").map_err(anyhow::Error::msg)?;
    let decode_steps: usize = a.get_usize("decode-steps").map_err(anyhow::Error::msg)?;

    let mut p = paper_adapter_profiles()[0].clone();
    p.max_experts = p.max_experts.min(cfg.e_max);
    p.avg_experts = p.avg_experts.min(p.max_experts as f64);
    let adapter = synth_adapter(&p, cfg.layers, cfg.num_experts, cfg.hidden, cfg.expert_inter, 42);

    let mut virt = Engine::new_weave(
        &set, &[adapter.clone()], Variant::Weave, StoreMode::Virtual, EngineOptions::default())?;
    let mut pad = Engine::new_weave(
        &set, &[adapter.clone()], Variant::Weave, StoreMode::Padding, EngineOptions::default())?;
    let name = adapter.name.clone();

    let max_bucket = *cfg.buckets.last().unwrap();
    let mut prompt_lens: Vec<usize> = cfg
        .buckets
        .iter()
        .map(|&b| (b * 3 / 4).max(2))
        .filter(|&pl| pl <= max_bucket && pl <= cfg.kv_cap / 2)
        .collect();
    prompt_lens.dedup();
    let mut batch_sizes: Vec<usize> = cfg
        .buckets
        .iter()
        .map(|&b| b.min(cfg.max_seqs))
        .take_while(|&b| b * 2 + 8 <= cfg.kv_cap)
        .collect();
    batch_sizes.dedup();

    let ttft_once = |engine: &mut Engine, name: &str, plen: usize| -> anyhow::Result<f64> {
        engine.reset_session();
        engine.submit(RequestSpec {
            adapter: Some(name.to_string()),
            prompt: (0..plen as i32).collect(),
            max_new_tokens: 1,
            sampling: SamplingParams::greedy(),
        })?;
        let done = engine.run_to_completion()?;
        Ok(done[0].record.ttft.as_secs_f64())
    };
    let mut t = Table::new(&["prompt len", "padding TTFT", "virtual TTFT", "delta"]);
    for &plen in &prompt_lens {
        // interleave the two stores per rep so drift cancels
        let (mut sp, mut sv) = (Samples::new(), Samples::new());
        for _ in 0..reps {
            sp.push(ttft_once(&mut pad, &name, plen)?);
            sv.push(ttft_once(&mut virt, &name, plen)?);
        }
        let (tp, tv) = (sp.median(), sv.median());
        t.row(&[
            plen.to_string(),
            format!("{:.1}ms", tp * 1e3),
            format!("{:.1}ms", tv * 1e3),
            format!("{:+.1}%", (tv / tp - 1.0) * 100.0),
        ]);
    }
    t.print("Figure 8a — TTFT: virtual weight tensor vs padding (paper: <3%)");
    t.write_csv("fig8_ttft").ok();

    let tpot_once = |engine: &mut Engine, name: &str, bs: usize, s: &mut Samples| -> anyhow::Result<()> {
        engine.reset_session();
        for _ in 0..bs {
            engine.submit(RequestSpec {
                adapter: Some(name.to_string()),
                prompt: (0..2).collect(),
                max_new_tokens: decode_steps,
                sampling: SamplingParams::greedy(),
            })?;
        }
        for c in engine.run_to_completion()? {
            if let Some(t) = c.record.tpot {
                s.push(t.as_secs_f64());
            }
        }
        Ok(())
    };
    let mut t = Table::new(&["batch", "padding TPOT", "virtual TPOT", "delta"]);
    for &bs in &batch_sizes {
        let (mut sp, mut sv) = (Samples::new(), Samples::new());
        for _ in 0..reps.div_ceil(2) {
            tpot_once(&mut pad, &name, bs, &mut sp)?;
            tpot_once(&mut virt, &name, bs, &mut sv)?;
        }
        let (tp, tv) = (sp.median(), sv.median());
        t.row(&[
            bs.to_string(),
            format!("{:.2}ms", tp * 1e3),
            format!("{:.2}ms", tv * 1e3),
            format!("{:+.1}%", (tv / tp - 1.0) * 100.0),
        ]);
    }
    t.print("Figure 8b — TPOT: virtual weight tensor vs padding (paper: <1%)");
    t.write_csv("fig8_tpot").ok();
    Ok(())
}
