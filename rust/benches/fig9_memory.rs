//! Figure 9 — memory usage and KV-cache capacity when serving 1–3
//! adapters on a single 64 GB device: vLLM-Ascend (Merged) vs
//! ExpertWeave-Padding vs ExpertWeave (virtual weight tensor).
//!
//! Runs the *real* expert-memory-manager allocator in accounting mode at
//! the paper's 16B-model scale (bf16 weights, 2 MB pages), charging a
//! simulated 64 GB `DeviceMemory`; KV capacity = what the remaining
//! budget affords at the paper's per-token KV cost.
//!
//! `cargo bench --bench fig9_memory`

use expertweave::adapters::generator::{paper_adapter_profiles, synth_adapter};
use expertweave::bench::{fmt_bytes, Table};
use expertweave::kvcache::{kv_capacity_tokens, paged_kv_capacity};
use expertweave::memsim::{gib, DeviceMemory};
use expertweave::model::ModelConfig;
use expertweave::vmm::expert_manager::ExpertMemoryManager;
use expertweave::vmm::DEFAULT_PAGE_SIZE;
use std::sync::{Arc, Mutex};

const BF16: usize = 2;
const DEVICE: usize = gib(64);
const GPU_UTIL: f64 = 0.9;
/// Activation + framework reserve per serving instance (calibrated so the
/// merged single-adapter deployment affords ~810K KV tokens, the paper's
/// measured value; vLLM/CANN runtime overhead is of this order).
const RESERVE_PER_INSTANCE: usize = (2.5 * (1u64 << 30) as f64) as usize;

/// Paper-scale weight store: accounting managers per (layer, proj).
struct Store {
    cfg: ModelConfig,
    managers: Vec<ExpertMemoryManager>,
    device: Arc<Mutex<DeviceMemory>>,
}

impl Store {
    fn new() -> Self {
        let cfg = ModelConfig::paper16b();
        let device = DeviceMemory::shared(DEVICE);
        let expert_proj = cfg.hidden * cfg.expert_inter * BF16;
        let managers = (0..cfg.layers * 3)
            .map(|_| {
                ExpertMemoryManager::new_accounting(
                    expert_proj,
                    cfg.total_expert_slots(),
                    DEFAULT_PAGE_SIZE,
                    device.clone(),
                )
            })
            .collect();
        Store { cfg, managers, device }
    }

    fn load_base_and_attn(&mut self) -> anyhow::Result<()> {
        // non-expert weights (attention, embeddings, shared experts)
        // charged directly; expert weights go through the page allocator
        let expert_bytes_f32 = self.cfg.layers * 3 * self.cfg.num_experts
            * self.cfg.hidden * self.cfg.expert_inter * 4;
        let non_expert = (self.cfg.base_model_bytes() - expert_bytes_f32) / 4 * BF16;
        self.device.lock().unwrap().alloc(non_expert)?;
        for m in &mut self.managers {
            m.load_range(0, self.cfg.num_experts)?;
        }
        Ok(())
    }

    fn load_adapter(&mut self, slot: usize, counts: &[usize], padded: bool) -> anyhow::Result<()> {
        let delta = self.cfg.adapter_slot_base(slot);
        for (l, &c) in counts.iter().enumerate() {
            let commit = if padded { self.cfg.e_max } else { c };
            if commit == 0 {
                continue;
            }
            for p in 0..3 {
                self.managers[l * 3 + p].load_range(delta, commit)?;
            }
        }
        Ok(())
    }

    fn used(&self) -> usize {
        self.device.lock().unwrap().used()
    }

    fn kv_tokens(&self) -> usize {
        kv_tokens_of(DEVICE, self.used(), 1, &self.cfg)
    }
}

/// KV tokens affordable on `device` bytes after `used` weight bytes and
/// `instances` runtime reserves, at the paper model's MLA cache cost
/// (compressed 512 + 64 rope dims per layer, bf16).
fn kv_tokens_of(device: usize, used: usize, instances: usize, cfg: &ModelConfig) -> usize {
    let kv_per_token = cfg.layers * (512 + 64) * BF16;
    let budget = (device as f64 * GPU_UTIL) as usize;
    let reserved = used + instances * RESERVE_PER_INSTANCE;
    kv_capacity_tokens(budget.saturating_sub(reserved), 1.0, kv_per_token)
}

fn main() -> anyhow::Result<()> {
    let cfg = ModelConfig::paper16b();
    let names = ["gate-math", "token-math", "gate-intent"];
    let counts: Vec<Vec<usize>> = paper_adapter_profiles()
        .iter()
        .filter(|p| names.contains(&p.name))
        .map(|p| {
            synth_adapter(p, cfg.layers, cfg.num_experts, 8, 4, 42)
                .layers
                .iter()
                .map(|l| l.expert_count())
                .collect()
        })
        .collect();
    let merged_model = cfg.base_model_bytes() / 4 * BF16;

    let mut t = Table::new(&[
        "adapters", "merged mem", "padding mem", "virtual mem",
        "merged KV(tok)", "padding KV(tok)", "virtual KV(tok)",
    ]);

    for n in 1..=3usize {
        // merged: n full model instances on the device
        let merged_used = merged_model.checked_mul(n).unwrap();
        let merged_cell = if merged_used > DEVICE {
            ("OOM".to_string(), "OOM".to_string())
        } else {
            let kv = kv_tokens_of(DEVICE, merged_used, n, &cfg);
            (fmt_bytes(merged_used), format!("{kv}"))
        };

        // padding / virtual: one shared deployment, n adapters
        let mut run = |padded: bool| -> anyhow::Result<(usize, usize)> {
            let mut s = Store::new();
            s.load_base_and_attn()?;
            for (i, c) in counts.iter().take(n).enumerate() {
                s.load_adapter(i, c, padded)?;
            }
            Ok((s.used(), s.kv_tokens()))
        };
        let (pad_used, pad_kv) = run(true)?;
        let (virt_used, virt_kv) = run(false)?;

        t.row(&[
            n.to_string(),
            merged_cell.0.clone(),
            fmt_bytes(pad_used),
            fmt_bytes(virt_used),
            merged_cell.1.clone(),
            pad_kv.to_string(),
            virt_kv.to_string(),
        ]);
    }
    t.print("Figure 9 — memory & KV capacity on one 64 GB device (paper scale)");
    t.write_csv("fig9_memory").ok();

    // headline ratios the paper quotes
    let mut virt2 = Store::new();
    virt2.load_base_and_attn()?;
    for (i, c) in counts.iter().take(2).enumerate() {
        virt2.load_adapter(i, c, false)?;
    }
    let merged2 = 2 * merged_model;
    if merged2 <= DEVICE {
        let merged_kv = kv_tokens_of(DEVICE, merged2, 2, &cfg);
        if merged_kv > 0 {
            println!(
                "\nKV capacity ratio at 2 adapters (weave/merged): {:.1}x (paper: 94.4x)",
                virt2.kv_tokens() as f64 / merged_kv as f64
            );
        } else {
            println!(
                "\nmerged 2-adapter deployment exhausts the device before any KV \
                 (weave affords {} tokens; paper measured 94.4x at a ~6K-token margin)",
                virt2.kv_tokens()
            );
        }
    }
    let mut pad1 = Store::new();
    pad1.load_base_and_attn()?;
    let base_used = pad1.used();
    pad1.load_adapter(0, &counts[0], true)?;
    let pad_over = pad1.used() - base_used;
    let mut virt1 = Store::new();
    virt1.load_base_and_attn()?;
    virt1.load_adapter(0, &counts[0], false)?;
    let virt_over = virt1.used() - base_used;
    println!(
        "1-adapter overhead: padding {} vs virtual {} ({:.1}% reduction; paper: 4.7 GB -> 2.8 GB, 40.4%)",
        fmt_bytes(pad_over),
        fmt_bytes(virt_over),
        (1.0 - virt_over as f64 / pad_over as f64) * 100.0
    );

    // Paged KV: logical vs physical capacity of the 2-adapter virtual
    // deployment's KV budget, with page-metadata overhead charged. At
    // 0% overlap the physical tokens match the flat accounting above up
    // to block rounding and metadata; prefix sharing multiplies the
    // *logical* capacity without touching the device budget.
    let kv_per_token = cfg.layers * (512 + 64) * BF16;
    let budget = (DEVICE as f64 * GPU_UTIL) as usize;
    let free = budget.saturating_sub(virt2.used() + RESERVE_PER_INSTANCE);
    let mut pt = Table::new(&[
        "prefix overlap", "physical KV(tok)", "logical KV(tok)", "page metadata",
    ]);
    for o in [0.0, 0.5, 0.95] {
        let c = paged_kv_capacity(free, 1.0, kv_per_token, 16, o);
        pt.row(&[
            format!("{:.0}%", o * 100.0),
            c.physical_tokens.to_string(),
            c.logical_tokens.to_string(),
            fmt_bytes(c.metadata_bytes),
        ]);
    }
    pt.print("Figure 9b — paged KV logical vs physical capacity (2-adapter virtual, block=16)");
    pt.write_csv("fig9_paged_capacity").ok();
    let flat = kv_capacity_tokens(free, 1.0, kv_per_token);
    let paged0 = paged_kv_capacity(free, 1.0, kv_per_token, 16, 0.0);
    println!(
        "paged metadata cost at 0% overlap: {} of {} flat tokens retained ({:.3}%)",
        paged0.physical_tokens,
        flat,
        paged0.physical_tokens as f64 / flat.max(1) as f64 * 100.0
    );
    Ok(())
}
