//! Table 1 — expert configuration and sparsity of the 10 ESFT adapters,
//! plus the section-3.1 fragmentation analysis (F_mem = 1.51 at
//! E_max = 13).
//!
//! `cargo bench --bench table1_sparsity`

use expertweave::adapters::generator::{
    adapter_fragmentation_factor, fragmentation_factor, paper_adapter_profiles, synth_adapter,
};
use expertweave::bench::Table;

fn main() {
    // paper scale: 26 MoE layers, M = 64 experts
    let (layers, m) = (26, 64);
    let adapters: Vec<_> = paper_adapter_profiles()
        .iter()
        .map(|p| synth_adapter(p, layers, m, 8, 4, 42))
        .collect();

    // paper's Table 1 reference values for side-by-side comparison
    let paper: &[(f64, f64)] = &[
        (7.04, 0.41),
        (6.12, 0.32),
        (9.50, 0.21),
        (7.12, 0.11),
        (7.73, 0.30),
        (5.15, 0.36),
        (7.35, 0.39),
        (6.58, 0.34),
        (4.69, 0.64),
        (3.85, 0.36),
    ];

    let mut t = Table::new(&[
        "adapter", "domain", "max#", "avg# (paper)", "sparsity (paper)",
    ]);
    for (ad, &(avg_p, s_p)) in adapters.iter().zip(paper) {
        t.row(&[
            ad.name.clone(),
            ad.domain.clone(),
            ad.max_experts().to_string(),
            format!("{:.2} ({avg_p:.2})", ad.avg_experts()),
            format!("{:.2} ({s_p:.2})", ad.sparsity()),
        ]);
    }
    t.print("Table 1 — ESFT adapter expert configuration and sparsity");
    t.write_csv("table1_sparsity").ok();

    let e_max = adapters.iter().map(|a| a.max_experts()).max().unwrap();
    println!("\nsmallest feasible E_max = {e_max} (paper: 13)");
    println!(
        "F_mem at E_max={e_max}: {:.2}   (paper: 1.51)",
        fragmentation_factor(&adapters, m, e_max)
    );
    println!(
        "adapter-weights-only fragmentation: {:.2}x",
        adapter_fragmentation_factor(&adapters, e_max)
    );
}
