//! Table 3 — serving accuracy: ExpertWeave must match the per-task
//! accuracy of the respective merged models exactly.
//!
//! With no GSM8K/intent datasets offline, accuracy parity is reproduced
//! as the stronger statement it follows from: **greedy-decode token
//! agreement**. For a corpus of prompts per task, the tokens produced by
//! ExpertWeave (two adapters resident, requests routed by adapter ID)
//! must equal those of the corresponding merged model, for every prompt
//! — hence any downstream-task accuracy is identical. The base model is
//! decoded too, to show the adapters actually change behaviour.
//!
//! `cargo bench --bench table3_accuracy`

use expertweave::adapters::generator::{paper_adapter_profiles, synth_adapter};
use expertweave::bench::Table;
use expertweave::engine::{Engine, EngineOptions, RequestSpec};
use expertweave::runtime::{ArtifactSet, Variant};
use expertweave::sampler::SamplingParams;
use expertweave::weights::StoreMode;
use expertweave::workload::prompts::PromptGen;
use std::path::PathBuf;

const PROMPTS_PER_TASK: usize = 24;
const MAX_NEW: usize = 8;

fn main() -> anyhow::Result<()> {
    let dir = PathBuf::from("artifacts/tiny");
    let set = ArtifactSet::load(&dir)?;
    let cfg = set.config.clone();

    let mk = |idx: usize| {
        let mut p = paper_adapter_profiles()[idx].clone();
        p.max_experts = p.max_experts.min(cfg.e_max);
        p.avg_experts = cfg.e_max as f64; // dense adapters: visible effect
        synth_adapter(&p, cfg.layers, cfg.num_experts, cfg.hidden, cfg.expert_inter, 42)
    };
    let ad_math = mk(0); // gate-math
    let ad_intent = mk(2); // gate-intent

    // prompt corpora per task (synthetic domain prompts, greedy decode)
    let mut gen = PromptGen::new(cfg.vocab, 7);
    let max_prompt = cfg.buckets.last().copied().unwrap().min(cfg.kv_cap / 4);
    let corpus = |gen: &mut PromptGen, domain: &str| -> Vec<Vec<i32>> {
        (0..PROMPTS_PER_TASK)
            .map(|_| {
                let (mut p, _) = gen.sample(domain);
                p.truncate(max_prompt.max(4));
                if p.is_empty() {
                    p.push(1);
                }
                p
            })
            .collect()
    };
    let math_prompts = corpus(&mut gen, "math");
    let intent_prompts = corpus(&mut gen, "intent");

    let decode = |engine: &mut Engine, adapter: Option<&str>, prompts: &[Vec<i32>]| {
        let mut ids = Vec::new();
        for p in prompts {
            ids.push(
                engine
                    .submit(RequestSpec {
                        adapter: adapter.map(str::to_string),
                        prompt: p.clone(),
                        max_new_tokens: MAX_NEW,
                        sampling: SamplingParams::greedy(),
                    })
                    .unwrap(),
            );
        }
        let done = engine.run_to_completion().unwrap();
        ids.iter()
            .map(|id| done.iter().find(|c| c.id == *id).unwrap().output.clone())
            .collect::<Vec<_>>()
    };

    // ExpertWeave: both adapters resident, both corpora through one engine
    let mut weave = Engine::new_weave(
        &set,
        &[ad_math.clone(), ad_intent.clone()],
        Variant::Weave,
        StoreMode::Virtual,
        EngineOptions::default(),
    )?;
    let w_math = decode(&mut weave, Some("gate-math"), &math_prompts);
    let w_intent = decode(&mut weave, Some("gate-intent"), &intent_prompts);
    let w_base_math = decode(&mut weave, None, &math_prompts);

    // merged references
    let mut m_math_engine = Engine::new_merged(&set, ad_math, EngineOptions::default())?;
    let m_math = decode(&mut m_math_engine, None, &math_prompts);
    drop(m_math_engine);
    let mut m_intent_engine = Engine::new_merged(&set, ad_intent, EngineOptions::default())?;
    let m_intent = decode(&mut m_intent_engine, None, &intent_prompts);
    drop(m_intent_engine);

    // base model reference
    let mut base_engine = Engine::new_base_only(&set, EngineOptions::default())?;
    let b_math = decode(&mut base_engine, None, &math_prompts);

    let agree = |a: &[Vec<i32>], b: &[Vec<i32>]| {
        let hits = a.iter().zip(b).filter(|(x, y)| x == y).count();
        100.0 * hits as f64 / a.len() as f64
    };

    let mut t = Table::new(&["system", "math agreement", "intent agreement"]);
    t.row(&[
        "ExpertWeave vs merged".into(),
        format!("{:.1}%", agree(&w_math, &m_math)),
        format!("{:.1}%", agree(&w_intent, &m_intent)),
    ]);
    t.row(&[
        "base model vs merged".into(),
        format!("{:.1}%", agree(&b_math, &m_math)),
        "-".into(),
    ]);
    t.row(&[
        "weave(base tokens) vs base".into(),
        format!("{:.1}%", agree(&w_base_math, &b_math)),
        "-".into(),
    ]);
    t.print("Table 3 — greedy-decode agreement (accuracy-parity mechanism)");
    t.write_csv("table3_accuracy").ok();

    let a1 = agree(&w_math, &m_math);
    let a2 = agree(&w_intent, &m_intent);
    let a3 = agree(&w_base_math, &b_math);
    assert_eq!(a1, 100.0, "weave must match merged on math");
    assert_eq!(a2, 100.0, "weave must match merged on intent");
    assert_eq!(a3, 100.0, "weave base-path must match base model");
    println!(
        "\nExpertWeave reproduces merged-model outputs exactly (=> identical task accuracy; paper: 62.3/78.8 on both systems)."
    );
    Ok(())
}
