//! The ESFT expert map Π (paper section 4.1/4.3), host side.
//!
//! `Π^(l)[i, j]` stores the virtual-weight-tensor slot of base expert `j`
//! under adapter slot `i` in layer `l`:
//!
//! ```text
//! Π^(l)[i, j] = j                     if j not fine-tuned by adapter i
//!             = Δ_i + δ_ij^(l)        otherwise, Δ_i = M + i·E_max
//! ```
//!
//! The map is stored flattened as `[L, N+1, M]` i32 with an identity row
//! at adapter index 0 (`AID -1` → row 0), matching the artifact ABI of the
//! L1 Pallas kernel. Loading/evicting an adapter rewrites only its rows;
//! the tensor is re-uploaded to the device by the engine afterwards.

use crate::model::ModelConfig;
use anyhow::{bail, Result};

/// Host copy of the per-layer ESFT expert maps.
#[derive(Debug, Clone, PartialEq)]
pub struct ExpertMaps {
    layers: usize,
    n_adapters: usize,
    m: usize,
    e_max: usize,
    /// `[L, N+1, M]` flattened, identity row at adapter index 0.
    data: Vec<i32>,
}

impl ExpertMaps {
    pub fn new(cfg: &ModelConfig) -> Self {
        let (l, n, m) = (cfg.layers, cfg.max_adapters, cfg.num_experts);
        let mut data = vec![0i32; l * (n + 1) * m];
        for li in 0..l {
            for row in 0..=n {
                let off = (li * (n + 1) + row) * m;
                for j in 0..m {
                    data[off + j] = j as i32;
                }
            }
        }
        ExpertMaps { layers: l, n_adapters: n, m, e_max: cfg.e_max, data }
    }

    /// Rebuild a host map from its flattened `[L, N+1, M]` device image
    /// (the simulated runtime reconstructs the uploaded tensor this way).
    pub fn from_flat(
        layers: usize,
        n_adapters: usize,
        m: usize,
        e_max: usize,
        data: Vec<i32>,
    ) -> Result<Self> {
        let want = layers * (n_adapters + 1) * m;
        if data.len() != want {
            bail!(
                "expert map image has {} elements, [{layers}, {}, {m}] wants {want}",
                data.len(),
                n_adapters + 1
            );
        }
        Ok(ExpertMaps { layers, n_adapters, m, e_max, data })
    }

    /// Flattened `[L, N+1, M]` i32 view (device upload).
    pub fn as_slice(&self) -> &[i32] {
        &self.data
    }

    pub fn shape(&self) -> [usize; 3] {
        [self.layers, self.n_adapters + 1, self.m]
    }

    fn idx(&self, layer: usize, row: usize, j: usize) -> usize {
        (layer * (self.n_adapters + 1) + row) * self.m + j
    }

    /// Π^(l)[slot, j] with row 0 = identity; `slot` is the adapter slot.
    pub fn lookup(&self, layer: usize, aid: i32, j: usize) -> i32 {
        let row = (aid + 1) as usize;
        self.data[self.idx(layer, row, j)]
    }

    /// Install adapter rows: for each layer, `experts[l]` is the sorted
    /// list of fine-tuned base expert IDs; local offset δ is the index in
    /// that sorted list (mirrors `python/compile/kernels/reroute.py`).
    pub fn install(&mut self, slot: usize, experts_per_layer: &[Vec<u32>]) -> Result<()> {
        if slot >= self.n_adapters {
            bail!("adapter slot {slot} out of range (N = {})", self.n_adapters);
        }
        if experts_per_layer.len() != self.layers {
            bail!(
                "adapter has {} layers, model has {}",
                experts_per_layer.len(),
                self.layers
            );
        }
        for (l, experts) in experts_per_layer.iter().enumerate() {
            if experts.len() > self.e_max {
                bail!(
                    "layer {l}: {} experts exceed E_max {}",
                    experts.len(),
                    self.e_max
                );
            }
            if !experts.windows(2).all(|w| w[0] < w[1]) {
                bail!("layer {l}: expert ids not strictly sorted");
            }
            let delta = (self.m + slot * self.e_max) as i32;
            let row = slot + 1;
            // reset the row to identity, then point fine-tuned experts at
            // their slots
            for j in 0..self.m {
                let at = self.idx(l, row, j);
                self.data[at] = j as i32;
            }
            for (off, &j) in experts.iter().enumerate() {
                if j as usize >= self.m {
                    bail!("layer {l}: expert id {j} >= M {}", self.m);
                }
                let at = self.idx(l, row, j as usize);
                self.data[at] = delta + off as i32;
            }
        }
        Ok(())
    }

    /// Reset an adapter slot's rows to identity (eviction).
    pub fn clear(&mut self, slot: usize) -> Result<()> {
        if slot >= self.n_adapters {
            bail!("adapter slot {slot} out of range");
        }
        for l in 0..self.layers {
            let row = slot + 1;
            for j in 0..self.m {
                let at = self.idx(l, row, j);
                self.data[at] = j as i32;
            }
        }
        Ok(())
    }

    /// Host-side rerouting of one token's top-k (reference semantics):
    /// `TopK'(x) = { Π[A(x), j] : j ∈ TopK(x) }`. Allocates; the hot
    /// path is the fused [`ExpertMaps::reroute_batch`].
    pub fn reroute(&self, layer: usize, aid: i32, top_k: &[i32]) -> Vec<i32> {
        top_k
            .iter()
            .map(|&j| self.lookup(layer, aid, j as usize))
            .collect()
    }

    /// Fused batched rerouting: rewrite a whole batch's top-k expert ids
    /// in one pass into a caller-owned buffer — the host analogue of the
    /// paper's fused rerouting kernel (one gather per element, no
    /// per-token dispatch, no allocation).
    ///
    /// `aids[i]` is token `i`'s adapter id (-1 = base); `top_k` is the
    /// `[tokens, K]`-flattened base-expert ids (so `K = top_k.len() /
    /// aids.len()`); `out` receives the rerouted virtual-tensor slots in
    /// the same layout.
    pub fn reroute_batch(
        &self,
        layer: usize,
        aids: &[i32],
        top_k: &[i32],
        out: &mut [i32],
    ) -> Result<()> {
        if layer >= self.layers {
            bail!("layer {layer} out of range (L = {})", self.layers);
        }
        if aids.is_empty() {
            if !top_k.is_empty() || !out.is_empty() {
                bail!("empty batch with non-empty top_k/out");
            }
            return Ok(());
        }
        if top_k.len() % aids.len() != 0 || out.len() != top_k.len() {
            bail!(
                "shape mismatch: {} aids, {} top_k, {} out",
                aids.len(),
                top_k.len(),
                out.len()
            );
        }
        let k = top_k.len() / aids.len();
        for (i, &aid) in aids.iter().enumerate() {
            if aid < -1 || aid >= self.n_adapters as i32 {
                bail!("token {i}: adapter id {aid} out of range (N = {})", self.n_adapters);
            }
            let base = (layer * (self.n_adapters + 1) + (aid + 1) as usize) * self.m;
            for j in 0..k {
                let e = top_k[i * k + j];
                if e < 0 || e as usize >= self.m {
                    bail!("token {i}: expert id {e} out of range (M = {})", self.m);
                }
                out[i * k + j] = self.data[base + e as usize];
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfig {
        let mut c = ModelConfig::paper16b();
        c.layers = 2;
        c.num_experts = 8;
        c.max_adapters = 3;
        c.e_max = 3;
        c
    }

    #[test]
    fn identity_by_default() {
        let maps = ExpertMaps::new(&cfg());
        for l in 0..2 {
            for aid in -1..3 {
                for j in 0..8 {
                    assert_eq!(maps.lookup(l, aid, j), j as i32);
                }
            }
        }
    }

    #[test]
    fn install_points_into_adapter_region() {
        let c = cfg();
        let mut maps = ExpertMaps::new(&c);
        maps.install(1, &[vec![1, 4], vec![7]]).unwrap();
        // layer 0: Δ_1 = 8 + 1*3 = 11; experts 1 -> 11, 4 -> 12
        assert_eq!(maps.lookup(0, 1, 1), 11);
        assert_eq!(maps.lookup(0, 1, 4), 12);
        assert_eq!(maps.lookup(0, 1, 0), 0); // untouched
        assert_eq!(maps.lookup(1, 1, 7), 11); // layer 1: δ restarts at 0
        // other adapters unaffected
        assert_eq!(maps.lookup(0, 0, 1), 1);
        assert_eq!(maps.lookup(0, 2, 4), 4);
        // base row (-1) is always identity
        assert_eq!(maps.lookup(0, -1, 4), 4);
    }

    #[test]
    fn reinstall_overwrites_and_clear_resets() {
        let mut maps = ExpertMaps::new(&cfg());
        maps.install(0, &[vec![0, 1], vec![2]]).unwrap();
        maps.install(0, &[vec![5], vec![]]).unwrap();
        assert_eq!(maps.lookup(0, 0, 0), 0); // reset by reinstall
        assert_eq!(maps.lookup(0, 0, 5), 8);
        maps.clear(0).unwrap();
        assert_eq!(maps.lookup(0, 0, 5), 5);
    }

    #[test]
    fn validation() {
        let mut maps = ExpertMaps::new(&cfg());
        assert!(maps.install(3, &[vec![], vec![]]).is_err()); // slot OOR
        assert!(maps.install(0, &[vec![]]).is_err()); // wrong layer count
        assert!(maps.install(0, &[vec![0, 1, 2, 3], vec![]]).is_err()); // > E_max
        assert!(maps.install(0, &[vec![2, 1], vec![]]).is_err()); // unsorted
        assert!(maps.install(0, &[vec![9], vec![]]).is_err()); // id >= M
    }

    #[test]
    fn reroute_semantics() {
        let mut maps = ExpertMaps::new(&cfg());
        maps.install(2, &[vec![3], vec![]]).unwrap();
        let out = maps.reroute(0, 2, &[3, 5, 3]);
        let delta = 8 + 2 * 3;
        assert_eq!(out, vec![delta as i32, 5, delta as i32]);
        assert_eq!(maps.reroute(0, -1, &[3, 5]), vec![3, 5]);
    }

    #[test]
    fn reroute_batch_matches_per_token_reference() {
        let c = cfg();
        let mut maps = ExpertMaps::new(&c);
        maps.install(0, &[vec![1, 4], vec![7]]).unwrap();
        maps.install(2, &[vec![3], vec![0, 5]]).unwrap();
        let aids = [-1, 0, 2, 0];
        let top_k = [3, 5, 1, 4, 3, 7, 4, 1]; // [4 tokens, K=2]
        let mut out = [0i32; 8];
        for layer in 0..2 {
            maps.reroute_batch(layer, &aids, &top_k, &mut out).unwrap();
            for (i, &aid) in aids.iter().enumerate() {
                let reference = maps.reroute(layer, aid, &top_k[i * 2..(i + 1) * 2]);
                assert_eq!(&out[i * 2..(i + 1) * 2], &reference[..], "token {i} layer {layer}");
            }
        }
        // shape / domain validation
        assert!(maps.reroute_batch(9, &aids, &top_k, &mut out).is_err());
        assert!(maps.reroute_batch(0, &aids, &top_k[..7], &mut out[..7]).is_err());
        assert!(maps.reroute_batch(0, &[-2], &[0], &mut out[..1]).is_err());
        assert!(maps.reroute_batch(0, &[0], &[99], &mut out[..1]).is_err());
        // empty batch is a no-op
        maps.reroute_batch(0, &[], &[], &mut []).unwrap();
    }

    #[test]
    fn from_flat_round_trips() {
        let c = cfg();
        let mut maps = ExpertMaps::new(&c);
        maps.install(1, &[vec![2, 6], vec![0]]).unwrap();
        let rebuilt = ExpertMaps::from_flat(
            c.layers,
            c.max_adapters,
            c.num_experts,
            c.e_max,
            maps.as_slice().to_vec(),
        )
        .unwrap();
        assert_eq!(rebuilt, maps);
        assert!(ExpertMaps::from_flat(1, 1, 1, 1, vec![0; 3]).is_err());
    }

    #[test]
    fn property_lookup_in_valid_domain() {
        crate::util::prop::check(505, 40, |rng| {
            let c = cfg();
            let mut maps = ExpertMaps::new(&c);
            for slot in 0..c.max_adapters {
                let per_layer: Vec<Vec<u32>> = (0..c.layers)
                    .map(|_| {
                        let k = rng.below((c.e_max + 1) as u64) as usize;
                        rng.sample_distinct(c.num_experts, k)
                            .into_iter()
                            .map(|x| x as u32)
                            .collect()
                    })
                    .collect();
                maps.install(slot, &per_layer).unwrap();
            }
            let g = c.total_expert_slots() as i32;
            for l in 0..c.layers {
                for aid in -1..(c.max_adapters as i32) {
                    for j in 0..c.num_experts {
                        let s = maps.lookup(l, aid, j);
                        assert!((0..g).contains(&s));
                        if aid >= 0 && s >= c.num_experts as i32 {
                            // fine-tuned: must be inside adapter aid's region
                            let d = c.adapter_slot_base(aid as usize) as i32;
                            assert!(s >= d && s < d + c.e_max as i32);
                        }
                    }
                }
            }
        });
    }
}
