//! `.esft` adapter checkpoint format + in-memory representation.
//!
//! Layout (little-endian):
//!
//! ```text
//! magic   "ESFT"                        4 B
//! version u32                           4 B
//! name    u32 len + utf8 bytes
//! domain  u32 len + utf8 bytes
//! layers  u32   hidden u32   inter u32
//! per layer:
//!   count u32
//!   expert ids  count * u32            (sorted base-model expert IDs)
//!   weights     count * 3 * hidden * inter * f32   (gate, up, down)
//! crc32  u32 over everything above
//! ```
//!
//! The format mirrors the paper's deployment flow: adapters live in
//! secondary storage, are loaded/cached in host memory ([`Adapter`]), and
//! only then copied into the device-side virtual weight tensor.

use anyhow::{bail, Context, Result};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"ESFT";
const VERSION: u32 = 1;

/// One MoE layer of an adapter.
#[derive(Debug, Clone, PartialEq)]
pub struct AdapterLayer {
    /// Sorted base-model expert IDs fine-tuned in this layer.
    pub expert_ids: Vec<u32>,
    /// `expert_ids.len() * 3 * hidden * inter` f32 weights,
    /// ordered `[expert][gate|up|down][...]`.
    pub weights: Vec<f32>,
}

impl AdapterLayer {
    pub fn expert_count(&self) -> usize {
        self.expert_ids.len()
    }

    /// The three projection matrices of local expert `e`, flattened.
    pub fn expert_weights(&self, e: usize, hidden: usize, inter: usize) -> &[f32] {
        let per = 3 * hidden * inter;
        &self.weights[e * per..(e + 1) * per]
    }
}

/// A fully loaded (host-cached) ESFT adapter.
#[derive(Debug, Clone, PartialEq)]
pub struct Adapter {
    pub name: String,
    pub domain: String,
    pub hidden: usize,
    pub inter: usize,
    pub layers: Vec<AdapterLayer>,
}

impl Adapter {
    /// E_i — max fine-tuned experts in any layer.
    pub fn max_experts(&self) -> usize {
        self.layers.iter().map(|l| l.expert_count()).max().unwrap_or(0)
    }

    /// Mean fine-tuned experts per layer.
    pub fn avg_experts(&self) -> f64 {
        if self.layers.is_empty() {
            return 0.0;
        }
        self.layers.iter().map(|l| l.expert_count()).sum::<usize>() as f64
            / self.layers.len() as f64
    }

    /// Adapter sparsity factor S_i (paper section 3.1):
    /// `Σ_l (E_i - e_i^(l)) / (L * E_i)`.
    pub fn sparsity(&self) -> f64 {
        let e_i = self.max_experts();
        if e_i == 0 || self.layers.is_empty() {
            return 0.0;
        }
        let l = self.layers.len();
        let deficit: usize = self.layers.iter().map(|la| e_i - la.expert_count()).sum();
        deficit as f64 / (l * e_i) as f64
    }

    /// Total fine-tuned experts across layers.
    pub fn total_experts(&self) -> usize {
        self.layers.iter().map(|l| l.expert_count()).sum()
    }

    /// Serialized + in-memory weight bytes (f32).
    pub fn weight_bytes(&self) -> usize {
        self.total_experts() * 3 * self.hidden * self.inter * 4
    }

    // -- (de)serialization -------------------------------------------------

    pub fn save(&self, path: &Path) -> Result<()> {
        let f = std::fs::File::create(path)
            .with_context(|| format!("create {}", path.display()))?;
        let mut w = CrcWriter::new(BufWriter::new(f));
        w.write_all(MAGIC)?;
        w.write_u32(VERSION)?;
        w.write_str(&self.name)?;
        w.write_str(&self.domain)?;
        w.write_u32(self.layers.len() as u32)?;
        w.write_u32(self.hidden as u32)?;
        w.write_u32(self.inter as u32)?;
        for layer in &self.layers {
            w.write_u32(layer.expert_ids.len() as u32)?;
            for &id in &layer.expert_ids {
                w.write_u32(id)?;
            }
            let expect = layer.expert_ids.len() * 3 * self.hidden * self.inter;
            if layer.weights.len() != expect {
                bail!("layer weight count {} != {}", layer.weights.len(), expect);
            }
            w.write_f32s(&layer.weights)?;
        }
        let crc = w.crc();
        w.write_u32(crc)?;
        w.into_inner().flush()?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Adapter> {
        let f = std::fs::File::open(path)
            .with_context(|| format!("open {}", path.display()))?;
        let mut r = CrcReader::new(BufReader::new(f));
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("not an ESFT adapter file");
        }
        let version = r.read_u32()?;
        if version != VERSION {
            bail!("unsupported ESFT version {version}");
        }
        let name = r.read_str()?;
        let domain = r.read_str()?;
        let n_layers = r.read_u32()? as usize;
        let hidden = r.read_u32()? as usize;
        let inter = r.read_u32()? as usize;
        if n_layers > 1024 || hidden > 1 << 20 || inter > 1 << 20 {
            bail!("implausible header (corrupt file?)");
        }
        let mut layers = Vec::with_capacity(n_layers);
        for _ in 0..n_layers {
            let count = r.read_u32()? as usize;
            let mut expert_ids = Vec::with_capacity(count);
            for _ in 0..count {
                expert_ids.push(r.read_u32()?);
            }
            if !expert_ids.windows(2).all(|w| w[0] < w[1]) {
                bail!("expert ids not strictly sorted");
            }
            let weights = r.read_f32s(count * 3 * hidden * inter)?;
            layers.push(AdapterLayer { expert_ids, weights });
        }
        let computed = r.crc();
        let stored = r.read_u32()?;
        if computed != stored {
            bail!("crc mismatch: file corrupt");
        }
        Ok(Adapter { name, domain, hidden, inter, layers })
    }
}

// -- tiny CRC-32 (IEEE) streaming wrappers ---------------------------------

fn crc32_update(mut crc: u32, data: &[u8]) -> u32 {
    crc = !crc;
    for &b in data {
        crc ^= b as u32;
        for _ in 0..8 {
            crc = (crc >> 1) ^ (0xEDB88320 & (0u32.wrapping_sub(crc & 1)));
        }
    }
    !crc
}

struct CrcWriter<W: Write> {
    inner: W,
    crc: u32,
}

impl<W: Write> CrcWriter<W> {
    fn new(inner: W) -> Self {
        CrcWriter { inner, crc: 0 }
    }

    fn write_all(&mut self, data: &[u8]) -> Result<()> {
        self.crc = crc32_update(self.crc, data);
        self.inner.write_all(data)?;
        Ok(())
    }

    fn write_u32(&mut self, v: u32) -> Result<()> {
        self.write_all(&v.to_le_bytes())
    }

    fn write_str(&mut self, s: &str) -> Result<()> {
        self.write_u32(s.len() as u32)?;
        self.write_all(s.as_bytes())
    }

    fn write_f32s(&mut self, v: &[f32]) -> Result<()> {
        // bulk: f32 slice viewed as bytes (little-endian hosts only, as
        // is every supported target)
        let bytes =
            unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) };
        self.write_all(bytes)
    }

    fn crc(&self) -> u32 {
        self.crc
    }

    fn into_inner(self) -> W {
        self.inner
    }
}

struct CrcReader<R: Read> {
    inner: R,
    crc: u32,
}

impl<R: Read> CrcReader<R> {
    fn new(inner: R) -> Self {
        CrcReader { inner, crc: 0 }
    }

    fn read_exact(&mut self, buf: &mut [u8]) -> Result<()> {
        self.inner.read_exact(buf)?;
        self.crc = crc32_update(self.crc, buf);
        Ok(())
    }

    fn read_u32(&mut self) -> Result<u32> {
        // NOTE: the trailing crc field itself is read with read_u32_raw
        let mut b = [0u8; 4];
        self.read_exact(&mut b)?;
        Ok(u32::from_le_bytes(b))
    }

    fn read_str(&mut self) -> Result<String> {
        let len = self.read_u32()? as usize;
        if len > 4096 {
            bail!("implausible string length {len}");
        }
        let mut b = vec![0u8; len];
        self.read_exact(&mut b)?;
        Ok(String::from_utf8(b).context("invalid utf8 in adapter header")?)
    }

    fn read_f32s(&mut self, count: usize) -> Result<Vec<f32>> {
        let mut v = vec![0f32; count];
        let bytes = unsafe {
            std::slice::from_raw_parts_mut(v.as_mut_ptr() as *mut u8, count * 4)
        };
        self.inner.read_exact(bytes)?;
        self.crc = crc32_update(self.crc, bytes);
        Ok(v)
    }

    fn crc(&self) -> u32 {
        self.crc
    }
}

// The crc trailer is read after crc() is captured, so reading it through
// read_u32 (which updates crc) is fine — we already snapshotted.

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn sample_adapter(seed: u64) -> Adapter {
        let mut rng = Pcg::new(seed);
        let (hidden, inter) = (8, 4);
        let layers = (0..3)
            .map(|_| {
                let count = rng.below(4) as usize;
                let expert_ids: Vec<u32> =
                    rng.sample_distinct(16, count).into_iter().map(|x| x as u32).collect();
                let weights = (0..count * 3 * hidden * inter)
                    .map(|_| rng.f32() - 0.5)
                    .collect();
                AdapterLayer { expert_ids, weights }
            })
            .collect();
        Adapter {
            name: format!("ad{seed}"),
            domain: "math".into(),
            hidden,
            inter,
            layers,
        }
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("ew_fmt_test");
        std::fs::create_dir_all(&dir).unwrap();
        for seed in 0..5 {
            let a = sample_adapter(seed);
            let p = dir.join(format!("a{seed}.esft"));
            a.save(&p).unwrap();
            let b = Adapter::load(&p).unwrap();
            assert_eq!(a, b);
        }
    }

    #[test]
    fn corruption_detected() {
        let dir = std::env::temp_dir().join("ew_fmt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let a = sample_adapter(9);
        let p = dir.join("corrupt.esft");
        a.save(&p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&p, &bytes).unwrap();
        assert!(Adapter::load(&p).is_err());
    }

    #[test]
    fn not_an_adapter() {
        let dir = std::env::temp_dir().join("ew_fmt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("junk.esft");
        std::fs::write(&p, b"not an adapter").unwrap();
        assert!(Adapter::load(&p).is_err());
    }

    #[test]
    fn stats() {
        let a = Adapter {
            name: "x".into(),
            domain: "d".into(),
            hidden: 2,
            inter: 2,
            layers: vec![
                AdapterLayer { expert_ids: vec![0, 1, 2], weights: vec![0.0; 36] },
                AdapterLayer { expert_ids: vec![5], weights: vec![0.0; 12] },
            ],
        };
        assert_eq!(a.max_experts(), 3);
        assert!((a.avg_experts() - 2.0).abs() < 1e-9);
        // S = ((3-3) + (3-1)) / (2*3) = 1/3
        assert!((a.sparsity() - 1.0 / 3.0).abs() < 1e-9);
        assert_eq!(a.weight_bytes(), 4 * 3 * 2 * 2 * 4);
    }
}
