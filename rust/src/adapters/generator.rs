//! Synthetic ESFT adapter generator.
//!
//! Off-the-shelf ESFT checkpoints are scarce (the paper itself notes
//! this); we cannot download the 10 published adapters offline, so we
//! regenerate adapters whose *expert-count profiles* — max experts in any
//! layer, average per layer, hence sparsity factor S_i — match Table 1 of
//! the paper exactly. Serving-system behaviour (memory layout, routing,
//! batching) depends only on these counts and the expert placements, not
//! on the weight values, so the substitution preserves every experiment
//! (DESIGN.md section 7).

use super::format::{Adapter, AdapterLayer};
use crate::util::rng::Pcg;

/// A Table-1 row: target profile for one synthetic adapter.
#[derive(Debug, Clone)]
pub struct AdapterProfile {
    pub name: &'static str,
    pub domain: &'static str,
    /// E_i — max fine-tuned experts in any layer.
    pub max_experts: usize,
    /// target mean experts per layer.
    pub avg_experts: f64,
}

/// The 10 published adapters of Table 1 (5 domains x {gate, token}).
pub fn paper_adapter_profiles() -> Vec<AdapterProfile> {
    vec![
        AdapterProfile { name: "gate-math", domain: "math", max_experts: 12, avg_experts: 7.04 },
        AdapterProfile { name: "token-math", domain: "math", max_experts: 9, avg_experts: 6.12 },
        AdapterProfile { name: "gate-intent", domain: "intent", max_experts: 12, avg_experts: 9.50 },
        AdapterProfile { name: "token-intent", domain: "intent", max_experts: 8, avg_experts: 7.12 },
        AdapterProfile { name: "gate-summary", domain: "summary", max_experts: 11, avg_experts: 7.73 },
        AdapterProfile { name: "token-summary", domain: "summary", max_experts: 8, avg_experts: 5.15 },
        AdapterProfile { name: "gate-law", domain: "law", max_experts: 12, avg_experts: 7.35 },
        AdapterProfile { name: "token-law", domain: "law", max_experts: 10, avg_experts: 6.58 },
        AdapterProfile { name: "gate-translation", domain: "translation", max_experts: 13, avg_experts: 4.69 },
        AdapterProfile { name: "token-translation", domain: "translation", max_experts: 6, avg_experts: 3.85 },
    ]
}

/// Per-layer expert counts hitting `max` exactly and `avg` as closely as
/// an integer profile over `layers` allows (|achieved - avg| < 1/L).
pub fn layer_counts(profile: &AdapterProfile, layers: usize, rng: &mut Pcg) -> Vec<usize> {
    assert!(layers >= 1);
    let target_total = (profile.avg_experts * layers as f64).round() as usize;
    let max = profile.max_experts;
    let target_total = target_total.clamp(max, layers * max);
    // start: one layer at the max, the rest at floor(average of remainder)
    let mut counts = vec![0usize; layers];
    counts[0] = max;
    let mut rest = target_total - max;
    // spread the remainder as evenly as possible, capped at max
    for i in 1..layers {
        let left = layers - i;
        let take = (rest / left).min(max);
        counts[i] = take;
        rest -= take;
    }
    // distribute leftover +1s (can happen due to the cap)
    let mut i = 1;
    while rest > 0 && i < layers {
        if counts[i] < max {
            counts[i] += 1;
            rest -= 1;
        }
        i += 1;
        if i == layers {
            i = 1;
        }
    }
    // jitter pairs (keep sum, keep <= max, keep the single max layer) for
    // realistic variance across layers
    for _ in 0..(if layers > 1 { layers * 4 } else { 0 }) {
        let a = 1 + rng.below((layers - 1) as u64) as usize;
        let b = 1 + rng.below((layers - 1) as u64) as usize;
        if a != b && counts[a] > 1 && counts[b] + 1 < max {
            counts[a] -= 1;
            counts[b] += 1;
        }
    }
    // place the max layer somewhere random
    let swap_to = rng.below(layers as u64) as usize;
    counts.swap(0, swap_to);
    counts
}

/// Generate a full synthetic adapter for a model geometry.
///
/// * expert IDs per layer follow a task-specific preference: each domain
///   seed biases a fixed subset of experts (the "expert specialization"
///   pattern ESFT exploits — top-activated sets differ across tasks).
/// * weights are seeded noise at fine-tuning scale (`base + 0.05·N(0,1)`
///   is applied at registry-load time against the base weights; here we
///   store the standalone fine-tuned rows).
pub fn synth_adapter(
    profile: &AdapterProfile,
    layers: usize,
    num_experts: usize,
    hidden: usize,
    inter: usize,
    seed: u64,
) -> Adapter {
    let mut rng = Pcg::with_stream(seed, fxhash(profile.name));
    let counts = layer_counts(profile, layers, &mut rng);
    // Domain-preferred experts: a fixed half of the expert space is 4x
    // more likely, making routed traffic concentrate like real ESFT tasks.
    let mut pref: Vec<f64> = vec![1.0; num_experts];
    let mut drng = Pcg::with_stream(fxhash(profile.domain), 77);
    for _ in 0..num_experts / 2 {
        pref[drng.below(num_experts as u64) as usize] = 4.0;
    }
    let total: f64 = pref.iter().sum();
    let probs: Vec<f64> = pref.iter().map(|p| p / total).collect();

    let layers_vec = (0..layers)
        .map(|_l| {
            let count = counts[_l].min(num_experts);
            // weighted distinct sampling
            let mut chosen = std::collections::BTreeSet::new();
            while chosen.len() < count {
                chosen.insert(rng.categorical(&probs) as u32);
            }
            let expert_ids: Vec<u32> = chosen.into_iter().collect();
            let n = expert_ids.len() * 3 * hidden * inter;
            let scale = 1.0 / (hidden as f32).sqrt();
            // uniform (not gaussian): ~5x faster generation at the 20-adapter
            // x 100M-param scale, indistinguishable for system behaviour
            let weights = (0..n).map(|_| (rng.f32() * 2.0 - 1.0) * scale).collect();
            AdapterLayer { expert_ids, weights }
        })
        .collect();
    Adapter {
        name: profile.name.to_string(),
        domain: profile.domain.to_string(),
        hidden,
        inter,
        layers: layers_vec,
    }
}

/// Synthesize `n` Table-1-profile adapters fitted to a model geometry
/// (the shared recipe of the CLI, fleet benches and tests): profiles
/// cycle through the 10 paper rows with expert counts clamped to the
/// config's `e_max`, and names are uniqued once `n` exceeds the
/// profile set so registries and fleet directories never collide.
pub fn synth_fleet_adapters(
    cfg: &crate::model::ModelConfig,
    n: usize,
    seed: u64,
) -> Vec<Adapter> {
    let profiles = paper_adapter_profiles();
    (0..n)
        .map(|i| {
            let mut p = profiles[i % profiles.len()].clone();
            p.max_experts = p.max_experts.min(cfg.e_max);
            p.avg_experts = p.avg_experts.min(p.max_experts as f64);
            let mut ad = synth_adapter(
                &p,
                cfg.layers,
                cfg.num_experts,
                cfg.hidden,
                cfg.expert_inter,
                seed + i as u64,
            );
            if i >= profiles.len() {
                ad.name = format!("{}+{}", ad.name, i / profiles.len());
            }
            ad
        })
        .collect()
}

/// Memory fragmentation factor F_mem of the padding approach for a set of
/// adapters (paper section 3.1):
/// `L * (M + N*E_max) / Σ_l (M + Σ_i e_i^(l))`.
pub fn fragmentation_factor(adapters: &[Adapter], m: usize, e_max: usize) -> f64 {
    if adapters.is_empty() {
        return 1.0;
    }
    let l = adapters[0].layers.len();
    let n = adapters.len();
    let allocated = l * (m + n * e_max);
    let used: usize = (0..l)
        .map(|li| m + adapters.iter().map(|a| a.layers[li].expert_count()).sum::<usize>())
        .sum();
    allocated as f64 / used as f64
}

/// Adapter-weights-only fragmentation (excludes the base model's M slots).
/// Note: the paper's reported F_mem = 1.51 uses the whole-tensor form
/// ([`fragmentation_factor`]); this adapter-only view is stricter (~2.0
/// for the Table-1 set) and is reported alongside it by the benches.
pub fn adapter_fragmentation_factor(adapters: &[Adapter], e_max: usize) -> f64 {
    if adapters.is_empty() {
        return 1.0;
    }
    let l = adapters[0].layers.len();
    let n = adapters.len();
    let allocated = l * n * e_max;
    let used: usize = adapters.iter().map(Adapter::total_experts).sum();
    allocated as f64 / used.max(1) as f64
}

fn fxhash(s: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    const L: usize = 26; // paper layer count for Table 1 checks

    #[test]
    fn layer_counts_hit_profile() {
        let mut rng = Pcg::new(1);
        for p in paper_adapter_profiles() {
            let counts = layer_counts(&p, L, &mut rng);
            assert_eq!(counts.len(), L);
            assert_eq!(*counts.iter().max().unwrap(), p.max_experts, "{}", p.name);
            let avg = counts.iter().sum::<usize>() as f64 / L as f64;
            assert!(
                (avg - p.avg_experts).abs() <= 0.5 / L as f64 + 0.021,
                "{}: avg {avg} target {}",
                p.name,
                p.avg_experts
            );
            assert!(counts.iter().all(|&c| c >= 1 && c <= p.max_experts));
        }
    }

    #[test]
    fn sparsity_matches_table1() {
        // Table 1's sparsity column follows from (max, avg):
        // S = (E - avg) / E. Verify generated adapters land on it.
        let expected = [
            ("gate-math", 0.41),
            ("token-math", 0.32),
            ("gate-intent", 0.21),
            ("token-intent", 0.11),
            ("gate-summary", 0.30),
            ("token-summary", 0.36),
            ("gate-law", 0.39),
            ("token-law", 0.34),
            ("gate-translation", 0.64),
            ("token-translation", 0.36),
        ];
        for (p, (name, s_target)) in paper_adapter_profiles().iter().zip(expected) {
            assert_eq!(p.name, name);
            let a = synth_adapter(p, L, 64, 8, 4, 42);
            assert!(
                (a.sparsity() - s_target).abs() < 0.03,
                "{name}: S {} vs table {s_target}",
                a.sparsity()
            );
        }
    }

    #[test]
    fn fragmentation_factor_matches_paper() {
        // paper: E_max = 13 over the 10 adapters yields F_mem = 1.51
        // (whole-tensor form, M = 64 base experts included)
        let adapters: Vec<Adapter> = paper_adapter_profiles()
            .iter()
            .map(|p| synth_adapter(p, L, 64, 8, 4, 42))
            .collect();
        let f = fragmentation_factor(&adapters, 64, 13);
        assert!((f - 1.51).abs() < 0.03, "F_mem = {f}");
        // adapter-only view is ~2x
        let fa = adapter_fragmentation_factor(&adapters, 13);
        assert!((fa - 2.0).abs() < 0.1, "adapter-only F = {fa}");
    }

    #[test]
    fn expert_ids_valid_and_sorted() {
        let p = &paper_adapter_profiles()[0];
        let a = synth_adapter(p, 8, 64, 8, 4, 7);
        for layer in &a.layers {
            assert!(layer.expert_ids.windows(2).all(|w| w[0] < w[1]));
            assert!(layer.expert_ids.iter().all(|&id| (id as usize) < 64));
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let p = &paper_adapter_profiles()[3];
        let a = synth_adapter(p, 8, 64, 8, 4, 5);
        let b = synth_adapter(p, 8, 64, 8, 4, 5);
        let c = synth_adapter(p, 8, 64, 8, 4, 6);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn property_counts_within_bounds_any_profile() {
        crate::util::prop::check(404, 60, |rng| {
            let max = 1 + rng.below(16) as usize;
            let avg = 1.0 + rng.f64() * (max as f64 - 1.0);
            let layers = 1 + rng.below(32) as usize;
            let p = AdapterProfile {
                name: "x",
                domain: "d",
                max_experts: max,
                avg_experts: avg,
            };
            let counts = layer_counts(&p, layers, rng);
            assert_eq!(counts.len(), layers);
            assert_eq!(*counts.iter().max().unwrap(), max);
            let total: usize = counts.iter().sum();
            let target = (avg * layers as f64).round() as usize;
            assert_eq!(total, target.clamp(max, layers * max));
        });
    }
}
