//! ESFT adapter ecosystem: on-disk format, synthetic generator matching
//! the paper's published adapter statistics (Table 1), the per-layer ESFT
//! expert map Π, and the runtime adapter registry.
//!
//! An **ESFT adapter** is, per MoE layer, a (possibly empty) set of
//! fine-tuned experts identified by base-model expert ID, plus the new
//! weights for exactly those experts. Counts vary across layers and
//! across adapters (the source of the fragmentation problem the virtual
//! weight tensor solves).

pub mod expert_map;
pub mod format;
pub mod generator;
pub mod registry;

pub use expert_map::ExpertMaps;
pub use format::{Adapter, AdapterLayer};
pub use generator::{
    paper_adapter_profiles, synth_adapter, synth_fleet_adapters, AdapterProfile,
};
pub use registry::AdapterRegistry;
