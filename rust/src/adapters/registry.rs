//! Runtime adapter registry: slot assignment, load/evict lifecycle, and
//! the coupling between the weight store (where the expert rows live) and
//! the ESFT expert maps (how the router finds them).
//!
//! Requests carry an adapter *name*; the registry resolves it to the AID
//! (slot index) the batch carries to the device. Loading an adapter is the
//! paper's Figure-1 flow: host-cached [`Adapter`] → physical pages mapped
//! into the virtual weight tensor → expert map rows installed.

use super::expert_map::ExpertMaps;
use super::format::Adapter;
use crate::model::ModelConfig;
use crate::weights::store::WeightStore;
use anyhow::{bail, Result};
use std::collections::HashMap;

/// Metadata of one resident adapter.
#[derive(Debug, Clone)]
pub struct ResidentAdapter {
    pub name: String,
    pub domain: String,
    pub slot: usize,
    /// Fine-tuned expert counts per layer (for stats/evict).
    pub counts: Vec<usize>,
    /// Monotonic use counter for LRU eviction.
    pub last_use: u64,
}

/// Adapter slot manager over a [`WeightStore`] + [`ExpertMaps`].
pub struct AdapterRegistry {
    cfg: ModelConfig,
    maps: ExpertMaps,
    by_name: HashMap<String, usize>,
    slots: Vec<Option<ResidentAdapter>>,
    clock: u64,
    /// Bumped whenever the expert maps change (engine re-uploads then).
    maps_version: u64,
}

impl AdapterRegistry {
    pub fn new(cfg: &ModelConfig) -> Self {
        AdapterRegistry {
            cfg: cfg.clone(),
            maps: ExpertMaps::new(cfg),
            by_name: HashMap::new(),
            slots: (0..cfg.max_adapters).map(|_| None).collect(),
            clock: 0,
            maps_version: 1,
        }
    }

    pub fn maps(&self) -> &ExpertMaps {
        &self.maps
    }

    pub fn maps_version(&self) -> u64 {
        self.maps_version
    }

    pub fn resident_count(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    pub fn resident(&self) -> impl Iterator<Item = &ResidentAdapter> {
        self.slots.iter().filter_map(|s| s.as_ref())
    }

    /// Resolve a request's adapter name to its AID; `None` (base model)
    /// maps to -1. Bumps the LRU clock.
    pub fn resolve(&mut self, name: Option<&str>) -> Result<i32> {
        match name {
            None => Ok(-1),
            Some(n) => match self.by_name.get(n) {
                Some(&slot) => {
                    self.clock += 1;
                    if let Some(r) = self.slots[slot].as_mut() {
                        r.last_use = self.clock;
                    }
                    Ok(slot as i32)
                }
                None => bail!("adapter {n:?} is not loaded"),
            },
        }
    }

    /// Peek an AID without touching LRU state.
    pub fn aid_of(&self, name: &str) -> Option<i32> {
        self.by_name.get(name).map(|&s| s as i32)
    }

    /// Load an adapter into a free slot (or error if full — callers can
    /// evict first via [`Self::lru_victim`]).
    pub fn load(&mut self, store: &mut WeightStore, adapter: &Adapter) -> Result<usize> {
        if self.by_name.contains_key(&adapter.name) {
            bail!("adapter {:?} already loaded", adapter.name);
        }
        let slot = match self.slots.iter().position(Option::is_none) {
            Some(s) => s,
            None => bail!(
                "no free adapter slots (N = {}); evict first",
                self.cfg.max_adapters
            ),
        };
        store.load_adapter(slot, adapter)?;
        let per_layer: Vec<Vec<u32>> =
            adapter.layers.iter().map(|l| l.expert_ids.clone()).collect();
        if let Err(e) = self.maps.install(slot, &per_layer) {
            // keep store and maps consistent
            let _ = store.unload_adapter(slot);
            return Err(e);
        }
        self.clock += 1;
        self.slots[slot] = Some(ResidentAdapter {
            name: adapter.name.clone(),
            domain: adapter.domain.clone(),
            slot,
            counts: adapter.layers.iter().map(|l| l.expert_count()).collect(),
            last_use: self.clock,
        });
        self.by_name.insert(adapter.name.clone(), slot);
        self.maps_version += 1;
        Ok(slot)
    }

    /// Evict by name; frees pages and resets the map rows.
    pub fn evict(&mut self, store: &mut WeightStore, name: &str) -> Result<usize> {
        let slot = match self.by_name.remove(name) {
            Some(s) => s,
            None => bail!("adapter {name:?} is not loaded"),
        };
        store.unload_adapter(slot)?;
        self.maps.clear(slot)?;
        self.slots[slot] = None;
        self.maps_version += 1;
        Ok(slot)
    }

    /// Least-recently-used resident adapter (eviction candidate).
    pub fn lru_victim(&self) -> Option<&ResidentAdapter> {
        self.resident().min_by_key(|r| r.last_use)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapters::generator::{paper_adapter_profiles, synth_adapter};
    use crate::memsim::DeviceMemory;
    use crate::vmm::page_pool::PagePool;
    use crate::weights::base_gen::BaseWeights;
    use crate::weights::store::StoreMode;
    use std::sync::{Arc, Mutex};

    fn cfg() -> ModelConfig {
        let mut c = ModelConfig::paper16b();
        c.name = "t".into();
        c.hidden = 16;
        c.layers = 2;
        c.num_experts = 8;
        c.expert_inter = 8;
        c.shared_inter = 16;
        c.max_adapters = 2;
        c.e_max = 3;
        c.vocab = 32;
        c.q_heads = 2;
        c.kv_heads = 1;
        c.head_dim = 8;
        c
    }

    fn setup() -> (AdapterRegistry, WeightStore) {
        let c = cfg();
        let pool = Arc::new(Mutex::new(PagePool::new(64 << 10, 4096).unwrap()));
        let device = DeviceMemory::shared(usize::MAX / 2);
        let mut store = WeightStore::new(&c, StoreMode::Virtual, pool, device).unwrap();
        store.load_base(&BaseWeights::generate(&c, 0)).unwrap();
        (AdapterRegistry::new(&c), store)
    }

    fn ad(name: &'static str, seed: u64) -> Adapter {
        let c = cfg();
        let mut p = paper_adapter_profiles()[0].clone();
        p.name = name;
        p.max_experts = c.e_max;
        p.avg_experts = 2.0;
        synth_adapter(&p, c.layers, c.num_experts, c.hidden, c.expert_inter, seed)
    }

    #[test]
    fn load_resolve_evict_cycle() {
        let (mut reg, mut store) = setup();
        assert_eq!(reg.resolve(None).unwrap(), -1);
        assert!(reg.resolve(Some("a")).is_err());

        let s0 = reg.load(&mut store, &ad("a", 1)).unwrap();
        let s1 = reg.load(&mut store, &ad("b", 2)).unwrap();
        assert_ne!(s0, s1);
        assert_eq!(reg.resolve(Some("a")).unwrap(), s0 as i32);
        assert_eq!(reg.resident_count(), 2);

        // full: third load fails until eviction
        assert!(reg.load(&mut store, &ad("c", 3)).is_err());
        reg.evict(&mut store, "a").unwrap();
        assert!(reg.aid_of("a").is_none());
        let s2 = reg.load(&mut store, &ad("c", 3)).unwrap();
        assert_eq!(s2, s0); // reuses the freed slot
    }

    #[test]
    fn maps_follow_lifecycle() {
        let (mut reg, mut store) = setup();
        let v0 = reg.maps_version();
        let a = ad("a", 4);
        let slot = reg.load(&mut store, &a).unwrap();
        assert!(reg.maps_version() > v0);
        // a fine-tuned expert points into the adapter window
        let c = cfg();
        let delta = c.adapter_slot_base(slot) as i32;
        let l0 = &a.layers[0].expert_ids;
        if let Some(&j) = l0.first() {
            let got = reg.maps().lookup(0, slot as i32, j as usize);
            assert!(got >= delta && got < delta + c.e_max as i32);
        }
        reg.evict(&mut store, "a").unwrap();
        if let Some(&j) = l0.first() {
            assert_eq!(reg.maps().lookup(0, slot as i32, j as usize), j as i32);
        }
    }

    #[test]
    fn lru_victim_is_least_recently_resolved() {
        let (mut reg, mut store) = setup();
        reg.load(&mut store, &ad("a", 1)).unwrap();
        reg.load(&mut store, &ad("b", 2)).unwrap();
        reg.resolve(Some("a")).unwrap(); // touch a; b is now LRU
        assert_eq!(reg.lru_victim().unwrap().name, "b");
        reg.resolve(Some("b")).unwrap();
        assert_eq!(reg.lru_victim().unwrap().name, "a");
    }

    #[test]
    fn duplicate_name_rejected() {
        let (mut reg, mut store) = setup();
        reg.load(&mut store, &ad("a", 1)).unwrap();
        assert!(reg.load(&mut store, &ad("a", 9)).is_err());
    }
}
