//! Micro-benchmark harness (criterion substitute) used by the
//! `rust/benches/*` paper-reproduction targets.
//!
//! Provides warmup + timed sampling with summary statistics, simple
//! fixed-width table printing (the "same rows the paper reports"), and
//! CSV emission under `target/bench_results/` for EXPERIMENTS.md.

use crate::util::stats::{Samples, Summary};
use std::io::Write;
use std::time::Instant;

/// Time `f` for `samples` measured runs after `warmup` unmeasured ones.
pub fn time_fn<F: FnMut()>(warmup: usize, samples: usize, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut s = Samples::new();
    for _ in 0..samples {
        let t0 = Instant::now();
        f();
        s.push(t0.elapsed().as_secs_f64());
    }
    s.summary()
}

/// Time a fallible closure, propagating the first error.
pub fn time_fn_result<F: FnMut() -> anyhow::Result<()>>(
    warmup: usize,
    samples: usize,
    mut f: F,
) -> anyhow::Result<Summary> {
    for _ in 0..warmup {
        f()?;
    }
    let mut s = Samples::new();
    for _ in 0..samples {
        let t0 = Instant::now();
        f()?;
        s.push(t0.elapsed().as_secs_f64());
    }
    Ok(s.summary())
}

/// Fixed-width table printer for paper-style result blocks.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self, title: &str) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        println!("\n== {title} ==");
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:<w$}  ", c, w = widths[i]));
            }
            println!("{}", s.trim_end());
        };
        line(&self.headers);
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        for row in &self.rows {
            line(row);
        }
    }

    /// Write the table as CSV under `target/bench_results/<name>.csv`.
    pub fn write_csv(&self, name: &str) -> std::io::Result<std::path::PathBuf> {
        let dir = std::path::Path::new("target/bench_results");
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{name}.csv"));
        let mut f = std::fs::File::create(&path)?;
        writeln!(f, "{}", self.headers.join(","))?;
        for row in &self.rows {
            writeln!(f, "{}", row.join(","))?;
        }
        Ok(path)
    }
}

/// Format seconds as adaptive ms/us.
pub fn fmt_time(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.2}s")
    } else if secs >= 1e-3 {
        format!("{:.1}ms", secs * 1e3)
    } else {
        format!("{:.1}us", secs * 1e6)
    }
}

/// Bytes with binary units.
pub fn fmt_bytes(b: usize) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u + 1 < UNITS.len() {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b}B")
    } else {
        format!("{v:.2}{}", UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_sane() {
        let s = time_fn(1, 5, || std::thread::sleep(std::time::Duration::from_millis(2)));
        assert_eq!(s.n, 5);
        assert!(s.median >= 0.002 && s.median < 0.2, "{}", s.median);
    }

    #[test]
    fn table_roundtrip() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        t.print("test");
        let p = t.write_csv("harness_selftest").unwrap();
        let text = std::fs::read_to_string(p).unwrap();
        assert_eq!(text, "a,b\n1,2\n");
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_time(2.0), "2.00s");
        assert_eq!(fmt_time(0.0025), "2.5ms");
        assert_eq!(fmt_time(2.5e-6), "2.5us");
        assert_eq!(fmt_bytes(10), "10B");
        assert_eq!(fmt_bytes(2 << 20), "2.00MiB");
    }
}
