//! Fleet-level adapter lifecycle state: who holds which adapter, which
//! resident is the eviction candidate, and how hot each adapter is.
//!
//! The coordinator is the only issuer of load/evict commands, so this
//! directory is authoritative (replica engines double-check evictions as
//! a safety net). All state is plain data — no channels — so the
//! placement logic is unit-testable.

use std::collections::HashMap;

/// Residency map: adapter placements per replica with per-placement LRU
/// ticks.
#[derive(Debug)]
pub struct AdapterDirectory {
    capacity: usize,
    /// Per replica: adapter name → last-use tick.
    resident: Vec<HashMap<String, u64>>,
    clock: u64,
}

impl AdapterDirectory {
    /// `capacity` = adapter slots per replica (N of the virtual weight
    /// tensor, or a tighter policy cap).
    pub fn new(replicas: usize, capacity: usize) -> AdapterDirectory {
        AdapterDirectory {
            capacity,
            resident: (0..replicas).map(|_| HashMap::new()).collect(),
            clock: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn is_resident(&self, replica: usize, name: &str) -> bool {
        self.resident[replica].contains_key(name)
    }

    /// Replicas currently holding `name`, ascending.
    pub fn replicas_of(&self, name: &str) -> Vec<usize> {
        (0..self.resident.len())
            .filter(|&r| self.is_resident(r, name))
            .collect()
    }

    /// How many replicas hold `name`.
    pub fn copies(&self, name: &str) -> usize {
        self.resident.iter().filter(|m| m.contains_key(name)).count()
    }

    /// Resident adapters on one replica.
    pub fn count(&self, replica: usize) -> usize {
        self.resident[replica].len()
    }

    pub fn has_free_slot(&self, replica: usize) -> bool {
        self.count(replica) < self.capacity
    }

    /// Record a placement (load issued) and mark it most-recently used.
    pub fn insert(&mut self, replica: usize, name: &str) {
        self.clock += 1;
        self.resident[replica].insert(name.to_string(), self.clock);
    }

    /// Record an eviction (or a failed load rollback).
    pub fn remove(&mut self, replica: usize, name: &str) {
        self.resident[replica].remove(name);
    }

    /// Bump the LRU tick of a placement (a request was routed to it).
    pub fn touch(&mut self, replica: usize, name: &str) {
        self.clock += 1;
        if let Some(t) = self.resident[replica].get_mut(name) {
            *t = self.clock;
        }
    }

    /// Extend the directory for a replica that joined at runtime (its
    /// index is the new length; indices are append-only and stable).
    pub fn add_replica(&mut self) {
        self.resident.push(HashMap::new());
    }

    /// Forget every placement on a dead replica (its slots are gone with
    /// the engine). The index stays valid — an empty map — so positional
    /// bookkeeping across the fleet is untouched.
    pub fn clear_replica(&mut self, replica: usize) {
        self.resident[replica].clear();
    }

    /// Least-recently-used resident on `replica` among those `idle`
    /// accepts (callers pass "no in-flight requests and not the adapter
    /// being placed").
    pub fn lru_evictable<F: Fn(&str) -> bool>(&self, replica: usize, idle: F) -> Option<String> {
        self.resident[replica]
            .iter()
            .filter(|e| idle(e.0))
            .min_by_key(|e| *e.1)
            .map(|e| e.0.clone())
    }
}

/// Per-adapter arrival-rate estimator: an exponentially decayed arrival
/// counter with configurable half-life. At steady state a Poisson
/// stream of rate λ holds weight `λ·h/ln2`, so the estimate is
/// `w·ln2/h` — reactive to bursts, cheap to update, no window storage.
#[derive(Debug)]
pub struct RateTracker {
    halflife: f64,
    /// name → (decayed weight, last observation time).
    w: HashMap<String, (f64, f64)>,
}

impl RateTracker {
    pub fn new(halflife: f64) -> RateTracker {
        RateTracker { halflife: halflife.max(1e-3), w: HashMap::new() }
    }

    /// Record an arrival for `name` at trace-time `t` (seconds,
    /// monotone); returns the smoothed req/s estimate.
    pub fn observe(&mut self, name: &str, t: f64) -> f64 {
        let (w, last) = self
            .w
            .get(name)
            .copied()
            .unwrap_or((0.0, t));
        let dt = (t - last).max(0.0);
        let decayed = w * 0.5f64.powf(dt / self.halflife) + 1.0;
        self.w.insert(name.to_string(), (decayed, t));
        decayed * std::f64::consts::LN_2 / self.halflife
    }

    /// Current estimate without recording an arrival.
    pub fn rate(&self, name: &str, t: f64) -> f64 {
        match self.w.get(name) {
            Some(&(w, last)) => {
                let dt = (t - last).max(0.0);
                w * 0.5f64.powf(dt / self.halflife) * std::f64::consts::LN_2 / self.halflife
            }
            None => 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn directory_lifecycle_and_lru() {
        let mut d = AdapterDirectory::new(2, 2);
        assert!(d.has_free_slot(0));
        d.insert(0, "a");
        d.insert(0, "b");
        assert!(!d.has_free_slot(0));
        assert_eq!(d.count(0), 2);
        assert_eq!(d.replicas_of("a"), vec![0]);
        d.insert(1, "a");
        assert_eq!(d.copies("a"), 2);

        // "a" was placed first but touch makes "b" older
        d.touch(0, "a");
        assert_eq!(d.lru_evictable(0, |_| true).unwrap(), "b");
        // filter excludes the only candidate -> none
        assert!(d.lru_evictable(0, |n| n != "b" && n != "a").is_none());

        d.remove(0, "b");
        assert!(d.has_free_slot(0));
        assert!(!d.is_resident(0, "b"));
    }

    #[test]
    fn directory_tracks_membership_changes() {
        let mut d = AdapterDirectory::new(2, 2);
        d.insert(0, "a");
        d.insert(1, "a");
        d.insert(1, "b");

        // a runtime join extends the index space, empty
        d.add_replica();
        assert_eq!(d.count(2), 0);
        assert!(d.has_free_slot(2));
        d.insert(2, "b");
        assert_eq!(d.copies("b"), 2);
        assert_eq!(d.replicas_of("b"), vec![1, 2]);

        // a replica loss clears its placements but keeps the index
        d.clear_replica(1);
        assert_eq!(d.count(1), 0);
        assert_eq!(d.copies("a"), 1);
        assert_eq!(d.replicas_of("b"), vec![2]);
        // the cleared slot can be repopulated (rebalance re-placement)
        d.insert(1, "a");
        assert_eq!(d.copies("a"), 2);
    }

    #[test]
    fn rate_tracker_converges_and_decays() {
        let mut r = RateTracker::new(1.0);
        // 10 req/s for 5 seconds
        let mut rate = 0.0;
        for i in 0..50 {
            rate = r.observe("hot", i as f64 * 0.1);
        }
        assert!((rate - 10.0).abs() < 2.5, "steady-state estimate {rate}");
        // a cold adapter stays cold
        let cold = r.observe("cold", 5.0);
        assert!(cold < 1.5, "single arrival {cold}");
        // decay: after 10 halflives the hot adapter is near zero
        assert!(r.rate("hot", 15.0) < 0.2);
        assert_eq!(r.rate("never", 0.0), 0.0);
    }
}
