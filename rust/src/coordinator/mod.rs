//! Fleet coordinator — the layer above single engines (the paper's L3
//! coordination role): adapter-aware request routing across N engine
//! replicas, fleet-level adapter lifecycle, and admission control.
//!
//! One ExpertWeave engine already serves ~20 adapters with single-digit
//! overhead; a production fleet runs many such replicas, and the win
//! over one-merged-engine-per-adapter deployments (ESFT-style,
//! [`crate::server::replay_multi`]) is decided a layer up: *which
//! replica serves which adapter*. This module owns that decision.
//!
//! # Architecture
//!
//! ```text
//!   Trace ──▶ Coordinator ──(FIFO cmd channel per replica)──▶ replica-0 [Engine]
//!              │  ▲                                      └──▶ replica-1 [Engine]
//!              │  └──(shared event channel: completions,      ...
//!              │      load/evict acks, reports)
//!              ├─ AdapterDirectory  (residency + per-placement LRU)
//!              ├─ RateTracker      (per-adapter EWMA arrival rates)
//!              └─ RoutingPolicy    (pure scoring over ReplicaViews)
//! ```
//!
//! Each replica is an [`Engine`] on its own thread (PJRT handles are not
//! `Send`; engines are built inside their threads). Per-replica command
//! channels are FIFO, which makes `Load(A); Submit(req-for-A)` safe
//! without waiting for acknowledgements.
//!
//! # Routing policies ([`RoutingPolicy`])
//!
//! * **RoundRobin** — stateless cycling. Fair in request count, blind to
//!   both load and adapter residency: under a skewed adapter mix every
//!   replica eventually needs every adapter, so small per-replica
//!   adapter capacity turns into continuous load/evict churn (each miss
//!   costs a weight re-sync) and shed requests once nothing idle is
//!   left to evict.
//! * **JoinShortestQueue** — route to the replica with the fewest
//!   outstanding requests (ties: most free KV slots). Evens out queue
//!   depth and so protects TTFT tails, but it is adapter-blind and
//!   inherits RoundRobin's churn under skew.
//! * **AdapterAffinity** — the coordinator's reason to exist: prefer
//!   replicas where the adapter is already resident, scored by queue
//!   depth then free KV slots; miss only when no copy is resident, then
//!   place on the least-loaded replica that can host one (free slot or
//!   idle LRU victim). Keeps hot adapters pinned, confines churn to the
//!   cold tail, and — combined with rate-triggered replication — turns
//!   a hot adapter into multiple copies instead of one hot replica.
//!
//! # Lifecycle
//!
//! Load-on-miss with per-replica capacity
//! ([`CoordinatorConfig::adapter_capacity`]) and LRU eviction; an
//! adapter with in-flight
//! requests on a replica is never chosen as victim (and
//! [`Engine::evict_adapter`] enforces the same invariant). When an
//! adapter's smoothed arrival rate crosses
//! [`CoordinatorConfig::replicate_rps`], it is proactively replicated to
//! the least-loaded replica with a free slot, up to
//! [`CoordinatorConfig::max_copies`] copies.
//!
//! # Admission control
//!
//! Per-adapter outstanding-request budgets
//! ([`CoordinatorConfig::queue_cap`]) shed excess arrivals at the door
//! instead of letting one hot adapter monopolize every queue; requests
//! whose adapter no replica can host are shed likewise. Shed and
//! rejected counts surface in [`Report::shed`] / [`Report::rejected`]
//! and in [`FleetStats`].

mod lifecycle;
mod replica;
mod router;

pub use lifecycle::{AdapterDirectory, RateTracker};
pub use replica::{ReplicaGauges, ReplicaHandle};
pub use router::{choose, ReplicaView, RouteDecision, RoutingPolicy};

use crate::adapters::format::Adapter;
use crate::engine::{Completion, Engine, RequestSpec};
use crate::metrics::Report;
use crate::sampler::Sampling;
use crate::server::Pacer;
use crate::util::stats::Samples;
use crate::workload::trace::Trace;
use anyhow::{bail, Result};
use replica::{spawn_replica, ReplicaCmd, ReplicaEvent};
use std::collections::HashMap;
use std::sync::atomic::Ordering;
use std::sync::mpsc::Receiver;
use std::sync::Arc;
use std::time::Duration;

/// Fleet-level tuning knobs.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Engine replicas in the fleet.
    pub replicas: usize,
    pub policy: RoutingPolicy,
    /// Resident-adapter budget per replica (≤ the model's `max_adapters`;
    /// smaller values model device-memory pressure).
    pub adapter_capacity: usize,
    /// Max outstanding (routed, uncompleted) requests per adapter across
    /// the fleet; arrivals beyond it are shed. 0 = unbounded.
    pub queue_cap: usize,
    /// Smoothed arrival rate (req/s) above which a hot adapter is
    /// replicated to another replica. `f64::INFINITY` disables.
    pub replicate_rps: f64,
    /// Half-life (seconds) of the arrival-rate EWMA.
    pub rate_halflife: f64,
    /// Max replicas any single adapter may be resident on. Enforced on
    /// both proactive replication and load-on-miss: an adapter-blind
    /// policy (RoundRobin/JSQ) that targets a replica without the
    /// adapter sheds the request once the copy budget is spent, rather
    /// than silently exceeding it.
    pub max_copies: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            replicas: 2,
            policy: RoutingPolicy::AdapterAffinity,
            adapter_capacity: 4,
            queue_cap: 64,
            replicate_rps: f64::INFINITY,
            rate_halflife: 2.0,
            max_copies: 2,
        }
    }
}

/// Routing / lifecycle / admission counters for one fleet run.
#[derive(Debug, Clone, Default)]
pub struct FleetStats {
    /// Requests submitted to some replica.
    pub routed: usize,
    /// Adapter requests landing on a replica that already held the
    /// adapter.
    pub affinity_hits: usize,
    /// Adapter requests that required a load-on-miss.
    pub affinity_misses: usize,
    /// Load commands issued (initial placement + misses + replication).
    pub loads: usize,
    /// Loads the engine refused (capacity race, duplicate).
    pub load_failures: usize,
    /// Evictions issued to make room.
    pub evictions: usize,
    /// Evictions the engine refused (in-flight safety net).
    pub evict_rejected: usize,
    /// Proactive hot-adapter replications.
    pub replications: usize,
    /// Shed: per-adapter queue budget exhausted.
    pub shed_queue_full: usize,
    /// Shed: no replica could host the adapter.
    pub shed_no_capacity: usize,
    /// Engine-level submit rejections after routing.
    pub submit_rejected: usize,
}

impl FleetStats {
    pub fn shed_total(&self) -> usize {
        self.shed_queue_full + self.shed_no_capacity
    }

    /// Fraction of routed adapter requests that hit a resident copy;
    /// `NaN` when no adapter-bound request was routed (a base-only run
    /// has no residency to measure).
    pub fn hit_rate(&self) -> f64 {
        let n = self.affinity_hits + self.affinity_misses;
        if n == 0 {
            return f64::NAN;
        }
        self.affinity_hits as f64 / n as f64
    }

    /// One-line summary for bench output.
    pub fn row(&self) -> String {
        let hit = if self.affinity_hits + self.affinity_misses == 0 {
            "-".to_string()
        } else {
            format!("{:.0}%", self.hit_rate() * 100.0)
        };
        format!(
            "routed={} hit={hit} loads={} evict={} repl={} \
             shed_q={} shed_cap={} rej={}",
            self.routed,
            self.loads,
            self.evictions,
            self.replications,
            self.shed_queue_full,
            self.shed_no_capacity,
            self.submit_rejected,
        )
    }
}

/// Result of one fleet replay.
#[derive(Debug)]
pub struct FleetOutcome {
    /// Fleet-level aggregate (rejected/shed filled from [`FleetStats`]).
    pub report: Report,
    /// Per-replica serving reports, by replica index.
    pub per_replica: Vec<Report>,
    pub completions: Vec<Completion>,
    pub stats: FleetStats,
}

/// The fleet coordinator. Build with [`Coordinator::launch`], then drive
/// a workload with [`Coordinator::replay`] (which consumes the fleet and
/// joins its threads).
pub struct Coordinator {
    cfg: CoordinatorConfig,
    replicas: Vec<ReplicaHandle>,
    events: Receiver<ReplicaEvent>,
    directory: AdapterDirectory,
    rates: RateTracker,
    /// Host-cached adapter checkpoints available for loading (shared
    /// refs: a load command ships an `Arc`, not a weight copy).
    host_adapters: HashMap<String, Arc<Adapter>>,
    /// Outstanding requests per replica (exact, event-driven).
    inflight: Vec<usize>,
    /// Outstanding requests per adapter across the fleet.
    inflight_adapter: HashMap<String, usize>,
    /// Outstanding requests per (replica, adapter).
    inflight_ra: Vec<HashMap<String, usize>>,
    rr_next: usize,
    stats: FleetStats,
}

impl Coordinator {
    /// Spawn `cfg.replicas` engine threads (`spawn(i)` supplies each
    /// factory; engines are built in-thread), wait until all are ready,
    /// and place `adapters` round-robin up to per-replica capacity.
    pub fn launch<F>(
        cfg: CoordinatorConfig,
        spawn: F,
        adapters: Vec<Adapter>,
    ) -> Result<Coordinator>
    where
        F: Fn(usize) -> Box<dyn FnOnce() -> Result<Engine> + Send>,
    {
        if cfg.replicas == 0 {
            bail!("fleet needs at least one replica");
        }
        if cfg.adapter_capacity == 0 {
            bail!("adapter_capacity must be at least 1");
        }
        if cfg.max_copies == 0 {
            bail!("max_copies must be at least 1");
        }
        let (ev_tx, ev_rx) = std::sync::mpsc::channel();
        let replicas: Vec<ReplicaHandle> = (0..cfg.replicas)
            .map(|i| spawn_replica(i, spawn(i), ev_tx.clone()))
            .collect();
        drop(ev_tx); // only replica threads hold senders now

        let mut ready = 0usize;
        while ready < cfg.replicas {
            match ev_rx.recv_timeout(Duration::from_secs(600)) {
                Ok(ReplicaEvent::Ready { replica, err: None }) => {
                    crate::log_debug!("coordinator", "replica {replica} ready");
                    ready += 1;
                }
                Ok(ReplicaEvent::Ready { replica, err: Some(e) }) => {
                    bail!("replica {replica} failed to start: {e}");
                }
                Ok(_) => {}
                Err(e) => bail!("fleet startup failed: {e}"),
            }
        }

        let n = cfg.replicas;
        let names: Vec<String> = adapters.iter().map(|a| a.name.clone()).collect();
        let mut coord = Coordinator {
            directory: AdapterDirectory::new(n, cfg.adapter_capacity),
            rates: RateTracker::new(cfg.rate_halflife),
            host_adapters: adapters
                .into_iter()
                .map(|a| (a.name.clone(), Arc::new(a)))
                .collect(),
            inflight: vec![0; n],
            inflight_adapter: HashMap::new(),
            inflight_ra: (0..n).map(|_| HashMap::new()).collect(),
            rr_next: 0,
            stats: FleetStats::default(),
            events: ev_rx,
            replicas,
            cfg,
        };

        // initial placement: adapter i starts on replica i % n (first
        // with a free slot); overflow adapters stay host-cached and are
        // loaded on demand
        for (i, name) in names.iter().enumerate() {
            let mut placed = false;
            for off in 0..n {
                let r = (i + off) % n;
                if coord.directory.has_free_slot(r) && !coord.directory.is_resident(r, name) {
                    coord.issue_load(r, name)?;
                    placed = true;
                    break;
                }
            }
            if !placed {
                crate::log_info!(
                    "coordinator",
                    "adapter {name:?} host-cached only (fleet at adapter capacity)"
                );
            }
        }
        Ok(coord)
    }

    pub fn config(&self) -> &CoordinatorConfig {
        &self.cfg
    }

    pub fn directory(&self) -> &AdapterDirectory {
        &self.directory
    }

    /// Record + send a load of a host-cached adapter to a replica.
    fn issue_load(&mut self, r: usize, name: &str) -> Result<()> {
        let Some(adapter) = self.host_adapters.get(name).cloned() else {
            bail!("adapter {name:?} is not host-cached");
        };
        self.directory.insert(r, name);
        self.stats.loads += 1;
        self.replicas[r].send(ReplicaCmd::Load(adapter))
    }

    /// LRU-resident adapter on `r` that is idle (no in-flight requests)
    /// and is not `keep`.
    fn evictable(&self, r: usize, keep: &str) -> Option<String> {
        let ra = &self.inflight_ra[r];
        self.directory
            .lru_evictable(r, |n| n != keep && ra.get(n).map_or(true, |&c| c == 0))
    }

    /// Per-replica snapshots for one routing decision.
    fn views(&self, name: Option<&str>) -> Vec<ReplicaView> {
        self.replicas
            .iter()
            .enumerate()
            .map(|(i, h)| {
                let resident = name.map_or(true, |n| self.directory.is_resident(i, n));
                let can_host = name.map_or(true, |n| {
                    self.host_adapters.contains_key(n)
                        && self.directory.copies(n) < self.cfg.max_copies
                        && (self.directory.has_free_slot(i) || self.evictable(i, n).is_some())
                });
                ReplicaView {
                    index: i,
                    inflight: self.inflight[i],
                    kv_free: h.gauges.kv_free.load(Ordering::Relaxed),
                    resident,
                    can_host,
                }
            })
            .collect()
    }

    /// Make `name` resident on `r` (no-op if it already is): evict the
    /// LRU idle adapter when the replica is at capacity, then load.
    fn ensure_resident(&mut self, r: usize, name: &str) -> Result<()> {
        if self.directory.is_resident(r, name) {
            return Ok(());
        }
        if !self.host_adapters.contains_key(name)
            || self.directory.copies(name) >= self.cfg.max_copies
        {
            return Ok(()); // engine will reject the submit
        }
        if !self.directory.has_free_slot(r) {
            let Some(victim) = self.evictable(r, name) else {
                // capacity raced away since the routing decision; the
                // engine rejects the submit and the event accounting
                // picks it up
                return Ok(());
            };
            self.directory.remove(r, &victim);
            self.stats.evictions += 1;
            self.replicas[r].send(ReplicaCmd::Evict(victim))?;
        }
        self.issue_load(r, name)
    }

    /// Replicate a hot adapter onto the least-loaded replica with a free
    /// slot (replication never evicts).
    fn try_replicate(&mut self, name: &str) -> Result<()> {
        let mut best: Option<usize> = None;
        for i in 0..self.replicas.len() {
            if self.directory.is_resident(i, name) || !self.directory.has_free_slot(i) {
                continue;
            }
            if best.map_or(true, |b| self.inflight[i] < self.inflight[b]) {
                best = Some(i);
            }
        }
        if let Some(i) = best {
            crate::log_info!(
                "coordinator",
                "replicating hot adapter {name:?} to replica {i}"
            );
            self.issue_load(i, name)?;
            self.stats.replications += 1;
        }
        Ok(())
    }

    fn inflight_for(&self, name: &str) -> usize {
        self.inflight_adapter.get(name).copied().unwrap_or(0)
    }

    /// Admit, place and submit one request (trace time `at`).
    fn dispatch(&mut self, spec: RequestSpec, at: f64) -> Result<()> {
        let adapter = spec.adapter.clone();
        let name = adapter.as_deref();
        if let Some(n) = name {
            if self.cfg.queue_cap > 0 && self.inflight_for(n) >= self.cfg.queue_cap {
                self.stats.shed_queue_full += 1;
                return Ok(());
            }
        }
        let views = self.views(name);
        let Some(decision) = choose(self.cfg.policy, &views, &mut self.rr_next) else {
            self.stats.shed_no_capacity += 1;
            return Ok(());
        };
        let r = decision.replica;
        if let Some(n) = name {
            if decision.resident {
                self.stats.affinity_hits += 1;
                self.directory.touch(r, n);
            } else {
                self.stats.affinity_misses += 1;
                self.ensure_resident(r, n)?;
            }
            *self.inflight_adapter.entry(n.to_string()).or_insert(0) += 1;
            *self.inflight_ra[r].entry(n.to_string()).or_insert(0) += 1;
            let rate = self.rates.observe(n, at);
            if self.cfg.replicate_rps.is_finite()
                && rate > self.cfg.replicate_rps
                && self.directory.copies(n) < self.cfg.max_copies
            {
                self.try_replicate(n)?;
            }
        }
        self.inflight[r] += 1;
        self.stats.routed += 1;
        self.replicas[r].send(ReplicaCmd::Submit(spec))
    }

    fn note_done(&mut self, replica: usize, adapter: Option<&str>) {
        self.inflight[replica] = self.inflight[replica].saturating_sub(1);
        if let Some(n) = adapter {
            if let Some(c) = self.inflight_adapter.get_mut(n) {
                *c = c.saturating_sub(1);
            }
            if let Some(c) = self.inflight_ra[replica].get_mut(n) {
                *c = c.saturating_sub(1);
            }
        }
    }

    fn apply(&mut self, ev: ReplicaEvent, completions: &mut Vec<Completion>) -> Result<()> {
        match ev {
            ReplicaEvent::Completed { replica, completion } => {
                self.note_done(replica, completion.adapter.as_deref());
                completions.push(completion);
            }
            ReplicaEvent::SubmitRejected { replica, adapter } => {
                self.note_done(replica, adapter.as_deref());
                self.stats.submit_rejected += 1;
            }
            ReplicaEvent::LoadDone { replica, adapter, err } => {
                if err.is_some() {
                    self.directory.remove(replica, &adapter);
                    self.stats.load_failures += 1;
                }
            }
            ReplicaEvent::EvictDone { replica, adapter, err } => {
                if err.is_some() {
                    // the engine kept it (safety net); restore our view
                    self.directory.insert(replica, &adapter);
                    self.stats.evict_rejected += 1;
                }
            }
            ReplicaEvent::Fatal { replica, err } => {
                bail!("replica {replica} failed: {err}");
            }
            ReplicaEvent::Ready { .. } | ReplicaEvent::Finished { .. } => {}
        }
        Ok(())
    }

    fn drain_events(&mut self, completions: &mut Vec<Completion>) -> Result<()> {
        loop {
            match self.events.try_recv() {
                Ok(ev) => self.apply(ev, completions)?,
                Err(_) => return Ok(()),
            }
        }
    }

    /// Replay a trace against the fleet in real time, then drain every
    /// replica and aggregate. Consumes the coordinator (threads are
    /// joined before returning).
    pub fn replay(mut self, trace: &Trace) -> Result<FleetOutcome> {
        let pacer = Pacer::start();
        let mut completions: Vec<Completion> = Vec::new();
        for e in &trace.events {
            pacer.wait_until(e.at);
            self.drain_events(&mut completions)?;
            let spec = RequestSpec {
                adapter: e.adapter.clone(),
                prompt: e.prompt.clone(),
                max_new_tokens: e.max_new_tokens,
                sampling: Sampling::Greedy,
            };
            self.dispatch(spec, e.at)?;
        }

        // all arrivals injected: ask every replica to drain and report
        // (wall anchored to replay start, so per-replica throughput is
        // comparable to the fleet aggregate)
        for h in &self.replicas {
            h.send(ReplicaCmd::Finish { since: pacer.started_at() })?;
        }
        let n = self.replicas.len();
        let mut reports: Vec<Option<Report>> = (0..n).map(|_| None).collect();
        let mut finished = 0usize;
        while finished < n {
            match self.events.recv_timeout(Duration::from_secs(600)) {
                Ok(ReplicaEvent::Finished { replica, report }) => {
                    if reports[replica].replace(report).is_none() {
                        finished += 1;
                    }
                }
                Ok(ev) => self.apply(ev, &mut completions)?,
                Err(e) => bail!("fleet drain failed: {e}"),
            }
        }
        let wall = pacer.elapsed().as_secs_f64().max(1e-9);
        for h in self.replicas.drain(..) {
            h.shutdown();
        }

        let per_replica: Vec<Report> =
            reports.into_iter().map(|r| r.expect("replica report")).collect();
        let mut ttft = Samples::new();
        let mut tpot = Samples::new();
        let mut e2e = Samples::new();
        for c in &completions {
            ttft.push(c.record.ttft.as_secs_f64());
            if let Some(t) = c.record.tpot {
                tpot.push(t.as_secs_f64());
            }
            e2e.push(c.record.e2e.as_secs_f64());
        }
        let prefill_tokens: usize = per_replica.iter().map(|r| r.prefill_tokens).sum();
        let decode_tokens: usize = per_replica.iter().map(|r| r.decode_tokens).sum();
        let report = Report {
            requests: completions.len(),
            prefill_tokens,
            decode_tokens,
            prefill_throughput: prefill_tokens as f64 / wall,
            decode_throughput: decode_tokens as f64 / wall,
            ttft: ttft.summary(),
            tpot: tpot.summary(),
            e2e: e2e.summary(),
            wall,
            rejected: self.stats.submit_rejected,
            shed: self.stats.shed_total(),
        };
        Ok(FleetOutcome { report, per_replica, completions, stats: self.stats })
    }
}
