//! Fleet coordinator — the layer above single engines (the paper's L3
//! coordination role): adapter-aware request routing across N engine
//! replicas, fleet-level adapter lifecycle, and admission control.
//!
//! One ExpertWeave engine already serves ~20 adapters with single-digit
//! overhead; a production fleet runs many such replicas, and the win
//! over one-merged-engine-per-adapter deployments (ESFT-style,
//! [`crate::server::replay_multi`]) is decided a layer up: *which
//! replica serves which adapter*. This module owns that decision.
//!
//! # Architecture
//!
//! ```text
//!   Trace ──▶ Coordinator ──(FIFO cmd channel per replica)──▶ replica-0 [Engine]
//!              │  ▲                                      └──▶ replica-1 [Engine]
//!              │  └──(shared event channel: completions,      ...
//!              │      load/evict acks, reports)
//!              ├─ AdapterDirectory  (residency + per-placement LRU)
//!              ├─ RateTracker      (per-adapter EWMA arrival rates)
//!              └─ RoutingPolicy    (pure scoring over ReplicaViews)
//! ```
//!
//! Each replica is an [`Engine`] on its own thread (PJRT handles are not
//! `Send`; engines are built inside their threads). Per-replica command
//! channels are FIFO, which makes `Load(A); Submit(req-for-A)` safe
//! without waiting for acknowledgements.
//!
//! # Routing policies ([`RoutingPolicy`])
//!
//! * **RoundRobin** — stateless cycling. Fair in request count, blind to
//!   both load and adapter residency: under a skewed adapter mix every
//!   replica eventually needs every adapter, so small per-replica
//!   adapter capacity turns into continuous load/evict churn (each miss
//!   costs a weight re-sync) and shed requests once nothing idle is
//!   left to evict.
//! * **JoinShortestQueue** — route to the replica with the fewest
//!   outstanding requests (ties: most free KV slots). Evens out queue
//!   depth and so protects TTFT tails, but it is adapter-blind and
//!   inherits RoundRobin's churn under skew.
//! * **AdapterAffinity** — the coordinator's reason to exist: prefer
//!   replicas where the adapter is already resident, scored by queue
//!   depth then free KV slots; miss only when no copy is resident, then
//!   place on the least-loaded replica that can host one (free slot or
//!   idle LRU victim). Keeps hot adapters pinned, confines churn to the
//!   cold tail, and — combined with rate-triggered replication — turns
//!   a hot adapter into multiple copies instead of one hot replica.
//! * **DeadlineAware** — route by *expected queue wait* (each replica's
//!   published decode-step EWMA × its in-flight count), resident copies
//!   first among the replicas that fit the request's deadline. When no
//!   replica can meet the deadline, refuse the submit with
//!   [`SubmitError::DeadlineUnmeetable`] instead of queueing a request
//!   that will expire — the fleet-level counterpart of the engine's own
//!   deadline-aware admission.
//!
//! # Lifecycle
//!
//! Load-on-miss with per-replica capacity
//! ([`CoordinatorConfig::adapter_capacity`]) and LRU eviction; an
//! adapter with in-flight
//! requests on a replica is never chosen as victim (and
//! [`Engine::evict_adapter`] enforces the same invariant). When an
//! adapter's smoothed arrival rate crosses
//! [`CoordinatorConfig::replicate_rps`], it is proactively replicated to
//! the least-loaded replica with a free slot, up to
//! [`CoordinatorConfig::max_copies`] copies.
//!
//! # Admission control
//!
//! Per-adapter outstanding-request budgets
//! ([`CoordinatorConfig::queue_cap`]) shed excess arrivals at the door
//! instead of letting one hot adapter monopolize every queue; requests
//! whose adapter no replica can host are shed likewise. Shed and
//! rejected counts surface in [`Report::shed`] / [`Report::rejected`]
//! and in [`FleetStats`].
//!
//! # Membership & failover
//!
//! The replica set is *elastic*: [`Coordinator::add_replica`] spawns a
//! fresh engine thread mid-run (indices are append-only and stable) and
//! [`Coordinator::retire_replica`] drains one replica and folds its
//! report away without stopping the fleet. Replica death is contained,
//! not fatal: a [`ReplicaEvent::Fatal`] (engine step error, panic, or
//! the `Die` chaos command) retires the replica in place and every
//! request routed to it is *re-submitted* to a survivor with its
//! remaining deadline budget (prefill re-runs; the stream may restart).
//! Only when no survivor can take a request — or its deadline cannot
//! survive the retry — does the client see a typed
//! [`AbortReason::ReplicaLost`] terminal event. Either way every
//! accepted stream is guaranteed a terminal event; nothing hangs.
//!
//! A wedged-but-alive replica is caught by heartbeat staleness: replica
//! threads restamp [`ReplicaGauges::last_beat_us`] after every command
//! and step (and on an idle timer), and a replica whose stamp is older
//! than [`CoordinatorConfig::suspect_after`] is *suspect* — excluded
//! from routing until it beats again, but not retired (it may just be
//! stuck in one long step).
//!
//! # Serving API
//!
//! The coordinator implements [`ServingBackend`] — the same typed
//! boundary as a single [`Engine`]: `submit` admits/routes and returns
//! a [`RequestHandle`] whose [`TokenEvent`] stream is fed by the routed
//! replica (tokens incrementally, then `Done`/`Aborted`); `cancel`
//! relays to the owning replica; `drain` completes in-flight work and
//! then refuses submits with [`SubmitError::ShuttingDown`]. Admission
//! failures are typed: `UnknownAdapter` (nobody host-caches it),
//! `QueueFull` (per-adapter budget), `Shed` (no replica with capacity).
//! [`Coordinator::replay`] is a thin client of this API.

mod lifecycle;
mod replica;
mod router;

pub use lifecycle::{AdapterDirectory, RateTracker};
pub use replica::{ReplicaGauges, ReplicaHandle};
pub use router::{choose, ReplicaView, RouteDecision, RouteError, RoutingPolicy};

use crate::adapters::format::Adapter;
use crate::engine::{Completion, Engine, StepEwma};
use crate::metrics::Report;
use crate::obs::flightrec::FlightRecorder;
use crate::obs::trace::{Candidate, DoorEvent, RouteSpan, TraceLog};
use crate::server::Pacer;
use crate::serving::{
    AbortReason, RequestHandle, RequestId, ServeRequest, ServingBackend, SubmitError, TokenEvent,
};
use crate::workload::trace::Trace;
use anyhow::{bail, Result};
use replica::{spawn_replica, ReplicaCmd, ReplicaEvent};
use std::collections::HashMap;
use std::sync::atomic::Ordering;
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Fleet-level tuning knobs.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Engine replicas in the fleet.
    pub replicas: usize,
    pub policy: RoutingPolicy,
    /// Resident-adapter budget per replica (≤ the model's `max_adapters`;
    /// smaller values model device-memory pressure).
    pub adapter_capacity: usize,
    /// Max outstanding (routed, uncompleted) requests per adapter across
    /// the fleet; arrivals beyond it are shed. 0 = unbounded.
    pub queue_cap: usize,
    /// Smoothed arrival rate (req/s) above which a hot adapter is
    /// replicated to another replica. `f64::INFINITY` disables.
    pub replicate_rps: f64,
    /// Half-life (seconds) of the arrival-rate EWMA.
    pub rate_halflife: f64,
    /// Max replicas any single adapter may be resident on. Enforced on
    /// both proactive replication and load-on-miss: an adapter-blind
    /// policy (RoundRobin/JSQ) that targets a replica without the
    /// adapter sheds the request once the copy budget is spent, rather
    /// than silently exceeding it.
    pub max_copies: usize,
    /// Heartbeat staleness bound: a replica whose
    /// [`ReplicaGauges::last_beat_us`] stamp is older than this is
    /// *suspect* — excluded from routing until it republishes (wedged
    /// threads stop taking traffic without being retired).
    /// `Duration::ZERO` disables suspect detection.
    pub suspect_after: Duration,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            replicas: 2,
            policy: RoutingPolicy::AdapterAffinity,
            adapter_capacity: 4,
            queue_cap: 64,
            replicate_rps: f64::INFINITY,
            rate_halflife: 2.0,
            max_copies: 2,
            suspect_after: Duration::from_secs(2),
        }
    }
}

/// Routing / lifecycle / admission counters for one fleet run.
#[derive(Debug, Clone, Default)]
pub struct FleetStats {
    /// Requests submitted to some replica.
    pub routed: usize,
    /// Adapter requests landing on a replica that already held the
    /// adapter.
    pub affinity_hits: usize,
    /// Adapter requests that required a load-on-miss.
    pub affinity_misses: usize,
    /// Load commands issued (initial placement + misses + replication).
    pub loads: usize,
    /// Loads the engine refused (capacity race, duplicate).
    pub load_failures: usize,
    /// Evictions issued to make room.
    pub evictions: usize,
    /// Evictions the engine refused (in-flight safety net).
    pub evict_rejected: usize,
    /// Proactive hot-adapter replications.
    pub replications: usize,
    /// Shed: per-adapter queue budget exhausted.
    pub shed_queue_full: usize,
    /// Shed: no replica could host the adapter.
    pub shed_no_capacity: usize,
    /// Deadline-aware routing found no replica whose expected queue wait
    /// fits the request's deadline (also counted in `submit_rejected`;
    /// the client sees [`SubmitError::DeadlineUnmeetable`]).
    pub deadline_unmeetable: usize,
    /// Typed rejections: unknown adapters refused at the door
    /// ([`SubmitError::UnknownAdapter`]) plus engine-level submit
    /// rejections after routing (residency races).
    pub submit_rejected: usize,
    /// Requests re-submitted to a surviving replica after their routed
    /// replica died (prefill re-runs; the client stream may restart).
    pub requests_rerouted: usize,
    /// Requests lost with a dead replica that could not be re-routed
    /// (no surviving capacity, remaining deadline too small, or the
    /// fleet was already finishing); the client saw a typed
    /// [`AbortReason::ReplicaLost`] terminal event.
    pub reroute_aborted: usize,
    /// Replicas retired, by failure or by [`Coordinator::retire_replica`].
    pub replica_retired: usize,
}

impl FleetStats {
    pub fn shed_total(&self) -> usize {
        self.shed_queue_full + self.shed_no_capacity
    }

    /// Fraction of routed adapter requests that hit a resident copy;
    /// `NaN` when no adapter-bound request was routed (a base-only run
    /// has no residency to measure).
    pub fn hit_rate(&self) -> f64 {
        let n = self.affinity_hits + self.affinity_misses;
        if n == 0 {
            return f64::NAN;
        }
        self.affinity_hits as f64 / n as f64
    }

    /// One-line summary for bench output.
    pub fn row(&self) -> String {
        let hit = if self.affinity_hits + self.affinity_misses == 0 {
            "-".to_string()
        } else {
            format!("{:.0}%", self.hit_rate() * 100.0)
        };
        format!(
            "routed={} hit={hit} loads={} evict={} repl={} \
             shed_q={} shed_cap={} dl={} rej={} rerouted={} \
             reroute_abort={} retired={}",
            self.routed,
            self.loads,
            self.evictions,
            self.replications,
            self.shed_queue_full,
            self.shed_no_capacity,
            self.deadline_unmeetable,
            self.submit_rejected,
            self.requests_rerouted,
            self.reroute_aborted,
            self.replica_retired,
        )
    }
}

/// Result of one fleet replay.
#[derive(Debug)]
pub struct FleetOutcome {
    /// Fleet-level aggregate (rejected/shed filled from [`FleetStats`]).
    pub report: Report,
    /// Per-replica serving reports, by replica index.
    pub per_replica: Vec<Report>,
    pub completions: Vec<Completion>,
    pub stats: FleetStats,
    /// The merged fleet trace (coordinator door/routing spans + every
    /// replica's phase spans), when [`Coordinator::enable_trace`] ran
    /// before the replay.
    pub trace: Option<TraceLog>,
}

/// Lifecycle state of one replica slot. Slots are append-only — a dead
/// replica keeps its index (empty directory row, zeroed in-flight) so
/// positional bookkeeping across the fleet never shifts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum ReplicaState {
    /// Serving; eligible for routing (unless heartbeat-suspect).
    Live,
    /// Draining toward [`Coordinator::retire_replica`]; no new routes.
    Retiring,
    /// Gone — failed or retired. Never routed to again.
    Dead,
}

/// Everything the coordinator must remember about a routed request to
/// cancel it, account its terminal event, or *re-submit it elsewhere*
/// if its replica dies mid-flight.
struct RouteEntry {
    /// Replica currently serving the request.
    replica: usize,
    /// Adapter name (admission bookkeeping key); `None` = base model.
    adapter: Option<String>,
    /// The full request, kept so failover can re-submit it verbatim
    /// (modulo the already-spent deadline budget).
    req: ServeRequest,
    /// When the current submission was sent — the base for computing
    /// the remaining deadline on failover.
    submitted_at: Instant,
}

/// The fleet coordinator. Build with [`Coordinator::launch`], then drive
/// a workload with [`Coordinator::replay`] (which consumes the fleet and
/// joins its threads).
pub struct Coordinator {
    cfg: CoordinatorConfig,
    replicas: Vec<ReplicaHandle>,
    /// Lifecycle state per replica slot, parallel to `replicas`.
    states: Vec<ReplicaState>,
    /// Each replica engine's live metric registry, by replica index
    /// (shipped in [`ReplicaEvent::Ready`]). The coordinator only reads
    /// them — snapshots for fleet `stats` frames, direct rendering by
    /// the Prometheus exposition — recording stays replica-side.
    obs: Vec<Arc<crate::obs::ObsRegistry>>,
    events: Receiver<ReplicaEvent>,
    /// Retained clone of the replica event sender, so replicas spawned
    /// at runtime ([`Coordinator::add_replica`]) report into the same
    /// channel as the launch set.
    events_tx: Sender<ReplicaEvent>,
    /// Fleet-level failover/membership gauges and counters, shared with
    /// the Prometheus exposition ([`crate::obs::expo::render_fleet`]).
    fleet_obs: Arc<crate::obs::FleetObs>,
    directory: AdapterDirectory,
    rates: RateTracker,
    /// Host-cached adapter checkpoints available for loading (shared
    /// refs: a load command ships an `Arc`, not a weight copy).
    host_adapters: HashMap<String, Arc<Adapter>>,
    /// Outstanding requests per replica (exact, event-driven).
    inflight: Vec<usize>,
    /// Outstanding requests per adapter across the fleet.
    inflight_adapter: HashMap<String, usize>,
    /// Outstanding requests per (replica, adapter).
    inflight_ra: Vec<HashMap<String, usize>>,
    rr_next: usize,
    stats: FleetStats,
    /// Fleet request-id allocator (ids are fleet-scoped, not per-replica).
    next_rid: RequestId,
    /// rid → client token-stream sender (the fleet half of each
    /// [`RequestHandle`]).
    clients: HashMap<RequestId, Sender<TokenEvent>>,
    /// rid → full route record: cancel routing, terminal-event
    /// accounting, and the re-submit payload for failover.
    routes: HashMap<RequestId, RouteEntry>,
    /// Serving-time origin for the arrival-rate EWMA.
    clock: Instant,
    /// Trace-time origin: captured before any replica thread spawns, so
    /// it predates every engine's own origin and rebasing replica spans
    /// onto it ([`TraceLog::absorb`]) never truncates.
    origin: Instant,
    /// Fleet-level trace log (door + routing spans), present once
    /// [`Coordinator::enable_trace`] ran. Replica phase spans merge into
    /// it at [`Coordinator::finish_traced`].
    trace: Option<TraceLog>,
    /// Each replica engine's always-on flight recorder, by replica index
    /// (shipped in [`ReplicaEvent::Ready`], like `obs`). Snapshot-only on
    /// this side: `flightrec` frames and fatal-crash tail dumps.
    flightrecs: Vec<Arc<FlightRecorder>>,
    /// Reports stashed from replicas retired mid-run (failure or
    /// [`Coordinator::retire_replica`]), folded into the final merge.
    retired_reports: HashMap<usize, Report>,
    /// Trace logs stashed from retired replicas, merged like live ones.
    retired_traces: HashMap<usize, TraceLog>,
    /// Draining: every new submit fails with `ShuttingDown`.
    shutting_down: bool,
    /// Final drain in progress (`finish` sent to every live replica):
    /// failover must abort lost requests instead of re-submitting them
    /// into engines that will never read another command.
    finishing: bool,
}

impl Coordinator {
    /// Spawn `cfg.replicas` engine threads (`spawn(i)` supplies each
    /// factory; engines are built in-thread), wait until all are ready,
    /// and place `adapters` round-robin up to per-replica capacity.
    pub fn launch<F>(
        cfg: CoordinatorConfig,
        spawn: F,
        adapters: Vec<Adapter>,
    ) -> Result<Coordinator>
    where
        F: Fn(usize) -> Box<dyn FnOnce() -> Result<Engine> + Send>,
    {
        if cfg.replicas == 0 {
            bail!("fleet needs at least one replica");
        }
        if cfg.adapter_capacity == 0 {
            bail!("adapter_capacity must be at least 1");
        }
        if cfg.max_copies == 0 {
            bail!("max_copies must be at least 1");
        }
        // the fleet trace origin must predate every engine's (engines
        // construct inside the threads spawned below)
        let origin = Instant::now();
        let (ev_tx, ev_rx) = std::sync::mpsc::channel();
        let replicas: Vec<ReplicaHandle> = (0..cfg.replicas)
            .map(|i| spawn_replica(i, spawn(i), ev_tx.clone(), origin))
            .collect();
        // ev_tx is retained: runtime joins (add_replica) clone it for
        // replicas spawned after launch

        let mut ready = 0usize;
        let mut obs_regs: Vec<Option<Arc<crate::obs::ObsRegistry>>> =
            (0..cfg.replicas).map(|_| None).collect();
        let mut flightrecs: Vec<Option<Arc<FlightRecorder>>> =
            (0..cfg.replicas).map(|_| None).collect();
        while ready < cfg.replicas {
            match ev_rx.recv_timeout(Duration::from_secs(600)) {
                Ok(ReplicaEvent::Ready { replica, err: None, obs, flightrec }) => {
                    crate::log_debug!("coordinator", "replica {replica} ready");
                    obs_regs[replica] = obs;
                    flightrecs[replica] = flightrec;
                    ready += 1;
                }
                Ok(ReplicaEvent::Ready { replica, err: Some(e), .. }) => {
                    bail!("replica {replica} failed to start: {e}");
                }
                Ok(_) => {}
                Err(e) => bail!("fleet startup failed: {e}"),
            }
        }

        let n = cfg.replicas;
        let names: Vec<String> = adapters.iter().map(|a| a.name.clone()).collect();
        let obs: Vec<Arc<crate::obs::ObsRegistry>> = obs_regs.into_iter().flatten().collect();
        let fleet_obs = Arc::new(crate::obs::FleetObs::new());
        for r in &obs {
            fleet_obs.push_registry(r.clone());
        }
        fleet_obs.replicas.store(n as u64, Ordering::Relaxed);
        let mut coord = Coordinator {
            directory: AdapterDirectory::new(n, cfg.adapter_capacity),
            rates: RateTracker::new(cfg.rate_halflife),
            host_adapters: adapters
                .into_iter()
                .map(|a| (a.name.clone(), Arc::new(a)))
                .collect(),
            inflight: vec![0; n],
            inflight_adapter: HashMap::new(),
            inflight_ra: (0..n).map(|_| HashMap::new()).collect(),
            rr_next: 0,
            stats: FleetStats::default(),
            next_rid: 1,
            clients: HashMap::new(),
            routes: HashMap::new(),
            clock: Instant::now(),
            origin,
            trace: None,
            flightrecs: flightrecs.into_iter().flatten().collect(),
            retired_reports: HashMap::new(),
            retired_traces: HashMap::new(),
            shutting_down: false,
            finishing: false,
            obs,
            events: ev_rx,
            events_tx: ev_tx,
            fleet_obs,
            states: vec![ReplicaState::Live; n],
            replicas,
            cfg,
        };

        // initial placement: adapter i starts on replica i % n (first
        // with a free slot); overflow adapters stay host-cached and are
        // loaded on demand
        for (i, name) in names.iter().enumerate() {
            let mut placed = false;
            for off in 0..n {
                let r = (i + off) % n;
                if coord.directory.has_free_slot(r) && !coord.directory.is_resident(r, name) {
                    coord.issue_load(r, name)?;
                    placed = true;
                    break;
                }
            }
            if !placed {
                crate::log_info!(
                    "coordinator",
                    "adapter {name:?} host-cached only (fleet at adapter capacity)"
                );
            }
        }
        Ok(coord)
    }

    pub fn config(&self) -> &CoordinatorConfig {
        &self.cfg
    }

    pub fn directory(&self) -> &AdapterDirectory {
        &self.directory
    }

    /// The live metric registries of every replica engine, by replica
    /// index. The fleet Prometheus exposition
    /// ([`crate::obs::expo::render`]) consumes these directly, labelling
    /// each family with `replica="i"`.
    pub fn obs_registries(&self) -> Vec<Arc<crate::obs::ObsRegistry>> {
        self.obs.clone()
    }

    /// One fleet-wide [`StatsSnapshot`]: every replica registry merged
    /// (counters/gauges summed, histograms merged bucketwise, adapter
    /// families combined by name), plus the coordinator's own
    /// door-keeping counters ([`FleetStats`]) in the `fleet` section.
    ///
    /// [`StatsSnapshot`]: crate::obs::StatsSnapshot
    pub fn stats_snapshot(&self) -> crate::obs::StatsSnapshot {
        let mut snap = crate::obs::StatsSnapshot::default();
        for r in &self.obs {
            snap.merge(&r.snapshot());
        }
        let s = &self.stats;
        snap.fleet = vec![
            ("routed".to_string(), s.routed as u64),
            ("affinity_hits".to_string(), s.affinity_hits as u64),
            ("affinity_misses".to_string(), s.affinity_misses as u64),
            ("loads".to_string(), s.loads as u64),
            ("load_failures".to_string(), s.load_failures as u64),
            ("evictions".to_string(), s.evictions as u64),
            ("evict_rejected".to_string(), s.evict_rejected as u64),
            ("replications".to_string(), s.replications as u64),
            ("shed_queue_full".to_string(), s.shed_queue_full as u64),
            ("shed_no_capacity".to_string(), s.shed_no_capacity as u64),
            ("deadline_unmeetable".to_string(), s.deadline_unmeetable as u64),
            ("submit_rejected".to_string(), s.submit_rejected as u64),
            ("requests_rerouted".to_string(), s.requests_rerouted as u64),
            ("reroute_aborted".to_string(), s.reroute_aborted as u64),
            ("replica_retired".to_string(), s.replica_retired as u64),
            ("fleet_replicas".to_string(), self.live_count() as u64),
            ("replica_suspect".to_string(), self.refresh_suspect()),
        ];
        snap
    }

    /// Fleet-level membership/failover gauges and counters, for the
    /// Prometheus exposition ([`crate::obs::expo::render_fleet`]). The
    /// `Arc` outlives a consuming `replay`/`finish`, like
    /// [`Coordinator::flight_recorders`].
    pub fn fleet_obs(&self) -> Arc<crate::obs::FleetObs> {
        self.fleet_obs.clone()
    }

    /// Replicas currently serving (not retiring, not dead).
    pub fn live_count(&self) -> usize {
        self.states.iter().filter(|s| **s == ReplicaState::Live).count()
    }

    /// Heartbeat staleness check against `now_us` (microseconds since
    /// `self.origin`). A zero stamp means the engine is still building —
    /// that is launch latency, not a wedged thread, so it counts fresh.
    fn is_suspect(&self, replica: usize, now_us: u64) -> bool {
        let sus = self.cfg.suspect_after.as_micros() as u64;
        if sus == 0 {
            return false;
        }
        let beat = self.replicas[replica].gauges.last_beat_us.load(Ordering::Relaxed);
        beat > 0 && now_us.saturating_sub(beat) > sus
    }

    /// Count suspect live replicas and refresh the shared gauges
    /// (callable from `&self`: everything it touches is atomic).
    fn refresh_suspect(&self) -> u64 {
        let now_us = self.origin.elapsed().as_micros() as u64;
        let n = (0..self.replicas.len())
            .filter(|&i| self.states[i] == ReplicaState::Live && self.is_suspect(i, now_us))
            .count() as u64;
        self.fleet_obs.suspect.store(n, Ordering::Relaxed);
        n
    }

    /// Shared handles to every replica engine's always-on flight
    /// recorder, by replica index. The rings outlive the coordinator
    /// (the engines record, anyone holding the `Arc` snapshots), so a
    /// caller can capture these before a consuming `replay`/`finish`
    /// and still dump the black box afterwards.
    pub fn flight_recorders(&self) -> Vec<Arc<FlightRecorder>> {
        self.flightrecs.clone()
    }

    /// Grow the fleet at runtime: spawn one more engine thread, wait
    /// until it reports ready, and re-balance by loading any host-cached
    /// adapter that currently has *zero* resident copies onto the
    /// newcomer (up to its capacity). Returns the new replica's index.
    /// Indices are append-only, so every existing route, label, and
    /// registry stays valid; events from replicas already running are
    /// folded normally while waiting.
    pub fn add_replica(
        &mut self,
        build: Box<dyn FnOnce() -> Result<Engine> + Send>,
    ) -> Result<usize> {
        let index = self.replicas.len();
        let handle = spawn_replica(index, build, self.events_tx.clone(), self.origin);
        self.replicas.push(handle);
        self.states.push(ReplicaState::Live);
        self.inflight.push(0);
        self.inflight_ra.push(HashMap::new());
        self.directory.add_replica();
        // placeholders keep the obs/flightrec vectors index-aligned even
        // if the engine build fails; replaced on Ready
        self.obs.push(Arc::new(crate::obs::ObsRegistry::new(0)));
        self.flightrecs.push(Arc::new(FlightRecorder::new()));
        let joined = loop {
            match self.events.recv_timeout(Duration::from_secs(600)) {
                Ok(ReplicaEvent::Ready { replica, err, obs, flightrec }) if replica == index => {
                    match err {
                        None => {
                            if let Some(o) = obs {
                                self.obs[index] = o;
                            }
                            if let Some(fr) = flightrec {
                                self.flightrecs[index] = fr;
                            }
                            break Ok(());
                        }
                        Some(e) => break Err(anyhow::anyhow!("{e}")),
                    }
                }
                Ok(ev) => self.apply(ev),
                Err(e) => break Err(anyhow::anyhow!("{e}")),
            }
        };
        if let Err(e) = joined {
            self.states[index] = ReplicaState::Dead;
            self.replicas[index].shutdown();
            bail!("replica {index} failed to join: {e}");
        }
        self.fleet_obs.push_registry(self.obs[index].clone());
        self.fleet_obs
            .replicas
            .store(self.live_count() as u64, Ordering::Relaxed);
        if self.trace.is_some() {
            self.replicas[index].send(ReplicaCmd::EnableTrace)?;
        }
        // re-balance: orphaned adapters (all copies died with retired
        // replicas) come back to life on the newcomer
        let orphans: Vec<String> = self
            .host_adapters
            .keys()
            .filter(|n| self.directory.copies(n) == 0)
            .cloned()
            .collect();
        for name in orphans {
            if !self.directory.has_free_slot(index) {
                break;
            }
            self.issue_load(index, &name)?;
        }
        crate::log_info!("coordinator", "replica {index} joined the fleet");
        Ok(index)
    }

    /// Shrink the fleet at runtime: stop routing to `replica`, wait for
    /// its in-flight work to complete (folding fleet events normally),
    /// then drain it and stash its report for the final merge. The slot
    /// stays (Dead) so indices never shift. If the replica fails while
    /// draining, failover already handled its requests and the retire
    /// is complete.
    pub fn retire_replica(&mut self, replica: usize) -> Result<()> {
        if replica >= self.replicas.len() || self.states[replica] != ReplicaState::Live {
            bail!("replica {replica} is not live");
        }
        self.states[replica] = ReplicaState::Retiring;
        crate::log_info!("coordinator", "retiring replica {replica} (draining)");
        let patience = Instant::now();
        while self.inflight[replica] > 0 {
            if self.states[replica] == ReplicaState::Dead {
                return Ok(()); // died mid-drain; failover covered it
            }
            if patience.elapsed() > Duration::from_secs(600) {
                bail!("replica {replica} did not drain in time");
            }
            match self.events.recv_timeout(Duration::from_millis(50)) {
                Ok(ev) => self.apply(ev),
                Err(RecvTimeoutError::Timeout) => {}
                Err(e) => bail!("fleet event channel failed: {e}"),
            }
        }
        if self.states[replica] == ReplicaState::Dead {
            return Ok(());
        }
        self.replicas[replica].send(ReplicaCmd::Finish { since: self.clock })?;
        loop {
            if self.states[replica] == ReplicaState::Dead {
                // failed while draining; failover settled its streams
                return Ok(());
            }
            if self.retired_reports.contains_key(&replica) {
                break; // apply() stashed the Finished for us
            }
            match self.events.recv_timeout(Duration::from_secs(600)) {
                Ok(ReplicaEvent::Finished { replica: r, report, trace }) if r == replica => {
                    self.retired_reports.insert(replica, report);
                    if let Some(t) = trace {
                        self.retired_traces.insert(replica, t);
                    }
                    break;
                }
                Ok(ev) => self.apply(ev),
                Err(e) => bail!("replica {replica} did not finish: {e}"),
            }
        }
        self.states[replica] = ReplicaState::Dead;
        self.stats.replica_retired += 1;
        self.fleet_obs.retired.fetch_add(1, Ordering::Relaxed);
        self.fleet_obs
            .replicas
            .store(self.live_count() as u64, Ordering::Relaxed);
        self.directory.clear_replica(replica);
        self.replicas[replica].shutdown();
        crate::log_info!("coordinator", "replica {replica} retired");
        Ok(())
    }

    /// Chaos hook ([`ServingBackend::kill_replica`], NDJSON
    /// `kill-replica` op): command a live replica to die as if its
    /// engine had crashed. Asynchronous — the `Fatal` event arrives on
    /// the event channel and the normal failover path takes over.
    pub fn kill_replica(&mut self, replica: usize) -> bool {
        replica < self.replicas.len()
            && self.states[replica] == ReplicaState::Live
            && self.replicas[replica].send(ReplicaCmd::Die).is_ok()
    }

    /// Turn on fleet-wide request tracing (idempotent): coordinator-side
    /// door/routing spans plus per-request phase spans inside every
    /// replica engine. The `EnableTrace` command rides each replica's
    /// FIFO channel, so it is applied before any submit issued after
    /// this call — no request admitted from here on is missed.
    pub fn enable_trace(&mut self) -> Result<()> {
        if self.trace.is_none() {
            self.trace = Some(TraceLog::with_origin(self.origin));
        }
        for (i, h) in self.replicas.iter().enumerate() {
            if self.states[i] != ReplicaState::Dead {
                h.send(ReplicaCmd::EnableTrace)?;
            }
        }
        Ok(())
    }

    /// Record a door-side reject/shed instant into the fleet trace
    /// (no-op when tracing is off). Pre-admission rejects have no fleet
    /// rid yet, so the stamped trace id is the client-supplied one or 0.
    fn trace_door(&mut self, req: &ServeRequest, code: &'static str) {
        let Some(t) = self.trace.as_mut() else { return };
        let at_us = t.rel_us(Instant::now());
        t.record_door(DoorEvent {
            trace: req.trace.unwrap_or(0),
            adapter: req.adapter.clone().unwrap_or_else(|| "base".into()),
            code,
            at_us,
        });
    }

    /// Record + send a load of a host-cached adapter to a replica.
    fn issue_load(&mut self, r: usize, name: &str) -> Result<()> {
        let Some(adapter) = self.host_adapters.get(name).cloned() else {
            bail!("adapter {name:?} is not host-cached");
        };
        self.directory.insert(r, name);
        self.stats.loads += 1;
        let sent = self.replicas[r].send(ReplicaCmd::Load(adapter));
        if sent.is_err() {
            // the replica died under us; un-record the placement (its
            // Fatal event retires it through the normal failover path)
            self.directory.remove(r, name);
            self.stats.loads -= 1;
        }
        sent
    }

    /// LRU-resident adapter on `r` that is idle (no in-flight requests)
    /// and is not `keep`.
    fn evictable(&self, r: usize, keep: &str) -> Option<String> {
        let ra = &self.inflight_ra[r];
        self.directory
            .lru_evictable(r, |n| n != keep && ra.get(n).map_or(true, |&c| c == 0))
    }

    /// Per-replica snapshots for one routing decision. Only live,
    /// non-suspect replicas appear — [`choose`] never sees a dead,
    /// retiring, or heartbeat-stale candidate ([`ReplicaView::index`]
    /// carries the true fleet index, so the filtered slice is safe for
    /// every policy including positional round-robin).
    fn views(&self, name: Option<&str>) -> Vec<ReplicaView> {
        let now_us = self.origin.elapsed().as_micros() as u64;
        self.replicas
            .iter()
            .enumerate()
            .filter(|(i, _)| {
                self.states[*i] == ReplicaState::Live && !self.is_suspect(*i, now_us)
            })
            .map(|(i, h)| {
                let resident = name.map_or(true, |n| self.directory.is_resident(i, n));
                let can_host = name.map_or(true, |n| {
                    self.host_adapters.contains_key(n)
                        && self.directory.copies(n) < self.cfg.max_copies
                        && (self.directory.has_free_slot(i) || self.evictable(i, n).is_some())
                });
                // expected queue wait: the replica's published step-time
                // estimate (decode side, same fallback the engine's own
                // admission uses) × our exact in-flight count. 0 for an
                // idle or not-yet-profiled replica — optimistic, like
                // the engine's own admission.
                let ewma = StepEwma {
                    prefill: h.gauges.ewma_prefill_us.load(Ordering::Relaxed) as f64 * 1e-6,
                    decode: h.gauges.ewma_decode_us.load(Ordering::Relaxed) as f64 * 1e-6,
                };
                ReplicaView {
                    index: i,
                    inflight: self.inflight[i],
                    kv_free: h.gauges.kv_free.load(Ordering::Relaxed),
                    expected_wait: ewma.decode_or_any() * self.inflight[i] as f64,
                    resident,
                    can_host,
                }
            })
            .collect()
    }

    /// Make `name` resident on `r` (no-op if it already is): evict the
    /// LRU idle adapter when the replica is at capacity, then load.
    fn ensure_resident(&mut self, r: usize, name: &str) -> Result<()> {
        if self.directory.is_resident(r, name) {
            return Ok(());
        }
        if !self.host_adapters.contains_key(name)
            || self.directory.copies(name) >= self.cfg.max_copies
        {
            return Ok(()); // engine will reject the submit
        }
        if !self.directory.has_free_slot(r) {
            let Some(victim) = self.evictable(r, name) else {
                // capacity raced away since the routing decision; the
                // engine rejects the submit and the event accounting
                // picks it up
                return Ok(());
            };
            self.directory.remove(r, &victim);
            self.stats.evictions += 1;
            self.replicas[r].send(ReplicaCmd::Evict(victim))?;
        }
        self.issue_load(r, name)
    }

    /// Replicate a hot adapter onto the least-loaded replica with a free
    /// slot (replication never evicts).
    fn try_replicate(&mut self, name: &str) -> Result<()> {
        let mut best: Option<usize> = None;
        for i in 0..self.replicas.len() {
            if self.states[i] != ReplicaState::Live
                || self.directory.is_resident(i, name)
                || !self.directory.has_free_slot(i)
            {
                continue;
            }
            if best.map_or(true, |b| self.inflight[i] < self.inflight[b]) {
                best = Some(i);
            }
        }
        if let Some(i) = best {
            crate::log_info!(
                "coordinator",
                "replicating hot adapter {name:?} to replica {i}"
            );
            self.issue_load(i, name)?;
            self.stats.replications += 1;
        }
        Ok(())
    }

    fn inflight_for(&self, name: &str) -> usize {
        self.inflight_adapter.get(name).copied().unwrap_or(0)
    }

    fn note_done(&mut self, replica: usize, adapter: Option<&str>) {
        self.inflight[replica] = self.inflight[replica].saturating_sub(1);
        if let Some(n) = adapter {
            if let Some(c) = self.inflight_adapter.get_mut(n) {
                *c = c.saturating_sub(1);
            }
            if let Some(c) = self.inflight_ra[replica].get_mut(n) {
                *c = c.saturating_sub(1);
            }
        }
    }

    /// Total requests routed and not yet terminal.
    fn inflight_total(&self) -> usize {
        self.inflight.iter().sum()
    }

    /// Fold one replica event into coordinator state, forwarding stream
    /// events to the owning client handle. Replica failure is *not*
    /// fatal to the fleet: the dead replica is retired in place and its
    /// in-flight requests fail over to survivors
    /// ([`Coordinator::lose_replica`]).
    fn apply(&mut self, ev: ReplicaEvent) {
        match ev {
            ReplicaEvent::Stream { replica, event } => {
                let rid = event.id();
                let terminal = event.is_terminal();
                if terminal {
                    let adapter = self.routes.remove(&rid).and_then(|e| e.adapter);
                    self.note_done(replica, adapter.as_deref());
                }
                if let Some(tx) = self.clients.get(&rid) {
                    let _ = tx.send(event);
                }
                if terminal {
                    self.clients.remove(&rid);
                }
            }
            ReplicaEvent::SubmitRejected { replica, rid, adapter, err } => {
                self.note_done(replica, adapter.as_deref());
                self.stats.submit_rejected += 1;
                self.routes.remove(&rid);
                if let Some(tx) = self.clients.remove(&rid) {
                    let _ = tx.send(TokenEvent::Aborted {
                        id: rid,
                        reason: AbortReason::Rejected(err),
                    });
                }
            }
            ReplicaEvent::LoadDone { replica, adapter, err } => {
                if err.is_some() {
                    self.directory.remove(replica, &adapter);
                    self.stats.load_failures += 1;
                }
            }
            ReplicaEvent::EvictDone { replica, adapter, err } => {
                if err.is_some() {
                    // the engine kept it (safety net); restore our view
                    self.directory.insert(replica, &adapter);
                    self.stats.evict_rejected += 1;
                }
            }
            ReplicaEvent::Fatal { replica, err } => {
                self.lose_replica(replica, &err);
            }
            ReplicaEvent::Finished { replica, report, trace } => {
                // a drain answer arriving outside the finish/retire wait
                // loops (e.g. a retire raced a failure): stash it so the
                // final merge still sees the replica's numbers
                self.retired_reports.entry(replica).or_insert(report);
                if let Some(t) = trace {
                    self.retired_traces.entry(replica).or_insert(t);
                }
            }
            ReplicaEvent::Ready { .. } => {}
        }
    }

    /// Retire `replica` in place: mark it dead, drop its directory row
    /// and in-flight books, and collect the route entries stranded on
    /// it. Idempotent — a second call (Fatal event after a send failure
    /// already retired it) returns nothing.
    fn mark_dead(&mut self, replica: usize, err: &str) -> Vec<(RequestId, RouteEntry)> {
        if self.states[replica] == ReplicaState::Dead {
            return Vec::new();
        }
        crate::log_warn!("coordinator", "retiring replica {replica}: {err}");
        // black-box dump: the dead engine's last recorded events,
        // straight from its shared flight-recorder ring
        if let Some(fr) = self.flightrecs.get(replica) {
            let snap = fr.snapshot();
            let tail: Vec<String> = snap
                .events
                .iter()
                .rev()
                .take(16)
                .rev()
                .map(|e| {
                    format!(
                        "{}+{}us id={} aid={} v={}",
                        e.kind.as_str(),
                        e.t_us,
                        e.id,
                        e.aid,
                        e.value
                    )
                })
                .collect();
            crate::log_warn!(
                "coordinator",
                "replica {replica} flight recorder: {} recorded, {} dropped, tail [{}]",
                snap.recorded,
                snap.dropped,
                tail.join(", ")
            );
        }
        self.states[replica] = ReplicaState::Dead;
        self.replicas[replica].shutdown();
        self.stats.replica_retired += 1;
        self.fleet_obs.retired.fetch_add(1, Ordering::Relaxed);
        self.fleet_obs
            .replicas
            .store(self.live_count() as u64, Ordering::Relaxed);
        self.directory.clear_replica(replica);
        self.inflight_ra[replica].clear();
        let rids: Vec<RequestId> = self
            .routes
            .iter()
            .filter(|(_, e)| e.replica == replica)
            .map(|(&rid, _)| rid)
            .collect();
        let mut lost = Vec::with_capacity(rids.len());
        for rid in rids {
            if let Some(e) = self.routes.remove(&rid) {
                if let Some(n) = &e.adapter {
                    if let Some(c) = self.inflight_adapter.get_mut(n) {
                        *c = c.saturating_sub(1);
                    }
                }
                lost.push((rid, e));
            }
        }
        self.inflight[replica] = 0;
        lost
    }

    /// Terminal path for a request that could not survive its replica:
    /// the client gets a typed [`AbortReason::ReplicaLost`], never a
    /// hung stream.
    fn abort_lost(&mut self, rid: RequestId) {
        self.stats.reroute_aborted += 1;
        self.fleet_obs.reroute_aborted.fetch_add(1, Ordering::Relaxed);
        if let Some(tx) = self.clients.remove(&rid) {
            let _ = tx.send(TokenEvent::Aborted { id: rid, reason: AbortReason::ReplicaLost });
        }
    }

    /// Failover: retire a dead replica and re-submit every request that
    /// was routed to it to a survivor, under the same fleet rid (the
    /// client keeps its stream; prefill re-runs, so the stream may
    /// restart — the last terminal event is authoritative). A request
    /// is aborted typed ([`abort_lost`]) only when its remaining
    /// deadline cannot survive the retry, no survivor can take it, or
    /// the fleet is already finishing. If a survivor turns out dead at
    /// submit time it joins the retirement cascade and its stranded
    /// requests enter the same worklist.
    ///
    /// [`abort_lost`]: Coordinator::abort_lost
    fn lose_replica(&mut self, replica: usize, err: &str) {
        let mut lost = self.mark_dead(replica, err);
        while let Some((rid, entry)) = lost.pop() {
            if self.finishing {
                self.abort_lost(rid);
                continue;
            }
            let mut req = entry.req;
            if let Some(d) = req.deadline {
                match d.checked_sub(entry.submitted_at.elapsed()) {
                    Some(rem) if !rem.is_zero() => req.deadline = Some(rem),
                    _ => {
                        self.abort_lost(rid);
                        continue;
                    }
                }
            }
            let name = entry.adapter;
            loop {
                let views = self.views(name.as_deref());
                let Ok(d) = choose(self.cfg.policy, &views, req.deadline, &mut self.rr_next)
                else {
                    self.abort_lost(rid);
                    break;
                };
                let r2 = d.replica;
                if let Some(n) = name.as_deref() {
                    if d.resident {
                        self.directory.touch(r2, n);
                    } else if let Err(e) = self.ensure_resident(r2, n) {
                        // r2 died too: fold its already-queued events
                        // (terminal streams before its Fatal, FIFO per
                        // sender) before sweeping it into the cascade
                        self.absorb_events();
                        lost.extend(self.mark_dead(r2, &format!("{e:#}")));
                        continue;
                    }
                }
                match self.replicas[r2].send(ReplicaCmd::Submit { rid, req: req.clone() }) {
                    Ok(()) => {
                        if let Some(n) = name.as_deref() {
                            *self.inflight_adapter.entry(n.to_string()).or_insert(0) += 1;
                            *self.inflight_ra[r2].entry(n.to_string()).or_insert(0) += 1;
                        }
                        self.inflight[r2] += 1;
                        self.routes.insert(
                            rid,
                            RouteEntry {
                                replica: r2,
                                adapter: name.clone(),
                                req,
                                submitted_at: Instant::now(),
                            },
                        );
                        self.stats.requests_rerouted += 1;
                        self.fleet_obs.rerouted.fetch_add(1, Ordering::Relaxed);
                        crate::log_info!(
                            "coordinator",
                            "re-routed request {rid} to replica {r2}"
                        );
                        break;
                    }
                    Err(e) => {
                        self.absorb_events();
                        lost.extend(self.mark_dead(r2, &format!("{e:#}")));
                        continue;
                    }
                }
            }
        }
    }

    /// Non-blocking: fold every already-delivered replica event.
    fn absorb_events(&mut self) {
        while let Ok(ev) = self.events.try_recv() {
            self.apply(ev);
        }
    }

    /// Admit, place and submit one request through the typed serving
    /// boundary. Sheds/rejections update [`FleetStats`] (and therefore
    /// the fleet report) — this is the single accounting point.
    fn route(&mut self, mut req: ServeRequest) -> Result<RequestHandle, SubmitError> {
        let arrival = Instant::now();
        // fold finished work first so routing scores are fresh (this
        // also applies any pending Fatal, retiring dead replicas before
        // they can be scored)
        self.absorb_events();
        if self.shutting_down {
            self.stats.submit_rejected += 1;
            self.trace_door(&req, "shutting_down");
            return Err(SubmitError::ShuttingDown);
        }
        let adapter = req.adapter.clone();
        let name = adapter.as_deref();
        if let Some(n) = name {
            if !self.host_adapters.contains_key(n) {
                self.stats.submit_rejected += 1;
                self.trace_door(&req, "unknown_adapter");
                return Err(SubmitError::UnknownAdapter(n.to_string()));
            }
            if self.cfg.queue_cap > 0 && self.inflight_for(n) >= self.cfg.queue_cap {
                self.stats.shed_queue_full += 1;
                self.trace_door(&req, "queue_full");
                return Err(SubmitError::QueueFull);
            }
        }
        // past the door budget checks = admitted to the routing stage
        let admitted = Instant::now();
        let views = self.views(name);
        let decision = match choose(self.cfg.policy, &views, req.deadline, &mut self.rr_next) {
            Ok(d) => d,
            Err(RouteError::NoCapacity) => {
                self.stats.shed_no_capacity += 1;
                self.trace_door(&req, "shed");
                return Err(SubmitError::Shed);
            }
            Err(RouteError::DeadlineUnmeetable) => {
                self.stats.deadline_unmeetable += 1;
                self.stats.submit_rejected += 1;
                self.trace_door(&req, "deadline_unmeetable");
                return Err(SubmitError::DeadlineUnmeetable);
            }
        };
        let r = decision.replica;
        if let Some(n) = name {
            if decision.resident {
                self.stats.affinity_hits += 1;
                self.directory.touch(r, n);
            } else {
                self.stats.affinity_misses += 1;
                if let Err(e) = self.ensure_resident(r, n) {
                    // the chosen replica died between scoring and load;
                    // retire it (failing over its in-flight work) and
                    // shed this request — the client retries against a
                    // fleet that no longer scores the dead replica
                    self.lose_replica(r, &format!("{e:#}"));
                    self.stats.shed_no_capacity += 1;
                    self.trace_door(&req, "shed");
                    return Err(SubmitError::Shed);
                }
            }
            let at = self.clock.elapsed().as_secs_f64();
            let rate = self.rates.observe(n, at);
            if self.cfg.replicate_rps.is_finite()
                && rate > self.cfg.replicate_rps
                && self.directory.copies(n) < self.cfg.max_copies
            {
                if let Err(e) = self.try_replicate(n) {
                    // best-effort: the replication target died, not the
                    // submit path — its own Fatal event retires it
                    crate::log_warn!("coordinator", "replication failed: {e:#}");
                }
            }
            // book the request as in-flight only after every fallible
            // step above — an error return must leave the books clean
            *self.inflight_adapter.entry(n.to_string()).or_insert(0) += 1;
            *self.inflight_ra[r].entry(n.to_string()).or_insert(0) += 1;
        }
        self.inflight[r] += 1;
        self.stats.routed += 1;
        let rid = self.next_rid;
        self.next_rid += 1;
        // the fleet trace id: the client's, or the rid itself. It rides
        // `req.trace` into the replica engine, which stamps it on every
        // phase span — the thread tying both halves of the timeline.
        let trace_id = req.trace.unwrap_or(rid);
        req.trace = Some(trace_id);
        let adapter_label = adapter.clone().unwrap_or_else(|| "base".into());
        let (handle, tx) = RequestHandle::new(rid);
        self.clients.insert(rid, tx);
        self.routes.insert(
            rid,
            RouteEntry { replica: r, adapter, req: req.clone(), submitted_at: Instant::now() },
        );
        if self.replicas[r].send(ReplicaCmd::Submit { rid, req }).is_err() {
            // the replica died between scoring and send. Fold its
            // already-queued events first (terminal streams precede its
            // Fatal, FIFO per sender — applying the Fatal retires it and
            // fails over this rid with everything else stranded there),
            // then retire explicitly in case the Fatal is still in
            // flight. Either way this rid is re-submitted to a survivor
            // (the handle we return streams from the new replica) or
            // terminated with a typed ReplicaLost abort — never hung.
            self.absorb_events();
            self.lose_replica(r, "submit channel closed");
        }
        if let Some(t) = self.trace.as_mut() {
            let candidates = views
                .iter()
                .map(|v| Candidate {
                    replica: v.index,
                    inflight: v.inflight,
                    kv_free: v.kv_free,
                    expected_wait_us: (v.expected_wait * 1e6) as u64,
                    resident: v.resident,
                })
                .collect();
            t.record_route(RouteSpan {
                rid,
                trace: trace_id,
                adapter: adapter_label,
                policy: self.cfg.policy.as_str(),
                replica: r,
                resident: decision.resident,
                candidates,
                arrival_us: t.rel_us(arrival),
                admitted_us: t.rel_us(admitted),
                routed_us: t.rel_us(Instant::now()),
            });
        }
        Ok(handle)
    }

    /// Ask every replica to drain, collect the per-replica reports (wall
    /// anchored to `since`), and join the threads. Consumes the fleet.
    /// Callers driving the fleet through [`ServingBackend`] directly
    /// (instead of [`Coordinator::replay`]) end a serving session with
    /// `drain()` followed by `finish(started_at)`.
    pub fn finish(self, since: Instant) -> Result<(Vec<Report>, FleetStats)> {
        let (per_replica, stats, _trace) = self.finish_traced(since)?;
        Ok((per_replica, stats))
    }

    /// [`Coordinator::finish`] plus the merged fleet trace: every
    /// replica's phase-span log is shipped back in its `Finished` event,
    /// rebased onto the coordinator's origin and re-keyed from engine
    /// sequence ids to fleet rids ([`TraceLog::absorb`]), then folded
    /// into the coordinator's own door/routing timeline. `None` unless
    /// [`Coordinator::enable_trace`] ran.
    pub fn finish_traced(
        mut self,
        since: Instant,
    ) -> Result<(Vec<Report>, FleetStats, Option<TraceLog>)> {
        self.absorb_events();
        // lose_replica must stop re-submitting from here on: replicas
        // processing Finish never read another command, so a re-routed
        // request would hang — typed aborts are the correct terminal
        self.finishing = true;
        let n = self.replicas.len();
        let mut reports: Vec<Option<Report>> = (0..n).map(|_| None).collect();
        let mut traces: Vec<Option<TraceLog>> = (0..n).map(|_| None).collect();
        let fill_dead = |me: &mut Coordinator,
                         reports: &mut Vec<Option<Report>>,
                         traces: &mut Vec<Option<TraceLog>>| {
            for i in 0..n {
                if reports[i].is_some() {
                    continue;
                }
                // a stashed report (retired mid-run, or a Finished that
                // apply() caught) fills the slot; a dead replica without
                // one contributes an empty report so the vector aligns
                if let Some(rep) = me.retired_reports.remove(&i) {
                    reports[i] = Some(rep);
                    traces[i] = me.retired_traces.remove(&i);
                } else if me.states[i] == ReplicaState::Dead {
                    reports[i] = Some(Report::empty());
                }
            }
        };
        // replicas retired mid-run already reported (or died without a
        // report); every remaining live/retiring one is asked to finish
        fill_dead(&mut self, &mut reports, &mut traces);
        for i in 0..n {
            if reports[i].is_some() {
                continue;
            }
            if self.replicas[i].send(ReplicaCmd::Finish { since }).is_err() {
                // died on the doorstep; failover (abort-only, we are
                // finishing) settles its streams, report stays empty
                self.lose_replica(i, "finish channel closed");
            }
        }
        fill_dead(&mut self, &mut reports, &mut traces);
        while reports.iter().any(|r| r.is_none()) {
            match self.events.recv_timeout(Duration::from_secs(600)) {
                Ok(ReplicaEvent::Finished { replica, report, trace }) => {
                    if reports[replica].is_none() {
                        reports[replica] = Some(report);
                        traces[replica] = trace;
                    }
                }
                Ok(ev) => {
                    // a Fatal here retires the replica; fill its slot so
                    // the wait terminates
                    self.apply(ev);
                    fill_dead(&mut self, &mut reports, &mut traces);
                }
                Err(e) => bail!("fleet drain failed: {e}"),
            }
        }
        for h in self.replicas.iter_mut() {
            h.shutdown();
        }
        let per_replica: Vec<Report> =
            reports.into_iter().map(|r| r.expect("replica report")).collect();
        let merged = self.trace.take().map(|mut fleet| {
            // replica spans carry the fleet trace id; map it back to the
            // fleet rid so Chrome tids line up with the routing spans
            let rekey: HashMap<u64, u64> =
                fleet.routes().iter().map(|s| (s.trace, s.rid)).collect();
            for (i, t) in traces.into_iter().enumerate() {
                if let Some(t) = t {
                    fleet.absorb(t, i as u64 + 1, &rekey);
                }
            }
            fleet
        });
        Ok((per_replica, self.stats, merged))
    }

    /// Replay a trace against the fleet in real time — a thin client of
    /// the serving API ([`ServingBackend`] submit/pump via
    /// [`crate::server::replay_backend`]) — then drain every replica and
    /// aggregate with [`Report::merge`]. Consumes the coordinator
    /// (threads are joined before returning).
    pub fn replay(mut self, trace: &Trace) -> Result<FleetOutcome> {
        let pacer = Pacer::start();
        self.clock = pacer.started_at();
        let (completions, _rejected) =
            crate::server::replay_backend(&mut self, trace, &pacer)?;
        let wall = pacer.elapsed().as_secs_f64().max(1e-9);
        let since = pacer.started_at();
        let (per_replica, stats, trace) = self.finish_traced(since)?;
        let mut report = Report::merge(
            per_replica.iter(),
            completions.iter().map(|c| &c.record),
            Some(wall),
        );
        // the fleet's admission books are authoritative for the
        // aggregate (per-replica reports only see post-routing rejects)
        report.requests = completions.len();
        report.rejected = stats.submit_rejected;
        report.shed = stats.shed_total();
        Ok(FleetOutcome { report, per_replica, completions, stats, trace })
    }
}

/// The fleet serving backend: `pump` folds replica events (blocking
/// briefly when none are pending) and forwards token streams to client
/// handles.
impl ServingBackend for Coordinator {
    fn submit(&mut self, req: ServeRequest) -> Result<RequestHandle, SubmitError> {
        self.route(req)
    }

    fn pump(&mut self) -> Result<bool> {
        match self.events.recv_timeout(Duration::from_millis(2)) {
            Ok(ev) => {
                self.apply(ev);
                self.absorb_events();
            }
            Err(RecvTimeoutError::Timeout) => {}
            // unreachable while the coordinator holds events_tx; kept as
            // a defensive exit
            Err(RecvTimeoutError::Disconnected) => bail!("fleet event channel closed"),
        }
        Ok(self.inflight_total() > 0)
    }

    fn cancel(&mut self, id: RequestId) -> bool {
        let Some(r) = self.routes.get(&id).map(|e| e.replica) else {
            return false;
        };
        self.replicas[r].send(ReplicaCmd::Cancel { rid: id }).is_ok()
    }

    fn has_work(&self) -> bool {
        self.inflight_total() > 0
    }

    fn kill_replica(&mut self, replica: usize) -> bool {
        Coordinator::kill_replica(self, replica)
    }

    fn stats(&mut self) -> Option<crate::obs::StatsSnapshot> {
        Some(self.stats_snapshot())
    }

    fn flightrec(&mut self) -> Option<crate::util::json::Json> {
        let pairs: Vec<(usize, &FlightRecorder)> = self
            .flightrecs
            .iter()
            .enumerate()
            .map(|(i, fr)| (i, &**fr))
            .collect();
        Some(crate::obs::flightrec::dump(&pairs))
    }

    /// Drain the whole fleet: finish every in-flight request *and* wait
    /// until every replica engine reports an idle scheduler, so a
    /// frontend (e.g. the fleet NDJSON listener) can close knowing no
    /// replica is still mid-step. The coordinator's own in-flight count
    /// reaches zero when the last terminal event arrives, which can be a
    /// beat before the emitting replica has finished its step and
    /// republished its gauges — without the second wait, a listener
    /// could shut down while a replica thread is still working.
    fn drain(&mut self) -> Result<()> {
        self.shutting_down = true;
        loop {
            // dead replicas' gauges can be frozen mid-step; only live
            // slots gate the drain
            let replica_busy = self.replicas.iter().enumerate().any(|(i, h)| {
                self.states[i] != ReplicaState::Dead
                    && h.gauges.active.load(Ordering::Relaxed) > 0
            });
            if !ServingBackend::has_work(self) && !replica_busy {
                break;
            }
            ServingBackend::pump(self)?;
        }
        // deliver any terminal events that raced the last pump
        self.absorb_events();
        Ok(())
    }
}
