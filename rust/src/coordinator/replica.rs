//! One fleet replica: an [`Engine`] owned by a dedicated thread.
//!
//! PJRT handles are not `Send`, so the engine is *built inside* its
//! thread from a `Send` factory and never leaves it. The coordinator
//! talks to the replica over a FIFO command channel — which gives the
//! crucial ordering guarantee that a `Load(adapter)` issued before a
//! `Submit` for that adapter is applied first — and receives completions
//! and lifecycle acknowledgements on a shared event channel.
//!
//! The thread publishes its KV headroom ([`ReplicaGauges`]) after every
//! command and step; the coordinator reads it lock-free as the
//! tie-break signal when scoring placements (queue depth it tracks
//! itself, exactly, from submit/completion events).

use crate::engine::{Completion, Engine, RequestSpec};
use crate::metrics::Report;
use anyhow::Result;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

/// Lock-free KV-pressure snapshot a replica thread keeps fresh (the
/// coordinator's queue-depth signal is its own exact in-flight count;
/// KV headroom is the one thing only the engine knows).
#[derive(Debug, Default)]
pub struct ReplicaGauges {
    /// Free KV token slots.
    pub kv_free: AtomicUsize,
}

/// Commands a replica executes in arrival order.
pub(crate) enum ReplicaCmd {
    Submit(RequestSpec),
    Load(Arc<crate::adapters::format::Adapter>),
    Evict(String),
    /// Drain all queued work, report (wall time anchored to `since`,
    /// the coordinator's replay start), and exit the thread.
    Finish { since: Instant },
}

/// Events a replica reports back to the coordinator.
pub(crate) enum ReplicaEvent {
    /// Sent once after engine construction; `err` is set on failure.
    Ready { replica: usize, err: Option<String> },
    Completed { replica: usize, completion: Completion },
    /// `Engine::submit` refused a routed request.
    SubmitRejected { replica: usize, adapter: Option<String> },
    LoadDone { replica: usize, adapter: String, err: Option<String> },
    EvictDone { replica: usize, adapter: String, err: Option<String> },
    /// Final per-replica serving report (response to `Finish`).
    Finished { replica: usize, report: Report },
    /// The engine failed mid-serve; the replica is gone.
    Fatal { replica: usize, err: String },
}

/// Coordinator-side handle to one replica thread.
pub struct ReplicaHandle {
    pub index: usize,
    pub gauges: Arc<ReplicaGauges>,
    cmd: Sender<ReplicaCmd>,
    join: Option<JoinHandle<()>>,
}

impl ReplicaHandle {
    pub(crate) fn send(&self, cmd: ReplicaCmd) -> Result<()> {
        self.cmd
            .send(cmd)
            .map_err(|_| anyhow::anyhow!("replica {} is no longer accepting commands", self.index))
    }

    /// Drop the command channel and wait for the thread to exit.
    pub(crate) fn shutdown(mut self) {
        drop(self.cmd);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Spawn a replica thread; the engine is constructed inside it.
pub(crate) fn spawn_replica(
    index: usize,
    build: Box<dyn FnOnce() -> Result<Engine> + Send>,
    events: Sender<ReplicaEvent>,
) -> ReplicaHandle {
    let (cmd_tx, cmd_rx) = channel::<ReplicaCmd>();
    let gauges = Arc::new(ReplicaGauges::default());
    let gauges_thread = gauges.clone();
    let join = std::thread::Builder::new()
        .name(format!("replica-{index}"))
        .spawn(move || {
            // a panicking replica must still surface as Fatal, or the
            // coordinator's drain would block until its recv timeout
            let events_panic = events.clone();
            let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                replica_main(index, build, cmd_rx, events, gauges_thread)
            }));
            if let Err(payload) = run {
                let err = payload
                    .downcast_ref::<String>()
                    .cloned()
                    .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                    .unwrap_or_else(|| "replica thread panicked".to_string());
                let _ = events_panic.send(ReplicaEvent::Fatal { replica: index, err });
            }
        })
        .expect("spawn replica thread");
    ReplicaHandle { index, gauges, cmd: cmd_tx, join: Some(join) }
}

fn publish(engine: &Engine, gauges: &ReplicaGauges) {
    gauges.kv_free.store(engine.kv_free_slots(), Ordering::Relaxed);
}

enum Flow {
    Continue,
    Finish(Instant),
}

fn handle_cmd(
    index: usize,
    engine: &mut Engine,
    events: &Sender<ReplicaEvent>,
    cmd: ReplicaCmd,
) -> Flow {
    match cmd {
        ReplicaCmd::Submit(spec) => {
            let adapter = spec.adapter.clone();
            if let Err(e) = engine.submit(spec) {
                crate::log_debug!("replica", "[{index}] submit rejected: {e:#}");
                engine.metrics.record_rejected();
                let _ = events.send(ReplicaEvent::SubmitRejected { replica: index, adapter });
            }
            Flow::Continue
        }
        ReplicaCmd::Load(adapter) => {
            let name = adapter.name.clone();
            let err = engine.load_adapter(&adapter).err().map(|e| format!("{e:#}"));
            if let Some(e) = &err {
                crate::log_warn!("replica", "[{index}] load {name:?} failed: {e}");
            }
            let _ = events.send(ReplicaEvent::LoadDone { replica: index, adapter: name, err });
            Flow::Continue
        }
        ReplicaCmd::Evict(name) => {
            let err = engine.evict_adapter(&name).err().map(|e| format!("{e:#}"));
            let _ = events.send(ReplicaEvent::EvictDone { replica: index, adapter: name, err });
            Flow::Continue
        }
        ReplicaCmd::Finish { since } => Flow::Finish(since),
    }
}

fn replica_main(
    index: usize,
    build: Box<dyn FnOnce() -> Result<Engine> + Send>,
    cmds: Receiver<ReplicaCmd>,
    events: Sender<ReplicaEvent>,
    gauges: Arc<ReplicaGauges>,
) {
    let mut engine = match build() {
        Ok(e) => {
            let _ = events.send(ReplicaEvent::Ready { replica: index, err: None });
            e
        }
        Err(e) => {
            let _ = events.send(ReplicaEvent::Ready {
                replica: index,
                err: Some(format!("{e:#}")),
            });
            return;
        }
    };
    publish(&engine, &gauges);

    let mut finishing: Option<Instant> = None;
    'serve: while finishing.is_none() {
        if engine.has_work() {
            // busy: absorb whatever commands are already queued, then step
            loop {
                match cmds.try_recv() {
                    Ok(cmd) => {
                        if let Flow::Finish(since) =
                            handle_cmd(index, &mut engine, &events, cmd)
                        {
                            finishing = Some(since);
                            break;
                        }
                    }
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => break 'serve,
                }
            }
            if finishing.is_none() {
                match engine.step() {
                    Ok(Some(done)) => {
                        for completion in done {
                            let _ = events
                                .send(ReplicaEvent::Completed { replica: index, completion });
                        }
                    }
                    Ok(None) => {}
                    Err(e) => {
                        let _ = events.send(ReplicaEvent::Fatal {
                            replica: index,
                            err: format!("{e:#}"),
                        });
                        return;
                    }
                }
            }
        } else {
            // idle: block until the coordinator has something for us
            match cmds.recv() {
                Ok(cmd) => {
                    if let Flow::Finish(since) = handle_cmd(index, &mut engine, &events, cmd) {
                        finishing = Some(since);
                    }
                }
                Err(_) => break 'serve,
            }
        }
        publish(&engine, &gauges);
    }

    if let Some(since) = finishing {
        // drain everything still queued, then report
        match engine.run_to_completion() {
            Ok(done) => {
                for completion in done {
                    let _ = events.send(ReplicaEvent::Completed { replica: index, completion });
                }
            }
            Err(e) => {
                let _ = events
                    .send(ReplicaEvent::Fatal { replica: index, err: format!("{e:#}") });
                return;
            }
        }
        publish(&engine, &gauges);
        engine.metrics.set_wall(since.elapsed());
        let report = engine.report();
        let _ = events.send(ReplicaEvent::Finished { replica: index, report });
    }
}
