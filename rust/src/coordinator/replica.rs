//! One fleet replica: an [`Engine`] owned by a dedicated thread.
//!
//! PJRT handles are not `Send`, so the engine is *built inside* its
//! thread from a `Send` factory and never leaves it. The coordinator
//! talks to the replica over a FIFO command channel — which gives the
//! crucial ordering guarantee that a `Load(adapter)` issued before a
//! `Submit` for that adapter is applied first — and receives token
//! streams and lifecycle acknowledgements on a shared event channel.
//!
//! The replica drives its engine through the serving API
//! ([`Engine::submit_request`] / [`Engine::cancel_request`]): each
//! routed request is held as a [`RequestHandle`], and every
//! [`TokenEvent`] the engine emits is re-addressed from the
//! engine-local sequence id to the coordinator's fleet request id and
//! forwarded upstream ([`ReplicaEvent::Stream`]) — so fleet clients see
//! the same incremental stream single-engine clients do.
//!
//! The thread publishes its KV headroom ([`ReplicaGauges`]) after every
//! command and step; the coordinator reads it lock-free as the
//! tie-break signal when scoring placements (queue depth it tracks
//! itself, exactly, from submit/terminal events).

use crate::engine::Engine;
use crate::metrics::Report;
use crate::serving::{RequestHandle, ServeRequest, SubmitError, TokenEvent};
use anyhow::Result;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Lock-free telemetry snapshot a replica thread keeps fresh — its
/// heartbeat to the coordinator, republished after every command and
/// step. The coordinator's queue-depth signal is its own exact in-flight
/// count; KV headroom and step-time estimates are the things only the
/// engine knows.
#[derive(Debug, Default)]
pub struct ReplicaGauges {
    /// Free KV token slots.
    pub kv_free: AtomicUsize,
    /// EWMA wall time of prefill-phase steps, microseconds (0 = no
    /// estimate yet). See [`crate::engine::StepEwma`].
    pub ewma_prefill_us: AtomicU64,
    /// EWMA wall time of pure decode steps, microseconds (0 = no
    /// estimate yet). [`RoutingPolicy::DeadlineAware`] scores replicas
    /// by this × the coordinator's in-flight count.
    ///
    /// [`RoutingPolicy::DeadlineAware`]: crate::coordinator::RoutingPolicy::DeadlineAware
    pub ewma_decode_us: AtomicU64,
    /// Sequences queued or running inside the engine. The coordinator's
    /// drain waits for this to reach zero on every replica so the fleet
    /// listener never closes while an engine is still mid-step.
    pub active: AtomicUsize,
    /// Monotonic heartbeat: microseconds since the coordinator's epoch
    /// at the last publish. Idle replicas republish on a short timer, so
    /// a stamp older than [`CoordinatorConfig::suspect_after`] means the
    /// thread is wedged (or dead) and routing marks the replica suspect.
    ///
    /// [`CoordinatorConfig::suspect_after`]: crate::coordinator::CoordinatorConfig::suspect_after
    pub last_beat_us: AtomicU64,
}

/// Commands a replica executes in arrival order.
pub(crate) enum ReplicaCmd {
    /// Submit a routed request under fleet request id `rid`.
    Submit { rid: u64, req: ServeRequest },
    /// Cancel fleet request `rid` (queued or mid-decode).
    Cancel { rid: u64 },
    Load(Arc<crate::adapters::format::Adapter>),
    Evict(String),
    /// Turn on the engine's per-request phase tracing. FIFO ordering
    /// guarantees it lands before any `Submit` issued after it, so the
    /// fleet trace misses no request.
    EnableTrace,
    /// Drain all queued work, report (wall time anchored to `since`,
    /// the coordinator's replay start), and exit the thread.
    Finish { since: Instant },
    /// Chaos hook: die immediately, as if the engine had crashed
    /// mid-step. The thread reports [`ReplicaEvent::Fatal`] and exits
    /// without draining — the coordinator's failover path handles the
    /// in-flight fallout exactly like a real crash.
    Die,
}

/// Events a replica reports back to the coordinator.
pub(crate) enum ReplicaEvent {
    /// Sent once after engine construction; `err` is set on failure.
    /// On success `obs` carries the engine's live metric registry
    /// ([`crate::obs::ObsRegistry`]) — recording stays inside the
    /// replica thread; the coordinator only snapshots/aggregates it
    /// (fleet `stats` frames, the Prometheus exposition).
    Ready {
        replica: usize,
        err: Option<String>,
        obs: Option<Arc<crate::obs::ObsRegistry>>,
        /// The engine's always-on flight recorder; the coordinator keeps
        /// a handle per replica so `flightrec` frames and crash dumps can
        /// snapshot every ring without a round-trip to the thread.
        flightrec: Option<Arc<crate::obs::flightrec::FlightRecorder>>,
    },
    /// A token-stream event, already re-addressed to the fleet rid.
    /// `Done`/`Aborted` are terminal (the coordinator's in-flight
    /// accounting keys off them).
    Stream { replica: usize, event: TokenEvent },
    /// [`Engine::submit_request`] refused a routed request (e.g. the
    /// adapter raced away between routing and arrival).
    SubmitRejected {
        replica: usize,
        rid: u64,
        adapter: Option<String>,
        err: SubmitError,
    },
    LoadDone { replica: usize, adapter: String, err: Option<String> },
    EvictDone { replica: usize, adapter: String, err: Option<String> },
    /// Final per-replica serving report (response to `Finish`). `trace`
    /// carries the engine's phase-span log when tracing was enabled —
    /// the coordinator rebases and merges it into the fleet timeline.
    Finished {
        replica: usize,
        report: Report,
        trace: Option<crate::obs::trace::TraceLog>,
    },
    /// The engine failed mid-serve; the replica is gone.
    Fatal { replica: usize, err: String },
}

/// Coordinator-side handle to one replica thread.
pub struct ReplicaHandle {
    pub index: usize,
    pub gauges: Arc<ReplicaGauges>,
    /// `None` once shut down (the channel drop is the exit signal).
    cmd: Option<Sender<ReplicaCmd>>,
    join: Option<JoinHandle<()>>,
}

impl ReplicaHandle {
    pub(crate) fn send(&self, cmd: ReplicaCmd) -> Result<()> {
        self.cmd
            .as_ref()
            .and_then(|tx| tx.send(cmd).ok())
            .ok_or_else(|| {
                anyhow::anyhow!("replica {} is no longer accepting commands", self.index)
            })
    }

    /// Drop the command channel and wait for the thread to exit.
    /// In-place (the handle stays in the membership vector, keeping
    /// replica indices stable for routing and labels); idempotent.
    pub(crate) fn shutdown(&mut self) {
        self.cmd = None;
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Spawn a replica thread; the engine is constructed inside it.
/// `epoch` anchors the heartbeat stamp (the coordinator's origin
/// instant, shared by every replica so staleness is comparable).
pub(crate) fn spawn_replica(
    index: usize,
    build: Box<dyn FnOnce() -> Result<Engine> + Send>,
    events: Sender<ReplicaEvent>,
    epoch: Instant,
) -> ReplicaHandle {
    let (cmd_tx, cmd_rx) = channel::<ReplicaCmd>();
    let gauges = Arc::new(ReplicaGauges::default());
    let gauges_thread = gauges.clone();
    let join = std::thread::Builder::new()
        .name(format!("replica-{index}"))
        .spawn(move || {
            // a panicking replica must still surface as Fatal, or the
            // coordinator's drain would block until its recv timeout
            let events_panic = events.clone();
            let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                replica_main(index, build, cmd_rx, events, gauges_thread, epoch)
            }));
            if let Err(payload) = run {
                let err = payload
                    .downcast_ref::<String>()
                    .cloned()
                    .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                    .unwrap_or_else(|| "replica thread panicked".to_string());
                let _ = events_panic.send(ReplicaEvent::Fatal { replica: index, err });
            }
        })
        .expect("spawn replica thread");
    ReplicaHandle { index, gauges, cmd: Some(cmd_tx), join: Some(join) }
}

fn publish(engine: &Engine, gauges: &ReplicaGauges, epoch: Instant) {
    gauges.kv_free.store(engine.kv_free_slots(), Ordering::Relaxed);
    let ewma = engine.step_ewma();
    gauges
        .ewma_prefill_us
        .store((ewma.prefill * 1e6) as u64, Ordering::Relaxed);
    gauges
        .ewma_decode_us
        .store((ewma.decode * 1e6) as u64, Ordering::Relaxed);
    let (waiting, running) = engine.queue_depth();
    gauges.active.store(waiting + running, Ordering::Relaxed);
    // the heartbeat edge: staleness is measured against this stamp, so
    // it must be the last store (everything above is at least as fresh)
    gauges
        .last_beat_us
        .store(epoch.elapsed().as_micros() as u64, Ordering::Relaxed);
}

/// In-flight request bookkeeping inside one replica thread.
#[derive(Default)]
struct Streams {
    /// fleet rid → the engine-side token stream.
    handles: HashMap<u64, RequestHandle>,
    /// fleet rid → engine-local sequence id (cancel routing).
    engine_id: HashMap<u64, u64>,
}

impl Streams {
    /// Forward every buffered engine event upstream, re-addressed to
    /// fleet rids; drop streams that reached a terminal event.
    fn forward(&mut self, index: usize, events: &Sender<ReplicaEvent>) {
        let mut finished: Vec<u64> = Vec::new();
        for (&rid, handle) in &self.handles {
            for ev in handle.drain_events() {
                let terminal = ev.is_terminal();
                let _ = events.send(ReplicaEvent::Stream {
                    replica: index,
                    event: ev.reid(rid),
                });
                if terminal {
                    finished.push(rid);
                }
            }
        }
        for rid in finished {
            self.handles.remove(&rid);
            self.engine_id.remove(&rid);
        }
    }
}

enum Flow {
    Continue,
    Finish(Instant),
    Die,
}

fn handle_cmd(
    index: usize,
    engine: &mut Engine,
    streams: &mut Streams,
    events: &Sender<ReplicaEvent>,
    cmd: ReplicaCmd,
) -> Flow {
    match cmd {
        ReplicaCmd::Submit { rid, req } => {
            let adapter = req.adapter.clone();
            match engine.submit_request(req) {
                Ok(handle) => {
                    streams.engine_id.insert(rid, handle.id);
                    streams.handles.insert(rid, handle);
                }
                Err(err) => {
                    crate::log_debug!("replica", "[{index}] submit rejected: {err}");
                    let _ = events.send(ReplicaEvent::SubmitRejected {
                        replica: index,
                        rid,
                        adapter,
                        err,
                    });
                }
            }
            Flow::Continue
        }
        ReplicaCmd::Cancel { rid } => {
            if let Some(&eid) = streams.engine_id.get(&rid) {
                // the Aborted event flows back through the handle and is
                // forwarded upstream like any other stream event
                engine.cancel_request(eid);
            }
            Flow::Continue
        }
        ReplicaCmd::Load(adapter) => {
            let name = adapter.name.clone();
            let err = engine.load_adapter(&adapter).err().map(|e| format!("{e:#}"));
            if let Some(e) = &err {
                crate::log_warn!("replica", "[{index}] load {name:?} failed: {e}");
            }
            let _ = events.send(ReplicaEvent::LoadDone { replica: index, adapter: name, err });
            Flow::Continue
        }
        ReplicaCmd::Evict(name) => {
            let err = engine.evict_adapter(&name).err().map(|e| format!("{e:#}"));
            let _ = events.send(ReplicaEvent::EvictDone { replica: index, adapter: name, err });
            Flow::Continue
        }
        ReplicaCmd::EnableTrace => {
            engine.enable_trace();
            Flow::Continue
        }
        ReplicaCmd::Finish { since } => Flow::Finish(since),
        ReplicaCmd::Die => Flow::Die,
    }
}

/// How often an idle replica wakes up just to restamp its heartbeat.
/// Far below any sane `suspect_after`, so an idle replica never looks
/// suspect; cheap (a handful of atomic stores per wakeup).
const IDLE_HEARTBEAT: Duration = Duration::from_millis(50);

fn replica_main(
    index: usize,
    build: Box<dyn FnOnce() -> Result<Engine> + Send>,
    cmds: Receiver<ReplicaCmd>,
    events: Sender<ReplicaEvent>,
    gauges: Arc<ReplicaGauges>,
    epoch: Instant,
) {
    let mut engine = match build() {
        Ok(e) => {
            let _ = events.send(ReplicaEvent::Ready {
                replica: index,
                err: None,
                obs: Some(e.obs()),
                flightrec: Some(e.flight_recorder()),
            });
            e
        }
        Err(e) => {
            let _ = events.send(ReplicaEvent::Ready {
                replica: index,
                err: Some(format!("{e:#}")),
                obs: None,
                flightrec: None,
            });
            return;
        }
    };
    publish(&engine, &gauges, epoch);
    let mut streams = Streams::default();

    let mut finishing: Option<Instant> = None;
    'serve: while finishing.is_none() {
        if engine.has_work() {
            // busy: absorb whatever commands are already queued, then step
            loop {
                match cmds.try_recv() {
                    Ok(cmd) => match handle_cmd(index, &mut engine, &mut streams, &events, cmd) {
                        Flow::Continue => {}
                        Flow::Finish(since) => {
                            finishing = Some(since);
                            break;
                        }
                        Flow::Die => {
                            let _ = events.send(ReplicaEvent::Fatal {
                                replica: index,
                                err: "killed by fault injection (kill-replica)".to_string(),
                            });
                            return;
                        }
                    },
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => break 'serve,
                }
            }
            if finishing.is_none() {
                if let Err(e) = engine.step() {
                    let _ = events.send(ReplicaEvent::Fatal {
                        replica: index,
                        err: format!("{e:#}"),
                    });
                    return;
                }
            }
        } else {
            // idle: wait for the coordinator, waking periodically so the
            // heartbeat below keeps getting restamped
            match cmds.recv_timeout(IDLE_HEARTBEAT) {
                Ok(cmd) => match handle_cmd(index, &mut engine, &mut streams, &events, cmd) {
                    Flow::Continue => {}
                    Flow::Finish(since) => finishing = Some(since),
                    Flow::Die => {
                        let _ = events.send(ReplicaEvent::Fatal {
                            replica: index,
                            err: "killed by fault injection (kill-replica)".to_string(),
                        });
                        return;
                    }
                },
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => break 'serve,
            }
        }
        streams.forward(index, &events);
        publish(&engine, &gauges, epoch);
    }

    if let Some(since) = finishing {
        // drain everything still queued, then report
        if let Err(e) = engine.drain_requests() {
            let _ = events.send(ReplicaEvent::Fatal { replica: index, err: format!("{e:#}") });
            return;
        }
        streams.forward(index, &events);
        publish(&engine, &gauges, epoch);
        engine.metrics.set_wall(since.elapsed());
        let report = engine.report();
        let trace = engine.take_trace();
        let _ = events.send(ReplicaEvent::Finished { replica: index, report, trace });
    }
}
