//! Routing policies: pure decision functions over per-replica snapshots.
//!
//! The coordinator assembles a [`ReplicaView`] per replica (its own
//! in-flight bookkeeping + the replica-published gauges) and asks
//! [`choose`] for a placement. Keeping this free of channels and threads
//! makes every policy unit-testable. When fleet tracing is on, the
//! coordinator records the full scored candidate set (one
//! [`crate::obs::trace::Candidate`] per view) plus the chosen replica
//! into the routing-decision span, so a Perfetto timeline shows not just
//! *where* a request went but what the alternatives looked like at that
//! instant.

use anyhow::{bail, Result};
use std::time::Duration;

/// Fleet request-routing policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutingPolicy {
    /// Cycle through replicas regardless of load or residency. The
    /// classic stateless baseline; under adapter skew it forces constant
    /// load-on-miss churn.
    RoundRobin,
    /// Least outstanding requests (ties: most free KV slots, then lowest
    /// index). Balances load but is adapter-blind, so cold replicas
    /// still pay adapter swaps.
    JoinShortestQueue,
    /// Prefer replicas where the request's adapter is already resident,
    /// scored by queue depth then free KV slots; fall back to the least
    /// loaded replica that *can* host it (free slot or idle LRU victim).
    AdapterAffinity,
    /// Deadline-first: prefer replicas whose expected queue wait
    /// ([`ReplicaView::expected_wait`] — published decode-step EWMA ×
    /// in-flight count) fits the request's deadline, resident copies
    /// first within the fitting set. When no replica can meet the
    /// deadline the request is refused with
    /// [`RouteError::DeadlineUnmeetable`] instead of being placed to
    /// expire in a queue. Requests without a deadline are routed by
    /// least expected wait (queue depth is only the tie-break), which
    /// distinguishes a slow-but-short queue from a fast one where
    /// [`RoutingPolicy::JoinShortestQueue`] cannot.
    DeadlineAware,
}

impl RoutingPolicy {
    pub fn parse(s: &str) -> Result<RoutingPolicy> {
        Ok(match s {
            "rr" | "round-robin" => RoutingPolicy::RoundRobin,
            "jsq" | "shortest-queue" => RoutingPolicy::JoinShortestQueue,
            "affinity" | "adapter-affinity" => RoutingPolicy::AdapterAffinity,
            "deadline" | "deadline-aware" => RoutingPolicy::DeadlineAware,
            other => bail!("unknown routing policy {other:?} (rr|jsq|affinity|deadline)"),
        })
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            RoutingPolicy::RoundRobin => "round-robin",
            RoutingPolicy::JoinShortestQueue => "shortest-queue",
            RoutingPolicy::AdapterAffinity => "adapter-affinity",
            RoutingPolicy::DeadlineAware => "deadline-aware",
        }
    }
}

impl std::fmt::Display for RoutingPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Snapshot of one replica at decision time.
#[derive(Debug, Clone, Copy)]
pub struct ReplicaView {
    pub index: usize,
    /// Requests routed there and not yet completed (coordinator's own
    /// count — exact, unlike the asynchronously published gauges).
    pub inflight: usize,
    /// Free KV token slots, as last published by the replica thread.
    pub kv_free: usize,
    /// Expected queue wait in seconds: the replica's published
    /// decode-step EWMA × `inflight`. `0.0` when the replica is idle or
    /// has no estimate yet (optimistic: an unknown replica is assumed
    /// fast rather than rejected blind).
    ///
    /// Deliberately conservative: it models in-flight work as served
    /// sequentially, while a continuous-batching replica advances up to
    /// `max_seqs` requests per step — so a deeply batched replica's
    /// wait is overestimated by up to that factor and DeadlineAware may
    /// refuse a deadline the replica could have met. Erring toward
    /// refusal (the client learns immediately) beats admitting a
    /// request that expires in the queue; ROADMAP tracks the
    /// service-rate model that sharpens this.
    pub expected_wait: f64,
    /// The request's adapter is resident (always true for base-model
    /// requests).
    pub resident: bool,
    /// A load-on-miss could succeed: free adapter slot, or an idle
    /// resident to evict (always true for base-model requests).
    pub can_host: bool,
}

/// Where a request was placed and whether its adapter was already there.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RouteDecision {
    pub replica: usize,
    pub resident: bool,
}

/// Why no replica was chosen.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouteError {
    /// Every permissible target is unable to serve the request (the
    /// caller sheds it).
    NoCapacity,
    /// [`RoutingPolicy::DeadlineAware`] only: some replica could serve
    /// the request, but none can meet its deadline (the caller rejects
    /// it with [`crate::serving::SubmitError::DeadlineUnmeetable`]).
    DeadlineUnmeetable,
}

/// Lower is better: queue depth first, then KV pressure, then index for
/// determinism.
fn score(v: &ReplicaView) -> (usize, usize, usize) {
    (v.inflight, usize::MAX - v.kv_free, v.index)
}

/// Lower is better: expected wait first (total order: NaN never occurs —
/// waits are products of finite non-negative gauges), then [`score`].
fn wait_then_score(a: &&ReplicaView, b: &&ReplicaView) -> std::cmp::Ordering {
    a.expected_wait
        .partial_cmp(&b.expected_wait)
        .unwrap_or(std::cmp::Ordering::Equal)
        .then_with(|| score(a).cmp(&score(b)))
}

/// Pick a replica for one request, or a typed [`RouteError`] when no
/// permissible target works.
///
/// `deadline` is consulted only by [`RoutingPolicy::DeadlineAware`];
/// `rr_next` is the round-robin wheel — it advances exactly once per
/// RoundRobin decision and is untouched by the other policies.
pub fn choose(
    policy: RoutingPolicy,
    views: &[ReplicaView],
    deadline: Option<Duration>,
    rr_next: &mut usize,
) -> Result<RouteDecision, RouteError> {
    if views.is_empty() {
        return Err(RouteError::NoCapacity);
    }
    let serveable = |v: &ReplicaView| v.resident || v.can_host;
    let decision = |v: &ReplicaView| RouteDecision { replica: v.index, resident: v.resident };
    match policy {
        RoutingPolicy::RoundRobin => {
            let v = &views[*rr_next % views.len()];
            *rr_next = rr_next.wrapping_add(1);
            serveable(v).then(|| decision(v)).ok_or(RouteError::NoCapacity)
        }
        RoutingPolicy::JoinShortestQueue => {
            let v = views.iter().min_by_key(|v| score(v)).ok_or(RouteError::NoCapacity)?;
            serveable(v).then(|| decision(v)).ok_or(RouteError::NoCapacity)
        }
        RoutingPolicy::AdapterAffinity => {
            if let Some(v) = views.iter().filter(|v| v.resident).min_by_key(|v| score(v)) {
                return Ok(RouteDecision { replica: v.index, resident: true });
            }
            views
                .iter()
                .filter(|v| v.can_host)
                .min_by_key(|v| score(v))
                .map(|v| RouteDecision { replica: v.index, resident: false })
                .ok_or(RouteError::NoCapacity)
        }
        RoutingPolicy::DeadlineAware => {
            if !views.iter().any(serveable) {
                return Err(RouteError::NoCapacity);
            }
            let fits =
                |v: &&ReplicaView| deadline.map_or(true, |d| v.expected_wait < d.as_secs_f64());
            // resident copies first within the fitting set (keeps the
            // affinity win), then any hostable fit; least expected wait
            // decides within each tier
            if let Some(v) = views
                .iter()
                .filter(|v| v.resident)
                .filter(&fits)
                .min_by(wait_then_score)
            {
                return Ok(decision(v));
            }
            views
                .iter()
                .filter(|v| v.can_host)
                .filter(&fits)
                .min_by(wait_then_score)
                .map(decision)
                .ok_or(RouteError::DeadlineUnmeetable)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn view(index: usize, inflight: usize, resident: bool) -> ReplicaView {
        ReplicaView {
            index,
            inflight,
            kv_free: 1000,
            expected_wait: 0.0,
            resident,
            can_host: true,
        }
    }

    #[test]
    fn policy_parse_roundtrip() {
        for p in [
            RoutingPolicy::RoundRobin,
            RoutingPolicy::JoinShortestQueue,
            RoutingPolicy::AdapterAffinity,
            RoutingPolicy::DeadlineAware,
        ] {
            assert_eq!(RoutingPolicy::parse(p.as_str()).unwrap(), p);
        }
        assert_eq!(RoutingPolicy::parse("rr").unwrap(), RoutingPolicy::RoundRobin);
        assert_eq!(
            RoutingPolicy::parse("deadline").unwrap(),
            RoutingPolicy::DeadlineAware
        );
        assert!(RoutingPolicy::parse("nope").is_err());
    }

    #[test]
    fn round_robin_cycles_and_sheds_unhostable() {
        let mut rr = 0;
        let views = vec![view(0, 9, false), view(1, 0, true), view(2, 3, false)];
        let picks: Vec<usize> = (0..6)
            .map(|_| {
                choose(RoutingPolicy::RoundRobin, &views, None, &mut rr)
                    .unwrap()
                    .replica
            })
            .collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
        // a replica that can neither serve nor host sheds, but the wheel
        // still advances past it
        let mut blocked = views.clone();
        blocked[0].can_host = false;
        let mut rr = 0;
        assert_eq!(
            choose(RoutingPolicy::RoundRobin, &blocked, None, &mut rr),
            Err(RouteError::NoCapacity)
        );
        assert_eq!(
            choose(RoutingPolicy::RoundRobin, &blocked, None, &mut rr)
                .unwrap()
                .replica,
            1
        );
    }

    #[test]
    fn jsq_picks_least_loaded_ignoring_residency() {
        let mut rr = 0;
        let views = vec![view(0, 5, true), view(1, 2, false), view(2, 7, true)];
        let d = choose(RoutingPolicy::JoinShortestQueue, &views, None, &mut rr).unwrap();
        assert_eq!(d.replica, 1);
        assert!(!d.resident);
        assert_eq!(rr, 0, "jsq must not advance the rr wheel");
    }

    #[test]
    fn jsq_breaks_ties_by_kv_free() {
        let mut rr = 0;
        let mut views = vec![view(0, 2, true), view(1, 2, true)];
        views[1].kv_free = 2000;
        let d = choose(RoutingPolicy::JoinShortestQueue, &views, None, &mut rr).unwrap();
        assert_eq!(d.replica, 1);
    }

    #[test]
    fn affinity_prefers_resident_even_when_busier() {
        let mut rr = 0;
        let views = vec![view(0, 4, true), view(1, 0, false), view(2, 2, true)];
        let d = choose(RoutingPolicy::AdapterAffinity, &views, None, &mut rr).unwrap();
        assert_eq!(d.replica, 2, "least-loaded resident wins");
        assert!(d.resident);
    }

    #[test]
    fn affinity_falls_back_to_hostable_then_sheds() {
        let mut rr = 0;
        let mut views = vec![view(0, 4, false), view(1, 1, false)];
        let d = choose(RoutingPolicy::AdapterAffinity, &views, None, &mut rr).unwrap();
        assert_eq!(d, RouteDecision { replica: 1, resident: false });
        views[0].can_host = false;
        views[1].can_host = false;
        assert_eq!(
            choose(RoutingPolicy::AdapterAffinity, &views, None, &mut rr),
            Err(RouteError::NoCapacity)
        );
    }

    /// The checklist scenario: replica A is busy in the EWMA sense (its
    /// decode steps are slow, so its expected wait is long) while
    /// replica B is effectively idle — but both carry the *same*
    /// in-flight count, so queue depth alone cannot tell them apart.
    /// JSQ ties on inflight and kv_free and falls back to the lowest
    /// index (A); DeadlineAware reads the expected wait and routes to B.
    #[test]
    fn deadline_aware_routes_by_expected_wait_where_jsq_cannot() {
        let mut rr = 0;
        let mut views = vec![view(0, 1, true), view(1, 1, true)];
        views[0].expected_wait = 0.250; // slow replica: 250 ms expected
        views[1].expected_wait = 0.002;
        let jsq = choose(RoutingPolicy::JoinShortestQueue, &views, None, &mut rr).unwrap();
        assert_eq!(jsq.replica, 0, "queue depth alone cannot distinguish");
        let d = choose(
            RoutingPolicy::DeadlineAware,
            &views,
            Some(Duration::from_millis(100)),
            &mut rr,
        )
        .unwrap();
        assert_eq!(d.replica, 1, "deadline-aware must route around the slow replica");
        // without a deadline it still prefers the shorter expected wait
        let d = choose(RoutingPolicy::DeadlineAware, &views, None, &mut rr).unwrap();
        assert_eq!(d.replica, 1);
        assert_eq!(rr, 0, "deadline-aware must not advance the rr wheel");
    }

    #[test]
    fn deadline_aware_prefers_fitting_resident_over_faster_nonresident() {
        let mut rr = 0;
        let mut views = vec![view(0, 1, true), view(1, 0, false)];
        views[0].expected_wait = 0.010;
        views[1].expected_wait = 0.0;
        let d = choose(
            RoutingPolicy::DeadlineAware,
            &views,
            Some(Duration::from_millis(100)),
            &mut rr,
        )
        .unwrap();
        assert_eq!(d.replica, 0, "a resident copy that fits the deadline wins");
        assert!(d.resident);
        // ...but a resident copy that cannot fit loses to a hostable one
        let d = choose(
            RoutingPolicy::DeadlineAware,
            &views,
            Some(Duration::from_millis(5)),
            &mut rr,
        )
        .unwrap();
        assert_eq!(d.replica, 1);
        assert!(!d.resident);
    }

    #[test]
    fn deadline_aware_distinguishes_unmeetable_from_no_capacity() {
        let mut rr = 0;
        let mut views = vec![view(0, 3, true), view(1, 2, true)];
        views[0].expected_wait = 0.500;
        views[1].expected_wait = 0.300;
        // every replica could serve it, none can meet 100 ms
        assert_eq!(
            choose(
                RoutingPolicy::DeadlineAware,
                &views,
                Some(Duration::from_millis(100)),
                &mut rr,
            ),
            Err(RouteError::DeadlineUnmeetable)
        );
        // a generous deadline routes to the least expected wait
        let d = choose(
            RoutingPolicy::DeadlineAware,
            &views,
            Some(Duration::from_secs(5)),
            &mut rr,
        )
        .unwrap();
        assert_eq!(d.replica, 1);
        // nobody can even host it: that is NoCapacity, not a deadline
        // problem
        views[0].resident = false;
        views[0].can_host = false;
        views[1].resident = false;
        views[1].can_host = false;
        assert_eq!(
            choose(
                RoutingPolicy::DeadlineAware,
                &views,
                Some(Duration::from_millis(100)),
                &mut rr,
            ),
            Err(RouteError::NoCapacity)
        );
    }
}
