//! Routing policies: pure decision functions over per-replica snapshots.
//!
//! The coordinator assembles a [`ReplicaView`] per replica (its own
//! in-flight bookkeeping + the replica-published KV gauge) and asks
//! [`choose`] for a placement. Keeping this free of channels and threads
//! makes every policy unit-testable.

use anyhow::{bail, Result};

/// Fleet request-routing policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutingPolicy {
    /// Cycle through replicas regardless of load or residency. The
    /// classic stateless baseline; under adapter skew it forces constant
    /// load-on-miss churn.
    RoundRobin,
    /// Least outstanding requests (ties: most free KV slots, then lowest
    /// index). Balances load but is adapter-blind, so cold replicas
    /// still pay adapter swaps.
    JoinShortestQueue,
    /// Prefer replicas where the request's adapter is already resident,
    /// scored by queue depth then free KV slots; fall back to the least
    /// loaded replica that *can* host it (free slot or idle LRU victim).
    AdapterAffinity,
}

impl RoutingPolicy {
    pub fn parse(s: &str) -> Result<RoutingPolicy> {
        Ok(match s {
            "rr" | "round-robin" => RoutingPolicy::RoundRobin,
            "jsq" | "shortest-queue" => RoutingPolicy::JoinShortestQueue,
            "affinity" | "adapter-affinity" => RoutingPolicy::AdapterAffinity,
            other => bail!("unknown routing policy {other:?} (rr|jsq|affinity)"),
        })
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            RoutingPolicy::RoundRobin => "round-robin",
            RoutingPolicy::JoinShortestQueue => "shortest-queue",
            RoutingPolicy::AdapterAffinity => "adapter-affinity",
        }
    }
}

impl std::fmt::Display for RoutingPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Snapshot of one replica at decision time.
#[derive(Debug, Clone, Copy)]
pub struct ReplicaView {
    pub index: usize,
    /// Requests routed there and not yet completed (coordinator's own
    /// count — exact, unlike the asynchronously published gauges).
    pub inflight: usize,
    /// Free KV token slots, as last published by the replica thread.
    pub kv_free: usize,
    /// The request's adapter is resident (always true for base-model
    /// requests).
    pub resident: bool,
    /// A load-on-miss could succeed: free adapter slot, or an idle
    /// resident to evict (always true for base-model requests).
    pub can_host: bool,
}

/// Where a request was placed and whether its adapter was already there.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RouteDecision {
    pub replica: usize,
    pub resident: bool,
}

/// Lower is better: queue depth first, then KV pressure, then index for
/// determinism.
fn score(v: &ReplicaView) -> (usize, usize, usize) {
    (v.inflight, usize::MAX - v.kv_free, v.index)
}

/// Pick a replica for one request, or `None` when every permissible
/// target would be unable to serve it (the caller sheds the request).
///
/// `rr_next` is the round-robin wheel; it advances exactly once per
/// RoundRobin decision and is untouched by the other policies.
pub fn choose(
    policy: RoutingPolicy,
    views: &[ReplicaView],
    rr_next: &mut usize,
) -> Option<RouteDecision> {
    if views.is_empty() {
        return None;
    }
    let serveable = |v: &ReplicaView| v.resident || v.can_host;
    match policy {
        RoutingPolicy::RoundRobin => {
            let v = &views[*rr_next % views.len()];
            *rr_next = rr_next.wrapping_add(1);
            serveable(v).then(|| RouteDecision { replica: v.index, resident: v.resident })
        }
        RoutingPolicy::JoinShortestQueue => {
            let v = views.iter().min_by_key(|v| score(v))?;
            serveable(v).then(|| RouteDecision { replica: v.index, resident: v.resident })
        }
        RoutingPolicy::AdapterAffinity => {
            if let Some(v) = views.iter().filter(|v| v.resident).min_by_key(|v| score(v)) {
                return Some(RouteDecision { replica: v.index, resident: true });
            }
            views
                .iter()
                .filter(|v| v.can_host)
                .min_by_key(|v| score(v))
                .map(|v| RouteDecision { replica: v.index, resident: false })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn view(index: usize, inflight: usize, resident: bool) -> ReplicaView {
        ReplicaView { index, inflight, kv_free: 1000, resident, can_host: true }
    }

    #[test]
    fn policy_parse_roundtrip() {
        for p in [
            RoutingPolicy::RoundRobin,
            RoutingPolicy::JoinShortestQueue,
            RoutingPolicy::AdapterAffinity,
        ] {
            assert_eq!(RoutingPolicy::parse(p.as_str()).unwrap(), p);
        }
        assert_eq!(RoutingPolicy::parse("rr").unwrap(), RoutingPolicy::RoundRobin);
        assert!(RoutingPolicy::parse("nope").is_err());
    }

    #[test]
    fn round_robin_cycles_and_sheds_unhostable() {
        let mut rr = 0;
        let views = vec![view(0, 9, false), view(1, 0, true), view(2, 3, false)];
        let picks: Vec<usize> = (0..6)
            .map(|_| choose(RoutingPolicy::RoundRobin, &views, &mut rr).unwrap().replica)
            .collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
        // a replica that can neither serve nor host sheds, but the wheel
        // still advances past it
        let mut blocked = views.clone();
        blocked[0].can_host = false;
        let mut rr = 0;
        assert!(choose(RoutingPolicy::RoundRobin, &blocked, &mut rr).is_none());
        assert_eq!(
            choose(RoutingPolicy::RoundRobin, &blocked, &mut rr).unwrap().replica,
            1
        );
    }

    #[test]
    fn jsq_picks_least_loaded_ignoring_residency() {
        let mut rr = 0;
        let views = vec![view(0, 5, true), view(1, 2, false), view(2, 7, true)];
        let d = choose(RoutingPolicy::JoinShortestQueue, &views, &mut rr).unwrap();
        assert_eq!(d.replica, 1);
        assert!(!d.resident);
        assert_eq!(rr, 0, "jsq must not advance the rr wheel");
    }

    #[test]
    fn jsq_breaks_ties_by_kv_free() {
        let mut rr = 0;
        let mut views = vec![view(0, 2, true), view(1, 2, true)];
        views[1].kv_free = 2000;
        let d = choose(RoutingPolicy::JoinShortestQueue, &views, &mut rr).unwrap();
        assert_eq!(d.replica, 1);
    }

    #[test]
    fn affinity_prefers_resident_even_when_busier() {
        let mut rr = 0;
        let views = vec![view(0, 4, true), view(1, 0, false), view(2, 2, true)];
        let d = choose(RoutingPolicy::AdapterAffinity, &views, &mut rr).unwrap();
        assert_eq!(d.replica, 2, "least-loaded resident wins");
        assert!(d.resident);
    }

    #[test]
    fn affinity_falls_back_to_hostable_then_sheds() {
        let mut rr = 0;
        let mut views = vec![view(0, 4, false), view(1, 1, false)];
        let d = choose(RoutingPolicy::AdapterAffinity, &views, &mut rr).unwrap();
        assert_eq!(d, RouteDecision { replica: 1, resident: false });
        views[0].can_host = false;
        views[1].can_host = false;
        assert!(choose(RoutingPolicy::AdapterAffinity, &views, &mut rr).is_none());
    }
}
