//! The serving engine: one deployment of the model on one (simulated)
//! device, tying together an execution backend, the weight store +
//! adapter registry, the continuous-batching scheduler, the KV cache and
//! the sampler.
//!
//! Deployment flavours mirror the paper's systems under test:
//! * [`Engine::new_weave`] — **ExpertWeave**: shared base model +
//!   N adapters through the virtual weight tensor and batched rerouting
//!   (or the SingleOp rerouting baseline, or the Padding store baseline).
//! * [`Engine::new_base_only`] — *vLLM-Ascend (Base-Only)*.
//! * [`Engine::new_merged`] — *vLLM-Ascend (Merged)*: one engine instance
//!   per adapter, serving its merged checkpoint in isolation.
//!
//! Each flavour also has a `sim_*` constructor that runs on the
//! [`SimRuntime`] backend instead of PJRT — same scheduler, weight
//! store, registry and metrics, but no AOT artifacts required. The fleet
//! [`crate::coordinator`] and artifact-free tests/benches use these.

use crate::adapters::format::Adapter;
use crate::adapters::registry::AdapterRegistry;
use crate::kvcache::PagedKvCache;
use crate::memsim::DeviceMemory;
use crate::metrics::{MetricsCollector, Report, RequestRecord};
use crate::model::ModelConfig;
use crate::obs::flightrec::{EventKind, FlightRecorder};
use crate::obs::trace::{RequestSpan, TraceLog};
use crate::obs::{ObsRegistry, StatsSnapshot};
use crate::runtime::{
    ArtifactSet, ParamSource, Runtime, SimPerf, SimRuntime, StepInputs, StepOutput, StepYield,
    Variant,
};
use crate::sampler::{FinishReason, SamplingParams};
use crate::scheduler::{SchedConfig, Scheduler, SeqState, StepWorkspace};
use crate::serving::{
    AbortReason, RequestHandle, RequestId, ServeRequest, ServingBackend, SubmitError, TokenEvent,
};
use crate::util::rng::Pcg;
use crate::vmm::page_pool::PagePool;
use crate::weights::{
    BaseOnlyParams, BaseWeights, MergedParams, StoreMode, StoreParams, WeightStore,
};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::sync::mpsc::Sender;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// A request as submitted by clients / the trace replayer.
#[derive(Debug, Clone)]
pub struct RequestSpec {
    /// Adapter name; `None` = base model.
    pub adapter: Option<String>,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    pub sampling: SamplingParams,
}

/// Completed request (tokens + latency record).
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: u64,
    pub adapter: Option<String>,
    pub output: Vec<i32>,
    /// Why generation ended: `Length` (token budget) or `Stop` (stop
    /// sequence / stop token matched). Carried on the NDJSON `done`
    /// frame as `finish`.
    pub finish: FinishReason,
    pub record: RequestRecord,
}

/// Smoothed step wall-time estimates (seconds), split by step shape.
/// `0.0` means no step of that shape has been observed yet. The fleet
/// coordinator publishes these per replica and routes deadline-bound
/// requests by the decode estimate (see
/// [`crate::coordinator::RoutingPolicy::DeadlineAware`]).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct StepEwma {
    /// EWMA over steps that fed at least one prefill token.
    pub prefill: f64,
    /// EWMA over pure decode steps (the steady-state service rate).
    pub decode: f64,
}

impl StepEwma {
    /// The decode estimate, falling back to the prefill estimate when no
    /// decode step has been observed yet; `0.0` with no estimate at all.
    pub fn decode_or_any(&self) -> f64 {
        if self.decode > 0.0 {
            self.decode
        } else {
            self.prefill
        }
    }
}

/// Engine tuning knobs beyond the artifact config.
#[derive(Debug, Clone)]
pub struct EngineOptions {
    /// Chunked-prefill budget per sequence per step.
    pub chunk: usize,
    /// Cap on concurrent sequences (≤ artifact max_seqs).
    pub max_seqs: usize,
    /// Seed for sampling.
    pub seed: u64,
    /// Physical page size for the weight store.
    pub page_size: usize,
    /// Simulated device capacity in bytes (weights ledger).
    pub device_capacity: usize,
    /// Fraction of the testbed's compute this deployment owns (1.0 =
    /// whole machine). Emulates per-instance device partitioning on the
    /// single-core testbed: after each step the engine idles
    /// `elapsed * (1/share - 1)`, so an instance pinned to half the
    /// devices runs at half speed even when its neighbours are idle
    /// (the Fig. 6 merged-deployment setup; see DESIGN.md section 7).
    pub compute_share: f64,
    /// Admission-queue bound: submits beyond this many *waiting*
    /// requests fail with [`SubmitError::QueueFull`]. 0 = unbounded.
    pub queue_cap: usize,
    /// Sim backend only: always materialize the full logits tensor
    /// instead of taking the greedy-token fast path (accuracy-style
    /// experiments; see [`SimRuntime::set_full_logits`]).
    pub sim_full_logits: bool,
    /// Sim backend only: deterministically fail the engine after this
    /// many device steps (0 = never) — the chaos-testing replica-death
    /// hook (see [`SimRuntime::fail_after_steps`]).
    pub sim_fail_after: usize,
    /// Tokens per physical KV page of the paged cache. `kv_cap` slots
    /// that don't fill a whole page are unaddressable (pick a divisor).
    pub kv_block: usize,
    /// Cross-request prefix sharing in the KV cache. Off restores flat
    /// private-slot semantics (every prompt pays full physical KV).
    pub kv_share: bool,
}

impl Default for EngineOptions {
    fn default() -> Self {
        EngineOptions {
            chunk: 256,
            max_seqs: usize::MAX,
            seed: 0,
            page_size: 2 << 20,
            device_capacity: usize::MAX / 2,
            compute_share: 1.0,
            queue_cap: 0,
            sim_full_logits: false,
            sim_fail_after: 0,
            kv_block: 16,
            kv_share: true,
        }
    }
}

enum Weights {
    Weave { store: WeightStore, registry: AdapterRegistry },
    BaseOnly,
    Merged { adapter: Adapter },
}

/// Execution backend: the real PJRT runtime over AOT artifacts, or the
/// wall-clock-calibrated simulation. Both honour the same step ABI.
enum Backend {
    Pjrt(Runtime),
    Sim(SimRuntime),
}

impl Backend {
    fn variant(&self) -> Variant {
        match self {
            Backend::Pjrt(r) => r.variant(),
            Backend::Sim(s) => s.variant(),
        }
    }

    fn upload_params<S: ParamSource>(&mut self, source: &mut S, version: u64) -> Result<()> {
        match self {
            Backend::Pjrt(r) => r.upload_params(source, version),
            Backend::Sim(s) => s.upload_params(source, version),
        }
    }

    fn upload_expert_maps(&mut self, maps: &[i32], version: u64) -> Result<()> {
        match self {
            Backend::Pjrt(r) => r.upload_expert_maps(maps, version),
            Backend::Sim(s) => s.upload_expert_maps(maps, version),
        }
    }

    /// Hot-path step into the engine-owned output buffer. `live_rows` is
    /// the number of rows the engine will sample; `want_tokens` signals
    /// that every live row is greedy (the sim backend may then skip
    /// logits entirely).
    fn step_into(
        &mut self,
        bucket: usize,
        inputs: &StepInputs,
        live_rows: usize,
        want_tokens: bool,
        out: &mut StepOutput,
    ) -> Result<()> {
        match self {
            Backend::Pjrt(r) => r.step_into(bucket, inputs, live_rows, want_tokens, out),
            Backend::Sim(s) => s.step_into(bucket, inputs, live_rows, want_tokens, out),
        }
    }

    fn reset_kv(&mut self) {
        match self {
            Backend::Pjrt(r) => r.reset_kv(),
            Backend::Sim(s) => s.reset_kv(),
        }
    }
}

/// One model deployment.
pub struct Engine {
    cfg: ModelConfig,
    backend: Backend,
    base: BaseWeights,
    weights: Weights,
    scheduler: Scheduler,
    kv: PagedKvCache,
    /// Paged-cache construction knobs, kept for session reset.
    kv_block: usize,
    kv_share: bool,
    /// High-water marks of the paged cache's cumulative counters already
    /// published to `obs` (the cache keeps totals; obs wants per-step
    /// deltas so fleet merges stay associative).
    kv_hits_seen: u64,
    kv_misses_seen: u64,
    kv_cow_seen: u64,
    /// Persistent step buffers: batch tensors (incl. the authoritative
    /// per-slot cache metadata) refilled in place every step.
    ws: StepWorkspace,
    /// Persistent step output buffer (logits or greedy tokens).
    step_out: StepOutput,
    pub metrics: MetricsCollector,
    /// Live telemetry: lock-free counters/histograms recorded from the
    /// step loop, shared (`Arc`) with the scrape surfaces — the NDJSON
    /// `stats` frame, the Prometheus listener and the fleet heartbeat.
    obs: Arc<ObsRegistry>,
    /// Opt-in per-request phase tracing ([`Engine::enable_trace`];
    /// exported as Chrome-trace JSON by [`Engine::write_trace`]). Spans
    /// are recorded only at completion/abort, never per step.
    trace: Option<TraceLog>,
    /// Always-on black-box flight recorder: bounded ring of recent
    /// request/step events, recorded allocation-free from the step loop
    /// and shared (`Arc`) with dump surfaces (the NDJSON `flightrec`
    /// frame, the coordinator's abort path).
    flightrec: Arc<FlightRecorder>,
    /// When this engine was built — the time origin of both the trace
    /// log and the flight recorder, so stamps taken before
    /// [`Engine::enable_trace`] keep their real offsets.
    constructed: Instant,
    rng: Pcg,
    next_seq: u64,
    /// EWMA of recent step wall time (seconds), split by step shape:
    /// steps that fed any prefill tokens update `ewma_prefill`, pure
    /// decode steps update `ewma_decode`. Both are 0 until observed.
    /// The split matters for deadline-aware admission and fleet routing:
    /// a heavy-prefill burst inflates only the prefill estimate, so
    /// borderline decode deadlines are no longer over-rejected for the
    /// steps it takes a unified EWMA to re-converge after a phase
    /// change.
    ewma_prefill: f64,
    ewma_decode: f64,
    weights_version: u64,
    device: Arc<Mutex<DeviceMemory>>,
    compute_share: f64,
    queue_cap: usize,
    /// Per-request token-event subscribers ([`ServingBackend::submit`]).
    streams: HashMap<RequestId, Sender<TokenEvent>>,
    /// Requests finished at the door (total-length cap already exhausted
    /// by the prompt): their `Done` event is sent at submit, and the
    /// completions surface through the next [`Engine::step`] so
    /// `run_to_completion` callers observe them too.
    instant_done: Vec<Completion>,
    /// Draining: every new submit fails with `ShuttingDown`.
    shutting_down: bool,
    /// Any in-flight request carries a deadline (skips the per-step
    /// expiry scan on the deadline-free replay hot path).
    has_deadlines: bool,
}

impl Engine {
    fn sched_config(cfg: &ModelConfig, opts: &EngineOptions) -> SchedConfig {
        SchedConfig {
            max_seqs: cfg.max_seqs.min(opts.max_seqs),
            // out_rows length is part of the step ABI: always the
            // config's max_seqs, even when admission is capped lower
            abi_max_seqs: cfg.max_seqs,
            chunk: opts.chunk.min(*cfg.buckets.last().unwrap()),
            buckets: cfg.buckets.clone(),
            kv_cap: cfg.kv_cap,
        }
    }

    /// Common tail of every constructor: scheduler/KV/metrics plumbing
    /// around an already-built backend + weight state.
    fn assemble(
        cfg: ModelConfig,
        backend: Backend,
        base: BaseWeights,
        weights: Weights,
        device: Arc<Mutex<DeviceMemory>>,
        opts: &EngineOptions,
    ) -> Result<Engine> {
        let sched_cfg = Self::sched_config(&cfg, opts);
        let obs = Arc::new(ObsRegistry::new(cfg.max_adapters));
        let constructed = Instant::now();
        let mut engine = Engine {
            ws: StepWorkspace::new(&sched_cfg, cfg.vocab),
            scheduler: Scheduler::new(sched_cfg),
            kv: PagedKvCache::new(cfg.kv_cap, opts.kv_block, opts.kv_share),
            kv_block: opts.kv_block,
            kv_share: opts.kv_share,
            kv_hits_seen: 0,
            kv_misses_seen: 0,
            kv_cow_seen: 0,
            step_out: StepOutput::new(),
            metrics: MetricsCollector::new(),
            obs,
            trace: None,
            flightrec: Arc::new(FlightRecorder::with_origin(constructed)),
            constructed,
            rng: Pcg::with_stream(opts.seed, 555),
            next_seq: 1,
            ewma_prefill: 0.0,
            ewma_decode: 0.0,
            weights_version: 1,
            device,
            cfg,
            backend,
            base,
            compute_share: opts.compute_share.clamp(0.05, 1.0),
            queue_cap: opts.queue_cap,
            streams: HashMap::new(),
            instant_done: Vec::new(),
            shutting_down: false,
            has_deadlines: false,
            weights,
        };
        engine.sync_device_state()?;
        engine.sync_obs_labels();
        Ok(engine)
    }

    /// Mirror the adapter registry's slot → name layout into the obs
    /// registry's preallocated label slots (merged deployments attribute
    /// their base-slot traffic to the merged adapter's name).
    fn sync_obs_labels(&self) {
        match &self.weights {
            Weights::Weave { registry, .. } => {
                for r in registry.resident() {
                    self.obs.set_adapter_name(r.slot as i32, &r.name);
                }
            }
            Weights::Merged { adapter } => {
                self.obs.set_adapter_name(-1, &adapter.name);
            }
            Weights::BaseOnly => {}
        }
    }

    /// Build the weave-flavour weight state (store + registry, adapters
    /// preloaded) against a fresh page pool on `device`.
    fn weave_weights(
        cfg: &ModelConfig,
        base: &BaseWeights,
        adapters: &[Adapter],
        mode: StoreMode,
        device: &Arc<Mutex<DeviceMemory>>,
        opts: &EngineOptions,
    ) -> Result<Weights> {
        // pool sized to the device budget (pages are the real constraint)
        let pool_pages = (opts.device_capacity / opts.page_size).min(1 << 20);
        let pool = Arc::new(Mutex::new(PagePool::new(opts.page_size, pool_pages)?));
        let mut store = WeightStore::new(cfg, mode, pool, device.clone())?;
        store.load_base(base)?;
        let mut registry = AdapterRegistry::new(cfg);
        for a in adapters {
            registry.load(&mut store, a)?;
        }
        Ok(Weights::Weave { store, registry })
    }

    /// ExpertWeave deployment: shared base + adapters.
    ///
    /// `variant` selects the rerouting implementation
    /// ([`Variant::Weave`] fused kernel / [`Variant::SingleOp`]);
    /// `mode` selects the weight store ([`StoreMode::Virtual`] /
    /// [`StoreMode::Padding`] baseline).
    pub fn new_weave(
        set: &ArtifactSet,
        adapters: &[Adapter],
        variant: Variant,
        mode: StoreMode,
        opts: EngineOptions,
    ) -> Result<Engine> {
        if !variant.is_adapter_aware() {
            bail!("weave deployment needs an adapter-aware variant");
        }
        let cfg = set.config.clone();
        let backend = Backend::Pjrt(Runtime::new(set, variant)?);
        let base = BaseWeights::generate(&cfg, opts.seed);
        let device = DeviceMemory::shared(opts.device_capacity);
        let weights = Self::weave_weights(&cfg, &base, adapters, mode, &device, &opts)?;
        Self::assemble(cfg, backend, base, weights, device, &opts)
    }

    /// ExpertWeave deployment on the simulated backend (no artifacts).
    pub fn sim_weave(
        cfg: &ModelConfig,
        perf: SimPerf,
        adapters: &[Adapter],
        variant: Variant,
        mode: StoreMode,
        opts: EngineOptions,
    ) -> Result<Engine> {
        if !variant.is_adapter_aware() {
            bail!("weave deployment needs an adapter-aware variant");
        }
        let mut rt = SimRuntime::new(cfg, variant, perf, opts.seed)?;
        rt.set_full_logits(opts.sim_full_logits);
        rt.fail_after_steps(opts.sim_fail_after);
        let backend = Backend::Sim(rt);
        let base = BaseWeights::generate(cfg, opts.seed);
        let device = DeviceMemory::shared(opts.device_capacity);
        let weights = Self::weave_weights(cfg, &base, adapters, mode, &device, &opts)?;
        Self::assemble(cfg.clone(), backend, base, weights, device, &opts)
    }

    /// vLLM-Ascend (Base-Only) baseline.
    pub fn new_base_only(set: &ArtifactSet, opts: EngineOptions) -> Result<Engine> {
        let cfg = set.config.clone();
        let backend = Backend::Pjrt(Runtime::new(set, Variant::Base)?);
        let base = BaseWeights::generate(&cfg, opts.seed);
        let device = DeviceMemory::shared(opts.device_capacity);
        device
            .lock()
            .unwrap()
            .alloc(cfg.base_model_bytes())
            .context("base model exceeds device budget")?;
        Self::assemble(cfg, backend, base, Weights::BaseOnly, device, &opts)
    }

    /// Base-only baseline on the simulated backend.
    pub fn sim_base_only(cfg: &ModelConfig, perf: SimPerf, opts: EngineOptions) -> Result<Engine> {
        let mut rt = SimRuntime::new(cfg, Variant::Base, perf, opts.seed)?;
        rt.set_full_logits(opts.sim_full_logits);
        rt.fail_after_steps(opts.sim_fail_after);
        let backend = Backend::Sim(rt);
        let base = BaseWeights::generate(cfg, opts.seed);
        let device = DeviceMemory::shared(opts.device_capacity);
        device
            .lock()
            .unwrap()
            .alloc(cfg.base_model_bytes())
            .context("base model exceeds device budget")?;
        Self::assemble(cfg.clone(), backend, base, Weights::BaseOnly, device, &opts)
    }

    /// vLLM-Ascend (Merged) baseline: serves exactly one adapter's merged
    /// checkpoint.
    pub fn new_merged(set: &ArtifactSet, adapter: Adapter, opts: EngineOptions) -> Result<Engine> {
        let cfg = set.config.clone();
        let backend = Backend::Pjrt(Runtime::new(set, Variant::Base)?);
        let base = BaseWeights::generate(&cfg, opts.seed);
        let device = DeviceMemory::shared(opts.device_capacity);
        device
            .lock()
            .unwrap()
            .alloc(cfg.base_model_bytes())
            .context("merged model exceeds device budget")?;
        Self::assemble(cfg, backend, base, Weights::Merged { adapter }, device, &opts)
    }

    /// Merged baseline on the simulated backend.
    pub fn sim_merged(
        cfg: &ModelConfig,
        perf: SimPerf,
        adapter: Adapter,
        opts: EngineOptions,
    ) -> Result<Engine> {
        let mut rt = SimRuntime::new(cfg, Variant::Base, perf, opts.seed)?;
        rt.set_full_logits(opts.sim_full_logits);
        rt.fail_after_steps(opts.sim_fail_after);
        let backend = Backend::Sim(rt);
        let base = BaseWeights::generate(cfg, opts.seed);
        let device = DeviceMemory::shared(opts.device_capacity);
        device
            .lock()
            .unwrap()
            .alloc(cfg.base_model_bytes())
            .context("merged model exceeds device budget")?;
        Self::assemble(
            cfg.clone(),
            backend,
            base,
            Weights::Merged { adapter },
            device,
            &opts,
        )
    }

    /// Upload weights + expert maps if stale.
    fn sync_device_state(&mut self) -> Result<()> {
        match &self.weights {
            Weights::Weave { store, registry } => {
                let mut src = StoreParams::new(&self.base, store);
                self.backend.upload_params(&mut src, self.weights_version)?;
                self.backend
                    .upload_expert_maps(registry.maps().as_slice(), registry.maps_version())?;
            }
            Weights::BaseOnly => {
                let mut src = BaseOnlyParams { base: &self.base };
                self.backend.upload_params(&mut src, self.weights_version)?;
            }
            Weights::Merged { adapter } => {
                let mut src = MergedParams::new(&self.cfg, &self.base, adapter);
                self.backend.upload_params(&mut src, self.weights_version)?;
            }
        }
        Ok(())
    }

    pub fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    pub fn variant(&self) -> Variant {
        self.backend.variant()
    }

    pub fn device(&self) -> Arc<Mutex<DeviceMemory>> {
        self.device.clone()
    }

    pub fn kv_free_slots(&self) -> usize {
        self.kv.free_slots()
    }

    /// Is this a weave deployment (dynamic adapter lifecycle available)?
    pub fn is_weave(&self) -> bool {
        matches!(self.weights, Weights::Weave { .. })
    }

    /// Names of the adapters currently resident (weave: registry
    /// contents; merged: the single merged adapter; base-only: none).
    /// Borrows — no per-call allocation; collect if you need ownership.
    pub fn resident_adapters(&self) -> impl Iterator<Item = &str> + '_ {
        let weave = match &self.weights {
            Weights::Weave { registry, .. } => {
                Some(registry.resident().map(|r| r.name.as_str()))
            }
            _ => None,
        };
        let merged = match &self.weights {
            Weights::Merged { adapter } => Some(adapter.name.as_str()),
            _ => None,
        };
        weave.into_iter().flatten().chain(merged)
    }

    /// Can this engine serve `name` right now without a load?
    pub fn has_adapter(&self, name: &str) -> bool {
        match &self.weights {
            Weights::Weave { registry, .. } => registry.aid_of(name).is_some(),
            Weights::BaseOnly => false,
            Weights::Merged { adapter } => adapter.name == name,
        }
    }

    /// Adapter slot capacity of this deployment (N of the virtual weight
    /// tensor; 1 for merged, 0 for base-only).
    pub fn adapter_slots_total(&self) -> usize {
        match &self.weights {
            Weights::Weave { .. } => self.cfg.max_adapters,
            Weights::BaseOnly => 0,
            Weights::Merged { .. } => 1,
        }
    }

    /// Least-recently-used resident adapter (weave only).
    pub fn lru_adapter(&self) -> Option<String> {
        match &self.weights {
            Weights::Weave { registry, .. } => registry.lru_victim().map(|r| r.name.clone()),
            _ => None,
        }
    }

    /// Load another adapter at runtime (weave deployments only).
    pub fn load_adapter(&mut self, adapter: &Adapter) -> Result<usize> {
        let Weights::Weave { store, registry } = &mut self.weights else {
            bail!("adapter load on a non-weave deployment");
        };
        let slot = registry.load(store, adapter)?;
        self.weights_version += 1;
        self.obs.set_adapter_name(slot as i32, &adapter.name);
        self.sync_device_state()?;
        Ok(slot)
    }

    /// Evict an adapter at runtime (weave deployments only). Refused
    /// while the adapter still has queued or running requests — evicting
    /// live expert weights would corrupt in-flight decoding.
    pub fn evict_adapter(&mut self, name: &str) -> Result<()> {
        let in_flight = self.scheduler.adapter_work(name);
        if in_flight > 0 {
            bail!("cannot evict adapter {name:?}: {in_flight} request(s) in flight");
        }
        let Weights::Weave { store, registry } = &mut self.weights else {
            bail!("adapter evict on a non-weave deployment");
        };
        let slot = registry.evict(store, name)?;
        self.weights_version += 1;
        self.obs.clear_adapter_name(slot as i32);
        self.sync_device_state()
    }

    /// Stable ordinal of a typed rejection for the flight recorder's
    /// fixed-width event payload (one `u64` per event — no room for the
    /// error string itself).
    fn reject_ordinal(e: &SubmitError) -> u64 {
        match e {
            SubmitError::UnknownAdapter(_) => 0,
            SubmitError::QueueFull => 1,
            SubmitError::Shed => 2,
            SubmitError::ShuttingDown => 3,
            SubmitError::DeadlineUnmeetable => 4,
            SubmitError::Invalid(_) => 5,
        }
    }

    /// Submit a request (legacy convenience): the typed
    /// [`Engine::submit_request`] with the handle reduced to its id.
    /// Token events are discarded; completions are still returned by
    /// [`Engine::step`] / [`Engine::run_to_completion`].
    pub fn submit(&mut self, req: RequestSpec) -> Result<u64> {
        match self.submit_request(req.into()) {
            Ok(handle) => Ok(handle.id),
            Err(e) => Err(e.into()),
        }
    }

    /// Typed admission check; does not allocate an id or touch metrics.
    fn admit(&mut self, req: &ServeRequest) -> Result<i32, SubmitError> {
        if self.shutting_down {
            return Err(SubmitError::ShuttingDown);
        }
        if self.queue_cap > 0 && self.scheduler.waiting_len() >= self.queue_cap {
            return Err(SubmitError::QueueFull);
        }
        // deadline-aware admission: if the queue's expected wait (EWMA
        // step time × queue depth) already exceeds the request's
        // deadline, reject at the door instead of letting it expire in
        // the queue (it would never occupy a batch slot anyway). The
        // estimate is the *decode*-step EWMA (the steady-state service
        // rate), not the prefill one — a heavy-prefill burst inflates
        // only `ewma_prefill`, so borderline decode deadlines are not
        // over-rejected right after a phase change. An empty queue, or
        // an engine with no estimate yet, never rejects.
        if let Some(d) = req.deadline {
            let expected = self.queue_wait_estimate();
            if expected > d.as_secs_f64() {
                return Err(SubmitError::DeadlineUnmeetable);
            }
        }
        let aid = match (&mut self.weights, req.adapter.as_deref()) {
            (Weights::Weave { registry, .. }, name) => match registry.resolve(name) {
                Ok(aid) => aid,
                Err(_) => {
                    return Err(SubmitError::UnknownAdapter(
                        name.unwrap_or_default().to_string(),
                    ))
                }
            },
            (Weights::BaseOnly, None) => -1,
            (Weights::BaseOnly, Some(n)) => {
                return Err(SubmitError::UnknownAdapter(n.to_string()))
            }
            (Weights::Merged { adapter }, Some(n)) if n == adapter.name => -1,
            (Weights::Merged { .. }, None) => -1,
            (Weights::Merged { .. }, Some(n)) => {
                return Err(SubmitError::UnknownAdapter(n.to_string()))
            }
        };
        if req.prompt.is_empty() {
            return Err(SubmitError::Invalid("empty prompt".into()));
        }
        // capacity check against the paged cache's addressable slots
        // (page-granular: a kv_cap that doesn't divide into whole pages
        // strands the remainder) — an over-size request would otherwise
        // wait forever for blocks that can never exist
        let need = req.prompt.len() + req.max_new_tokens.max(1);
        if need > self.kv.capacity() {
            return Err(SubmitError::Invalid(format!(
                "request needs {need} KV slots (prompt {} + output {}), capacity is {}",
                req.prompt.len(),
                req.max_new_tokens.max(1),
                self.kv.capacity()
            )));
        }
        Ok(aid)
    }

    /// Submit through the serving API: typed errors, and a
    /// [`RequestHandle`] streaming [`TokenEvent`]s as the engine steps.
    /// Rejections are recorded in this engine's own metrics
    /// ([`crate::metrics::Report::rejected`]) — callers keep no separate
    /// rejection books.
    pub fn submit_request(
        &mut self,
        req: ServeRequest,
    ) -> Result<RequestHandle, SubmitError> {
        let aid = match self.admit(&req) {
            Ok(aid) => aid,
            Err(e) => {
                self.metrics.record_rejected();
                self.obs.record_rejected();
                self.flightrec.record(EventKind::Reject, 0, -1, Self::reject_ordinal(&e));
                return Err(e);
            }
        };
        self.obs.record_submitted(aid);
        let id = self.next_seq;
        self.next_seq += 1;
        self.flightrec.record(EventKind::Submit, id, aid, req.prompt.len() as u64);
        // Resolve sampling once at the door: clamp out-of-range knobs,
        // pin the seed (an explicit per-request seed makes the sampled
        // stream reproducible across backend modes and fleet replicas;
        // otherwise one is drawn here, off the step hot path), and fold
        // the optional total-length cap into max_new.
        let mut sampling = req.sampling;
        sampling.sanitize();
        if sampling.seed.is_none() {
            sampling.seed = Some(self.rng.next_u64());
        }
        let mut max_new = req.max_new_tokens.max(1);
        if sampling.max_len > 0 {
            // A total-length cap at or below the prompt leaves no token
            // budget at all: finish immediately with reason `length` and
            // empty output (no batch slot, no KV, no generated token).
            if sampling.max_len <= req.prompt.len() {
                return Ok(self.finish_at_door(
                    id,
                    aid,
                    req.adapter,
                    req.prompt,
                    req.trace,
                    sampling,
                ));
            }
            max_new = max_new.min(sampling.max_len - req.prompt.len());
        }
        let mut seq = SeqState::new(id, aid, req.adapter, req.prompt, max_new, sampling);
        seq.trace = req.trace.unwrap_or(0);
        if let Some(d) = req.deadline {
            seq.deadline = Some(Instant::now() + d);
            self.has_deadlines = true;
        }
        self.scheduler.submit(seq);
        let (handle, tx) = RequestHandle::new(id);
        self.streams.insert(id, tx);
        Ok(handle)
    }

    /// Complete an admitted request at the door with reason `length` and
    /// no output (its `max_len` cap is already exhausted by the prompt).
    /// Books the same completion records as a stepped request, sends the
    /// terminal `Done` on the returned handle immediately, and queues the
    /// completion for the next [`Engine::step`].
    fn finish_at_door(
        &mut self,
        id: u64,
        aid: i32,
        adapter: Option<String>,
        prompt: Vec<i32>,
        trace: Option<u64>,
        sampling: SamplingParams,
    ) -> RequestHandle {
        let now = Instant::now();
        let mut seq = SeqState::new(id, aid, adapter, prompt, 0, sampling);
        seq.trace = trace.unwrap_or(0);
        seq.finished_at = Some(now);
        self.obs.record_completed(aid, 0, 0);
        self.flightrec.record(EventKind::Done, id, aid, 0);
        self.trace_request(&seq, "done");
        let record = RequestRecord {
            id,
            adapter: seq.adapter.clone(),
            prompt_tokens: seq.prompt_len,
            output_tokens: 0,
            ttft: Duration::ZERO,
            tpot: None,
            e2e: now - seq.arrival,
        };
        self.metrics.complete_request(record.clone());
        let completion = Completion {
            id,
            adapter: seq.adapter,
            output: Vec::new(),
            finish: FinishReason::Length,
            record,
        };
        self.instant_done.push(completion.clone());
        let (handle, tx) = RequestHandle::new(id);
        let _ = tx.send(TokenEvent::Done { id, completion });
        handle
    }

    /// Cancel a queued or running request: its KV slots are freed
    /// immediately and its stream receives a terminal
    /// [`TokenEvent::Aborted`] (`Cancelled`). Returns `false` when the
    /// id is not in flight.
    pub fn cancel_request(&mut self, id: RequestId) -> bool {
        match self.scheduler.cancel(id, &mut self.kv, &mut self.ws) {
            Some(seq) => {
                self.metrics.record_aborted(false);
                self.obs.record_aborted(seq.aid);
                self.flightrec.record(EventKind::Abort, id, seq.aid, 0);
                self.trace_request(&seq, "cancelled");
                self.finish_stream(id, AbortReason::Cancelled);
                true
            }
            None => false,
        }
    }

    /// Fold a finished/aborted sequence's phase stamps into the trace
    /// log (no-op unless [`Engine::enable_trace`] was called).
    fn trace_request(&mut self, seq: &SeqState, outcome: &'static str) {
        let Some(trace) = self.trace.as_mut() else { return };
        let span = RequestSpan {
            id: seq.id,
            trace: seq.trace,
            pid: 1,
            adapter: seq.adapter.clone().unwrap_or_else(|| "base".into()),
            outcome,
            arrival_us: trace.rel_us(seq.arrival),
            admitted_us: seq.admitted_at.map(|t| trace.rel_us(t)),
            first_scheduled_us: seq.first_scheduled_at.map(|t| trace.rel_us(t)),
            prefill_done_us: seq.prefill_done_at.map(|t| trace.rel_us(t)),
            first_token_us: seq.first_token_at.map(|t| trace.rel_us(t)),
            finished_us: trace.rel_us(seq.finished_at.unwrap_or_else(Instant::now)),
        };
        trace.record(span);
    }

    /// Finish all queued and running work, then refuse new submits with
    /// [`SubmitError::ShuttingDown`].
    pub fn drain_requests(&mut self) -> Result<()> {
        self.shutting_down = true;
        while self.step()?.is_some() {}
        Ok(())
    }

    /// Send a terminal abort on a request's stream and drop it.
    fn finish_stream(&mut self, id: RequestId, reason: AbortReason) {
        if let Some(tx) = self.streams.remove(&id) {
            let _ = tx.send(TokenEvent::Aborted { id, reason });
        }
    }

    /// Expire deadline-passed requests (queued ones before they can
    /// occupy a batch slot; running ones free their KV).
    fn process_expiries(&mut self) {
        if !self.has_deadlines {
            return;
        }
        let expired = self.scheduler.expire_deadlines(
            Instant::now(),
            &mut self.kv,
            &mut self.ws,
        );
        for seq in expired {
            self.metrics.record_aborted(true);
            self.obs.record_aborted(seq.aid);
            self.flightrec.record(EventKind::Abort, seq.id, seq.aid, 1);
            self.trace_request(&seq, "deadline");
            self.finish_stream(seq.id, AbortReason::DeadlineExceeded);
        }
        // un-latch once no in-flight request carries a deadline, so the
        // deadline-free hot path stays scan-free on long-lived sessions
        self.has_deadlines = self.scheduler.deadline_work();
    }

    pub fn has_work(&self) -> bool {
        !self.scheduler.is_idle()
    }

    pub fn queue_depth(&self) -> (usize, usize) {
        (self.scheduler.waiting_len(), self.scheduler.running_len())
    }

    /// The engine's smoothed step-time estimates (prefill vs decode).
    pub fn step_ewma(&self) -> StepEwma {
        StepEwma { prefill: self.ewma_prefill, decode: self.ewma_decode }
    }

    /// Expected wait (seconds) of a newly queued request before it can
    /// occupy a batch slot: decode-step EWMA × waiting depth. `0.0` when
    /// the queue is empty or no estimate exists yet (optimistic —
    /// admission never rejects blind).
    pub fn queue_wait_estimate(&self) -> f64 {
        self.step_ewma().decode_or_any() * self.scheduler.waiting_len() as f64
    }

    /// Run one engine iteration (one packed batch through the model).
    /// Returns completions finished this step; `None` if idle.
    ///
    /// The steady-state decode iteration is allocation-free: the batch is
    /// built into the persistent [`StepWorkspace`], the backend refills
    /// the persistent [`StepOutput`], and all-greedy batches skip logits
    /// materialization entirely on the sim backend
    /// (`tests/hotpath_alloc.rs` asserts the zero-allocation property).
    pub fn step(&mut self) -> Result<Option<Vec<Completion>>> {
        self.process_expiries();
        // requests completed at the door since the last step (max_len
        // exhausted by the prompt) are folded into this step's result so
        // `run_to_completion` callers observe them
        let mut instant = std::mem::take(&mut self.instant_done);
        let t0 = Instant::now();
        let Some(batch) = self.scheduler.build_batch(&mut self.kv, &mut self.ws)? else {
            return Ok(if instant.is_empty() { None } else { Some(instant) });
        };
        let want_tokens = self.ws.all_greedy();
        self.backend.step_into(
            batch.bucket,
            &self.ws.inputs,
            self.ws.rows.len(),
            want_tokens,
            &mut self.step_out,
        )?;
        // sample every row that completed its backlog (indexed loop +
        // disjoint field borrows: rows are copied out while the sampler
        // bank, step output and scheduler mutate)
        let vocab = self.cfg.vocab;
        for i in 0..self.ws.rows.len() {
            let r = self.ws.rows[i];
            let ridx = r.ridx as usize;
            // Per-request params, fetched once per row and O(1) by the
            // running-list index captured at batch build (the running
            // list does not mutate between build_batch and this loop, and
            // `sampling_at` asserts the id still matches).
            let params = self.scheduler.sampling_at(ridx, r.seq);
            let tok = match self.step_out.kind {
                StepYield::GreedyTokens => self.step_out.tokens[r.row],
                StepYield::Logits => {
                    // Per-request state: randomness comes from the slot's
                    // seed-derived PRNG, so the token stream is invariant
                    // to batch composition and slot assignment order.
                    let row =
                        &mut self.step_out.logits[r.row * vocab..(r.row + 1) * vocab];
                    self.ws.samplers.sample_row(r.sampler as usize, params, row)
                }
            };
            // Stop/penalty bookkeeping runs on both paths so the greedy
            // fast path and the logits path observe identical state.
            let stop = self.ws.samplers.observe(r.sampler as usize, params, tok);
            if stop {
                self.scheduler.mark_stop_at(ridx, r.seq);
            }
            let first = self.scheduler.push_token_at(ridx, r.seq, tok);
            self.obs.record_token(r.aid);
            if first {
                self.flightrec.record(EventKind::FirstToken, r.seq, r.aid, tok as u32 as u64);
            }
            // stream the token while the request is still in flight —
            // TTFT is only real if the first token leaves the engine now
            if let Some(tx) = self.streams.get(&r.seq) {
                let ev = if first {
                    TokenEvent::First { id: r.seq, token: tok }
                } else {
                    TokenEvent::Token { id: r.seq, token: tok }
                };
                if tx.send(ev).is_err() {
                    // client hung up: stop streaming (the request itself
                    // keeps running; use `cancel_request` to abort it)
                    self.streams.remove(&r.seq);
                }
            }
        }
        // device-partitioning emulation: idle out the unowned share
        if self.compute_share < 1.0 {
            let extra = t0.elapsed().mul_f64(1.0 / self.compute_share - 1.0);
            std::thread::sleep(extra);
        }
        let finished = self.scheduler.reap(&mut self.kv, &mut self.ws);
        let wall = t0.elapsed();
        // EWMA the step wall time into the estimate matching the step's
        // shape: any prefill tokens make it a prefill-phase step.
        let est = if batch.prefill_tokens > 0 {
            &mut self.ewma_prefill
        } else {
            &mut self.ewma_decode
        };
        *est = if *est == 0.0 {
            wall.as_secs_f64()
        } else {
            0.8 * *est + 0.2 * wall.as_secs_f64()
        };
        self.metrics.record_step(
            wall,
            self.step_out.execute_time,
            batch.prefill_tokens + batch.decode_tokens,
        );
        // live telemetry: atomics only — the steady-state decode step
        // stays allocation-free with recording enabled
        self.obs.record_step(
            wall.as_micros() as u64,
            self.step_out.execute_time.as_micros() as u64,
            batch.prefill_tokens as u64,
            batch.decode_tokens as u64,
        );
        self.flightrec.record(EventKind::Step, 0, -1, wall.as_micros() as u64);
        self.obs.set_gauges(
            self.kv.free_slots() as u64,
            self.scheduler.waiting_len() as u64,
            self.scheduler.running_len() as u64,
        );
        // prefix-cache telemetry: the cache keeps cumulative totals, obs
        // takes the per-step delta (atomics only — still allocation-free)
        let (hits, misses) = (self.kv.prefix_hit_tokens(), self.kv.prefix_miss_tokens());
        self.obs.record_prefix(hits - self.kv_hits_seen, misses - self.kv_misses_seen);
        self.kv_hits_seen = hits;
        self.kv_misses_seen = misses;
        let cow = self.kv.cow_copies();
        self.obs.record_cow(cow - self.kv_cow_seen);
        self.kv_cow_seen = cow;
        self.obs.set_kv_shared(self.kv.shared_blocks() as u64);
        self.metrics.set_kv_sharing(self.kv.shared_blocks(), cow as usize);
        let completions: Vec<Completion> = finished
            .into_iter()
            .map(|seq| {
                let first = seq.first_token_at.unwrap_or_else(Instant::now);
                let end = seq.finished_at.unwrap_or_else(Instant::now);
                let outputs = seq.generated();
                self.obs.record_completed(
                    seq.aid,
                    (first - seq.arrival).as_micros() as u64,
                    (end - seq.arrival).as_micros() as u64,
                );
                self.flightrec.record(EventKind::Done, seq.id, seq.aid, outputs as u64);
                self.trace_request(&seq, "done");
                let record = RequestRecord {
                    id: seq.id,
                    adapter: seq.adapter.clone(),
                    prompt_tokens: seq.prompt_len,
                    output_tokens: outputs,
                    ttft: first - seq.arrival,
                    tpot: (outputs > 1)
                        .then(|| (end - first) / (outputs as u32 - 1)),
                    e2e: end - seq.arrival,
                };
                self.metrics.complete_request(record.clone());
                let completion = Completion {
                    id: seq.id,
                    adapter: seq.adapter,
                    output: seq.tokens[seq.prompt_len..].to_vec(),
                    finish: seq.finish,
                    record,
                };
                if let Some(tx) = self.streams.remove(&seq.id) {
                    let _ = tx.send(TokenEvent::Done {
                        id: seq.id,
                        completion: completion.clone(),
                    });
                }
                completion
            })
            .collect();
        instant.extend(completions);
        Ok(Some(instant))
    }

    /// Drain everything that is queued; returns all completions.
    pub fn run_to_completion(&mut self) -> Result<Vec<Completion>> {
        let mut all = Vec::new();
        while let Some(mut done) = self.step()? {
            all.append(&mut done);
        }
        Ok(all)
    }

    pub fn report(&mut self) -> Report {
        self.metrics.report()
    }

    /// The engine's live telemetry registry. The returned `Arc` is how
    /// scrape surfaces (Prometheus listener, fleet coordinator) read
    /// engine state from other threads without locking the engine.
    pub fn obs(&self) -> Arc<ObsRegistry> {
        Arc::clone(&self.obs)
    }

    /// Current live-stats snapshot, with gauges refreshed first (the
    /// NDJSON `stats` frame body).
    pub fn stats_snapshot(&self) -> StatsSnapshot {
        self.obs.set_gauges(
            self.kv.free_slots() as u64,
            self.scheduler.waiting_len() as u64,
            self.scheduler.running_len() as u64,
        );
        self.obs.set_kv_shared(self.kv.shared_blocks() as u64);
        self.obs.snapshot()
    }

    /// Start collecting per-request phase spans (idempotent). Spans
    /// accumulate until [`Engine::write_trace`] / session reset.
    pub fn enable_trace(&mut self) {
        if self.trace.is_none() {
            // Anchor the origin at engine construction, not at
            // enable-time: phase stamps taken before tracing was turned
            // on (e.g. a request admitted just prior) would otherwise
            // all saturate to 0 and collapse into one point.
            self.trace = Some(TraceLog::with_origin(self.constructed));
        }
    }

    /// Hand the collected trace log to the caller (fleet replicas ship
    /// it to the coordinator at drain for the merged timeline). Tracing
    /// stops until [`Engine::enable_trace`] is called again.
    pub fn take_trace(&mut self) -> Option<TraceLog> {
        self.trace.take()
    }

    /// Shared handle to this engine's always-on flight recorder (the
    /// black-box ring of recent request/step events).
    pub fn flight_recorder(&self) -> Arc<FlightRecorder> {
        Arc::clone(&self.flightrec)
    }

    /// Spans collected so far (0 when tracing is disabled).
    pub fn trace_len(&self) -> usize {
        self.trace.as_ref().map_or(0, TraceLog::len)
    }

    /// Write the collected phase spans as Chrome-trace JSON (the
    /// `--trace-out` target). Errors if tracing was never enabled.
    pub fn write_trace(&self, path: &std::path::Path) -> Result<()> {
        match &self.trace {
            Some(t) => {
                t.write(path).with_context(|| format!("writing trace to {}", path.display()))
            }
            None => bail!("tracing not enabled (call enable_trace first)"),
        }
    }

    /// Start a fresh serving session on the same deployment: clears the
    /// scheduler, KV cache and metrics (weights and compiled executables
    /// stay resident). Benches reuse one engine across sweep cells to
    /// amortize PJRT compilation.
    pub fn reset_session(&mut self) {
        // resetting mid-flight would drop live requests with no terminal
        // event on their streams — refuse it loudly
        assert!(
            self.scheduler.is_idle(),
            "reset_session with requests in flight"
        );
        let sched_cfg = Scheduler::rebuild_config(&self.scheduler);
        self.ws = StepWorkspace::new(&sched_cfg, self.cfg.vocab);
        self.scheduler = Scheduler::new(sched_cfg);
        self.kv = PagedKvCache::new(self.cfg.kv_cap, self.kv_block, self.kv_share);
        self.kv_hits_seen = 0;
        self.kv_misses_seen = 0;
        self.kv_cow_seen = 0;
        self.step_out = StepOutput::new();
        self.metrics = MetricsCollector::new();
        self.obs.reset();
        if self.trace.is_some() {
            self.trace = Some(TraceLog::with_origin(self.constructed));
        }
        self.streams.clear();
        self.instant_done.clear();
        self.shutting_down = false;
        self.has_deadlines = false;
        self.ewma_prefill = 0.0;
        self.ewma_decode = 0.0;
        self.backend.reset_kv();
    }
}

/// The single-replica serving backend: `pump` runs one engine step.
impl ServingBackend for Engine {
    fn submit(&mut self, req: ServeRequest) -> Result<RequestHandle, SubmitError> {
        self.submit_request(req)
    }

    fn pump(&mut self) -> Result<bool> {
        self.step()?;
        Ok(Engine::has_work(self))
    }

    fn cancel(&mut self, id: RequestId) -> bool {
        self.cancel_request(id)
    }

    fn has_work(&self) -> bool {
        Engine::has_work(self)
    }

    fn drain(&mut self) -> Result<()> {
        self.drain_requests()
    }

    fn stats(&mut self) -> Option<StatsSnapshot> {
        Some(self.stats_snapshot())
    }

    fn flightrec(&mut self) -> Option<crate::util::json::Json> {
        Some(crate::obs::flightrec::dump(&[(0, &*self.flightrec)]))
    }
}
