//! KV cache allocators.
//!
//! Two allocators share the `[0, kv_cap)` slot arena the step ABI
//! expects:
//!
//! - [`KvCache`] — the original flat allocator: private token slots per
//!   sequence, no sharing. Kept as the reference semantics (differential
//!   tests) and for the Fig. 9 flat-capacity accounting.
//! - [`PagedKvCache`] (in [`paged`]) — the serving allocator: block/page
//!   tables per sequence, refcounted physical blocks, prefix-hash
//!   sharing across requests, and copy-on-write on divergence. The
//!   engine runs on this one.
//!
//! This module is also the source of the "KV cache capacity in tokens"
//! metrics the paper reports (Fig. 9): [`kv_capacity_tokens`] for a flat
//! deployment, [`paged_kv_capacity`] for the logical-vs-physical view
//! under prefix sharing.

pub mod paged;

pub use paged::{CowCopy, PagedKvCache};

use anyhow::{bail, Result};
use std::collections::HashMap;

/// Slot-granular KV cache allocator for one engine.
#[derive(Debug, Clone)]
pub struct KvCache {
    cap: usize,
    free: Vec<u32>,
    seqs: HashMap<u64, Vec<u32>>,
    peak_used: usize,
}

impl KvCache {
    pub fn new(cap: usize) -> Self {
        KvCache {
            cap,
            free: (0..cap as u32).rev().collect(),
            seqs: HashMap::new(),
            peak_used: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn free_slots(&self) -> usize {
        self.free.len()
    }

    pub fn used_slots(&self) -> usize {
        self.cap - self.free.len()
    }

    pub fn peak_used(&self) -> usize {
        self.peak_used
    }

    /// Can `n` more tokens be cached right now?
    pub fn has_room(&self, n: usize) -> bool {
        self.free.len() >= n
    }

    /// Append `n` slots to sequence `seq` (created on first call).
    /// Returns the new slots in position order.
    pub fn alloc(&mut self, seq: u64, n: usize) -> Result<Vec<u32>> {
        let mut out = Vec::with_capacity(n);
        self.alloc_into(seq, n, &mut out)?;
        Ok(out)
    }

    /// Hot-path allocation: append `n` slots to sequence `seq`, writing
    /// them (in position order) into the caller-owned `out` buffer, which
    /// is cleared first. With the per-sequence list pre-sized via
    /// [`KvCache::reserve_seq`], the steady-state decode path performs no
    /// heap allocation here.
    pub fn alloc_into(&mut self, seq: u64, n: usize, out: &mut Vec<u32>) -> Result<()> {
        out.clear();
        if n > self.free.len() {
            bail!(
                "KV cache full: need {n} slots, {} free of {}",
                self.free.len(),
                self.cap
            );
        }
        let at = self.free.len() - n;
        out.extend_from_slice(&self.free[at..]);
        self.free.truncate(at);
        self.seqs.entry(seq).or_default().extend_from_slice(out);
        self.peak_used = self.peak_used.max(self.used_slots());
        Ok(())
    }

    /// Pre-size sequence `seq`'s slot list for `cap` total slots so later
    /// [`KvCache::alloc_into`] calls never reallocate it. The scheduler
    /// calls this once at admission with the sequence's worst-case token
    /// count (prompt + max_new).
    pub fn reserve_seq(&mut self, seq: u64, cap: usize) {
        let held = self.seqs.entry(seq).or_default();
        held.reserve(cap.saturating_sub(held.len()));
    }

    /// All slots of a sequence, in position order.
    pub fn slots_of(&self, seq: u64) -> Option<&[u32]> {
        self.seqs.get(&seq).map(|v| v.as_slice())
    }

    pub fn seq_len(&self, seq: u64) -> usize {
        self.seqs.get(&seq).map_or(0, |v| v.len())
    }

    /// Release a finished sequence's slots back to the pool.
    pub fn free_seq(&mut self, seq: u64) -> usize {
        match self.seqs.remove(&seq) {
            Some(slots) => {
                let n = slots.len();
                self.free.extend(slots);
                n
            }
            None => 0,
        }
    }

    /// Live sequence count.
    pub fn seq_count(&self) -> usize {
        self.seqs.len()
    }
}

/// KV capacity (tokens) a device budget affords after weights, mirroring
/// vLLM's `gpu-memory-utilization` computation. Used by the Fig. 9
/// accounting at paper scale.
pub fn kv_capacity_tokens(
    device_free_bytes: usize,
    utilization: f64,
    kv_bytes_per_token: usize,
) -> usize {
    ((device_free_bytes as f64 * utilization) as usize) / kv_bytes_per_token.max(1)
}

/// Host-side metadata bytes charged per physical block by the paged
/// allocator: the `Block` record (refcount, fill, two hashes, flags)
/// plus its share of the free-list and two hash-index entries. Small
/// against the device-side KV bytes of a block, but Fig. 9 accounting
/// includes it so the paged capacity numbers stay honest.
pub const PAGED_BLOCK_META_BYTES: usize = 96;

/// Logical-vs-physical KV capacity of a paged deployment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PagedKvCapacity {
    /// Device tokens actually materialized (block-granular).
    pub physical_tokens: usize,
    /// Tokens addressable by concurrent sequences at the given prefix
    /// overlap: shared prefix blocks are paid once but serve every
    /// sequence referencing them.
    pub logical_tokens: usize,
    /// Host metadata overhead of the block structures.
    pub metadata_bytes: usize,
}

/// Paged-cache capacity a device budget affords, and the logical
/// multiplier prefix sharing buys at a given overlap fraction. With
/// `prefix_overlap = 0` the physical capacity matches
/// [`kv_capacity_tokens`] up to block rounding and metadata — the
/// flat-mode Fig. 9 numbers are unchanged by construction.
pub fn paged_kv_capacity(
    device_free_bytes: usize,
    utilization: f64,
    kv_bytes_per_token: usize,
    block_size: usize,
    prefix_overlap: f64,
) -> PagedKvCapacity {
    let block_size = block_size.max(1);
    let budget = (device_free_bytes as f64 * utilization) as usize;
    let per_block =
        block_size * kv_bytes_per_token.max(1) + PAGED_BLOCK_META_BYTES;
    let blocks = budget / per_block;
    let physical = blocks * block_size;
    // a shared fraction `o` of every sequence's footprint is resident
    // once instead of once-per-sequence, so N concurrent sequences fit
    // in (1 - o) * N + o sequence-footprints of physical memory:
    // logical capacity ≈ physical / (1 - o) for o < 1
    let o = prefix_overlap.clamp(0.0, 0.9999);
    let logical = (physical as f64 / (1.0 - o)) as usize;
    PagedKvCapacity {
        physical_tokens: physical,
        logical_tokens: logical,
        metadata_bytes: blocks * PAGED_BLOCK_META_BYTES,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_and_free_cycle() {
        let mut kv = KvCache::new(16);
        let a = kv.alloc(1, 5).unwrap();
        assert_eq!(a.len(), 5);
        assert_eq!(kv.slots_of(1).unwrap(), &a[..]);
        let b = kv.alloc(1, 3).unwrap();
        assert_eq!(kv.seq_len(1), 8);
        assert_eq!(kv.slots_of(1).unwrap()[5..], b[..]);
        kv.alloc(2, 8).unwrap();
        assert_eq!(kv.free_slots(), 0);
        assert!(kv.alloc(3, 1).is_err());
        assert_eq!(kv.free_seq(1), 8);
        assert_eq!(kv.free_slots(), 8);
        assert_eq!(kv.peak_used(), 16);
        assert_eq!(kv.seq_count(), 1);
    }

    #[test]
    fn slots_are_unique_across_sequences() {
        let mut kv = KvCache::new(64);
        let a = kv.alloc(1, 20).unwrap();
        let b = kv.alloc(2, 20).unwrap();
        let mut all: Vec<u32> = a.iter().chain(b.iter()).copied().collect();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), 40);
    }

    #[test]
    fn alloc_into_matches_alloc_and_reuses_buffers() {
        let mut a = KvCache::new(16);
        let mut b = KvCache::new(16);
        let mut buf = Vec::new();
        for (seq, n) in [(1u64, 5usize), (2, 3), (1, 2)] {
            let v = a.alloc(seq, n).unwrap();
            b.alloc_into(seq, n, &mut buf).unwrap();
            assert_eq!(v, buf, "alloc and alloc_into must assign identical slots");
        }
        assert_eq!(a.free_slots(), b.free_slots());
        assert_eq!(a.slots_of(1), b.slots_of(1));
        // reserve_seq pre-sizes so the hot path never grows the list
        b.reserve_seq(9, 4);
        let held_ptr = b.seqs.get(&9).unwrap().as_ptr();
        let cap = b.seqs.get(&9).unwrap().capacity();
        assert!(cap >= 4);
        for _ in 0..4 {
            b.alloc_into(9, 1, &mut buf).unwrap();
        }
        assert_eq!(b.seqs.get(&9).unwrap().as_ptr(), held_ptr, "no realloc");
        // over-capacity request still fails cleanly and leaves out empty
        assert!(b.alloc_into(9, 64, &mut buf).is_err());
        assert!(buf.is_empty());
    }

    #[test]
    fn free_unknown_seq_is_zero() {
        let mut kv = KvCache::new(4);
        assert_eq!(kv.free_seq(99), 0);
    }

    #[test]
    fn capacity_tokens_math() {
        // paper scale-ish sanity: 30 GB free, 90% util, 70 KB/token
        let t = kv_capacity_tokens(30 << 30, 0.9, 70 << 10);
        assert!((300_000..500_000).contains(&t), "{t}");
    }

    #[test]
    fn paged_capacity_matches_flat_at_zero_overlap() {
        let flat = kv_capacity_tokens(30 << 30, 0.9, 70 << 10);
        let paged = paged_kv_capacity(30 << 30, 0.9, 70 << 10, 16, 0.0);
        // physical capacity within one block + metadata rounding of flat
        assert!(paged.physical_tokens <= flat);
        assert!(
            flat - paged.physical_tokens <= 16 + flat / 1000,
            "flat {flat} vs paged physical {}",
            paged.physical_tokens
        );
        assert_eq!(paged.logical_tokens, paged.physical_tokens);
        assert!(paged.metadata_bytes > 0);
        // sharing multiplies the logical view, never the physical one
        let hot = paged_kv_capacity(30 << 30, 0.9, 70 << 10, 16, 0.95);
        assert_eq!(hot.physical_tokens, paged.physical_tokens);
        assert!(hot.logical_tokens >= paged.logical_tokens * 19);
    }

    #[test]
    fn property_no_slot_leaks_or_aliases() {
        crate::util::prop::check(606, 40, |rng| {
            let cap = 32;
            let mut kv = KvCache::new(cap);
            let mut live: Vec<u64> = Vec::new();
            for step in 0..80 {
                if rng.below(3) > 0 {
                    let seq = step as u64;
                    let n = 1 + rng.below(6) as usize;
                    if kv.alloc(seq, n).is_ok() && !live.contains(&seq) {
                        live.push(seq);
                    }
                } else if !live.is_empty() {
                    let i = rng.below(live.len() as u64) as usize;
                    let seq = live.swap_remove(i);
                    kv.free_seq(seq);
                }
                // invariant: free + Σ per-seq = cap, all slots distinct
                let held: usize = live.iter().map(|&s| kv.seq_len(s)).sum();
                assert_eq!(kv.free_slots() + held, cap);
                let mut all: Vec<u32> = live
                    .iter()
                    .flat_map(|&s| kv.slots_of(s).unwrap().iter().copied())
                    .collect();
                all.sort_unstable();
                let before = all.len();
                all.dedup();
                assert_eq!(all.len(), before, "aliased slots");
            }
        });
    }
}
