//! Paged KV cache with refcounted cross-request prefix sharing.
//!
//! The flat [`super::KvCache`] hands every sequence private token slots,
//! so ten requests sharing one system prompt pay for it ten times. This
//! module re-casts the slot arena as **blocks** (pages of `block_size`
//! consecutive slots) with:
//!
//! - a **block table per sequence** (`SeqTable`): logical position `p`
//!   lives in physical slot `blocks[p / bs] * bs + p % bs`;
//! - **refcounted physical blocks**: identical prompt prefixes attach to
//!   the same blocks, so the memory is paid once per *distinct* prefix;
//! - a **prefix-hash index** keyed by a per-adapter rolling hash chain of
//!   block contents: sealed (full) blocks register in `prefix_index`,
//!   the partially-filled tail block keeps a live entry in `tail_index`,
//!   so an arriving request can adopt both the full-block prefix and a
//!   matching partial tail;
//! - **copy-on-write on divergence**: appending into a block another
//!   sequence also references allocates a private copy first and reports
//!   it as a [`CowCopy`] for the caller to mirror (the host analogue of
//!   vLLM's `copy_blocks` device op);
//! - **lazy eviction**: a block whose refcount drops to zero goes on a
//!   FIFO free list but keeps its hash registration, so a follow-up
//!   request with the same prefix can resurrect it before it is reused
//!   (FIFO reuse ≈ oldest-freed content evicted first).
//!
//! ## Sharing is host-side accounting
//!
//! Neither step backend consumes `cache_seg`/`cache_pos` beyond shape
//! checks (the sim derives outputs from token/pos/aid only; PJRT
//! forwards them opaquely), so sharing needs no kernel change here: the
//! scheduler stamps a shared slot with the seg of its most recent
//! writer/attacher. A real seg-masked attention kernel would instead
//! gather per-sequence block tables on device — that kernel is future
//! work; the capacity/admission wins measured by `fig13_prefix_cache`
//! are backend-independent.
//!
//! ## Zero-allocation contract
//!
//! Everything the steady decode path touches is preallocated: the free
//! list is a `VecDeque` sized for every block, both hash indexes are
//! `HashMap`s with capacity for one entry per block (their entry counts
//! are bounded by the block count, so they never rehash), and
//! per-sequence block tables are pre-sized by [`PagedKvCache::reserve_seq`].
//! `tests/hotpath_alloc.rs` asserts 0 allocs/steady-decode-step with
//! this cache under the engine.

use anyhow::{bail, Result};
use std::collections::{HashMap, VecDeque};

/// One pending host-side block copy produced by copy-on-write: the first
/// `filled` slots of `src_block` were logically duplicated into
/// `dst_block` for the sequence that diverged. The scheduler drains
/// these after each allocation to re-stamp the destination slots'
/// device-visible metadata (`cache_seg`/`cache_pos`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CowCopy {
    /// Physical block the content was copied from (still owned by the
    /// remaining sharers).
    pub src_block: u32,
    /// Freshly allocated private block.
    pub dst_block: u32,
    /// Index of the block within the diverging sequence's block table
    /// (logical position of its first token = `block_index * block_size`).
    pub block_index: u32,
    /// Tokens already resident in the block at copy time.
    pub filled: u32,
}

#[derive(Debug, Clone, Default)]
struct Block {
    /// Live references (sequences whose tables contain this block).
    refcount: u32,
    /// Tokens written into the block so far.
    filled: u32,
    /// Rolling chain hash over (adapter seed, every prior sealed block,
    /// the tokens written here so far).
    run_hash: u64,
    /// Key under which the block is registered in `prefix_index`
    /// (0 = not registered).
    sealed_key: u64,
    /// Whether the block id currently sits in the free deque (lazily
    /// cleared on pop, so resurrected blocks leave stale entries behind
    /// instead of forcing an O(n) deque removal).
    in_free: bool,
}

#[derive(Debug, Clone)]
struct SeqTable {
    /// Physical block ids, in logical position order.
    blocks: Vec<u32>,
    /// Logical tokens resident (attached + written).
    len: usize,
    /// Chain hash after the last *sealed* block (seed when none).
    chain: u64,
}

/// splitmix64-style combiner; the chain identity of a prefix is the
/// fold of this over (adapter seed, token ids in order).
#[inline]
fn mix(h: u64, v: u64) -> u64 {
    let mut x = h ^ v.wrapping_mul(0x9e37_79b9_7f4a_7c15);
    x ^= x >> 30;
    x = x.wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

/// Per-adapter chain seed: prefixes only match within one adapter
/// (ESFT task preambles are adapter-specific; a base-model prompt must
/// never adopt an adapter's cached KV, whose values went through
/// rerouted experts).
#[inline]
fn chain_seed(aid: i32) -> u64 {
    mix(0xe2f0_77ea_7e57_c0de, (aid as i64 as u64) ^ 0xada7)
}

#[inline]
fn tok_key(t: i32) -> u64 {
    // disambiguate token values from the seed domain
    (t as u32 as u64) | (1 << 40)
}

/// Block/page-table KV cache with refcounted cross-request prefix
/// sharing. Slot ids remain plain `u32` indexes into the same
/// `[0, capacity)` arena the step ABI expects — `block * block_size +
/// offset` — so the engine's `cache_seg`/`cache_pos` arrays are
/// unchanged in shape.
#[derive(Debug, Clone)]
pub struct PagedKvCache {
    block_size: usize,
    blocks: Vec<Block>,
    /// FIFO free list of refcount-0 blocks (may contain stale entries
    /// for resurrected blocks; see `Block::in_free`).
    free: VecDeque<u32>,
    /// Count of refcount-0 blocks (authoritative; the deque is not).
    free_blocks: usize,
    /// Count of blocks with refcount >= 2 (the shared-pages gauge).
    shared_blocks: usize,
    /// Sealed-block registry: chain hash -> block id.
    prefix_index: HashMap<u64, u32>,
    /// Partial-tail registry: current chain hash -> block id (kept fresh
    /// on every append so a hit always matches the block's live state).
    tail_index: HashMap<u64, u32>,
    seqs: HashMap<u64, SeqTable>,
    pending_copies: Vec<CowCopy>,
    share: bool,
    peak_used_blocks: usize,
    prefix_hit_tokens: u64,
    prefix_miss_tokens: u64,
    cow_copies: u64,
}

impl PagedKvCache {
    /// `cap_slots` is the slot-arena size (the ABI `kv_cap`); blocks
    /// beyond the last whole multiple of `block_size` are unusable.
    /// `share` gates prefix attachment: with it off the cache behaves
    /// like a block-granular private allocator (the fig13 baseline).
    pub fn new(cap_slots: usize, block_size: usize, share: bool) -> Self {
        assert!(block_size > 0, "block_size must be positive");
        let nb = cap_slots / block_size;
        PagedKvCache {
            block_size,
            blocks: vec![Block::default(); nb],
            free: (0..nb as u32).collect(),
            free_blocks: nb,
            shared_blocks: 0,
            prefix_index: HashMap::with_capacity(nb),
            tail_index: HashMap::with_capacity(nb),
            seqs: HashMap::with_capacity(64),
            pending_copies: Vec::with_capacity(32),
            share,
            peak_used_blocks: 0,
            prefix_hit_tokens: 0,
            prefix_miss_tokens: 0,
            cow_copies: 0,
        }
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    pub fn num_blocks(&self) -> usize {
        self.blocks.len()
    }

    /// Usable slot capacity (whole blocks only).
    pub fn capacity(&self) -> usize {
        self.blocks.len() * self.block_size
    }

    pub fn free_blocks(&self) -> usize {
        self.free_blocks
    }

    /// Physically free slots (block-granular: a partially filled live
    /// block contributes nothing).
    pub fn free_slots(&self) -> usize {
        self.free_blocks * self.block_size
    }

    /// Physically occupied slots (block-granular).
    pub fn used_slots(&self) -> usize {
        (self.blocks.len() - self.free_blocks) * self.block_size
    }

    pub fn peak_used(&self) -> usize {
        self.peak_used_blocks * self.block_size
    }

    /// Blocks needed to hold `tokens` logical tokens from scratch.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_size)
    }

    /// Can `n` more tokens be cached right now, ignoring sharing?
    /// (Conservative: assumes a fresh block per `block_size` tokens.)
    pub fn has_room(&self, n: usize) -> bool {
        self.free_blocks >= self.blocks_for(n)
    }

    pub fn seq_count(&self) -> usize {
        self.seqs.len()
    }

    /// Logical tokens resident for a sequence (attached + written).
    pub fn seq_len(&self, seq: u64) -> usize {
        self.seqs.get(&seq).map_or(0, |t| t.len)
    }

    /// The sequence's block table, in logical position order.
    pub fn blocks_of(&self, seq: u64) -> Option<&[u32]> {
        self.seqs.get(&seq).map(|t| t.blocks.as_slice())
    }

    /// Physical slot of a sequence's logical position `p`.
    pub fn slot_of(&self, seq: u64, p: usize) -> Option<u32> {
        let t = self.seqs.get(&seq)?;
        if p >= t.len {
            return None;
        }
        Some(t.blocks[p / self.block_size] * self.block_size as u32
            + (p % self.block_size) as u32)
    }

    /// Upper bound on physical blocks sequence `seq` still needs to
    /// reach `final_len` logical tokens: whole blocks beyond its table,
    /// plus one for the copy-on-write a shared partial tail will force
    /// on its next append. The scheduler's conservative admission
    /// reservation sums this over all running sequences.
    pub fn future_blocks(&self, seq: u64, final_len: usize) -> usize {
        match self.seqs.get(&seq) {
            Some(t) => {
                let total = self.blocks_for(final_len).max(t.blocks.len());
                let mut need = total - t.blocks.len();
                if let Some(&b) = t.blocks.last() {
                    let blk = &self.blocks[b as usize];
                    if blk.refcount > 1
                        && (blk.filled as usize) < self.block_size
                        && t.len < final_len
                    {
                        need += 1;
                    }
                }
                need
            }
            None => self.blocks_for(final_len),
        }
    }

    /// Prompt tokens served from the shared cache since construction.
    pub fn prefix_hit_tokens(&self) -> u64 {
        self.prefix_hit_tokens
    }

    /// Prompt tokens that had to be prefilled despite sharing being on.
    pub fn prefix_miss_tokens(&self) -> u64 {
        self.prefix_miss_tokens
    }

    /// Copy-on-write block copies performed since construction.
    pub fn cow_copies(&self) -> u64 {
        self.cow_copies
    }

    /// Blocks currently referenced by two or more sequences.
    pub fn shared_blocks(&self) -> usize {
        self.shared_blocks
    }

    /// Pre-size sequence `seq`'s block table for `cap_tokens` logical
    /// tokens so later appends never reallocate it. Call before
    /// [`PagedKvCache::attach_prefix`] at admission.
    pub fn reserve_seq(&mut self, seq: u64, cap_tokens: usize, aid: i32) {
        let need = self.blocks_for(cap_tokens);
        let t = self.seqs.entry(seq).or_insert_with(|| SeqTable {
            blocks: Vec::new(),
            len: 0,
            chain: chain_seed(aid),
        });
        t.blocks.reserve(need.saturating_sub(t.blocks.len()));
    }

    /// How much of `tokens` (capped at `limit`) is already cached for
    /// adapter `aid`, without attaching: returns `(cached_tokens,
    /// live_full_blocks)` where the second counts matched *sealed*
    /// blocks that are already referenced by a live sequence — the
    /// blocks a new request would share for free. Matched refcount-0
    /// (resurrectable) blocks and a matched partial tail still consume
    /// free-pool blocks, so admission must not discount them.
    pub fn probe_prefix(&self, tokens: &[i32], aid: i32, limit: usize) -> (usize, usize) {
        if !self.share {
            return (0, 0);
        }
        let bs = self.block_size;
        let limit = limit.min(tokens.len());
        let mut h = chain_seed(aid);
        let mut matched = 0usize;
        let mut live_full = 0usize;
        while matched + bs <= limit {
            let mut h2 = h;
            for &t in &tokens[matched..matched + bs] {
                h2 = mix(h2, tok_key(t));
            }
            match self.prefix_index.get(&h2) {
                Some(&b) if self.blocks[b as usize].sealed_key == h2 => {
                    if self.blocks[b as usize].refcount >= 1 {
                        live_full += 1;
                    }
                    matched += bs;
                    h = h2;
                }
                _ => break,
            }
        }
        // deepest matching partial tail at the current chain depth
        let mut h2 = h;
        let mut best = 0usize;
        for d in 1..=(limit - matched).min(bs.saturating_sub(1)) {
            h2 = mix(h2, tok_key(tokens[matched + d - 1]));
            if let Some(&b) = self.tail_index.get(&h2) {
                let blk = &self.blocks[b as usize];
                if blk.filled as usize == d && blk.run_hash == h2 {
                    best = d;
                }
            }
        }
        (matched + best, live_full)
    }

    /// Adopt the longest cached prefix of `tokens` (capped at `limit`,
    /// normally `prompt_len - 1` so the last prompt token is always
    /// computed and yields first-token logits): increfs every matched
    /// sealed block plus at most one matching partial tail, installs
    /// them as the head of `seq`'s block table, and returns the number
    /// of logical tokens now resident — the scheduler skips prefilling
    /// them. Also advances the prefix hit/miss token counters.
    pub fn attach_prefix(&mut self, seq: u64, tokens: &[i32], aid: i32, limit: usize) -> usize {
        if !self.share {
            return 0;
        }
        let bs = self.block_size;
        let limit = limit.min(tokens.len());
        let mut table = self.seqs.remove(&seq).unwrap_or_else(|| SeqTable {
            blocks: Vec::new(),
            len: 0,
            chain: chain_seed(aid),
        });
        debug_assert!(table.blocks.is_empty(), "attach_prefix on a non-empty sequence");
        let mut h = table.chain;
        let mut matched = 0usize;
        while matched + bs <= limit {
            let mut h2 = h;
            for &t in &tokens[matched..matched + bs] {
                h2 = mix(h2, tok_key(t));
            }
            match self.prefix_index.get(&h2).copied() {
                Some(b) if self.blocks[b as usize].sealed_key == h2 => {
                    self.incref(b);
                    table.blocks.push(b);
                    matched += bs;
                    h = h2;
                }
                _ => break,
            }
        }
        table.chain = h;
        let mut h2 = h;
        let mut best: Option<(u32, usize)> = None;
        for d in 1..=(limit - matched).min(bs.saturating_sub(1)) {
            h2 = mix(h2, tok_key(tokens[matched + d - 1]));
            if let Some(&b) = self.tail_index.get(&h2) {
                let blk = &self.blocks[b as usize];
                if blk.filled as usize == d && blk.run_hash == h2 {
                    best = Some((b, d));
                }
            }
        }
        if let Some((b, d)) = best {
            self.incref(b);
            table.blocks.push(b);
            matched += d;
        }
        table.len = matched;
        self.prefix_hit_tokens += matched as u64;
        self.prefix_miss_tokens += (tokens.len() - matched) as u64;
        self.seqs.insert(seq, table);
        matched
    }

    /// Append `tokens` to sequence `seq`, writing the slot of each (in
    /// logical position order) into the caller-owned `out` buffer,
    /// which is cleared first. Fresh blocks come off the FIFO free
    /// list; appending into a block shared with another sequence
    /// triggers copy-on-write (recorded for [`PagedKvCache::drain_copies`]).
    /// The token values feed the rolling prefix hash so future requests
    /// can match this content. Fails without side effects when the free
    /// pool cannot cover the worst case.
    pub fn alloc_into(
        &mut self,
        seq: u64,
        aid: i32,
        tokens: &[i32],
        out: &mut Vec<u32>,
    ) -> Result<()> {
        out.clear();
        if tokens.is_empty() {
            return Ok(());
        }
        let bs = self.block_size;
        let mut table = self.seqs.remove(&seq).unwrap_or_else(|| SeqTable {
            blocks: Vec::new(),
            len: 0,
            chain: chain_seed(aid),
        });
        // precheck so failure leaves the cache untouched
        let (tail_room, tail_shared) = match table.blocks.last() {
            Some(&b) => {
                let blk = &self.blocks[b as usize];
                let room = bs - blk.filled as usize;
                (room, room > 0 && blk.refcount > 1)
            }
            None => (0, false),
        };
        let need = tokens.len().saturating_sub(tail_room).div_ceil(bs)
            + tail_shared as usize;
        if need > self.free_blocks {
            let free = self.free_slots();
            if !table.blocks.is_empty() || table.len > 0 {
                self.seqs.insert(seq, table);
            }
            bail!(
                "KV cache full: need {} block(s) for {} token(s), {} free of {} slots",
                need,
                tokens.len(),
                free,
                self.capacity()
            );
        }
        for &tok in tokens {
            let tail = match table.blocks.last().copied() {
                Some(b) if (self.blocks[b as usize].filled as usize) < bs => {
                    if self.blocks[b as usize].refcount > 1 {
                        self.cow(&mut table, b)
                    } else {
                        b
                    }
                }
                _ => {
                    let b = self.pop_free();
                    self.blocks[b as usize].run_hash = table.chain;
                    table.blocks.push(b);
                    b
                }
            };
            let blk = &mut self.blocks[tail as usize];
            if blk.filled > 0 {
                // the partial-tail entry tracks the live hash; retire
                // the stale depth before advancing (only if it is ours —
                // a COW source keeps its entry for future attachers)
                if self.tail_index.get(&blk.run_hash) == Some(&tail) {
                    self.tail_index.remove(&blk.run_hash);
                }
            }
            blk.run_hash = mix(blk.run_hash, tok_key(tok));
            out.push(tail * bs as u32 + blk.filled);
            blk.filled += 1;
            table.len += 1;
            if blk.filled as usize == bs {
                // seal: register for whole-block prefix matching (first
                // writer of a content hash keeps the registration)
                table.chain = blk.run_hash;
                let key = blk.run_hash;
                let blk_sealed = &mut self.blocks[tail as usize];
                if !self.prefix_index.contains_key(&key) {
                    self.prefix_index.insert(key, tail);
                    blk_sealed.sealed_key = key;
                }
            } else {
                self.tail_index.insert(blk.run_hash, tail);
            }
        }
        self.seqs.insert(seq, table);
        Ok(())
    }

    /// Move the pending copy-on-write records into `out` (cleared
    /// first). The scheduler calls this after every allocation to
    /// re-stamp the destination slots' device-visible metadata.
    pub fn drain_copies(&mut self, out: &mut Vec<CowCopy>) {
        out.clear();
        out.append(&mut self.pending_copies);
    }

    /// Drop sequence `seq`'s references. Blocks whose refcount reaches
    /// zero join the free list (their content hash stays registered for
    /// resurrection until the block is reused); the slots of each such
    /// block are appended to `freed` (cleared first) so the caller can
    /// clear their device-visible metadata. Returns the sequence's
    /// logical token count (0 if unknown).
    pub fn decref_seq(&mut self, seq: u64, freed: &mut Vec<u32>) -> usize {
        freed.clear();
        let Some(table) = self.seqs.remove(&seq) else {
            return 0;
        };
        let bs = self.block_size;
        for &b in &table.blocks {
            let dead = self.decref(b);
            if dead {
                let blk = &self.blocks[b as usize];
                for j in 0..blk.filled {
                    freed.push(b * bs as u32 + j);
                }
            }
        }
        table.len
    }

    fn incref(&mut self, b: u32) {
        let blk = &mut self.blocks[b as usize];
        blk.refcount += 1;
        match blk.refcount {
            1 => {
                // resurrection off the free list (stale deque entry is
                // skipped lazily on pop)
                self.free_blocks -= 1;
                self.peak_used_blocks =
                    self.peak_used_blocks.max(self.blocks.len() - self.free_blocks);
            }
            2 => self.shared_blocks += 1,
            _ => {}
        }
    }

    /// Decrement; returns true when the block became free.
    fn decref(&mut self, b: u32) -> bool {
        let blk = &mut self.blocks[b as usize];
        debug_assert!(blk.refcount > 0, "double free of block {b}");
        blk.refcount -= 1;
        match blk.refcount {
            0 => {
                self.free_blocks += 1;
                if !blk.in_free {
                    blk.in_free = true;
                    self.free.push_back(b);
                }
                true
            }
            1 => {
                self.shared_blocks -= 1;
                false
            }
            _ => false,
        }
    }

    /// Pop a truly-free block, skipping stale entries for resurrected
    /// blocks, and wipe its cached identity (this is the eviction
    /// point: FIFO order reuses the oldest-freed content first).
    fn pop_free(&mut self) -> u32 {
        loop {
            let b = self
                .free
                .pop_front()
                .expect("free_blocks accounting out of sync with deque");
            self.blocks[b as usize].in_free = false;
            if self.blocks[b as usize].refcount > 0 {
                continue; // resurrected since it was freed
            }
            let blk = &mut self.blocks[b as usize];
            if blk.sealed_key != 0 {
                if self.prefix_index.get(&blk.sealed_key) == Some(&b) {
                    self.prefix_index.remove(&blk.sealed_key);
                }
                blk.sealed_key = 0;
            } else if blk.filled > 0 && self.tail_index.get(&blk.run_hash) == Some(&b) {
                self.tail_index.remove(&blk.run_hash);
            }
            let blk = &mut self.blocks[b as usize];
            blk.filled = 0;
            blk.run_hash = 0;
            blk.refcount = 1;
            self.free_blocks -= 1;
            self.peak_used_blocks =
                self.peak_used_blocks.max(self.blocks.len() - self.free_blocks);
            return b;
        }
    }

    /// Copy-on-write: give `table` a private copy of its shared tail
    /// block `src` (capacity was prechecked by the caller).
    fn cow(&mut self, table: &mut SeqTable, src: u32) -> u32 {
        let dst = self.pop_free();
        let (filled, run_hash) = {
            let s = &self.blocks[src as usize];
            (s.filled, s.run_hash)
        };
        {
            let d = &mut self.blocks[dst as usize];
            d.filled = filled;
            d.run_hash = run_hash;
        }
        // the source keeps its tail_index registration: it still serves
        // future attachers of the common prefix
        self.decref(src);
        *table.blocks.last_mut().expect("cow on empty table") = dst;
        self.cow_copies += 1;
        self.pending_copies.push(CowCopy {
            src_block: src,
            dst_block: dst,
            block_index: (table.blocks.len() - 1) as u32,
            filled,
        });
        dst
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn slots(kv: &PagedKvCache, seq: u64) -> Vec<u32> {
        (0..kv.seq_len(seq)).map(|p| kv.slot_of(seq, p).unwrap()).collect()
    }

    #[test]
    fn block_size_one_matches_flat_kvcache_semantics() {
        // the differential anchor: with 1-slot blocks and sharing off,
        // the paged cache is semantically the flat allocator — same
        // per-call slot sets while allocation is monotone, and identical
        // free/used/per-seq accounting across arbitrary churn (slot
        // *order* legitimately differs: flat hands out the tail of a
        // reversed free list, paged pops a FIFO deque)
        let mut flat = crate::kvcache::KvCache::new(32);
        let mut paged = PagedKvCache::new(32, 1, false);
        let mut fbuf = Vec::new();
        let mut pbuf = Vec::new();
        let toks: Vec<i32> = (0..8).collect();
        for (seq, n) in [(1u64, 5usize), (2, 3), (1, 2), (3, 8)] {
            flat.alloc_into(seq, n, &mut fbuf).unwrap();
            paged.alloc_into(seq, -1, &toks[..n], &mut pbuf).unwrap();
            fbuf.sort_unstable();
            pbuf.sort_unstable();
            assert_eq!(fbuf, pbuf, "seq {seq} n {n}");
            assert_eq!(flat.seq_len(seq), paged.seq_len(seq));
        }
        assert_eq!(flat.free_slots(), paged.free_slots());
        let mut freed = Vec::new();
        assert_eq!(paged.decref_seq(1, &mut freed), flat.free_seq(1));
        assert_eq!(freed.len(), 7);
        assert_eq!(flat.free_slots(), paged.free_slots());
        assert_eq!(flat.used_slots(), paged.used_slots());
        // post-churn: accounting stays in lockstep even when ids diverge
        crate::util::prop::check(411, 10, |rng| {
            let mut flat = crate::kvcache::KvCache::new(24);
            let mut paged = PagedKvCache::new(24, 1, false);
            let mut live: Vec<u64> = Vec::new();
            let (mut fb, mut pb, mut fr) = (Vec::new(), Vec::new(), Vec::new());
            for step in 0..60u64 {
                if rng.below(3) > 0 {
                    let n = 1 + rng.below(5) as usize;
                    let f = flat.alloc_into(step, n, &mut fb);
                    let p = paged.alloc_into(step, -1, &vec![7; n], &mut pb);
                    assert_eq!(f.is_ok(), p.is_ok(), "admission must agree");
                    if f.is_ok() && !live.contains(&step) {
                        live.push(step);
                    }
                } else if !live.is_empty() {
                    let i = rng.below(live.len() as u64) as usize;
                    let seq = live.swap_remove(i);
                    assert_eq!(flat.free_seq(seq), paged.decref_seq(seq, &mut fr));
                }
                assert_eq!(flat.free_slots(), paged.free_slots());
                assert_eq!(flat.used_slots(), paged.used_slots());
                for &s in &live {
                    assert_eq!(flat.seq_len(s), paged.seq_len(s));
                }
            }
        });
    }

    #[test]
    fn full_block_prefix_is_shared_and_refcounted() {
        let mut kv = PagedKvCache::new(64, 4, true);
        let prompt: Vec<i32> = (100..112).collect(); // 3 full blocks
        let mut buf = Vec::new();
        kv.alloc_into(1, 0, &prompt, &mut buf).unwrap();
        assert_eq!(kv.used_slots(), 12);
        // identical prompt, same adapter: the cap at prompt_len-1 = 11
        // admits the first 2 sealed blocks; the third sealed at depth 12
        // is past the cap, and no partial tail exists (12 | 4)
        let (cached, live) = kv.probe_prefix(&prompt, 0, prompt.len() - 1);
        assert_eq!(cached, 8, "2 sealed blocks within the cap");
        assert_eq!(live, 2);
        kv.reserve_seq(2, 16, 0);
        let got = kv.attach_prefix(2, &prompt, 0, prompt.len() - 1);
        assert_eq!(got, 8);
        assert_eq!(kv.seq_len(2), 8);
        assert_eq!(kv.shared_blocks(), 2);
        // physical memory did not grow: still 3 blocks
        assert_eq!(kv.used_slots(), 12);
        // shared slots are the same physical slots
        assert_eq!(slots(&kv, 1)[..8], slots(&kv, 2)[..]);
        assert_eq!(kv.prefix_hit_tokens(), 8);
        assert_eq!(kv.prefix_miss_tokens(), 4);
        // a different adapter must not match
        assert_eq!(kv.probe_prefix(&prompt, 1, prompt.len() - 1), (0, 0));
        // a diverging prompt matches only the common full blocks
        let mut other = prompt.clone();
        other[9] = 999;
        assert_eq!(kv.probe_prefix(&other, 0, other.len() - 1).0, 8);
    }

    #[test]
    fn cow_on_divergence_keeps_the_source_intact() {
        let mut kv = PagedKvCache::new(64, 4, true);
        let prompt: Vec<i32> = (7..13).collect(); // block 0 full, block 1 holds 2
        let mut buf = Vec::new();
        kv.alloc_into(1, -1, &prompt, &mut buf).unwrap();
        let s1 = slots(&kv, 1);
        // seq 2's prompt extends seq 1's by one diverging token, so the
        // cap (prompt_len-1 = 6) admits seq 1's whole residency: one
        // sealed block + the 2-deep partial tail
        let mut prompt2 = prompt.clone();
        prompt2.push(42);
        kv.reserve_seq(2, 12, -1);
        let got = kv.attach_prefix(2, &prompt2, -1, prompt2.len() - 1);
        assert_eq!(got, 6, "1 sealed block + 2-deep partial tail");
        assert_eq!(kv.shared_blocks(), 2);
        // seq 2 writes its 7th token into the shared partial tail: COW
        kv.alloc_into(2, -1, &[42], &mut buf).unwrap();
        let mut copies = Vec::new();
        kv.drain_copies(&mut copies);
        assert_eq!(copies.len(), 1);
        let c = copies[0];
        assert_eq!(c.block_index, 1);
        assert_eq!(c.filled, 2, "two shared tokens lived in the tail at copy time");
        assert_ne!(c.src_block, c.dst_block);
        // seq 1's physical slots are untouched; seq 2's tail moved
        assert_eq!(slots(&kv, 1), s1);
        let s2 = slots(&kv, 2);
        assert_eq!(s2[..4], s1[..4], "sealed block still shared");
        assert_ne!(s2[4], s1[4], "diverged tail is private");
        assert_eq!(kv.cow_copies(), 1);
        assert_eq!(kv.shared_blocks(), 1, "only the sealed block stays shared");
        // seq 1 keeps appending into its original tail without COW
        kv.alloc_into(1, -1, &[55], &mut buf).unwrap();
        kv.drain_copies(&mut copies);
        assert!(copies.is_empty(), "exclusive append must not copy");
    }

    #[test]
    fn freed_blocks_resurrect_until_reused() {
        let mut kv = PagedKvCache::new(16, 4, true);
        let prompt: Vec<i32> = (0..8).collect();
        let mut buf = Vec::new();
        let mut freed = Vec::new();
        kv.alloc_into(1, 0, &prompt, &mut buf).unwrap();
        kv.decref_seq(1, &mut freed);
        assert_eq!(kv.used_slots(), 0, "refcount-0 blocks are free");
        assert_eq!(freed.len(), 8);
        // the content hash survives: a new identical request resurrects
        // the first sealed block (the second, sealed at depth 8, is past
        // the prompt_len-1 cap) — the TTFT win across sequential requests
        kv.reserve_seq(2, 10, 0);
        let got = kv.attach_prefix(2, &prompt, 0, prompt.len() - 1);
        assert_eq!(got, 4, "the in-cap sealed block resurrects");
        assert_eq!(kv.used_slots(), 4, "resurrection consumes the free pool");
        // churn through the whole pool so the freed blocks get reused...
        let mut freed2 = Vec::new();
        kv.decref_seq(2, &mut freed2);
        let filler: Vec<i32> = (100..116).collect();
        kv.alloc_into(9, 1, &filler, &mut buf).unwrap();
        // ...then the old prefix is gone (evicted on reuse)
        assert_eq!(kv.probe_prefix(&prompt, 0, prompt.len() - 1), (0, 0));
    }

    #[test]
    fn alloc_failure_is_side_effect_free() {
        let mut kv = PagedKvCache::new(8, 4, true);
        let mut buf = Vec::new();
        kv.alloc_into(1, -1, &[1, 2, 3, 4, 5], &mut buf).unwrap();
        let free_before = kv.free_blocks();
        let toks: Vec<i32> = (0..9).collect();
        assert!(kv.alloc_into(2, -1, &toks, &mut buf).is_err());
        assert!(buf.is_empty());
        assert_eq!(kv.free_blocks(), free_before);
        assert_eq!(kv.seq_len(2), 0);
    }

    #[test]
    fn property_refcounts_never_leak() {
        crate::util::prop::check(909, 30, |rng| {
            let bs = 1 + rng.below(4) as usize;
            let mut kv = PagedKvCache::new(64 * bs, bs, true);
            let mut live: Vec<u64> = Vec::new();
            let mut buf = Vec::new();
            let mut freed = Vec::new();
            let mut next = 0u64;
            // a small pool of prompts makes sharing and COW frequent
            let prompts: Vec<Vec<i32>> = (0..4)
                .map(|p| (0..12).map(|i| (p * 3 + i) as i32).collect())
                .collect();
            for _ in 0..120 {
                if rng.below(3) > 0 {
                    next += 1;
                    let prompt = &prompts[rng.below(4) as usize];
                    let aid = rng.below(2) as i32 - 1;
                    kv.reserve_seq(next, prompt.len() + 4, aid);
                    let got = kv.attach_prefix(next, prompt, aid, prompt.len() - 1);
                    if kv
                        .alloc_into(next, aid, &prompt[got..], &mut buf)
                        .is_ok()
                    {
                        live.push(next);
                    } else {
                        kv.decref_seq(next, &mut freed);
                    }
                } else if !live.is_empty() {
                    let i = rng.below(live.len() as u64) as usize;
                    kv.decref_seq(live.swap_remove(i), &mut freed);
                }
                kv.drain_copies(&mut Vec::new());
            }
            for &s in &live {
                assert_eq!(kv.seq_len(s), 12);
                kv.decref_seq(s, &mut freed);
            }
            assert_eq!(kv.used_slots(), 0, "all refcounts must return to zero");
            assert_eq!(kv.shared_blocks(), 0);
            assert_eq!(kv.free_blocks(), kv.num_blocks());
        });
    }
}
