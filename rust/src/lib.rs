//! # ExpertWeave
//!
//! A from-scratch reproduction of *ExpertWeave: Efficiently Serving
//! Expert-Specialized Fine-Tuned Adapters at Scale* (CS.DC 2025) as a
//! three-layer Rust + JAX + Pallas stack.
//!
//! ExpertWeave serves many **ESFT adapters** (per-layer subsets of fine-tuned
//! MoE experts) concurrently over a single shared Mixture-of-Experts base
//! model. Its two core mechanisms, both implemented here:
//!
//! * **Virtual-memory-assisted expert weight management** ([`vmm`],
//!   [`weights`]): one contiguous *virtual weight tensor* of
//!   `M + N * E_max` expert slots per MoE projection, with physical 2 MB
//!   pages mapped only under slots that actually hold expert weights.
//!   Padding slots consume address space but no memory.
//! * **Batched rerouting** ([`adapters`], L1 Pallas kernel):
//!   per-layer expert maps `Π[aid, expert]` rewrite the router's top-k
//!   expert IDs per token so that tokens of different adapters, batched
//!   together, are dispatched to the right fine-tuned experts by an
//!   *unmodified* grouped-matmul operator.
//!
//! The crate is organised like a serving framework (vLLM-role), because the
//! paper's system is one: [`scheduler`] (continuous batching + chunked
//! prefill), [`kvcache`], [`sampler`], [`runtime`] (PJRT execution of
//! AOT-lowered JAX/Pallas artifacts), [`server`] (trace replay), plus the
//! experiment substrates [`workload`], [`metrics`], [`memsim`] and
//! [`bench`], plus the always-on live telemetry layer [`obs`]
//! (lock-free per-adapter counters and log2 histograms recorded from
//! the zero-allocation step loop, per-request phase tracing exportable
//! as Chrome-trace JSON, and the NDJSON `stats` frame / Prometheus
//! exposition surfaces — see `docs/OBSERVABILITY.md`).
//!
//! The online request/response boundary is the [`serving`] API:
//! [`serving::ServingBackend`] (submit / pump / cancel / drain,
//! implemented by the single [`engine::Engine`], the fleet
//! [`coordinator::Coordinator`], and the remote
//! [`serving::frontend::NdjsonClient`]), per-request token streams
//! ([`serving::RequestHandle`] delivering [`serving::TokenEvent`]s),
//! typed admission errors ([`serving::SubmitError`]), and a std-only
//! NDJSON-over-TCP frontend ([`serving::frontend`], exposed as
//! `expertweave serve --listen` and — fleet behind the identical
//! router — `expertweave fleet --listen`; wire spec in
//! `docs/PROTOCOL.md`). The trace replayers in [`server`] and the
//! open-loop Poisson load generator ([`workload::openloop`],
//! `expertweave loadgen`) are thin clients of this API.
//!
//! Above the single engine sits the **fleet layer** ([`coordinator`]):
//! N engine replicas on their own threads behind a coordinator that does
//! adapter-aware routing (RoundRobin / JoinShortestQueue /
//! AdapterAffinity / DeadlineAware — the last routes by each replica's
//! published decode-step EWMA × queue depth and refuses deadlines no
//! replica can meet), fleet-wide adapter lifecycle (load-on-miss,
//! per-replica capacity with LRU eviction, rate-triggered replication of
//! hot adapters) and admission control (bounded per-adapter queues with
//! shed accounting). This is the scale story of the paper taken to its
//! deployment conclusion: one shared-adapter engine beats
//! one-merged-engine-per-adapter *within* a device, and the coordinator
//! extends that across devices.
//!
//! Execution backends: the PJRT runtime consumes AOT artifacts
//! (`make artifacts`); [`runtime::sim`] is a drop-in simulated backend
//! with the same step ABI and a calibrated wall-clock cost model, so the
//! serving and fleet layers run (and are tested/benchmarked) in
//! artifact-free environments.
//!
//! Python/JAX runs only at build time (`make artifacts`); the request path
//! is pure Rust + PJRT.

pub mod adapters;
pub mod bench;
pub mod coordinator;
pub mod engine;
pub mod kvcache;
pub mod memsim;
pub mod metrics;
pub mod model;
pub mod obs;
pub mod runtime;
pub mod sampler;
pub mod scheduler;
pub mod server;
pub mod serving;
pub mod util;
pub mod vmm;
pub mod weights;
pub mod workload;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
