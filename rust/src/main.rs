//! `expertweave` — the leader CLI.
//!
//! Subcommands:
//! * `serve`        — replay a synthetic workload trace against a
//!                    deployment (weave / base-only / merged) and print
//!                    the serving report.
//! * `gen-adapters` — synthesize the Table-1 ESFT adapters for a config
//!                    and write `.esft` checkpoints.
//! * `inspect`      — show an artifact set (config, executables, ABI).
//! * `sparsity`     — print the Table-1 sparsity/fragmentation analysis.
//!
//! Examples:
//! ```text
//! expertweave inspect --config tiny
//! expertweave gen-adapters --config small --out /tmp/adapters
//! expertweave serve --config tiny --adapters 2 --lambda 5 --horizon 10
//! ```

use anyhow::{bail, Context, Result};
use expertweave::adapters::generator::{
    adapter_fragmentation_factor, fragmentation_factor, paper_adapter_profiles, synth_adapter,
};
use expertweave::bench::Table;
use expertweave::engine::{Engine, EngineOptions};
use expertweave::runtime::{ArtifactSet, Variant};
use expertweave::server;
use expertweave::util::args::Args;
use expertweave::util::logging::{set_level, Level};
use expertweave::weights::StoreMode;
use expertweave::workload::trace::{Trace, TraceSpec};
use std::path::PathBuf;

fn main() {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        eprintln!("usage: expertweave <serve|gen-adapters|inspect|sparsity> [options]");
        std::process::exit(2);
    }
    let cmd = argv.remove(0);
    let result = match cmd.as_str() {
        "serve" => serve(argv),
        "gen-adapters" => gen_adapters(argv),
        "inspect" => inspect(argv),
        "sparsity" => sparsity(argv),
        other => {
            eprintln!("unknown command {other:?}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn artifact_set(config: &str) -> Result<ArtifactSet> {
    let dir = PathBuf::from("artifacts").join(config);
    ArtifactSet::load(&dir)
}

fn serve(argv: Vec<String>) -> Result<()> {
    let a = Args::new("expertweave serve", "replay a synthetic trace")
        .opt("config", Some("tiny"), "artifact config (tiny|small)")
        .opt("deployment", Some("weave"), "weave|singleop|padding|base-only")
        .opt("adapters", Some("2"), "number of Table-1 adapters to load")
        .opt("lambda", Some("2.0"), "aggregate arrival rate (req/s)")
        .opt("alpha", Some("1.0"), "power-law skew (1 = uniform)")
        .opt("horizon", Some("10.0"), "trace horizon (s)")
        .opt("chunk", Some("256"), "chunked-prefill budget per seq")
        .opt("seed", Some("0"), "workload seed")
        .flag("verbose", "debug logging")
        .parse(argv)
        .map_err(anyhow::Error::msg)?;
    if a.has_flag("verbose") {
        set_level(Level::Debug);
    }
    let set = artifact_set(&a.get_or("config", "tiny"))?;
    let cfg = set.config.clone();
    let n: usize = a.get_usize("adapters").map_err(anyhow::Error::msg)?;
    if n > cfg.max_adapters {
        bail!("config supports at most {} adapters", cfg.max_adapters);
    }
    let profiles = paper_adapter_profiles();
    let adapters: Vec<_> = (0..n)
        .map(|i| {
            let mut p = profiles[i % profiles.len()].clone();
            p.max_experts = p.max_experts.min(cfg.e_max);
            p.avg_experts = p.avg_experts.min(p.max_experts as f64);
            synth_adapter(&p, cfg.layers, cfg.num_experts, cfg.hidden, cfg.expert_inter, 42 + i as u64)
        })
        .collect();

    let opts = EngineOptions {
        chunk: a.get_usize("chunk").map_err(anyhow::Error::msg)?,
        ..Default::default()
    };
    let deployment = a.get_or("deployment", "weave");
    let mut engine = match deployment.as_str() {
        "weave" => Engine::new_weave(&set, &adapters, Variant::Weave, StoreMode::Virtual, opts)?,
        "singleop" => {
            Engine::new_weave(&set, &adapters, Variant::SingleOp, StoreMode::Virtual, opts)?
        }
        "padding" => Engine::new_weave(&set, &adapters, Variant::Weave, StoreMode::Padding, opts)?,
        "base-only" => Engine::new_base_only(&set, opts)?,
        other => bail!("unknown deployment {other:?}"),
    };

    let trace_adapters: Vec<(String, String)> = if deployment == "base-only" {
        vec![]
    } else {
        adapters
            .iter()
            .map(|ad| (ad.name.clone(), ad.domain.clone()))
            .collect()
    };
    let mut trace = if trace_adapters.is_empty() {
        // base-only: same arrival pattern, all requests to the base model
        let mut t = Trace::generate(&TraceSpec {
            adapters: vec![("base".into(), "math".into())],
            lambda: a.get_f64("lambda").map_err(anyhow::Error::msg)?,
            alpha: 1.0,
            horizon: a.get_f64("horizon").map_err(anyhow::Error::msg)?,
            vocab: cfg.vocab,
            seed: a.get_usize("seed").map_err(anyhow::Error::msg)? as u64,
        });
        for e in &mut t.events {
            e.adapter = None;
        }
        t
    } else {
        Trace::generate(&TraceSpec {
            adapters: trace_adapters,
            lambda: a.get_f64("lambda").map_err(anyhow::Error::msg)?,
            alpha: a.get_f64("alpha").map_err(anyhow::Error::msg)?,
            horizon: a.get_f64("horizon").map_err(anyhow::Error::msg)?,
            vocab: cfg.vocab,
            seed: a.get_usize("seed").map_err(anyhow::Error::msg)? as u64,
        })
    };
    // keep prompts + outputs within the model's bucket/KV budget
    let max_prompt = cfg.buckets.last().copied().unwrap_or(64).min(cfg.kv_cap / 2);
    let max_new = (cfg.kv_cap / 8).max(1);
    for e in &mut trace.events {
        e.prompt.truncate(max_prompt);
        e.max_new_tokens = e.max_new_tokens.clamp(1, max_new);
    }
    println!(
        "replaying {} requests over {:.1}s against {deployment} ({})...",
        trace.len(),
        a.get_f64("horizon").map_err(anyhow::Error::msg)?,
        cfg.name
    );
    let outcome = server::replay(&mut engine, &trace)?;
    println!("{}", outcome.report.row(&format!("{deployment}/{}", cfg.name)));
    if outcome.rejected > 0 {
        println!("rejected: {}", outcome.rejected);
    }
    Ok(())
}

fn gen_adapters(argv: Vec<String>) -> Result<()> {
    let a = Args::new("expertweave gen-adapters", "write Table-1 .esft checkpoints")
        .opt("config", Some("small"), "artifact config")
        .opt("out", Some("adapters"), "output directory")
        .opt("seed", Some("42"), "generator seed")
        .parse(argv)
        .map_err(anyhow::Error::msg)?;
    let set = artifact_set(&a.get_or("config", "small"))?;
    let cfg = set.config;
    let dir = PathBuf::from(a.get_or("out", "adapters"));
    std::fs::create_dir_all(&dir)?;
    let seed: u64 = a.get_usize("seed").map_err(anyhow::Error::msg)? as u64;
    for p in paper_adapter_profiles() {
        let mut p = p;
        p.max_experts = p.max_experts.min(cfg.e_max);
        p.avg_experts = p.avg_experts.min(p.max_experts as f64);
        let ad = synth_adapter(&p, cfg.layers, cfg.num_experts, cfg.hidden, cfg.expert_inter, seed);
        let path = dir.join(format!("{}.esft", ad.name));
        ad.save(&path).with_context(|| format!("write {}", path.display()))?;
        println!(
            "{:<20} max={:<3} avg={:<5.2} S={:.2} {}",
            ad.name,
            ad.max_experts(),
            ad.avg_experts(),
            ad.sparsity(),
            path.display()
        );
    }
    Ok(())
}

fn inspect(argv: Vec<String>) -> Result<()> {
    let a = Args::new("expertweave inspect", "show an artifact set")
        .opt("config", Some("tiny"), "artifact config")
        .parse(argv)
        .map_err(anyhow::Error::msg)?;
    let set = artifact_set(&a.get_or("config", "tiny"))?;
    let c = &set.config;
    println!("config {}", c.name);
    println!(
        "  H={} L={} QH={} KVH={} D={} vocab={}",
        c.hidden, c.layers, c.q_heads, c.kv_heads, c.head_dim, c.vocab
    );
    println!(
        "  experts: M={} top-k={} F={} | adapters: N={} E_max={} G={}",
        c.num_experts, c.top_k, c.expert_inter, c.max_adapters, c.e_max,
        c.total_expert_slots()
    );
    println!("  kv_cap={} max_seqs={} buckets={:?}", c.kv_cap, c.max_seqs, c.buckets);
    println!(
        "  base model ≈ {} (f32), expert = {}/layer/proj",
        expertweave::bench::fmt_bytes(c.base_model_bytes()),
        expertweave::bench::fmt_bytes(c.expert_proj_bytes()),
    );
    let mut t = Table::new(&["file", "variant", "bucket", "out_rows", "gmm_blk", "inputs"]);
    for e in &set.executables {
        t.row(&[
            e.file.file_name().unwrap().to_string_lossy().to_string(),
            e.variant.as_str().to_string(),
            e.bucket.to_string(),
            e.out_rows.to_string(),
            e.gmm_block.to_string(),
            (e.params.len() + e.inputs.len()).to_string(),
        ]);
    }
    t.print("executables");
    Ok(())
}

fn sparsity(argv: Vec<String>) -> Result<()> {
    let a = Args::new("expertweave sparsity", "Table-1 adapter analysis")
        .opt("layers", Some("26"), "layers (26 = paper scale)")
        .opt("e-max", Some("13"), "E_max for fragmentation")
        .parse(argv)
        .map_err(anyhow::Error::msg)?;
    let layers: usize = a.get_usize("layers").map_err(anyhow::Error::msg)?;
    let e_max: usize = a.get_usize("e-max").map_err(anyhow::Error::msg)?;
    let adapters: Vec<_> = paper_adapter_profiles()
        .iter()
        .map(|p| synth_adapter(p, layers, 64, 8, 4, 42))
        .collect();
    let mut t = Table::new(&["adapter", "max#", "avg#", "sparsity"]);
    for ad in &adapters {
        t.row(&[
            ad.name.clone(),
            ad.max_experts().to_string(),
            format!("{:.2}", ad.avg_experts()),
            format!("{:.2}", ad.sparsity()),
        ]);
    }
    t.print("Table 1 — adapter sparsity");
    println!(
        "F_mem (M=64, E_max={e_max}): {:.2}   adapter-only: {:.2}",
        fragmentation_factor(&adapters, 64, e_max),
        adapter_fragmentation_factor(&adapters, e_max)
    );
    Ok(())
}
