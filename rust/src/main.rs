//! `expertweave` — the leader CLI.
//!
//! Subcommands:
//! * `serve`        — replay a synthetic workload trace against a
//!                    deployment (weave / base-only / merged) and print
//!                    the serving report. `--backend sim` needs no
//!                    artifacts. With `--listen <addr>` it serves
//!                    NDJSON requests over TCP instead (token streams,
//!                    cancel, drain) — see `serving::frontend`.
//! * `fleet`        — replay a trace against a coordinated multi-replica
//!                    fleet (routing policy, adapter lifecycle, admission
//!                    control) on the sim backend. With `--listen <addr>`
//!                    it serves the fleet online over the same NDJSON
//!                    frontend `serve --listen` uses (docs/PROTOCOL.md).
//! * `loadgen`      — open-loop load generator: Poisson arrivals at a
//!                    target rate against an in-process fleet (sweeping
//!                    routing policies → BENCH_fleet_online.json) or a
//!                    remote NDJSON server (`--connect`).
//! * `gen-adapters` — synthesize the Table-1 ESFT adapters for a config
//!                    and write `.esft` checkpoints.
//! * `inspect`      — show an artifact set (config, executables, ABI).
//! * `sparsity`     — print the Table-1 sparsity/fragmentation analysis.
//!
//! Examples:
//! ```text
//! expertweave inspect --config tiny
//! expertweave gen-adapters --config small --out /tmp/adapters
//! expertweave serve --config tiny --adapters 2 --lambda 5 --horizon 10
//! expertweave serve --backend sim --adapters 4 --lambda 10 --horizon 5
//! expertweave serve --backend sim --adapters 2 --listen 127.0.0.1:7070 \
//!             --metrics-listen 127.0.0.1:9464 --trace-out /tmp/trace.json
//! expertweave fleet --replicas 3 --adapters 6 --policy affinity --horizon 6
//! expertweave fleet --replicas 2 --adapters 4 --policy deadline --listen 127.0.0.1:7071
//! expertweave loadgen --replicas 2 --rate 50 --deadline-ms 300
//! expertweave loadgen --connect 127.0.0.1:7071 --rate 40 --deadline-ms 250
//! expertweave loadgen --connect 127.0.0.1:7071 --rate 40 --kill-replica 0@1500
//! ```

use anyhow::{bail, Context, Result};
use expertweave::adapters::generator::{
    adapter_fragmentation_factor, fragmentation_factor, paper_adapter_profiles, synth_adapter,
    synth_fleet_adapters,
};
use expertweave::bench::Table;
use expertweave::coordinator::{Coordinator, CoordinatorConfig, RoutingPolicy};
use expertweave::engine::{Engine, EngineOptions};
use expertweave::model::ModelConfig;
use expertweave::runtime::{ArtifactSet, SimPerf, Variant};
use expertweave::server;
use expertweave::obs::expo::MetricsListener;
use expertweave::util::args::Args;
use expertweave::util::logging::{set_level, Level};
use expertweave::{log_error, log_info, log_warn};
use expertweave::weights::StoreMode;
use expertweave::workload::trace::{Trace, TraceSpec};
use expertweave::workload::OpenLoopSpec;
use std::path::PathBuf;

fn main() {
    expertweave::obs::expo::mark_process_start();
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        eprintln!(
            "usage: expertweave <serve|fleet|loadgen|gen-adapters|inspect|sparsity> [options]"
        );
        std::process::exit(2);
    }
    let cmd = argv.remove(0);
    let result = match cmd.as_str() {
        "serve" => serve(argv),
        "fleet" => fleet(argv),
        "loadgen" => loadgen(argv),
        "gen-adapters" => gen_adapters(argv),
        "inspect" => inspect(argv),
        "sparsity" => sparsity(argv),
        other => {
            log_error!("main", "unknown command {other:?}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        log_error!("main", "error: {e:#}");
        std::process::exit(1);
    }
}

/// Apply the shared `--quiet`/`--verbose` pair: quiet wins (errors
/// only), verbose turns on debug, otherwise the default level stands.
fn apply_log_flags(a: &Args) {
    if a.has_flag("quiet") {
        set_level(Level::Error);
    } else if a.has_flag("verbose") {
        set_level(Level::Debug);
    }
}

/// Spawn the std-only Prometheus listener over `regs` when
/// `--metrics-listen` was given (shared by `serve` and `fleet`).
fn spawn_metrics(
    a: &Args,
    regs: Vec<std::sync::Arc<expertweave::obs::ObsRegistry>>,
) -> Result<Option<MetricsListener>> {
    let Some(addr) = a.get("metrics-listen") else {
        return Ok(None);
    };
    let listener = MetricsListener::spawn(&addr, move || expertweave::obs::expo::render(&regs))
        .with_context(|| format!("bind metrics listener {addr}"))?;
    log_info!("metrics", "Prometheus exposition on http://{}/metrics", listener.local_addr());
    Ok(Some(listener))
}

/// Like [`spawn_metrics`] but for a fleet: renders the membership /
/// failover families (`expertweave_fleet_replicas`,
/// `expertweave_replica_suspect`, reroute counters, ...) alongside the
/// merged per-replica registries — and keeps tracking replicas that
/// join at runtime, which a fixed registry list would miss.
fn spawn_metrics_fleet(
    a: &Args,
    fleet: std::sync::Arc<expertweave::obs::FleetObs>,
) -> Result<Option<MetricsListener>> {
    let Some(addr) = a.get("metrics-listen") else {
        return Ok(None);
    };
    let listener =
        MetricsListener::spawn(&addr, move || expertweave::obs::expo::render_fleet(&fleet))
            .with_context(|| format!("bind metrics listener {addr}"))?;
    log_info!("metrics", "Prometheus exposition on http://{}/metrics", listener.local_addr());
    Ok(Some(listener))
}

fn artifact_set(config: &str) -> Result<ArtifactSet> {
    let dir = PathBuf::from("artifacts").join(config);
    ArtifactSet::load(&dir)
}

/// Write the merged fleet Chrome trace and its flight-recorder sidecar
/// (the trace path with its extension replaced by `flightrec.json`)
/// when `--trace-out` was given.
fn write_fleet_trace(
    a: &Args,
    trace: Option<&expertweave::obs::trace::TraceLog>,
    recorders: &[std::sync::Arc<expertweave::obs::flightrec::FlightRecorder>],
) -> Result<()> {
    let Some(path) = a.get("trace-out") else {
        return Ok(());
    };
    let path = PathBuf::from(path);
    let Some(t) = trace else {
        bail!("--trace-out was given but no trace was collected");
    };
    t.write(&path).with_context(|| format!("writing trace to {}", path.display()))?;
    log_info!(
        "fleet",
        "wrote merged fleet trace ({} request span(s)) to {}",
        t.len(),
        path.display()
    );
    let pairs: Vec<(usize, &expertweave::obs::flightrec::FlightRecorder)> =
        recorders.iter().enumerate().map(|(i, fr)| (i, &**fr)).collect();
    let dump = expertweave::obs::flightrec::dump(&pairs);
    let fr_path = path.with_extension("flightrec.json");
    std::fs::write(&fr_path, format!("{dump}\n"))?;
    log_info!("fleet", "wrote flight-recorder dump to {}", fr_path.display());
    Ok(())
}

fn serve(argv: Vec<String>) -> Result<()> {
    let a = Args::new("expertweave serve", "replay a synthetic trace, or serve NDJSON over TCP")
        .opt("backend", Some("pjrt"), "execution backend (pjrt|sim)")
        .opt("config", Some("tiny"), "artifact config (tiny|small); pjrt only")
        .opt("deployment", Some("weave"), "weave|singleop|padding|base-only")
        .opt("adapters", Some("2"), "number of Table-1 adapters to load")
        .opt("listen", None, "serve NDJSON requests on this TCP addr instead of replaying")
        .opt("metrics-listen", None, "serve Prometheus text metrics (/metrics) on this TCP addr")
        .opt("trace-out", None, "write request phase spans as Chrome-trace JSON to this path")
        .opt("queue-cap", Some("0"), "admission queue bound (0 = unbounded); listen mode")
        .opt("lambda", Some("2.0"), "aggregate arrival rate (req/s)")
        .opt("alpha", Some("1.0"), "power-law skew (1 = uniform)")
        .opt("horizon", Some("10.0"), "trace horizon (s)")
        .opt("chunk", Some("256"), "chunked-prefill budget per seq")
        .opt("seed", Some("0"), "workload seed")
        .flag("verbose", "debug logging")
        .flag("quiet", "errors only")
        .parse(argv)
        .map_err(anyhow::Error::msg)?;
    apply_log_flags(&a);
    let backend = a.get_or("backend", "pjrt");
    let set = match backend.as_str() {
        "pjrt" => Some(artifact_set(&a.get_or("config", "tiny"))?),
        "sim" => None,
        other => bail!("unknown backend {other:?} (pjrt|sim)"),
    };
    let cfg = match &set {
        Some(s) => s.config.clone(),
        None => ModelConfig::sim_default(),
    };
    let n: usize = a.get_usize("adapters").map_err(anyhow::Error::msg)?;
    if n > cfg.max_adapters {
        bail!("config supports at most {} adapters", cfg.max_adapters);
    }
    let adapters = synth_fleet_adapters(&cfg, n, 42);

    let opts = EngineOptions {
        chunk: a.get_usize("chunk").map_err(anyhow::Error::msg)?,
        queue_cap: a.get_usize("queue-cap").map_err(anyhow::Error::msg)?,
        ..Default::default()
    };
    let deployment = a.get_or("deployment", "weave");
    let mut engine = match (&set, deployment.as_str()) {
        (Some(set), "weave") => {
            Engine::new_weave(set, &adapters, Variant::Weave, StoreMode::Virtual, opts)?
        }
        (Some(set), "singleop") => {
            Engine::new_weave(set, &adapters, Variant::SingleOp, StoreMode::Virtual, opts)?
        }
        (Some(set), "padding") => {
            Engine::new_weave(set, &adapters, Variant::Weave, StoreMode::Padding, opts)?
        }
        (Some(set), "base-only") => Engine::new_base_only(set, opts)?,
        (None, "weave") => Engine::sim_weave(
            &cfg,
            SimPerf::default(),
            &adapters,
            Variant::Weave,
            StoreMode::Virtual,
            opts,
        )?,
        (None, "singleop") => Engine::sim_weave(
            &cfg,
            SimPerf::default(),
            &adapters,
            Variant::SingleOp,
            StoreMode::Virtual,
            opts,
        )?,
        (None, "padding") => Engine::sim_weave(
            &cfg,
            SimPerf::default(),
            &adapters,
            Variant::Weave,
            StoreMode::Padding,
            opts,
        )?,
        (None, "base-only") => Engine::sim_base_only(&cfg, SimPerf::default(), opts)?,
        (_, other) => bail!("unknown deployment {other:?}"),
    };

    if a.get("trace-out").is_some() {
        engine.enable_trace();
    }
    let mut metrics = spawn_metrics(&a, vec![engine.obs()])?;
    let write_trace = |engine: &Engine| -> Result<()> {
        if let Some(path) = a.get("trace-out") {
            let path = PathBuf::from(path);
            engine.write_trace(&path)?;
            log_info!(
                "serve",
                "wrote {} request span(s) to {}",
                engine.trace_len(),
                path.display()
            );
            // the black-box dump rides along: recent request/step events
            // from the always-on flight recorder
            let fr = engine.flight_recorder();
            let dump = expertweave::obs::flightrec::dump(&[(0, &*fr)]);
            let fr_path = path.with_extension("flightrec.json");
            std::fs::write(&fr_path, format!("{dump}\n"))?;
            log_info!("serve", "wrote flight-recorder dump to {}", fr_path.display());
        }
        Ok(())
    };

    // --listen: online NDJSON-over-TCP serving instead of trace replay
    if let Some(addr) = a.get("listen") {
        let frontend = expertweave::serving::frontend::NdjsonServer::bind(&addr)?;
        log_info!(
            "serve",
            "serving {deployment}/{} ({backend}) on {} — NDJSON per line; \
             {{\"op\":\"drain\"}} to stop",
            cfg.name,
            frontend.local_addr()?
        );
        for name in engine.resident_adapters() {
            log_info!("serve", "  adapter: {name}");
        }
        frontend.run(&mut engine)?;
        if let Some(l) = metrics.as_mut() {
            l.shutdown();
        }
        write_trace(&engine)?;
        println!("{}", engine.report().row(&format!("{deployment}/{}", cfg.name)));
        return Ok(());
    }

    let trace_adapters: Vec<(String, String)> = if deployment == "base-only" {
        vec![]
    } else {
        adapters
            .iter()
            .map(|ad| (ad.name.clone(), ad.domain.clone()))
            .collect()
    };
    let mut trace = if trace_adapters.is_empty() {
        // base-only: same arrival pattern, all requests to the base model
        let mut t = Trace::generate(&TraceSpec {
            adapters: vec![("base".into(), "math".into())],
            lambda: a.get_f64("lambda").map_err(anyhow::Error::msg)?,
            alpha: 1.0,
            horizon: a.get_f64("horizon").map_err(anyhow::Error::msg)?,
            vocab: cfg.vocab,
            seed: a.get_usize("seed").map_err(anyhow::Error::msg)? as u64,
        });
        for e in &mut t.events {
            e.adapter = None;
        }
        t
    } else {
        Trace::generate(&TraceSpec {
            adapters: trace_adapters,
            lambda: a.get_f64("lambda").map_err(anyhow::Error::msg)?,
            alpha: a.get_f64("alpha").map_err(anyhow::Error::msg)?,
            horizon: a.get_f64("horizon").map_err(anyhow::Error::msg)?,
            vocab: cfg.vocab,
            seed: a.get_usize("seed").map_err(anyhow::Error::msg)? as u64,
        })
    };
    // keep prompts + outputs within the model's bucket/KV budget
    let max_prompt = cfg.buckets.last().copied().unwrap_or(64).min(cfg.kv_cap / 2);
    trace.clip(max_prompt, (cfg.kv_cap / 8).max(1));
    log_info!(
        "serve",
        "replaying {} requests over {:.1}s against {deployment} ({}, {backend})...",
        trace.len(),
        a.get_f64("horizon").map_err(anyhow::Error::msg)?,
        cfg.name
    );
    let outcome = server::replay(&mut engine, &trace)?;
    if let Some(l) = metrics.as_mut() {
        l.shutdown();
    }
    write_trace(&engine)?;
    println!("{}", outcome.report.row(&format!("{deployment}/{}", cfg.name)));
    if outcome.rejected > 0 {
        println!("rejected: {}", outcome.rejected);
    }
    Ok(())
}

fn fleet(argv: Vec<String>) -> Result<()> {
    let a = Args::new(
        "expertweave fleet",
        "coordinated multi-replica replay, or online NDJSON fleet serving (sim backend)",
    )
    .opt("replicas", Some("3"), "engine replicas")
    .opt("adapters", Some("6"), "distinct adapters in the workload")
    .opt("capacity", Some("3"), "resident-adapter budget per replica")
    .opt("policy", Some("affinity"), "rr|jsq|affinity|deadline")
    .opt("listen", None, "serve NDJSON requests on this TCP addr instead of replaying")
    .opt("metrics-listen", None, "serve Prometheus text metrics (/metrics) on this TCP addr")
    .opt("trace-out", None, "write the merged fleet Chrome-trace JSON to this path")
    .opt("lambda", Some("24.0"), "aggregate arrival rate (req/s)")
    .opt("alpha", Some("0.3"), "power-law skew (1 = uniform)")
    .opt("horizon", Some("6.0"), "trace horizon (s)")
    .opt("queue-cap", Some("32"), "per-adapter outstanding cap (0 = off)")
    .opt("replicate-rps", Some("0"), "hot-adapter replication threshold (0 = off)")
    .opt("chunk", Some("64"), "chunked-prefill budget per seq")
    .opt("seed", Some("0"), "workload seed")
    .flag("verbose", "debug logging")
    .flag("quiet", "errors only")
    .parse(argv)
    .map_err(anyhow::Error::msg)?;
    apply_log_flags(&a);
    let replicas: usize = a.get_usize("replicas").map_err(anyhow::Error::msg)?;
    let n_adapters: usize = a.get_usize("adapters").map_err(anyhow::Error::msg)?;
    let capacity: usize = a.get_usize("capacity").map_err(anyhow::Error::msg)?;
    let policy = RoutingPolicy::parse(&a.get_or("policy", "affinity"))?;
    let seed: u64 = a.get_usize("seed").map_err(anyhow::Error::msg)? as u64;
    let replicate_rps: f64 = a.get_f64("replicate-rps").map_err(anyhow::Error::msg)?;

    let mut cfg = ModelConfig::sim_default();
    cfg.max_adapters = capacity.max(1);
    let adapters = synth_fleet_adapters(&cfg, n_adapters, 42);

    let mut trace = Trace::generate(&TraceSpec {
        adapters: adapters
            .iter()
            .map(|ad| (ad.name.clone(), ad.domain.clone()))
            .collect(),
        lambda: a.get_f64("lambda").map_err(anyhow::Error::msg)?,
        alpha: a.get_f64("alpha").map_err(anyhow::Error::msg)?,
        horizon: a.get_f64("horizon").map_err(anyhow::Error::msg)?,
        vocab: cfg.vocab,
        seed,
    });
    let max_prompt = cfg.buckets.last().copied().unwrap_or(64).min(cfg.kv_cap / 2);
    trace.clip(max_prompt, (cfg.kv_cap / 16).max(1));

    let coord_cfg = CoordinatorConfig {
        replicas,
        policy,
        adapter_capacity: capacity,
        queue_cap: a.get_usize("queue-cap").map_err(anyhow::Error::msg)?,
        replicate_rps: if replicate_rps > 0.0 { replicate_rps } else { f64::INFINITY },
        max_copies: replicas.min(2).max(1),
        ..Default::default()
    };
    let opts = EngineOptions {
        chunk: a.get_usize("chunk").map_err(anyhow::Error::msg)?,
        page_size: 64 << 10,
        ..Default::default()
    };

    // --listen: online NDJSON fleet serving instead of trace replay.
    // The frontend router is the exact one `serve --listen` uses — the
    // coordinator is just another ServingBackend behind it.
    if let Some(addr) = a.get("listen") {
        let frontend = expertweave::serving::frontend::NdjsonServer::bind(&addr)?;
        log_info!(
            "fleet",
            "fleet serving on {} — {replicas} sim replicas x capacity {capacity}, \
             policy {policy}; NDJSON per line; {{\"op\":\"drain\"}} to stop",
            frontend.local_addr()?
        );
        for ad in &adapters {
            log_info!("fleet", "  adapter: {}", ad.name);
        }
        let spawn_cfg = cfg.clone();
        let started = std::time::Instant::now();
        let mut coord = Coordinator::launch(
            coord_cfg,
            move |i| {
                let cfg = spawn_cfg.clone();
                let opts = EngineOptions { seed: i as u64, ..opts.clone() };
                Box::new(move || {
                    Engine::sim_weave(
                        &cfg,
                        SimPerf::default(),
                        &[],
                        Variant::Weave,
                        StoreMode::Virtual,
                        opts,
                    )
                })
            },
            adapters,
        )?;
        if a.get("trace-out").is_some() {
            coord.enable_trace()?;
        }
        let recorders = coord.flight_recorders();
        let mut metrics = spawn_metrics_fleet(&a, coord.fleet_obs())?;
        // run() returns once a client drained the fleet: every replica
        // is idle, so finish() only collects reports and joins threads
        frontend.run(&mut coord)?;
        if let Some(l) = metrics.as_mut() {
            l.shutdown();
        }
        let (per_replica, stats, trace) = coord.finish_traced(started)?;
        write_fleet_trace(&a, trace.as_ref(), &recorders)?;
        for (i, r) in per_replica.iter().enumerate() {
            println!("{}", r.row(&format!("replica-{i}")));
        }
        println!("  {}", stats.row());
        return Ok(());
    }

    log_info!(
        "fleet",
        "fleet: {} replicas x capacity {} | {} adapters | policy {policy} | {} requests",
        replicas,
        capacity,
        n_adapters,
        trace.len()
    );
    // launched here (not via server::replay_fleet) so --metrics-listen
    // can observe the replicas while the replay runs
    let spawn_cfg = cfg.clone();
    let mut coord = Coordinator::launch(
        coord_cfg,
        move |i| {
            let cfg = spawn_cfg.clone();
            let opts = EngineOptions { seed: i as u64, ..opts.clone() };
            Box::new(move || {
                Engine::sim_weave(
                    &cfg,
                    SimPerf::default(),
                    &[],
                    Variant::Weave,
                    StoreMode::Virtual,
                    opts,
                )
            })
        },
        adapters,
    )?;
    if a.get("trace-out").is_some() {
        coord.enable_trace()?;
    }
    let recorders = coord.flight_recorders();
    let mut metrics = spawn_metrics_fleet(&a, coord.fleet_obs())?;
    let outcome = coord.replay(&trace)?;
    if let Some(l) = metrics.as_mut() {
        l.shutdown();
    }
    write_fleet_trace(&a, outcome.trace.as_ref(), &recorders)?;
    println!("{}", outcome.report.row(&format!("fleet/{policy}")));
    for (i, r) in outcome.per_replica.iter().enumerate() {
        println!("{}", r.row(&format!("  replica-{i}")));
    }
    println!("  {}", outcome.stats.row());
    println!(
        "  goodput: {:.2} completions/s over {:.1}s",
        outcome.report.goodput(),
        outcome.report.wall
    );
    Ok(())
}

fn loadgen(argv: Vec<String>) -> Result<()> {
    let a = Args::new(
        "expertweave loadgen",
        "open-loop load generator: in-process fleet policy sweep, or a remote NDJSON server",
    )
    .opt("connect", None, "drive a remote NDJSON server instead of an in-process fleet")
    .opt("adapter-names", None, "adapter names to address, comma-separated (remote mode)")
    .opt("policies", Some("rr,jsq,affinity,deadline"), "routing policies to sweep (fleet mode)")
    .opt("replicas", Some("2"), "fleet replicas (fleet mode)")
    .opt("adapters", Some("4"), "distinct adapters (fleet mode)")
    .opt("capacity", Some("3"), "resident-adapter budget per replica (fleet mode)")
    .opt("queue-cap", Some("0"), "per-adapter outstanding cap (0 = off; fleet mode)")
    .opt("rate", Some("50.0"), "offered arrival rate (req/s, Poisson)")
    .opt("horizon", Some("4.0"), "arrival horizon (s)")
    .opt("deadline-ms", Some("300"), "per-request completion deadline (0 = none)")
    .opt("prompt", Some("24"), "mean prompt length (tokens)")
    .opt("max-new", Some("8"), "output budget per request")
    .opt("alpha", Some("0.5"), "power-law adapter skew (1 = uniform)")
    .opt("prefix-overlap", Some("0"), "percent of each prompt drawn from shared preambles (0-100)")
    .opt("sampled-frac", Some("0"), "percent of requests issued as seeded sampled decodes (0-100)")
    .opt("vocab", Some("512"), "prompt-token vocabulary bound (remote mode)")
    .opt("seed", Some("0"), "arrival-process seed")
    .opt("kill-replica", None, "chaos: kill fleet replica I, T ms into the run, as \"I@T\" (remote mode)")
    .opt("out", Some("target/bench_results/BENCH_fleet_online.json"), "result JSON path")
    .flag("verbose", "debug logging")
    .flag("quiet", "errors only")
    .parse(argv)
    .map_err(anyhow::Error::msg)?;
    apply_log_flags(&a);
    let rate = a.get_f64("rate").map_err(anyhow::Error::msg)?;
    let horizon = a.get_f64("horizon").map_err(anyhow::Error::msg)?;
    let deadline_ms = a.get_f64("deadline-ms").map_err(anyhow::Error::msg)?;
    let ol = OpenLoopSpec {
        rate,
        horizon,
        adapters: Vec::new(),
        alpha: a.get_f64("alpha").map_err(anyhow::Error::msg)?,
        prompt_len: a.get_usize("prompt").map_err(anyhow::Error::msg)?,
        max_new: a.get_usize("max-new").map_err(anyhow::Error::msg)?,
        deadline: (deadline_ms > 0.0)
            .then(|| std::time::Duration::from_secs_f64(deadline_ms / 1e3)),
        vocab: a.get_usize("vocab").map_err(anyhow::Error::msg)?,
        prefix_overlap: a.get_f64("prefix-overlap").map_err(anyhow::Error::msg)? / 100.0,
        sampled_frac: a.get_f64("sampled-frac").map_err(anyhow::Error::msg)? / 100.0,
        seed: a.get_usize("seed").map_err(anyhow::Error::msg)? as u64,
    };

    // chaos hook: "<replica>@<ms>" — kill one fleet replica mid-run
    // through a second client connection (PROTOCOL.md v4 kill-replica)
    let chaos: Option<(usize, f64)> = match a.get("kill-replica") {
        None => None,
        Some(s) => {
            let (i, at) = s
                .split_once('@')
                .with_context(|| format!("--kill-replica wants \"<replica>@<ms>\", got {s:?}"))?;
            Some((
                i.trim().parse::<usize>().with_context(|| format!("bad replica index {i:?}"))?,
                at.trim().parse::<f64>().with_context(|| format!("bad kill time {at:?}"))?,
            ))
        }
    };

    // remote mode: a thin NDJSON client is just another ServingBackend
    if let Some(addr) = a.get("connect") {
        let mut spec = ol;
        if let Some(names) = a.get("adapter-names") {
            spec.adapters = names
                .split(',')
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty())
                .collect();
        }
        let mut client = expertweave::serving::frontend::NdjsonClient::connect(&addr)?;
        log_info!("loadgen", "driving {addr} open-loop at {rate} req/s for {horizon}s...");
        let killer = chaos.map(|(replica, at_ms)| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                std::thread::sleep(std::time::Duration::from_secs_f64(at_ms / 1e3));
                match expertweave::serving::frontend::NdjsonClient::connect(&addr) {
                    Ok(mut c) => {
                        use expertweave::serving::ServingBackend;
                        c.kill_replica(replica);
                        log_info!("loadgen", "chaos: kill-replica {replica} sent at {at_ms} ms");
                    }
                    Err(e) => log_warn!("loadgen", "chaos: connect for kill failed: {e:#}"),
                }
            })
        });
        let outcome = expertweave::workload::openloop::drive(&mut client, &spec)?;
        if let Some(k) = killer {
            let _ = k.join();
        }
        println!("{}", outcome.row("remote"));
        return Ok(());
    }
    if chaos.is_some() {
        bail!("--kill-replica drives a live server: pair it with --connect");
    }

    // fleet mode: identical arrival process against each routing policy
    let policies = a
        .get_or("policies", "rr,jsq,affinity,deadline")
        .split(',')
        .map(|s| RoutingPolicy::parse(s.trim()))
        .collect::<Result<Vec<_>>>()?;
    // perf defaults to the shared near-saturation hardware model
    // (FleetLoadSpec::near_saturation_perf), same as the fig12 bench
    let spec = expertweave::workload::openloop::FleetLoadSpec {
        replicas: a.get_usize("replicas").map_err(anyhow::Error::msg)?,
        n_adapters: a.get_usize("adapters").map_err(anyhow::Error::msg)?,
        adapter_capacity: a.get_usize("capacity").map_err(anyhow::Error::msg)?,
        queue_cap: a.get_usize("queue-cap").map_err(anyhow::Error::msg)?,
        open_loop: ol,
        ..Default::default()
    };
    log_info!(
        "loadgen",
        "loadgen: {} replicas | {} adapters | {rate} req/s for {horizon}s | deadline {}",
        spec.replicas,
        spec.n_adapters,
        if deadline_ms > 0.0 { format!("{deadline_ms} ms") } else { "none".into() },
    );
    let mut rows = Vec::new();
    for policy in policies {
        let row = expertweave::workload::openloop::run_fleet_open_loop(&spec, policy)?;
        println!("{}", row.outcome.row(&policy.to_string()));
        println!("  {}", row.stats.row());
        println!("  {}", row.phases.row());
        rows.push(row);
    }
    let json = expertweave::workload::openloop::fleet_online_json(&spec, &rows);
    let out = std::path::PathBuf::from(a.get_or(
        "out",
        "target/bench_results/BENCH_fleet_online.json",
    ));
    if let Some(dir) = out.parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(&out, format!("{json}\n"))?;
    log_info!("loadgen", "wrote {}", out.display());
    let miss = |p: RoutingPolicy| {
        rows.iter()
            .find(|r| r.policy == p)
            .map(|r| r.outcome.deadline_miss_rate())
    };
    if let (Some(dl), Some(rr)) =
        (miss(RoutingPolicy::DeadlineAware), miss(RoutingPolicy::RoundRobin))
    {
        println!(
            "deadline-miss rate: deadline-aware {:.1}% vs round-robin {:.1}%",
            dl * 100.0,
            rr * 100.0
        );
    }
    Ok(())
}

fn gen_adapters(argv: Vec<String>) -> Result<()> {
    let a = Args::new("expertweave gen-adapters", "write Table-1 .esft checkpoints")
        .opt("config", Some("small"), "artifact config")
        .opt("out", Some("adapters"), "output directory")
        .opt("seed", Some("42"), "generator seed")
        .parse(argv)
        .map_err(anyhow::Error::msg)?;
    let set = artifact_set(&a.get_or("config", "small"))?;
    let cfg = set.config;
    let dir = PathBuf::from(a.get_or("out", "adapters"));
    std::fs::create_dir_all(&dir)?;
    let seed: u64 = a.get_usize("seed").map_err(anyhow::Error::msg)? as u64;
    for p in paper_adapter_profiles() {
        let mut p = p;
        p.max_experts = p.max_experts.min(cfg.e_max);
        p.avg_experts = p.avg_experts.min(p.max_experts as f64);
        let ad = synth_adapter(&p, cfg.layers, cfg.num_experts, cfg.hidden, cfg.expert_inter, seed);
        let path = dir.join(format!("{}.esft", ad.name));
        ad.save(&path).with_context(|| format!("write {}", path.display()))?;
        println!(
            "{:<20} max={:<3} avg={:<5.2} S={:.2} {}",
            ad.name,
            ad.max_experts(),
            ad.avg_experts(),
            ad.sparsity(),
            path.display()
        );
    }
    Ok(())
}

fn inspect(argv: Vec<String>) -> Result<()> {
    let a = Args::new("expertweave inspect", "show an artifact set")
        .opt("config", Some("tiny"), "artifact config")
        .parse(argv)
        .map_err(anyhow::Error::msg)?;
    let set = artifact_set(&a.get_or("config", "tiny"))?;
    let c = &set.config;
    println!("config {}", c.name);
    println!(
        "  H={} L={} QH={} KVH={} D={} vocab={}",
        c.hidden, c.layers, c.q_heads, c.kv_heads, c.head_dim, c.vocab
    );
    println!(
        "  experts: M={} top-k={} F={} | adapters: N={} E_max={} G={}",
        c.num_experts, c.top_k, c.expert_inter, c.max_adapters, c.e_max,
        c.total_expert_slots()
    );
    println!("  kv_cap={} max_seqs={} buckets={:?}", c.kv_cap, c.max_seqs, c.buckets);
    println!(
        "  base model ≈ {} (f32), expert = {}/layer/proj",
        expertweave::bench::fmt_bytes(c.base_model_bytes()),
        expertweave::bench::fmt_bytes(c.expert_proj_bytes()),
    );
    let mut t = Table::new(&["file", "variant", "bucket", "out_rows", "gmm_blk", "inputs"]);
    for e in &set.executables {
        t.row(&[
            e.file.file_name().unwrap().to_string_lossy().to_string(),
            e.variant.as_str().to_string(),
            e.bucket.to_string(),
            e.out_rows.to_string(),
            e.gmm_block.to_string(),
            (e.params.len() + e.inputs.len()).to_string(),
        ]);
    }
    t.print("executables");
    Ok(())
}

fn sparsity(argv: Vec<String>) -> Result<()> {
    let a = Args::new("expertweave sparsity", "Table-1 adapter analysis")
        .opt("layers", Some("26"), "layers (26 = paper scale)")
        .opt("e-max", Some("13"), "E_max for fragmentation")
        .parse(argv)
        .map_err(anyhow::Error::msg)?;
    let layers: usize = a.get_usize("layers").map_err(anyhow::Error::msg)?;
    let e_max: usize = a.get_usize("e-max").map_err(anyhow::Error::msg)?;
    let adapters: Vec<_> = paper_adapter_profiles()
        .iter()
        .map(|p| synth_adapter(p, layers, 64, 8, 4, 42))
        .collect();
    let mut t = Table::new(&["adapter", "max#", "avg#", "sparsity"]);
    for ad in &adapters {
        t.row(&[
            ad.name.clone(),
            ad.max_experts().to_string(),
            format!("{:.2}", ad.avg_experts()),
            format!("{:.2}", ad.sparsity()),
        ]);
    }
    t.print("Table 1 — adapter sparsity");
    println!(
        "F_mem (M=64, E_max={e_max}): {:.2}   adapter-only: {:.2}",
        fragmentation_factor(&adapters, 64, e_max),
        adapter_fragmentation_factor(&adapters, e_max)
    );
    Ok(())
}
