//! Simulated accelerator memory budget.
//!
//! The paper's memory-efficiency experiments (Fig. 9) run on one 64 GB
//! Ascend NPU. CPU PJRT has no such boundary, so every component that
//! would consume device memory (expert weight pages, KV cache slots,
//! activation reserve) charges a [`DeviceMemory`] ledger instead. All
//! capacity/OOM numbers reported by the benches come from this ledger
//! driven by the *real* allocator logic (`vmm::expert_manager` in
//! accounting mode), making the paper-scale math exact.

use anyhow::{bail, Result};
use std::sync::{Arc, Mutex};

/// A byte-granular device memory ledger with OOM semantics.
#[derive(Debug)]
pub struct DeviceMemory {
    capacity: usize,
    used: usize,
    peak: usize,
}

impl DeviceMemory {
    pub fn new(capacity: usize) -> Self {
        DeviceMemory { capacity, used: 0, peak: 0 }
    }

    /// Shared handle (weights manager + KV cache charge the same device).
    pub fn shared(capacity: usize) -> Arc<Mutex<DeviceMemory>> {
        Arc::new(Mutex::new(DeviceMemory::new(capacity)))
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn used(&self) -> usize {
        self.used
    }

    pub fn free(&self) -> usize {
        self.capacity - self.used
    }

    pub fn peak(&self) -> usize {
        self.peak
    }

    /// Charge `bytes`; fails with an OOM error if the budget is exceeded.
    pub fn alloc(&mut self, bytes: usize) -> Result<()> {
        if self.used + bytes > self.capacity {
            bail!(
                "device OOM: need {bytes} B, {} B free of {} B",
                self.free(),
                self.capacity
            );
        }
        self.used += bytes;
        self.peak = self.peak.max(self.used);
        Ok(())
    }

    /// Release `bytes` back to the budget.
    pub fn release(&mut self, bytes: usize) {
        debug_assert!(bytes <= self.used, "release of {bytes} B exceeds used {}", self.used);
        self.used = self.used.saturating_sub(bytes);
    }
}

/// Convenience: gibibytes.
pub const fn gib(n: usize) -> usize {
    n << 30
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_release_and_oom() {
        let mut d = DeviceMemory::new(100);
        d.alloc(60).unwrap();
        assert_eq!(d.free(), 40);
        assert!(d.alloc(41).is_err());
        d.alloc(40).unwrap();
        assert_eq!(d.free(), 0);
        d.release(100);
        assert_eq!(d.used(), 0);
        assert_eq!(d.peak(), 100);
    }

    #[test]
    fn failed_alloc_charges_nothing() {
        let mut d = DeviceMemory::new(10);
        assert!(d.alloc(11).is_err());
        assert_eq!(d.used(), 0);
    }
}
