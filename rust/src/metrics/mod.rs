//! Serving metrics: the paper's four headline numbers — prefill
//! throughput, TTFT, decode throughput, TPOT — plus per-step engine
//! telemetry.
//!
//! This module is the *post-hoc* side of the telemetry story: exact
//! per-request records and full sample distributions, aggregated into a
//! [`Report`] once a run finishes. Its live complement is
//! [`crate::obs`] — lock-free atomic counters and preallocated
//! histograms that can be scraped mid-run (Prometheus exposition, the
//! NDJSON `stats` frame) without draining the engine. Both record from
//! the same step loop; see `docs/OBSERVABILITY.md` for how the two
//! surfaces relate.

use crate::util::stats::{Samples, Summary};
use std::time::Duration;

/// Per-request lifecycle record.
#[derive(Debug, Clone)]
pub struct RequestRecord {
    pub id: u64,
    pub adapter: Option<String>,
    pub prompt_tokens: usize,
    pub output_tokens: usize,
    /// Arrival → first output token.
    pub ttft: Duration,
    /// Mean time per output token after the first.
    pub tpot: Option<Duration>,
    /// Arrival → completion.
    pub e2e: Duration,
}

/// Aggregated serving metrics over a run.
#[derive(Debug, Default)]
pub struct MetricsCollector {
    records: Vec<RequestRecord>,
    pub step_count: usize,
    pub step_time: Samples,
    /// Time spent inside PJRT execute (XLA compute) per step.
    pub execute_time: Samples,
    pub batched_tokens: Samples,
    run_wall: Option<Duration>,
    rejected: usize,
    aborted: usize,
    deadline_missed: usize,
    kv_pages_shared: usize,
    kv_pages_cow: usize,
}

/// Final report of a serving run (one Fig. 5/6/10 data point).
#[derive(Debug, Clone)]
pub struct Report {
    pub requests: usize,
    pub prefill_tokens: usize,
    pub decode_tokens: usize,
    /// tokens / s over the run wall time.
    pub prefill_throughput: f64,
    pub decode_throughput: f64,
    pub ttft: Summary,
    pub tpot: Summary,
    pub e2e: Summary,
    pub wall: f64,
    /// Requests refused at submit time (unknown adapter, over KV
    /// capacity, ...).
    pub rejected: usize,
    /// Requests shed by admission control before reaching an engine
    /// (bounded per-adapter queues, no replica with capacity).
    pub shed: usize,
    /// Admitted requests that did not complete: client cancellations
    /// plus deadline expiries.
    pub aborted: usize,
    /// Subset of `aborted` that hit their deadline (queued requests past
    /// deadline are dropped before ever occupying a batch slot).
    pub deadline_missed: usize,
    /// Peak physical KV pages referenced by more than one sequence over
    /// the run (paged cache prefix sharing; 0 with sharing off).
    pub kv_pages_shared: usize,
    /// Copy-on-write KV page splits performed over the run.
    pub kv_pages_cow: usize,
}

impl MetricsCollector {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn complete_request(&mut self, rec: RequestRecord) {
        self.records.push(rec);
    }

    /// Pre-size the per-step sample buffers for `n` more steps so the
    /// steady-state step loop never reallocates them (the hot-path
    /// zero-allocation test and benches call this before measuring).
    pub fn reserve_steps(&mut self, n: usize) {
        self.step_time.reserve(n);
        self.execute_time.reserve(n);
        self.batched_tokens.reserve(n);
    }

    pub fn record_step(&mut self, wall: Duration, execute: Duration, tokens: usize) {
        self.step_count += 1;
        self.step_time.push(wall.as_secs_f64());
        self.execute_time.push(execute.as_secs_f64());
        self.batched_tokens.push(tokens as f64);
    }

    pub fn set_wall(&mut self, wall: Duration) {
        self.run_wall = Some(wall);
    }

    /// Count a request refused at submit time.
    pub fn record_rejected(&mut self) {
        self.rejected += 1;
    }

    pub fn rejected(&self) -> usize {
        self.rejected
    }

    /// Count an admitted request that ended without completing.
    /// `deadline` marks deadline expiries (vs client cancellations).
    pub fn record_aborted(&mut self, deadline: bool) {
        self.aborted += 1;
        if deadline {
            self.deadline_missed += 1;
        }
    }

    pub fn aborted(&self) -> usize {
        self.aborted
    }

    pub fn completed(&self) -> usize {
        self.records.len()
    }

    /// Publish the paged-cache sharing totals (the engine calls this
    /// when a report is cut; `shared` keeps the high-water mark so a
    /// drained engine still reports the sharing it saw mid-run).
    pub fn set_kv_sharing(&mut self, shared: usize, cow: usize) {
        self.kv_pages_shared = self.kv_pages_shared.max(shared);
        self.kv_pages_cow = cow;
    }

    pub fn records(&self) -> &[RequestRecord] {
        &self.records
    }

    pub fn report(&mut self) -> Report {
        let wall = self
            .run_wall
            .map(|d| d.as_secs_f64())
            .unwrap_or_else(|| self.step_time.sum())
            .max(1e-9);
        let prefill_tokens: usize = self.records.iter().map(|r| r.prompt_tokens).sum();
        let decode_tokens: usize = self.records.iter().map(|r| r.output_tokens).sum();
        let mut ttft = Samples::new();
        let mut tpot = Samples::new();
        let mut e2e = Samples::new();
        for r in &self.records {
            ttft.push(r.ttft.as_secs_f64());
            if let Some(t) = r.tpot {
                tpot.push(t.as_secs_f64());
            }
            e2e.push(r.e2e.as_secs_f64());
        }
        Report {
            requests: self.records.len(),
            prefill_tokens,
            decode_tokens,
            prefill_throughput: prefill_tokens as f64 / wall,
            decode_throughput: decode_tokens as f64 / wall,
            ttft: ttft.summary(),
            tpot: tpot.summary(),
            e2e: e2e.summary(),
            wall,
            rejected: self.rejected,
            // admission control lives above single engines: the fleet
            // coordinator fills this on its aggregate report
            shed: 0,
            aborted: self.aborted,
            deadline_missed: self.deadline_missed,
            kv_pages_shared: self.kv_pages_shared,
            kv_pages_cow: self.kv_pages_cow,
        }
    }
}

impl Report {
    /// Merge per-source reports into one system-level view: requests,
    /// tokens and failure counters add; wall time is the longest source
    /// (or `wall_override`, e.g. the coordinator's replay clock);
    /// throughputs are recomputed over the merged wall; latency
    /// summaries are rebuilt request-weighted from `records`.
    ///
    /// This is the single merge used by both
    /// [`crate::server::aggregate`] (isolated instances) and the fleet
    /// coordinator's aggregate — they previously re-implemented it
    /// independently. Safe on empty input: zero counts, epsilon wall,
    /// NaN latency summaries.
    pub fn merge<'a>(
        parts: impl IntoIterator<Item = &'a Report>,
        records: impl IntoIterator<Item = &'a RequestRecord>,
        wall_override: Option<f64>,
    ) -> Report {
        let mut requests = 0;
        let mut prefill_tokens = 0;
        let mut decode_tokens = 0;
        let mut rejected = 0;
        let mut shed = 0;
        let mut aborted = 0;
        let mut deadline_missed = 0;
        let mut kv_pages_shared = 0;
        let mut kv_pages_cow = 0;
        let mut wall: f64 = 0.0;
        for r in parts {
            requests += r.requests;
            prefill_tokens += r.prefill_tokens;
            decode_tokens += r.decode_tokens;
            rejected += r.rejected;
            shed += r.shed;
            aborted += r.aborted;
            deadline_missed += r.deadline_missed;
            kv_pages_shared += r.kv_pages_shared;
            kv_pages_cow += r.kv_pages_cow;
            wall = wall.max(r.wall);
        }
        let wall = wall_override.unwrap_or(wall).max(1e-9);
        let mut ttft = Samples::new();
        let mut tpot = Samples::new();
        let mut e2e = Samples::new();
        for rec in records {
            ttft.push(rec.ttft.as_secs_f64());
            if let Some(t) = rec.tpot {
                tpot.push(t.as_secs_f64());
            }
            e2e.push(rec.e2e.as_secs_f64());
        }
        Report {
            requests,
            prefill_tokens,
            decode_tokens,
            prefill_throughput: prefill_tokens as f64 / wall,
            decode_throughput: decode_tokens as f64 / wall,
            ttft: ttft.summary(),
            tpot: tpot.summary(),
            e2e: e2e.summary(),
            wall,
            rejected,
            shed,
            aborted,
            deadline_missed,
            kv_pages_shared,
            kv_pages_cow,
        }
    }

    /// An all-zero report: the fleet-merge contribution of a replica
    /// that crashed (or was retired) before producing one. Latency
    /// summaries are NaN, counts zero — [`Report::merge`] treats it as
    /// a no-op input.
    pub fn empty() -> Report {
        Report::merge(
            std::iter::empty::<&Report>(),
            std::iter::empty::<&RequestRecord>(),
            None,
        )
    }

    /// Completed requests per second of wall time — the fleet
    /// experiments' headline number (Fig. 10).
    pub fn goodput(&self) -> f64 {
        self.requests as f64 / self.wall.max(1e-9)
    }

    /// One bench-output row (fixed-width, paper-style).
    pub fn row(&self, label: &str) -> String {
        let mut row = format!(
            "{label:<28} req={:<4} prefill={:>8.1} tok/s decode={:>7.1} tok/s \
             TTFT p50={:>7.1} ms TPOT p50={:>7.1} ms",
            self.requests,
            self.prefill_throughput,
            self.decode_throughput,
            self.ttft.median * 1e3,
            self.tpot.median * 1e3,
        );
        if self.rejected > 0 || self.shed > 0 {
            row.push_str(&format!(
                " rejected={} shed={}",
                self.rejected, self.shed
            ));
        }
        if self.aborted > 0 {
            row.push_str(&format!(
                " aborted={} (deadline={})",
                self.aborted, self.deadline_missed
            ));
        }
        if self.kv_pages_shared > 0 || self.kv_pages_cow > 0 {
            row.push_str(&format!(
                " kv_shared={} cow={}",
                self.kv_pages_shared, self.kv_pages_cow
            ));
        }
        row
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_math() {
        let mut m = MetricsCollector::new();
        for i in 0..4 {
            m.complete_request(RequestRecord {
                id: i,
                adapter: None,
                prompt_tokens: 100,
                output_tokens: 10,
                ttft: Duration::from_millis(50 + i as u64 * 10),
                tpot: Some(Duration::from_millis(20)),
                e2e: Duration::from_millis(300),
            });
        }
        m.set_wall(Duration::from_secs(2));
        m.record_rejected();
        let mut r = m.report();
        assert_eq!(r.requests, 4);
        assert_eq!(r.prefill_tokens, 400);
        assert!((r.prefill_throughput - 200.0).abs() < 1e-9);
        assert!((r.decode_throughput - 20.0).abs() < 1e-9);
        assert!((r.ttft.median - 0.065).abs() < 1e-9);
        assert_eq!(r.rejected, 1);
        assert_eq!(r.shed, 0);
        assert!((r.goodput() - 2.0).abs() < 1e-9);
        r.shed = 2; // what a coordinator-filled aggregate carries
        assert!(r.row("x").contains("rejected=1 shed=2"));
    }

    #[test]
    fn aborted_counters_flow_to_report() {
        let mut m = MetricsCollector::new();
        m.record_aborted(false); // cancellation
        m.record_aborted(true); // deadline expiry
        let r = m.report();
        assert_eq!(r.aborted, 2);
        assert_eq!(r.deadline_missed, 1);
        assert!(r.row("x").contains("aborted=2 (deadline=1)"));
    }

    #[test]
    fn merge_is_request_weighted_and_empty_safe() {
        let rec = |ttft_ms: u64| RequestRecord {
            id: 0,
            adapter: None,
            prompt_tokens: 10,
            output_tokens: 5,
            ttft: Duration::from_millis(ttft_ms),
            tpot: Some(Duration::from_millis(20)),
            e2e: Duration::from_millis(100),
        };
        let mk = |n: usize, wall: f64| {
            let mut m = MetricsCollector::new();
            for _ in 0..n {
                m.complete_request(rec(10));
            }
            m.set_wall(Duration::from_secs_f64(wall));
            m.report()
        };
        let a = mk(3, 2.0);
        let b = mk(1, 4.0);
        let records: Vec<RequestRecord> =
            (0..3).map(|_| rec(10)).chain(std::iter::once(rec(50))).collect();
        let merged = Report::merge([&a, &b], records.iter(), None);
        assert_eq!(merged.requests, 4);
        assert_eq!(merged.prefill_tokens, 40);
        assert!((merged.wall - 4.0).abs() < 1e-9, "wall = max of parts");
        assert!((merged.prefill_throughput - 10.0).abs() < 1e-9);
        // request-weighted: 3x 10ms + 1x 50ms -> mean 20ms
        assert!((merged.ttft.mean - 0.020).abs() < 1e-9);
        // wall override wins
        let w = Report::merge([&a, &b], records.iter(), Some(8.0));
        assert!((w.wall - 8.0).abs() < 1e-9);
        assert!((w.prefill_throughput - 5.0).abs() < 1e-9);

        // empty merge: no parts, no records -> zeroes, finite wall, no
        // panic rendering the row (regression: empty-run edge cases)
        let empty = Report::merge(
            std::iter::empty::<&Report>(),
            std::iter::empty::<&RequestRecord>(),
            None,
        );
        assert_eq!(empty.requests, 0);
        assert!(empty.wall > 0.0);
        assert!(empty.ttft.mean.is_nan());
        assert!(empty.ttft.min.is_nan(), "empty min must not be +inf");
        assert_eq!(empty.goodput(), 0.0);
        let _ = empty.row("empty");
    }

    #[test]
    fn kv_sharing_flows_to_report_and_merge() {
        let mut m = MetricsCollector::new();
        m.set_kv_sharing(5, 1);
        m.set_kv_sharing(2, 3); // gauge fell back; peak must hold
        let r = m.report();
        assert_eq!((r.kv_pages_shared, r.kv_pages_cow), (5, 3));
        assert!(r.row("x").contains("kv_shared=5 cow=3"));
        let merged = Report::merge(
            [&r, &r],
            std::iter::empty::<&RequestRecord>(),
            None,
        );
        assert_eq!((merged.kv_pages_shared, merged.kv_pages_cow), (10, 6));
        // silent when sharing never happened
        let quiet = MetricsCollector::new().report();
        assert!(!quiet.row("x").contains("kv_shared"));
    }

    #[test]
    fn steps_recorded() {
        let mut m = MetricsCollector::new();
        m.record_step(Duration::from_millis(10), Duration::from_millis(8), 16);
        m.record_step(Duration::from_millis(12), Duration::from_millis(9), 32);
        assert_eq!(m.step_count, 2);
        assert!((m.batched_tokens.mean() - 24.0).abs() < 1e-9);
    }
}
