//! Serving metrics: the paper's four headline numbers — prefill
//! throughput, TTFT, decode throughput, TPOT — plus per-step engine
//! telemetry.

use crate::util::stats::{Samples, Summary};
use std::time::Duration;

/// Per-request lifecycle record.
#[derive(Debug, Clone)]
pub struct RequestRecord {
    pub id: u64,
    pub adapter: Option<String>,
    pub prompt_tokens: usize,
    pub output_tokens: usize,
    /// Arrival → first output token.
    pub ttft: Duration,
    /// Mean time per output token after the first.
    pub tpot: Option<Duration>,
    /// Arrival → completion.
    pub e2e: Duration,
}

/// Aggregated serving metrics over a run.
#[derive(Debug, Default)]
pub struct MetricsCollector {
    records: Vec<RequestRecord>,
    pub step_count: usize,
    pub step_time: Samples,
    /// Time spent inside PJRT execute (XLA compute) per step.
    pub execute_time: Samples,
    pub batched_tokens: Samples,
    run_wall: Option<Duration>,
    rejected: usize,
}

/// Final report of a serving run (one Fig. 5/6/10 data point).
#[derive(Debug, Clone)]
pub struct Report {
    pub requests: usize,
    pub prefill_tokens: usize,
    pub decode_tokens: usize,
    /// tokens / s over the run wall time.
    pub prefill_throughput: f64,
    pub decode_throughput: f64,
    pub ttft: Summary,
    pub tpot: Summary,
    pub e2e: Summary,
    pub wall: f64,
    /// Requests refused at submit time (unknown adapter, over KV
    /// capacity, ...).
    pub rejected: usize,
    /// Requests shed by admission control before reaching an engine
    /// (bounded per-adapter queues, no replica with capacity).
    pub shed: usize,
}

impl MetricsCollector {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn complete_request(&mut self, rec: RequestRecord) {
        self.records.push(rec);
    }

    pub fn record_step(&mut self, wall: Duration, execute: Duration, tokens: usize) {
        self.step_count += 1;
        self.step_time.push(wall.as_secs_f64());
        self.execute_time.push(execute.as_secs_f64());
        self.batched_tokens.push(tokens as f64);
    }

    pub fn set_wall(&mut self, wall: Duration) {
        self.run_wall = Some(wall);
    }

    /// Count a request refused at submit time.
    pub fn record_rejected(&mut self) {
        self.rejected += 1;
    }

    pub fn rejected(&self) -> usize {
        self.rejected
    }

    pub fn completed(&self) -> usize {
        self.records.len()
    }

    pub fn records(&self) -> &[RequestRecord] {
        &self.records
    }

    pub fn report(&mut self) -> Report {
        let wall = self
            .run_wall
            .map(|d| d.as_secs_f64())
            .unwrap_or_else(|| self.step_time.sum())
            .max(1e-9);
        let prefill_tokens: usize = self.records.iter().map(|r| r.prompt_tokens).sum();
        let decode_tokens: usize = self.records.iter().map(|r| r.output_tokens).sum();
        let mut ttft = Samples::new();
        let mut tpot = Samples::new();
        let mut e2e = Samples::new();
        for r in &self.records {
            ttft.push(r.ttft.as_secs_f64());
            if let Some(t) = r.tpot {
                tpot.push(t.as_secs_f64());
            }
            e2e.push(r.e2e.as_secs_f64());
        }
        Report {
            requests: self.records.len(),
            prefill_tokens,
            decode_tokens,
            prefill_throughput: prefill_tokens as f64 / wall,
            decode_throughput: decode_tokens as f64 / wall,
            ttft: ttft.summary(),
            tpot: tpot.summary(),
            e2e: e2e.summary(),
            wall,
            rejected: self.rejected,
            // admission control lives above single engines: the fleet
            // coordinator fills this on its aggregate report
            shed: 0,
        }
    }
}

impl Report {
    /// Completed requests per second of wall time — the fleet
    /// experiments' headline number (Fig. 10).
    pub fn goodput(&self) -> f64 {
        self.requests as f64 / self.wall.max(1e-9)
    }

    /// One bench-output row (fixed-width, paper-style).
    pub fn row(&self, label: &str) -> String {
        let mut row = format!(
            "{label:<28} req={:<4} prefill={:>8.1} tok/s decode={:>7.1} tok/s \
             TTFT p50={:>7.1} ms TPOT p50={:>7.1} ms",
            self.requests,
            self.prefill_throughput,
            self.decode_throughput,
            self.ttft.median * 1e3,
            self.tpot.median * 1e3,
        );
        if self.rejected > 0 || self.shed > 0 {
            row.push_str(&format!(
                " rejected={} shed={}",
                self.rejected, self.shed
            ));
        }
        row
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_math() {
        let mut m = MetricsCollector::new();
        for i in 0..4 {
            m.complete_request(RequestRecord {
                id: i,
                adapter: None,
                prompt_tokens: 100,
                output_tokens: 10,
                ttft: Duration::from_millis(50 + i as u64 * 10),
                tpot: Some(Duration::from_millis(20)),
                e2e: Duration::from_millis(300),
            });
        }
        m.set_wall(Duration::from_secs(2));
        m.record_rejected();
        let mut r = m.report();
        assert_eq!(r.requests, 4);
        assert_eq!(r.prefill_tokens, 400);
        assert!((r.prefill_throughput - 200.0).abs() < 1e-9);
        assert!((r.decode_throughput - 20.0).abs() < 1e-9);
        assert!((r.ttft.median - 0.065).abs() < 1e-9);
        assert_eq!(r.rejected, 1);
        assert_eq!(r.shed, 0);
        assert!((r.goodput() - 2.0).abs() < 1e-9);
        r.shed = 2; // what a coordinator-filled aggregate carries
        assert!(r.row("x").contains("rejected=1 shed=2"));
    }

    #[test]
    fn steps_recorded() {
        let mut m = MetricsCollector::new();
        m.record_step(Duration::from_millis(10), Duration::from_millis(8), 16);
        m.record_step(Duration::from_millis(12), Duration::from_millis(9), 32);
        assert_eq!(m.step_count, 2);
        assert!((m.batched_tokens.mean() - 24.0).abs() < 1e-9);
    }
}
