//! Model configuration, parsed from the artifact ABI (`meta.json`).
//!
//! The Rust side never hard-codes model geometry: everything — tensor
//! shapes, expert-slot layout, token buckets — comes from the `meta.json`
//! emitted by `python/compile/aot.py`, so the two layers cannot drift.

use crate::util::json::Json;
use anyhow::{bail, Context, Result};

/// Mirror of `python/compile/configs.py::ModelConfig`.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub vocab: usize,
    pub hidden: usize,
    pub layers: usize,
    pub q_heads: usize,
    pub kv_heads: usize,
    pub head_dim: usize,
    /// M — routed experts in the base model (router domain).
    pub num_experts: usize,
    pub top_k: usize,
    pub expert_inter: usize,
    pub shared_inter: usize,
    /// N — adapter slots in the virtual weight tensor.
    pub max_adapters: usize,
    /// E_max — expert slots per adapter per layer.
    pub e_max: usize,
    /// CAP — KV slot-pool size.
    pub kv_cap: usize,
    /// O — logits rows returned per step.
    pub max_seqs: usize,
    pub buckets: Vec<usize>,
    pub rope_theta: f64,
    pub rms_eps: f64,
}

impl ModelConfig {
    /// G = M + N * E_max: expert slots in the virtual weight tensor.
    pub fn total_expert_slots(&self) -> usize {
        self.num_experts + self.max_adapters * self.e_max
    }

    /// Δ_i — first slot of adapter slot `i`'s region.
    pub fn adapter_slot_base(&self, adapter_slot: usize) -> usize {
        self.num_experts + adapter_slot * self.e_max
    }

    /// Bytes of one expert's weights for one projection (f32).
    ///
    /// gate/up are `[H, F]`, down is `[F, H]` — same element count.
    pub fn expert_proj_bytes(&self) -> usize {
        self.hidden * self.expert_inter * 4
    }

    /// Bytes of one expert across all three projections in one layer.
    pub fn expert_bytes(&self) -> usize {
        3 * self.expert_proj_bytes()
    }

    /// Bytes of KV cache per token slot across all layers (f32).
    pub fn kv_bytes_per_token(&self) -> usize {
        self.layers * 2 * self.kv_heads * self.head_dim * 4
    }

    /// Total parameter bytes of a merged/base (G = M) model, f32.
    pub fn base_model_bytes(&self) -> usize {
        let h = self.hidden;
        let emb = self.vocab * h * 2; // embed + lm_head
        let per_layer = h // ln_attn
            + h * (self.q_heads * self.head_dim) // wq
            + 2 * h * (self.kv_heads * self.head_dim) // wk, wv
            + (self.q_heads * self.head_dim) * h // wo
            + h // ln_ffn
            + h * self.num_experts // router
            + 3 * self.num_experts * h * self.expert_inter // experts
            + 3 * h * self.shared_inter; // shared expert
        (emb + h + self.layers * per_layer) * 4
    }

    /// Parse the `config` object of `meta.json`.
    pub fn from_json(j: &Json) -> Result<Self> {
        let us = |k: &str| -> Result<usize> {
            j.get(k)
                .and_then(Json::as_usize)
                .with_context(|| format!("config field {k}"))
        };
        Ok(ModelConfig {
            name: j
                .get("name")
                .and_then(Json::as_str)
                .context("config.name")?
                .to_string(),
            vocab: us("vocab")?,
            hidden: us("hidden")?,
            layers: us("layers")?,
            q_heads: us("q_heads")?,
            kv_heads: us("kv_heads")?,
            head_dim: us("head_dim")?,
            num_experts: us("num_experts")?,
            top_k: us("top_k")?,
            expert_inter: us("expert_inter")?,
            shared_inter: us("shared_inter")?,
            max_adapters: us("max_adapters")?,
            e_max: us("e_max")?,
            kv_cap: us("kv_cap")?,
            max_seqs: us("max_seqs")?,
            buckets: j
                .get("buckets")
                .and_then(Json::as_usize_vec)
                .context("config.buckets")?,
            rope_theta: j.get("rope_theta").and_then(Json::as_f64).unwrap_or(10000.0),
            rms_eps: j.get("rms_eps").and_then(Json::as_f64).unwrap_or(1e-6),
        })
    }

    /// Small self-contained geometry for the simulated backend
    /// ([`crate::runtime::sim`]): no artifacts required, cheap host-side
    /// weight generation, and token buckets sized for serving/fleet
    /// experiments. Callers tune `max_adapters`/`kv_cap` per scenario.
    pub fn sim_default() -> Self {
        ModelConfig {
            name: "sim".into(),
            vocab: 512,
            hidden: 64,
            layers: 4,
            q_heads: 4,
            kv_heads: 2,
            head_dim: 16,
            num_experts: 16,
            top_k: 2,
            expert_inter: 32,
            shared_inter: 64,
            max_adapters: 8,
            e_max: 4,
            kv_cap: 4096,
            max_seqs: 16,
            buckets: vec![16, 64, 256],
            rope_theta: 10000.0,
            rms_eps: 1e-6,
        }
    }

    /// Paper-scale geometry (16B ESFT-vanilla / DeepSeek-V2-Lite) used by
    /// the Fig. 9 / Table 1 accounting experiments. Mirrors
    /// `configs.PAPER16B`; no artifacts exist for it.
    pub fn paper16b() -> Self {
        ModelConfig {
            name: "paper16b".into(),
            vocab: 102400,
            hidden: 2048,
            layers: 26,
            q_heads: 16,
            kv_heads: 16,
            head_dim: 128,
            num_experts: 64,
            top_k: 6,
            expert_inter: 1408,
            shared_inter: 2816,
            max_adapters: 20,
            e_max: 13,
            kv_cap: 0,
            max_seqs: 256,
            buckets: vec![],
            rope_theta: 10000.0,
            rms_eps: 1e-6,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_minimal() {
        let j = Json::parse(
            r#"{"name":"tiny","vocab":128,"hidden":32,"layers":2,
                "q_heads":2,"kv_heads":1,"head_dim":16,"num_experts":8,
                "top_k":2,"expert_inter":16,"shared_inter":32,
                "max_adapters":3,"e_max":3,"kv_cap":64,"max_seqs":8,
                "buckets":[4,16],"rope_theta":10000.0,"rms_eps":1e-6}"#,
        )
        .unwrap();
        let c = ModelConfig::from_json(&j).unwrap();
        assert_eq!(c.total_expert_slots(), 8 + 3 * 3);
        assert_eq!(c.adapter_slot_base(2), 8 + 6);
        assert_eq!(c.expert_bytes(), 3 * 32 * 16 * 4);
        assert_eq!(c.kv_bytes_per_token(), 2 * 2 * 16 * 4);
    }

    #[test]
    fn paper16b_sizes_match_paper() {
        let c = ModelConfig::paper16b();
        // one expert (three [2048,1408] f32 projections) ≈ 34.6 MB
        assert_eq!(c.expert_proj_bytes(), 2048 * 1408 * 4);
        // total params ≈ 16B * 4 B/f32 ≈ 60+ GB f32? No — the 16B model is
        // ~16e9 params; f32 bytes ≈ 64 GB, bf16 ≈ 32 GB. The paper serves
        // in bf16-ish precision; our ledger maths use explicit dtype sizes
        // at the call site, so here we only sanity-check the f32 figure.
        let p = c.base_model_bytes() as f64 / 4.0; // param count
        assert!((13e9..18e9).contains(&p), "param count {p}");
    }

    #[test]
    fn missing_field_is_error() {
        let j = Json::parse(r#"{"name":"x"}"#).unwrap();
        assert!(ModelConfig::from_json(&j).is_err());
    }
}
