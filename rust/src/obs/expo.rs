//! Prometheus text exposition (format 0.0.4) over a std-only HTTP/1.0
//! listener.
//!
//! [`render`] turns one or more [`ObsRegistry`] snapshots into the text
//! format: per-replica counter/gauge/histogram families carry a
//! `replica` label, per-adapter families are aggregated across replicas
//! by adapter name (the fleet view the coordinator exports). The
//! [`MetricsListener`] is a single background thread serving every HTTP
//! request with a fresh render — no HTTP framework, no routing: any
//! request path gets the metrics page.
//!
//! Scrapes never touch the hot path: they read the registry atomics with
//! `Relaxed` loads from the listener thread.

use super::{bucket_upper, FleetObs, ObsRegistry, StatsSnapshot, HISTO_BUCKETS};
use std::fmt::Write as _;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, OnceLock};
use std::time::{Duration, Instant};

/// Escape a label value per the exposition format.
fn label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

/// Process start anchor for `expertweave_uptime_seconds`. Set once by
/// [`mark_process_start`] (the CLI calls it first thing in `main`);
/// falls back to first-render time when embedding code never did.
static PROCESS_START: OnceLock<Instant> = OnceLock::new();

/// Anchor the uptime gauge at the caller's notion of process start
/// (idempotent — the first call wins).
pub fn mark_process_start() {
    let _ = PROCESS_START.set(Instant::now());
}

fn histo(out: &mut String, name: &str, replica: usize, h: &super::HistoSnapshot) {
    let mut acc = 0u64;
    for b in 0..HISTO_BUCKETS.min(h.buckets.len()) {
        if h.buckets[b] == 0 {
            continue;
        }
        acc += h.buckets[b];
        let le = bucket_upper(b);
        if le == u64::MAX {
            continue; // folded into +Inf below
        }
        let _ = writeln!(out, "{name}_bucket{{replica=\"{replica}\",le=\"{le}\"}} {acc}");
    }
    let _ = writeln!(out, "{name}_bucket{{replica=\"{replica}\",le=\"+Inf\"}} {}", h.count);
    let _ = writeln!(out, "{name}_sum{{replica=\"{replica}\"}} {}", h.sum);
    let _ = writeln!(out, "{name}_count{{replica=\"{replica}\"}} {}", h.count);
}

/// Render the exposition page for one or more registries (one per
/// replica; a single engine passes a one-element slice).
pub fn render(regs: &[Arc<ObsRegistry>]) -> String {
    let snaps: Vec<StatsSnapshot> = regs.iter().map(|r| r.snapshot()).collect();
    let mut merged = StatsSnapshot::default();
    for s in &snaps {
        merged.merge(s);
    }
    let mut out = String::with_capacity(4096);

    // build identity first: scrapers join on this to tag every other
    // family with the running version/commit
    let _ = writeln!(out, "# HELP expertweave_build_info Build metadata; the value is always 1.");
    let _ = writeln!(out, "# TYPE expertweave_build_info gauge");
    let version = env!("CARGO_PKG_VERSION");
    let git = option_env!("EXPERTWEAVE_GIT_SHA").unwrap_or("unknown");
    let _ = writeln!(
        out,
        "expertweave_build_info{{version=\"{}\",git=\"{}\"}} 1",
        label(version),
        label(git)
    );
    let uptime = PROCESS_START.get_or_init(Instant::now).elapsed().as_secs_f64();
    let _ = writeln!(out, "# HELP expertweave_uptime_seconds Seconds since process start.");
    let _ = writeln!(out, "# TYPE expertweave_uptime_seconds gauge");
    let _ = writeln!(out, "expertweave_uptime_seconds {uptime:.3}");

    let counter = |out: &mut String, name: &str, help: &str, get: &dyn Fn(&StatsSnapshot) -> u64| {
        let _ = writeln!(out, "# HELP {name} {help}");
        let _ = writeln!(out, "# TYPE {name} counter");
        for (i, s) in snaps.iter().enumerate() {
            let _ = writeln!(out, "{name}{{replica=\"{i}\"}} {}", get(s));
        }
    };
    counter(&mut out, "expertweave_steps_total", "Engine steps executed.", &|s| s.steps);
    counter(
        &mut out,
        "expertweave_requests_submitted_total",
        "Requests admitted into the engine.",
        &|s| s.requests_submitted,
    );
    counter(
        &mut out,
        "expertweave_requests_completed_total",
        "Requests finished with all tokens delivered.",
        &|s| s.requests_completed,
    );
    counter(
        &mut out,
        "expertweave_requests_rejected_total",
        "Requests refused at admission.",
        &|s| s.requests_rejected,
    );
    counter(
        &mut out,
        "expertweave_requests_aborted_total",
        "Requests cancelled or expired after admission.",
        &|s| s.requests_aborted,
    );
    counter(
        &mut out,
        "expertweave_tokens_prefill_total",
        "Prompt tokens prefilled.",
        &|s| s.tokens_prefill,
    );
    counter(
        &mut out,
        "expertweave_tokens_decode_total",
        "Decode tokens scheduled.",
        &|s| s.tokens_decode,
    );
    counter(
        &mut out,
        "expertweave_kv_prefix_hits_total",
        "Prompt tokens adopted from shared KV prefix pages.",
        &|s| s.kv_prefix_hits,
    );
    counter(
        &mut out,
        "expertweave_kv_prefix_misses_total",
        "Prompt tokens prefilled fresh (no shared prefix page).",
        &|s| s.kv_prefix_misses,
    );
    counter(
        &mut out,
        "expertweave_kv_cow_copies_total",
        "Copy-on-write KV page splits on divergence.",
        &|s| s.kv_pages_cow,
    );

    let gauge = |out: &mut String, name: &str, help: &str, get: &dyn Fn(&StatsSnapshot) -> u64| {
        let _ = writeln!(out, "# HELP {name} {help}");
        let _ = writeln!(out, "# TYPE {name} gauge");
        for (i, s) in snaps.iter().enumerate() {
            let _ = writeln!(out, "{name}{{replica=\"{i}\"}} {}", get(s));
        }
    };
    gauge(&mut out, "expertweave_kv_free_slots", "Free KV-cache token slots.", &|s| s.kv_free);
    gauge(
        &mut out,
        "expertweave_kv_pages_shared",
        "Physical KV pages referenced by more than one sequence.",
        &|s| s.kv_pages_shared,
    );
    gauge(&mut out, "expertweave_queue_waiting", "Requests waiting for admission.", &|s| {
        s.waiting
    });
    gauge(&mut out, "expertweave_queue_running", "Requests actively decoding.", &|s| s.running);

    for (name, help, get) in [
        (
            "expertweave_step_wall_us",
            "Engine step wall time (microseconds).",
            (|s: &StatsSnapshot| &s.step_wall_us) as fn(&StatsSnapshot) -> &super::HistoSnapshot,
        ),
        (
            "expertweave_step_exec_us",
            "Backend execute time per step (microseconds).",
            |s: &StatsSnapshot| &s.step_exec_us,
        ),
        ("expertweave_ttft_us", "Time to first token (microseconds).", |s: &StatsSnapshot| {
            &s.ttft_us
        }),
        ("expertweave_e2e_us", "Request end-to-end latency (microseconds).", |s: &StatsSnapshot| {
            &s.e2e_us
        }),
    ] {
        let _ = writeln!(out, "# HELP {name} {help}");
        let _ = writeln!(out, "# TYPE {name} histogram");
        for (i, s) in snaps.iter().enumerate() {
            histo(&mut out, name, i, get(s));
        }
    }

    // per-adapter families: aggregated across replicas by adapter name
    for (name, help, get) in [
        (
            "expertweave_adapter_requests_submitted_total",
            "Requests admitted, by adapter.",
            (|a: &super::AdapterStats| a.submitted) as fn(&super::AdapterStats) -> u64,
        ),
        (
            "expertweave_adapter_requests_completed_total",
            "Requests completed, by adapter.",
            |a: &super::AdapterStats| a.completed,
        ),
        (
            "expertweave_adapter_requests_aborted_total",
            "Requests cancelled or expired, by adapter.",
            |a: &super::AdapterStats| a.aborted,
        ),
        (
            "expertweave_adapter_tokens_generated_total",
            "Output tokens sampled, by adapter.",
            |a: &super::AdapterStats| a.tokens,
        ),
    ] {
        let _ = writeln!(out, "# HELP {name} {help}");
        let _ = writeln!(out, "# TYPE {name} counter");
        for a in &merged.adapters {
            let _ = writeln!(out, "{name}{{adapter=\"{}\"}} {}", label(&a.name), get(a));
        }
    }
    out
}

/// Fleet exposition page: [`render`] over the fleet's *current* registry
/// list (replicas can join at runtime, so the list is read per scrape,
/// not captured at listener spawn), plus the coordinator's fleet-level
/// failure-handling families.
pub fn render_fleet(fleet: &FleetObs) -> String {
    use std::sync::atomic::Ordering;
    let mut out = render(&fleet.registries());
    for (name, kind, help, v) in [
        (
            "expertweave_fleet_replicas",
            "gauge",
            "Live (routable) replicas in the fleet.",
            fleet.replicas.load(Ordering::Relaxed),
        ),
        (
            "expertweave_replica_suspect",
            "gauge",
            "Live replicas whose heartbeat is currently stale (excluded from routing).",
            fleet.suspect.load(Ordering::Relaxed),
        ),
        (
            "expertweave_requests_rerouted_total",
            "counter",
            "Requests re-submitted to a surviving replica after theirs died.",
            fleet.rerouted.load(Ordering::Relaxed),
        ),
        (
            "expertweave_reroute_aborted_total",
            "counter",
            "Failover aborts: remaining deadline could not survive the retry.",
            fleet.reroute_aborted.load(Ordering::Relaxed),
        ),
        (
            "expertweave_replica_retired_total",
            "counter",
            "Replicas retired from the fleet (crashed, killed, or drained out).",
            fleet.retired.load(Ordering::Relaxed),
        ),
    ] {
        let _ = writeln!(out, "# HELP {name} {help}");
        let _ = writeln!(out, "# TYPE {name} {kind}");
        let _ = writeln!(out, "{name} {v}");
    }
    out
}

/// std-only Prometheus scrape endpoint: one background thread, one
/// `TcpListener`, a fresh [`render`] per request. Shut down by flag +
/// loopback poke (same pattern as the NDJSON server acceptor).
pub struct MetricsListener {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl MetricsListener {
    /// Bind `listen` (e.g. `127.0.0.1:9464`; port 0 picks a free port)
    /// and serve `render()` to every HTTP request until shutdown.
    pub fn spawn<F>(listen: &str, render_page: F) -> std::io::Result<MetricsListener>
    where
        F: Fn() -> String + Send + 'static,
    {
        let listener = TcpListener::bind(listen)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let flag = Arc::clone(&stop);
        let join = std::thread::Builder::new().name("metrics-listener".into()).spawn(move || {
            for conn in listener.incoming() {
                if flag.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(mut sock) = conn else { continue };
                // drain the request head best-effort; every path serves
                // the metrics page, so the content doesn't matter
                let _ = sock.set_read_timeout(Some(Duration::from_millis(500)));
                let mut head = [0u8; 1024];
                let _ = sock.read(&mut head);
                let body = render_page();
                let resp = format!(
                    "HTTP/1.0 200 OK\r\nContent-Type: text/plain; version=0.0.4; \
                     charset=utf-8\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{}",
                    body.len(),
                    body
                );
                let _ = sock.write_all(resp.as_bytes());
            }
        })?;
        Ok(MetricsListener { addr, stop, join: Some(join) })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop the listener thread (idempotent; also runs on drop).
    pub fn shutdown(&mut self) {
        if self.join.is_none() {
            return;
        }
        self.stop.store(true, Ordering::SeqCst);
        let poke: SocketAddr = if self.addr.ip().is_unspecified() {
            (std::net::Ipv4Addr::LOCALHOST, self.addr.port()).into()
        } else {
            self.addr
        };
        let _ = TcpStream::connect_timeout(&poke, Duration::from_millis(500));
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for MetricsListener {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// One-shot scrape of a metrics endpoint; returns the response body.
/// Used by tests and handy for humans without curl.
pub fn scrape(addr: &SocketAddr) -> std::io::Result<String> {
    let mut sock = TcpStream::connect_timeout(addr, Duration::from_secs(5))?;
    sock.set_read_timeout(Some(Duration::from_secs(5)))?;
    sock.write_all(b"GET /metrics HTTP/1.0\r\n\r\n")?;
    let mut resp = String::new();
    sock.read_to_string(&mut resp)?;
    match resp.split_once("\r\n\r\n") {
        Some((head, body)) if head.starts_with("HTTP/1.0 200") => Ok(body.to_string()),
        _ => Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("unexpected scrape response: {}", resp.lines().next().unwrap_or("")),
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_registry() -> Arc<ObsRegistry> {
        let r = ObsRegistry::new(2);
        r.set_adapter_name(0, "math");
        r.record_submitted(0);
        r.record_completed(0, 1_000, 50_000);
        r.record_step(200, 150, 32, 8);
        r.record_token(0);
        r.set_gauges(512, 0, 4);
        r.record_prefix(24, 8);
        r.set_kv_shared(2);
        Arc::new(r)
    }

    #[test]
    fn render_exposes_all_families() {
        let page = render(&[sample_registry()]);
        for family in [
            "expertweave_steps_total{replica=\"0\"} 1",
            "expertweave_requests_completed_total{replica=\"0\"} 1",
            "expertweave_kv_free_slots{replica=\"0\"} 512",
            "expertweave_kv_prefix_hits_total{replica=\"0\"} 24",
            "expertweave_kv_prefix_misses_total{replica=\"0\"} 8",
            "expertweave_kv_cow_copies_total{replica=\"0\"} 0",
            "expertweave_kv_pages_shared{replica=\"0\"} 2",
            "expertweave_queue_running{replica=\"0\"} 4",
            "expertweave_step_wall_us_count{replica=\"0\"} 1",
            "expertweave_adapter_requests_completed_total{adapter=\"math\"} 1",
            "expertweave_adapter_tokens_generated_total{adapter=\"math\"} 1",
        ] {
            assert!(page.contains(family), "missing {family:?} in:\n{page}");
        }
        // HELP/TYPE precede every family
        assert!(page.contains("# TYPE expertweave_ttft_us histogram"));
        assert!(page.contains("# TYPE expertweave_kv_free_slots gauge"));
        // build identity + uptime lead the page
        assert!(page.contains("expertweave_build_info{version=\""));
        assert!(page.contains("expertweave_uptime_seconds"));
    }

    #[test]
    fn render_histograms_are_cumulative_and_terminated() {
        let r = ObsRegistry::new(0);
        for v in [1u64, 2, 3, 100, 10_000] {
            r.record_step(v, v, 0, 0);
        }
        let page = render(&[Arc::new(r)]);
        let mut last = 0u64;
        let mut saw_inf = false;
        for line in page.lines().filter(|l| l.starts_with("expertweave_step_wall_us_bucket")) {
            let v: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(v >= last, "bucket counts must be cumulative: {line}");
            last = v;
            saw_inf |= line.contains("le=\"+Inf\"");
        }
        assert!(saw_inf, "+Inf bucket required");
        assert_eq!(last, 5, "+Inf equals total count");
    }

    #[test]
    fn render_merges_adapter_families_across_replicas() {
        let a = sample_registry();
        let b = sample_registry();
        let page = render(&[a, b]);
        // per-replica families keep their own label ...
        assert!(page.contains("expertweave_steps_total{replica=\"1\"} 1"));
        // ... while adapter families sum across replicas
        assert!(page.contains("expertweave_adapter_requests_completed_total{adapter=\"math\"} 2"));
    }

    #[test]
    fn render_fleet_appends_failover_families() {
        use std::sync::atomic::Ordering;
        let fleet = FleetObs::new();
        fleet.push_registry(sample_registry());
        fleet.replicas.store(3, Ordering::Relaxed);
        fleet.suspect.store(1, Ordering::Relaxed);
        fleet.rerouted.store(2, Ordering::Relaxed);
        fleet.retired.store(1, Ordering::Relaxed);
        let page = render_fleet(&fleet);
        // the per-replica families come from the registry list ...
        assert!(page.contains("expertweave_steps_total{replica=\"0\"} 1"));
        // ... and the fleet failover families are appended unlabeled
        for family in [
            "expertweave_fleet_replicas 3",
            "expertweave_replica_suspect 1",
            "expertweave_requests_rerouted_total 2",
            "expertweave_reroute_aborted_total 0",
            "expertweave_replica_retired_total 1",
        ] {
            assert!(page.contains(family), "missing {family:?} in:\n{page}");
        }
        assert!(page.contains("# TYPE expertweave_fleet_replicas gauge"));
        assert!(page.contains("# TYPE expertweave_requests_rerouted_total counter"));
        // a runtime join shows up on the next render without respawning
        fleet.push_registry(sample_registry());
        let page2 = render_fleet(&fleet);
        assert!(page2.contains("expertweave_steps_total{replica=\"1\"} 1"));
    }

    #[test]
    fn listener_serves_scrapes() {
        let reg = sample_registry();
        let regs = vec![Arc::clone(&reg)];
        let mut l = MetricsListener::spawn("127.0.0.1:0", move || render(&regs)).unwrap();
        let addr = l.local_addr();
        let body = scrape(&addr).unwrap();
        assert!(body.contains("expertweave_requests_completed_total{replica=\"0\"} 1"));
        // a second scrape sees fresh state
        reg.record_completed(0, 1_000, 2_000);
        let body2 = scrape(&addr).unwrap();
        assert!(body2.contains("expertweave_requests_completed_total{replica=\"0\"} 2"));
        // shutdown joins the listener thread; a hang here fails the test
        l.shutdown();
    }
}
