//! Always-on black-box flight recorder: a fixed-capacity, preallocated
//! ring of recent request/step events per engine.
//!
//! `--trace-out` tracing is opt-in, so the incident you actually care
//! about is usually the one you were *not* tracing. The flight recorder
//! closes that gap the way an aircraft recorder does: it is always
//! recording into a bounded ring, overwriting the oldest events, and is
//! dumped as JSON only when someone asks — on a replica abort, at
//! drain, or via the NDJSON `{"op":"flightrec"}` frame (protocol v3,
//! docs/PROTOCOL.md). The last [`FLIGHTREC_CAPACITY`] events preceding
//! an incident are reconstructable even when nothing was enabled.
//!
//! Recording must therefore be as cheap as the obs counters it sits
//! next to: zero heap allocations, no locks, no CAS loops. One
//! [`FlightRecorder::record`] is a relaxed `fetch_add` on the cursor
//! plus five relaxed/release stores into a preallocated slot
//! (`tests/hotpath_alloc.rs` proves the steady-state decode step stays
//! at 0 allocations with the recorder live). The price is the classic
//! black-box trade: a reader racing a writer that has lapped the ring
//! can observe a torn slot (fields from two different events). Readers
//! detect *dropped* history via the cursor, and torn slots are bounded
//! to the ring's write frontier — acceptable for a post-incident
//! artifact, which is a reconstruction aid, not an audit log.

use crate::util::json::{arr, obj, Json};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Ring capacity (events). Power of two so the slot index is a mask.
pub const FLIGHTREC_CAPACITY: usize = 4096;

/// What happened. Stored in the slot as a `u32` discriminant.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u32)]
pub enum EventKind {
    /// A request was admitted (`value` = prompt tokens).
    Submit = 1,
    /// A submit was refused (`value` = [`crate::serving::SubmitError`]
    /// ordinal).
    Reject = 2,
    /// One engine step retired (`value` = step wall µs; `id` = step
    /// counter).
    Step = 3,
    /// A request produced its first output token (`value` = the token).
    FirstToken = 4,
    /// A request completed (`value` = output tokens generated).
    Done = 5,
    /// An admitted request aborted (`value`: 0 = cancelled,
    /// 1 = deadline).
    Abort = 6,
}

impl EventKind {
    pub fn as_str(self) -> &'static str {
        match self {
            EventKind::Submit => "submit",
            EventKind::Reject => "reject",
            EventKind::Step => "step",
            EventKind::FirstToken => "first_token",
            EventKind::Done => "done",
            EventKind::Abort => "abort",
        }
    }

    fn from_u32(v: u32) -> Option<EventKind> {
        Some(match v {
            1 => EventKind::Submit,
            2 => EventKind::Reject,
            3 => EventKind::Step,
            4 => EventKind::FirstToken,
            5 => EventKind::Done,
            6 => EventKind::Abort,
            _ => return None,
        })
    }
}

/// One preallocated ring slot. `seq` is the 1-based global sequence
/// number of the event occupying the slot (0 = never written); it is
/// stored last with `Release` so a fully-published slot is observable
/// as such, while a torn read under an active lap stays detectable by
/// its out-of-window `seq`.
#[derive(Debug)]
struct Slot {
    seq: AtomicU64,
    t_us: AtomicU64,
    /// `(kind as u64) << 32 | aid as u32` (aid -1 = base → 0xffffffff).
    kind_aid: AtomicU64,
    id: AtomicU64,
    value: AtomicU64,
}

/// A decoded event out of a [`FlightRecorder::snapshot`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FlightEvent {
    /// 1-based global sequence number (gaps = ring overwrites).
    pub seq: u64,
    /// Microseconds since the recorder's origin (engine construction).
    pub t_us: u64,
    pub kind: EventKind,
    /// Adapter id (-1 = base model; meaningless for `Step`).
    pub aid: i32,
    /// Request id (engine-local), or the step counter for `Step`.
    pub id: u64,
    pub value: u64,
}

/// Point-in-time copy of one recorder's ring.
#[derive(Debug, Clone)]
pub struct FlightSnapshot {
    pub capacity: usize,
    /// Total events ever recorded.
    pub recorded: u64,
    /// Events overwritten before this snapshot could see them.
    pub dropped: u64,
    /// Surviving events, oldest first.
    pub events: Vec<FlightEvent>,
}

/// The per-engine ring. Shared as an `Arc`: the engine records, the
/// coordinator (or the NDJSON frontend) snapshots from any thread.
#[derive(Debug)]
pub struct FlightRecorder {
    origin: Instant,
    cursor: AtomicU64,
    slots: Box<[Slot]>,
}

impl Default for FlightRecorder {
    fn default() -> Self {
        Self::new()
    }
}

impl FlightRecorder {
    pub fn new() -> Self {
        Self::with_origin(Instant::now())
    }

    /// A recorder whose `t_us` zero is `origin` (engines pass their
    /// construction instant, the same origin their [`super::trace`]
    /// log uses, so the two artifacts line up).
    pub fn with_origin(origin: Instant) -> Self {
        let slots = (0..FLIGHTREC_CAPACITY)
            .map(|_| Slot {
                seq: AtomicU64::new(0),
                t_us: AtomicU64::new(0),
                kind_aid: AtomicU64::new(0),
                id: AtomicU64::new(0),
                value: AtomicU64::new(0),
            })
            .collect::<Vec<_>>()
            .into_boxed_slice();
        FlightRecorder { origin, cursor: AtomicU64::new(0), slots }
    }

    /// Record one event. Wait-free, allocation-free: one relaxed
    /// `fetch_add` plus five stores into a preallocated slot.
    #[inline]
    pub fn record(&self, kind: EventKind, id: u64, aid: i32, value: u64) {
        let n = self.cursor.fetch_add(1, Ordering::Relaxed);
        let slot = &self.slots[(n as usize) & (FLIGHTREC_CAPACITY - 1)];
        let t_us = self.origin.elapsed().as_micros() as u64;
        slot.t_us.store(t_us, Ordering::Relaxed);
        slot.kind_aid
            .store(((kind as u64) << 32) | (aid as u32 as u64), Ordering::Relaxed);
        slot.id.store(id, Ordering::Relaxed);
        slot.value.store(value, Ordering::Relaxed);
        // publish last: a slot is only as valid as its seq
        slot.seq.store(n + 1, Ordering::Release);
    }

    /// Total events ever recorded.
    pub fn recorded(&self) -> u64 {
        self.cursor.load(Ordering::Relaxed)
    }

    /// Copy the surviving window out of the ring, oldest first. Slots
    /// whose `seq` falls outside the live window (unwritten, lapped
    /// mid-copy, or torn) are skipped rather than misreported.
    pub fn snapshot(&self) -> FlightSnapshot {
        let recorded = self.cursor.load(Ordering::Acquire);
        let window = recorded.min(FLIGHTREC_CAPACITY as u64);
        let oldest = recorded - window; // seqs (oldest, recorded] survive
        let mut events = Vec::with_capacity(window as usize);
        for slot in self.slots.iter() {
            let seq = slot.seq.load(Ordering::Acquire);
            if seq <= oldest || seq > recorded {
                continue;
            }
            let kind_aid = slot.kind_aid.load(Ordering::Relaxed);
            let Some(kind) = EventKind::from_u32((kind_aid >> 32) as u32) else {
                continue;
            };
            events.push(FlightEvent {
                seq,
                t_us: slot.t_us.load(Ordering::Relaxed),
                kind,
                aid: (kind_aid & 0xffff_ffff) as u32 as i32,
                id: slot.id.load(Ordering::Relaxed),
                value: slot.value.load(Ordering::Relaxed),
            });
        }
        events.sort_unstable_by_key(|e| e.seq);
        FlightSnapshot {
            capacity: FLIGHTREC_CAPACITY,
            recorded,
            dropped: oldest,
            events,
        }
    }
}

impl FlightSnapshot {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("recorded", Json::Int(self.recorded as i64)),
            ("dropped", Json::Int(self.dropped as i64)),
            (
                "events",
                arr(self.events.iter().map(|e| {
                    obj(vec![
                        ("seq", Json::Int(e.seq as i64)),
                        ("t_us", Json::Int(e.t_us as i64)),
                        ("kind", Json::Str(e.kind.as_str().into())),
                        ("aid", Json::Int(e.aid as i64)),
                        ("id", Json::Int(e.id as i64)),
                        ("value", Json::Int(e.value as i64)),
                    ])
                })),
            ),
        ])
    }
}

/// The dump document for one engine or a whole fleet: one `replicas`
/// entry per recorder (a standalone engine is replica 0). This is the
/// body of the `{"op":"flightrec"}` response frame and of the
/// `<trace-out>.flightrec.json` file written at shutdown.
pub fn dump(recorders: &[(usize, &FlightRecorder)]) -> Json {
    obj(vec![
        ("capacity", Json::Int(FLIGHTREC_CAPACITY as i64)),
        (
            "replicas",
            arr(recorders.iter().map(|(i, r)| {
                let snap = r.snapshot();
                let Json::Obj(mut body) = snap.to_json() else { unreachable!() };
                body.insert("replica".into(), Json::Int(*i as i64));
                Json::Obj(body)
            })),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots_in_order() {
        let r = FlightRecorder::new();
        r.record(EventKind::Submit, 1, -1, 4);
        r.record(EventKind::Step, 1, -1, 120);
        r.record(EventKind::FirstToken, 1, 0, 17);
        r.record(EventKind::Done, 1, 0, 8);
        let snap = r.snapshot();
        assert_eq!(snap.recorded, 4);
        assert_eq!(snap.dropped, 0);
        let kinds: Vec<EventKind> = snap.events.iter().map(|e| e.kind).collect();
        assert_eq!(
            kinds,
            vec![EventKind::Submit, EventKind::Step, EventKind::FirstToken, EventKind::Done]
        );
        assert_eq!(snap.events[0].value, 4);
        assert_eq!(snap.events[2].aid, 0);
        assert_eq!(snap.events[0].aid, -1, "base traffic round-trips aid -1");
        // seqs are 1-based and strictly increasing
        let seqs: Vec<u64> = snap.events.iter().map(|e| e.seq).collect();
        assert_eq!(seqs, vec![1, 2, 3, 4]);
    }

    #[test]
    fn ring_overwrites_oldest_and_reports_drops() {
        let r = FlightRecorder::new();
        let total = FLIGHTREC_CAPACITY as u64 + 100;
        for i in 0..total {
            r.record(EventKind::Step, i, -1, i);
        }
        let snap = r.snapshot();
        assert_eq!(snap.recorded, total);
        assert_eq!(snap.dropped, 100);
        assert_eq!(snap.events.len(), FLIGHTREC_CAPACITY);
        assert_eq!(snap.events.first().unwrap().seq, 101, "oldest 100 overwritten");
        assert_eq!(snap.events.last().unwrap().seq, total);
        // the surviving window is contiguous
        for w in snap.events.windows(2) {
            assert_eq!(w[0].seq + 1, w[1].seq);
        }
    }

    #[test]
    fn dump_shape_is_stable() {
        let a = FlightRecorder::new();
        let b = FlightRecorder::new();
        a.record(EventKind::Submit, 1, -1, 3);
        b.record(EventKind::Abort, 2, 0, 1);
        let doc = Json::parse(&dump(&[(0, &a), (1, &b)]).to_string()).unwrap();
        assert_eq!(doc.at(&["capacity"]).as_i64(), Some(FLIGHTREC_CAPACITY as i64));
        let replicas = doc.at(&["replicas"]).as_arr().unwrap();
        assert_eq!(replicas.len(), 2);
        assert_eq!(replicas[0].at(&["replica"]).as_i64(), Some(0));
        assert_eq!(replicas[1].at(&["replica"]).as_i64(), Some(1));
        let ev = &replicas[1].at(&["events"]).as_arr().unwrap()[0];
        assert_eq!(ev.at(&["kind"]).as_str(), Some("abort"));
        assert_eq!(ev.at(&["value"]).as_i64(), Some(1));
        assert_eq!(ev.at(&["aid"]).as_i64(), Some(0));
    }

    #[test]
    fn shared_across_threads() {
        let r = std::sync::Arc::new(FlightRecorder::new());
        let writer = {
            let r = r.clone();
            std::thread::spawn(move || {
                for i in 0..10_000u64 {
                    r.record(EventKind::Step, i, -1, i);
                }
            })
        };
        // reader races the writer: every decoded event must be coherent
        for _ in 0..50 {
            let snap = r.snapshot();
            for e in &snap.events {
                assert_eq!(e.kind, EventKind::Step);
            }
        }
        writer.join().unwrap();
        assert_eq!(r.recorded(), 10_000);
    }
}
