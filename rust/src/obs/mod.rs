//! Always-on live telemetry that is provably free on the hot path.
//!
//! [`crate::metrics`] answers "what happened over the whole run" after
//! drain; this module answers "what is happening right now" while the
//! engine is live. The two are complementary: `metrics::Report` stays the
//! post-hoc experiment record, `obs` is the operational surface scraped by
//! the NDJSON `stats` frame (docs/PROTOCOL.md) and the Prometheus
//! exposition listener (`--metrics-listen`, [`expo`]).
//!
//! Design constraints, in priority order:
//!
//! 1. **Zero allocation on the recording path.** Every `record_*` method
//!    touches only preallocated atomics with `Relaxed` ordering — no
//!    locks, no heap. `tests/hotpath_alloc.rs` proves the steady-state
//!    decode step still performs 0 allocations *with recording enabled*.
//! 2. **Lock-free recording.** The only `Mutex` in the registry guards
//!    per-slot adapter *names*, which are written exclusively on adapter
//!    load/evict (cold control path) and read on scrape — never by
//!    `record_*`.
//! 3. **Preallocated labels.** Per-adapter counters live in a fixed
//!    `Vec<AdapterSlot>` sized at engine construction (`max_adapters + 1`
//!    slots; index 0 is the base model, index `aid + 1` mirrors the
//!    adapter registry's slot == aid layout), so recording never inserts
//!    into a map.
//!
//! Latency-shaped values go into [`Histo`]: 64 log2 buckets of `AtomicU64`
//! (bucket `b` holds values of bit-length `b`, i.e. `[2^(b-1), 2^b - 1]`;
//! bucket 0 holds exactly 0). Quantile estimates return the upper bound of
//! the containing bucket, so they always upper-bound the true quantile and
//! are off by at most one bucket width (a factor of 2) — property-tested
//! below against exact [`crate::util::stats::Samples`] quantiles.
//!
//! Snapshots ([`StatsSnapshot`]) are taken on the scrape path (allocation
//! there is fine) and merge associatively across replicas, which is how
//! the fleet coordinator aggregates per-replica families.

pub mod expo;
pub mod flightrec;
pub mod trace;

use crate::util::json::{arr, obj, Json};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Schema version of the [`StatsSnapshot`] JSON rendering (the NDJSON
/// `stats` frame carries this as `"version"`).
///
/// v2: prefix-cache families (`kv_prefix_hits`, `kv_prefix_misses`,
/// `kv_pages_cow` counters; `kv_pages_shared` gauge) — see
/// docs/PROTOCOL.md.
///
/// v3: fleet failover families in the `fleet` section
/// (`requests_rerouted` / `reroute_aborted` / `replica_retired`
/// counters; `fleet_replicas` / `replica_suspect` gauges) — see
/// docs/PROTOCOL.md.
pub const STATS_VERSION: i64 = 3;

/// Number of log2 buckets in a [`Histo`] (covers the full `u64` range).
pub const HISTO_BUCKETS: usize = 64;

/// Bucket index for a recorded value: 0 for 0, else the bit length of
/// `v`, clamped into the last bucket.
#[inline]
fn bucket_of(v: u64) -> usize {
    if v == 0 {
        0
    } else {
        (64 - v.leading_zeros() as usize).min(HISTO_BUCKETS - 1)
    }
}

/// Inclusive upper bound of bucket `b` (the quantile estimate returned
/// for ranks landing in that bucket). The last bucket is unbounded.
#[inline]
fn bucket_upper(b: usize) -> u64 {
    match b {
        0 => 0,
        _ if b >= HISTO_BUCKETS - 1 => u64::MAX,
        _ => (1u64 << b) - 1,
    }
}

/// Fixed-size lock-free log2 histogram. `record` is wait-free: three
/// `Relaxed` fetch-adds on preallocated atomics.
#[derive(Debug)]
pub struct Histo {
    buckets: [AtomicU64; HISTO_BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
}

impl Default for Histo {
    fn default() -> Self {
        Histo {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }
}

impl Histo {
    #[inline]
    pub fn record(&self, v: u64) {
        self.buckets[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> HistoSnapshot {
        HistoSnapshot {
            buckets: self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
            count: self.count.load(Ordering::Relaxed),
            sum: self.sum.load(Ordering::Relaxed),
        }
    }

    fn reset(&self) {
        for b in &self.buckets {
            b.store(0, Ordering::Relaxed);
        }
        self.count.store(0, Ordering::Relaxed);
        self.sum.store(0, Ordering::Relaxed);
    }
}

/// Point-in-time copy of a [`Histo`]; merges associatively (bucketwise
/// addition), so replica families can be aggregated in any order.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct HistoSnapshot {
    pub buckets: Vec<u64>,
    pub count: u64,
    pub sum: u64,
}

impl HistoSnapshot {
    pub fn merge(&mut self, other: &HistoSnapshot) {
        if self.buckets.is_empty() {
            self.buckets = vec![0; HISTO_BUCKETS];
        }
        for (i, c) in other.buckets.iter().enumerate() {
            self.buckets[i] += c;
        }
        self.count += other.count;
        self.sum = self.sum.wrapping_add(other.sum);
    }

    /// Quantile estimate for `q` in `[0, 1]`: the inclusive upper bound of
    /// the bucket containing the (nearest-rank) quantile. Always
    /// upper-bounds the exact nearest-rank quantile, and exceeds it by at
    /// most one log2 bucket width (`est <= 2 * exact + 1`).
    pub fn quantile(&self, q: f64) -> Option<u64> {
        if self.count == 0 {
            return None;
        }
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut acc = 0u64;
        for (b, c) in self.buckets.iter().enumerate() {
            acc += c;
            if acc >= rank {
                return Some(bucket_upper(b));
            }
        }
        Some(bucket_upper(HISTO_BUCKETS - 1))
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Compact JSON for the NDJSON `stats` frame: quantile estimates, not
    /// raw buckets (the Prometheus exposition carries the full buckets).
    fn to_json(&self) -> Json {
        let q = |p: f64| self.quantile(p).map_or(Json::Null, |v| Json::Int(v as i64));
        obj(vec![
            ("count", Json::Int(self.count as i64)),
            ("sum", Json::Int(self.sum as i64)),
            ("p50", q(0.50)),
            ("p90", q(0.90)),
            ("p99", q(0.99)),
        ])
    }
}

/// Per-adapter counter block. Index 0 of [`ObsRegistry::adapters`] is the
/// base model; index `aid + 1` is the registry slot `aid`. The name is
/// the only non-atomic field and is written solely on load/evict.
#[derive(Debug, Default)]
struct AdapterSlot {
    name: Mutex<String>,
    submitted: AtomicU64,
    completed: AtomicU64,
    aborted: AtomicU64,
    tokens: AtomicU64,
}

impl AdapterSlot {
    fn reset_counters(&self) {
        self.submitted.store(0, Ordering::Relaxed);
        self.completed.store(0, Ordering::Relaxed);
        self.aborted.store(0, Ordering::Relaxed);
        self.tokens.store(0, Ordering::Relaxed);
    }
}

/// The live telemetry registry. One per engine; shared as an
/// `Arc<ObsRegistry>` with the replica heartbeat (fleet) and the
/// Prometheus exposition thread.
///
/// All `record_*` methods are wait-free (preallocated atomics, `Relaxed`)
/// and allocation-free; `snapshot()` is the cold scrape path.
#[derive(Debug)]
pub struct ObsRegistry {
    enabled: AtomicBool,
    // counters
    steps: AtomicU64,
    requests_submitted: AtomicU64,
    requests_completed: AtomicU64,
    requests_rejected: AtomicU64,
    requests_aborted: AtomicU64,
    tokens_prefill: AtomicU64,
    tokens_decode: AtomicU64,
    // prefix-cache counters (paged KV cache sharing)
    kv_prefix_hits: AtomicU64,
    kv_prefix_misses: AtomicU64,
    kv_pages_cow: AtomicU64,
    // histograms (microseconds)
    step_wall_us: Histo,
    step_exec_us: Histo,
    ttft_us: Histo,
    e2e_us: Histo,
    // gauges
    kv_free: AtomicU64,
    kv_pages_shared: AtomicU64,
    waiting: AtomicU64,
    running: AtomicU64,
    // labelled counters, preallocated: [base, aid 0, aid 1, ...]
    adapters: Vec<AdapterSlot>,
}

impl ObsRegistry {
    /// Build a registry with room for `max_adapters` labelled slots plus
    /// the base model. Recording accepts any `aid` in `-1..max_adapters`.
    pub fn new(max_adapters: usize) -> Self {
        let adapters: Vec<AdapterSlot> =
            (0..=max_adapters).map(|_| AdapterSlot::default()).collect();
        *adapters[0].name.lock().unwrap() = "base".into();
        ObsRegistry {
            enabled: AtomicBool::new(true),
            steps: AtomicU64::new(0),
            requests_submitted: AtomicU64::new(0),
            requests_completed: AtomicU64::new(0),
            requests_rejected: AtomicU64::new(0),
            requests_aborted: AtomicU64::new(0),
            tokens_prefill: AtomicU64::new(0),
            tokens_decode: AtomicU64::new(0),
            kv_prefix_hits: AtomicU64::new(0),
            kv_prefix_misses: AtomicU64::new(0),
            kv_pages_cow: AtomicU64::new(0),
            step_wall_us: Histo::default(),
            step_exec_us: Histo::default(),
            ttft_us: Histo::default(),
            e2e_us: Histo::default(),
            kv_free: AtomicU64::new(0),
            kv_pages_shared: AtomicU64::new(0),
            waiting: AtomicU64::new(0),
            running: AtomicU64::new(0),
            adapters,
        }
    }

    /// Turn recording on/off (the obs-off bench series; scrape surfaces
    /// keep working on whatever was recorded so far).
    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    #[inline]
    pub fn is_enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    #[inline]
    fn slot(&self, aid: i32) -> Option<&AdapterSlot> {
        self.adapters.get((aid + 1) as usize)
    }

    /// One engine step: wall/execute time (µs) and the token split of the
    /// batch. Called from `Engine::step` — must stay allocation-free.
    #[inline]
    pub fn record_step(&self, wall_us: u64, exec_us: u64, prefill_tokens: u64, decode_tokens: u64) {
        if !self.is_enabled() {
            return;
        }
        self.steps.fetch_add(1, Ordering::Relaxed);
        self.step_wall_us.record(wall_us);
        self.step_exec_us.record(exec_us);
        self.tokens_prefill.fetch_add(prefill_tokens, Ordering::Relaxed);
        self.tokens_decode.fetch_add(decode_tokens, Ordering::Relaxed);
    }

    /// One sampled output token for `aid` (-1 = base). Per-row in the
    /// step loop — must stay allocation-free.
    #[inline]
    pub fn record_token(&self, aid: i32) {
        if !self.is_enabled() {
            return;
        }
        if let Some(s) = self.slot(aid) {
            s.tokens.fetch_add(1, Ordering::Relaxed);
        }
    }

    #[inline]
    pub fn record_submitted(&self, aid: i32) {
        if !self.is_enabled() {
            return;
        }
        self.requests_submitted.fetch_add(1, Ordering::Relaxed);
        if let Some(s) = self.slot(aid) {
            s.submitted.fetch_add(1, Ordering::Relaxed);
        }
    }

    #[inline]
    pub fn record_rejected(&self) {
        if !self.is_enabled() {
            return;
        }
        self.requests_rejected.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn record_aborted(&self, aid: i32) {
        if !self.is_enabled() {
            return;
        }
        self.requests_aborted.fetch_add(1, Ordering::Relaxed);
        if let Some(s) = self.slot(aid) {
            s.aborted.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// One finished request with its first-token and end-to-end latency.
    #[inline]
    pub fn record_completed(&self, aid: i32, ttft_us: u64, e2e_us: u64) {
        if !self.is_enabled() {
            return;
        }
        self.requests_completed.fetch_add(1, Ordering::Relaxed);
        self.ttft_us.record(ttft_us);
        self.e2e_us.record(e2e_us);
        if let Some(s) = self.slot(aid) {
            s.completed.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Publish instantaneous gauges (KV free slots, queue depths).
    #[inline]
    pub fn set_gauges(&self, kv_free: u64, waiting: u64, running: u64) {
        self.kv_free.store(kv_free, Ordering::Relaxed);
        self.waiting.store(waiting, Ordering::Relaxed);
        self.running.store(running, Ordering::Relaxed);
    }

    /// Prefix-cache outcome of one scheduling round: prompt tokens
    /// adopted from shared pages (`hits`) vs prefilled fresh (`misses`).
    /// Called from `Engine::step` with per-step deltas — allocation-free.
    #[inline]
    pub fn record_prefix(&self, hits: u64, misses: u64) {
        if !self.is_enabled() || (hits == 0 && misses == 0) {
            return;
        }
        self.kv_prefix_hits.fetch_add(hits, Ordering::Relaxed);
        self.kv_prefix_misses.fetch_add(misses, Ordering::Relaxed);
    }

    /// Copy-on-write page splits performed this step (paged KV cache).
    #[inline]
    pub fn record_cow(&self, copies: u64) {
        if !self.is_enabled() || copies == 0 {
            return;
        }
        self.kv_pages_cow.fetch_add(copies, Ordering::Relaxed);
    }

    /// Publish the shared-pages gauge (physical KV pages referenced by
    /// more than one sequence right now).
    #[inline]
    pub fn set_kv_shared(&self, pages: u64) {
        self.kv_pages_shared.store(pages, Ordering::Relaxed);
    }

    /// Label slot `aid` (on adapter load / registry sync). A name change
    /// means the physical slot was reused by a different adapter, so the
    /// slot counters restart from zero under the new label.
    pub fn set_adapter_name(&self, aid: i32, name: &str) {
        if let Some(s) = self.slot(aid) {
            let mut n = s.name.lock().unwrap();
            if *n != name {
                s.reset_counters();
                *n = name.to_string();
            }
        }
    }

    /// Clear slot `aid`'s label on eviction (its counters stop being
    /// exported until the slot is reused).
    pub fn clear_adapter_name(&self, aid: i32) {
        if let Some(s) = self.slot(aid) {
            s.name.lock().unwrap().clear();
            s.reset_counters();
        }
    }

    /// Point-in-time copy of everything (the scrape path; allocates).
    pub fn snapshot(&self) -> StatsSnapshot {
        let ld = |a: &AtomicU64| a.load(Ordering::Relaxed);
        let adapters = self
            .adapters
            .iter()
            .filter_map(|s| {
                let name = s.name.lock().unwrap().clone();
                if name.is_empty() {
                    return None;
                }
                Some(AdapterStats {
                    name,
                    submitted: ld(&s.submitted),
                    completed: ld(&s.completed),
                    aborted: ld(&s.aborted),
                    tokens: ld(&s.tokens),
                })
            })
            .collect();
        StatsSnapshot {
            replicas: 1,
            steps: ld(&self.steps),
            requests_submitted: ld(&self.requests_submitted),
            requests_completed: ld(&self.requests_completed),
            requests_rejected: ld(&self.requests_rejected),
            requests_aborted: ld(&self.requests_aborted),
            tokens_prefill: ld(&self.tokens_prefill),
            tokens_decode: ld(&self.tokens_decode),
            kv_prefix_hits: ld(&self.kv_prefix_hits),
            kv_prefix_misses: ld(&self.kv_prefix_misses),
            kv_pages_cow: ld(&self.kv_pages_cow),
            kv_free: ld(&self.kv_free),
            kv_pages_shared: ld(&self.kv_pages_shared),
            waiting: ld(&self.waiting),
            running: ld(&self.running),
            step_wall_us: self.step_wall_us.snapshot(),
            step_exec_us: self.step_exec_us.snapshot(),
            ttft_us: self.ttft_us.snapshot(),
            e2e_us: self.e2e_us.snapshot(),
            adapters,
            fleet: Vec::new(),
        }
    }

    /// Reset all counters and histograms (session reset); labels and the
    /// enabled flag survive.
    pub fn reset(&self) {
        self.steps.store(0, Ordering::Relaxed);
        self.requests_submitted.store(0, Ordering::Relaxed);
        self.requests_completed.store(0, Ordering::Relaxed);
        self.requests_rejected.store(0, Ordering::Relaxed);
        self.requests_aborted.store(0, Ordering::Relaxed);
        self.tokens_prefill.store(0, Ordering::Relaxed);
        self.tokens_decode.store(0, Ordering::Relaxed);
        self.kv_prefix_hits.store(0, Ordering::Relaxed);
        self.kv_prefix_misses.store(0, Ordering::Relaxed);
        self.kv_pages_cow.store(0, Ordering::Relaxed);
        self.step_wall_us.reset();
        self.step_exec_us.reset();
        self.ttft_us.reset();
        self.e2e_us.reset();
        self.kv_free.store(0, Ordering::Relaxed);
        self.kv_pages_shared.store(0, Ordering::Relaxed);
        self.waiting.store(0, Ordering::Relaxed);
        self.running.store(0, Ordering::Relaxed);
        for s in &self.adapters {
            s.reset_counters();
        }
    }
}

/// Per-adapter counter snapshot (one exposition label set).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AdapterStats {
    pub name: String,
    pub submitted: u64,
    pub completed: u64,
    pub aborted: u64,
    pub tokens: u64,
}

/// Point-in-time view of one registry — or, after [`merge`], of a whole
/// fleet. Rendered as the NDJSON `stats` frame body (see
/// docs/PROTOCOL.md) and consumed by the Prometheus exposition.
///
/// [`merge`]: StatsSnapshot::merge
#[derive(Debug, Clone, PartialEq, Default)]
pub struct StatsSnapshot {
    /// Registries merged into this snapshot (1 = single engine).
    pub replicas: usize,
    pub steps: u64,
    pub requests_submitted: u64,
    pub requests_completed: u64,
    pub requests_rejected: u64,
    pub requests_aborted: u64,
    pub tokens_prefill: u64,
    pub tokens_decode: u64,
    /// Prompt tokens adopted from shared prefix pages (paged KV cache).
    pub kv_prefix_hits: u64,
    /// Prompt tokens that had to be prefilled fresh.
    pub kv_prefix_misses: u64,
    /// Copy-on-write page splits performed.
    pub kv_pages_cow: u64,
    /// Gauges; summed across replicas on merge.
    pub kv_free: u64,
    /// Physical KV pages currently referenced by more than one sequence.
    pub kv_pages_shared: u64,
    pub waiting: u64,
    pub running: u64,
    pub step_wall_us: HistoSnapshot,
    pub step_exec_us: HistoSnapshot,
    pub ttft_us: HistoSnapshot,
    pub e2e_us: HistoSnapshot,
    /// Per-adapter families, merged by name across replicas, sorted.
    pub adapters: Vec<AdapterStats>,
    /// Fleet-door counters (coordinator only: routed, shed, ...).
    pub fleet: Vec<(String, u64)>,
}

impl StatsSnapshot {
    /// Aggregate `other` into `self` (replica family merge). Counters and
    /// gauges sum, histograms merge bucketwise, adapters merge by name —
    /// associative and commutative, property-tested below.
    pub fn merge(&mut self, other: &StatsSnapshot) {
        self.replicas += other.replicas;
        self.steps += other.steps;
        self.requests_submitted += other.requests_submitted;
        self.requests_completed += other.requests_completed;
        self.requests_rejected += other.requests_rejected;
        self.requests_aborted += other.requests_aborted;
        self.tokens_prefill += other.tokens_prefill;
        self.tokens_decode += other.tokens_decode;
        self.kv_prefix_hits += other.kv_prefix_hits;
        self.kv_prefix_misses += other.kv_prefix_misses;
        self.kv_pages_cow += other.kv_pages_cow;
        self.kv_free += other.kv_free;
        self.kv_pages_shared += other.kv_pages_shared;
        self.waiting += other.waiting;
        self.running += other.running;
        self.step_wall_us.merge(&other.step_wall_us);
        self.step_exec_us.merge(&other.step_exec_us);
        self.ttft_us.merge(&other.ttft_us);
        self.e2e_us.merge(&other.e2e_us);
        let mut by_name: BTreeMap<String, AdapterStats> = BTreeMap::new();
        for a in self.adapters.drain(..).chain(other.adapters.iter().cloned()) {
            let e = by_name.entry(a.name.clone()).or_insert_with(|| AdapterStats {
                name: a.name.clone(),
                submitted: 0,
                completed: 0,
                aborted: 0,
                tokens: 0,
            });
            e.submitted += a.submitted;
            e.completed += a.completed;
            e.aborted += a.aborted;
            e.tokens += a.tokens;
        }
        self.adapters = by_name.into_values().collect();
        for (k, v) in &other.fleet {
            match self.fleet.iter_mut().find(|(n, _)| n == k) {
                Some(slot) => slot.1 += v,
                None => self.fleet.push((k.clone(), *v)),
            }
        }
    }

    /// The versioned `stats` frame body (without the `event`/`id` keys,
    /// which the frontend adds).
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("version", Json::Int(STATS_VERSION)),
            ("replicas", Json::Int(self.replicas as i64)),
            (
                "counters",
                obj(vec![
                    ("steps", Json::Int(self.steps as i64)),
                    ("requests_submitted", Json::Int(self.requests_submitted as i64)),
                    ("requests_completed", Json::Int(self.requests_completed as i64)),
                    ("requests_rejected", Json::Int(self.requests_rejected as i64)),
                    ("requests_aborted", Json::Int(self.requests_aborted as i64)),
                    ("tokens_prefill", Json::Int(self.tokens_prefill as i64)),
                    ("tokens_decode", Json::Int(self.tokens_decode as i64)),
                    ("kv_prefix_hits", Json::Int(self.kv_prefix_hits as i64)),
                    ("kv_prefix_misses", Json::Int(self.kv_prefix_misses as i64)),
                    ("kv_pages_cow", Json::Int(self.kv_pages_cow as i64)),
                ]),
            ),
            (
                "gauges",
                obj(vec![
                    ("kv_free", Json::Int(self.kv_free as i64)),
                    ("kv_pages_shared", Json::Int(self.kv_pages_shared as i64)),
                    ("waiting", Json::Int(self.waiting as i64)),
                    ("running", Json::Int(self.running as i64)),
                ]),
            ),
            (
                "latency_us",
                obj(vec![
                    ("step_wall", self.step_wall_us.to_json()),
                    ("step_exec", self.step_exec_us.to_json()),
                    ("ttft", self.ttft_us.to_json()),
                    ("e2e", self.e2e_us.to_json()),
                ]),
            ),
            (
                "adapters",
                arr(self
                    .adapters
                    .iter()
                    .map(|a| {
                        obj(vec![
                            ("adapter", Json::Str(a.name.clone())),
                            ("submitted", Json::Int(a.submitted as i64)),
                            ("completed", Json::Int(a.completed as i64)),
                            ("aborted", Json::Int(a.aborted as i64)),
                            ("tokens_generated", Json::Int(a.tokens as i64)),
                        ])
                    })
                    .collect()),
            ),
        ];
        if !self.fleet.is_empty() {
            fields.push((
                "fleet",
                Json::Obj(
                    self.fleet
                        .iter()
                        .map(|(k, v)| (k.clone(), Json::Int(*v as i64)))
                        .collect(),
                ),
            ));
        }
        obj(fields)
    }
}

/// Fleet-level live telemetry shared between the coordinator and the
/// Prometheus exposition ([`expo::render_fleet`]): failure-handling
/// counters/gauges plus the *dynamic* list of replica registries.
///
/// The coordinator updates the atomics from its event loop (never the
/// engine hot path) and pushes a registry when a replica joins at
/// runtime; the metrics listener thread reads everything lock-free
/// except the registry list (a short mutex-guarded clone per scrape).
/// Registries of dead replicas stay listed — their counters are history
/// the fleet totals must keep.
#[derive(Debug, Default)]
pub struct FleetObs {
    /// Live (routable) replicas right now.
    pub replicas: AtomicU64,
    /// Live replicas whose heartbeat is currently stale (excluded from
    /// routing but not yet retired).
    pub suspect: AtomicU64,
    /// Requests re-submitted to a surviving replica after theirs died.
    pub rerouted: AtomicU64,
    /// Failover aborts: the remaining deadline could not survive the
    /// retry (clients saw `replica_lost`).
    pub reroute_aborted: AtomicU64,
    /// Replicas retired — crashed, killed, or drained out.
    pub retired: AtomicU64,
    registries: Mutex<Vec<Arc<ObsRegistry>>>,
}

impl FleetObs {
    pub fn new() -> FleetObs {
        FleetObs::default()
    }

    /// Register one replica's live metric registry (launch or runtime
    /// join). Never removed: dead replicas keep their history.
    pub fn push_registry(&self, reg: Arc<ObsRegistry>) {
        self.registries.lock().unwrap().push(reg);
    }

    /// Snapshot of the registry list (cheap `Arc` clones).
    pub fn registries(&self) -> Vec<Arc<ObsRegistry>> {
        self.registries.lock().unwrap().clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Pcg;
    use crate::util::stats::Samples;

    fn random_histo(rng: &mut Pcg, n: usize, cap: u64) -> (HistoSnapshot, Vec<u64>) {
        let h = Histo::default();
        let mut vals = Vec::with_capacity(n);
        for _ in 0..n {
            let v = rng.below(cap);
            h.record(v);
            vals.push(v);
        }
        (h.snapshot(), vals)
    }

    #[test]
    fn bucket_layout() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 1);
        assert_eq!(bucket_of(2), 2);
        assert_eq!(bucket_of(3), 2);
        assert_eq!(bucket_of(4), 3);
        assert_eq!(bucket_of(u64::MAX), HISTO_BUCKETS - 1);
        assert_eq!(bucket_upper(0), 0);
        assert_eq!(bucket_upper(1), 1);
        assert_eq!(bucket_upper(2), 3);
        assert_eq!(bucket_upper(62), (1 << 62) - 1);
        assert_eq!(bucket_upper(63), u64::MAX);
        // every value falls at or below its bucket's upper bound
        for v in [0u64, 1, 2, 3, 7, 8, 1000, 1 << 40] {
            assert!(v <= bucket_upper(bucket_of(v)), "v={v}");
        }
    }

    /// Property (satellite): log2-bucket quantile estimates bound the
    /// true `Samples` quantile at the matching nearest rank from above,
    /// within one bucket width (factor of 2).
    #[test]
    fn quantile_estimate_bounds_exact_within_one_bucket() {
        prop::check(61, 200, |rng| {
            let n = 1 + rng.below(400) as usize;
            let (snap, vals) = random_histo(rng, n, 1 << 20);
            let mut s = Samples::new();
            for &v in &vals {
                s.push(v as f64);
            }
            for q in [0.10, 0.50, 0.90, 0.99] {
                // nearest-rank exact quantile, extracted through Samples
                // by asking for the percentile that lands exactly on the
                // rank (linear interpolation at an integer rank is exact)
                let rank = ((q * n as f64).ceil() as usize).clamp(1, n);
                let p = if n == 1 {
                    50.0
                } else {
                    100.0 * (rank - 1) as f64 / (n - 1) as f64
                };
                let exact = s.percentile(p);
                let est = snap.quantile(q).unwrap() as f64;
                assert!(
                    est >= exact,
                    "estimate must upper-bound: q={q} est={est} exact={exact}"
                );
                assert!(
                    est <= 2.0 * exact + 1.0,
                    "within one log2 bucket: q={q} est={est} exact={exact}"
                );
            }
        });
    }

    /// Property (satellite): merging replica families is associative and
    /// commutative, with the empty snapshot as identity — aggregation
    /// order across the fleet cannot change the answer.
    #[test]
    fn merge_is_associative_and_commutative() {
        prop::check(62, 100, |rng| {
            let mk = |rng: &mut Pcg| {
                let (h, _) = random_histo(rng, 1 + rng.below(64) as usize, 1 << 16);
                h
            };
            let (a, b, c) = (mk(rng), mk(rng), mk(rng));

            let mut ab_c = a.clone();
            ab_c.merge(&b);
            ab_c.merge(&c);

            let mut bc = b.clone();
            bc.merge(&c);
            let mut a_bc = a.clone();
            a_bc.merge(&bc);

            let mut ba_c = b.clone();
            ba_c.merge(&a);
            ba_c.merge(&c);

            assert_eq!(ab_c, a_bc, "associative");
            assert_eq!(ab_c, ba_c, "commutative");
            assert_eq!(ab_c.count, a.count + b.count + c.count);

            let mut with_id = ab_c.clone();
            with_id.merge(&HistoSnapshot::default());
            assert_eq!(with_id, ab_c, "identity");
        });
    }

    /// Full-snapshot merge: per-adapter families combine by name, in any
    /// replica order.
    #[test]
    fn snapshot_merge_combines_adapter_families_by_name() {
        prop::check(63, 50, |rng| {
            let names = ["math", "code", "base"];
            let mk = |rng: &mut Pcg| {
                let mut s = StatsSnapshot { replicas: 1, ..Default::default() };
                for name in names.iter().take(1 + rng.below(3) as usize) {
                    s.adapters.push(AdapterStats {
                        name: name.to_string(),
                        submitted: rng.below(100),
                        completed: rng.below(100),
                        aborted: rng.below(10),
                        tokens: rng.below(10_000),
                    });
                }
                s.requests_completed = s.adapters.iter().map(|a| a.completed).sum();
                s
            };
            let (a, b) = (mk(rng), mk(rng));
            let mut ab = a.clone();
            ab.merge(&b);
            let mut ba = b.clone();
            ba.merge(&a);
            assert_eq!(ab, ba, "fleet aggregation is order-independent");
            assert_eq!(ab.replicas, 2);
            assert_eq!(
                ab.requests_completed,
                a.requests_completed + b.requests_completed
            );
            // totals attribute exactly: sum over merged adapter families
            // equals the sum over both inputs
            let total = |s: &StatsSnapshot| s.adapters.iter().map(|x| x.completed).sum::<u64>();
            assert_eq!(total(&ab), total(&a) + total(&b));
            // merged list is sorted and duplicate-free
            for w in ab.adapters.windows(2) {
                assert!(w[0].name < w[1].name);
            }
        });
    }

    #[test]
    fn registry_records_and_snapshots() {
        let r = ObsRegistry::new(2);
        r.set_adapter_name(0, "math");
        r.record_submitted(0);
        r.record_submitted(-1);
        r.record_token(0);
        r.record_token(0);
        r.record_token(-1);
        r.record_step(120, 80, 16, 8);
        r.record_completed(0, 1_500, 30_000);
        r.record_rejected();
        r.set_gauges(100, 2, 6);
        r.record_prefix(12, 4);
        r.record_cow(1);
        r.set_kv_shared(3);
        let s = r.snapshot();
        assert_eq!(s.steps, 1);
        assert_eq!(s.requests_submitted, 2);
        assert_eq!(s.requests_completed, 1);
        assert_eq!(s.requests_rejected, 1);
        assert_eq!((s.tokens_prefill, s.tokens_decode), (16, 8));
        assert_eq!((s.kv_free, s.waiting, s.running), (100, 2, 6));
        assert_eq!((s.kv_prefix_hits, s.kv_prefix_misses), (12, 4));
        assert_eq!((s.kv_pages_cow, s.kv_pages_shared), (1, 3));
        assert_eq!(s.step_wall_us.count, 1);
        assert!(s.step_wall_us.quantile(0.5).unwrap() >= 120);
        let math = s.adapters.iter().find(|a| a.name == "math").unwrap();
        assert_eq!((math.submitted, math.completed, math.tokens), (1, 1, 2));
        let base = s.adapters.iter().find(|a| a.name == "base").unwrap();
        assert_eq!((base.submitted, base.tokens), (1, 1));
        // out-of-range aids are ignored, not panics
        r.record_token(99);
        r.record_submitted(-5);

        // disabled: nothing moves
        r.set_enabled(false);
        r.record_step(1, 1, 1, 1);
        r.record_submitted(0);
        assert_eq!(r.snapshot().steps, 1);
        r.set_enabled(true);

        // slot reuse under a new name restarts its counters
        r.set_adapter_name(0, "code");
        let s2 = r.snapshot();
        let code = s2.adapters.iter().find(|a| a.name == "code").unwrap();
        assert_eq!(code.tokens, 0);
        assert!(!s2.adapters.iter().any(|a| a.name == "math"));

        r.reset();
        let s3 = r.snapshot();
        assert_eq!(s3.steps, 0);
        assert_eq!(s3.requests_submitted, 0);
    }

    #[test]
    fn stats_frame_json_shape() {
        let r = ObsRegistry::new(1);
        r.set_adapter_name(0, "math");
        r.record_submitted(0);
        r.record_completed(0, 1000, 2000);
        r.record_prefix(8, 2);
        let j = r.snapshot().to_json();
        assert_eq!(j.at(&["version"]).as_i64(), Some(STATS_VERSION));
        assert_eq!(j.at(&["replicas"]).as_i64(), Some(1));
        assert_eq!(j.at(&["counters", "requests_completed"]).as_i64(), Some(1));
        assert_eq!(j.at(&["counters", "kv_prefix_hits"]).as_i64(), Some(8));
        assert_eq!(j.at(&["counters", "kv_pages_cow"]).as_i64(), Some(0));
        assert_eq!(j.at(&["gauges", "kv_pages_shared"]).as_i64(), Some(0));
        let adapters = j.at(&["adapters"]).as_arr().unwrap();
        assert!(adapters.iter().any(|a| {
            a.at(&["adapter"]).as_str() == Some("math")
                && a.at(&["completed"]).as_i64() == Some(1)
        }));
        // fleet block only present when populated
        assert!(j.get("fleet").is_none());
    }
}
