//! Per-request phase tracing, exportable as Chrome-trace JSON
//! (`chrome://tracing`, Perfetto) — for one engine or for a whole fleet.
//!
//! The engine stamps request phases in the scheduler's per-slot state
//! (queued → admitted → first-scheduled → prefill-done → decode →
//! done/aborted; see [`crate::scheduler::SeqState`]) and, when tracing is
//! enabled, folds each finished request into a [`RequestSpan`] here.
//!
//! On a fleet, the coordinator keeps its own `TraceLog` for door-side
//! events: a [`RouteSpan`] per routed request (admission queue wait +
//! routing decision with the scored candidate set) and a [`DoorEvent`]
//! per request refused at the door (shed, queue-full, unmeetable
//! deadline, ...). At drain it [`TraceLog::absorb`]s every replica's
//! log into one merged timeline: `pid` 0 is the coordinator, `pid`
//! `replica + 1` is that replica's engine, and replica-local request
//! ids are re-keyed to fleet request ids so one request is one `tid`
//! across processes. Every span carries the request's end-to-end
//! **trace id** (client-supplied via the NDJSON `trace` field, or the
//! fleet request id) in its `args`, which is how a Perfetto query ties
//! the door-admission span to the replica's decode span.
//!
//! Tracing is opt-in (`--trace-out`) and entirely off the steady-state
//! path: spans are recorded only at routing/completion/abort, never per
//! step. (The always-on counterpart is [`crate::obs::flightrec`].)

use crate::util::json::{arr, obj, Json};
use std::collections::HashMap;
use std::time::Instant;

/// One request's phase timeline, in microseconds relative to the trace
/// origin. Missing stamps (e.g. a request aborted while queued) truncate
/// the timeline at the last phase reached.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RequestSpan {
    pub id: u64,
    /// End-to-end trace id tying this span to coordinator-side spans
    /// (0 = none: standalone engine with no client-supplied id).
    pub trace: u64,
    /// Chrome-trace process id this span renders under (1 for a
    /// standalone engine; the fleet merge rewrites it to `replica + 1`).
    pub pid: u64,
    /// Adapter name, or `"base"`.
    pub adapter: String,
    /// `"done"`, `"cancelled"` or `"deadline"`.
    pub outcome: &'static str,
    pub arrival_us: u64,
    pub admitted_us: Option<u64>,
    pub first_scheduled_us: Option<u64>,
    pub prefill_done_us: Option<u64>,
    pub first_token_us: Option<u64>,
    pub finished_us: u64,
}

/// One scored replica in a routing decision (a row of the candidate set
/// the policy chose from).
#[derive(Debug, Clone, PartialEq)]
pub struct Candidate {
    pub replica: usize,
    pub inflight: usize,
    pub kv_free: usize,
    pub expected_wait_us: u64,
    pub resident: bool,
}

/// Coordinator-side timeline of one routed request: admission queue
/// wait (`arrival → admitted`) and the routing decision
/// (`admitted → routed`), with the policy, the scored candidate set,
/// and the chosen replica.
#[derive(Debug, Clone, PartialEq)]
pub struct RouteSpan {
    /// Fleet request id (`tid` of the coordinator track).
    pub rid: u64,
    /// End-to-end trace id (client-supplied or `rid`).
    pub trace: u64,
    pub adapter: String,
    pub policy: &'static str,
    /// The replica the request was placed on.
    pub replica: usize,
    /// The adapter was already resident there (affinity hit).
    pub resident: bool,
    pub candidates: Vec<Candidate>,
    pub arrival_us: u64,
    pub admitted_us: u64,
    pub routed_us: u64,
}

/// A request refused at the fleet door (never placed): shed, queue
/// bound, unknown adapter, unmeetable deadline, shutdown.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DoorEvent {
    pub trace: u64,
    pub adapter: String,
    /// The typed rejection (`crate::serving::SubmitError::code`).
    pub code: &'static str,
    pub at_us: u64,
}

/// Accumulates [`RequestSpan`]s (and, on a coordinator, [`RouteSpan`]s /
/// [`DoorEvent`]s) against a fixed time origin and writes them out in
/// the Chrome trace-event format.
#[derive(Debug)]
pub struct TraceLog {
    origin: Instant,
    spans: Vec<RequestSpan>,
    routes: Vec<RouteSpan>,
    doors: Vec<DoorEvent>,
}

impl Default for TraceLog {
    fn default() -> Self {
        Self::with_origin(Instant::now())
    }
}

impl TraceLog {
    pub fn new() -> Self {
        Self::default()
    }

    /// A log whose time zero is `origin`. Engines anchor this at
    /// *construction* (not at `enable_trace`) so stamps of requests
    /// queued before tracing was turned on keep their real offsets
    /// instead of collapsing onto t=0.
    pub fn with_origin(origin: Instant) -> Self {
        TraceLog { origin, spans: Vec::new(), routes: Vec::new(), doors: Vec::new() }
    }

    pub fn origin(&self) -> Instant {
        self.origin
    }

    /// Microseconds since the trace origin (saturating at 0 for stamps
    /// that predate it — which, with the origin anchored at engine
    /// construction, cannot happen for stamps the engine itself takes).
    pub fn rel_us(&self, t: Instant) -> u64 {
        t.saturating_duration_since(self.origin).as_micros() as u64
    }

    pub fn record(&mut self, span: RequestSpan) {
        self.spans.push(span);
    }

    pub fn record_route(&mut self, span: RouteSpan) {
        self.routes.push(span);
    }

    pub fn record_door(&mut self, ev: DoorEvent) {
        self.doors.push(ev);
    }

    pub fn len(&self) -> usize {
        self.spans.len()
    }

    pub fn is_empty(&self) -> bool {
        self.spans.is_empty() && self.routes.is_empty() && self.doors.is_empty()
    }

    pub fn spans(&self) -> &[RequestSpan] {
        &self.spans
    }

    pub fn routes(&self) -> &[RouteSpan] {
        &self.routes
    }

    pub fn doors(&self) -> &[DoorEvent] {
        &self.doors
    }

    /// Fold a replica engine's log into this (coordinator) log: rebase
    /// every stamp from `other`'s origin onto ours (both origins come
    /// from the same process-wide monotonic clock, so the shift is
    /// exact), rewrite `pid` to the fleet-assigned process id, and
    /// re-key replica-local request ids to fleet request ids via
    /// `rekey` (trace id → fleet rid) so one request is one `tid`
    /// across the merged timeline.
    pub fn absorb(&mut self, other: TraceLog, pid: u64, rekey: &HashMap<u64, u64>) {
        let fwd = other.origin.saturating_duration_since(self.origin).as_micros() as u64;
        let back = self.origin.saturating_duration_since(other.origin).as_micros() as u64;
        let shift = |us: u64| (us + fwd).saturating_sub(back);
        for mut s in other.spans {
            s.pid = pid;
            if let Some(&rid) = rekey.get(&s.trace) {
                s.id = rid;
            }
            s.arrival_us = shift(s.arrival_us);
            s.admitted_us = s.admitted_us.map(shift);
            s.first_scheduled_us = s.first_scheduled_us.map(shift);
            s.prefill_done_us = s.prefill_done_us.map(shift);
            s.first_token_us = s.first_token_us.map(shift);
            s.finished_us = shift(s.finished_us);
            self.spans.push(s);
        }
    }

    /// The `{"traceEvents": [...]}` document. Request phases become
    /// `ph:"X"` complete events on track `pid` = span's process,
    /// `tid` = request id; coordinator route spans render on `pid` 0
    /// (`door_admission` + `routing_decision`); door rejections are
    /// instant events; process-name metadata labels each `pid` for
    /// Perfetto.
    pub fn to_chrome_json(&self) -> Json {
        let mut events = Vec::new();
        let fleet = !self.routes.is_empty() || !self.doors.is_empty();
        // process-name metadata so Perfetto shows "coordinator" /
        // "replica N" instead of bare pids
        let mut pids: Vec<u64> = self.spans.iter().map(|s| s.pid).collect();
        pids.sort_unstable();
        pids.dedup();
        let proc_name = |pid: u64, name: String| {
            obj(vec![
                ("name", Json::Str("process_name".into())),
                ("ph", Json::Str("M".into())),
                ("pid", Json::Int(pid as i64)),
                ("args", obj(vec![("name", Json::Str(name))])),
            ])
        };
        if fleet {
            events.push(proc_name(0, "coordinator".into()));
        }
        for &pid in &pids {
            let name =
                if fleet { format!("replica {}", pid.saturating_sub(1)) } else { "engine".into() };
            events.push(proc_name(pid, name));
        }

        for r in &self.routes {
            let complete = |name: &str, ts: u64, end: u64, args: Json| {
                obj(vec![
                    ("name", Json::Str(name.into())),
                    ("ph", Json::Str("X".into())),
                    ("ts", Json::Int(ts as i64)),
                    ("dur", Json::Int(end.saturating_sub(ts) as i64)),
                    ("pid", Json::Int(0)),
                    ("tid", Json::Int(r.rid as i64)),
                    ("cat", Json::Str(r.adapter.clone())),
                    ("args", args),
                ])
            };
            events.push(complete(
                "door_admission",
                r.arrival_us,
                r.admitted_us,
                obj(vec![
                    ("trace", Json::Int(r.trace as i64)),
                    ("adapter", Json::Str(r.adapter.clone())),
                ]),
            ));
            let candidates = arr(r
                .candidates
                .iter()
                .map(|c| {
                    obj(vec![
                        ("replica", Json::Int(c.replica as i64)),
                        ("inflight", Json::Int(c.inflight as i64)),
                        ("kv_free", Json::Int(c.kv_free as i64)),
                        ("expected_wait_us", Json::Int(c.expected_wait_us as i64)),
                        ("resident", Json::Bool(c.resident)),
                    ])
                })
                .collect());
            events.push(complete(
                "routing_decision",
                r.admitted_us,
                r.routed_us,
                obj(vec![
                    ("trace", Json::Int(r.trace as i64)),
                    ("policy", Json::Str(r.policy.into())),
                    ("replica", Json::Int(r.replica as i64)),
                    ("resident", Json::Bool(r.resident)),
                    ("candidates", candidates),
                ]),
            ));
        }
        for d in &self.doors {
            events.push(obj(vec![
                ("name", Json::Str(format!("shed:{}", d.code))),
                ("ph", Json::Str("i".into())),
                ("ts", Json::Int(d.at_us as i64)),
                ("s", Json::Str("t".into())),
                ("pid", Json::Int(0)),
                ("tid", Json::Int(0)),
                ("cat", Json::Str(d.adapter.clone())),
                (
                    "args",
                    obj(vec![
                        ("trace", Json::Int(d.trace as i64)),
                        ("code", Json::Str(d.code.into())),
                        ("adapter", Json::Str(d.adapter.clone())),
                    ]),
                ),
            ]));
        }
        for s in &self.spans {
            let complete = |name: &str, ts: u64, end: u64| {
                obj(vec![
                    ("name", Json::Str(name.into())),
                    ("ph", Json::Str("X".into())),
                    ("ts", Json::Int(ts as i64)),
                    ("dur", Json::Int(end.saturating_sub(ts) as i64)),
                    ("pid", Json::Int(s.pid as i64)),
                    ("tid", Json::Int(s.id as i64)),
                    ("cat", Json::Str(s.adapter.clone())),
                    (
                        "args",
                        obj(vec![
                            ("trace", Json::Int(s.trace as i64)),
                            ("adapter", Json::Str(s.adapter.clone())),
                            ("outcome", Json::Str(s.outcome.into())),
                        ]),
                    ),
                ])
            };
            // queued: arrival until the scheduler admitted the request
            let admitted = s.admitted_us.unwrap_or(s.finished_us);
            events.push(complete("queued", s.arrival_us, admitted));
            if let Some(t) = s.admitted_us {
                // admitted but not yet packed into a batch
                let sched = s.first_scheduled_us.unwrap_or(s.finished_us);
                events.push(complete("admitted", t, sched));
            }
            if let Some(t) = s.first_scheduled_us {
                let done = s.prefill_done_us.unwrap_or(s.finished_us);
                events.push(complete("prefill", t, done));
            }
            if let Some(t) = s.prefill_done_us {
                events.push(complete("decode", t, s.finished_us));
            }
            if let Some(t) = s.first_token_us {
                events.push(obj(vec![
                    ("name", Json::Str("first_token".into())),
                    ("ph", Json::Str("i".into())),
                    ("ts", Json::Int(t as i64)),
                    ("s", Json::Str("t".into())),
                    ("pid", Json::Int(s.pid as i64)),
                    ("tid", Json::Int(s.id as i64)),
                    ("cat", Json::Str(s.adapter.clone())),
                ]));
            }
        }
        obj(vec![
            ("traceEvents", arr(events)),
            ("displayTimeUnit", Json::Str("ms".into())),
        ])
    }

    /// Write the Chrome trace to `path` (the `--trace-out` target).
    pub fn write(&self, path: &std::path::Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent().filter(|d| !d.as_os_str().is_empty()) {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, format!("{}\n", self.to_chrome_json()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(id: u64, outcome: &'static str) -> RequestSpan {
        RequestSpan {
            id,
            trace: 0,
            pid: 1,
            adapter: "math".into(),
            outcome,
            arrival_us: 100,
            admitted_us: Some(150),
            first_scheduled_us: Some(200),
            prefill_done_us: Some(500),
            first_token_us: Some(520),
            finished_us: 900,
        }
    }

    #[test]
    fn chrome_trace_shape() {
        let mut log = TraceLog::new();
        log.record(span(1, "done"));
        log.record(RequestSpan {
            // aborted while queued: only the queued phase renders
            id: 2,
            trace: 0,
            pid: 1,
            adapter: "base".into(),
            outcome: "cancelled",
            arrival_us: 10,
            admitted_us: None,
            first_scheduled_us: None,
            prefill_done_us: None,
            first_token_us: None,
            finished_us: 40,
        });
        let doc = log.to_chrome_json();
        // round-trips through the parser (valid JSON)
        let doc = Json::parse(&doc.to_string()).unwrap();
        let events = doc.at(&["traceEvents"]).as_arr().unwrap();
        // process_name metadata + request 1 (queued, admitted, prefill,
        // decode + first_token) + request 2 (queued only)
        assert_eq!(events.len(), 7);
        let of = |id: i64, name: &str| {
            events
                .iter()
                .find(|e| {
                    e.at(&["tid"]).as_i64() == Some(id)
                        && e.at(&["name"]).as_str() == Some(name)
                })
                .cloned()
        };
        let decode = of(1, "decode").unwrap();
        assert_eq!(decode.at(&["ts"]).as_i64(), Some(500));
        assert_eq!(decode.at(&["dur"]).as_i64(), Some(400));
        assert_eq!(decode.at(&["cat"]).as_str(), Some("math"));
        assert_eq!(decode.at(&["args", "outcome"]).as_str(), Some("done"));
        let queued2 = of(2, "queued").unwrap();
        assert_eq!(queued2.at(&["dur"]).as_i64(), Some(30));
        assert_eq!(queued2.at(&["args", "outcome"]).as_str(), Some("cancelled"));
        assert!(of(2, "prefill").is_none(), "missing stamps truncate the timeline");
        // a non-fleet log labels its single process "engine"
        let meta = events
            .iter()
            .find(|e| e.at(&["name"]).as_str() == Some("process_name"))
            .unwrap();
        assert_eq!(meta.at(&["args", "name"]).as_str(), Some("engine"));
        // phases on one track tile without overlap
        let seq: Vec<(i64, i64)> = ["queued", "admitted", "prefill", "decode"]
            .iter()
            .map(|n| {
                let e = of(1, n).unwrap();
                (e.at(&["ts"]).as_i64().unwrap(), e.at(&["dur"]).as_i64().unwrap())
            })
            .collect();
        for w in seq.windows(2) {
            assert_eq!(w[0].0 + w[0].1, w[1].0, "phase end == next phase start");
        }
    }

    #[test]
    fn write_creates_parseable_file() {
        let mut log = TraceLog::new();
        log.record(span(7, "done"));
        let dir = std::env::temp_dir().join(format!("ew_trace_{}", std::process::id()));
        let path = dir.join("trace.json");
        log.write(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(Json::parse(text.trim()).is_ok());
        std::fs::remove_dir_all(&dir).ok();
    }

    /// The satellite-1 regression: with the origin anchored at engine
    /// construction, stamps taken *before* `enable_trace` keep distinct
    /// positive offsets instead of all collapsing to 0.
    #[test]
    fn pre_enable_stamps_keep_distinct_offsets() {
        let constructed = Instant::now();
        let t1 = constructed + std::time::Duration::from_micros(1_000);
        let t2 = constructed + std::time::Duration::from_micros(2_500);
        // tracing enabled long after both stamps were taken
        let log = TraceLog::with_origin(constructed);
        assert_eq!(log.rel_us(t1), 1_000);
        assert_eq!(log.rel_us(t2), 2_500);
        assert_ne!(log.rel_us(t1), log.rel_us(t2), "offsets must not collapse");
        // the old behaviour (origin = enable time) collapsed both to 0
        let late = TraceLog::with_origin(t2 + std::time::Duration::from_secs(1));
        assert_eq!(late.rel_us(t1), 0);
        assert_eq!(late.rel_us(t2), 0);
    }

    #[test]
    fn absorb_rebases_rekeys_and_sets_pid() {
        let base = Instant::now();
        let mut fleet = TraceLog::with_origin(base);
        fleet.record_route(RouteSpan {
            rid: 42,
            trace: 7,
            adapter: "math".into(),
            policy: "adapter-affinity",
            replica: 1,
            resident: true,
            candidates: vec![Candidate {
                replica: 1,
                inflight: 0,
                kv_free: 100,
                expected_wait_us: 0,
                resident: true,
            }],
            arrival_us: 10,
            admitted_us: 12,
            routed_us: 20,
        });
        // replica log whose origin is 1 ms after the fleet origin; its
        // local request 3 carries trace id 7
        let mut replica = TraceLog::with_origin(base + std::time::Duration::from_millis(1));
        let mut s = span(3, "done");
        s.trace = 7;
        replica.record(s);
        let rekey: HashMap<u64, u64> = [(7u64, 42u64)].into_iter().collect();
        fleet.absorb(replica, 2, &rekey);
        let merged = &fleet.spans()[0];
        assert_eq!(merged.id, 42, "replica-local id re-keyed to the fleet rid");
        assert_eq!(merged.pid, 2, "pid = replica + 1");
        assert_eq!(merged.arrival_us, 1_100, "rebased onto the fleet origin");
        assert_eq!(merged.finished_us, 1_900);
        // rendering: coordinator + replica tracks in one document
        let doc = Json::parse(&fleet.to_chrome_json().to_string()).unwrap();
        let events = doc.at(&["traceEvents"]).as_arr().unwrap();
        let routing = events
            .iter()
            .find(|e| e.at(&["name"]).as_str() == Some("routing_decision"))
            .unwrap();
        assert_eq!(routing.at(&["pid"]).as_i64(), Some(0));
        assert_eq!(routing.at(&["tid"]).as_i64(), Some(42));
        assert_eq!(routing.at(&["args", "replica"]).as_i64(), Some(1));
        assert_eq!(routing.at(&["args", "trace"]).as_i64(), Some(7));
        let decode = events
            .iter()
            .find(|e| e.at(&["name"]).as_str() == Some("decode"))
            .unwrap();
        assert_eq!(decode.at(&["pid"]).as_i64(), Some(2));
        assert_eq!(decode.at(&["tid"]).as_i64(), Some(42));
        assert_eq!(decode.at(&["args", "trace"]).as_i64(), Some(7));
        // process labels for Perfetto
        let names: Vec<&str> = events
            .iter()
            .filter(|e| e.at(&["name"]).as_str() == Some("process_name"))
            .filter_map(|e| e.at(&["args", "name"]).as_str())
            .collect();
        assert!(names.contains(&"coordinator"));
        assert!(names.contains(&"replica 1"));
    }

    /// An absorb in the other time direction: a replica constructed
    /// *before* the fleet origin shifts backwards, saturating at 0.
    #[test]
    fn absorb_shifts_earlier_origins_back() {
        let base = Instant::now();
        let mut fleet = TraceLog::with_origin(base + std::time::Duration::from_millis(2));
        let mut replica = TraceLog::with_origin(base);
        replica.record(span(1, "done")); // arrival_us = 100
        fleet.absorb(replica, 1, &HashMap::new());
        let merged = &fleet.spans()[0];
        assert_eq!(merged.arrival_us, 0, "pre-origin stamps clamp to 0");
        assert_eq!(merged.finished_us, 0); // 900 µs < 2 ms shift
    }
}
