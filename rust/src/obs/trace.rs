//! Per-request phase tracing, exportable as Chrome-trace JSON
//! (`chrome://tracing`, Perfetto).
//!
//! The engine stamps request phases in the scheduler's per-slot state
//! (queued → admitted → first-scheduled → prefill-done → decode →
//! done/aborted; see [`crate::scheduler::SeqState`]) and, when tracing is
//! enabled, folds each finished request into a [`RequestSpan`] here. The
//! span timeline renders as one track per request (`tid` = request id,
//! `cat` = adapter), so adapter interference and queueing delay are
//! visible at a glance.
//!
//! Tracing is opt-in (`--trace-out`) and entirely off the steady-state
//! path: spans are recorded only at request completion/abort, never per
//! step.

use crate::util::json::{arr, obj, Json};
use std::time::Instant;

/// One request's phase timeline, in microseconds relative to the trace
/// origin. Missing stamps (e.g. a request aborted while queued) truncate
/// the timeline at the last phase reached.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RequestSpan {
    pub id: u64,
    /// Adapter name, or `"base"`.
    pub adapter: String,
    /// `"done"`, `"cancelled"` or `"deadline"`.
    pub outcome: &'static str,
    pub arrival_us: u64,
    pub admitted_us: Option<u64>,
    pub first_scheduled_us: Option<u64>,
    pub prefill_done_us: Option<u64>,
    pub first_token_us: Option<u64>,
    pub finished_us: u64,
}

/// Accumulates [`RequestSpan`]s against a fixed time origin and writes
/// them out in the Chrome trace-event format.
#[derive(Debug)]
pub struct TraceLog {
    origin: Instant,
    spans: Vec<RequestSpan>,
}

impl Default for TraceLog {
    fn default() -> Self {
        TraceLog { origin: Instant::now(), spans: Vec::new() }
    }
}

impl TraceLog {
    pub fn new() -> Self {
        Self::default()
    }

    /// Microseconds since the trace origin (saturating at 0 for stamps
    /// that predate it, e.g. requests queued before tracing started).
    pub fn rel_us(&self, t: Instant) -> u64 {
        t.saturating_duration_since(self.origin).as_micros() as u64
    }

    pub fn record(&mut self, span: RequestSpan) {
        self.spans.push(span);
    }

    pub fn len(&self) -> usize {
        self.spans.len()
    }

    pub fn is_empty(&self) -> bool {
        self.spans.is_empty()
    }

    pub fn spans(&self) -> &[RequestSpan] {
        &self.spans
    }

    /// The `{"traceEvents": [...]}` document. Phases become `ph:"X"`
    /// complete events on track `tid` = request id; the first token is an
    /// instant event on the same track.
    pub fn to_chrome_json(&self) -> Json {
        let mut events = Vec::new();
        for s in &self.spans {
            let complete = |name: &str, ts: u64, end: u64| {
                obj(vec![
                    ("name", Json::Str(name.into())),
                    ("ph", Json::Str("X".into())),
                    ("ts", Json::Int(ts as i64)),
                    ("dur", Json::Int(end.saturating_sub(ts) as i64)),
                    ("pid", Json::Int(1)),
                    ("tid", Json::Int(s.id as i64)),
                    ("cat", Json::Str(s.adapter.clone())),
                    (
                        "args",
                        obj(vec![
                            ("adapter", Json::Str(s.adapter.clone())),
                            ("outcome", Json::Str(s.outcome.into())),
                        ]),
                    ),
                ])
            };
            // queued: arrival until the scheduler admitted the request
            let admitted = s.admitted_us.unwrap_or(s.finished_us);
            events.push(complete("queued", s.arrival_us, admitted));
            if let Some(t) = s.admitted_us {
                // admitted but not yet packed into a batch
                let sched = s.first_scheduled_us.unwrap_or(s.finished_us);
                events.push(complete("admitted", t, sched));
            }
            if let Some(t) = s.first_scheduled_us {
                let done = s.prefill_done_us.unwrap_or(s.finished_us);
                events.push(complete("prefill", t, done));
            }
            if let Some(t) = s.prefill_done_us {
                events.push(complete("decode", t, s.finished_us));
            }
            if let Some(t) = s.first_token_us {
                events.push(obj(vec![
                    ("name", Json::Str("first_token".into())),
                    ("ph", Json::Str("i".into())),
                    ("ts", Json::Int(t as i64)),
                    ("s", Json::Str("t".into())),
                    ("pid", Json::Int(1)),
                    ("tid", Json::Int(s.id as i64)),
                    ("cat", Json::Str(s.adapter.clone())),
                ]));
            }
        }
        obj(vec![
            ("traceEvents", arr(events)),
            ("displayTimeUnit", Json::Str("ms".into())),
        ])
    }

    /// Write the Chrome trace to `path` (the `--trace-out` target).
    pub fn write(&self, path: &std::path::Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent().filter(|d| !d.as_os_str().is_empty()) {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, format!("{}\n", self.to_chrome_json()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(id: u64, outcome: &'static str) -> RequestSpan {
        RequestSpan {
            id,
            adapter: "math".into(),
            outcome,
            arrival_us: 100,
            admitted_us: Some(150),
            first_scheduled_us: Some(200),
            prefill_done_us: Some(500),
            first_token_us: Some(520),
            finished_us: 900,
        }
    }

    #[test]
    fn chrome_trace_shape() {
        let mut log = TraceLog::new();
        log.record(span(1, "done"));
        log.record(RequestSpan {
            // aborted while queued: only the queued phase renders
            id: 2,
            adapter: "base".into(),
            outcome: "cancelled",
            arrival_us: 10,
            admitted_us: None,
            first_scheduled_us: None,
            prefill_done_us: None,
            first_token_us: None,
            finished_us: 40,
        });
        let doc = log.to_chrome_json();
        // round-trips through the parser (valid JSON)
        let doc = Json::parse(&doc.to_string()).unwrap();
        let events = doc.at(&["traceEvents"]).as_arr().unwrap();
        // request 1: queued, admitted, prefill, decode + first_token
        // request 2: queued only
        assert_eq!(events.len(), 6);
        let of = |id: i64, name: &str| {
            events
                .iter()
                .find(|e| {
                    e.at(&["tid"]).as_i64() == Some(id)
                        && e.at(&["name"]).as_str() == Some(name)
                })
                .cloned()
        };
        let decode = of(1, "decode").unwrap();
        assert_eq!(decode.at(&["ts"]).as_i64(), Some(500));
        assert_eq!(decode.at(&["dur"]).as_i64(), Some(400));
        assert_eq!(decode.at(&["cat"]).as_str(), Some("math"));
        assert_eq!(decode.at(&["args", "outcome"]).as_str(), Some("done"));
        let queued2 = of(2, "queued").unwrap();
        assert_eq!(queued2.at(&["dur"]).as_i64(), Some(30));
        assert_eq!(queued2.at(&["args", "outcome"]).as_str(), Some("cancelled"));
        assert!(of(2, "prefill").is_none(), "missing stamps truncate the timeline");
        // phases on one track tile without overlap
        let seq: Vec<(i64, i64)> = ["queued", "admitted", "prefill", "decode"]
            .iter()
            .map(|n| {
                let e = of(1, n).unwrap();
                (e.at(&["ts"]).as_i64().unwrap(), e.at(&["dur"]).as_i64().unwrap())
            })
            .collect();
        for w in seq.windows(2) {
            assert_eq!(w[0].0 + w[0].1, w[1].0, "phase end == next phase start");
        }
    }

    #[test]
    fn write_creates_parseable_file() {
        let mut log = TraceLog::new();
        log.record(span(7, "done"));
        let dir = std::env::temp_dir().join(format!("ew_trace_{}", std::process::id()));
        let path = dir.join("trace.json");
        log.write(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(Json::parse(text.trim()).is_ok());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rel_us_saturates_before_origin() {
        let log = TraceLog::new();
        let before = Instant::now().checked_sub(std::time::Duration::from_secs(1));
        if let Some(t) = before {
            assert_eq!(log.rel_us(t), 0);
        }
        assert!(log.is_empty());
    }
}
