//! Artifact discovery: parse `artifacts/<config>/meta.json` into typed
//! metadata (the artifact ABI between `python/compile/aot.py` and the
//! runtime).

use crate::model::ModelConfig;
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// Which step-function flavour an executable implements.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Variant {
    /// No rerouting inputs; `G = M` (base-only and merged deployments).
    Base,
    /// Fused Pallas batched-rerouting kernel (ExpertWeave).
    Weave,
    /// Unfused rerouting ops (ExpertWeave-SingleOp baseline, Fig. 7).
    SingleOp,
}

impl Variant {
    pub fn parse(s: &str) -> Result<Variant> {
        Ok(match s {
            "base" => Variant::Base,
            "weave" => Variant::Weave,
            "singleop" => Variant::SingleOp,
            other => bail!("unknown variant {other:?}"),
        })
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Variant::Base => "base",
            Variant::Weave => "weave",
            Variant::SingleOp => "singleop",
        }
    }

    /// Does this variant take `aid` + `expert_maps` inputs?
    pub fn is_adapter_aware(&self) -> bool {
        !matches!(self, Variant::Base)
    }
}

/// Shape+dtype of one named input tensor.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    /// `"f32"` or `"i32"`.
    pub dtype: String,
}

impl TensorSpec {
    fn from_json(j: &Json) -> Result<TensorSpec> {
        Ok(TensorSpec {
            name: j.get("name").and_then(Json::as_str).context("spec.name")?.to_string(),
            shape: j.get("shape").and_then(Json::as_usize_vec).context("spec.shape")?,
            dtype: j.get("dtype").and_then(Json::as_str).context("spec.dtype")?.to_string(),
        })
    }

    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Metadata of one compiled step executable.
#[derive(Debug, Clone)]
pub struct ExecutableMeta {
    pub file: PathBuf,
    pub variant: Variant,
    /// Token bucket T.
    pub bucket: usize,
    /// O — logits rows returned.
    pub out_rows: usize,
    pub gmm_block: usize,
    /// Ordered weight tensors (first inputs of the program).
    pub params: Vec<TensorSpec>,
    /// Ordered non-param inputs (kv_cache first).
    pub inputs: Vec<TensorSpec>,
    /// Input index of the donated kv_cache (= params.len()).
    pub donate_input_index: usize,
}

/// All executables + config of one artifact directory.
#[derive(Debug, Clone)]
pub struct ArtifactSet {
    pub dir: PathBuf,
    pub config: ModelConfig,
    pub executables: Vec<ExecutableMeta>,
}

impl ArtifactSet {
    pub fn load(dir: &Path) -> Result<ArtifactSet> {
        let meta_path = dir.join("meta.json");
        let text = std::fs::read_to_string(&meta_path)
            .with_context(|| format!("read {} (run `make artifacts`)", meta_path.display()))?;
        let j = Json::parse(&text).context("parse meta.json")?;
        let config = ModelConfig::from_json(j.at(&["config"])).context("meta.config")?;
        let mut executables = Vec::new();
        for e in j.at(&["executables"]).as_arr().context("meta.executables")? {
            let params = e
                .at(&["params"])
                .as_arr()
                .context("exe.params")?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            let inputs = e
                .at(&["inputs"])
                .as_arr()
                .context("exe.inputs")?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            executables.push(ExecutableMeta {
                file: dir.join(e.get("file").and_then(Json::as_str).context("exe.file")?),
                variant: Variant::parse(
                    e.get("variant").and_then(Json::as_str).context("exe.variant")?,
                )?,
                bucket: e.get("bucket").and_then(Json::as_usize).context("exe.bucket")?,
                out_rows: e.get("out_rows").and_then(Json::as_usize).context("exe.out_rows")?,
                gmm_block: e.get("gmm_block").and_then(Json::as_usize).unwrap_or(0),
                donate_input_index: e
                    .get("donate_input_index")
                    .and_then(Json::as_usize)
                    .context("exe.donate_input_index")?,
                params,
                inputs,
            });
        }
        if executables.is_empty() {
            bail!("no executables in {}", meta_path.display());
        }
        Ok(ArtifactSet { dir: dir.to_path_buf(), config, executables })
    }

    /// Executables of one variant, ascending by bucket.
    pub fn variant(&self, v: Variant) -> Vec<&ExecutableMeta> {
        let mut out: Vec<&ExecutableMeta> =
            self.executables.iter().filter(|e| e.variant == v).collect();
        out.sort_by_key(|e| e.bucket);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
        d.join("meta.json").exists().then_some(d)
    }

    #[test]
    fn loads_tiny_meta() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts/tiny missing (run `make artifacts`)");
            return;
        };
        let set = ArtifactSet::load(&dir).unwrap();
        assert_eq!(set.config.name, "tiny");
        // 3 variants x 2 buckets
        assert_eq!(set.executables.len(), 6);
        let weave = set.variant(Variant::Weave);
        assert_eq!(weave.len(), 2);
        assert!(weave[0].bucket < weave[1].bucket);
        let e = weave[0];
        assert_eq!(e.donate_input_index, e.params.len());
        assert_eq!(e.inputs[0].name, "kv_cache");
        assert_eq!(e.inputs.last().unwrap().name, "expert_maps");
        assert!(e.file.exists());
        // base variant has no rerouting inputs
        let base = set.variant(Variant::Base)[0];
        assert!(base.inputs.iter().all(|i| i.name != "aid"));
        // expert tensor sizing differs between variants
        let g_w = weave[0].params.iter().find(|p| p.name == "layer0.w_gate").unwrap();
        let g_b = base.params.iter().find(|p| p.name == "layer0.w_gate").unwrap();
        assert_eq!(g_w.shape[0], set.config.total_expert_slots());
        assert_eq!(g_b.shape[0], set.config.num_experts);
    }

    #[test]
    fn variant_parse() {
        assert_eq!(Variant::parse("weave").unwrap(), Variant::Weave);
        assert!(Variant::parse("nope").is_err());
        assert!(Variant::Weave.is_adapter_aware());
        assert!(!Variant::Base.is_adapter_aware());
    }
}
