//! PJRT execution core: compiled step executables with device-resident
//! weights and a chained KV cache.
//!
//! Buffer lifecycle per step:
//! * weight buffers — uploaded once per weights *version* (adapter
//!   load/evict), reused by `execute_b` every step;
//! * KV cache — output of step *n* feeds step *n+1*. The `xla` crate's
//!   PJRT wrapper returns outputs as one tuple buffer, so the tuple is
//!   fetched to host and the KV part re-uploaded (~2x kv bytes of PCIe-
//!   equivalent traffic per step; bounded and measured in EXPERIMENTS.md
//!   §Perf — the in-graph donation alias still avoids a third copy);
//! * batch tensors (token ids, slots, AID, ...) — tiny, uploaded per step.

use super::artifacts::{ArtifactSet, ExecutableMeta, TensorSpec, Variant};
use crate::model::ModelConfig;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// Source of weight tensors by ABI name.
///
/// `expert_tensor` must serve the stacked `[G|M, ..]` projections
/// (`layerN.w_gate|w_up|w_down`); everything else comes from `named`.
pub trait ParamSource {
    fn named(&self, name: &str) -> Option<&[f32]>;
    /// Stacked expert tensor for (layer, proj) sized per `spec`.
    fn expert_tensor(&mut self, layer: usize, proj: usize, len: usize) -> Result<&[f32]>;
}

/// One packed step batch (already bucket-padded by the scheduler).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StepInputs {
    pub token_ids: Vec<i32>,
    pub positions: Vec<i32>,
    pub seg_ids: Vec<i32>,
    pub slot_idx: Vec<i32>,
    pub cache_seg: Vec<i32>,
    pub cache_pos: Vec<i32>,
    pub out_rows: Vec<i32>,
    /// Adapter ID per token (-1 = base); ignored by `base` executables.
    pub aid: Vec<i32>,
}

impl StepInputs {
    /// An all-padding batch for bucket `t` (useful in tests/benches).
    pub fn blank(cfg: &ModelConfig, bucket: usize, out_rows: usize) -> StepInputs {
        StepInputs {
            token_ids: vec![0; bucket],
            positions: vec![0; bucket],
            seg_ids: vec![-1; bucket],
            slot_idx: vec![cfg.kv_cap as i32; bucket],
            cache_seg: vec![-1; cfg.kv_cap],
            cache_pos: vec![0; cfg.kv_cap],
            out_rows: vec![0; out_rows],
            aid: vec![-1; bucket],
        }
    }
}

/// What a backend produced for the sampled rows of one step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepYield {
    /// `StepOutput::logits` holds `filled_rows * vocab` row-major f32.
    Logits,
    /// `StepOutput::tokens[r]` is row `r`'s greedy token (sim fast path;
    /// no logits were materialized).
    GreedyTokens,
}

/// Reusable output buffer of one step. The engine owns one instance and
/// every backend refills it in place (`step_into`), so the steady-state
/// loop never allocates a fresh logits tensor.
#[derive(Debug)]
pub struct StepOutput {
    pub kind: StepYield,
    /// `[filled_rows, vocab]` row-major logits (`kind == Logits`).
    pub logits: Vec<f32>,
    /// Greedy token per row (`kind == GreedyTokens`).
    pub tokens: Vec<i32>,
    /// Rows actually filled. PJRT always fills the full ABI `out_rows`;
    /// the sim backend fills only the batch's live rows.
    pub filled_rows: usize,
    /// Wall time inside the backend execute (the XLA part of the step).
    pub execute_time: std::time::Duration,
}

impl StepOutput {
    pub fn new() -> StepOutput {
        StepOutput {
            kind: StepYield::Logits,
            logits: Vec::new(),
            tokens: Vec::new(),
            filled_rows: 0,
            execute_time: std::time::Duration::ZERO,
        }
    }

    /// Row `row`'s logits slice (`kind == Logits`, `row < filled_rows`).
    pub fn row_logits(&self, row: usize, vocab: usize) -> &[f32] {
        &self.logits[row * vocab..(row + 1) * vocab]
    }
}

impl Default for StepOutput {
    fn default() -> Self {
        Self::new()
    }
}

struct CompiledStep {
    meta: ExecutableMeta,
    exe: xla::PjRtLoadedExecutable,
}

/// The PJRT runtime for one model variant on one (simulated) device.
pub struct Runtime {
    client: xla::PjRtClient,
    cfg: ModelConfig,
    variant: Variant,
    steps: BTreeMap<usize, CompiledStep>,
    /// Compiled token buckets, ascending (cached so [`Runtime::buckets`]
    /// returns a slice instead of re-collecting per call).
    bucket_list: Vec<usize>,
    /// Device buffers for `params`, ordered per the ABI manifest.
    param_bufs: Vec<xla::PjRtBuffer>,
    /// Host KV cache image between steps (see module docs).
    kv_literal: Option<xla::Literal>,
    /// Cached device buffer of the expert maps (re-built on version bump).
    expert_maps_buf: Option<xla::PjRtBuffer>,
    maps_version: u64,
    weights_version: u64,
    scratch: Vec<f32>,
}

fn parse_layer_proj(name: &str) -> Option<(usize, usize)> {
    let rest = name.strip_prefix("layer")?;
    let (l, field) = rest.split_once('.')?;
    let proj = match field {
        "w_gate" => 0,
        "w_up" => 1,
        "w_down" => 2,
        _ => return None,
    };
    Some((l.parse().ok()?, proj))
}

impl Runtime {
    /// Compile all buckets of `variant` from `set`.
    pub fn new(set: &ArtifactSet, variant: Variant) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        let mut steps = BTreeMap::new();
        for meta in set.variant(variant) {
            let t0 = std::time::Instant::now();
            let proto = xla::HloModuleProto::from_text_file(
                meta.file.to_str().context("artifact path utf8")?,
            )
            .with_context(|| format!("parse {}", meta.file.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compile {}", meta.file.display()))?;
            crate::log_info!(
                "runtime",
                "compiled {} in {:.2}s",
                meta.file.file_name().unwrap().to_string_lossy(),
                t0.elapsed().as_secs_f64()
            );
            steps.insert(meta.bucket, CompiledStep { meta: meta.clone(), exe });
        }
        if steps.is_empty() {
            bail!("no {} executables in {}", variant.as_str(), set.dir.display());
        }
        let bucket_list: Vec<usize> = steps.keys().copied().collect();
        Ok(Runtime {
            client,
            cfg: set.config.clone(),
            variant,
            steps,
            bucket_list,
            param_bufs: Vec::new(),
            kv_literal: None,
            expert_maps_buf: None,
            maps_version: 0,
            weights_version: 0,
            scratch: Vec::new(),
        })
    }

    pub fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    pub fn variant(&self) -> Variant {
        self.variant
    }

    /// Available token buckets, ascending.
    pub fn buckets(&self) -> &[usize] {
        &self.bucket_list
    }

    /// Smallest bucket that fits `tokens`.
    pub fn bucket_for(&self, tokens: usize) -> Option<usize> {
        self.steps.keys().copied().find(|&b| b >= tokens)
    }

    pub fn out_rows(&self, bucket: usize) -> Option<usize> {
        self.steps.get(&bucket).map(|s| s.meta.out_rows)
    }

    fn manifest(&self) -> &ExecutableMeta {
        &self.steps.values().next().unwrap().meta
    }

    /// Upload all weight tensors from `source`. Call at startup and after
    /// every adapter load/evict (`version` guards redundant uploads).
    pub fn upload_params<S: ParamSource>(&mut self, source: &mut S, version: u64) -> Result<()> {
        if version == self.weights_version && !self.param_bufs.is_empty() {
            return Ok(());
        }
        let manifest: Vec<TensorSpec> = self.manifest().params.clone();
        let mut bufs = Vec::with_capacity(manifest.len());
        for spec in &manifest {
            let data: &[f32] = if let Some((layer, proj)) = parse_layer_proj(&spec.name) {
                source.expert_tensor(layer, proj, spec.element_count())?
            } else {
                source
                    .named(&spec.name)
                    .with_context(|| format!("missing param {}", spec.name))?
            };
            if data.len() != spec.element_count() {
                bail!(
                    "param {}: {} elements, manifest wants {:?}",
                    spec.name,
                    data.len(),
                    spec.shape
                );
            }
            bufs.push(
                self.client
                    .buffer_from_host_buffer(data, &spec.shape, None)
                    .with_context(|| format!("upload {}", spec.name))?,
            );
        }
        self.param_bufs = bufs;
        self.weights_version = version;
        Ok(())
    }

    /// Upload the flattened `[L, N+1, M]` expert maps (adapter-aware
    /// variants only).
    pub fn upload_expert_maps(&mut self, maps: &[i32], version: u64) -> Result<()> {
        if !self.variant.is_adapter_aware() {
            return Ok(());
        }
        if version == self.maps_version && self.expert_maps_buf.is_some() {
            return Ok(());
        }
        let dims = [
            self.cfg.layers,
            self.cfg.max_adapters + 1,
            self.cfg.num_experts,
        ];
        if maps.len() != dims.iter().product::<usize>() {
            bail!("expert maps length {} != {:?}", maps.len(), dims);
        }
        self.expert_maps_buf = Some(self.client.buffer_from_host_buffer(maps, &dims, None)?);
        self.maps_version = version;
        Ok(())
    }

    /// Reset the KV cache to zeros (new serving session).
    pub fn reset_kv(&mut self) {
        self.kv_literal = None;
    }

    fn kv_dims(&self) -> [usize; 5] {
        [
            self.cfg.layers,
            2,
            self.cfg.kv_cap,
            self.cfg.kv_heads,
            self.cfg.head_dim,
        ]
    }

    /// Execute one step, returning a freshly allocated output (tests and
    /// one-shot callers; the engine hot path uses [`Runtime::step_into`]).
    pub fn step(&mut self, bucket: usize, inputs: &StepInputs) -> Result<StepOutput> {
        let mut out = StepOutput::new();
        let rows = self.out_rows(bucket).unwrap_or(0);
        self.step_into(bucket, inputs, rows, false, &mut out)?;
        Ok(out)
    }

    /// Execute one step into the caller-owned `out` buffer.
    ///
    /// `live_rows` / `want_tokens` are hot-path hints the compiled
    /// executables cannot exploit (the device always computes the full
    /// `[out_rows, vocab]` block and the fused rerouting runs in-graph),
    /// so this backend ignores them and always yields
    /// [`StepYield::Logits`] for every ABI row. The signature matches the
    /// sim backend so the engine drives both identically.
    pub fn step_into(
        &mut self,
        bucket: usize,
        inputs: &StepInputs,
        _live_rows: usize,
        _want_tokens: bool,
        out: &mut StepOutput,
    ) -> Result<()> {
        let Some(step) = self.steps.get(&bucket) else {
            bail!("no executable for bucket {bucket}");
        };
        let meta = &step.meta;
        if self.param_bufs.is_empty() {
            bail!("params not uploaded");
        }
        let t = meta.bucket;
        for (name, v, want) in [
            ("token_ids", inputs.token_ids.len(), t),
            ("positions", inputs.positions.len(), t),
            ("seg_ids", inputs.seg_ids.len(), t),
            ("slot_idx", inputs.slot_idx.len(), t),
            ("cache_seg", inputs.cache_seg.len(), self.cfg.kv_cap),
            ("cache_pos", inputs.cache_pos.len(), self.cfg.kv_cap),
            ("out_rows", inputs.out_rows.len(), meta.out_rows),
            ("aid", inputs.aid.len(), t),
        ] {
            if v != want {
                bail!("step input {name}: {v} elements, bucket wants {want}");
            }
        }

        // kv cache buffer: from last step's literal, or zeros
        let kv_dims = self.kv_dims();
        let kv_buf = match &self.kv_literal {
            Some(lit) => self.client.buffer_from_host_literal(None, lit)?,
            None => {
                let n: usize = kv_dims.iter().product();
                self.scratch.clear();
                self.scratch.resize(n, 0.0);
                self.client
                    .buffer_from_host_buffer(&self.scratch, &kv_dims, None)?
            }
        };

        let up_i32 = |data: &[i32], dims: &[usize]| -> Result<xla::PjRtBuffer> {
            Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
        };
        let mut batch_bufs: Vec<xla::PjRtBuffer> = vec![
            kv_buf,
            up_i32(&inputs.token_ids, &[t])?,
            up_i32(&inputs.positions, &[t])?,
            up_i32(&inputs.seg_ids, &[t])?,
            up_i32(&inputs.slot_idx, &[t])?,
            up_i32(&inputs.cache_seg, &[self.cfg.kv_cap])?,
            up_i32(&inputs.cache_pos, &[self.cfg.kv_cap])?,
            up_i32(&inputs.out_rows, &[meta.out_rows])?,
        ];
        if self.variant.is_adapter_aware() {
            batch_bufs.push(up_i32(&inputs.aid, &[t])?);
        }

        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(
            self.param_bufs.len() + batch_bufs.len() + 1,
        );
        args.extend(self.param_bufs.iter());
        args.extend(batch_bufs.iter());
        if self.variant.is_adapter_aware() {
            args.push(
                self.expert_maps_buf
                    .as_ref()
                    .context("expert maps not uploaded")?,
            );
        }

        let t0 = std::time::Instant::now();
        let result = step.exe.execute_b(&args).context("PJRT execute")?;
        let tuple = result[0][0].to_literal_sync()?;
        let execute_time = t0.elapsed();

        let (logits_lit, kv_lit) = tuple.to_tuple2().context("untuple step outputs")?;
        let logits = logits_lit.to_vec::<f32>()?;
        debug_assert_eq!(logits.len(), meta.out_rows * self.cfg.vocab);
        self.kv_literal = Some(kv_lit);
        out.kind = StepYield::Logits;
        // move the readback buffer in rather than memcpy it (to_vec
        // already allocated; see ROADMAP for the borrowed-literal plan)
        out.logits = logits;
        out.tokens.clear();
        out.filled_rows = meta.out_rows;
        out.execute_time = execute_time;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    // Runtime tests live in rust/tests/runtime_integration.rs — they need
    // the tiny artifacts on disk and a PJRT client (one per process).
}
