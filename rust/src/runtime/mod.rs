//! PJRT runtime: load AOT artifacts (HLO text + `meta.json`), compile them
//! on the CPU PJRT client, and execute model steps with device-resident
//! weights.
//!
//! This is the only module that touches the `xla` crate. Everything above
//! it (scheduler, engine, server) sees plain Rust types.

pub mod artifacts;
pub mod engine;

pub use artifacts::{ArtifactSet, ExecutableMeta, TensorSpec, Variant};
pub use engine::{ParamSource, Runtime, StepInputs, StepOutput};
