//! PJRT runtime: load AOT artifacts (HLO text + `meta.json`), compile them
//! on the CPU PJRT client, and execute model steps with device-resident
//! weights.
//!
//! This is the only module that touches the `xla` crate. Everything above
//! it (scheduler, engine, server) sees plain Rust types.
//!
//! [`sim`] provides a drop-in simulated backend with the same step
//! contract for artifact-free environments (serving/fleet experiments).

pub mod artifacts;
pub mod engine;
pub mod sim;

pub use artifacts::{ArtifactSet, ExecutableMeta, TensorSpec, Variant};
pub use engine::{ParamSource, Runtime, StepInputs, StepOutput, StepYield};
pub use sim::{SimPerf, SimRuntime};
