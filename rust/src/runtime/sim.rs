//! Simulated execution backend: the PJRT runtime's step contract without
//! PJRT.
//!
//! [`SimRuntime`] accepts the exact same [`StepInputs`] the scheduler
//! packs for the compiled executables, burns a calibrated amount of wall
//! time per step (so real-time trace replay, queueing and
//! `compute_share` partitioning behave like they do against the real
//! runtime), and produces deterministic pseudo-outputs — a pure function
//! of the sampled row's `(token, position, AID, rerouted experts)` and
//! the engine seed, so greedy decoding is reproducible across runs and
//! replicas.
//!
//! ## Hot path
//!
//! The step is written for the zero-allocation steady state:
//!
//! * `step_into` refills a caller-owned [`StepOutput`] — no fresh logits
//!   tensor per step.
//! * When the engine signals that every live row samples greedily
//!   (`want_tokens`), the backend yields [`StepYield::GreedyTokens`]:
//!   one token per live row read directly off the row hash, O(1) per
//!   row, instead of materializing an `out_rows × vocab` logits block.
//!   The full-logits path stays available behind
//!   [`SimRuntime::set_full_logits`] for accuracy-style experiments that
//!   want the whole tensor. The two paths agree exactly: the
//!   pseudo-logits row is constructed with its argmax pinned to the
//!   fast-path token, so a greedy stream never changes when the engine
//!   switches modes (e.g. when a temperature-sampled request joins the
//!   batch mid-generation).
//! * Adapter-aware variants run the host analogue of the paper's fused
//!   batched-rerouting kernel each step:
//!   [`ExpertMaps::reroute_batch`] rewrites the batch's (simulated)
//!   top-k expert ids in one pass per layer into persistent buffers, and
//!   the rerouted ids are folded into the row hash — so outputs react to
//!   expert-map changes (load/evict) exactly like the real kernel's
//!   would, at O(live_rows · K) per layer with no allocation.
//!
//! What it is for: serving-layer experiments — the scheduler, engine,
//! server and the fleet [`crate::coordinator`] — in environments without
//! AOT artifacts or an `xla_extension` build (CI, the offline testbed).
//! What it is *not*: a model. Outputs carry no semantics beyond
//! determinism, so accuracy experiments (Table 3) still require the PJRT
//! backend.

use super::engine::{ParamSource, StepInputs, StepOutput, StepYield};
use crate::adapters::expert_map::ExpertMaps;
use crate::model::ModelConfig;
use crate::runtime::Variant;
use anyhow::{bail, Result};
use std::time::Duration;

/// Wall-time cost model of one simulated device.
///
/// Step latency is `step_base + per_token * bucket` — bucket-shaped, not
/// token-shaped, because the compiled executables the simulation stands
/// in for always execute the full padded bucket.
#[derive(Debug, Clone, Copy)]
pub struct SimPerf {
    /// Fixed per-step overhead (dispatch, sampling, bookkeeping).
    pub step_base: Duration,
    /// Compute per bucket token.
    pub per_token: Duration,
    /// Weight-upload latency charged when the weights version changes
    /// after startup (an adapter load/evict re-sync).
    pub adapter_swap: Duration,
}

impl Default for SimPerf {
    fn default() -> Self {
        SimPerf {
            step_base: Duration::from_micros(500),
            per_token: Duration::from_micros(20),
            adapter_swap: Duration::from_millis(25),
        }
    }
}

impl SimPerf {
    /// A faster profile for unit tests (keeps replay horizons short).
    pub fn fast() -> Self {
        SimPerf {
            step_base: Duration::from_micros(100),
            per_token: Duration::from_micros(2),
            adapter_swap: Duration::from_millis(2),
        }
    }

    /// No latency injection at all: steps run as fast as the host can
    /// drive them. This is the profile the hot-path microbench
    /// (`benches/fig11_hotpath.rs`) uses to measure pipeline overhead
    /// rather than the simulated device.
    pub fn instant() -> Self {
        SimPerf {
            step_base: Duration::ZERO,
            per_token: Duration::ZERO,
            adapter_swap: Duration::ZERO,
        }
    }
}

/// Simulated runtime for one engine (device) — see module docs.
pub struct SimRuntime {
    cfg: ModelConfig,
    variant: Variant,
    perf: SimPerf,
    seed: u64,
    weights_version: u64,
    maps_version: u64,
    params_uploaded: bool,
    /// Always materialize the full `[out_rows, vocab]` logits block,
    /// even when the engine only needs greedy tokens.
    full_logits: bool,
    /// Fault injection: fail `step_into` once this many steps have run
    /// (0 = never). Deterministic replica-death hook for chaos tests.
    fail_after: usize,
    /// Steps executed so far (drives `fail_after`).
    steps_taken: usize,
    /// Host copy of the uploaded expert maps (adapter-aware variants).
    maps: Option<ExpertMaps>,
    // persistent per-step scratch (zero-allocation steady state)
    aid_buf: Vec<i32>,
    topk_buf: Vec<i32>,
    route_buf: Vec<i32>,
    fold_buf: Vec<u64>,
}

fn splitmix(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

impl SimRuntime {
    pub fn new(cfg: &ModelConfig, variant: Variant, perf: SimPerf, seed: u64) -> Result<SimRuntime> {
        if cfg.buckets.is_empty() {
            bail!("sim runtime needs token buckets in the config");
        }
        if cfg.vocab == 0 || cfg.kv_cap == 0 || cfg.max_seqs == 0 {
            bail!("sim runtime needs vocab/kv_cap/max_seqs > 0");
        }
        Ok(SimRuntime {
            cfg: cfg.clone(),
            variant,
            perf,
            seed,
            weights_version: 0,
            maps_version: 0,
            params_uploaded: false,
            full_logits: false,
            fail_after: 0,
            steps_taken: 0,
            maps: None,
            aid_buf: Vec::new(),
            topk_buf: Vec::new(),
            route_buf: Vec::new(),
            fold_buf: Vec::new(),
        })
    }

    pub fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    pub fn variant(&self) -> Variant {
        self.variant
    }

    pub fn buckets(&self) -> &[usize] {
        &self.cfg.buckets
    }

    /// Force the full-logits path even for all-greedy batches (accuracy
    /// experiments that want the whole tensor; see module docs).
    pub fn set_full_logits(&mut self, on: bool) {
        self.full_logits = on;
    }

    /// Fault injection: make the `n+1`-th step fail with an error, as if
    /// the device had died mid-decode (0 disables). The coordinator's
    /// failover path treats the resulting engine error like any other
    /// replica crash, which is exactly what chaos tests want.
    pub fn fail_after_steps(&mut self, n: usize) {
        self.fail_after = n;
    }

    /// Logits rows per bucket; must mirror `SchedConfig::out_rows`.
    pub fn out_rows(&self, bucket: usize) -> Option<usize> {
        self.cfg
            .buckets
            .contains(&bucket)
            .then(|| bucket.min(self.cfg.max_seqs))
    }

    /// Accepts any [`ParamSource`] for signature parity with the PJRT
    /// runtime; the data is not read. A version bump after the initial
    /// upload models an adapter load/evict weight re-sync and costs
    /// [`SimPerf::adapter_swap`] of wall time.
    pub fn upload_params<S: ParamSource>(&mut self, _source: &mut S, version: u64) -> Result<()> {
        if version == self.weights_version && self.params_uploaded {
            return Ok(());
        }
        if self.params_uploaded && !self.perf.adapter_swap.is_zero() {
            std::thread::sleep(self.perf.adapter_swap);
        }
        self.weights_version = version;
        self.params_uploaded = true;
        Ok(())
    }

    /// Keep a host copy of the expert maps so the per-step fused reroute
    /// (the rows' routing signature) reflects the resident adapters.
    pub fn upload_expert_maps(&mut self, maps: &[i32], version: u64) -> Result<()> {
        if !self.variant.is_adapter_aware() {
            return Ok(());
        }
        if version == self.maps_version && self.maps.is_some() {
            return Ok(());
        }
        self.maps = Some(ExpertMaps::from_flat(
            self.cfg.layers,
            self.cfg.max_adapters,
            self.cfg.num_experts,
            self.cfg.e_max,
            maps.to_vec(),
        )?);
        self.maps_version = version;
        Ok(())
    }

    pub fn reset_kv(&mut self) {
        // the simulation keeps no device KV state
    }

    /// Token index a logits row points at (clamped like the device
    /// gather would be).
    #[inline]
    fn row_token(inputs: &StepInputs, bucket: usize, r: usize) -> usize {
        (inputs.out_rows[r].max(0) as usize).min(bucket - 1)
    }

    /// Base hash of row `r`: the pure function of
    /// `(seed, token, position, AID)` every output derives from.
    #[inline]
    fn row_seed(&self, inputs: &StepInputs, t: usize) -> u64 {
        self.seed
            ^ (inputs.token_ids[t] as u64).wrapping_mul(0x9e3779b1)
            ^ ((inputs.positions[t] as u64) << 24)
            ^ (((inputs.aid[t] as i64) as u64) << 48)
    }

    /// The greedy token of a row with mixed hash `h` (already folded and
    /// splitmixed). Single source of truth for BOTH output paths: the
    /// fast path returns it directly, the logits path pins the row's
    /// argmax to it.
    #[inline]
    fn greedy_token(h: u64, vocab: usize) -> i32 {
        ((h >> 17) % vocab as u64) as i32
    }

    /// Fused batched rerouting over the live rows: simulate each row's
    /// per-layer top-k router picks, rewrite them through the expert maps
    /// in one [`ExpertMaps::reroute_batch`] pass per layer (the host
    /// analogue of the L1 Pallas kernel), and fold the rerouted slot ids
    /// into `fold_buf[r]`. All buffers are persistent — zero allocation
    /// in the steady state.
    fn route_fold(&mut self, inputs: &StepInputs, bucket: usize, live: usize) -> Result<()> {
        let SimRuntime { cfg, seed, maps, aid_buf, topk_buf, route_buf, fold_buf, .. } = self;
        fold_buf.clear();
        fold_buf.resize(live, 0);
        let Some(maps) = maps else {
            return Ok(());
        };
        let k = cfg.top_k.max(1);
        let m = cfg.num_experts as u64;
        aid_buf.clear();
        topk_buf.clear();
        topk_buf.resize(live * k, 0);
        route_buf.clear();
        route_buf.resize(live * k, 0);
        for r in 0..live {
            let t = Self::row_token(inputs, bucket, r);
            aid_buf.push(inputs.aid[t]);
        }
        for l in 0..cfg.layers {
            // simulated router: deterministic top-k base experts per row
            for r in 0..live {
                let t = Self::row_token(inputs, bucket, r);
                let mut h = splitmix(
                    *seed ^ (inputs.token_ids[t] as u64) ^ ((l as u64) << 40) ^ 0x7261_6e6b,
                );
                for j in 0..k {
                    h = splitmix(h);
                    topk_buf[r * k + j] = (h % m) as i32;
                }
            }
            maps.reroute_batch(l, &aid_buf[..live], &topk_buf[..live * k], &mut route_buf[..live * k])?;
            for r in 0..live {
                for j in 0..k {
                    fold_buf[r] = splitmix(fold_buf[r] ^ (route_buf[r * k + j] as u64) ^ ((l as u64) << 32));
                }
            }
        }
        Ok(())
    }

    /// One simulated step, returning a freshly allocated output (tests
    /// and one-shot callers). Always takes the logits path over every ABI
    /// row — the exact legacy behaviour.
    pub fn step(&mut self, bucket: usize, inputs: &StepInputs) -> Result<StepOutput> {
        let mut out = StepOutput::new();
        let rows = self.out_rows(bucket).unwrap_or(0);
        self.step_into(bucket, inputs, rows, false, &mut out)?;
        Ok(out)
    }

    /// One simulated step into the caller-owned `out` buffer: validate
    /// the batch like the PJRT runtime, sleep the modelled latency, run
    /// the fused batched reroute, then emit either greedy tokens
    /// (`want_tokens`, O(1) per live row) or deterministic pseudo-logits.
    /// `live_rows` is the number of rows the engine will actually sample
    /// (`ws.rows.len()`); pad rows are never computed.
    pub fn step_into(
        &mut self,
        bucket: usize,
        inputs: &StepInputs,
        live_rows: usize,
        want_tokens: bool,
        out: &mut StepOutput,
    ) -> Result<()> {
        let Some(out_rows) = self.out_rows(bucket) else {
            bail!("no executable for bucket {bucket}");
        };
        if !self.params_uploaded {
            bail!("params not uploaded");
        }
        for (name, v, want) in [
            ("token_ids", inputs.token_ids.len(), bucket),
            ("positions", inputs.positions.len(), bucket),
            ("seg_ids", inputs.seg_ids.len(), bucket),
            ("slot_idx", inputs.slot_idx.len(), bucket),
            ("cache_seg", inputs.cache_seg.len(), self.cfg.kv_cap),
            ("cache_pos", inputs.cache_pos.len(), self.cfg.kv_cap),
            ("out_rows", inputs.out_rows.len(), out_rows),
            ("aid", inputs.aid.len(), bucket),
        ] {
            if v != want {
                bail!("step input {name}: {v} elements, bucket wants {want}");
            }
        }

        if self.fail_after > 0 && self.steps_taken >= self.fail_after {
            bail!("injected fault: sim device failed after {} steps", self.steps_taken);
        }
        self.steps_taken += 1;

        let latency = self.perf.step_base + self.perf.per_token * bucket as u32;
        if !latency.is_zero() {
            std::thread::sleep(latency);
        }

        let live = live_rows.min(out_rows);
        self.route_fold(inputs, bucket, live)?;

        let vocab = self.cfg.vocab;
        out.execute_time = latency;
        if want_tokens && !self.full_logits {
            // greedy fast path: one token per live row, straight off the
            // row hash — no vocab-wide logits materialized
            out.kind = StepYield::GreedyTokens;
            out.logits.clear();
            out.tokens.clear();
            for r in 0..live {
                let t = Self::row_token(inputs, bucket, r);
                let h = splitmix(self.row_seed(inputs, t) ^ self.fold_buf[r]);
                out.tokens.push(Self::greedy_token(h, vocab));
            }
            out.filled_rows = live;
            return Ok(());
        }

        // logits path: live rows only, unless the full tensor was asked for
        let filled = if self.full_logits { out_rows } else { live };
        out.kind = StepYield::Logits;
        out.tokens.clear();
        out.logits.clear();
        out.logits.resize(filled * vocab, 0.0);
        for r in 0..filled {
            let t = Self::row_token(inputs, bucket, r);
            let fold = self.fold_buf.get(r).copied().unwrap_or(0);
            let h0 = splitmix(self.row_seed(inputs, t) ^ fold);
            let mut h = h0;
            let row = &mut out.logits[r * vocab..(r + 1) * vocab];
            for v in row.iter_mut() {
                h = splitmix(h);
                // map to [-4, 4): enough spread for distinct sampling
                *v = ((h >> 11) as f64 / (1u64 << 53) as f64 * 8.0 - 4.0) as f32;
            }
            // pin the argmax to the fast-path token (above the [-4, 4)
            // range) so greedy decoding is identical under both paths
            row[Self::greedy_token(h0, vocab) as usize] = 5.0;
        }
        out.filled_rows = filled;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct NoParams;
    impl ParamSource for NoParams {
        fn named(&self, _name: &str) -> Option<&[f32]> {
            None
        }
        fn expert_tensor(&mut self, _l: usize, _p: usize, _len: usize) -> Result<&[f32]> {
            bail!("sim never reads params")
        }
    }

    fn cfg() -> ModelConfig {
        ModelConfig::sim_default()
    }

    fn rt(seed: u64) -> SimRuntime {
        let mut rt =
            SimRuntime::new(&cfg(), Variant::Weave, SimPerf::fast(), seed).unwrap();
        rt.upload_params(&mut NoParams, 1).unwrap();
        rt
    }

    #[test]
    fn step_is_deterministic_and_shaped() {
        let c = cfg();
        let bucket = c.buckets[0];
        let out_rows = bucket.min(c.max_seqs);
        let mut inputs = StepInputs::blank(&c, bucket, out_rows);
        inputs.token_ids[0] = 7;
        inputs.seg_ids[0] = 0;
        inputs.aid[0] = 2;
        let a = rt(42).step(bucket, &inputs).unwrap();
        let b = rt(42).step(bucket, &inputs).unwrap();
        assert_eq!(a.kind, StepYield::Logits);
        assert_eq!(a.logits, b.logits);
        assert_eq!(a.filled_rows, out_rows);
        assert_eq!(a.logits.len(), out_rows * c.vocab);
        // different adapter -> different greedy token for the same prompt
        inputs.aid[0] = -1;
        let base = rt(42).step(bucket, &inputs).unwrap();
        assert_ne!(&a.logits[..c.vocab], &base.logits[..c.vocab]);
    }

    #[test]
    fn greedy_fast_path_is_deterministic_and_allocation_lean() {
        let c = cfg();
        let bucket = c.buckets[0];
        let out_rows = bucket.min(c.max_seqs);
        let mut inputs = StepInputs::blank(&c, bucket, out_rows);
        inputs.token_ids[0] = 7;
        inputs.seg_ids[0] = 0;
        let mut r1 = rt(42);
        let mut r2 = rt(42);
        let mut o1 = StepOutput::new();
        let mut o2 = StepOutput::new();
        r1.step_into(bucket, &inputs, 2, true, &mut o1).unwrap();
        r2.step_into(bucket, &inputs, 2, true, &mut o2).unwrap();
        assert_eq!(o1.kind, StepYield::GreedyTokens);
        assert_eq!(o1.filled_rows, 2);
        assert_eq!(o1.tokens, o2.tokens);
        assert!(o1.logits.is_empty(), "no logits materialized");
        assert!(o1.tokens.iter().all(|&t| (t as usize) < c.vocab));
        // a different seed decodes differently
        let mut o3 = StepOutput::new();
        rt(43).step_into(bucket, &inputs, 2, true, &mut o3).unwrap();
        assert_ne!(o1.tokens, o3.tokens);
        // the buffer is refilled in place across steps
        let before = o1.tokens.as_ptr();
        r1.step_into(bucket, &inputs, 2, true, &mut o1).unwrap();
        assert_eq!(o1.tokens.as_ptr(), before);
    }

    #[test]
    fn greedy_tokens_agree_with_logits_argmax() {
        // a greedy stream must not change when the engine switches output
        // modes (e.g. a temperature request joins the batch): the logits
        // row's argmax is pinned to the fast-path token
        let c = cfg();
        let bucket = c.buckets[0];
        let out_rows = bucket.min(c.max_seqs);
        let mut inputs = StepInputs::blank(&c, bucket, out_rows);
        for t in 0..4 {
            inputs.token_ids[t] = 3 + t as i32;
            inputs.positions[t] = t as i32;
            inputs.seg_ids[t] = 0;
            inputs.aid[t] = if t % 2 == 0 { 1 } else { -1 };
            inputs.out_rows[t] = t as i32;
        }
        let mut r = rt(11);
        let mut maps = ExpertMaps::new(&c);
        maps.install(1, &vec![vec![0, 1, 2]; c.layers]).unwrap();
        r.upload_expert_maps(maps.as_slice(), 1).unwrap();
        let mut toks = StepOutput::new();
        r.step_into(bucket, &inputs, 4, true, &mut toks).unwrap();
        let mut lg = StepOutput::new();
        r.step_into(bucket, &inputs, 4, false, &mut lg).unwrap();
        assert_eq!(toks.kind, StepYield::GreedyTokens);
        assert_eq!(lg.kind, StepYield::Logits);
        for row in 0..4 {
            let argmax = crate::sampler::argmax(lg.row_logits(row, c.vocab));
            assert_eq!(toks.tokens[row], argmax, "row {row} diverged across modes");
        }
    }

    #[test]
    fn full_logits_option_overrides_the_fast_path() {
        let c = cfg();
        let bucket = c.buckets[0];
        let out_rows = bucket.min(c.max_seqs);
        let inputs = StepInputs::blank(&c, bucket, out_rows);
        let mut r = rt(0);
        r.set_full_logits(true);
        let mut out = StepOutput::new();
        r.step_into(bucket, &inputs, 1, true, &mut out).unwrap();
        assert_eq!(out.kind, StepYield::Logits);
        assert_eq!(out.filled_rows, out_rows, "full tensor on request");
        assert_eq!(out.logits.len(), out_rows * c.vocab);
    }

    #[test]
    fn expert_map_changes_change_outputs() {
        let c = cfg();
        let bucket = c.buckets[0];
        let out_rows = bucket.min(c.max_seqs);
        let mut inputs = StepInputs::blank(&c, bucket, out_rows);
        inputs.seg_ids[0] = 0;
        let identity = ExpertMaps::new(&c);
        let mut routed = ExpertMaps::new(&c);
        let experts: Vec<Vec<u32>> = vec![vec![0, 1, 2, 3]; c.layers];
        routed.install(0, &experts).unwrap();
        let mut a = rt(7);
        a.upload_expert_maps(identity.as_slice(), 1).unwrap();
        let mut b = rt(7);
        b.upload_expert_maps(routed.as_slice(), 1).unwrap();
        // over a handful of prompts, an adapter row (aid 0) must react to
        // the rerouted experts; base rows (aid -1, identity map row) must
        // not. (Each single token's simulated top-k may by chance miss
        // the fine-tuned experts, so assert across tokens.)
        let mut differs = false;
        for tok in 0..8 {
            inputs.token_ids[0] = tok;
            inputs.aid[0] = 0;
            let la = a.step(bucket, &inputs).unwrap();
            let lb = b.step(bucket, &inputs).unwrap();
            differs |= la.logits[..c.vocab] != lb.logits[..c.vocab];
            inputs.aid[0] = -1;
            let ba = a.step(bucket, &inputs).unwrap();
            let bb = b.step(bucket, &inputs).unwrap();
            assert_eq!(&ba.logits[..c.vocab], &bb.logits[..c.vocab]);
        }
        assert!(differs, "rerouted experts must change some adapter output");
    }

    #[test]
    fn rejects_bad_shapes_and_unknown_buckets() {
        let c = cfg();
        let bucket = c.buckets[0];
        let inputs = StepInputs::blank(&c, bucket, bucket.min(c.max_seqs));
        let mut r = rt(0);
        assert!(r.step(bucket + 1, &inputs).is_err());
        let mut short = inputs.clone();
        short.aid.pop();
        assert!(r.step(bucket, &short).is_err());
    }

    #[test]
    fn params_required_before_step() {
        let c = cfg();
        let mut r = SimRuntime::new(&c, Variant::Weave, SimPerf::fast(), 0).unwrap();
        let bucket = c.buckets[0];
        let inputs = StepInputs::blank(&c, bucket, bucket.min(c.max_seqs));
        assert!(r.step(bucket, &inputs).is_err());
        r.upload_params(&mut NoParams, 1).unwrap();
        assert!(r.step(bucket, &inputs).is_ok());
    }
}
