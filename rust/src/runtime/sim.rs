//! Simulated execution backend: the PJRT runtime's step contract without
//! PJRT.
//!
//! [`SimRuntime`] accepts the exact same [`StepInputs`] the scheduler
//! packs for the compiled executables, burns a calibrated amount of wall
//! time per step (so real-time trace replay, queueing and
//! `compute_share` partitioning behave like they do against the real
//! runtime), and produces deterministic pseudo-logits — a pure function
//! of the sampled row's `(token, position, AID)` and the engine seed, so
//! greedy decoding is reproducible across runs and replicas.
//!
//! What it is for: serving-layer experiments — the scheduler, engine,
//! server and the fleet [`crate::coordinator`] — in environments without
//! AOT artifacts or an `xla_extension` build (CI, the offline testbed).
//! What it is *not*: a model. Logits carry no semantics beyond
//! determinism, so accuracy experiments (Table 3) still require the PJRT
//! backend.

use super::engine::{ParamSource, StepInputs, StepOutput};
use crate::model::ModelConfig;
use crate::runtime::Variant;
use anyhow::{bail, Result};
use std::time::Duration;

/// Wall-time cost model of one simulated device.
///
/// Step latency is `step_base + per_token * bucket` — bucket-shaped, not
/// token-shaped, because the compiled executables the simulation stands
/// in for always execute the full padded bucket.
#[derive(Debug, Clone, Copy)]
pub struct SimPerf {
    /// Fixed per-step overhead (dispatch, sampling, bookkeeping).
    pub step_base: Duration,
    /// Compute per bucket token.
    pub per_token: Duration,
    /// Weight-upload latency charged when the weights version changes
    /// after startup (an adapter load/evict re-sync).
    pub adapter_swap: Duration,
}

impl Default for SimPerf {
    fn default() -> Self {
        SimPerf {
            step_base: Duration::from_micros(500),
            per_token: Duration::from_micros(20),
            adapter_swap: Duration::from_millis(25),
        }
    }
}

impl SimPerf {
    /// A faster profile for unit tests (keeps replay horizons short).
    pub fn fast() -> Self {
        SimPerf {
            step_base: Duration::from_micros(100),
            per_token: Duration::from_micros(2),
            adapter_swap: Duration::from_millis(2),
        }
    }
}

/// Simulated runtime for one engine (device) — see module docs.
pub struct SimRuntime {
    cfg: ModelConfig,
    variant: Variant,
    perf: SimPerf,
    seed: u64,
    weights_version: u64,
    maps_version: u64,
    params_uploaded: bool,
}

fn splitmix(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

impl SimRuntime {
    pub fn new(cfg: &ModelConfig, variant: Variant, perf: SimPerf, seed: u64) -> Result<SimRuntime> {
        if cfg.buckets.is_empty() {
            bail!("sim runtime needs token buckets in the config");
        }
        if cfg.vocab == 0 || cfg.kv_cap == 0 || cfg.max_seqs == 0 {
            bail!("sim runtime needs vocab/kv_cap/max_seqs > 0");
        }
        Ok(SimRuntime {
            cfg: cfg.clone(),
            variant,
            perf,
            seed,
            weights_version: 0,
            maps_version: 0,
            params_uploaded: false,
        })
    }

    pub fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    pub fn variant(&self) -> Variant {
        self.variant
    }

    pub fn buckets(&self) -> Vec<usize> {
        self.cfg.buckets.clone()
    }

    /// Logits rows per bucket; must mirror `SchedConfig::out_rows`.
    pub fn out_rows(&self, bucket: usize) -> Option<usize> {
        self.cfg
            .buckets
            .contains(&bucket)
            .then(|| bucket.min(self.cfg.max_seqs))
    }

    /// Accepts any [`ParamSource`] for signature parity with the PJRT
    /// runtime; the data is not read. A version bump after the initial
    /// upload models an adapter load/evict weight re-sync and costs
    /// [`SimPerf::adapter_swap`] of wall time.
    pub fn upload_params<S: ParamSource>(&mut self, _source: &mut S, version: u64) -> Result<()> {
        if version == self.weights_version && self.params_uploaded {
            return Ok(());
        }
        if self.params_uploaded && !self.perf.adapter_swap.is_zero() {
            std::thread::sleep(self.perf.adapter_swap);
        }
        self.weights_version = version;
        self.params_uploaded = true;
        Ok(())
    }

    pub fn upload_expert_maps(&mut self, maps: &[i32], version: u64) -> Result<()> {
        if !self.variant.is_adapter_aware() {
            return Ok(());
        }
        let want = self.cfg.layers * (self.cfg.max_adapters + 1) * self.cfg.num_experts;
        if maps.len() != want {
            bail!("expert maps length {} != {want}", maps.len());
        }
        self.maps_version = version;
        Ok(())
    }

    pub fn reset_kv(&mut self) {
        // the simulation keeps no device KV state
    }

    /// One simulated step: validate the batch like the PJRT runtime,
    /// sleep the modelled latency, emit deterministic pseudo-logits.
    pub fn step(&mut self, bucket: usize, inputs: &StepInputs) -> Result<StepOutput> {
        let Some(out_rows) = self.out_rows(bucket) else {
            bail!("no executable for bucket {bucket}");
        };
        if !self.params_uploaded {
            bail!("params not uploaded");
        }
        for (name, v, want) in [
            ("token_ids", inputs.token_ids.len(), bucket),
            ("positions", inputs.positions.len(), bucket),
            ("seg_ids", inputs.seg_ids.len(), bucket),
            ("slot_idx", inputs.slot_idx.len(), bucket),
            ("cache_seg", inputs.cache_seg.len(), self.cfg.kv_cap),
            ("cache_pos", inputs.cache_pos.len(), self.cfg.kv_cap),
            ("out_rows", inputs.out_rows.len(), out_rows),
            ("aid", inputs.aid.len(), bucket),
        ] {
            if v != want {
                bail!("step input {name}: {v} elements, bucket wants {want}");
            }
        }

        let latency = self.perf.step_base + self.perf.per_token * bucket as u32;
        if !latency.is_zero() {
            std::thread::sleep(latency);
        }

        let vocab = self.cfg.vocab;
        let mut logits = vec![0.0f32; out_rows * vocab];
        for r in 0..out_rows {
            let t = (inputs.out_rows[r].max(0) as usize).min(bucket - 1);
            let mut h = splitmix(
                self.seed
                    ^ (inputs.token_ids[t] as u64).wrapping_mul(0x9e3779b1)
                    ^ ((inputs.positions[t] as u64) << 24)
                    ^ (((inputs.aid[t] as i64) as u64) << 48),
            );
            let row = &mut logits[r * vocab..(r + 1) * vocab];
            for v in row.iter_mut() {
                h = splitmix(h);
                // map to [-4, 4): enough spread for distinct greedy argmax
                *v = ((h >> 11) as f64 / (1u64 << 53) as f64 * 8.0 - 4.0) as f32;
            }
        }
        Ok(StepOutput { logits, out_rows, execute_time: latency })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct NoParams;
    impl ParamSource for NoParams {
        fn named(&self, _name: &str) -> Option<&[f32]> {
            None
        }
        fn expert_tensor(&mut self, _l: usize, _p: usize, _len: usize) -> Result<&[f32]> {
            bail!("sim never reads params")
        }
    }

    fn cfg() -> ModelConfig {
        ModelConfig::sim_default()
    }

    fn rt(seed: u64) -> SimRuntime {
        let mut rt =
            SimRuntime::new(&cfg(), Variant::Weave, SimPerf::fast(), seed).unwrap();
        rt.upload_params(&mut NoParams, 1).unwrap();
        rt
    }

    #[test]
    fn step_is_deterministic_and_shaped() {
        let c = cfg();
        let bucket = c.buckets[0];
        let out_rows = bucket.min(c.max_seqs);
        let mut inputs = StepInputs::blank(&c, bucket, out_rows);
        inputs.token_ids[0] = 7;
        inputs.seg_ids[0] = 0;
        inputs.aid[0] = 2;
        let a = rt(42).step(bucket, &inputs).unwrap();
        let b = rt(42).step(bucket, &inputs).unwrap();
        assert_eq!(a.logits, b.logits);
        assert_eq!(a.out_rows, out_rows);
        assert_eq!(a.logits.len(), out_rows * c.vocab);
        // different adapter -> different greedy token for the same prompt
        inputs.aid[0] = -1;
        let base = rt(42).step(bucket, &inputs).unwrap();
        assert_ne!(&a.logits[..c.vocab], &base.logits[..c.vocab]);
    }

    #[test]
    fn rejects_bad_shapes_and_unknown_buckets() {
        let c = cfg();
        let bucket = c.buckets[0];
        let inputs = StepInputs::blank(&c, bucket, bucket.min(c.max_seqs));
        let mut r = rt(0);
        assert!(r.step(bucket + 1, &inputs).is_err());
        let mut short = inputs.clone();
        short.aid.pop();
        assert!(r.step(bucket, &short).is_err());
    }

    #[test]
    fn params_required_before_step() {
        let c = cfg();
        let mut r = SimRuntime::new(&c, Variant::Weave, SimPerf::fast(), 0).unwrap();
        let bucket = c.buckets[0];
        let inputs = StepInputs::blank(&c, bucket, bucket.min(c.max_seqs));
        assert!(r.step(bucket, &inputs).is_err());
        r.upload_params(&mut NoParams, 1).unwrap();
        assert!(r.step(bucket, &inputs).is_ok());
    }
}
