//! Production sampling surface over step logits.
//!
//! Operates on one `[vocab]` row of the step output (the engine slices the
//! `[O, vocab]` block by out-row index). Every request carries a
//! [`SamplingParams`]; per-request mutable state (PRNG, penalty counts,
//! stop-sequence ring) lives in a preallocated [`SamplerBank`] slot so the
//! steady-state decode loop never heap-allocates.
//!
//! Determinism contract: a sampled token depends only on the request's
//! resolved seed, the number of tokens the request has sampled so far, and
//! the logits row — never on batch composition, slot assignment order, or
//! which backend mode produced the logits. Greedy rows consume no
//! randomness, so mixing greedy and sampled requests in one batch cannot
//! perturb either stream.
//!
//! NaN policy: logits are ordered with [`f32::total_cmp`] after mapping NaN
//! to `-inf`, so a backend emitting a NaN logit can never panic the sampler
//! and the NaN token is simply unsampleable.

use crate::util::rng::Pcg;

/// Most stop sequences a single request may carry (protocol cap, see
/// `docs/PROTOCOL.md` v5).
pub const MAX_STOP_SEQS: usize = 8;
/// Longest stop sequence, in tokens (protocol cap). Bounds the per-slot
/// recent-token ring used for match detection.
pub const MAX_STOP_SEQ_LEN: usize = 16;

/// Per-request sampling configuration (serving API + NDJSON protocol v5).
///
/// The zero value of each knob disables it: `temperature == 0.0` is greedy
/// argmax, `top_k == 0` and `top_p == 1.0` apply no filter,
/// `repetition_penalty == 1.0` and zero presence/frequency penalties leave
/// logits untouched, `max_len == 0` imposes no total-length cap, and empty
/// stop/bias lists are no-ops. [`SamplingParams::greedy`] is the
/// all-disabled default used by every greedy-agreement experiment.
///
/// Penalty semantics: the penalty token-count table counts *seen* tokens —
/// the prompt plus everything generated so far. `repetition_penalty`
/// divides positive logits (multiplies negative ones) of seen tokens,
/// `presence_penalty` is subtracted once per seen token, and
/// `frequency_penalty` is subtracted once per occurrence.
#[derive(Debug, Clone, PartialEq)]
pub struct SamplingParams {
    /// Softmax temperature; `0.0` (or below) selects greedy argmax.
    pub temperature: f32,
    /// Keep only the `top_k` largest logits before sampling; `0` disables.
    pub top_k: usize,
    /// Nucleus filter: sample from the minimal probability-sorted prefix
    /// whose mass reaches `top_p`; `1.0` disables.
    pub top_p: f32,
    /// Divide positive / multiply negative logits of seen tokens; `1.0`
    /// disables.
    pub repetition_penalty: f32,
    /// Subtracted from the logit of every seen token; `0.0` disables.
    pub presence_penalty: f32,
    /// Subtracted per occurrence of a seen token; `0.0` disables.
    pub frequency_penalty: f32,
    /// Token-id sequences that finish the request with reason `stop` once
    /// the generated stream ends with one of them (matches may straddle
    /// step boundaries). At most [`MAX_STOP_SEQS`] sequences of at most
    /// [`MAX_STOP_SEQ_LEN`] tokens each; sequences over the length cap
    /// are *dropped* by [`SamplingParams::sanitize`], never truncated — a
    /// truncated prefix would match more often than the caller asked.
    pub stop_sequences: Vec<Vec<i32>>,
    /// Single token ids that finish the request with reason `stop`.
    pub stop_token_ids: Vec<i32>,
    /// Cap on total sequence length (prompt + generated); `0` disables.
    /// Tighter than `max_new_tokens` wins.
    pub max_len: usize,
    /// Additive per-token logit bias; `-inf` makes a token unsampleable.
    pub logit_bias: Vec<(i32, f32)>,
    /// Per-request seed. `Some` pins the sampled stream: the same seed and
    /// prompt reproduce byte-identical tokens across backend modes, batch
    /// compositions, and fleet replicas. `None` draws a seed from the
    /// engine at submit time.
    pub seed: Option<u64>,
}

impl Default for SamplingParams {
    fn default() -> Self {
        Self::greedy()
    }
}

impl SamplingParams {
    /// Greedy argmax with every knob disabled — the exact-agreement mode
    /// used by the accuracy experiments.
    pub fn greedy() -> SamplingParams {
        SamplingParams {
            temperature: 0.0,
            top_k: 0,
            top_p: 1.0,
            repetition_penalty: 1.0,
            presence_penalty: 0.0,
            frequency_penalty: 0.0,
            stop_sequences: Vec::new(),
            stop_token_ids: Vec::new(),
            max_len: 0,
            logit_bias: Vec::new(),
            seed: None,
        }
    }

    /// Plain temperature sampling.
    pub fn temperature(t: f32) -> SamplingParams {
        SamplingParams { temperature: t, ..Self::greedy() }
    }

    /// Top-k filter then temperature sampling.
    pub fn top_k(k: usize, t: f32) -> SamplingParams {
        SamplingParams { temperature: t, top_k: k, ..Self::greedy() }
    }

    /// Nucleus (top-p) filter then temperature sampling.
    pub fn top_p(p: f32, t: f32) -> SamplingParams {
        SamplingParams { temperature: t, top_p: p, ..Self::greedy() }
    }

    /// Builder-style seed pin.
    pub fn with_seed(mut self, seed: u64) -> SamplingParams {
        self.seed = Some(seed);
        self
    }

    /// `true` when token choice is argmax (no randomness consumed).
    pub fn is_greedy(&self) -> bool {
        !(self.temperature > 0.0)
    }

    /// `true` when any logit-mutating knob is active.
    pub fn has_penalties(&self) -> bool {
        self.repetition_penalty != 1.0
            || self.presence_penalty != 0.0
            || self.frequency_penalty != 0.0
    }

    /// `true` when this request's rows need materialized logits. Plain
    /// greedy rows (no penalties, no bias) can ride the backend's O(1)
    /// greedy fast path; anything else forces the logits path.
    pub fn needs_logits(&self) -> bool {
        !self.is_greedy() || self.has_penalties() || !self.logit_bias.is_empty()
    }

    /// `true` when the request can ever finish with reason `stop`.
    pub fn has_stops(&self) -> bool {
        !self.stop_sequences.is_empty() || !self.stop_token_ids.is_empty()
    }

    /// Clamp every knob into its valid range and enforce the stop caps.
    /// Called once at submit; keeps the hot path branch-free of validity
    /// checks.
    pub fn sanitize(&mut self) {
        if !self.temperature.is_finite() || self.temperature < 0.0 {
            self.temperature = 0.0;
        }
        if !self.top_p.is_finite() || self.top_p <= 0.0 || self.top_p > 1.0 {
            self.top_p = 1.0;
        }
        if !self.repetition_penalty.is_finite() || self.repetition_penalty <= 0.0 {
            self.repetition_penalty = 1.0;
        }
        if !self.presence_penalty.is_finite() {
            self.presence_penalty = 0.0;
        }
        if !self.frequency_penalty.is_finite() {
            self.frequency_penalty = 0.0;
        }
        // Over-long stop sequences are dropped, not truncated: matching a
        // 16-token prefix would fire *more* often than the caller asked,
        // ending generation on text they never requested a stop for.
        self.stop_sequences
            .retain(|s| !s.is_empty() && s.len() <= MAX_STOP_SEQ_LEN);
        self.stop_sequences.truncate(MAX_STOP_SEQS);
        // A NaN bias would poison its logit (NaN propagates through the
        // additive bias); neutralize it rather than ban the token.
        for (_, b) in &mut self.logit_bias {
            if b.is_nan() {
                *b = 0.0;
            }
        }
    }
}

/// Why a finished request stopped generating.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    /// Hit `max_new_tokens` (or the `max_len` total cap).
    Length,
    /// Matched a stop sequence or stop token id.
    Stop,
}

impl FinishReason {
    /// Stable wire tag used by the NDJSON `done` frame.
    pub fn as_str(&self) -> &'static str {
        match self {
            FinishReason::Length => "length",
            FinishReason::Stop => "stop",
        }
    }
}

/// Mutable per-request sampler state. One slot per schedulable sequence,
/// preallocated in the bank and recycled on slot reuse.
#[derive(Debug, Clone)]
struct SlotState {
    rng: Pcg,
    /// Seen-token occurrence counts (prompt + generated), vocab-sized.
    counts: Vec<u32>,
    /// Token ids with `counts > 0`, so reset is O(distinct seen) instead
    /// of O(vocab).
    touched: Vec<i32>,
    /// Last `MAX_STOP_SEQ_LEN` generated tokens (stop-sequence cursor).
    recent: [i32; MAX_STOP_SEQ_LEN],
    recent_len: usize,
}

/// Stream id for per-request sampler PRNGs: keeps request streams disjoint
/// from the engine-level PCG streams (e.g. 555 for the legacy engine rng).
const SAMPLER_STREAM: u64 = 0x53_41_4d_50; // "SAMP"

impl SlotState {
    fn with_vocab(vocab: usize) -> SlotState {
        SlotState {
            rng: Pcg::with_stream(0, SAMPLER_STREAM),
            counts: vec![0; vocab],
            touched: Vec::with_capacity(vocab),
            recent: [0; MAX_STOP_SEQ_LEN],
            recent_len: 0,
        }
    }

    fn reset(&mut self, seed: u64, prompt: &[i32]) {
        self.rng = Pcg::with_stream(seed, SAMPLER_STREAM);
        for &t in self.touched.iter() {
            self.counts[t as usize] = 0;
        }
        self.touched.clear();
        self.recent_len = 0;
        for &t in prompt {
            self.count(t);
        }
    }

    fn count(&mut self, tok: i32) {
        if tok >= 0 && (tok as usize) < self.counts.len() {
            if self.counts[tok as usize] == 0 {
                self.touched.push(tok);
            }
            self.counts[tok as usize] += 1;
        }
    }

    fn push_recent(&mut self, tok: i32) {
        if self.recent_len == MAX_STOP_SEQ_LEN {
            self.recent.copy_within(1.., 0);
            self.recent[MAX_STOP_SEQ_LEN - 1] = tok;
        } else {
            self.recent[self.recent_len] = tok;
            self.recent_len += 1;
        }
    }

    /// Does the generated stream currently end with any stop sequence?
    fn stop_matched(&self, stops: &[Vec<i32>]) -> bool {
        stops.iter().any(|s| {
            s.len() <= self.recent_len
                && self.recent[self.recent_len - s.len()..self.recent_len] == s[..]
        })
    }
}

/// NaN-as-`-inf` ordering key: total order, never panics, and a NaN logit
/// can never win a comparison against a real value.
#[inline]
fn key(x: f32) -> f32 {
    if x.is_nan() {
        f32::NEG_INFINITY
    } else {
        x
    }
}

/// Preallocated bank of per-request sampler slots plus shared sort/prob
/// scratch. Lives in the scheduler's `StepWorkspace`; nothing here
/// allocates after construction.
#[derive(Debug, Clone)]
pub struct SamplerBank {
    slots: Vec<SlotState>,
    free: Vec<usize>,
    vocab: usize,
    /// Candidate token indices, reused per sampled row (top-k/top-p sort).
    idx: Vec<usize>,
    /// Candidate probabilities, parallel to `idx`.
    probs: Vec<f32>,
}

impl SamplerBank {
    /// Bank with `slots` recyclable request slots over a `vocab`-sized
    /// token space. All memory is committed here.
    pub fn new(slots: usize, vocab: usize) -> SamplerBank {
        SamplerBank {
            slots: (0..slots).map(|_| SlotState::with_vocab(vocab)).collect(),
            free: (0..slots).rev().collect(),
            vocab,
            idx: Vec::with_capacity(vocab),
            probs: Vec::with_capacity(vocab),
        }
    }

    /// Number of slots currently attached to live requests.
    pub fn in_use(&self) -> usize {
        self.slots.len() - self.free.len()
    }

    /// Attach a fresh request: seed its PRNG, zero its penalty table, seed
    /// the table with the prompt. O(distinct prior tokens + prompt), no
    /// allocation. Panics if the bank is exhausted (the scheduler bounds
    /// concurrent sequences by bank size).
    pub fn acquire(&mut self, seed: u64, prompt: &[i32]) -> usize {
        let slot = self.free.pop().expect("sampler bank exhausted");
        self.slots[slot].reset(seed, prompt);
        slot
    }

    /// Return a slot to the free list (request finished or aborted).
    pub fn release(&mut self, slot: usize) {
        debug_assert!(!self.free.contains(&slot));
        self.free.push(slot);
    }

    /// Sample the next token for `slot` from a mutable logits row,
    /// applying logit bias and penalties in place. Allocation-free; greedy
    /// params consume no randomness.
    pub fn sample_row(&mut self, slot: usize, params: &SamplingParams, logits: &mut [f32]) -> i32 {
        let st = &mut self.slots[slot];
        for &(t, b) in &params.logit_bias {
            if t >= 0 && (t as usize) < logits.len() {
                logits[t as usize] += b;
            }
        }
        if params.has_penalties() {
            let rep = params.repetition_penalty;
            for &t in st.touched.iter() {
                let c = st.counts[t as usize] as f32;
                let x = &mut logits[t as usize];
                if rep != 1.0 {
                    *x = if *x > 0.0 { *x / rep } else { *x * rep };
                }
                *x -= params.frequency_penalty * c + params.presence_penalty;
            }
        }
        if params.is_greedy() {
            return argmax(logits);
        }

        let n = logits.len();
        let t = params.temperature;
        let k = if params.top_k == 0 { n } else { params.top_k.min(n) };
        if k == n && params.top_p >= 1.0 {
            // Unfiltered temperature sampling: CDF walk in logit order, no
            // sort needed.
            let m = logits.iter().fold(f32::NEG_INFINITY, |a, &x| a.max(key(x)));
            let mut sum = 0.0f32;
            for &x in logits.iter() {
                sum += ((key(x) - m) / t).exp();
            }
            if !(sum > 0.0) || !sum.is_finite() {
                return argmax(logits);
            }
            let u = st.rng.f32() * sum;
            let mut acc = 0.0f32;
            let mut last_live = 0usize;
            for (i, &x) in logits.iter().enumerate() {
                let p = ((key(x) - m) / t).exp();
                if p > 0.0 {
                    last_live = i;
                }
                acc += p;
                if u < acc && p > 0.0 {
                    return i as i32;
                }
            }
            return last_live as i32;
        }

        // Filtered path: rank candidates (NaN sorts last via `key`), apply
        // top-k, then take the minimal sorted prefix with mass >= top_p.
        self.idx.clear();
        self.idx.extend(0..n);
        if k < n {
            self.idx.select_nth_unstable_by(k - 1, |&a, &b| {
                key(logits[b]).total_cmp(&key(logits[a])).then(a.cmp(&b))
            });
            self.idx.truncate(k);
        }
        self.idx.sort_unstable_by(|&a, &b| {
            key(logits[b]).total_cmp(&key(logits[a])).then(a.cmp(&b))
        });
        let m = key(logits[self.idx[0]]);
        self.probs.clear();
        let mut sum = 0.0f32;
        for &i in self.idx.iter() {
            let p = ((key(logits[i]) - m) / t).exp();
            sum += p;
            self.probs.push(p);
        }
        if !(sum > 0.0) || !sum.is_finite() {
            return self.idx[0] as i32;
        }
        // Minimal prefix whose normalized mass reaches top_p.
        let target = params.top_p * sum;
        let mut cut = self.probs.len();
        let mut acc = 0.0f32;
        for (j, &p) in self.probs.iter().enumerate() {
            acc += p;
            if acc >= target {
                cut = j + 1;
                break;
            }
        }
        let mass: f32 = self.probs[..cut].iter().sum();
        let u = st.rng.f32() * mass;
        let mut acc = 0.0f32;
        let mut last_live = 0usize;
        for (j, &p) in self.probs[..cut].iter().enumerate() {
            if p > 0.0 {
                last_live = j;
            }
            acc += p;
            if u < acc && p > 0.0 {
                return self.idx[j] as i32;
            }
        }
        self.idx[last_live] as i32
    }

    /// Record an emitted token for `slot` (penalty counts + stop cursor)
    /// and report whether the request should finish with reason `stop`.
    /// Called for every emitted token on both the greedy fast path and the
    /// logits path, so the two modes observe identical state.
    pub fn observe(&mut self, slot: usize, params: &SamplingParams, tok: i32) -> bool {
        let st = &mut self.slots[slot];
        if params.has_penalties() || !params.stop_sequences.is_empty() {
            st.count(tok);
        }
        if params.stop_token_ids.contains(&tok) {
            return true;
        }
        if !params.stop_sequences.is_empty() {
            st.push_recent(tok);
            return st.stop_matched(&params.stop_sequences);
        }
        false
    }

    /// Vocab size the bank was committed for.
    pub fn vocab(&self) -> usize {
        self.vocab
    }
}

/// argmax with deterministic tie-break (lowest index). NaN logits are
/// skipped — they can never win, so a NaN row cannot panic or poison the
/// result.
pub fn argmax(logits: &[f32]) -> i32 {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &v) in logits.iter().enumerate() {
        if !v.is_nan() && v > best_v {
            best_v = v;
            best = i;
        }
    }
    best as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    fn one_shot(params: &SamplingParams, logits: &[f32], seed: u64) -> i32 {
        let mut bank = SamplerBank::new(1, logits.len());
        let slot = bank.acquire(seed, &[]);
        let mut row = logits.to_vec();
        bank.sample_row(slot, params, &mut row)
    }

    #[test]
    fn greedy_is_argmax_with_stable_ties() {
        let l = [0.0, 3.0, 3.0, -1.0];
        assert_eq!(one_shot(&SamplingParams::greedy(), &l, 0), 1);
    }

    #[test]
    fn temperature_sampling_follows_distribution() {
        let l = [0.0f32, (2.0f32).ln()]; // probs 1/3, 2/3 at T=1
        let mut bank = SamplerBank::new(1, 2);
        let params = SamplingParams::temperature(1.0);
        let n = 30_000;
        let mut ones = 0;
        for s in 0..n {
            let slot = bank.acquire(s, &[]);
            let mut row = l;
            if bank.sample_row(slot, &params, &mut row) == 1 {
                ones += 1;
            }
            bank.release(slot);
        }
        let frac = ones as f64 / n as f64;
        assert!((frac - 2.0 / 3.0).abs() < 0.02, "{frac}");
    }

    #[test]
    fn topk_restricts_support() {
        let l = [0.0, 10.0, 9.0, -5.0, 8.0];
        let mut bank = SamplerBank::new(1, 5);
        let slot = bank.acquire(3, &[]);
        let params = SamplingParams::top_k(2, 1.0);
        for _ in 0..200 {
            let mut row = l;
            let t = bank.sample_row(slot, &params, &mut row);
            assert!(t == 1 || t == 2, "sampled {t} outside top-2");
        }
    }

    #[test]
    fn topk_k1_is_greedy() {
        let l = [1.0, 0.5, 2.0];
        assert_eq!(one_shot(&SamplingParams::top_k(1, 1.0), &l, 4), 2);
    }

    #[test]
    fn nan_row_does_not_panic_and_is_unsampleable() {
        // Regression: the old TopK path ordered logits with
        // partial_cmp().unwrap() and panicked on NaN.
        let l = [1.0, f32::NAN, 3.0, f32::NAN, 2.0];
        let mut bank = SamplerBank::new(1, 5);
        let slot = bank.acquire(7, &[]);
        for params in [
            SamplingParams::greedy(),
            SamplingParams::temperature(1.0),
            SamplingParams::top_k(3, 1.0),
            SamplingParams::top_p(0.9, 1.0),
        ] {
            for _ in 0..100 {
                let mut row = l;
                let t = bank.sample_row(slot, &params, &mut row);
                assert!(t == 0 || t == 2 || t == 4, "sampled NaN token {t}");
            }
        }
        let all_nan = [f32::NAN; 4];
        assert_eq!(argmax(&all_nan), 0);
        let mut row = all_nan;
        let _ = bank.sample_row(slot, &SamplingParams::temperature(1.0), &mut row);
    }

    #[test]
    fn seeded_stream_is_reproducible() {
        let l: Vec<f32> = (0..32).map(|i| ((i * 37) % 11) as f32 * 0.3).collect();
        let params = SamplingParams::top_p(0.8, 0.9);
        let run = |seed: u64| -> Vec<i32> {
            let mut bank = SamplerBank::new(1, 32);
            let slot = bank.acquire(seed, &[]);
            (0..64)
                .map(|_| {
                    let mut row = l.clone();
                    bank.sample_row(slot, &params, &mut row)
                })
                .collect()
        };
        assert_eq!(run(42), run(42));
        assert_ne!(run(42), run(43));
    }

    #[test]
    fn logit_bias_neg_inf_excludes_token() {
        let l = [5.0, 4.9, 4.8, 4.7];
        let mut params = SamplingParams::temperature(1.0);
        params.logit_bias = vec![(0, f32::NEG_INFINITY)];
        let mut bank = SamplerBank::new(1, 4);
        let slot = bank.acquire(11, &[]);
        for _ in 0..500 {
            let mut row = l;
            assert_ne!(bank.sample_row(slot, &params, &mut row), 0);
        }
    }

    #[test]
    fn penalties_discount_seen_tokens() {
        let l = [2.0, 2.0, 0.0];
        let mut params = SamplingParams::greedy();
        params.repetition_penalty = 1.5;
        let mut bank = SamplerBank::new(1, 3);
        // Token 0 appears in the prompt, so greedy-with-penalty flips to 1.
        let slot = bank.acquire(0, &[0]);
        let mut row = l;
        assert_eq!(bank.sample_row(slot, &params, &mut row), 1);
    }

    #[test]
    fn observe_detects_stop_sequences_across_calls() {
        let mut params = SamplingParams::greedy();
        params.stop_sequences = vec![vec![7, 8, 9]];
        params.stop_token_ids = vec![99];
        let mut bank = SamplerBank::new(1, 128);
        let slot = bank.acquire(0, &[]);
        assert!(!bank.observe(slot, &params, 7));
        assert!(!bank.observe(slot, &params, 8));
        assert!(!bank.observe(slot, &params, 7)); // broken match restarts
        assert!(!bank.observe(slot, &params, 8));
        assert!(bank.observe(slot, &params, 9));
        assert!(bank.observe(slot, &params, 99));
    }

    #[test]
    fn slot_reuse_resets_state() {
        let mut params = SamplingParams::greedy();
        params.stop_sequences = vec![vec![1, 2]];
        params.repetition_penalty = 2.0;
        let mut bank = SamplerBank::new(1, 8);
        let a = bank.acquire(0, &[3, 3, 3]);
        assert!(!bank.observe(a, &params, 1));
        bank.release(a);
        let b = bank.acquire(0, &[]);
        assert_eq!(a, b);
        // Fresh slot: the dangling [1] prefix from the old request must not
        // complete a stop match, and old penalty counts must be gone.
        assert!(!bank.observe(b, &params, 2));
        // Leaked counts for token 3 would halve its logit (3.0 -> 1.5) and
        // flip the argmax to token 0.
        let mut row = [2.0, 0.0, 0.0, 3.0, 0.0, 0.0, 0.0, 0.0];
        assert_eq!(bank.sample_row(b, &params, &mut row), 3);
    }

    #[test]
    fn sanitize_clamps_out_of_range() {
        let mut p = SamplingParams::temperature(f32::NAN);
        p.top_p = 0.0;
        p.repetition_penalty = -3.0;
        // over-long sequences must be dropped (a truncated prefix would
        // stop too often), valid ones kept — even when invalid ones come
        // first — and the sequence-count cap applies to the survivors
        p.stop_sequences = vec![vec![1; 99], vec![2, 3], vec![], vec![4; 17], vec![5]];
        p.logit_bias = vec![(0, f32::NAN), (1, f32::NEG_INFINITY), (2, 0.5)];
        p.sanitize();
        assert_eq!(p.temperature, 0.0);
        assert_eq!(p.top_p, 1.0);
        assert_eq!(p.repetition_penalty, 1.0);
        assert_eq!(p.stop_sequences, vec![vec![2, 3], vec![5]]);
        let mut many = SamplingParams::greedy();
        many.stop_sequences = vec![vec![1; 2]; 99];
        many.sanitize();
        assert_eq!(many.stop_sequences.len(), MAX_STOP_SEQS);
        // NaN bias neutralized; -inf (a deliberate ban) passes through
        assert_eq!(p.logit_bias[0].1, 0.0);
        assert_eq!(p.logit_bias[1].1, f32::NEG_INFINITY);
        assert_eq!(p.logit_bias[2].1, 0.5);
    }
}
