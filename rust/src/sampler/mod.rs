//! Token sampling over step logits: greedy, temperature, top-k.
//!
//! Operates on one `[vocab]` row of the step output (the engine slices the
//! `[O, vocab]` block by out-row index). Deterministic given the PRNG.

use crate::util::rng::Pcg;

/// Sampling configuration per request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Sampling {
    /// argmax (ties -> lowest token id). Used by the accuracy experiments
    /// (greedy agreement must be exact).
    Greedy,
    /// softmax(logits / temperature) sampling.
    Temperature(f32),
    /// top-k filter then temperature sampling.
    TopK { k: usize, temperature: f32 },
}

/// Sample one token id from a logits row.
pub fn sample(logits: &[f32], mode: Sampling, rng: &mut Pcg) -> i32 {
    match mode {
        Sampling::Greedy => argmax(logits),
        Sampling::Temperature(t) => {
            let probs = softmax_scaled(logits, t);
            pick(&probs, rng)
        }
        Sampling::TopK { k, temperature } => {
            let k = k.clamp(1, logits.len());
            // indices of the k largest logits
            let mut idx: Vec<usize> = (0..logits.len()).collect();
            idx.select_nth_unstable_by(k - 1, |&a, &b| {
                logits[b].partial_cmp(&logits[a]).unwrap()
            });
            idx.truncate(k);
            let sub: Vec<f32> = idx.iter().map(|&i| logits[i]).collect();
            let probs = softmax_scaled(&sub, temperature);
            idx[pick(&probs, rng) as usize] as i32
        }
    }
}

/// argmax with deterministic tie-break (lowest index).
pub fn argmax(logits: &[f32]) -> i32 {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &v) in logits.iter().enumerate() {
        if v > best_v {
            best_v = v;
            best = i;
        }
    }
    best as i32
}

fn softmax_scaled(logits: &[f32], temperature: f32) -> Vec<f32> {
    let t = temperature.max(1e-6);
    let m = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mut e: Vec<f32> = logits.iter().map(|&x| ((x - m) / t).exp()).collect();
    let s: f32 = e.iter().sum();
    for v in &mut e {
        *v /= s;
    }
    e
}

fn pick(probs: &[f32], rng: &mut Pcg) -> i32 {
    let x = rng.f32();
    let mut acc = 0.0;
    for (i, &p) in probs.iter().enumerate() {
        acc += p;
        if x < acc {
            return i as i32;
        }
    }
    (probs.len() - 1) as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_is_argmax_with_stable_ties() {
        let l = [0.0, 3.0, 3.0, -1.0];
        let mut rng = Pcg::new(0);
        assert_eq!(sample(&l, Sampling::Greedy, &mut rng), 1);
    }

    #[test]
    fn zero_temperature_degenerates_to_argmax() {
        let l = [0.1, 5.0, -2.0];
        let mut rng = Pcg::new(1);
        for _ in 0..50 {
            assert_eq!(sample(&l, Sampling::Temperature(1e-9), &mut rng), 1);
        }
    }

    #[test]
    fn temperature_sampling_follows_distribution() {
        let l = [0.0f32, (2.0f32).ln()]; // probs 1/3, 2/3 at T=1
        let mut rng = Pcg::new(2);
        let n = 30_000;
        let mut ones = 0;
        for _ in 0..n {
            if sample(&l, Sampling::Temperature(1.0), &mut rng) == 1 {
                ones += 1;
            }
        }
        let frac = ones as f64 / n as f64;
        assert!((frac - 2.0 / 3.0).abs() < 0.02, "{frac}");
    }

    #[test]
    fn topk_restricts_support() {
        let l = [0.0, 10.0, 9.0, -5.0, 8.0];
        let mut rng = Pcg::new(3);
        for _ in 0..200 {
            let t = sample(&l, Sampling::TopK { k: 2, temperature: 1.0 }, &mut rng);
            assert!(t == 1 || t == 2, "sampled {t} outside top-2");
        }
    }

    #[test]
    fn topk_k1_is_greedy() {
        let l = [1.0, 0.5, 2.0];
        let mut rng = Pcg::new(4);
        assert_eq!(
            sample(&l, Sampling::TopK { k: 1, temperature: 1.0 }, &mut rng),
            2
        );
    }
}
