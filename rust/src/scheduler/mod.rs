//! Continuous batching + chunked prefill scheduler (the vLLM-role core).
//!
//! Every step packs, into one token bucket:
//! 1. one token per *decoding* sequence (decode keeps priority so TPOT
//!    stays flat — the Sarathi/vLLM hybrid-batch rule), then
//! 2. chunked prefill tokens of admitted sequences, FCFS, up to
//!    `chunk` tokens per sequence per step.
//!
//! New sequences are admitted while the sequence and KV-block budgets
//! hold (conservative reservation: blocks for prompt + max_new). The
//! budget is *physical*: with the paged KV cache
//! ([`crate::kvcache::PagedKvCache`]), prompt blocks already resident
//! for another live request are shared instead of re-reserved, so
//! admitted concurrency grows with prefix overlap — and the cached
//! prefix is adopted at admission (`attach_prefix`), so prefill skips
//! it entirely (the TTFT win). Tokens of requests for different ESFT
//! adapters are freely interleaved — the batch carries the per-token
//! AID array the rerouting kernel consumes (token-granularity batching,
//! paper section 4.3).
//!
//! ## The step workspace (zero-allocation hot path)
//!
//! [`Scheduler::build_batch`] does not allocate: it refills a
//! caller-owned [`StepWorkspace`] in place. The workspace owns the
//! [`StepInputs`] tensors (bucket-sized arrays re-padded per step;
//! `cache_seg`/`cache_pos` are `kv_cap`-sized, persistent, and updated
//! per *dirty slot* — O(tokens touched) per step instead of O(kv_cap)
//! clones), the out-row bindings, and the planning/slot scratch buffers.
//! One workspace lives for the whole serving session, so the
//! steady-state decode loop performs zero heap allocations end to end
//! (asserted by `tests/hotpath_alloc.rs` under the `alloc-counter`
//! feature).

use crate::kvcache::{CowCopy, PagedKvCache};
use crate::runtime::engine::StepInputs;
use crate::sampler::{FinishReason, SamplerBank, SamplingParams};
use anyhow::{bail, Result};
use std::collections::VecDeque;
use std::time::Instant;

/// Scheduler limits (derived from the artifact ABI + engine policy).
#[derive(Debug, Clone)]
pub struct SchedConfig {
    /// Max concurrently running sequences (≤ artifact `max_seqs`;
    /// engine policy may cap it below the ABI).
    pub max_seqs: usize,
    /// The model's `max_seqs` as compiled into the step executables.
    /// Determines the `out_rows` tensor length, which must match the
    /// ABI even when `max_seqs` is policy-capped lower.
    pub abi_max_seqs: usize,
    /// Max prefill tokens per sequence per step (chunked prefill).
    pub chunk: usize,
    /// Token buckets, ascending (from the artifact set).
    pub buckets: Vec<usize>,
    /// KV slot-pool size CAP.
    pub kv_cap: usize,
}

impl SchedConfig {
    /// Logits rows available for a bucket (mirrors the ABI: the
    /// executables are compiled against the config's `max_seqs`, not
    /// the engine's possibly-lower admission cap).
    pub fn out_rows(&self, bucket: usize) -> usize {
        bucket.min(self.abi_max_seqs)
    }

    pub fn max_bucket(&self) -> usize {
        *self.buckets.last().unwrap()
    }
}

/// Segment id carried to the device for a sequence (the ABI is `i32`:
/// the id's low 31 bits). The projection is only unique among
/// *concurrently running* sequences — admission refuses to co-schedule
/// two sequences whose projections collide, which becomes possible once
/// sequence ids wrap past 2^31 (see [`Scheduler`]'s admit loop).
pub fn seg_of(id: u64) -> i32 {
    (id & 0x7fff_ffff) as i32
}

/// One sequence moving through the engine.
#[derive(Debug, Clone)]
pub struct SeqState {
    pub id: u64,
    /// End-to-end trace id (0 = none). Set at submit from
    /// [`crate::serving::ServeRequest::trace`]; carried into the
    /// request's [`crate::obs::trace::RequestSpan`] so replica-local
    /// spans join the fleet-wide timeline.
    pub trace: u64,
    /// Adapter ID for rerouting (-1 = base model).
    pub aid: i32,
    pub adapter: Option<String>,
    /// prompt ++ generated tokens.
    pub tokens: Vec<i32>,
    pub prompt_len: usize,
    /// How many of `tokens` are already in the KV cache.
    pub prefilled: usize,
    pub max_new: usize,
    pub sampling: SamplingParams,
    /// `sampling.needs_logits()` captured at construction so batch build
    /// copies a bool instead of re-walking the params per row per step.
    pub needs_logits: bool,
    /// Sampler-bank slot held while running ([`StepWorkspace::samplers`]);
    /// acquired at admission, released with the KV blocks.
    pub sampler_slot: Option<usize>,
    /// Why the sequence finished. `Length` until a stop sequence / stop
    /// token match marks it `Stop` (see [`Scheduler::mark_stop`]).
    pub finish: FinishReason,
    pub arrival: Instant,
    /// Absolute completion deadline; past it the sequence is expired by
    /// [`Scheduler::expire_deadlines`] (queued sequences are dropped
    /// before ever occupying a batch slot).
    pub deadline: Option<Instant>,
    /// When the scheduler moved the sequence from waiting to running
    /// (the queued → admitted phase edge; see `docs/OBSERVABILITY.md`).
    pub admitted_at: Option<Instant>,
    /// When the sequence's tokens were first packed into a batch.
    pub first_scheduled_at: Option<Instant>,
    /// When the last prompt chunk was fed (decode phase begins).
    pub prefill_done_at: Option<Instant>,
    pub first_token_at: Option<Instant>,
    pub finished_at: Option<Instant>,
}

impl SeqState {
    pub fn new(
        id: u64,
        aid: i32,
        adapter: Option<String>,
        prompt: Vec<i32>,
        max_new: usize,
        sampling: SamplingParams,
    ) -> Self {
        let prompt_len = prompt.len();
        // pre-size for the whole lifetime so per-step token pushes never
        // reallocate on the decode hot path
        let mut tokens = prompt;
        tokens.reserve(max_new);
        let needs_logits = sampling.needs_logits();
        SeqState {
            id,
            trace: 0,
            aid,
            adapter,
            tokens,
            prompt_len,
            prefilled: 0,
            max_new,
            sampling,
            needs_logits,
            sampler_slot: None,
            finish: FinishReason::Length,
            arrival: Instant::now(),
            deadline: None,
            admitted_at: None,
            first_scheduled_at: None,
            prefill_done_at: None,
            first_token_at: None,
            finished_at: None,
        }
    }

    /// Tokens not yet fed to the model.
    pub fn pending(&self) -> usize {
        self.tokens.len() - self.prefilled
    }

    pub fn generated(&self) -> usize {
        self.tokens.len() - self.prompt_len
    }

    pub fn done(&self) -> bool {
        self.finish == FinishReason::Stop || self.generated() >= self.max_new
    }

    /// In pure decode phase (prompt fully prefilled)?
    pub fn decoding(&self) -> bool {
        self.prefilled >= self.prompt_len
    }
}

/// Summary of one packed step batch. The batch tensors themselves live
/// in the [`StepWorkspace`] the batch was built into.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Batch {
    pub bucket: usize,
    /// Logits rows the ABI returns for this bucket.
    pub out_rows: usize,
    pub prefill_tokens: usize,
    pub decode_tokens: usize,
}

/// Binding of one live logits row to the sequence it must be sampled
/// for (the row points at the sequence's last scheduled token).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OutRow {
    /// Index into `StepInputs::out_rows` / the logits block.
    pub row: usize,
    /// Sequence id to push the sampled token to.
    pub seq: u64,
    /// The sequence's index in the scheduler's running list at batch
    /// build. Valid until the running list next mutates (reap / cancel /
    /// expire) — i.e. for the whole per-step sampling loop — so the
    /// engine's `*_at` lookups are O(1) instead of scanning the running
    /// list per row (`seq` double-checks against staleness).
    pub ridx: u32,
    /// The sequence's adapter id (-1 = base), captured at batch build so
    /// the engine attributes sampled tokens to adapters without
    /// re-scanning the running list (per-adapter obs counters).
    pub aid: i32,
    /// The sequence's slot in [`StepWorkspace::samplers`] (per-request
    /// PRNG, penalty counts, stop cursor).
    pub sampler: u32,
    /// Whether this row's request needs materialized logits (sampled, or
    /// greedy with penalties/bias). When no row does, the backend may
    /// skip logits entirely (the O(1) greedy fast path).
    pub needs_logits: bool,
}

/// Persistent, engine-owned buffers of the step hot path.
///
/// One instance lives for a whole serving session; `build_batch` refills
/// it in place so the steady-state loop allocates nothing. `cache_seg` /
/// `cache_pos` inside [`StepWorkspace::inputs`] are the *authoritative*
/// per-slot cache metadata mirrored to the device each step — they are
/// updated incrementally (dirty slots only) by batch builds and by
/// sequence release (reap/cancel/expire), never rebuilt or cloned.
#[derive(Debug, Clone)]
pub struct StepWorkspace {
    /// The packed step tensors, refilled per batch.
    pub inputs: StepInputs,
    /// Live out-row bindings of the current batch.
    pub rows: Vec<OutRow>,
    /// Per-request sampler state (PRNG, penalty token-count table,
    /// stop-sequence cursor) plus shared sort/prob scratch. Slots are
    /// acquired at admission and recycled on release, so the sampled
    /// decode path allocates nothing mid-step.
    pub samplers: SamplerBank,
    /// Scratch: (running-seq index, tokens this step).
    plan: Vec<(usize, usize)>,
    /// Scratch for KV slot allocation.
    slots: Vec<u32>,
    /// Scratch for physically-freed slots reported by `decref_seq`
    /// (release only clears metadata of slots whose block refcount hit
    /// zero — shared blocks stay live for their other sequences).
    freed: Vec<u32>,
    /// Scratch for pending copy-on-write records drained per alloc.
    copies: Vec<CowCopy>,
}

impl StepWorkspace {
    /// `vocab` sizes the sampler bank's penalty tables and sort scratch
    /// (the model's logits width).
    pub fn new(cfg: &SchedConfig, vocab: usize) -> Self {
        let max_bucket = cfg.max_bucket();
        let max_rows = cfg.out_rows(max_bucket);
        StepWorkspace {
            inputs: StepInputs {
                token_ids: Vec::with_capacity(max_bucket),
                positions: Vec::with_capacity(max_bucket),
                seg_ids: Vec::with_capacity(max_bucket),
                slot_idx: Vec::with_capacity(max_bucket),
                cache_seg: vec![-1; cfg.kv_cap],
                cache_pos: vec![0; cfg.kv_cap],
                out_rows: Vec::with_capacity(max_rows),
                aid: Vec::with_capacity(max_bucket),
            },
            rows: Vec::with_capacity(max_rows),
            samplers: SamplerBank::new(cfg.max_seqs, vocab),
            plan: Vec::with_capacity(cfg.max_seqs.min(max_rows.max(16))),
            slots: Vec::with_capacity(cfg.chunk.min(max_bucket)),
            freed: Vec::with_capacity(cfg.kv_cap),
            copies: Vec::with_capacity(32),
        }
    }

    /// Every live row of the current batch is plain greedy — no sampled
    /// request, no penalties, no logit bias — so the backend may skip
    /// materializing logits entirely (the O(1) fast path).
    pub fn all_greedy(&self) -> bool {
        self.rows.iter().all(|r| !r.needs_logits)
    }
}

/// The continuous-batching scheduler.
#[derive(Debug, Clone)]
pub struct Scheduler {
    cfg: SchedConfig,
    waiting: VecDeque<SeqState>,
    running: Vec<SeqState>,
}

impl Scheduler {
    pub fn new(cfg: SchedConfig) -> Self {
        assert!(!cfg.buckets.is_empty());
        assert!(cfg.chunk > 0);
        Scheduler { cfg, waiting: VecDeque::new(), running: Vec::new() }
    }

    pub fn config(&self) -> &SchedConfig {
        &self.cfg
    }

    /// Clone a scheduler's config (engine session reset).
    pub fn rebuild_config(s: &Scheduler) -> SchedConfig {
        s.cfg.clone()
    }

    pub fn submit(&mut self, seq: SeqState) {
        self.waiting.push_back(seq);
    }

    pub fn waiting_len(&self) -> usize {
        self.waiting.len()
    }

    pub fn running_len(&self) -> usize {
        self.running.len()
    }

    pub fn is_idle(&self) -> bool {
        self.waiting.is_empty() && self.running.is_empty()
    }

    pub fn running(&self) -> &[SeqState] {
        &self.running
    }

    /// Is any queued or running sequence carrying a deadline? (The
    /// engine skips the per-step expiry scan when this is false.)
    pub fn deadline_work(&self) -> bool {
        self.waiting
            .iter()
            .chain(self.running.iter())
            .any(|s| s.deadline.is_some())
    }

    /// Queued + running sequences bound to adapter `name` (the engine
    /// refuses to evict an adapter while this is non-zero).
    pub fn adapter_work(&self, name: &str) -> usize {
        self.waiting
            .iter()
            .chain(self.running.iter())
            .filter(|s| s.adapter.as_deref() == Some(name))
            .count()
    }

    fn admit(&mut self, kv: &mut PagedKvCache, ws: &mut StepWorkspace) {
        if self.waiting.is_empty() {
            return;
        }
        // conservative reservation: the physical blocks every running
        // sequence may still need to finish (pending prompt + remaining
        // output, block-granular, +1 for a pending tail copy-on-write)
        // are already spoken for (no preemption)
        let mut reserved: usize = self
            .running
            .iter()
            .map(|s| kv.future_blocks(s.id, s.prompt_len + s.max_new))
            .sum();
        while self.running.len() < self.cfg.max_seqs {
            let Some(seq) = self.waiting.front() else { break };
            // seg-id safety: the attention kernel isolates sequences by
            // the 31-bit projection of their id, so two live sequences
            // must never share it. Hold the collider at the queue head
            // (FCFS order preserved) until the resident one finishes.
            let seg = seg_of(seq.id);
            if self.running.iter().any(|r| seg_of(r.id) == seg) {
                break;
            }
            // logical vs physical admission: the sequence only needs
            // fresh physical blocks for the part of its footprint that
            // is not already resident in a *live* sequence's shared
            // prefix (refcount-0 cached blocks and partial tails still
            // draw on the free pool, so they are not discounted).
            let final_len = seq.tokens.len() + seq.max_new;
            let limit = seq.prompt_len.saturating_sub(1);
            let (cached, live_full) = kv.probe_prefix(&seq.tokens, seq.aid, limit);
            let need = kv.blocks_for(final_len).saturating_sub(live_full);
            if kv.free_blocks() < reserved + need {
                break;
            }
            reserved += need;
            let mut seq = self.waiting.pop_front().unwrap();
            seq.admitted_at = Some(Instant::now());
            // attach per-request sampler state: the bank has exactly
            // max_seqs slots, so admission can never exhaust it. The seed
            // is resolved at submit (engine); the id fallback keeps raw
            // scheduler use deterministic.
            let seed = seq.sampling.seed.unwrap_or(seq.id);
            seq.sampler_slot =
                Some(ws.samplers.acquire(seed, &seq.tokens[..seq.prompt_len]));
            // pre-size the block table so decode-path allocs never grow it
            kv.reserve_seq(seq.id, final_len, seq.aid);
            // adopt the cached prefix: those tokens are already resident,
            // so prefill skips them entirely (the prefix-cache TTFT win)
            let attached = kv.attach_prefix(seq.id, &seq.tokens, seq.aid, limit);
            debug_assert_eq!(attached, cached, "probe and attach must agree");
            if attached > 0 {
                seq.prefilled = attached;
                // stamp the adopted slots' device-visible metadata with
                // the attaching sequence's seg (most-recent-attacher
                // convention; see the kvcache::paged module docs)
                let bs = kv.block_size();
                let blocks = kv.blocks_of(seq.id).expect("attached seq has a table");
                for p in 0..attached {
                    let slot = blocks[p / bs] as usize * bs + p % bs;
                    ws.inputs.cache_seg[slot] = seg;
                    ws.inputs.cache_pos[slot] = p as i32;
                }
            }
            self.running.push(seq);
        }
    }

    /// Build the next batch into `ws`, allocating KV slots and updating
    /// the workspace's persistent `cache_seg`/`cache_pos` in place.
    /// Returns `None` when nothing is runnable. Performs no heap
    /// allocation once the workspace buffers have reached steady-state
    /// capacity.
    pub fn build_batch(
        &mut self,
        kv: &mut PagedKvCache,
        ws: &mut StepWorkspace,
    ) -> Result<Option<Batch>> {
        self.admit(kv, ws);
        ws.rows.clear();
        if self.running.is_empty() {
            return Ok(None);
        }
        debug_assert!(
            self.running.iter().enumerate().all(|(i, a)| {
                self.running[..i].iter().all(|b| seg_of(a.id) != seg_of(b.id))
            }),
            "duplicate seg ids among running sequences"
        );
        let budget = self.cfg.max_bucket();
        let StepWorkspace { inputs, rows, plan, slots, copies, .. } = ws;
        plan.clear();
        let mut total = 0usize;

        // decode first: one token each
        for (i, s) in self.running.iter().enumerate() {
            if s.decoding() && total < budget {
                debug_assert_eq!(s.pending(), 1);
                plan.push((i, 1));
                total += 1;
            }
        }
        // then chunked prefill, FCFS over running order
        for (i, s) in self.running.iter().enumerate() {
            if !s.decoding() && total < budget {
                let take = s.pending().min(self.cfg.chunk).min(budget - total);
                if take > 0 {
                    plan.push((i, take));
                    total += take;
                }
            }
        }
        if total == 0 {
            return Ok(None);
        }
        let Some(&bucket) = self.cfg.buckets.iter().find(|&&b| b >= total) else {
            bail!("no bucket fits {total} tokens (buckets {:?})", self.cfg.buckets);
        };
        let out_rows = self.cfg.out_rows(bucket);

        // re-pad the reusable bucket-sized tensors in place (clear +
        // resize = fill; no allocation once capacity is established).
        // cache_seg/cache_pos are persistent: only dirty slots below.
        inputs.token_ids.clear();
        inputs.token_ids.resize(bucket, 0);
        inputs.positions.clear();
        inputs.positions.resize(bucket, 0);
        inputs.seg_ids.clear();
        inputs.seg_ids.resize(bucket, -1);
        inputs.slot_idx.clear();
        inputs.slot_idx.resize(bucket, self.cfg.kv_cap as i32);
        inputs.aid.clear();
        inputs.aid.resize(bucket, -1);
        inputs.out_rows.clear();
        inputs.out_rows.resize(out_rows, 0);

        let mut cursor = 0usize;
        let mut prefill_tokens = 0usize;
        let mut decode_tokens = 0usize;

        for &(si, take) in plan.iter() {
            let seq = &mut self.running[si];
            let start = seq.prefilled;
            // the token values feed the paged cache's rolling prefix
            // hash, so this sequence's blocks become matchable by
            // future requests with the same (adapter, prefix)
            kv.alloc_into(seq.id, seq.aid, &seq.tokens[start..start + take], slots)?;
            let seg = seg_of(seq.id);
            // appending into a block shared with another sequence moved
            // this sequence's tail to a private copy: re-stamp the
            // copied slots' metadata (host analogue of device copy_blocks)
            kv.drain_copies(copies);
            let bs = kv.block_size();
            for c in copies.iter() {
                let first = c.block_index as usize * bs;
                for j in 0..c.filled as usize {
                    let slot = c.dst_block as usize * bs + j;
                    inputs.cache_seg[slot] = seg;
                    inputs.cache_pos[slot] = (first + j) as i32;
                }
            }
            for (j, &slot) in slots.iter().enumerate() {
                let pos = (start + j) as i32;
                let t = cursor + j;
                inputs.token_ids[t] = seq.tokens[start + j];
                inputs.positions[t] = pos;
                inputs.seg_ids[t] = seg;
                inputs.slot_idx[t] = slot as i32;
                inputs.aid[t] = seq.aid;
                inputs.cache_seg[slot as usize] = seg;
                inputs.cache_pos[slot as usize] = pos;
            }
            if seq.decoding() {
                decode_tokens += take;
            } else {
                prefill_tokens += take;
            }
            seq.prefilled += take;
            // phase stamps: both are Some by steady-state decode, so the
            // hot path pays two is_none checks and no clock reads
            if seq.first_scheduled_at.is_none() {
                seq.first_scheduled_at = Some(Instant::now());
            }
            if seq.prefill_done_at.is_none() && seq.decoding() {
                seq.prefill_done_at = Some(Instant::now());
            }
            // this step consumed the whole backlog → its last row yields
            // the next token
            if seq.pending() == 0 {
                let row_idx = rows.len();
                if row_idx >= out_rows {
                    bail!("out_rows overflow: {row_idx} >= {out_rows}");
                }
                inputs.out_rows[row_idx] = (cursor + take - 1) as i32;
                rows.push(OutRow {
                    row: row_idx,
                    seq: seq.id,
                    ridx: si as u32,
                    aid: seq.aid,
                    sampler: seq.sampler_slot.expect("running seq holds a sampler slot")
                        as u32,
                    needs_logits: seq.needs_logits,
                });
            }
            cursor += take;
        }
        Ok(Some(Batch { bucket, out_rows, prefill_tokens, decode_tokens }))
    }

    /// Append a sampled token to a running sequence. Returns `true` when
    /// it was the sequence's *first* generated token (the TTFT edge —
    /// the engine emits [`crate::serving::TokenEvent::First`] on it).
    pub fn push_token(&mut self, seq_id: u64, token: i32) -> Result<bool> {
        let Some(seq) = self.running.iter_mut().find(|s| s.id == seq_id) else {
            bail!("push_token: unknown sequence {seq_id}");
        };
        seq.tokens.push(token);
        let first = seq.first_token_at.is_none();
        if first {
            seq.first_token_at = Some(Instant::now());
        }
        Ok(first)
    }

    /// Drop a sequence's KV block references and recycle its sampler
    /// slot. Only blocks whose refcount reaches zero are physically
    /// freed — shared prefix blocks stay resident for their surviving
    /// sequences — and only those slots get their device-visible
    /// metadata cleared.
    fn release(seq: &mut SeqState, kv: &mut PagedKvCache, ws: &mut StepWorkspace) {
        let StepWorkspace { inputs, freed, samplers, .. } = ws;
        if let Some(slot) = seq.sampler_slot.take() {
            samplers.release(slot);
        }
        kv.decref_seq(seq.id, freed);
        for &s in freed.iter() {
            inputs.cache_seg[s as usize] = -1;
            inputs.cache_pos[s as usize] = 0;
        }
    }

    /// Mark a running sequence finished with reason `stop` (stop sequence
    /// or stop token matched). It is collected by the next [`Self::reap`].
    pub fn mark_stop(&mut self, id: u64) {
        if let Some(seq) = self.running.iter_mut().find(|s| s.id == id) {
            seq.finish = FinishReason::Stop;
        }
    }

    /// A running sequence's sampling params (id-keyed linear scan; the
    /// step hot path uses [`Self::sampling_at`] instead).
    pub fn sampling(&self, id: u64) -> Option<&SamplingParams> {
        self.running.iter().find(|s| s.id == id).map(|s| &s.sampling)
    }

    /// Bind an [`OutRow`] back to its running sequence, panicking if the
    /// binding went stale (the running list mutated since batch build —
    /// a step-loop ordering bug, not a recoverable condition).
    fn at(&self, idx: usize, id: u64) -> &SeqState {
        let seq = &self.running[idx];
        assert_eq!(seq.id, id, "stale OutRow: running list mutated mid-step");
        seq
    }

    /// O(1) variant of [`Self::sampling`] keyed by [`OutRow::ridx`] + id.
    /// Only valid between the batch build and the next running-list
    /// mutation — exactly the engine's per-step sampling loop.
    pub fn sampling_at(&self, idx: usize, id: u64) -> &SamplingParams {
        &self.at(idx, id).sampling
    }

    /// O(1) variant of [`Self::mark_stop`] keyed by [`OutRow::ridx`] + id.
    pub fn mark_stop_at(&mut self, idx: usize, id: u64) {
        self.at(idx, id);
        self.running[idx].finish = FinishReason::Stop;
    }

    /// O(1) variant of [`Self::push_token`] keyed by [`OutRow::ridx`] +
    /// id; same TTFT-edge return.
    pub fn push_token_at(&mut self, idx: usize, id: u64, token: i32) -> bool {
        self.at(idx, id);
        let seq = &mut self.running[idx];
        seq.tokens.push(token);
        let first = seq.first_token_at.is_none();
        if first {
            seq.first_token_at = Some(Instant::now());
        }
        first
    }

    /// Remove finished sequences, freeing their KV slots; returns them.
    pub fn reap(&mut self, kv: &mut PagedKvCache, ws: &mut StepWorkspace) -> Vec<SeqState> {
        let mut out = Vec::new();
        let mut i = 0;
        while i < self.running.len() {
            if self.running[i].done() {
                let mut seq = self.running.swap_remove(i);
                seq.finished_at = Some(Instant::now());
                Self::release(&mut seq, kv, ws);
                out.push(seq);
            } else {
                i += 1;
            }
        }
        out
    }

    /// Remove a sequence wherever it is (queued or running), freeing any
    /// KV slots it holds. Returns it, or `None` if unknown (already
    /// finished, or never submitted).
    pub fn cancel(
        &mut self,
        id: u64,
        kv: &mut PagedKvCache,
        ws: &mut StepWorkspace,
    ) -> Option<SeqState> {
        if let Some(pos) = self.waiting.iter().position(|s| s.id == id) {
            return self.waiting.remove(pos);
        }
        if let Some(pos) = self.running.iter().position(|s| s.id == id) {
            let mut seq = self.running.swap_remove(pos);
            Self::release(&mut seq, kv, ws);
            return Some(seq);
        }
        None
    }

    /// Remove every sequence whose deadline is at or before `now`.
    /// Queued sequences are dropped without ever occupying a batch slot;
    /// running ones free their KV slots. The engine calls this ahead of
    /// each batch build so an expired request cannot be admitted.
    pub fn expire_deadlines(
        &mut self,
        now: Instant,
        kv: &mut PagedKvCache,
        ws: &mut StepWorkspace,
    ) -> Vec<SeqState> {
        let mut out = Vec::new();
        let mut i = 0;
        while i < self.waiting.len() {
            if self.waiting[i].deadline.is_some_and(|d| d <= now) {
                out.extend(self.waiting.remove(i));
            } else {
                i += 1;
            }
        }
        let mut i = 0;
        while i < self.running.len() {
            if self.running[i].deadline.is_some_and(|d| d <= now) {
                let mut seq = self.running.swap_remove(i);
                Self::release(&mut seq, kv, ws);
                out.push(seq);
            } else {
                i += 1;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> SchedConfig {
        SchedConfig { max_seqs: 4, abi_max_seqs: 4, chunk: 8, buckets: vec![4, 16], kv_cap: 64 }
    }

    fn seq(id: u64, prompt_len: usize, max_new: usize) -> SeqState {
        SeqState::new(
            id,
            -1,
            None,
            (0..prompt_len as i32).collect(),
            max_new,
            SamplingParams::greedy(),
        )
    }

    /// Flat-equivalent paged cache (1-slot blocks, sharing off): the
    /// scheduler behaviour tests pin the same numbers as the original
    /// flat allocator.
    fn flat_kv(cap: usize) -> PagedKvCache {
        PagedKvCache::new(cap, 1, false)
    }

    const VOCAB: usize = 64;

    fn setup() -> (Scheduler, PagedKvCache, StepWorkspace) {
        let c = cfg();
        (Scheduler::new(c.clone()), flat_kv(64), StepWorkspace::new(&c, VOCAB))
    }

    #[test]
    fn single_seq_prefill_then_decode() {
        let (mut s, mut kv, mut ws) = setup();
        s.submit(seq(1, 10, 2));
        // chunk=8: first step takes 8 prompt tokens, no rows
        let b = s.build_batch(&mut kv, &mut ws).unwrap().unwrap();
        assert_eq!(b.prefill_tokens, 8);
        assert_eq!(b.bucket, 16);
        assert!(ws.rows.is_empty());
        // second step: remaining 2 prompt tokens -> one row
        let b = s.build_batch(&mut kv, &mut ws).unwrap().unwrap();
        assert_eq!(b.prefill_tokens, 2);
        assert_eq!(b.bucket, 4);
        assert_eq!(ws.rows.len(), 1);
        assert_eq!(ws.inputs.out_rows[0], 1); // last of the 2 tokens
        s.push_token(1, 42).unwrap();
        // decode step: 1 token
        let b = s.build_batch(&mut kv, &mut ws).unwrap().unwrap();
        assert_eq!(b.decode_tokens, 1);
        assert_eq!(ws.inputs.token_ids[0], 42);
        assert_eq!(ws.inputs.positions[0], 10);
        s.push_token(1, 43).unwrap();
        let done = s.reap(&mut kv, &mut ws);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].tokens.len(), 12);
        assert_eq!(kv.used_slots(), 0);
        assert!(s.is_idle());
    }

    #[test]
    fn decode_has_priority_and_mixed_batches_pack() {
        let (mut s, mut kv, mut ws) = setup();
        s.submit(seq(1, 3, 4));
        let b = s.build_batch(&mut kv, &mut ws).unwrap().unwrap();
        assert_eq!(b.prefill_tokens, 3);
        assert_eq!(ws.rows.len(), 1);
        s.push_token(1, 9).unwrap();
        // now submit a long-prompt request; batch = 1 decode + prefill chunk
        s.submit(seq(2, 12, 1));
        let b = s.build_batch(&mut kv, &mut ws).unwrap().unwrap();
        assert_eq!(b.decode_tokens, 1);
        assert_eq!(b.prefill_tokens, 8);
        // decode token sits at index 0
        assert_eq!(ws.inputs.positions[0], 3);
        // seg ids differ per sequence
        assert_ne!(ws.inputs.seg_ids[0], ws.inputs.seg_ids[1]);
    }

    #[test]
    fn admission_respects_max_seqs_and_kv_room() {
        let (mut s, mut kv, mut ws) = setup();
        for i in 0..6 {
            s.submit(seq(i, 4, 2));
        }
        let _ = s.build_batch(&mut kv, &mut ws).unwrap().unwrap();
        assert_eq!(s.running_len(), 4); // max_seqs
        assert_eq!(s.waiting_len(), 2);

        // KV-constrained admission: capacity 16, each seq reserves 6
        let c = SchedConfig { max_seqs: 64, abi_max_seqs: 64, kv_cap: 16, ..cfg() };
        let (mut s, mut kv, mut ws) =
            (Scheduler::new(c.clone()), flat_kv(16), StepWorkspace::new(&c, VOCAB));
        for i in 0..5 {
            s.submit(seq(i, 4, 2)); // needs 6 reserved
        }
        let _ = s.build_batch(&mut kv, &mut ws).unwrap().unwrap();
        assert_eq!(s.running_len(), 2, "16 slots / 6 per seq -> 2 admitted");
    }

    #[test]
    fn batch_arrays_are_consistent() {
        let (mut s, mut kv, mut ws) = setup();
        s.submit(seq(7, 5, 3));
        s.submit(seq(8, 2, 3));
        let b = s.build_batch(&mut kv, &mut ws).unwrap().unwrap();
        // every non-pad token has a valid slot; pads point out of range
        for t in 0..b.bucket {
            if ws.inputs.seg_ids[t] >= 0 {
                let slot = ws.inputs.slot_idx[t] as usize;
                assert!(slot < 64);
                assert_eq!(ws.inputs.cache_seg[slot], ws.inputs.seg_ids[t]);
                assert_eq!(ws.inputs.cache_pos[slot], ws.inputs.positions[t]);
            } else {
                assert_eq!(ws.inputs.slot_idx[t], 64);
            }
        }
        // rows reference in-batch positions and carry the sampling mode
        for r in &ws.rows {
            let t = ws.inputs.out_rows[r.row] as usize;
            assert!(t < b.bucket);
            assert!(ws.inputs.seg_ids[t] >= 0);
            assert!(!r.needs_logits);
        }
        assert!(ws.all_greedy());
    }

    #[test]
    fn adapter_work_counts_waiting_and_running() {
        let (mut s, mut kv, mut ws) = setup();
        let mut with = |id: u64, name: &str| {
            s.submit(SeqState::new(
                id,
                0,
                Some(name.to_string()),
                vec![1, 2, 3],
                2,
                SamplingParams::greedy(),
            ));
        };
        with(1, "math");
        with(2, "law");
        with(3, "math");
        assert_eq!(s.adapter_work("math"), 2);
        assert_eq!(s.adapter_work("law"), 1);
        assert_eq!(s.adapter_work("none"), 0);
        // admission moves them to running; counts must not change
        let _ = s.build_batch(&mut kv, &mut ws).unwrap();
        assert_eq!(s.adapter_work("math"), 2);
        assert_eq!(s.adapter_work("law"), 1);
    }

    #[test]
    fn cancel_frees_kv_wherever_the_seq_is() {
        let (mut s, mut kv, mut ws) = setup();
        s.submit(seq(1, 4, 8));
        // queued cancel: no KV held, just drops from waiting
        assert_eq!(s.cancel(1, &mut kv, &mut ws).unwrap().id, 1);
        assert!(s.is_idle());
        assert!(s.cancel(1, &mut kv, &mut ws).is_none(), "idempotent");

        // running cancel: KV slots must come back
        s.submit(seq(2, 4, 8));
        let _ = s.build_batch(&mut kv, &mut ws).unwrap().unwrap();
        assert!(kv.used_slots() > 0);
        let got = s.cancel(2, &mut kv, &mut ws).unwrap();
        assert_eq!(got.id, 2);
        assert_eq!(kv.used_slots(), 0);
        assert!(s.is_idle());
        // cleared slot metadata is device-consistent
        assert!(ws.inputs.cache_seg.iter().all(|&x| x == -1));
    }

    #[test]
    fn expired_deadline_never_reaches_a_batch() {
        let (mut s, mut kv, mut ws) = setup();
        let mut dead = seq(1, 4, 2);
        dead.deadline = Some(Instant::now() - std::time::Duration::from_millis(1));
        s.submit(dead);
        s.submit(seq(2, 4, 2));
        let expired = s.expire_deadlines(Instant::now(), &mut kv, &mut ws);
        assert_eq!(expired.len(), 1);
        assert_eq!(expired[0].id, 1);
        assert_eq!(expired[0].prefilled, 0, "expired while queued: no tokens fed");
        // the live sequence still runs
        let _ = s.build_batch(&mut kv, &mut ws).unwrap().unwrap();
        assert_eq!(ws.rows.len(), 1);
        assert_eq!(ws.rows[0].seq, 2);

        // a running sequence past deadline frees its KV on expiry
        let mut dead = seq(3, 4, 8);
        s.submit(seq(9, 2, 1));
        dead.deadline = Some(Instant::now());
        s.submit(dead);
        let _ = s.build_batch(&mut kv, &mut ws).unwrap();
        let used_before = kv.used_slots();
        let expired = s.expire_deadlines(Instant::now(), &mut kv, &mut ws);
        assert_eq!(expired.iter().filter(|e| e.id == 3).count(), 1);
        assert!(used_before > 0);
        assert!(kv.used_slots() < used_before);
    }

    #[test]
    fn shared_prefix_grows_admission_and_skips_prefill() {
        // 5 blocks of 4 slots; each request needs 3 blocks privately
        // (8-token prompt + 4 new = 12 tokens), so flat accounting fits
        // only one (2 * 12 = 24 > 20 slots). With sharing, the second
        // identical-prompt request discounts the live prompt block and
        // admits — and its prefill skips the 4 adopted tokens.
        let c = SchedConfig {
            max_seqs: 8,
            abi_max_seqs: 8,
            chunk: 8,
            buckets: vec![4, 16],
            kv_cap: 20,
        };
        let mut s = Scheduler::new(c.clone());
        let mut kv = PagedKvCache::new(20, 4, true);
        let mut ws = StepWorkspace::new(&c, VOCAB);
        let prompt: Vec<i32> = (100..108).collect();
        let req = |id: u64| {
            SeqState::new(id, 2, Some("math".into()), prompt.clone(), 4, SamplingParams::greedy())
        };
        s.submit(req(1));
        let _ = s.build_batch(&mut kv, &mut ws).unwrap().unwrap();
        s.push_token(1, 1).unwrap();
        s.submit(req(2));
        let b = s.build_batch(&mut kv, &mut ws).unwrap().unwrap();
        assert_eq!(s.running_len(), 2, "sharing must widen admission");
        assert_eq!(b.decode_tokens, 1);
        assert_eq!(
            b.prefill_tokens, 4,
            "the 4 adopted prefix tokens must not be re-prefilled"
        );
        assert_eq!(kv.prefix_hit_tokens(), 4);
        assert_eq!(kv.prefix_miss_tokens(), 4);
        assert_eq!(kv.shared_blocks(), 1);
        // the adopted slots were re-stamped for the attaching sequence
        for slot in 0..4 {
            assert_eq!(ws.inputs.cache_seg[slot], seg_of(2));
            assert_eq!(ws.inputs.cache_pos[slot], slot as i32);
        }
        // drain both; every block refcount must return to zero
        for _ in 0..32 {
            let seqs: Vec<u64> = ws.rows.iter().map(|r| r.seq).collect();
            for id in seqs {
                s.push_token(id, 7).unwrap();
            }
            s.reap(&mut kv, &mut ws);
            if s.build_batch(&mut kv, &mut ws).unwrap().is_none() {
                break;
            }
        }
        assert!(s.is_idle());
        assert_eq!(kv.used_slots(), 0);
        assert_eq!(kv.shared_blocks(), 0);
        assert!(ws.inputs.cache_seg.iter().all(|&x| x == -1));
    }

    #[test]
    fn push_token_reports_ttft_edge() {
        let (mut s, mut kv, mut ws) = setup();
        s.submit(seq(1, 2, 3));
        let _ = s.build_batch(&mut kv, &mut ws).unwrap().unwrap();
        assert!(s.push_token(1, 5).unwrap(), "first generated token");
        let _ = s.build_batch(&mut kv, &mut ws).unwrap().unwrap();
        assert!(!s.push_token(1, 6).unwrap(), "second token is not First");
    }

    #[test]
    fn colliding_seg_ids_are_never_co_scheduled() {
        let (mut s, mut kv, mut ws) = setup();
        let low = 5u64;
        let high = low + (1u64 << 31); // same 31-bit projection
        assert_eq!(seg_of(low), seg_of(high));
        s.submit(seq(low, 2, 1));
        s.submit(seq(high, 2, 1));
        let _ = s.build_batch(&mut kv, &mut ws).unwrap().unwrap();
        assert_eq!(s.running_len(), 1, "collider must wait");
        assert_eq!(s.waiting_len(), 1);
        assert_eq!(s.running()[0].id, low);
        // finish the first; the collider is then admitted
        s.push_token(low, 1).unwrap();
        s.reap(&mut kv, &mut ws);
        let _ = s.build_batch(&mut kv, &mut ws).unwrap().unwrap();
        assert_eq!(s.running_len(), 1);
        assert_eq!(s.running()[0].id, high);
        s.push_token(high, 1).unwrap();
        s.reap(&mut kv, &mut ws);
        assert!(s.is_idle());
        assert_eq!(kv.used_slots(), 0);
    }

    #[test]
    fn rows_capture_per_sequence_sampling() {
        let (mut s, mut kv, mut ws) = setup();
        s.submit(SeqState::new(
            1,
            -1,
            None,
            vec![0, 1],
            2,
            SamplingParams::temperature(0.7),
        ));
        s.submit(seq(2, 2, 2));
        let _ = s.build_batch(&mut kv, &mut ws).unwrap().unwrap();
        assert_eq!(ws.rows.len(), 2);
        assert!(!ws.all_greedy(), "one sampled row forces the logits path");
        let by_seq = |id: u64| *ws.rows.iter().find(|r| r.seq == id).unwrap();
        assert!(by_seq(1).needs_logits);
        assert!(!by_seq(2).needs_logits);
        // each running sequence holds a distinct sampler slot
        assert_ne!(by_seq(1).sampler, by_seq(2).sampler);
        assert_eq!(ws.samplers.in_use(), 2);
        // draining releases the slots back to the bank
        for _ in 0..4 {
            let ids: Vec<u64> = ws.rows.iter().map(|r| r.seq).collect();
            for id in ids {
                s.push_token(id, 1).unwrap();
            }
            s.reap(&mut kv, &mut ws);
            if s.build_batch(&mut kv, &mut ws).unwrap().is_none() {
                break;
            }
        }
        assert!(s.is_idle());
        assert_eq!(ws.samplers.in_use(), 0, "sampler slots must be recycled");
    }

    #[test]
    fn stop_marked_sequence_is_reaped_with_stop_reason() {
        let (mut s, mut kv, mut ws) = setup();
        s.submit(seq(1, 2, 8));
        let _ = s.build_batch(&mut kv, &mut ws).unwrap().unwrap();
        s.push_token(1, 5).unwrap();
        s.mark_stop(1);
        let done = s.reap(&mut kv, &mut ws);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].finish, FinishReason::Stop);
        assert_eq!(done[0].generated(), 1, "stopped well before max_new");
        assert_eq!(ws.samplers.in_use(), 0);
        assert_eq!(kv.used_slots(), 0);
    }

    #[test]
    fn phase_stamps_progress_in_order_and_rows_carry_aid() {
        let (mut s, mut kv, mut ws) = setup();
        let mut q = seq(1, 10, 2);
        q.aid = 3;
        s.submit(q);
        let r = |s: &Scheduler| s.running()[0].clone();
        // chunk=8: first build admits + schedules but prefill is partial
        let _ = s.build_batch(&mut kv, &mut ws).unwrap().unwrap();
        assert!(r(&s).admitted_at.is_some());
        assert!(r(&s).first_scheduled_at.is_some());
        assert!(r(&s).prefill_done_at.is_none(), "prompt not fully fed yet");
        // second build feeds the last chunk: prefill done, row emitted
        let _ = s.build_batch(&mut kv, &mut ws).unwrap().unwrap();
        assert!(r(&s).prefill_done_at.is_some());
        assert_eq!(ws.rows.len(), 1);
        assert_eq!(ws.rows[0].aid, 3, "rows carry the adapter id");
        s.push_token(1, 7).unwrap();
        let got = r(&s);
        let admitted = got.admitted_at.unwrap();
        let scheduled = got.first_scheduled_at.unwrap();
        let prefill_done = got.prefill_done_at.unwrap();
        let first_tok = got.first_token_at.unwrap();
        assert!(got.arrival <= admitted);
        assert!(admitted <= scheduled);
        assert!(scheduled <= prefill_done);
        assert!(prefill_done <= first_tok);
    }

    #[test]
    fn property_token_budget_and_row_capacity_hold() {
        crate::util::prop::check(707, 30, |rng| {
            let max_seqs = 1 + rng.below(6) as usize;
            let cfg = SchedConfig {
                max_seqs,
                abi_max_seqs: max_seqs,
                chunk: 1 + rng.below(12) as usize,
                buckets: vec![4, 16, 64],
                kv_cap: 256,
            };
            let mut s = Scheduler::new(cfg.clone());
            let mut kv = flat_kv(256);
            let mut ws = StepWorkspace::new(&cfg, VOCAB);
            let mut next_id = 0u64;
            for _ in 0..30 {
                if rng.below(2) == 0 {
                    next_id += 1;
                    s.submit(seq_with(next_id, 1 + rng.below(40) as usize, 1 + rng.below(5) as usize));
                }
                if let Some(b) = s.build_batch(&mut kv, &mut ws).unwrap() {
                    let used = b.prefill_tokens + b.decode_tokens;
                    assert!(used <= b.bucket);
                    assert!(b.bucket <= 64);
                    assert!(ws.rows.len() <= cfg.out_rows(b.bucket));
                    for r in &ws.rows {
                        s.push_token(r.seq, 1).unwrap();
                    }
                    s.reap(&mut kv, &mut ws);
                }
            }
            // drain: everything eventually terminates
            for _ in 0..500 {
                match s.build_batch(&mut kv, &mut ws).unwrap() {
                    Some(_) => {
                        for r in &ws.rows {
                            s.push_token(r.seq, 1).unwrap();
                        }
                        s.reap(&mut kv, &mut ws);
                    }
                    None => break,
                }
            }
            assert!(s.is_idle(), "scheduler must drain");
            assert_eq!(kv.used_slots(), 0);
            assert_eq!(ws.samplers.in_use(), 0, "sampler slots must drain too");
        });

        fn seq_with(id: u64, p: usize, n: usize) -> SeqState {
            SeqState::new(id, -1, None, (0..p as i32).collect(), n, SamplingParams::greedy())
        }
    }
}
