//! Continuous batching + chunked prefill scheduler (the vLLM-role core).
//!
//! Every step packs, into one token bucket:
//! 1. one token per *decoding* sequence (decode keeps priority so TPOT
//!    stays flat — the Sarathi/vLLM hybrid-batch rule), then
//! 2. chunked prefill tokens of admitted sequences, FCFS, up to
//!    `chunk` tokens per sequence per step.
//!
//! New sequences are admitted while the sequence and KV-slot budgets
//! hold (conservative reservation: prompt + max_new slots). Tokens of
//! requests for different ESFT adapters are freely interleaved — the
//! batch carries the per-token AID array the rerouting kernel consumes
//! (token-granularity batching, paper section 4.3).

use crate::kvcache::KvCache;
use crate::runtime::engine::StepInputs;
use crate::sampler::Sampling;
use anyhow::{bail, Result};
use std::collections::VecDeque;
use std::time::Instant;

/// Scheduler limits (derived from the artifact ABI + engine policy).
#[derive(Debug, Clone)]
pub struct SchedConfig {
    /// Max concurrently running sequences (≤ artifact `max_seqs`;
    /// engine policy may cap it below the ABI).
    pub max_seqs: usize,
    /// The model's `max_seqs` as compiled into the step executables.
    /// Determines the `out_rows` tensor length, which must match the
    /// ABI even when `max_seqs` is policy-capped lower.
    pub abi_max_seqs: usize,
    /// Max prefill tokens per sequence per step (chunked prefill).
    pub chunk: usize,
    /// Token buckets, ascending (from the artifact set).
    pub buckets: Vec<usize>,
    /// KV slot-pool size CAP.
    pub kv_cap: usize,
}

impl SchedConfig {
    /// Logits rows available for a bucket (mirrors the ABI: the
    /// executables are compiled against the config's `max_seqs`, not
    /// the engine's possibly-lower admission cap).
    pub fn out_rows(&self, bucket: usize) -> usize {
        bucket.min(self.abi_max_seqs)
    }

    pub fn max_bucket(&self) -> usize {
        *self.buckets.last().unwrap()
    }
}

/// One sequence moving through the engine.
#[derive(Debug, Clone)]
pub struct SeqState {
    pub id: u64,
    /// Adapter ID for rerouting (-1 = base model).
    pub aid: i32,
    pub adapter: Option<String>,
    /// prompt ++ generated tokens.
    pub tokens: Vec<i32>,
    pub prompt_len: usize,
    /// How many of `tokens` are already in the KV cache.
    pub prefilled: usize,
    pub max_new: usize,
    pub sampling: Sampling,
    pub arrival: Instant,
    /// Absolute completion deadline; past it the sequence is expired by
    /// [`Scheduler::expire_deadlines`] (queued sequences are dropped
    /// before ever occupying a batch slot).
    pub deadline: Option<Instant>,
    pub first_token_at: Option<Instant>,
    pub finished_at: Option<Instant>,
}

impl SeqState {
    pub fn new(
        id: u64,
        aid: i32,
        adapter: Option<String>,
        prompt: Vec<i32>,
        max_new: usize,
        sampling: Sampling,
    ) -> Self {
        let prompt_len = prompt.len();
        SeqState {
            id,
            aid,
            adapter,
            tokens: prompt,
            prompt_len,
            prefilled: 0,
            max_new,
            sampling,
            arrival: Instant::now(),
            deadline: None,
            first_token_at: None,
            finished_at: None,
        }
    }

    /// Tokens not yet fed to the model.
    pub fn pending(&self) -> usize {
        self.tokens.len() - self.prefilled
    }

    pub fn generated(&self) -> usize {
        self.tokens.len() - self.prompt_len
    }

    pub fn done(&self) -> bool {
        self.generated() >= self.max_new
    }

    /// In pure decode phase (prompt fully prefilled)?
    pub fn decoding(&self) -> bool {
        self.prefilled >= self.prompt_len
    }
}

/// A packed step batch plus the bookkeeping to apply its results.
#[derive(Debug)]
pub struct Batch {
    pub bucket: usize,
    pub inputs: StepInputs,
    /// `(out_row index, seq id)` — rows that must be sampled after the
    /// step (the row points at the sequence's last scheduled token).
    pub rows: Vec<(usize, u64)>,
    pub prefill_tokens: usize,
    pub decode_tokens: usize,
}

/// Per-slot cache metadata mirrored to the device each step
/// (`cache_seg` / `cache_pos` inputs of the step executable).
#[derive(Debug)]
pub struct SlotMeta {
    pub seg: Vec<i32>,
    pub pos: Vec<i32>,
}

impl SlotMeta {
    pub fn new(cap: usize) -> Self {
        SlotMeta { seg: vec![-1; cap], pos: vec![0; cap] }
    }

    pub fn clear_slots(&mut self, slots: &[u32]) {
        for &s in slots {
            self.seg[s as usize] = -1;
            self.pos[s as usize] = 0;
        }
    }
}

/// The continuous-batching scheduler.
#[derive(Debug)]
pub struct Scheduler {
    cfg: SchedConfig,
    waiting: VecDeque<SeqState>,
    running: Vec<SeqState>,
}

impl Scheduler {
    pub fn new(cfg: SchedConfig) -> Self {
        assert!(!cfg.buckets.is_empty());
        assert!(cfg.chunk > 0);
        Scheduler { cfg, waiting: VecDeque::new(), running: Vec::new() }
    }

    pub fn config(&self) -> &SchedConfig {
        &self.cfg
    }

    /// Clone a scheduler's config (engine session reset).
    pub fn rebuild_config(s: &Scheduler) -> SchedConfig {
        s.cfg.clone()
    }

    pub fn submit(&mut self, seq: SeqState) {
        self.waiting.push_back(seq);
    }

    pub fn waiting_len(&self) -> usize {
        self.waiting.len()
    }

    pub fn running_len(&self) -> usize {
        self.running.len()
    }

    pub fn is_idle(&self) -> bool {
        self.waiting.is_empty() && self.running.is_empty()
    }

    pub fn running(&self) -> &[SeqState] {
        &self.running
    }

    /// Is any queued or running sequence carrying a deadline? (The
    /// engine skips the per-step expiry scan when this is false.)
    pub fn deadline_work(&self) -> bool {
        self.waiting
            .iter()
            .chain(self.running.iter())
            .any(|s| s.deadline.is_some())
    }

    /// Queued + running sequences bound to adapter `name` (the engine
    /// refuses to evict an adapter while this is non-zero).
    pub fn adapter_work(&self, name: &str) -> usize {
        self.waiting
            .iter()
            .chain(self.running.iter())
            .filter(|s| s.adapter.as_deref() == Some(name))
            .count()
    }

    /// Upper bound on KV slots a sequence will still consume.
    fn future_need(seq: &SeqState) -> usize {
        seq.pending() + seq.max_new.saturating_sub(seq.generated())
    }

    fn admit(&mut self, kv: &KvCache) {
        // conservative reservation: pending prompt + remaining output of
        // every running sequence is already spoken for (no preemption)
        let mut reserved: usize =
            self.running.iter().map(Self::future_need).sum();
        while self.running.len() < self.cfg.max_seqs {
            let Some(seq) = self.waiting.front() else { break };
            let need = Self::future_need(seq);
            if kv.free_slots() < reserved + need {
                break;
            }
            reserved += need;
            let seq = self.waiting.pop_front().unwrap();
            self.running.push(seq);
        }
    }

    /// Build the next batch, allocating KV slots and updating `meta`.
    /// Returns `None` when nothing is runnable.
    pub fn build_batch(&mut self, kv: &mut KvCache, meta: &mut SlotMeta) -> Result<Option<Batch>> {
        self.admit(kv);
        if self.running.is_empty() {
            return Ok(None);
        }
        let budget = self.cfg.max_bucket();
        // (seq index, how many tokens this step)
        let mut plan: Vec<(usize, usize)> = Vec::new();
        let mut total = 0usize;

        // decode first: one token each
        for (i, s) in self.running.iter().enumerate() {
            if s.decoding() && total < budget {
                debug_assert_eq!(s.pending(), 1);
                plan.push((i, 1));
                total += 1;
            }
        }
        // then chunked prefill, FCFS over running order
        for (i, s) in self.running.iter().enumerate() {
            if !s.decoding() && total < budget {
                let take = s.pending().min(self.cfg.chunk).min(budget - total);
                if take > 0 {
                    plan.push((i, take));
                    total += take;
                }
            }
        }
        if total == 0 {
            return Ok(None);
        }
        let Some(&bucket) = self.cfg.buckets.iter().find(|&&b| b >= total) else {
            bail!("no bucket fits {total} tokens (buckets {:?})", self.cfg.buckets);
        };
        let out_rows = self.cfg.out_rows(bucket);

        let mut inputs = StepInputs {
            token_ids: vec![0; bucket],
            positions: vec![0; bucket],
            seg_ids: vec![-1; bucket],
            slot_idx: vec![self.cfg.kv_cap as i32; bucket],
            cache_seg: Vec::new(),
            cache_pos: Vec::new(),
            out_rows: vec![0; out_rows],
            aid: vec![-1; bucket],
        };
        let mut rows: Vec<(usize, u64)> = Vec::new();
        let mut cursor = 0usize;
        let mut prefill_tokens = 0usize;
        let mut decode_tokens = 0usize;

        for &(si, take) in &plan {
            let seq = &mut self.running[si];
            let start = seq.prefilled;
            let slots = kv.alloc(seq.id, take)?;
            let seg = (seq.id & 0x7fff_ffff) as i32;
            for (j, &slot) in slots.iter().enumerate() {
                let pos = (start + j) as i32;
                let t = cursor + j;
                inputs.token_ids[t] = seq.tokens[start + j];
                inputs.positions[t] = pos;
                inputs.seg_ids[t] = seg;
                inputs.slot_idx[t] = slot as i32;
                inputs.aid[t] = seq.aid;
                meta.seg[slot as usize] = seg;
                meta.pos[slot as usize] = pos;
            }
            if seq.decoding() {
                decode_tokens += take;
            } else {
                prefill_tokens += take;
            }
            seq.prefilled += take;
            // this step consumed the whole backlog → its last row yields
            // the next token
            if seq.pending() == 0 {
                let row_idx = rows.len();
                if row_idx >= out_rows {
                    bail!("out_rows overflow: {row_idx} >= {out_rows}");
                }
                inputs.out_rows[row_idx] = (cursor + take - 1) as i32;
                rows.push((row_idx, seq.id));
            }
            cursor += take;
        }
        inputs.cache_seg = meta.seg.clone();
        inputs.cache_pos = meta.pos.clone();
        Ok(Some(Batch { bucket, inputs, rows, prefill_tokens, decode_tokens }))
    }

    /// Append a sampled token to a running sequence. Returns `true` when
    /// it was the sequence's *first* generated token (the TTFT edge —
    /// the engine emits [`crate::serving::TokenEvent::First`] on it).
    pub fn push_token(&mut self, seq_id: u64, token: i32) -> Result<bool> {
        let Some(seq) = self.running.iter_mut().find(|s| s.id == seq_id) else {
            bail!("push_token: unknown sequence {seq_id}");
        };
        seq.tokens.push(token);
        let first = seq.first_token_at.is_none();
        if first {
            seq.first_token_at = Some(Instant::now());
        }
        Ok(first)
    }

    /// Free a sequence's KV slots and clear its device-visible metadata.
    fn release(seq: &SeqState, kv: &mut KvCache, meta: &mut SlotMeta) {
        if let Some(slots) = kv.slots_of(seq.id) {
            let slots = slots.to_vec();
            meta.clear_slots(&slots);
        }
        kv.free_seq(seq.id);
    }

    /// Remove finished sequences, freeing their KV slots; returns them.
    pub fn reap(&mut self, kv: &mut KvCache, meta: &mut SlotMeta) -> Vec<SeqState> {
        let mut out = Vec::new();
        let mut i = 0;
        while i < self.running.len() {
            if self.running[i].done() {
                let mut seq = self.running.swap_remove(i);
                seq.finished_at = Some(Instant::now());
                Self::release(&seq, kv, meta);
                out.push(seq);
            } else {
                i += 1;
            }
        }
        out
    }

    /// Remove a sequence wherever it is (queued or running), freeing any
    /// KV slots it holds. Returns it, or `None` if unknown (already
    /// finished, or never submitted).
    pub fn cancel(&mut self, id: u64, kv: &mut KvCache, meta: &mut SlotMeta) -> Option<SeqState> {
        if let Some(pos) = self.waiting.iter().position(|s| s.id == id) {
            return self.waiting.remove(pos);
        }
        if let Some(pos) = self.running.iter().position(|s| s.id == id) {
            let seq = self.running.swap_remove(pos);
            Self::release(&seq, kv, meta);
            return Some(seq);
        }
        None
    }

    /// Remove every sequence whose deadline is at or before `now`.
    /// Queued sequences are dropped without ever occupying a batch slot;
    /// running ones free their KV slots. The engine calls this ahead of
    /// each batch build so an expired request cannot be admitted.
    pub fn expire_deadlines(
        &mut self,
        now: Instant,
        kv: &mut KvCache,
        meta: &mut SlotMeta,
    ) -> Vec<SeqState> {
        let mut out = Vec::new();
        let mut i = 0;
        while i < self.waiting.len() {
            if self.waiting[i].deadline.is_some_and(|d| d <= now) {
                out.extend(self.waiting.remove(i));
            } else {
                i += 1;
            }
        }
        let mut i = 0;
        while i < self.running.len() {
            if self.running[i].deadline.is_some_and(|d| d <= now) {
                let seq = self.running.swap_remove(i);
                Self::release(&seq, kv, meta);
                out.push(seq);
            } else {
                i += 1;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> SchedConfig {
        SchedConfig { max_seqs: 4, abi_max_seqs: 4, chunk: 8, buckets: vec![4, 16], kv_cap: 64 }
    }

    fn seq(id: u64, prompt_len: usize, max_new: usize) -> SeqState {
        SeqState::new(
            id,
            -1,
            None,
            (0..prompt_len as i32).collect(),
            max_new,
            Sampling::Greedy,
        )
    }

    fn setup() -> (Scheduler, KvCache, SlotMeta) {
        (Scheduler::new(cfg()), KvCache::new(64), SlotMeta::new(64))
    }

    #[test]
    fn single_seq_prefill_then_decode() {
        let (mut s, mut kv, mut meta) = setup();
        s.submit(seq(1, 10, 2));
        // chunk=8: first step takes 8 prompt tokens, no rows
        let b = s.build_batch(&mut kv, &mut meta).unwrap().unwrap();
        assert_eq!(b.prefill_tokens, 8);
        assert_eq!(b.bucket, 16);
        assert!(b.rows.is_empty());
        // second step: remaining 2 prompt tokens -> one row
        let b = s.build_batch(&mut kv, &mut meta).unwrap().unwrap();
        assert_eq!(b.prefill_tokens, 2);
        assert_eq!(b.bucket, 4);
        assert_eq!(b.rows.len(), 1);
        assert_eq!(b.inputs.out_rows[0], 1); // last of the 2 tokens
        s.push_token(1, 42).unwrap();
        // decode step: 1 token
        let b = s.build_batch(&mut kv, &mut meta).unwrap().unwrap();
        assert_eq!(b.decode_tokens, 1);
        assert_eq!(b.inputs.token_ids[0], 42);
        assert_eq!(b.inputs.positions[0], 10);
        s.push_token(1, 43).unwrap();
        let done = s.reap(&mut kv, &mut meta);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].tokens.len(), 12);
        assert_eq!(kv.used_slots(), 0);
        assert!(s.is_idle());
    }

    #[test]
    fn decode_has_priority_and_mixed_batches_pack() {
        let (mut s, mut kv, mut meta) = setup();
        s.submit(seq(1, 3, 4));
        let b = s.build_batch(&mut kv, &mut meta).unwrap().unwrap();
        assert_eq!(b.rows.len(), 1);
        s.push_token(1, 9).unwrap();
        // now submit a long-prompt request; batch = 1 decode + prefill chunk
        s.submit(seq(2, 12, 1));
        let b = s.build_batch(&mut kv, &mut meta).unwrap().unwrap();
        assert_eq!(b.decode_tokens, 1);
        assert_eq!(b.prefill_tokens, 8);
        // decode token sits at index 0
        assert_eq!(b.inputs.positions[0], 3);
        // seg ids differ per sequence
        assert_ne!(b.inputs.seg_ids[0], b.inputs.seg_ids[1]);
    }

    #[test]
    fn admission_respects_max_seqs_and_kv_room() {
        let (mut s, mut kv, mut meta) = setup();
        for i in 0..6 {
            s.submit(seq(i, 4, 2));
        }
        let _ = s.build_batch(&mut kv, &mut meta).unwrap().unwrap();
        assert_eq!(s.running_len(), 4); // max_seqs
        assert_eq!(s.waiting_len(), 2);

        // KV-constrained admission: capacity 64, each seq reserves 6
        let (mut s, mut kv, mut meta) = (
            Scheduler::new(SchedConfig { max_seqs: 64, abi_max_seqs: 64, ..cfg() }),
            KvCache::new(16),
            SlotMeta::new(16),
        );
        for i in 0..5 {
            s.submit(seq(i, 4, 2)); // needs 6 reserved
        }
        let _ = s.build_batch(&mut kv, &mut meta).unwrap().unwrap();
        assert_eq!(s.running_len(), 2, "16 slots / 6 per seq -> 2 admitted");
    }

    #[test]
    fn batch_arrays_are_consistent() {
        let (mut s, mut kv, mut meta) = setup();
        s.submit(seq(7, 5, 3));
        s.submit(seq(8, 2, 3));
        let b = s.build_batch(&mut kv, &mut meta).unwrap().unwrap();
        // every non-pad token has a valid slot; pads point out of range
        for t in 0..b.bucket {
            if b.inputs.seg_ids[t] >= 0 {
                let slot = b.inputs.slot_idx[t] as usize;
                assert!(slot < 64);
                assert_eq!(meta.seg[slot], b.inputs.seg_ids[t]);
                assert_eq!(meta.pos[slot], b.inputs.positions[t]);
            } else {
                assert_eq!(b.inputs.slot_idx[t], 64);
            }
        }
        // rows reference in-batch positions
        for &(row, _) in &b.rows {
            let r = b.inputs.out_rows[row] as usize;
            assert!(r < b.bucket);
            assert!(b.inputs.seg_ids[r] >= 0);
        }
    }

    #[test]
    fn adapter_work_counts_waiting_and_running() {
        let (mut s, mut kv, mut meta) = setup();
        let mut with = |id: u64, name: &str| {
            s.submit(SeqState::new(
                id,
                0,
                Some(name.to_string()),
                vec![1, 2, 3],
                2,
                Sampling::Greedy,
            ));
        };
        with(1, "math");
        with(2, "law");
        with(3, "math");
        assert_eq!(s.adapter_work("math"), 2);
        assert_eq!(s.adapter_work("law"), 1);
        assert_eq!(s.adapter_work("none"), 0);
        // admission moves them to running; counts must not change
        let _ = s.build_batch(&mut kv, &mut meta).unwrap();
        assert_eq!(s.adapter_work("math"), 2);
        assert_eq!(s.adapter_work("law"), 1);
    }

    #[test]
    fn cancel_frees_kv_wherever_the_seq_is() {
        let (mut s, mut kv, mut meta) = setup();
        s.submit(seq(1, 4, 8));
        // queued cancel: no KV held, just drops from waiting
        assert_eq!(s.cancel(1, &mut kv, &mut meta).unwrap().id, 1);
        assert!(s.is_idle());
        assert!(s.cancel(1, &mut kv, &mut meta).is_none(), "idempotent");

        // running cancel: KV slots must come back
        s.submit(seq(2, 4, 8));
        let _ = s.build_batch(&mut kv, &mut meta).unwrap().unwrap();
        assert!(kv.used_slots() > 0);
        let got = s.cancel(2, &mut kv, &mut meta).unwrap();
        assert_eq!(got.id, 2);
        assert_eq!(kv.used_slots(), 0);
        assert!(s.is_idle());
        // cleared slot metadata is device-consistent
        assert!(meta.seg.iter().all(|&x| x == -1));
    }

    #[test]
    fn expired_deadline_never_reaches_a_batch() {
        let (mut s, mut kv, mut meta) = setup();
        let mut dead = seq(1, 4, 2);
        dead.deadline = Some(Instant::now() - std::time::Duration::from_millis(1));
        s.submit(dead);
        s.submit(seq(2, 4, 2));
        let expired = s.expire_deadlines(Instant::now(), &mut kv, &mut meta);
        assert_eq!(expired.len(), 1);
        assert_eq!(expired[0].id, 1);
        assert_eq!(expired[0].prefilled, 0, "expired while queued: no tokens fed");
        // the live sequence still runs
        let b = s.build_batch(&mut kv, &mut meta).unwrap().unwrap();
        assert_eq!(b.rows.len(), 1);
        assert_eq!(b.rows[0].1, 2);

        // a running sequence past deadline frees its KV on expiry
        let mut dead = seq(3, 4, 8);
        s.submit(seq(9, 2, 1));
        dead.deadline = Some(Instant::now());
        s.submit(dead);
        let _ = s.build_batch(&mut kv, &mut meta).unwrap();
        let used_before = kv.used_slots();
        let expired = s.expire_deadlines(Instant::now(), &mut kv, &mut meta);
        assert_eq!(expired.iter().filter(|e| e.id == 3).count(), 1);
        assert!(used_before > 0);
        assert!(kv.used_slots() < used_before);
    }

    #[test]
    fn push_token_reports_ttft_edge() {
        let (mut s, mut kv, mut meta) = setup();
        s.submit(seq(1, 2, 3));
        let _ = s.build_batch(&mut kv, &mut meta).unwrap().unwrap();
        assert!(s.push_token(1, 5).unwrap(), "first generated token");
        let _ = s.build_batch(&mut kv, &mut meta).unwrap().unwrap();
        assert!(!s.push_token(1, 6).unwrap(), "second token is not First");
    }

    #[test]
    fn property_token_budget_and_row_capacity_hold() {
        crate::util::prop::check(707, 30, |rng| {
            let max_seqs = 1 + rng.below(6) as usize;
            let cfg = SchedConfig {
                max_seqs,
                abi_max_seqs: max_seqs,
                chunk: 1 + rng.below(12) as usize,
                buckets: vec![4, 16, 64],
                kv_cap: 256,
            };
            let mut s = Scheduler::new(cfg.clone());
            let mut kv = KvCache::new(256);
            let mut meta = SlotMeta::new(256);
            let mut next_id = 0u64;
            for _ in 0..30 {
                if rng.below(2) == 0 {
                    next_id += 1;
                    s.submit(seq_with(next_id, 1 + rng.below(40) as usize, 1 + rng.below(5) as usize));
                }
                if let Some(b) = s.build_batch(&mut kv, &mut meta).unwrap() {
                    let used = b.prefill_tokens + b.decode_tokens;
                    assert!(used <= b.bucket);
                    assert!(b.bucket <= 64);
                    assert!(b.rows.len() <= cfg.out_rows(b.bucket));
                    for (row, seq_id) in &b.rows {
                        let _ = row;
                        s.push_token(*seq_id, 1).unwrap();
                    }
                    s.reap(&mut kv, &mut meta);
                }
            }
            // drain: everything eventually terminates
            for _ in 0..500 {
                match s.build_batch(&mut kv, &mut meta).unwrap() {
                    Some(b) => {
                        for (_, seq_id) in &b.rows {
                            s.push_token(*seq_id, 1).unwrap();
                        }
                        s.reap(&mut kv, &mut meta);
                    }
                    None => break,
                }
            }
            assert!(s.is_idle(), "scheduler must drain");
            assert_eq!(kv.used_slots(), 0);
        });

        fn seq_with(id: u64, p: usize, n: usize) -> SeqState {
            SeqState::new(id, -1, None, (0..p as i32).collect(), n, Sampling::Greedy)
        }
    }
}
