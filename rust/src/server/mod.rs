//! Online serving loop: real-time trace replay against one or more
//! engine instances (the paper's section-5.2 experiment harness).
//!
//! * [`replay`] — drive one engine with a [`Trace`], injecting requests at
//!   their arrival times and stepping the engine whenever it has work.
//! * [`replay_multi`] — run several isolated instances concurrently on
//!   threads (the *vLLM-Ascend (Merged)* deployment of Fig. 6: one engine
//!   per adapter, each receiving only its domain's requests). Engines are
//!   constructed inside their threads (PJRT handles are not `Send`).

use crate::engine::{Completion, Engine, RequestSpec};
use crate::metrics::Report;
use crate::sampler::Sampling;
use crate::workload::trace::Trace;
use anyhow::Result;
use std::time::{Duration, Instant};

/// Outcome of one replay run.
#[derive(Debug)]
pub struct ReplayOutcome {
    pub report: Report,
    pub completions: Vec<Completion>,
    /// Requests whose submission failed (e.g. adapter not loaded).
    pub rejected: usize,
}

/// Replay a trace against one engine in real time.
///
/// The loop steps the engine whenever work is queued; between arrivals
/// with an idle engine it sleeps in short slices. Requests are greedy-
/// sampled (accuracy experiments rely on determinism).
pub fn replay(engine: &mut Engine, trace: &Trace) -> Result<ReplayOutcome> {
    let start = Instant::now();
    let mut next = 0usize;
    let mut completions = Vec::new();
    let mut rejected = 0usize;
    loop {
        let now = start.elapsed().as_secs_f64();
        while next < trace.events.len() && trace.events[next].at <= now {
            let e = &trace.events[next];
            let spec = RequestSpec {
                adapter: e.adapter.clone(),
                prompt: e.prompt.clone(),
                max_new_tokens: e.max_new_tokens,
                sampling: Sampling::Greedy,
            };
            if engine.submit(spec).is_err() {
                rejected += 1;
            }
            next += 1;
        }
        if engine.has_work() {
            if let Some(mut done) = engine.step()? {
                completions.append(&mut done);
            }
        } else if next < trace.events.len() {
            let wait = trace.events[next].at - start.elapsed().as_secs_f64();
            if wait > 0.0 {
                std::thread::sleep(Duration::from_secs_f64(wait.min(0.05)));
            }
        } else {
            break;
        }
    }
    engine.metrics.set_wall(start.elapsed());
    Ok(ReplayOutcome { report: engine.report(), completions, rejected })
}

/// Construct-and-replay on a dedicated thread per instance.
///
/// `builders` supply `(engine factory, trace)` pairs; every factory runs
/// on its own thread (one PJRT client each), mirroring independent
/// serving processes pinned to disjoint devices.
pub fn replay_multi(
    builders: Vec<(
        Box<dyn FnOnce() -> Result<Engine> + Send>,
        Trace,
    )>,
) -> Result<Vec<ReplayOutcome>> {
    let handles: Vec<_> = builders
        .into_iter()
        .enumerate()
        .map(|(i, (build, trace))| {
            std::thread::Builder::new()
                .name(format!("instance-{i}"))
                .spawn(move || -> Result<ReplayOutcome> {
                    let mut engine = build()?;
                    replay(&mut engine, &trace)
                })
                .expect("spawn instance thread")
        })
        .collect();
    handles
        .into_iter()
        .map(|h| h.join().expect("instance thread panicked"))
        .collect()
}

/// Aggregate reports of isolated instances into one system-level view
/// (throughputs add; latency summaries are merged request-weighted).
pub fn aggregate(outcomes: &[ReplayOutcome]) -> Report {
    let mut requests = 0;
    let mut prefill_tokens = 0;
    let mut decode_tokens = 0;
    let mut wall: f64 = 0.0;
    let mut ttft = crate::util::stats::Samples::new();
    let mut tpot = crate::util::stats::Samples::new();
    let mut e2e = crate::util::stats::Samples::new();
    for o in outcomes {
        requests += o.report.requests;
        prefill_tokens += o.report.prefill_tokens;
        decode_tokens += o.report.decode_tokens;
        wall = wall.max(o.report.wall);
        for c in &o.completions {
            ttft.push(c.record.ttft.as_secs_f64());
            if let Some(t) = c.record.tpot {
                tpot.push(t.as_secs_f64());
            }
            e2e.push(c.record.e2e.as_secs_f64());
        }
    }
    let wall = wall.max(1e-9);
    Report {
        requests,
        prefill_tokens,
        decode_tokens,
        prefill_throughput: prefill_tokens as f64 / wall,
        decode_throughput: decode_tokens as f64 / wall,
        ttft: ttft.summary(),
        tpot: tpot.summary(),
        e2e: e2e.summary(),
        wall,
    }
}
