//! Online serving loop: real-time trace replay against one or more
//! engine instances (the paper's section-5.2 experiment harness).
//!
//! Every replayer here is a *thin client* of the serving API
//! ([`crate::serving::ServingBackend`]): requests are submitted as
//! [`ServeRequest`]s, completions are gathered from each
//! [`RequestHandle`]'s token stream, and rejection accounting lives in
//! the backend. Benches and examples therefore exercise exactly the
//! path a network frontend does.
//!
//! * [`Pacer`] — wall-clock pacing of trace arrival times, shared by
//!   every replayer (including [`crate::coordinator::Coordinator`]).
//! * [`replay_backend`] — drive *any* [`ServingBackend`] with a
//!   [`Trace`]: inject arrivals on schedule, pump whenever the backend
//!   has work, and collect streamed completions.
//! * [`replay`] — single-engine wrapper that also finalizes the
//!   engine's serving report.
//! * [`replay_multi`] — run several isolated instances concurrently on
//!   threads (the *vLLM-Ascend (Merged)* deployment of Fig. 6: one engine
//!   per adapter, each receiving only its domain's requests). Engines are
//!   constructed inside their threads (PJRT handles are not `Send`).
//! * [`replay_fleet`] — the coordinated-fleet path (Fig. 10): same
//!   replicas-on-threads shape, but requests flow through
//!   [`crate::coordinator::Coordinator`]'s routing and admission control
//!   instead of a static per-adapter split.

use crate::engine::{Completion, Engine};
use crate::metrics::Report;
use crate::sampler::SamplingParams;
use crate::serving::{RequestHandle, ServeRequest, ServingBackend, TokenEvent};
use crate::workload::trace::Trace;
use anyhow::Result;
use std::time::{Duration, Instant};

/// Wall-clock pacer for trace injection.
///
/// The previous replay loop slept in fixed 50 ms slices and re-derived
/// `start.elapsed()` between the wait computation and the sleep, so an
/// idle engine could inject an arrival up to one slice late even with
/// nothing else to do. The pacer computes the remaining wait *once* and
/// sleeps it in full: injection error is bounded by OS sleep/wakeup
/// precision (sub-millisecond on the testbed), not by a polling slice.
#[derive(Debug, Clone, Copy)]
pub struct Pacer {
    start: Instant,
}

impl Pacer {
    pub fn start() -> Pacer {
        Pacer { start: Instant::now() }
    }

    /// Seconds of trace time elapsed.
    pub fn now(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    /// The instant replay started (fleet replicas anchor their serving
    /// wall time to this, not to their own construction time).
    pub fn started_at(&self) -> Instant {
        self.start
    }

    /// Sleep until trace time `at` (no-op if already past).
    pub fn wait_until(&self, at: f64) {
        let wait = at - self.now();
        if wait > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(wait));
        }
    }
}

/// Outcome of one replay run.
#[derive(Debug)]
pub struct ReplayOutcome {
    pub report: Report,
    pub completions: Vec<Completion>,
    /// Requests whose submission failed (e.g. adapter not loaded).
    pub rejected: usize,
}

/// Replay a trace against any serving backend in real time: inject each
/// arrival at its trace time via [`ServingBackend::submit`], pump while
/// the backend has work (sleeping until the next arrival when idle), and
/// collect the completions streamed over each request's handle.
///
/// Returns `(completions, rejected)` where `rejected` counts submits the
/// backend refused (typed [`crate::serving::SubmitError`]s — the
/// backend's own report carries the authoritative rejected/shed split).
/// Requests are greedy-sampled (accuracy experiments rely on
/// determinism).
pub fn replay_backend<B: ServingBackend>(
    backend: &mut B,
    trace: &Trace,
    pacer: &Pacer,
) -> Result<(Vec<Completion>, usize)> {
    let mut next = 0usize;
    let mut rejected = 0usize;
    let mut handles: Vec<RequestHandle> = Vec::new();
    let mut completions = Vec::new();
    // drain each live stream, keep completions, drop finished handles —
    // called inside the loop so token events are consumed as they are
    // produced instead of accumulating for the whole run
    let sweep = |handles: &mut Vec<RequestHandle>, completions: &mut Vec<Completion>| {
        handles.retain(|h| {
            let mut terminal = false;
            for ev in h.drain_events() {
                terminal = terminal || ev.is_terminal();
                if let TokenEvent::Done { completion, .. } = ev {
                    completions.push(completion);
                }
            }
            !terminal
        });
    };
    loop {
        let now = pacer.now();
        while next < trace.events.len() && trace.events[next].at <= now {
            let e = &trace.events[next];
            let req = ServeRequest {
                adapter: e.adapter.clone(),
                prompt: e.prompt.clone(),
                max_new_tokens: e.max_new_tokens,
                sampling: SamplingParams::greedy(),
                deadline: None,
                trace: None,
            };
            match backend.submit(req) {
                Ok(h) => handles.push(h),
                Err(_) => rejected += 1,
            }
            next += 1;
        }
        if backend.has_work() {
            backend.pump()?;
            sweep(&mut handles, &mut completions);
        } else if next < trace.events.len() {
            pacer.wait_until(trace.events[next].at);
        } else {
            break;
        }
    }
    sweep(&mut handles, &mut completions);
    Ok((completions, rejected))
}

/// Replay a trace against one engine in real time (thin client of
/// [`replay_backend`]), finalizing the engine's serving report.
pub fn replay(engine: &mut Engine, trace: &Trace) -> Result<ReplayOutcome> {
    let pacer = Pacer::start();
    let (completions, rejected) = replay_backend(engine, trace, &pacer)?;
    engine.metrics.set_wall(pacer.elapsed());
    Ok(ReplayOutcome { report: engine.report(), completions, rejected })
}

/// Construct-and-replay on a dedicated thread per instance.
///
/// `builders` supply `(engine factory, trace)` pairs; every factory runs
/// on its own thread (one PJRT client each), mirroring independent
/// serving processes pinned to disjoint devices.
pub fn replay_multi(
    builders: Vec<(
        Box<dyn FnOnce() -> Result<Engine> + Send>,
        Trace,
    )>,
) -> Result<Vec<ReplayOutcome>> {
    let handles: Vec<_> = builders
        .into_iter()
        .enumerate()
        .map(|(i, (build, trace))| {
            std::thread::Builder::new()
                .name(format!("instance-{i}"))
                .spawn(move || -> Result<ReplayOutcome> {
                    let mut engine = build()?;
                    replay(&mut engine, &trace)
                })
                .expect("spawn instance thread")
        })
        .collect();
    handles
        .into_iter()
        .map(|h| h.join().expect("instance thread panicked"))
        .collect()
}

/// Fleet analogue of [`replay`]: launch a [`Coordinator`] over
/// `spawn`-built replicas with `adapters` host-cached, then replay the
/// trace through its router/admission path.
///
/// [`Coordinator`]: crate::coordinator::Coordinator
pub fn replay_fleet<F>(
    cfg: crate::coordinator::CoordinatorConfig,
    spawn: F,
    adapters: Vec<crate::adapters::format::Adapter>,
    trace: &Trace,
) -> Result<crate::coordinator::FleetOutcome>
where
    F: Fn(usize) -> Box<dyn FnOnce() -> Result<Engine> + Send>,
{
    crate::coordinator::Coordinator::launch(cfg, spawn, adapters)?.replay(trace)
}

/// Aggregate reports of isolated instances into one system-level view
/// (throughputs add; latency summaries are merged request-weighted).
/// Thin wrapper over [`Report::merge`] — the same merge the fleet
/// coordinator uses for its aggregate.
pub fn aggregate(outcomes: &[ReplayOutcome]) -> Report {
    Report::merge(
        outcomes.iter().map(|o| &o.report),
        outcomes
            .iter()
            .flat_map(|o| o.completions.iter().map(|c| &c.record)),
        None,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::EngineOptions;
    use crate::model::ModelConfig;
    use crate::runtime::{SimPerf, Variant};
    use crate::weights::StoreMode;
    use crate::workload::trace::{Trace, TraceSpec};

    /// Arrival-time fidelity: each wait lands within a tight bound of
    /// the scheduled arrival, and never early.
    #[test]
    fn pacer_injects_on_time() {
        let arrivals = [0.005, 0.02, 0.05, 0.08, 0.11];
        let pacer = Pacer::start();
        for &at in &arrivals {
            pacer.wait_until(at);
            let now = pacer.now();
            assert!(now >= at, "woke early: {now} < {at}");
            // the property under test is "sleeps the full remaining
            // wait, computed once" — lateness equals OS wakeup
            // overshoot. The bound only needs to catch gross bugs
            // (sleeping a wrong duration) while surviving loaded CI
            // runners, so it is deliberately loose.
            assert!(now - at < 0.25, "woke {:.1} ms late", (now - at) * 1e3);
        }
        // waiting for the past returns immediately
        let t0 = pacer.now();
        pacer.wait_until(0.0);
        assert!(pacer.now() - t0 < 0.005);
    }

    /// Aggregating zero outcomes (e.g. a trace with no adapter-bound
    /// events split into zero per-adapter instances) must yield an
    /// empty, renderable report — not ±inf/panic (regression).
    #[test]
    fn aggregate_of_nothing_is_empty_not_broken() {
        let agg = aggregate(&[]);
        assert_eq!(agg.requests, 0);
        assert_eq!(agg.rejected + agg.shed + agg.aborted, 0);
        assert!(agg.wall > 0.0 && agg.wall.is_finite());
        assert_eq!(agg.goodput(), 0.0);
        assert!(agg.ttft.median.is_nan());
        let _ = agg.row("empty");
    }

    /// End-to-end replay over the simulated backend: every trace event
    /// is injected and completes; rejects surface in the report.
    #[test]
    fn replay_sim_engine_completes_trace() {
        let mut cfg = ModelConfig::sim_default();
        cfg.max_adapters = 2;
        let profiles = crate::adapters::generator::paper_adapter_profiles();
        let mk = |i: usize| {
            let mut p = profiles[i].clone();
            p.max_experts = p.max_experts.min(cfg.e_max);
            p.avg_experts = p.avg_experts.min(p.max_experts as f64);
            crate::adapters::generator::synth_adapter(
                &p,
                cfg.layers,
                cfg.num_experts,
                cfg.hidden,
                cfg.expert_inter,
                42 + i as u64,
            )
        };
        let ads = [mk(0), mk(2)];
        let mut engine = Engine::sim_weave(
            &cfg,
            SimPerf::fast(),
            &ads,
            Variant::Weave,
            StoreMode::Virtual,
            EngineOptions { page_size: 64 << 10, ..Default::default() },
        )
        .unwrap();

        let mut trace = Trace::generate(&TraceSpec {
            adapters: ads
                .iter()
                .map(|a| (a.name.clone(), a.domain.clone()))
                .collect(),
            lambda: 30.0,
            alpha: 0.5,
            horizon: 0.4,
            vocab: cfg.vocab,
            seed: 7,
        });
        for e in &mut trace.events {
            e.prompt.truncate(24);
            e.max_new_tokens = e.max_new_tokens.clamp(1, 4);
        }
        // one event asks for an adapter that is not loaded -> rejected
        if let Some(e) = trace.events.first_mut() {
            e.adapter = Some("not-loaded".into());
        }
        let n = trace.len();
        assert!(n > 1, "trace too short: {n}");
        let outcome = replay(&mut engine, &trace).unwrap();
        assert_eq!(outcome.rejected, 1);
        assert_eq!(outcome.report.rejected, 1);
        assert_eq!(outcome.completions.len(), n - 1);
        assert!(outcome.report.decode_throughput > 0.0);
        let last_arrival = trace.events.last().unwrap().at;
        assert!(outcome.report.wall >= last_arrival);
    }
}
