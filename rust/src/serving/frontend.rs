//! Std-only NDJSON-over-TCP frontend and client: the network face of
//! the serving API (`expertweave serve --listen`, `expertweave fleet
//! --listen`, `expertweave loadgen --connect`).
//!
//! **The wire format is specified in
//! [`docs/PROTOCOL.md`](../../../docs/PROTOCOL.md)** — one JSON object
//! per line in each direction (parsed and emitted with
//! [`crate::util::json`]; no external deps). In one breath: submit
//! frames carry `id`/`adapter`/`prompt`/`max_new_tokens`/`deadline_ms`
//! plus the protocol-v5 sampling fields (`temperature`, `top_k`,
//! `top_p`, the three penalties, `stop`, `stop_token_ids`,
//! `logit_bias`, `max_len`, `seed`); `{"op":"cancel","id":..}` cancels;
//! `{"op":"stats"}` answers with one versioned live-telemetry frame
//! (counters, gauges, latency quantiles — see [`crate::obs`]);
//! `{"op":"drain"}` finishes all in-flight work, acknowledges with
//! `{"event":"drained"}` on every connection, and shuts the server
//! down. Responses stream `first`/`token` incrementally (the TTFT edge
//! is observable on the wire), and every request ends with exactly one
//! `done`, `aborted`, or immediate `error` frame.
//!
//! Server architecture ([`NdjsonServer`]): one serving thread owns the
//! backend (PJRT handles are not `Send`, so engines never cross
//! threads) and multiplexes all connections; an acceptor plus one
//! reader thread per connection feed parsed lines over a channel. A
//! client disconnect cancels its outstanding requests — socket teardown
//! is client-side cancellation. The backend is *any*
//! [`ServingBackend`]: a single engine (`serve --listen`) or the fleet
//! coordinator (`fleet --listen`) — the router code here is identical
//! for both.
//!
//! Client ([`NdjsonClient`]): the same trait from the other side of the
//! socket — `submit` writes a frame, `pump` folds response lines into
//! per-request [`TokenEvent`] streams — so load generators and tests
//! drive a remote server exactly like an in-process engine.

use crate::engine::Completion;
use crate::metrics::RequestRecord;
use crate::sampler::{FinishReason, SamplingParams};
use crate::serving::{
    AbortReason, RequestHandle, RequestId, ServeRequest, ServingBackend, SubmitError,
    TokenEvent,
};
use crate::util::json::{obj, Json};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Connection-scoped commands the reader threads feed the serving loop.
enum Cmd {
    Conn { conn: usize, writer: TcpStream },
    Line { conn: usize, text: String },
    Gone { conn: usize },
}

/// A bound-but-not-yet-serving NDJSON server. Binding is split from
/// serving so callers (tests, the CLI) can learn the ephemeral port
/// before the blocking serve loop starts.
pub struct NdjsonServer {
    listener: TcpListener,
}

impl NdjsonServer {
    /// Bind `addr` (e.g. `127.0.0.1:7070`, or port 0 for ephemeral).
    pub fn bind(addr: &str) -> Result<NdjsonServer> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        Ok(NdjsonServer { listener })
    }

    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Serve until a client sends `{"op":"drain"}`: accept connections,
    /// admit NDJSON requests into `backend`, and stream token events
    /// back per connection. Blocks the calling thread (it is the only
    /// thread that touches the backend).
    pub fn run<B: ServingBackend>(self, backend: &mut B) -> Result<()> {
        let addr = self.local_addr()?;
        let (tx, rx) = channel::<Cmd>();
        let stopping = Arc::new(AtomicBool::new(false));
        let acceptor = spawn_acceptor(self.listener, tx, stopping.clone());

        let result = serve_loop(backend, &rx);

        // unblock the acceptor: set the flag, then poke the socket.
        // A wildcard bind (0.0.0.0 / [::]) is not connectable on every
        // platform — poke loopback on the bound port instead.
        stopping.store(true, Ordering::SeqCst);
        let mut poke = addr;
        if poke.ip().is_unspecified() {
            poke.set_ip(match poke.ip() {
                std::net::IpAddr::V4(_) => std::net::Ipv4Addr::LOCALHOST.into(),
                std::net::IpAddr::V6(_) => std::net::Ipv6Addr::LOCALHOST.into(),
            });
        }
        let _ = TcpStream::connect(poke);
        let _ = acceptor.join();
        result
    }
}

fn spawn_acceptor(
    listener: TcpListener,
    tx: Sender<Cmd>,
    stopping: Arc<AtomicBool>,
) -> std::thread::JoinHandle<()> {
    std::thread::Builder::new()
        .name("ndjson-accept".into())
        .spawn(move || {
            let mut next_conn = 0usize;
            while let Ok((stream, _)) = listener.accept() {
                if stopping.load(Ordering::SeqCst) {
                    return;
                }
                let conn = next_conn;
                next_conn += 1;
                let Ok(writer) = stream.try_clone() else { continue };
                // a client that stops reading must not wedge the single
                // serving thread once its socket buffer fills: writes
                // that stall this long error out and drop the connection
                let _ = writer.set_write_timeout(Some(Duration::from_secs(10)));
                if tx.send(Cmd::Conn { conn, writer }).is_err() {
                    return;
                }
                let line_tx = tx.clone();
                let _ = std::thread::Builder::new()
                    .name(format!("ndjson-read-{conn}"))
                    .spawn(move || {
                        let mut reader = BufReader::new(stream);
                        let mut line = String::new();
                        loop {
                            line.clear();
                            match reader.read_line(&mut line) {
                                Ok(0) | Err(_) => break,
                                Ok(_) => {
                                    let text = line.trim().to_string();
                                    if !text.is_empty()
                                        && line_tx.send(Cmd::Line { conn, text }).is_err()
                                    {
                                        break;
                                    }
                                }
                            }
                        }
                        let _ = line_tx.send(Cmd::Gone { conn });
                    });
            }
        })
        .expect("spawn ndjson acceptor")
}

/// Per-server connection + request bookkeeping.
#[derive(Default)]
struct Router {
    writers: HashMap<usize, TcpStream>,
    handles: HashMap<RequestId, RequestHandle>,
    /// rid → (conn, client tag).
    owners: HashMap<RequestId, (usize, String)>,
    /// (conn, client tag) → rid (cancel lookup).
    by_tag: HashMap<(usize, String), RequestId>,
    /// Connections whose writes failed (slow/hung-up clients); the serve
    /// loop cancels their outstanding requests like a disconnect.
    dead: Vec<usize>,
    next_auto_tag: u64,
}

impl Router {
    fn write_line(&mut self, conn: usize, value: &Json) {
        let failed = match self.writers.get_mut(&conn) {
            Some(w) => writeln!(w, "{value}").is_err(),
            None => false,
        };
        if failed {
            self.writers.remove(&conn);
            self.dead.push(conn);
        }
    }

    fn finish_request(&mut self, rid: RequestId) {
        self.handles.remove(&rid);
        if let Some((conn, tag)) = self.owners.remove(&rid) {
            self.by_tag.remove(&(conn, tag));
        }
    }

    /// Forward every buffered token event to its owner connection.
    fn flush_streams(&mut self) {
        let rids: Vec<RequestId> = self.handles.keys().copied().collect();
        for rid in rids {
            let Some(handle) = self.handles.get(&rid) else { continue };
            let events = handle.drain_events();
            let Some(&(conn, ref tag)) = self.owners.get(&rid) else { continue };
            let tag = tag.clone();
            let mut terminal = false;
            for ev in events {
                terminal = terminal || ev.is_terminal();
                let line = event_json(&tag, ev);
                self.write_line(conn, &line);
            }
            if terminal {
                self.finish_request(rid);
            }
        }
    }

    /// Requests owned by a connection (its teardown cancels them).
    fn requests_of(&self, conn: usize) -> Vec<RequestId> {
        self.owners
            .iter()
            .filter(|(_, (c, _))| *c == conn)
            .map(|(&rid, _)| rid)
            .collect()
    }
}

/// Render one token event as its wire line.
fn event_json(tag: &str, ev: TokenEvent) -> Json {
    match ev {
        TokenEvent::First { token, .. } => obj(vec![
            ("id", Json::Str(tag.to_string())),
            ("event", Json::Str("first".into())),
            ("token", Json::Int(token as i64)),
        ]),
        TokenEvent::Token { token, .. } => obj(vec![
            ("id", Json::Str(tag.to_string())),
            ("event", Json::Str("token".into())),
            ("token", Json::Int(token as i64)),
        ]),
        TokenEvent::Done { completion, .. } => {
            let tokens =
                completion.output.iter().map(|&t| Json::Int(t as i64)).collect::<Vec<_>>();
            let rec = &completion.record;
            obj(vec![
                ("id", Json::Str(tag.to_string())),
                ("event", Json::Str("done".into())),
                ("tokens", Json::Arr(tokens)),
                ("finish", Json::Str(completion.finish.as_str().into())),
                ("prompt_tokens", Json::Int(rec.prompt_tokens as i64)),
                ("ttft_ms", Json::Num(rec.ttft.as_secs_f64() * 1e3)),
                (
                    "tpot_ms",
                    rec.tpot
                        .map(|t| Json::Num(t.as_secs_f64() * 1e3))
                        .unwrap_or(Json::Null),
                ),
                ("e2e_ms", Json::Num(rec.e2e.as_secs_f64() * 1e3)),
            ])
        }
        TokenEvent::Aborted { reason, .. } => {
            let mut fields = vec![
                ("id", Json::Str(tag.to_string())),
                ("event", Json::Str("aborted".into())),
                ("reason", Json::Str(reason.as_str().into())),
            ];
            // post-routing rejections keep their typed code on the wire
            // (clients like NdjsonClient rebuild the SubmitError from it
            // — a remote load generator must classify a replica-side
            // deadline rejection exactly like an in-process one)
            if let AbortReason::Rejected(err) = &reason {
                fields.push(("code", Json::Str(err.code().into())));
            }
            obj(fields)
        }
    }
}

fn error_json(tag: &str, code: &str, message: &str) -> Json {
    obj(vec![
        ("id", Json::Str(tag.to_string())),
        ("event", Json::Str("error".into())),
        ("code", Json::Str(code.to_string())),
        ("message", Json::Str(message.to_string())),
    ])
}

/// Parse a request line into a [`ServeRequest`]. `Err` carries
/// `(code, message)` for the error event.
fn parse_request(v: &Json) -> std::result::Result<ServeRequest, (String, String)> {
    let bad = |m: &str| ("invalid".to_string(), m.to_string());
    let prompt = v
        .get("prompt")
        .and_then(|p| p.as_arr())
        .ok_or_else(|| bad("\"prompt\" must be an array of token ids"))?
        .iter()
        .map(|t| t.as_i64().map(|x| x as i32))
        .collect::<Option<Vec<i32>>>()
        .ok_or_else(|| bad("\"prompt\" must contain integers"))?;
    let max_new_tokens = match v.get("max_new_tokens") {
        None => 16,
        Some(m) => m
            .as_usize()
            .ok_or_else(|| bad("\"max_new_tokens\" must be a non-negative integer"))?,
    };
    let adapter = match v.get("adapter") {
        None | Some(Json::Null) => None,
        Some(a) => Some(
            a.as_str()
                .ok_or_else(|| bad("\"adapter\" must be a string"))?
                .to_string(),
        ),
    };
    let deadline = match v.get("deadline_ms") {
        None | Some(Json::Null) => None,
        Some(d) => {
            // bound the range: Duration::from_secs_f64 panics on huge or
            // non-finite input, and a panic here would take down the
            // serving thread for every client
            const MAX_DEADLINE_MS: f64 = 1e12; // ~31 years
            let ms = d
                .as_f64()
                .filter(|x| x.is_finite() && (0.0..=MAX_DEADLINE_MS).contains(x))
                .ok_or_else(|| {
                    bad("\"deadline_ms\" must be a finite number in [0, 1e12]")
                })?;
            Some(Duration::from_secs_f64(ms / 1e3))
        }
    };
    // Protocol v5 sampling fields: every one optional, zero value =
    // disabled. Out-of-range values are clamped by `sanitize` at submit;
    // only *type* errors are rejected here.
    let num = |key: &'static str| -> std::result::Result<Option<f64>, (String, String)> {
        match v.get(key) {
            None | Some(Json::Null) => Ok(None),
            Some(x) => x
                .as_f64()
                .filter(|f| f.is_finite())
                .map(Some)
                .ok_or_else(|| bad(&format!("\"{key}\" must be a finite number"))),
        }
    };
    let uint = |key: &'static str| -> std::result::Result<Option<usize>, (String, String)> {
        match v.get(key) {
            None | Some(Json::Null) => Ok(None),
            Some(x) => x
                .as_usize()
                .map(Some)
                .ok_or_else(|| bad(&format!("\"{key}\" must be a non-negative integer"))),
        }
    };
    let tok_list = |x: &Json, what: &str| -> std::result::Result<Vec<i32>, (String, String)> {
        x.as_arr()
            .ok_or_else(|| bad(&format!("{what} must be an array of token ids")))?
            .iter()
            .map(|t| t.as_i64().map(|i| i as i32))
            .collect::<Option<Vec<i32>>>()
            .ok_or_else(|| bad(&format!("{what} must contain integers")))
    };
    let mut sampling = SamplingParams::greedy();
    if let Some(t) = num("temperature")? {
        sampling.temperature = t as f32;
    }
    if let Some(k) = uint("top_k")? {
        sampling.top_k = k;
    }
    if let Some(p) = num("top_p")? {
        sampling.top_p = p as f32;
    }
    if let Some(r) = num("repetition_penalty")? {
        sampling.repetition_penalty = r as f32;
    }
    if let Some(p) = num("presence_penalty")? {
        sampling.presence_penalty = p as f32;
    }
    if let Some(f) = num("frequency_penalty")? {
        sampling.frequency_penalty = f as f32;
    }
    if let Some(n) = uint("max_len")? {
        sampling.max_len = n;
    }
    match v.get("seed") {
        None | Some(Json::Null) => {}
        Some(s) => {
            // Lossless u64 seeds: JSON numbers lose integer precision
            // past 2^53 (and our Int fast path past 2^63), so the full
            // range travels as a decimal string — NdjsonClient always
            // emits that form. Plain non-negative integers are accepted
            // too (hand-written clients, the CI smoke test).
            let parsed = match s {
                Json::Str(t) => t.parse::<u64>().ok(),
                _ => s.as_i64().filter(|&i| i >= 0).map(|i| i as u64),
            };
            sampling.seed = Some(parsed.ok_or_else(|| {
                bad("\"seed\" must be a non-negative integer or a decimal string")
            })?);
        }
    }
    match v.get("stop") {
        None | Some(Json::Null) => {}
        Some(s) => {
            let seqs = s
                .as_arr()
                .ok_or_else(|| bad("\"stop\" must be an array of token-id arrays"))?;
            for seq in seqs {
                sampling.stop_sequences.push(tok_list(seq, "each \"stop\" entry")?);
            }
        }
    }
    match v.get("stop_token_ids") {
        None | Some(Json::Null) => {}
        Some(s) => sampling.stop_token_ids = tok_list(s, "\"stop_token_ids\"")?,
    }
    match v.get("logit_bias") {
        None | Some(Json::Null) => {}
        Some(b) => {
            let pairs = b
                .as_arr()
                .ok_or_else(|| bad("\"logit_bias\" must be an array of [token, bias] pairs"))?;
            for p in pairs {
                let pair = p.as_arr().filter(|a| a.len() == 2);
                let (tok, bias) = pair
                    .and_then(|a| Some((a[0].as_i64()?, a[1].as_f64()?)))
                    .ok_or_else(|| bad("each \"logit_bias\" entry must be [token, bias]"))?;
                sampling.logit_bias.push((tok as i32, bias as f32));
            }
        }
    }
    let trace = match v.get("trace") {
        None | Some(Json::Null) => None,
        Some(t) => Some(
            t.as_i64()
                .filter(|&x| x > 0)
                .ok_or_else(|| bad("\"trace\" must be a positive integer"))?
                as u64,
        ),
    };
    Ok(ServeRequest { adapter, prompt, max_new_tokens, sampling, deadline, trace })
}

fn serve_loop<B: ServingBackend>(backend: &mut B, rx: &Receiver<Cmd>) -> Result<()> {
    let mut router = Router::default();
    loop {
        // absorb every pending command
        while let Ok(cmd) = rx.try_recv() {
            if handle_cmd(backend, &mut router, cmd)? {
                return finish_drain(backend, &mut router, rx);
            }
        }
        if backend.has_work() {
            backend.pump()?;
            router.flush_streams();
        } else {
            // idle: block for the next command (short timeout so a
            // straggling event flush still happens promptly)
            match rx.recv_timeout(Duration::from_millis(20)) {
                Ok(cmd) => {
                    if handle_cmd(backend, &mut router, cmd)? {
                        return finish_drain(backend, &mut router, rx);
                    }
                }
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => return Ok(()),
            }
            router.flush_streams();
        }
        reap_dead_conns(backend, &mut router);
    }
}

/// Cancel the outstanding requests of connections whose writes failed
/// (equivalent to a client disconnect).
fn reap_dead_conns<B: ServingBackend>(backend: &mut B, router: &mut Router) {
    for conn in std::mem::take(&mut router.dead) {
        for rid in router.requests_of(conn) {
            backend.cancel(rid);
            router.finish_request(rid);
        }
    }
}

/// Complete all in-flight work, answer the commands still queued behind
/// the drain (their submits fail with `shutting_down`, honoring the
/// wire contract), flush the final events, and acknowledge the drain on
/// every connection.
fn finish_drain<B: ServingBackend>(
    backend: &mut B,
    router: &mut Router,
    rx: &Receiver<Cmd>,
) -> Result<()> {
    backend.drain()?;
    while let Ok(cmd) = rx.try_recv() {
        // the backend is draining: submits are rejected with a typed
        // ShuttingDown that handle_cmd turns into an error event, so no
        // request queued behind the drain disappears silently. A second
        // drain command is a no-op (we are already finishing).
        let _ = handle_cmd(backend, router, cmd)?;
    }
    router.flush_streams();
    let ack = obj(vec![("event", Json::Str("drained".into()))]);
    let conns: Vec<usize> = router.writers.keys().copied().collect();
    for conn in conns {
        router.write_line(conn, &ack);
    }
    Ok(())
}

/// Apply one command; returns `true` when a drain was requested.
fn handle_cmd<B: ServingBackend>(
    backend: &mut B,
    router: &mut Router,
    cmd: Cmd,
) -> Result<bool> {
    match cmd {
        Cmd::Conn { conn, writer } => {
            router.writers.insert(conn, writer);
        }
        Cmd::Gone { conn } => {
            // disconnect = cancel everything the client still has running
            for rid in router.requests_of(conn) {
                backend.cancel(rid);
                router.finish_request(rid);
            }
            router.writers.remove(&conn);
        }
        Cmd::Line { conn, text } => {
            let parsed = match Json::parse(&text) {
                Ok(v) => v,
                Err(e) => {
                    let line = error_json("", "bad_json", &e.to_string());
                    router.write_line(conn, &line);
                    return Ok(false);
                }
            };
            match parsed.get("op").and_then(|o| o.as_str()) {
                Some("drain") => return Ok(true),
                Some("stats") => {
                    // live telemetry snapshot (PROTOCOL.md v2): answered
                    // inline without disturbing in-flight requests. The
                    // optional "id" round-trips so clients can correlate.
                    let tag = parsed
                        .get("id")
                        .and_then(|i| i.as_str())
                        .unwrap_or("")
                        .to_string();
                    match backend.stats() {
                        Some(snap) => {
                            let mut frame = snap.to_json();
                            if let Json::Obj(m) = &mut frame {
                                m.insert("event".into(), Json::Str("stats".into()));
                                if !tag.is_empty() {
                                    m.insert("id".into(), Json::Str(tag));
                                }
                            }
                            router.write_line(conn, &frame);
                        }
                        None => {
                            let line = error_json(
                                &tag,
                                "unsupported",
                                "this backend exposes no stats",
                            );
                            router.write_line(conn, &line);
                        }
                    }
                }
                Some("flightrec") => {
                    // black-box snapshot (PROTOCOL.md v3): the recent
                    // request/step events from every engine's always-on
                    // flight-recorder ring, answered inline like stats.
                    let tag = parsed
                        .get("id")
                        .and_then(|i| i.as_str())
                        .unwrap_or("")
                        .to_string();
                    match backend.flightrec() {
                        Some(mut frame) => {
                            if let Json::Obj(m) = &mut frame {
                                m.insert("event".into(), Json::Str("flightrec".into()));
                                if !tag.is_empty() {
                                    m.insert("id".into(), Json::Str(tag));
                                }
                            }
                            router.write_line(conn, &frame);
                        }
                        None => {
                            let line = error_json(
                                &tag,
                                "unsupported",
                                "this backend exposes no flight recorder",
                            );
                            router.write_line(conn, &line);
                        }
                    }
                }
                Some("cancel") => {
                    let tag = parsed
                        .get("id")
                        .and_then(|i| i.as_str())
                        .unwrap_or("")
                        .to_string();
                    let rid = router.by_tag.get(&(conn, tag.clone())).copied();
                    // on success the Aborted event arrives via the stream
                    if !rid.map(|r| backend.cancel(r)).unwrap_or(false) {
                        let line =
                            error_json(&tag, "unknown_request", "no such in-flight request");
                        router.write_line(conn, &line);
                    }
                }
                Some("kill-replica") => {
                    // chaos hook (PROTOCOL.md v4): forcibly fail one
                    // fleet replica; failover handles the fallout.
                    let tag = parsed
                        .get("id")
                        .and_then(|i| i.as_str())
                        .unwrap_or("")
                        .to_string();
                    let replica = parsed
                        .get("replica")
                        .and_then(|r| r.as_i64())
                        .unwrap_or(-1);
                    let killed = replica >= 0 && backend.kill_replica(replica as usize);
                    if !killed {
                        let line = error_json(
                            &tag,
                            "unknown_replica",
                            "no live replica at that index (or backend has no fleet)",
                        );
                        router.write_line(conn, &line);
                    }
                }
                Some(other) => {
                    let msg =
                        format!("unknown op {other:?} (cancel|drain|stats|flightrec|kill-replica)");
                    let line = error_json("", "bad_request", &msg);
                    router.write_line(conn, &line);
                }
                None => {
                    let tag = match parsed.get("id").and_then(|i| i.as_str()) {
                        Some(t) => t.to_string(),
                        None => {
                            router.next_auto_tag += 1;
                            format!("auto-{}", router.next_auto_tag)
                        }
                    };
                    if router.by_tag.contains_key(&(conn, tag.clone())) {
                        let line =
                            error_json(&tag, "duplicate_id", "id already in flight here");
                        router.write_line(conn, &line);
                        return Ok(false);
                    }
                    match parse_request(&parsed) {
                        Ok(req) => match backend.submit(req) {
                            Ok(handle) => {
                                let rid = handle.id;
                                router.handles.insert(rid, handle);
                                router.owners.insert(rid, (conn, tag.clone()));
                                router.by_tag.insert((conn, tag), rid);
                            }
                            Err(e) => {
                                let line = error_json(&tag, e.code(), &e.to_string());
                                router.write_line(conn, &line);
                            }
                        },
                        Err((code, msg)) => {
                            let line = error_json(&tag, &code, &msg);
                            router.write_line(conn, &line);
                        }
                    }
                }
            }
        }
    }
    Ok(false)
}

// ---------------------------------------------------------------------
// NDJSON client: the serving API from the other side of the socket.
// ---------------------------------------------------------------------

/// A [`ServingBackend`] that forwards to a remote NDJSON server over one
/// TCP connection — the client half of the wire protocol
/// (`docs/PROTOCOL.md`).
///
/// `submit` writes a request frame and returns a [`RequestHandle`]
/// exactly like an in-process engine; `pump` folds response lines
/// (delivered by a reader thread) into the per-request streams. Wire
/// `error` frames — which the server emits for rejected submits,
/// because rejection is asynchronous from the client's point of view —
/// surface as a terminal [`TokenEvent::Aborted`] with
/// [`AbortReason::Rejected`] carrying the decoded [`SubmitError`].
///
/// Request tags on the wire are the client-assigned numeric ids, so the
/// handle ids round-trip unchanged.
pub struct NdjsonClient {
    writer: TcpStream,
    /// Response lines from the reader thread.
    lines: Receiver<String>,
    /// rid → client-side token-stream sender.
    streams: HashMap<RequestId, Sender<TokenEvent>>,
    next_rid: RequestId,
    drained: bool,
    shutting_down: bool,
}

impl NdjsonClient {
    /// Connect to a serving NDJSON listener (e.g. `127.0.0.1:7070`).
    pub fn connect(addr: &str) -> Result<NdjsonClient> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        let writer = stream.try_clone()?;
        let (tx, rx) = channel::<String>();
        std::thread::Builder::new()
            .name("ndjson-client-read".into())
            .spawn(move || {
                let mut reader = BufReader::new(stream);
                let mut line = String::new();
                loop {
                    line.clear();
                    match reader.read_line(&mut line) {
                        Ok(0) | Err(_) => break,
                        Ok(_) => {
                            let text = line.trim().to_string();
                            if !text.is_empty() && tx.send(text).is_err() {
                                break;
                            }
                        }
                    }
                }
            })
            .context("spawn ndjson client reader")?;
        Ok(NdjsonClient {
            writer,
            lines: rx,
            streams: HashMap::new(),
            next_rid: 1,
            drained: false,
            shutting_down: false,
        })
    }

    /// Has the server acknowledged a drain on this connection?
    pub fn is_drained(&self) -> bool {
        self.drained
    }

    fn send_line(&mut self, line: &Json) -> bool {
        writeln!(self.writer, "{line}").is_ok()
    }

    /// Fold one response line into the client state.
    fn apply_line(&mut self, text: &str) {
        let Ok(v) = Json::parse(text) else { return };
        let event = v.get("event").and_then(|e| e.as_str()).unwrap_or("");
        if event == "drained" {
            self.drained = true;
            return;
        }
        let Some(rid) = v
            .get("id")
            .and_then(|i| i.as_str())
            .and_then(|t| t.parse::<RequestId>().ok())
        else {
            return;
        };
        if !self.streams.contains_key(&rid) {
            return;
        }
        let ev = match event {
            "first" => v
                .get("token")
                .and_then(Json::as_i64)
                .map(|t| TokenEvent::First { id: rid, token: t as i32 }),
            "token" => v
                .get("token")
                .and_then(Json::as_i64)
                .map(|t| TokenEvent::Token { id: rid, token: t as i32 }),
            "done" => Some(done_event(rid, &v)),
            "aborted" => {
                let reason = match v.get("reason").and_then(|r| r.as_str()) {
                    Some("cancelled") => AbortReason::Cancelled,
                    Some("deadline") => AbortReason::DeadlineExceeded,
                    Some("replica_lost") => AbortReason::ReplicaLost,
                    _ => {
                        // post-routing rejection: the frame carries the
                        // typed code, so the decoded SubmitError matches
                        // what an in-process backend would have produced
                        let code = v.get("code").and_then(|c| c.as_str()).unwrap_or("");
                        AbortReason::Rejected(decode_error(code, "rejected upstream"))
                    }
                };
                Some(TokenEvent::Aborted { id: rid, reason })
            }
            "error" => {
                let code = v.get("code").and_then(|c| c.as_str()).unwrap_or("");
                let msg = v.get("message").and_then(|m| m.as_str()).unwrap_or("");
                Some(TokenEvent::Aborted {
                    id: rid,
                    reason: AbortReason::Rejected(decode_error(code, msg)),
                })
            }
            _ => None,
        };
        let Some(ev) = ev else { return };
        let terminal = ev.is_terminal();
        if let Some(tx) = self.streams.get(&rid) {
            let _ = tx.send(ev);
        }
        if terminal {
            self.streams.remove(&rid);
        }
    }
}

/// Rebuild a [`TokenEvent::Done`] from its wire frame (the latency
/// record is reconstructed from the reported milliseconds).
fn done_event(rid: RequestId, v: &Json) -> TokenEvent {
    let output: Vec<i32> = v
        .get("tokens")
        .and_then(Json::as_arr)
        .map(|a| a.iter().filter_map(Json::as_i64).map(|t| t as i32).collect())
        .unwrap_or_default();
    let finish = match v.get("finish").and_then(|f| f.as_str()) {
        Some("stop") => FinishReason::Stop,
        _ => FinishReason::Length,
    };
    let ms = |k: &str| v.get(k).and_then(Json::as_f64);
    let dur = |x: f64| Duration::from_secs_f64((x / 1e3).max(0.0));
    let record = RequestRecord {
        id: rid,
        adapter: None,
        prompt_tokens: v.get("prompt_tokens").and_then(Json::as_usize).unwrap_or(0),
        output_tokens: output.len(),
        ttft: dur(ms("ttft_ms").unwrap_or(0.0)),
        tpot: ms("tpot_ms").map(dur),
        e2e: dur(ms("e2e_ms").unwrap_or(0.0)),
    };
    TokenEvent::Done {
        id: rid,
        completion: Completion { id: rid, adapter: None, output, finish, record },
    }
}

/// Decode a wire `error` frame's `code` back into the typed
/// [`SubmitError`] (the inverse of [`SubmitError::code`]).
fn decode_error(code: &str, message: &str) -> SubmitError {
    match code {
        "unknown_adapter" => SubmitError::UnknownAdapter(message.to_string()),
        "queue_full" => SubmitError::QueueFull,
        "shed" => SubmitError::Shed,
        "shutting_down" => SubmitError::ShuttingDown,
        "deadline_unmeetable" => SubmitError::DeadlineUnmeetable,
        "" | "invalid" => SubmitError::Invalid(message.to_string()),
        other => SubmitError::Invalid(format!("{other}: {message}")),
    }
}

impl ServingBackend for NdjsonClient {
    /// Write the request frame. Submission over the wire cannot fail
    /// synchronously (server rejections arrive as `error` frames, which
    /// become [`AbortReason::Rejected`] on the stream); the only local
    /// failures are a draining client or a dead connection, both
    /// [`SubmitError::ShuttingDown`].
    fn submit(&mut self, req: ServeRequest) -> std::result::Result<RequestHandle, SubmitError> {
        if self.shutting_down {
            return Err(SubmitError::ShuttingDown);
        }
        let rid = self.next_rid;
        self.next_rid += 1;
        let mut fields = vec![
            ("id", Json::Str(rid.to_string())),
            (
                "prompt",
                Json::Arr(req.prompt.iter().map(|&t| Json::Int(t as i64)).collect()),
            ),
            ("max_new_tokens", Json::Int(req.max_new_tokens as i64)),
        ];
        if let Some(a) = &req.adapter {
            fields.push(("adapter", Json::Str(a.clone())));
        }
        if let Some(d) = req.deadline {
            fields.push(("deadline_ms", Json::Num(d.as_secs_f64() * 1e3)));
        }
        // Sampling fields (protocol v5): serialize only the knobs that
        // deviate from the greedy default, so v4-era greedy traffic is
        // byte-identical on the wire.
        let s = &req.sampling;
        if s.temperature != 0.0 {
            fields.push(("temperature", Json::Num(s.temperature as f64)));
        }
        if s.top_k != 0 {
            fields.push(("top_k", Json::Int(s.top_k as i64)));
        }
        if s.top_p != 1.0 {
            fields.push(("top_p", Json::Num(s.top_p as f64)));
        }
        if s.repetition_penalty != 1.0 {
            fields.push(("repetition_penalty", Json::Num(s.repetition_penalty as f64)));
        }
        if s.presence_penalty != 0.0 {
            fields.push(("presence_penalty", Json::Num(s.presence_penalty as f64)));
        }
        if s.frequency_penalty != 0.0 {
            fields.push(("frequency_penalty", Json::Num(s.frequency_penalty as f64)));
        }
        if !s.stop_sequences.is_empty() {
            fields.push((
                "stop",
                Json::Arr(
                    s.stop_sequences
                        .iter()
                        .map(|seq| {
                            Json::Arr(seq.iter().map(|&t| Json::Int(t as i64)).collect())
                        })
                        .collect(),
                ),
            ));
        }
        if !s.stop_token_ids.is_empty() {
            fields.push((
                "stop_token_ids",
                Json::Arr(s.stop_token_ids.iter().map(|&t| Json::Int(t as i64)).collect()),
            ));
        }
        if !s.logit_bias.is_empty() {
            fields.push((
                "logit_bias",
                Json::Arr(
                    s.logit_bias
                        .iter()
                        .map(|&(t, b)| {
                            // JSON has no Inf literal: a ±inf bias (the
                            // documented "unsampleable" form) ships as a
                            // finite f64 beyond f32 range, which the
                            // server's f32 narrowing turns back into ±inf
                            // (PROTOCOL.md, logit_bias). NaN is a no-op
                            // bias (sanitize would zero it anyway).
                            let wire = if b.is_finite() {
                                b as f64
                            } else if b == f32::NEG_INFINITY {
                                -1e39
                            } else if b == f32::INFINITY {
                                1e39
                            } else {
                                0.0
                            };
                            Json::Arr(vec![Json::Int(t as i64), Json::Num(wire)])
                        })
                        .collect(),
                ),
            ));
        }
        if s.max_len != 0 {
            fields.push(("max_len", Json::Int(s.max_len as i64)));
        }
        if let Some(seed) = s.seed {
            // decimal string: lossless for the full u64 range (an Int
            // would wrap past 2^63 and be rejected server-side; loadgen
            // draws seeds from the whole range)
            fields.push(("seed", Json::Str(seed.to_string())));
        }
        if let Some(t) = req.trace {
            fields.push(("trace", Json::Int(t as i64)));
        }
        let line = obj(fields);
        if !self.send_line(&line) {
            return Err(SubmitError::ShuttingDown);
        }
        let (handle, tx) = RequestHandle::new(rid);
        self.streams.insert(rid, tx);
        Ok(handle)
    }

    fn pump(&mut self) -> Result<bool> {
        let mut got = false;
        while let Ok(text) = self.lines.try_recv() {
            self.apply_line(&text);
            got = true;
        }
        if !got {
            // nothing buffered: block briefly so pump loops don't spin
            match self.lines.recv_timeout(Duration::from_millis(2)) {
                Ok(text) => self.apply_line(&text),
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => {
                    if !self.streams.is_empty() {
                        bail!(
                            "server closed the connection with {} request(s) in flight",
                            self.streams.len()
                        );
                    }
                }
            }
        }
        Ok(!self.streams.is_empty())
    }

    /// Relay a cancel frame. Returns `false` for unknown/terminal ids;
    /// on success the terminal `Aborted` arrives via the stream like any
    /// other event.
    fn cancel(&mut self, id: RequestId) -> bool {
        if !self.streams.contains_key(&id) {
            return false;
        }
        let line = obj(vec![
            ("op", Json::Str("cancel".into())),
            ("id", Json::Str(id.to_string())),
        ]);
        self.send_line(&line)
    }

    fn has_work(&self) -> bool {
        !self.streams.is_empty()
    }

    /// Relay a `kill-replica` frame (chaos hook, protocol v4). Fire and
    /// forget: a bad index comes back as an `error` frame, which carries
    /// no request id and is ignored by `apply_line` — the caller's
    /// observable signal is the fleet's failover stats, not this return.
    fn kill_replica(&mut self, replica: usize) -> bool {
        let line = obj(vec![
            ("op", Json::Str("kill-replica".into())),
            ("replica", Json::Int(replica as i64)),
        ]);
        self.send_line(&line)
    }

    /// Send `{"op":"drain"}` and wait for the server to finish all
    /// in-flight work and acknowledge with `drained`. The server flushes
    /// every outstanding terminal event before the ack, so all local
    /// streams close.
    fn drain(&mut self) -> Result<()> {
        if !self.shutting_down {
            self.shutting_down = true;
            let line = obj(vec![("op", Json::Str("drain".into()))]);
            if !self.send_line(&line) {
                bail!("connection closed before the drain could be sent");
            }
        }
        let deadline = Instant::now() + Duration::from_secs(600);
        while !self.drained || !self.streams.is_empty() {
            match self.lines.recv_timeout(Duration::from_millis(50)) {
                Ok(text) => self.apply_line(&text),
                Err(RecvTimeoutError::Timeout) => {
                    if Instant::now() > deadline {
                        bail!("drain timed out waiting for the server's ack");
                    }
                }
                Err(RecvTimeoutError::Disconnected) => {
                    if self.drained {
                        // the server acked and hung up; close any
                        // stragglers as shut down
                        for (id, tx) in self.streams.drain() {
                            let _ = tx.send(TokenEvent::Aborted {
                                id,
                                reason: AbortReason::Rejected(SubmitError::ShuttingDown),
                            });
                        }
                        break;
                    }
                    bail!("server closed the connection before acknowledging the drain");
                }
            }
        }
        Ok(())
    }
}
