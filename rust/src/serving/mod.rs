//! The online serving API: the typed request/response boundary every
//! frontend (trace replay, the NDJSON TCP server, tests) talks to.
//!
//! The paper's value proposition is *online* multi-adapter serving, and
//! the previous surface — `Engine::submit -> anyhow::Result<u64>` plus
//! buffered completions out of `step()` — could not express the things
//! an online boundary needs: incremental token delivery (TTFT is only
//! observable if the first token leaves the engine when it is sampled),
//! client-side cancellation, per-request deadlines, and machine-readable
//! rejection reasons. This module owns those contracts:
//!
//! * [`ServeRequest`] — one request, addressed to an adapter by name
//!   (the ESFT serving shape: the adapter *is* the routing key), with an
//!   optional relative deadline.
//! * [`ServingBackend`] — the trait implemented by both the
//!   single-replica [`Engine`] and the fleet
//!   [`Coordinator`]: `submit` / `pump` / `cancel` / `drain`.
//! * [`RequestHandle`] — per-request stream of [`TokenEvent`]s over a
//!   channel: `First` (TTFT edge), `Token`, then exactly one terminal
//!   `Done` or `Aborted`.
//! * [`SubmitError`] — typed admission failures (`UnknownAdapter`,
//!   `QueueFull`, `Shed`, `ShuttingDown`, `DeadlineUnmeetable`,
//!   `Invalid`) instead of stringly `anyhow` errors at the boundary.
//!
//! The trace replayers ([`crate::server::replay`] and friends) are thin
//! clients of this API, so every bench and example exercises the same
//! path a network frontend does. The NDJSON-over-TCP frontend lives in
//! [`frontend`].
//!
//! [`Engine`]: crate::engine::Engine
//! [`Coordinator`]: crate::coordinator::Coordinator

pub mod frontend;

use crate::engine::{Completion, RequestSpec};
use crate::sampler::SamplingParams;
use std::fmt;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::time::Duration;

/// Backend-assigned request identifier, unique within one backend.
pub type RequestId = u64;

/// One online request as submitted through [`ServingBackend::submit`].
#[derive(Debug, Clone)]
pub struct ServeRequest {
    /// Adapter name; `None` = base model.
    pub adapter: Option<String>,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    /// Per-request sampling configuration (temperature, top-k/top-p,
    /// penalties, stop conditions, logit bias, seed — NDJSON protocol
    /// v5). [`SamplingParams::greedy`] for exact-agreement decoding.
    pub sampling: SamplingParams,
    /// Relative deadline from submission. A request that has not
    /// *completed* by its deadline is aborted with
    /// [`AbortReason::DeadlineExceeded`]; a request whose deadline
    /// expires while still queued is dropped before ever occupying a
    /// batch slot.
    pub deadline: Option<Duration>,
    /// End-to-end trace id (client-supplied via the NDJSON `trace`
    /// field, protocol v3). Propagated through routing into the
    /// replica's phase spans so one request is traceable across the
    /// whole fleet; `None` = let the backend assign one (the fleet
    /// uses the request id).
    pub trace: Option<u64>,
}

impl From<RequestSpec> for ServeRequest {
    fn from(spec: RequestSpec) -> ServeRequest {
        ServeRequest {
            adapter: spec.adapter,
            prompt: spec.prompt,
            max_new_tokens: spec.max_new_tokens,
            sampling: spec.sampling,
            deadline: None,
            trace: None,
        }
    }
}

/// Why a request was admitted but not completed.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AbortReason {
    /// The client cancelled it ([`ServingBackend::cancel`]).
    Cancelled,
    /// Its deadline expired before completion.
    DeadlineExceeded,
    /// A post-routing engine rejection (fleet path: the routed replica
    /// refused the submit, e.g. the adapter raced away after the
    /// routing decision).
    Rejected(SubmitError),
    /// The replica holding this request died and the remaining deadline
    /// could not survive a re-routed retry (fleet failover path; see
    /// docs/PROTOCOL.md). Requests whose deadline *can* survive are
    /// silently re-submitted to a surviving replica instead — the
    /// stream may restart (`First` again) but always terminates.
    ReplicaLost,
}

impl AbortReason {
    /// Stable wire-format tag (the NDJSON frontend's `reason` field).
    pub fn as_str(&self) -> &'static str {
        match self {
            AbortReason::Cancelled => "cancelled",
            AbortReason::DeadlineExceeded => "deadline",
            AbortReason::Rejected(_) => "rejected",
            AbortReason::ReplicaLost => "replica_lost",
        }
    }
}

/// One event in a request's token stream.
///
/// Ordering contract: zero or one `First`, then zero or more `Token`,
/// then exactly one terminal event (`Done` or `Aborted`). A request
/// aborted before its first token emits only `Aborted`.
#[derive(Debug, Clone)]
pub enum TokenEvent {
    /// The first generated token (the TTFT edge).
    First { id: RequestId, token: i32 },
    /// A subsequent generated token.
    Token { id: RequestId, token: i32 },
    /// Terminal: the request completed; full output + latency record.
    Done { id: RequestId, completion: Completion },
    /// Terminal: the request was cancelled, deadline-expired, or
    /// rejected after routing.
    Aborted { id: RequestId, reason: AbortReason },
}

impl TokenEvent {
    pub fn id(&self) -> RequestId {
        match self {
            TokenEvent::First { id, .. }
            | TokenEvent::Token { id, .. }
            | TokenEvent::Done { id, .. }
            | TokenEvent::Aborted { id, .. } => *id,
        }
    }

    /// Does this event end the stream?
    pub fn is_terminal(&self) -> bool {
        matches!(self, TokenEvent::Done { .. } | TokenEvent::Aborted { .. })
    }

    /// The same event re-addressed to `id` (the fleet coordinator maps
    /// replica-local sequence ids to fleet request ids). `Done` payloads
    /// are re-addressed too — `completion.id` must agree with the
    /// stream's id, or per-replica sequence ids would collide fleet-wide.
    pub fn reid(self, id: RequestId) -> TokenEvent {
        match self {
            TokenEvent::First { token, .. } => TokenEvent::First { id, token },
            TokenEvent::Token { token, .. } => TokenEvent::Token { id, token },
            TokenEvent::Done { mut completion, .. } => {
                completion.id = id;
                completion.record.id = id;
                TokenEvent::Done { id, completion }
            }
            TokenEvent::Aborted { reason, .. } => TokenEvent::Aborted { id, reason },
        }
    }
}

/// Typed submission failure at the serving boundary.
///
/// Every variant has a stable machine-readable tag ([`SubmitError::code`])
/// that the NDJSON frontend emits as the `error` frame's `code` field
/// (see `docs/PROTOCOL.md`):
///
/// ```
/// use expertweave::serving::SubmitError;
///
/// let err = SubmitError::DeadlineUnmeetable;
/// assert_eq!(err.code(), "deadline_unmeetable"); // stable wire tag
/// assert!(err.to_string().contains("deadline")); // human-readable
/// ```
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SubmitError {
    /// No deployment (or no fleet replica) can serve this adapter.
    UnknownAdapter(String),
    /// The admission queue budget for this backend (or this adapter's
    /// fleet-wide outstanding budget) is exhausted; retry later.
    QueueFull,
    /// Admission control shed the request (no replica with capacity).
    Shed,
    /// The backend is draining and accepts no new work.
    ShuttingDown,
    /// Deadline-aware admission: the queue's expected wait (EWMA step
    /// time × queue depth) already exceeds the request's deadline, so it
    /// would expire before ever occupying a batch slot.
    DeadlineUnmeetable,
    /// The request itself is malformed (empty prompt, exceeds KV
    /// capacity, ...).
    Invalid(String),
}

impl SubmitError {
    /// Stable wire-format tag (the NDJSON frontend's `code` field).
    pub fn code(&self) -> &'static str {
        match self {
            SubmitError::UnknownAdapter(_) => "unknown_adapter",
            SubmitError::QueueFull => "queue_full",
            SubmitError::Shed => "shed",
            SubmitError::ShuttingDown => "shutting_down",
            SubmitError::DeadlineUnmeetable => "deadline_unmeetable",
            SubmitError::Invalid(_) => "invalid",
        }
    }
}

impl fmt::Display for SubmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SubmitError::UnknownAdapter(n) => write!(f, "adapter {n:?} is not served here"),
            SubmitError::QueueFull => write!(f, "admission queue is full"),
            SubmitError::Shed => write!(f, "request shed by admission control"),
            SubmitError::ShuttingDown => write!(f, "backend is shutting down"),
            SubmitError::DeadlineUnmeetable => {
                write!(f, "deadline shorter than the queue's expected wait")
            }
            SubmitError::Invalid(why) => write!(f, "invalid request: {why}"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Client-side handle to one submitted request: the receive half of its
/// [`TokenEvent`] stream plus the backend-assigned id (pass it to
/// [`ServingBackend::cancel`]).
///
/// Events arrive when the backend is pumped. With an in-process
/// [`Engine`] backend the submitting thread is also the pumping thread,
/// so use the non-blocking accessors between pumps; with a threaded
/// backend (fleet coordinator behind a pumping loop, or the TCP
/// frontend) [`RequestHandle::recv_timeout`] can block.
///
/// # Example
///
/// ```
/// # use expertweave::engine::{Engine, EngineOptions};
/// # use expertweave::model::ModelConfig;
/// # use expertweave::runtime::{SimPerf, Variant};
/// # use expertweave::sampler::SamplingParams;
/// # use expertweave::serving::{ServeRequest, ServingBackend};
/// # use expertweave::weights::StoreMode;
/// # let cfg = ModelConfig::sim_default();
/// # let mut engine = Engine::sim_weave(&cfg, SimPerf::instant(), &[], Variant::Weave,
/// #     StoreMode::Virtual, EngineOptions { page_size: 64 << 10, ..Default::default() })
/// #     .unwrap();
/// let handle = engine
///     .submit_request(ServeRequest {
///         adapter: None,
///         prompt: vec![7, 8],
///         max_new_tokens: 1,
///         sampling: SamplingParams::greedy(),
///         deadline: None,
///         trace: None,
///     })
///     .unwrap();
/// assert!(handle.try_event().is_none(), "nothing pumped yet");
/// while engine.pump().unwrap() {}
/// let events = handle.drain_events();
/// assert!(events.last().unwrap().is_terminal());
/// ```
///
/// [`Engine`]: crate::engine::Engine
#[derive(Debug)]
pub struct RequestHandle {
    pub id: RequestId,
    rx: Receiver<TokenEvent>,
}

impl RequestHandle {
    /// Create a handle and the sender the backend feeds.
    pub(crate) fn new(id: RequestId) -> (RequestHandle, Sender<TokenEvent>) {
        let (tx, rx) = channel();
        (RequestHandle { id, rx }, tx)
    }

    /// Next buffered event, if any (non-blocking).
    pub fn try_event(&self) -> Option<TokenEvent> {
        self.rx.try_recv().ok()
    }

    /// Wait up to `timeout` for the next event (threaded backends).
    pub fn recv_timeout(&self, timeout: Duration) -> Result<TokenEvent, RecvTimeoutError> {
        self.rx.recv_timeout(timeout)
    }

    /// Drain every buffered event (non-blocking).
    pub fn drain_events(&self) -> Vec<TokenEvent> {
        let mut out = Vec::new();
        while let Some(ev) = self.try_event() {
            out.push(ev);
        }
        out
    }
}

/// A serving backend: something that admits requests, produces token
/// streams, and can cancel and drain. Implemented by the single-replica
/// [`Engine`], the fleet [`Coordinator`], and the remote
/// [`NdjsonClient`] — callers written against this trait (the trace
/// replayers, the open-loop load generator, the NDJSON listener) work
/// unchanged across all three.
///
/// # Example
///
/// Submit against a simulated engine and stream the result:
///
/// ```
/// use expertweave::engine::{Engine, EngineOptions};
/// use expertweave::model::ModelConfig;
/// use expertweave::runtime::{SimPerf, Variant};
/// use expertweave::sampler::SamplingParams;
/// use expertweave::serving::{ServeRequest, ServingBackend, TokenEvent};
/// use expertweave::weights::StoreMode;
///
/// let cfg = ModelConfig::sim_default();
/// let mut engine = Engine::sim_weave(
///     &cfg,
///     SimPerf::instant(),
///     &[], // no adapters: base-model serving
///     Variant::Weave,
///     StoreMode::Virtual,
///     EngineOptions { page_size: 64 << 10, ..Default::default() },
/// )
/// .unwrap();
/// let handle = engine
///     .submit_request(ServeRequest {
///         adapter: None,
///         prompt: vec![1, 2, 3],
///         max_new_tokens: 2,
///         sampling: SamplingParams::greedy(),
///         deadline: None,
///         trace: None,
///     })
///     .unwrap();
/// while engine.pump().unwrap() {}
/// let events = handle.drain_events();
/// assert!(matches!(events.first(), Some(TokenEvent::First { .. })));
/// assert!(matches!(events.last(), Some(TokenEvent::Done { .. })));
/// ```
///
/// [`Engine`]: crate::engine::Engine
/// [`Coordinator`]: crate::coordinator::Coordinator
/// [`NdjsonClient`]: crate::serving::frontend::NdjsonClient
pub trait ServingBackend {
    /// Admit one request. On success the request is queued and its
    /// events will flow through the returned handle as the backend is
    /// pumped. On failure the typed reason is returned immediately and
    /// the backend's `rejected`/`shed` accounting is updated — callers
    /// do not keep their own rejection books.
    fn submit(&mut self, req: ServeRequest) -> Result<RequestHandle, SubmitError>;

    /// Advance work: run one engine step (in-process engine) or process
    /// pending replica events (fleet). Returns whether work remains.
    fn pump(&mut self) -> anyhow::Result<bool>;

    /// Cancel a request by id. Queued requests are dropped before ever
    /// occupying a batch slot; running requests are aborted and their KV
    /// slots freed. Returns `false` for ids not in flight (already
    /// terminal, or never admitted). The stream receives
    /// [`TokenEvent::Aborted`] with [`AbortReason::Cancelled`].
    fn cancel(&mut self, id: RequestId) -> bool;

    /// Is any admitted request still queued or running?
    fn has_work(&self) -> bool;

    /// Finish all in-flight work, then stop admitting: every subsequent
    /// `submit` fails with [`SubmitError::ShuttingDown`]. Pumps
    /// internally until idle.
    fn drain(&mut self) -> anyhow::Result<()>;

    /// Live telemetry snapshot (the NDJSON `stats` frame body; see
    /// docs/PROTOCOL.md and `docs/OBSERVABILITY.md`). `None` for
    /// backends with no local registry (e.g. the remote
    /// [`NdjsonClient`] — ask the remote end with a `stats` op instead).
    ///
    /// [`NdjsonClient`]: crate::serving::frontend::NdjsonClient
    fn stats(&mut self) -> Option<crate::obs::StatsSnapshot> {
        None
    }

    /// Flight-recorder dump (the NDJSON `flightrec` frame body, protocol
    /// v3; see [`crate::obs::flightrec`]). `None` for backends with no
    /// local recorder (e.g. the remote [`NdjsonClient`] — ask the remote
    /// end with a `flightrec` op instead).
    ///
    /// [`NdjsonClient`]: crate::serving::frontend::NdjsonClient
    fn flightrec(&mut self) -> Option<crate::util::json::Json> {
        None
    }

    /// Chaos-testing hook: forcibly kill one fleet replica, as if its
    /// engine thread had crashed. Returns `true` if the kill was
    /// delivered (the replica existed and was alive). Default `false`
    /// for backends with no replicas to kill; implemented by the fleet
    /// [`Coordinator`] and relayed over the wire by [`NdjsonClient`]
    /// (`kill-replica` op, protocol v4).
    ///
    /// [`Coordinator`]: crate::coordinator::Coordinator
    /// [`NdjsonClient`]: crate::serving::frontend::NdjsonClient
    fn kill_replica(&mut self, _replica: usize) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn submit_error_codes_are_stable() {
        let cases = [
            (SubmitError::UnknownAdapter("x".into()), "unknown_adapter"),
            (SubmitError::QueueFull, "queue_full"),
            (SubmitError::Shed, "shed"),
            (SubmitError::ShuttingDown, "shutting_down"),
            (SubmitError::DeadlineUnmeetable, "deadline_unmeetable"),
            (SubmitError::Invalid("y".into()), "invalid"),
        ];
        for (e, code) in cases {
            assert_eq!(e.code(), code);
            assert!(!e.to_string().is_empty());
        }
    }

    #[test]
    fn token_event_reid_and_terminality() {
        let ev = TokenEvent::First { id: 1, token: 7 };
        assert!(!ev.is_terminal());
        let ev = ev.reid(42);
        assert_eq!(ev.id(), 42);
        let done = TokenEvent::Aborted { id: 3, reason: AbortReason::Cancelled };
        assert!(done.is_terminal());
        assert_eq!(done.reid(9).id(), 9);
        // Done payloads are re-addressed too (fleet rid mapping)
        let completion = Completion {
            id: 3,
            adapter: None,
            output: vec![],
            finish: crate::sampler::FinishReason::Length,
            record: crate::metrics::RequestRecord {
                id: 3,
                adapter: None,
                prompt_tokens: 1,
                output_tokens: 0,
                ttft: Duration::ZERO,
                tpot: None,
                e2e: Duration::ZERO,
            },
        };
        let TokenEvent::Done { id, completion } =
            (TokenEvent::Done { id: 3, completion }).reid(42)
        else {
            panic!("reid must preserve the variant");
        };
        assert_eq!(id, 42);
        assert_eq!(completion.id, 42);
        assert_eq!(completion.record.id, 42);
        assert_eq!(AbortReason::DeadlineExceeded.as_str(), "deadline");
        assert_eq!(
            AbortReason::Rejected(SubmitError::QueueFull).as_str(),
            "rejected"
        );
        assert_eq!(AbortReason::ReplicaLost.as_str(), "replica_lost");
    }

    #[test]
    fn handle_streams_in_order() {
        let (h, tx) = RequestHandle::new(5);
        assert!(h.try_event().is_none());
        tx.send(TokenEvent::First { id: 5, token: 1 }).unwrap();
        tx.send(TokenEvent::Token { id: 5, token: 2 }).unwrap();
        let evs = h.drain_events();
        assert_eq!(evs.len(), 2);
        assert!(matches!(evs[0], TokenEvent::First { token: 1, .. }));
        assert!(matches!(evs[1], TokenEvent::Token { token: 2, .. }));
    }
}
