//! Counting wrapper around the system allocator, used to *prove* the
//! step pipeline's zero-allocation steady state (`tests/hotpath_alloc.rs`
//! asserts it; `benches/fig11_hotpath.rs` reports it). Compiled only
//! under the test-only `alloc-counter` feature so normal builds keep the
//! system allocator untouched.
//!
//! The counter is global to the process: binaries that want it install
//! it themselves with
//!
//! ```ignore
//! #[global_allocator]
//! static GLOBAL: expertweave::util::alloc_counter::CountingAlloc =
//!     expertweave::util::alloc_counter::CountingAlloc;
//! ```
//!
//! and read [`allocations`] before/after the region under test.
//! Deallocations are not counted — the contract under test is "no new
//! heap blocks on the hot path", and frees of pre-existing blocks are
//! fine.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOCS: AtomicU64 = AtomicU64::new(0);

/// See module docs. Every `alloc`/`alloc_zeroed`/`realloc` bumps the
/// global counter, then defers to [`System`].
pub struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

/// Total heap allocations observed process-wide since start.
pub fn allocations() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}
