//! Tiny CLI argument parser (clap substitute).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args, and
//! generated `--help` text. Typed getters parse on access and report
//! errors naming the offending flag.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Declarative CLI spec + parsed values.
pub struct Args {
    program: String,
    about: String,
    specs: Vec<Spec>,
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

struct Spec {
    name: String,
    default: Option<String>,
    help: String,
    is_flag: bool,
}

impl Args {
    pub fn new(program: &str, about: &str) -> Self {
        Args {
            program: program.to_string(),
            about: about.to_string(),
            specs: Vec::new(),
            values: BTreeMap::new(),
            flags: Vec::new(),
            positional: Vec::new(),
        }
    }

    /// Declare `--name <value>` with optional default.
    pub fn opt(mut self, name: &str, default: Option<&str>, help: &str) -> Self {
        self.specs.push(Spec {
            name: name.to_string(),
            default: default.map(|s| s.to_string()),
            help: help.to_string(),
            is_flag: false,
        });
        self
    }

    /// Declare a boolean `--name` flag.
    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.specs.push(Spec {
            name: name.to_string(),
            default: None,
            help: help.to_string(),
            is_flag: true,
        });
        self
    }

    /// Parse an iterator of raw args (exclusive of argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(mut self, argv: I) -> Result<Self, String> {
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if a == "--help" || a == "-h" {
                return Err(self.usage());
            }
            if a == "--bench" {
                // cargo bench appends this to harness=false binaries
                continue;
            }
            if let Some(body) = a.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| format!("unknown option --{name}\n{}", self.usage()))?;
                if spec.is_flag {
                    if inline.is_some() {
                        return Err(format!("flag --{name} takes no value"));
                    }
                    self.flags.push(name);
                } else {
                    let v = match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| format!("option --{name} requires a value"))?,
                    };
                    self.values.insert(name, v);
                }
            } else {
                self.positional.push(a);
            }
        }
        Ok(self)
    }

    /// Parse from the process environment.
    pub fn parse_env(self) -> Result<Self, String> {
        self.parse(std::env::args().skip(1))
    }

    pub fn usage(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{} — {}", self.program, self.about);
        let _ = writeln!(s, "\nOptions:");
        for spec in &self.specs {
            let head = if spec.is_flag {
                format!("  --{}", spec.name)
            } else {
                format!("  --{} <v>", spec.name)
            };
            let dfl = spec
                .default
                .as_ref()
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            let _ = writeln!(s, "{head:<28}{}{dfl}", spec.help);
        }
        s
    }

    fn raw(&self, name: &str) -> Option<String> {
        if let Some(v) = self.values.get(name) {
            return Some(v.clone());
        }
        self.specs
            .iter()
            .find(|s| s.name == name)
            .and_then(|s| s.default.clone())
    }

    pub fn get(&self, name: &str) -> Option<String> {
        self.raw(name)
    }

    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.raw(name).unwrap_or_else(|| default.to_string())
    }

    pub fn get_parsed<T: std::str::FromStr>(&self, name: &str) -> Result<T, String> {
        let v = self
            .raw(name)
            .ok_or_else(|| format!("missing required option --{name}"))?;
        v.parse::<T>()
            .map_err(|_| format!("invalid value for --{name}: {v:?}"))
    }

    pub fn get_usize(&self, name: &str) -> Result<usize, String> {
        self.get_parsed(name)
    }

    pub fn get_f64(&self, name: &str) -> Result<f64, String> {
        self.get_parsed(name)
    }

    /// Comma-separated list, e.g. `--lam 1,2,5`.
    pub fn get_list<T: std::str::FromStr>(&self, name: &str) -> Result<Vec<T>, String> {
        let v = self
            .raw(name)
            .ok_or_else(|| format!("missing required option --{name}"))?;
        v.split(',')
            .filter(|s| !s.is_empty())
            .map(|s| {
                s.trim()
                    .parse::<T>()
                    .map_err(|_| format!("invalid element in --{name}: {s:?}"))
            })
            .collect()
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk() -> Args {
        Args::new("t", "test")
            .opt("config", Some("tiny"), "model config")
            .opt("lam", None, "arrival rates")
            .flag("verbose", "log more")
    }

    fn strs(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let a = mk().parse(strs(&[])).unwrap();
        assert_eq!(a.get_or("config", "x"), "tiny");
        let a = mk().parse(strs(&["--config", "small"])).unwrap();
        assert_eq!(a.get_or("config", "x"), "small");
        let a = mk().parse(strs(&["--config=small"])).unwrap();
        assert_eq!(a.get_or("config", "x"), "small");
    }

    #[test]
    fn flags_and_positional() {
        let a = mk().parse(strs(&["--verbose", "pos1", "pos2"])).unwrap();
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional(), &["pos1", "pos2"]);
        assert!(!mk().parse(strs(&[])).unwrap().has_flag("verbose"));
    }

    #[test]
    fn typed_and_lists() {
        let a = mk().parse(strs(&["--lam", "1,2.5,5"])).unwrap();
        assert_eq!(a.get_list::<f64>("lam").unwrap(), vec![1.0, 2.5, 5.0]);
        assert!(a.get_usize("lam").is_err());
    }

    #[test]
    fn errors() {
        assert!(mk().parse(strs(&["--nope"])).is_err());
        assert!(mk().parse(strs(&["--lam"])).is_err());
        assert!(mk().parse(strs(&["--verbose=1"])).is_err());
        assert!(mk().parse(strs(&["--help"])).is_err());
    }
}
