//! Minimal JSON parser/serializer (serde substitute).
//!
//! Covers the full JSON grammar needed by `artifacts/*/meta.json` and the
//! bench CSV/JSON reports: objects, arrays, strings (with escapes),
//! numbers, booleans, null. Numbers are kept as `f64` plus an exact `i64`
//! fast path for integers.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    /// Integer fast path (exact for |x| < 2^63).
    Int(i64),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style access; returns Null for missing paths.
    pub fn at(&self, path: &[&str]) -> &Json {
        let mut cur = self;
        for k in path {
            match cur.get(k) {
                Some(v) => cur = v,
                None => return &Json::Null,
            }
        }
        cur
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Int(i) => Some(*i),
            Json::Num(f) if f.fract() == 0.0 => Some(*f as i64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|v| usize::try_from(v).ok())
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Int(i) => Some(*i as f64),
            Json::Num(f) => Some(*f),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Shape-style arrays: `[2, 3, 4]` → `vec![2, 3, 4]`.
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Int(i) => write!(f, "{i}"),
            Json::Num(n) => {
                if n.is_finite() {
                    write!(f, "{n}")
                } else {
                    write!(f, "null") // JSON has no Inf/NaN
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.i, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            self.i += 1;
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or_else(|| self.err("short \\u escape"))?;
                            let cp = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| self.err("bad \\u escape"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs: join if a high surrogate.
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                self.i += 4;
                                if self.b.get(self.i) != Some(&b'\\')
                                    || self.b.get(self.i + 1) != Some(&b'u')
                                {
                                    return Err(self.err("lone high surrogate"));
                                }
                                self.i += 2;
                                let hex2 = self
                                    .b
                                    .get(self.i..self.i + 4)
                                    .ok_or_else(|| self.err("short \\u escape"))?;
                                let lo = u32::from_str_radix(
                                    std::str::from_utf8(hex2)
                                        .map_err(|_| self.err("bad \\u escape"))?,
                                    16,
                                )
                                .map_err(|_| self.err("bad \\u escape"))?;
                                self.i -= 1; // compensated below
                                char::from_u32(
                                    0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00),
                                )
                                .ok_or_else(|| self.err("bad surrogate pair"))?
                            } else {
                                self.i -= 1; // compensated below
                                char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?
                            };
                            s.push(c);
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a run of plain bytes (UTF-8 passes through)
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        let mut is_float = false;
        if self.peek() == Some(b'.') {
            is_float = true;
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            is_float = true;
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        if !is_float {
            if let Ok(i) = text.parse::<i64>() {
                return Ok(Json::Int(i));
            }
        }
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

/// Convenience builders for report emission.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
    Json::Arr(items.into_iter().collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-42").unwrap(), Json::Int(-42));
        assert_eq!(Json::parse("2.5e3").unwrap(), Json::Num(2500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.at(&["a"]).as_arr().unwrap().len(), 3);
        assert_eq!(v.at(&["c"]).as_str(), Some("x"));
        assert_eq!(v.at(&["a"]).as_arr().unwrap()[2].at(&["b"]), &Json::Null);
    }

    #[test]
    fn parses_escapes() {
        let v = Json::parse(r#""a\nb\t\"q\" é 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"q\" é 😀");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("01x").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("{}extra").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"shape":[8,2,64],"dtype":"f32","n":-3,"f":0.5,"ok":true,"s":"a\"b"}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn roundtrip_randomized() {
        // property: parse(to_string(x)) == x for machine-built values
        let mut rng = crate::util::rng::Pcg::new(7);
        for _ in 0..200 {
            let v = random_json(&mut rng, 3);
            let text = v.to_string();
            assert_eq!(Json::parse(&text).unwrap(), v, "text: {text}");
        }
    }

    fn random_json(rng: &mut crate::util::rng::Pcg, depth: u32) -> Json {
        match rng.next_u64() % if depth == 0 { 4 } else { 6 } {
            0 => Json::Null,
            1 => Json::Bool(rng.next_u64() % 2 == 0),
            2 => Json::Int(rng.next_u64() as i64 >> 16),
            3 => Json::Str(format!("s{}", rng.next_u64() % 1000)),
            4 => Json::Arr((0..rng.next_u64() % 4).map(|_| random_json(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.next_u64() % 4)
                    .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }

    #[test]
    fn usize_vec() {
        let v = Json::parse("[2, 3, 4]").unwrap();
        assert_eq!(v.as_usize_vec().unwrap(), vec![2, 3, 4]);
        assert!(Json::parse("[2, -1]").unwrap().as_usize_vec().is_none());
    }
}
