//! Leveled stderr logging with monotonic timestamps (log-crate substitute,
//! no global state beyond an atomic level).

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        _ => Level::Debug,
    }
}

/// Pure gating predicate: would a message at `msg` print when the
/// global level is `current`? Split out from [`enabled`] so gating is
/// testable without mutating the process-wide `LEVEL` atomic (tests run
/// concurrently; a test that flips the global races every other test
/// that logs).
pub fn enabled_at(msg: Level, current: Level) -> bool {
    msg <= current
}

pub fn enabled(level: Level) -> bool {
    enabled_at(level, self::level())
}

#[doc(hidden)]
pub fn log_impl(level: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
    let tag = match level {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
    };
    eprintln!("[{t:9.3}s {tag} {target}] {msg}");
}

#[macro_export]
macro_rules! log_error {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log_impl($crate::util::logging::Level::Error, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log_impl($crate::util::logging::Level::Warn, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log_impl($crate::util::logging::Level::Info, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log_impl($crate::util::logging::Level::Debug, $target, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    // exercises the pure predicate only: mutating the global LEVEL here
    // would race concurrently-running tests that log
    #[test]
    fn level_gating() {
        assert!(enabled_at(Level::Error, Level::Warn));
        assert!(enabled_at(Level::Warn, Level::Warn));
        assert!(!enabled_at(Level::Info, Level::Warn));
        assert!(enabled_at(Level::Info, Level::Info));
        assert!(!enabled_at(Level::Debug, Level::Info));
        assert!(enabled_at(Level::Error, Level::Error));
        assert!(!enabled_at(Level::Warn, Level::Error));
    }

    #[test]
    fn default_level_is_info() {
        // read-only on the global: the process default admits info and
        // below unless a CLI flag changed it
        assert!(level() >= Level::Error);
    }
}
