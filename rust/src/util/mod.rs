//! Commodity substrates built in-tree because the image is offline
//! (no serde/clap/criterion/tokio): JSON, CLI args, PRNG, stats,
//! logging and a tiny property-testing helper.

#[cfg(feature = "alloc-counter")]
pub mod alloc_counter;
pub mod args;
pub mod json;
pub mod logging;
pub mod prop;
pub mod rng;
pub mod stats;
