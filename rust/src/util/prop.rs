//! Micro property-testing helper (proptest substitute).
//!
//! `check(seed, cases, |rng| ...)` runs a randomized invariant many times
//! with independent PRNG streams and reports the failing case index + its
//! reproduction seed on panic, so failures are one-line reproducible:
//!
//! ```text
//! property failed at case 17 (repro: Pcg::with_stream(SEED, 17))
//! ```

use super::rng::Pcg;

/// Run `f` for `cases` independent randomized cases.
///
/// Each case gets its own PRNG stream derived from `seed` and the case
/// index; any panic inside `f` is annotated with the case index so it can
/// be replayed in isolation with [`replay`].
pub fn check<F: Fn(&mut Pcg) + std::panic::RefUnwindSafe>(seed: u64, cases: u64, f: F) {
    for case in 0..cases {
        let result = std::panic::catch_unwind(|| {
            let mut rng = Pcg::with_stream(seed, case);
            f(&mut rng);
        });
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property failed at case {case} (repro: prop::replay({seed}, {case})): {msg}"
            );
        }
    }
}

/// Re-run a single failing case from [`check`].
pub fn replay<F: FnOnce(&mut Pcg)>(seed: u64, case: u64, f: F) {
    let mut rng = Pcg::with_stream(seed, case);
    f(&mut rng);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_when_invariant_holds() {
        check(1, 50, |rng| {
            let x = rng.below(100);
            assert!(x < 100);
        });
    }

    #[test]
    fn reports_case_on_failure() {
        let r = std::panic::catch_unwind(|| {
            check(2, 50, |rng| {
                // fail when we draw a value in the upper half
                assert!(rng.below(100) < 50, "drew upper half");
            });
        });
        let err = r.unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("property failed at case"), "{msg}");
        assert!(msg.contains("drew upper half"), "{msg}");
    }
}
