//! PCG-XSH-RR 64/32 pseudo-random generator plus the distributions the
//! workload generator needs (uniform, normal, exponential, power-law,
//! Poisson-process gaps). Deterministic and seedable — every experiment in
//! EXPERIMENTS.md records its seed.

/// PCG-XSH-RR 64/32 (O'Neill 2014). Small, fast, good statistical quality.
#[derive(Debug, Clone)]
pub struct Pcg {
    state: u64,
    inc: u64,
}

const MUL: u64 = 6364136223846793005;

impl Pcg {
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e39cb94b95bdb)
    }

    /// Independent stream per `stream` value (odd increment).
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg { state: 0, inc: (stream << 1) | 1 };
        rng.state = rng.inc.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(MUL).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, n)` without modulo bias (Lemire's method).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        loop {
            let x = self.next_u64();
            let (hi, lo) = mul128(x, n);
            if lo >= n.wrapping_neg() % n {
                return hi;
            }
            let _ = lo;
        }
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with rate `lambda` (Poisson inter-arrival gap).
    pub fn exp(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        let u = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        -u.ln() / lambda
    }

    /// Pick an index from a normalized discrete distribution.
    pub fn categorical(&mut self, probs: &[f64]) -> usize {
        let x = self.f64();
        let mut acc = 0.0;
        for (i, p) in probs.iter().enumerate() {
            acc += p;
            if x < acc {
                return i;
            }
        }
        probs.len() - 1
    }

    /// Sample `k` distinct values from `0..n` (Floyd's algorithm), sorted.
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut chosen = std::collections::BTreeSet::new();
        for j in (n - k)..n {
            let t = self.below((j + 1) as u64) as usize;
            if !chosen.insert(t) {
                chosen.insert(j);
            }
        }
        chosen.into_iter().collect()
    }
}

fn mul128(a: u64, b: u64) -> (u64, u64) {
    let r = (a as u128) * (b as u128);
    ((r >> 64) as u64, r as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg::new(42);
        let mut b = Pcg::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg::with_stream(1, 1);
        let mut b = Pcg::with_stream(1, 2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn below_in_range_and_roughly_uniform() {
        let mut rng = Pcg::new(7);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[rng.below(10) as usize] += 1;
        }
        for c in counts {
            assert!((8_000..12_000).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut rng = Pcg::new(3);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / 10_000.0 - 0.5).abs() < 0.02);
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg::new(5);
        let n = 50_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn exp_mean() {
        let mut rng = Pcg::new(9);
        let lambda = 4.0;
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| rng.exp(lambda)).sum::<f64>() / n as f64;
        assert!((mean - 1.0 / lambda).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn sample_distinct_properties() {
        let mut rng = Pcg::new(11);
        for _ in 0..200 {
            let n = 1 + rng.below(40) as usize;
            let k = rng.below((n + 1) as u64) as usize;
            let s = rng.sample_distinct(n, k);
            assert_eq!(s.len(), k);
            assert!(s.windows(2).all(|w| w[0] < w[1]), "sorted distinct");
            assert!(s.iter().all(|&x| x < n));
        }
    }

    #[test]
    fn categorical_distribution() {
        let mut rng = Pcg::new(13);
        let probs = [0.5, 0.3, 0.2];
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[rng.categorical(&probs)] += 1;
        }
        assert!((counts[0] as f64 / 30_000.0 - 0.5).abs() < 0.02);
        assert!((counts[1] as f64 / 30_000.0 - 0.3).abs() < 0.02);
    }
}
