//! Latency/throughput statistics: online summaries and percentile
//! estimation over recorded samples. Used by [`crate::metrics`] and the
//! bench harness ([`crate::bench`]).

/// A collected sample set with percentile queries (exact, sorted lazily).
#[derive(Debug, Clone, Default)]
pub struct Samples {
    values: Vec<f64>,
    sorted: bool,
}

impl Samples {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, v: f64) {
        self.values.push(v);
        self.sorted = false;
    }

    /// Pre-size for `additional` more samples (hot loops that must not
    /// reallocate mid-measurement, e.g. the zero-allocation step test).
    pub fn reserve(&mut self, additional: usize) {
        self.values.reserve(additional);
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.values.is_empty() {
            return f64::NAN;
        }
        self.values.iter().sum::<f64>() / self.values.len() as f64
    }

    pub fn sum(&self) -> f64 {
        self.values.iter().sum()
    }

    pub fn min(&self) -> f64 {
        self.values.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.values.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn std(&self) -> f64 {
        let n = self.values.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.values.iter().map(|v| (v - m) * (v - m)).sum::<f64>() / (n - 1) as f64).sqrt()
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.values.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
    }

    /// Exact percentile by linear interpolation, `p` in [0, 100].
    pub fn percentile(&mut self, p: f64) -> f64 {
        if self.values.is_empty() {
            return f64::NAN;
        }
        self.ensure_sorted();
        let n = self.values.len();
        if n == 1 {
            return self.values[0];
        }
        let rank = (p / 100.0) * (n - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        let frac = rank - lo as f64;
        self.values[lo] * (1.0 - frac) + self.values[hi] * frac
    }

    pub fn median(&mut self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p99(&mut self) -> f64 {
        self.percentile(99.0)
    }

    /// `(mean, median, p99, min, max)` summary tuple. An empty sample
    /// set yields NaN statistics across the board (not the fold
    /// identities ±inf for min/max), so empty-run reports render as
    /// "NaN" rather than pseudo-values — regression guard for
    /// aggregation over zero outcomes.
    pub fn summary(&mut self) -> Summary {
        if self.values.is_empty() {
            return Summary {
                n: 0,
                mean: f64::NAN,
                median: f64::NAN,
                p90: f64::NAN,
                p99: f64::NAN,
                min: f64::NAN,
                max: f64::NAN,
                std: 0.0,
            };
        }
        Summary {
            n: self.len(),
            mean: self.mean(),
            median: self.median(),
            p90: self.percentile(90.0),
            p99: self.p99(),
            min: self.min(),
            max: self.max(),
            std: self.std(),
        }
    }
}

/// Precomputed summary of a [`Samples`] set.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub median: f64,
    pub p90: f64,
    pub p99: f64,
    pub min: f64,
    pub max: f64,
    pub std: f64,
}

impl std::fmt::Display for Summary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "n={} mean={:.3} p50={:.3} p90={:.3} p99={:.3} min={:.3} max={:.3}",
            self.n, self.mean, self.median, self.p90, self.p99, self.min, self.max
        )
    }
}

/// Fixed-boundary histogram for long-running online aggregation
/// (O(1) memory irrespective of request count).
#[derive(Debug, Clone)]
pub struct Histogram {
    bounds: Vec<f64>, // ascending upper bounds; last bucket = +inf
    counts: Vec<u64>,
    total: u64,
    sum: f64,
}

impl Histogram {
    /// Exponential buckets covering `[lo, hi]` with `n` buckets.
    pub fn exponential(lo: f64, hi: f64, n: usize) -> Self {
        assert!(lo > 0.0 && hi > lo && n >= 2);
        let ratio = (hi / lo).powf(1.0 / (n - 1) as f64);
        let mut bounds: Vec<f64> = (0..n).map(|i| lo * ratio.powi(i as i32)).collect();
        bounds.push(f64::INFINITY);
        let len = bounds.len();
        Histogram { bounds, counts: vec![0; len], total: 0, sum: 0.0 }
    }

    pub fn record(&mut self, v: f64) {
        let idx = self.bounds.partition_point(|&b| b < v);
        self.counts[idx] += 1;
        self.total += 1;
        self.sum += v;
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            f64::NAN
        } else {
            self.sum / self.total as f64
        }
    }

    /// Percentile estimate: upper bound of the bucket containing the rank.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.total == 0 {
            return f64::NAN;
        }
        let rank = ((p / 100.0) * self.total as f64).ceil().max(1.0) as u64;
        let mut acc = 0;
        for (i, c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= rank {
                return self.bounds[i];
            }
        }
        *self.bounds.last().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_exact() {
        let mut s = Samples::new();
        for v in [5.0, 1.0, 3.0, 2.0, 4.0] {
            s.push(v);
        }
        assert_eq!(s.median(), 3.0);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 5.0);
        assert_eq!(s.percentile(25.0), 2.0);
    }

    #[test]
    fn summary_sane() {
        let mut s = Samples::new();
        for i in 1..=100 {
            s.push(i as f64);
        }
        let sum = s.summary();
        assert_eq!(sum.n, 100);
        assert!((sum.mean - 50.5).abs() < 1e-9);
        assert!((sum.median - 50.5).abs() < 1e-9);
        assert!((sum.p99 - 99.01).abs() < 0.02);
    }

    #[test]
    fn empty_is_nan() {
        let mut s = Samples::new();
        assert!(s.mean().is_nan());
        assert!(s.median().is_nan());
        let sum = s.summary();
        assert_eq!(sum.n, 0);
        assert!(sum.mean.is_nan());
        assert!(sum.min.is_nan() && sum.max.is_nan(), "no ±inf fold identities");
        assert_eq!(sum.std, 0.0);
    }

    #[test]
    fn histogram_percentile_bounds_true_value() {
        let mut h = Histogram::exponential(1e-4, 10.0, 64);
        let mut rng = crate::util::rng::Pcg::new(1);
        let mut s = Samples::new();
        for _ in 0..10_000 {
            let v = rng.exp(2.0);
            h.record(v);
            s.push(v);
        }
        // histogram p99 within one bucket ratio of exact p99
        let exact = s.p99();
        let est = h.percentile(99.0);
        assert!(est >= exact, "estimate must upper-bound");
        assert!(est / exact < 1.35, "est {est} exact {exact}");
    }

    #[test]
    fn histogram_mean_exact() {
        let mut h = Histogram::exponential(0.1, 10.0, 8);
        for v in [0.5, 1.5, 2.5] {
            h.record(v);
        }
        assert!((h.mean() - 1.5).abs() < 1e-12);
        assert_eq!(h.count(), 3);
    }
}
