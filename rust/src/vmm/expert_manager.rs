//! The paper's **expert memory manager** (section 4.2): maps physical
//! pages only under occupied expert slots of a virtual weight tensor,
//! with *sub-page allocation* — a partially filled boundary page is shared
//! by the neighbouring adapter's experts via reference counting, so
//! expert/page misalignment never wastes a page or double-maps one.
//!
//! The manager is generic over a [`Backing`]:
//! * [`Backing::Real`] — a live [`VirtualSpace`] + [`PagePool`] (memfd
//!   pages; bytes are readable/writable and feed PJRT buffer uploads).
//! * [`Backing::Accounting`] — no memory is touched; page map/unmap
//!   charge a [`DeviceMemory`] ledger. Used to run the *same allocator
//!   logic* at paper scale (16B model, 64 GB device) for Fig. 9.

use super::page_pool::{PageId, PagePool};
use super::virtual_mem::VirtualSpace;
use crate::memsim::DeviceMemory;
use anyhow::{bail, Context, Result};
use std::collections::{BTreeMap, HashMap};
use std::sync::{Arc, Mutex};

/// Physical backing of an [`ExpertMemoryManager`].
pub enum Backing {
    /// memfd-backed pages, really mapped into the reserved range.
    Real { space: VirtualSpace, pool: Arc<Mutex<PagePool>> },
    /// Ledger-only: page map/unmap charges `page_size` bytes to `device`.
    Accounting { device: Arc<Mutex<DeviceMemory>>, mapped: std::collections::BTreeSet<usize> },
}

/// Memory statistics of one virtual weight tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MemStats {
    /// Pages currently mapped (physical commitment).
    pub mapped_pages: usize,
    /// `mapped_pages * page_size`.
    pub mapped_bytes: usize,
    /// Bytes of expert weights actually loaded (no padding).
    pub used_bytes: usize,
    /// Bytes the padding approach would commit for the same loads
    /// (full reservation of every slot ever addressable is *not* counted;
    /// this is the virtual span of loaded adapters — see
    /// `weights::padding` for the baseline's own accounting).
    pub reserved_bytes: usize,
}

/// Manages physical pages for one virtual weight tensor of
/// `total_slots` expert slots of `expert_size` bytes each.
pub struct ExpertMemoryManager {
    expert_size: usize,
    total_slots: usize,
    page_size: usize,
    /// page index -> number of loaded ranges touching it
    refcount: HashMap<usize, u32>,
    /// first_slot -> slot count of each loaded range
    loaded: BTreeMap<usize, usize>,
    backing: Backing,
    used_bytes: usize,
}

impl ExpertMemoryManager {
    /// Real backing: reserve the full virtual span, share `pool` pages.
    pub fn new_real(
        expert_size: usize,
        total_slots: usize,
        pool: Arc<Mutex<PagePool>>,
    ) -> Result<Self> {
        let page_size = pool.lock().unwrap().page_size();
        let total_bytes = expert_size
            .checked_mul(total_slots)
            .context("tensor size overflow")?;
        let pages = total_bytes.div_ceil(page_size);
        let space = VirtualSpace::reserve(page_size, pages)?;
        Ok(ExpertMemoryManager {
            expert_size,
            total_slots,
            page_size,
            refcount: HashMap::new(),
            loaded: BTreeMap::new(),
            backing: Backing::Real { space, pool },
            used_bytes: 0,
        })
    }

    /// Accounting backing: identical allocator behaviour, ledger-only.
    pub fn new_accounting(
        expert_size: usize,
        total_slots: usize,
        page_size: usize,
        device: Arc<Mutex<DeviceMemory>>,
    ) -> Self {
        ExpertMemoryManager {
            expert_size,
            total_slots,
            page_size,
            refcount: HashMap::new(),
            loaded: BTreeMap::new(),
            backing: Backing::Accounting { device, mapped: Default::default() },
            used_bytes: 0,
        }
    }

    pub fn expert_size(&self) -> usize {
        self.expert_size
    }

    pub fn page_size(&self) -> usize {
        self.page_size
    }

    pub fn total_slots(&self) -> usize {
        self.total_slots
    }

    /// Page indices covered by a slot range's bytes.
    fn pages_of(&self, first_slot: usize, n_slots: usize) -> std::ops::RangeInclusive<usize> {
        let lo = first_slot * self.expert_size;
        let hi = (first_slot + n_slots) * self.expert_size - 1;
        (lo / self.page_size)..=(hi / self.page_size)
    }

    fn overlaps_loaded(&self, first_slot: usize, n_slots: usize) -> bool {
        self.loaded.iter().any(|(&s, &n)| s < first_slot + n_slots && first_slot < s + n)
    }

    fn map_one(&mut self, page_index: usize) -> Result<()> {
        match &mut self.backing {
            Backing::Real { space, pool } => {
                let page = {
                    let mut p = pool.lock().unwrap();
                    p.alloc(1)?[0]
                };
                if let Err(e) = space.map_page(page_index, page, &pool.lock().unwrap()) {
                    pool.lock().unwrap().free(&[page]);
                    return Err(e);
                }
                Ok(())
            }
            Backing::Accounting { device, mapped } => {
                device.lock().unwrap().alloc(self.page_size)?;
                mapped.insert(page_index);
                Ok(())
            }
        }
    }

    fn unmap_one(&mut self, page_index: usize) {
        match &mut self.backing {
            Backing::Real { space, pool } => {
                let page = space
                    .unmap_page(page_index)
                    .expect("refcounted page must be mapped");
                pool.lock().unwrap().free(&[page]);
            }
            Backing::Accounting { device, mapped } => {
                assert!(mapped.remove(&page_index), "unmap of unmapped page");
                device.lock().unwrap().release(self.page_size);
            }
        }
    }

    /// Load a contiguous range of expert slots (paper: mapping
    /// `[Δ_i : Δ_i + e_i^(l)]`), committing only the pages that are not
    /// already mapped by a neighbouring range (sub-page sharing).
    ///
    /// On OOM the operation is rolled back completely.
    pub fn load_range(&mut self, first_slot: usize, n_slots: usize) -> Result<()> {
        if n_slots == 0 {
            return Ok(());
        }
        if first_slot + n_slots > self.total_slots {
            bail!(
                "slot range [{first_slot}, {}) exceeds tensor slots {}",
                first_slot + n_slots,
                self.total_slots
            );
        }
        if self.overlaps_loaded(first_slot, n_slots) {
            bail!("slot range [{first_slot}, {}) overlaps a loaded range", first_slot + n_slots);
        }
        let mut newly_mapped: Vec<usize> = Vec::new();
        for page in self.pages_of(first_slot, n_slots) {
            if self.refcount.get(&page).copied().unwrap_or(0) == 0 {
                if let Err(e) = self.map_one(page) {
                    // roll back pages mapped so far by this call
                    for &p in &newly_mapped {
                        self.refcount.remove(&p);
                        self.unmap_one(p);
                    }
                    return Err(e);
                }
                newly_mapped.push(page);
            }
            *self.refcount.entry(page).or_insert(0) += 1;
        }
        self.loaded.insert(first_slot, n_slots);
        self.used_bytes += n_slots * self.expert_size;
        Ok(())
    }

    /// Unload a previously loaded range; pages whose refcount drops to 0
    /// are unmapped and returned to the pool (`aclrtUnmapMem` +
    /// `aclrtFreePhysical`).
    pub fn unload_range(&mut self, first_slot: usize) -> Result<()> {
        let n_slots = match self.loaded.remove(&first_slot) {
            Some(n) => n,
            None => bail!("no loaded range starts at slot {first_slot}"),
        };
        for page in self.pages_of(first_slot, n_slots) {
            let rc = self
                .refcount
                .get_mut(&page)
                .expect("loaded range must have refcounted pages");
            *rc -= 1;
            if *rc == 0 {
                self.refcount.remove(&page);
                self.unmap_one(page);
            }
        }
        self.used_bytes -= n_slots * self.expert_size;
        Ok(())
    }

    /// Copy one expert's weights into its slot (real backing only).
    pub fn write_expert(&mut self, slot: usize, data: &[u8]) -> Result<()> {
        if data.len() != self.expert_size {
            bail!("expert data {} B != expert_size {} B", data.len(), self.expert_size);
        }
        match &mut self.backing {
            Backing::Real { space, .. } => space.write(slot * self.expert_size, data),
            Backing::Accounting { .. } => bail!("write on accounting backing"),
        }
    }

    /// Read one expert's weights back (real backing only).
    pub fn read_expert(&self, slot: usize, out: &mut [u8]) -> Result<()> {
        match &self.backing {
            Backing::Real { space, .. } => space.read(slot * self.expert_size, out),
            Backing::Accounting { .. } => bail!("read on accounting backing"),
        }
    }

    /// Borrow a loaded slot range as `f32`s (PJRT upload path).
    pub fn slice_f32(&self, first_slot: usize, n_slots: usize) -> Result<&[f32]> {
        match &self.backing {
            Backing::Real { space, .. } => space.slice_f32(
                first_slot * self.expert_size,
                n_slots * self.expert_size / std::mem::size_of::<f32>(),
            ),
            Backing::Accounting { .. } => bail!("slice on accounting backing"),
        }
    }

    pub fn is_loaded(&self, first_slot: usize) -> bool {
        self.loaded.contains_key(&first_slot)
    }

    pub fn stats(&self) -> MemStats {
        let mapped_pages = self.refcount.len();
        let reserved_bytes = self
            .loaded
            .iter()
            .map(|(_, &n)| n * self.expert_size)
            .sum::<usize>();
        MemStats {
            mapped_pages,
            mapped_bytes: mapped_pages * self.page_size,
            used_bytes: self.used_bytes,
            reserved_bytes,
        }
    }
}

impl Drop for ExpertMemoryManager {
    fn drop(&mut self) {
        // Release everything (pages back to pool / ledger).
        let starts: Vec<usize> = self.loaded.keys().copied().collect();
        for s in starts {
            let _ = self.unload_range(s);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const PS: usize = 64 << 10; // 64 KB test pages

    fn real_mgr(expert_size: usize, slots: usize, pool_pages: usize) -> (ExpertMemoryManager, Arc<Mutex<PagePool>>) {
        let pool = Arc::new(Mutex::new(PagePool::new(PS, pool_pages).unwrap()));
        let mgr = ExpertMemoryManager::new_real(expert_size, slots, pool.clone()).unwrap();
        (mgr, pool)
    }

    #[test]
    fn load_maps_only_covering_pages() {
        // expert = 1.5 pages (the paper's Fig. 3 example)
        let esz = PS * 3 / 2;
        let (mut mgr, pool) = real_mgr(esz, 8, 32);
        mgr.load_range(0, 2).unwrap(); // 3 pages exactly
        assert_eq!(mgr.stats().mapped_pages, 3);
        assert_eq!(pool.lock().unwrap().allocated_pages(), 3);
        // slots 2..8 unmapped: no physical cost for padding
        assert_eq!(mgr.stats().used_bytes, 2 * esz);
    }

    #[test]
    fn subpage_sharing_between_neighbouring_ranges() {
        // expert = half a page: ranges [0,1) and [1,2) share page 0
        let esz = PS / 2;
        let (mut mgr, pool) = real_mgr(esz, 8, 32);
        mgr.load_range(0, 1).unwrap();
        assert_eq!(pool.lock().unwrap().allocated_pages(), 1);
        mgr.load_range(1, 1).unwrap();
        // second load shares the already-mapped page — no new page
        assert_eq!(pool.lock().unwrap().allocated_pages(), 1);
        // unloading the first range must keep the shared page alive
        mgr.unload_range(0).unwrap();
        assert_eq!(pool.lock().unwrap().allocated_pages(), 1);
        mgr.unload_range(1).unwrap();
        assert_eq!(pool.lock().unwrap().allocated_pages(), 0);
    }

    #[test]
    fn misaligned_boundary_page_shared() {
        // Fig. 3: expert = 1.5 pages; adapter A = slots [0,2), B = [3,4).
        // B starts at byte 4.5*PS -> page 4; A's pages are 0,1,2.
        let esz = PS * 3 / 2;
        let (mut mgr, pool) = real_mgr(esz, 8, 32);
        mgr.load_range(0, 2).unwrap(); // pages 0..=2
        mgr.load_range(3, 1).unwrap(); // bytes [4.5PS, 6PS) -> pages 4,5
        assert_eq!(pool.lock().unwrap().allocated_pages(), 5);
        // now load slot 2 (bytes [3PS, 4.5PS) -> pages 3,4): page 4 shared
        mgr.load_range(2, 1).unwrap();
        assert_eq!(pool.lock().unwrap().allocated_pages(), 6);
        mgr.unload_range(3).unwrap(); // page 5 freed, page 4 kept (shared)
        assert_eq!(pool.lock().unwrap().allocated_pages(), 5);
    }

    #[test]
    fn write_read_roundtrip_across_pages() {
        let esz = PS + 1024; // misaligned on purpose
        let (mut mgr, _pool) = real_mgr(esz, 4, 32);
        mgr.load_range(1, 2).unwrap();
        let data: Vec<u8> = (0..esz).map(|i| (i % 251) as u8).collect();
        mgr.write_expert(2, &data).unwrap();
        let mut back = vec![0u8; esz];
        mgr.read_expert(2, &mut back).unwrap();
        assert_eq!(back, data);
        // slot 0 not loaded: write must fail, not fault
        assert!(mgr.write_expert(0, &data).is_err());
    }

    #[test]
    fn oom_rolls_back_cleanly() {
        let esz = PS;
        let (mut mgr, pool) = real_mgr(esz, 16, 4);
        mgr.load_range(0, 3).unwrap();
        // needs 5 pages, only 1 left -> OOM, nothing must leak
        assert!(mgr.load_range(4, 5).is_err());
        assert_eq!(pool.lock().unwrap().allocated_pages(), 3);
        assert_eq!(mgr.stats().mapped_pages, 3);
        // and we can still load what fits
        mgr.load_range(4, 1).unwrap();
    }

    #[test]
    fn overlap_rejected() {
        let (mut mgr, _pool) = real_mgr(PS, 8, 16);
        mgr.load_range(2, 3).unwrap();
        assert!(mgr.load_range(4, 2).is_err());
        assert!(mgr.load_range(0, 3).is_err());
        mgr.load_range(0, 2).unwrap();
    }

    #[test]
    fn unload_unknown_range_rejected() {
        let (mut mgr, _pool) = real_mgr(PS, 8, 16);
        mgr.load_range(0, 2).unwrap();
        assert!(mgr.unload_range(1).is_err()); // 1 is inside, not a start
        mgr.unload_range(0).unwrap();
    }

    #[test]
    fn accounting_backing_matches_real_page_counts() {
        let esz = PS * 3 / 2;
        let device = DeviceMemory::shared(PS * 1000);
        let mut acc = ExpertMemoryManager::new_accounting(esz, 64, PS, device.clone());
        let (mut real, pool) = real_mgr(esz, 64, 1000);
        let loads = [(0usize, 2usize), (5, 3), (8, 1), (20, 4)];
        for &(s, n) in &loads {
            acc.load_range(s, n).unwrap();
            real.load_range(s, n).unwrap();
            assert_eq!(acc.stats(), real.stats());
            assert_eq!(
                device.lock().unwrap().used(),
                pool.lock().unwrap().allocated_pages() * PS
            );
        }
        acc.unload_range(5).unwrap();
        real.unload_range(5).unwrap();
        assert_eq!(acc.stats(), real.stats());
    }

    #[test]
    fn accounting_oom_at_budget() {
        let device = DeviceMemory::shared(PS * 2);
        let mut acc = ExpertMemoryManager::new_accounting(PS, 16, PS, device);
        acc.load_range(0, 2).unwrap();
        assert!(acc.load_range(4, 1).is_err());
    }

    #[test]
    fn property_refcounts_equal_covering_ranges() {
        crate::util::prop::check(303, 25, |rng| {
            let esz = (1 + rng.below(4) as usize) * PS / 2 + if rng.below(2) == 0 { 0 } else { 4096 };
            let slots = 32;
            let device = DeviceMemory::shared(usize::MAX / 2);
            let mut mgr = ExpertMemoryManager::new_accounting(esz, slots, PS, device);
            let mut model: Vec<(usize, usize)> = Vec::new();
            for _ in 0..60 {
                if rng.below(2) == 0 {
                    let s = rng.below(slots as u64) as usize;
                    let n = 1 + rng.below(4) as usize;
                    if s + n <= slots && mgr.load_range(s, n).is_ok() {
                        model.push((s, n));
                    }
                } else if !model.is_empty() {
                    let i = rng.below(model.len() as u64) as usize;
                    let (s, _) = model.swap_remove(i);
                    mgr.unload_range(s).unwrap();
                }
                // model-check: mapped pages == union of pages of loaded ranges
                let mut pages = std::collections::BTreeSet::new();
                for &(s, n) in &model {
                    let lo = s * esz / PS;
                    let hi = ((s + n) * esz - 1) / PS;
                    pages.extend(lo..=hi);
                }
                assert_eq!(mgr.stats().mapped_pages, pages.len());
                let used: usize = model.iter().map(|&(_, n)| n * esz).sum();
                assert_eq!(mgr.stats().used_bytes, used);
            }
        });
    }
}
