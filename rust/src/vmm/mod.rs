//! Virtual-memory-assisted expert weight management (paper section 4.2).
//!
//! The paper decouples *virtual address reservation* from *physical page
//! commitment* using AscendCL VMM APIs so that the padded regions of the
//! virtual weight tensor consume no device memory. This module rebuilds
//! that API surface on Linux:
//!
//! | AscendCL                  | here                                      |
//! |---------------------------|-------------------------------------------|
//! | `aclrtReserveMemAddress`  | [`virtual_mem::VirtualSpace::reserve`] (`mmap(PROT_NONE)`) |
//! | `aclrtMallocPhysical`     | [`page_pool::PagePool::alloc`] (`memfd` pages) |
//! | `aclrtFreePhysical`       | [`page_pool::PagePool::free`]             |
//! | `aclrtMapMem`             | [`virtual_mem::VirtualSpace::map_page`] (`mmap(MAP_FIXED)`) |
//! | `aclrtUnmapMem`           | [`virtual_mem::VirtualSpace::unmap_page`] |
//!
//! [`expert_manager::ExpertMemoryManager`] implements the paper's
//! *expert memory manager*: it maps pages only under occupied expert
//! slots, shares partially-filled boundary pages between neighbouring
//! adapters (sub-page allocation), and reference-counts pages so eviction
//! releases exactly the pages no loaded range still touches.
//!
//! The same manager runs against an accounting-only backing
//! ([`expert_manager::Backing::Accounting`]) to reproduce the paper-scale
//! memory numbers (Fig. 9) without 64 GB of host RAM.

pub mod expert_manager;
pub mod page_pool;
pub mod virtual_mem;

/// Default physical page granularity (the paper's 2 MB).
pub const DEFAULT_PAGE_SIZE: usize = 2 << 20;
