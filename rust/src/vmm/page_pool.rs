//! Physical memory page pool (the paper's per-device pool of fixed-size
//! pages, `aclrtMallocPhysical`/`aclrtFreePhysical`).
//!
//! Pages are backed by a `memfd` so they can be mapped at arbitrary
//! virtual addresses with `mmap(MAP_FIXED)` — the same decoupling the
//! Ascend runtime provides between physical NPU pages and virtual device
//! addresses. The pool pre-allocates capacity from the "device" (the
//! memfd), hands pages to virtual weight tensors at adapter-load time and
//! takes them back on eviction for reuse.

use anyhow::{bail, Context, Result};
use std::os::fd::{AsRawFd, FromRawFd, OwnedFd};

/// Identifier of one physical page inside the pool's memfd.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct PageId(pub u32);

/// A fixed-granularity physical page pool ("device memory").
pub struct PagePool {
    fd: OwnedFd,
    page_size: usize,
    capacity: usize,
    free: Vec<PageId>,
    /// High-water mark of simultaneously allocated pages.
    peak_allocated: usize,
}

impl PagePool {
    /// Create a pool of `capacity` pages of `page_size` bytes each.
    pub fn new(page_size: usize, capacity: usize) -> Result<Self> {
        if page_size == 0 || page_size % page_align() != 0 {
            bail!("page_size {page_size} must be a positive multiple of the OS page size");
        }
        let fd = unsafe {
            let raw = libc::memfd_create(
                b"expertweave-pool\0".as_ptr() as *const libc::c_char,
                libc::MFD_CLOEXEC,
            );
            if raw < 0 {
                bail!("memfd_create failed: {}", std::io::Error::last_os_error());
            }
            OwnedFd::from_raw_fd(raw)
        };
        let total = page_size
            .checked_mul(capacity)
            .context("pool size overflow")?;
        let rc = unsafe { libc::ftruncate(fd.as_raw_fd(), total as libc::off_t) };
        if rc != 0 {
            bail!("ftruncate failed: {}", std::io::Error::last_os_error());
        }
        // LIFO free list: hot pages get reused first.
        let free = (0..capacity as u32).rev().map(PageId).collect();
        Ok(PagePool { fd, page_size, capacity, free, peak_allocated: 0 })
    }

    pub fn page_size(&self) -> usize {
        self.page_size
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    pub fn allocated_pages(&self) -> usize {
        self.capacity - self.free.len()
    }

    pub fn peak_allocated(&self) -> usize {
        self.peak_allocated
    }

    pub(crate) fn raw_fd(&self) -> i32 {
        self.fd.as_raw_fd()
    }

    /// Byte offset of a page inside the memfd.
    pub fn page_offset(&self, page: PageId) -> usize {
        page.0 as usize * self.page_size
    }

    /// Allocate `n` physical pages (`aclrtMallocPhysical`).
    pub fn alloc(&mut self, n: usize) -> Result<Vec<PageId>> {
        if n > self.free.len() {
            bail!(
                "device out of memory: requested {n} pages, {} free of {}",
                self.free.len(),
                self.capacity
            );
        }
        let at = self.free.len() - n;
        let pages = self.free.split_off(at);
        self.peak_allocated = self.peak_allocated.max(self.allocated_pages());
        Ok(pages)
    }

    /// Return pages to the pool (`aclrtFreePhysical`).
    ///
    /// Double-free is a logic error and panics in debug builds.
    pub fn free(&mut self, pages: &[PageId]) {
        for &p in pages {
            debug_assert!(
                !self.free.contains(&p),
                "double free of physical page {p:?}"
            );
            debug_assert!((p.0 as usize) < self.capacity);
            self.free.push(p);
        }
    }
}

impl std::fmt::Debug for PagePool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PagePool")
            .field("page_size", &self.page_size)
            .field("capacity", &self.capacity)
            .field("free", &self.free.len())
            .finish()
    }
}

/// OS page size (mmap granularity floor).
pub fn page_align() -> usize {
    static CACHE: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    *CACHE.get_or_init(|| unsafe { libc::sysconf(libc::_SC_PAGESIZE) as usize })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_free_cycle() {
        let mut pool = PagePool::new(64 << 10, 16).unwrap();
        assert_eq!(pool.free_pages(), 16);
        let a = pool.alloc(5).unwrap();
        assert_eq!(a.len(), 5);
        assert_eq!(pool.allocated_pages(), 5);
        let b = pool.alloc(11).unwrap();
        assert_eq!(pool.free_pages(), 0);
        pool.free(&a);
        assert_eq!(pool.free_pages(), 5);
        pool.free(&b);
        assert_eq!(pool.free_pages(), 16);
        assert_eq!(pool.peak_allocated(), 16);
    }

    #[test]
    fn oom_is_an_error() {
        let mut pool = PagePool::new(64 << 10, 4).unwrap();
        let _a = pool.alloc(3).unwrap();
        assert!(pool.alloc(2).is_err());
        assert!(pool.alloc(1).is_ok());
    }

    #[test]
    fn distinct_pages() {
        let mut pool = PagePool::new(64 << 10, 32).unwrap();
        let a = pool.alloc(32).unwrap();
        let mut set = std::collections::HashSet::new();
        for p in &a {
            assert!(set.insert(*p), "duplicate page handed out");
        }
    }

    #[test]
    fn rejects_bad_page_size() {
        assert!(PagePool::new(1000, 4).is_err());
        assert!(PagePool::new(0, 4).is_err());
    }

    #[test]
    fn reuse_is_lifo() {
        let mut pool = PagePool::new(64 << 10, 8).unwrap();
        let a = pool.alloc(2).unwrap();
        pool.free(&a);
        let b = pool.alloc(2).unwrap();
        // LIFO: the just-freed pages come back first (reuse-hot property)
        assert_eq!(
            std::collections::HashSet::<PageId>::from_iter(a),
            std::collections::HashSet::from_iter(b)
        );
    }

    #[test]
    fn property_alloc_free_never_loses_pages() {
        crate::util::prop::check(101, 50, |rng| {
            let cap = 1 + rng.below(64) as usize;
            let mut pool = PagePool::new(64 << 10, cap).unwrap();
            let mut held: Vec<Vec<PageId>> = Vec::new();
            for _ in 0..100 {
                if rng.below(2) == 0 {
                    let want = rng.below(8) as usize;
                    if let Ok(pages) = pool.alloc(want) {
                        held.push(pages);
                    }
                } else if let Some(pages) = held.pop() {
                    pool.free(&pages);
                }
                let held_count: usize = held.iter().map(|v| v.len()).sum();
                assert_eq!(pool.allocated_pages(), held_count);
                assert_eq!(pool.free_pages() + held_count, cap);
            }
        });
    }
}
