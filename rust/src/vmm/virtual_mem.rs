//! Contiguous virtual address space with page-granular physical mapping
//! (`aclrtReserveMemAddress` / `aclrtMapMem` / `aclrtUnmapMem`).
//!
//! `reserve` mmaps the whole range `PROT_NONE` (pure address-space
//! reservation, zero physical cost); `map_page` replaces one page-sized
//! window with a `MAP_SHARED | MAP_FIXED` view of a [`PagePool`] page;
//! `unmap_page` restores the `PROT_NONE` reservation. Accessing an
//! unmapped window faults — exactly the "inconsiderate implementations
//! lead to runtime errors" hazard the paper calls out, which the
//! [`super::expert_manager`] layer exists to prevent.

use super::page_pool::{page_align, PageId, PagePool};
use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// A reserved virtual region with decoupled physical backing.
pub struct VirtualSpace {
    base: *mut u8,
    len: usize,
    page_size: usize,
    /// page-index -> physical page currently mapped there
    mapped: BTreeMap<usize, PageId>,
}

// The raw pointer is owned exclusively by this struct (mmap region).
unsafe impl Send for VirtualSpace {}

impl VirtualSpace {
    /// Reserve `pages * page_size` bytes of contiguous virtual address
    /// space without committing any physical memory.
    pub fn reserve(page_size: usize, pages: usize) -> Result<Self> {
        if page_size == 0 || page_size % page_align() != 0 {
            bail!("page_size must be a multiple of the OS page size");
        }
        let len = page_size * pages;
        let base = unsafe {
            libc::mmap(
                std::ptr::null_mut(),
                len.max(1),
                libc::PROT_NONE,
                libc::MAP_PRIVATE | libc::MAP_ANONYMOUS | libc::MAP_NORESERVE,
                -1,
                0,
            )
        };
        if base == libc::MAP_FAILED {
            bail!("reserve mmap failed: {}", std::io::Error::last_os_error());
        }
        Ok(VirtualSpace { base: base as *mut u8, len, page_size, mapped: BTreeMap::new() })
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn page_size(&self) -> usize {
        self.page_size
    }

    pub fn mapped_pages(&self) -> usize {
        self.mapped.len()
    }

    pub fn base_ptr(&self) -> *const u8 {
        self.base
    }

    /// Is the page at `page_index` currently backed?
    pub fn is_mapped(&self, page_index: usize) -> bool {
        self.mapped.contains_key(&page_index)
    }

    /// Map a physical page from `pool` at `page_index` (`aclrtMapMem`).
    pub fn map_page(&mut self, page_index: usize, page: PageId, pool: &PagePool) -> Result<()> {
        if pool.page_size() != self.page_size {
            bail!("pool page size mismatch");
        }
        let offset = page_index * self.page_size;
        if offset + self.page_size > self.len {
            bail!("map beyond reserved range: page {page_index}");
        }
        if self.mapped.contains_key(&page_index) {
            bail!("page {page_index} already mapped");
        }
        let addr = unsafe {
            libc::mmap(
                self.base.add(offset) as *mut libc::c_void,
                self.page_size,
                libc::PROT_READ | libc::PROT_WRITE,
                libc::MAP_SHARED | libc::MAP_FIXED,
                pool.raw_fd(),
                pool.page_offset(page) as libc::off_t,
            )
        };
        if addr == libc::MAP_FAILED {
            bail!("map_page mmap failed: {}", std::io::Error::last_os_error());
        }
        self.mapped.insert(page_index, page);
        Ok(())
    }

    /// Unmap the page at `page_index`, restoring the bare reservation;
    /// returns the physical page so the caller can release it to the pool
    /// (`aclrtUnmapMem`).
    pub fn unmap_page(&mut self, page_index: usize) -> Result<PageId> {
        let page = match self.mapped.remove(&page_index) {
            Some(p) => p,
            None => bail!("page {page_index} is not mapped"),
        };
        let offset = page_index * self.page_size;
        let addr = unsafe {
            libc::mmap(
                self.base.add(offset) as *mut libc::c_void,
                self.page_size,
                libc::PROT_NONE,
                libc::MAP_PRIVATE | libc::MAP_ANONYMOUS | libc::MAP_FIXED | libc::MAP_NORESERVE,
                -1,
                0,
            )
        };
        if addr == libc::MAP_FAILED {
            bail!("unmap re-reserve failed: {}", std::io::Error::last_os_error());
        }
        Ok(page)
    }

    fn check_range_mapped(&self, offset: usize, len: usize) -> Result<()> {
        if len == 0 {
            return Ok(());
        }
        if offset + len > self.len {
            bail!("range [{offset}, {}) beyond reservation", offset + len);
        }
        let first = offset / self.page_size;
        let last = (offset + len - 1) / self.page_size;
        for p in first..=last {
            if !self.mapped.contains_key(&p) {
                bail!("access to unmapped page {p} (offset {offset}, len {len})");
            }
        }
        Ok(())
    }

    /// Copy bytes into the region (must be fully mapped).
    pub fn write(&mut self, offset: usize, data: &[u8]) -> Result<()> {
        self.check_range_mapped(offset, data.len())?;
        unsafe {
            std::ptr::copy_nonoverlapping(data.as_ptr(), self.base.add(offset), data.len());
        }
        Ok(())
    }

    /// Read bytes out of the region (must be fully mapped).
    pub fn read(&self, offset: usize, out: &mut [u8]) -> Result<()> {
        self.check_range_mapped(offset, out.len())?;
        unsafe {
            std::ptr::copy_nonoverlapping(self.base.add(offset), out.as_mut_ptr(), out.len());
        }
        Ok(())
    }

    /// Borrow a mapped range as a typed slice (e.g. for buffer upload).
    ///
    /// # Safety-by-construction
    /// Errors (rather than faulting) if any page in the range is unmapped.
    pub fn slice_f32(&self, offset: usize, count: usize) -> Result<&[f32]> {
        let len = count * std::mem::size_of::<f32>();
        self.check_range_mapped(offset, len)?;
        if offset % std::mem::align_of::<f32>() != 0 {
            bail!("unaligned f32 slice at offset {offset}");
        }
        Ok(unsafe { std::slice::from_raw_parts(self.base.add(offset) as *const f32, count) })
    }
}

impl Drop for VirtualSpace {
    fn drop(&mut self) {
        unsafe {
            libc::munmap(self.base as *mut libc::c_void, self.len.max(1));
        }
    }
}

impl std::fmt::Debug for VirtualSpace {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("VirtualSpace")
            .field("len", &self.len)
            .field("page_size", &self.page_size)
            .field("mapped_pages", &self.mapped.len())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const PS: usize = 64 << 10;

    #[test]
    fn reserve_is_free_of_physical_pages() {
        let vs = VirtualSpace::reserve(PS, 1024).unwrap(); // 64 MB of address space
        assert_eq!(vs.mapped_pages(), 0);
        assert_eq!(vs.len(), 1024 * PS);
    }

    #[test]
    fn map_write_read_roundtrip() {
        let mut pool = PagePool::new(PS, 4).unwrap();
        let mut vs = VirtualSpace::reserve(PS, 8).unwrap();
        let p = pool.alloc(1).unwrap()[0];
        vs.map_page(2, p, &pool).unwrap();
        let data = vec![0xAB_u8; 128];
        vs.write(2 * PS + 100, &data).unwrap();
        let mut back = vec![0u8; 128];
        vs.read(2 * PS + 100, &mut back).unwrap();
        assert_eq!(back, data);
    }

    #[test]
    fn unmapped_access_is_an_error_not_a_fault() {
        let mut pool = PagePool::new(PS, 4).unwrap();
        let mut vs = VirtualSpace::reserve(PS, 8).unwrap();
        assert!(vs.write(0, &[1, 2, 3]).is_err());
        let p = pool.alloc(1).unwrap()[0];
        vs.map_page(0, p, &pool).unwrap();
        // crossing into the unmapped second page is rejected
        assert!(vs.write(PS - 2, &[1, 2, 3, 4]).is_err());
        assert!(vs.write(PS - 2, &[1, 2]).is_ok());
    }

    #[test]
    fn two_mappings_of_same_physical_page_share_content() {
        // the mechanism behind sub-page sharing between adjacent adapters
        let mut pool = PagePool::new(PS, 2).unwrap();
        let mut a = VirtualSpace::reserve(PS, 2).unwrap();
        let mut b = VirtualSpace::reserve(PS, 2).unwrap();
        let p = pool.alloc(1).unwrap()[0];
        a.map_page(0, p, &pool).unwrap();
        b.map_page(1, p, &pool).unwrap();
        a.write(10, b"hello").unwrap();
        let mut out = [0u8; 5];
        b.read(PS + 10, &mut out).unwrap();
        assert_eq!(&out, b"hello");
    }

    #[test]
    fn unmap_returns_page_and_blocks_access() {
        let mut pool = PagePool::new(PS, 2).unwrap();
        let mut vs = VirtualSpace::reserve(PS, 2).unwrap();
        let p = pool.alloc(1).unwrap()[0];
        vs.map_page(1, p, &pool).unwrap();
        vs.write(PS, &[9]).unwrap();
        let back = vs.unmap_page(1).unwrap();
        assert_eq!(back, p);
        assert!(vs.write(PS, &[9]).is_err());
        assert!(vs.unmap_page(1).is_err());
        pool.free(&[back]);
        assert_eq!(pool.free_pages(), 2);
    }

    #[test]
    fn remap_after_unmap_preserves_pool_content() {
        // physical pages keep their bytes while unmapped (memfd-backed)
        let mut pool = PagePool::new(PS, 1).unwrap();
        let mut vs = VirtualSpace::reserve(PS, 4).unwrap();
        let p = pool.alloc(1).unwrap()[0];
        vs.map_page(0, p, &pool).unwrap();
        vs.write(0, b"persist").unwrap();
        let p = vs.unmap_page(0).unwrap();
        vs.map_page(3, p, &pool).unwrap();
        let mut out = [0u8; 7];
        vs.read(3 * PS, &mut out).unwrap();
        assert_eq!(&out, b"persist");
    }

    #[test]
    fn double_map_rejected() {
        let mut pool = PagePool::new(PS, 2).unwrap();
        let mut vs = VirtualSpace::reserve(PS, 2).unwrap();
        let pages = pool.alloc(2).unwrap();
        vs.map_page(0, pages[0], &pool).unwrap();
        assert!(vs.map_page(0, pages[1], &pool).is_err());
    }

    #[test]
    fn map_out_of_range_rejected() {
        let mut pool = PagePool::new(PS, 1).unwrap();
        let mut vs = VirtualSpace::reserve(PS, 2).unwrap();
        let p = pool.alloc(1).unwrap()[0];
        assert!(vs.map_page(2, p, &pool).is_err());
    }

    #[test]
    fn slice_f32_over_mapped_range() {
        let mut pool = PagePool::new(PS, 2).unwrap();
        let mut vs = VirtualSpace::reserve(PS, 2).unwrap();
        for (i, p) in pool.alloc(2).unwrap().into_iter().enumerate() {
            vs.map_page(i, p, &pool).unwrap();
        }
        let vals: Vec<f32> = (0..32).map(|i| i as f32).collect();
        let bytes: &[u8] = unsafe {
            std::slice::from_raw_parts(vals.as_ptr() as *const u8, vals.len() * 4)
        };
        vs.write(PS - 64, bytes).unwrap(); // straddles the page boundary
        let s = vs.slice_f32(PS - 64, 32).unwrap();
        assert_eq!(s, &vals[..]);
        assert!(vs.slice_f32(PS - 63, 4).is_err()); // unaligned
    }

    #[test]
    fn property_mapped_set_tracks_operations() {
        crate::util::prop::check(202, 30, |rng| {
            let pages = 16;
            let mut pool = PagePool::new(PS, pages).unwrap();
            let mut vs = VirtualSpace::reserve(PS, pages).unwrap();
            let mut model: std::collections::BTreeMap<usize, PageId> = Default::default();
            for _ in 0..60 {
                let idx = rng.below(pages as u64) as usize;
                if model.contains_key(&idx) {
                    let p = vs.unmap_page(idx).unwrap();
                    assert_eq!(p, model.remove(&idx).unwrap());
                    pool.free(&[p]);
                } else if let Ok(ps) = pool.alloc(1) {
                    vs.map_page(idx, ps[0], &pool).unwrap();
                    model.insert(idx, ps[0]);
                }
                assert_eq!(vs.mapped_pages(), model.len());
                for (&i, _) in &model {
                    assert!(vs.is_mapped(i));
                }
            }
        });
    }
}
