//! Seeded base-model weight generation.
//!
//! The paper serves the ESFT-vanilla 16B checkpoint; no checkpoint is
//! available offline, so base weights are generated deterministically from
//! a seed (uniform `±1/sqrt(fan_in)`, RMS-norm gains = 1). System
//! behaviour — routing distributions, batching, memory — is what the
//! experiments measure, and the weave≡merged equivalence tests are
//! value-exact regardless of the values chosen.

use crate::model::ModelConfig;
use crate::util::rng::Pcg;

/// All non-expert parameters by name, plus the base (`M`-slot) expert
/// tensors per layer/projection.
pub struct BaseWeights {
    cfg: ModelConfig,
    /// name -> host array for every non-expert parameter.
    named: std::collections::BTreeMap<String, Vec<f32>>,
    /// `[layer][proj]` -> `[M * hidden * inter]` f32 (proj: gate, up, down).
    experts: Vec<[Vec<f32>; 3]>,
}

/// Projection index names (order fixed by the artifact ABI).
pub const PROJ_NAMES: [&str; 3] = ["w_gate", "w_up", "w_down"];

fn fill_uniform(rng: &mut Pcg, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| (rng.f32() * 2.0 - 1.0) * scale).collect()
}

impl BaseWeights {
    /// Generate every base parameter for `cfg` from `seed`.
    pub fn generate(cfg: &ModelConfig, seed: u64) -> Self {
        let mut named = std::collections::BTreeMap::new();
        let h = cfg.hidden;
        let (qd, kd) = (cfg.q_heads * cfg.head_dim, cfg.kv_heads * cfg.head_dim);
        let s_h = 1.0 / (h as f32).sqrt();
        let mut rng = Pcg::with_stream(seed, 0);

        named.insert("embed".into(), fill_uniform(&mut rng, cfg.vocab * h, s_h));
        for l in 0..cfg.layers {
            let p = format!("layer{l}.");
            let mut lrng = Pcg::with_stream(seed, 100 + l as u64);
            named.insert(format!("{p}ln_attn"), vec![1.0; h]);
            named.insert(format!("{p}wq"), fill_uniform(&mut lrng, h * qd, s_h));
            named.insert(format!("{p}wk"), fill_uniform(&mut lrng, h * kd, s_h));
            named.insert(format!("{p}wv"), fill_uniform(&mut lrng, h * kd, s_h));
            named.insert(format!("{p}wo"), fill_uniform(&mut lrng, qd * h, 1.0 / (qd as f32).sqrt()));
            named.insert(format!("{p}ln_ffn"), vec![1.0; h]);
            named.insert(format!("{p}router"), fill_uniform(&mut lrng, h * cfg.num_experts, s_h));
            named.insert(format!("{p}shared_gate"), fill_uniform(&mut lrng, h * cfg.shared_inter, s_h));
            named.insert(format!("{p}shared_up"), fill_uniform(&mut lrng, h * cfg.shared_inter, s_h));
            named.insert(
                format!("{p}shared_down"),
                fill_uniform(&mut lrng, cfg.shared_inter * h, 1.0 / (cfg.shared_inter as f32).sqrt()),
            );
        }
        named.insert("ln_final".into(), vec![1.0; h]);
        named.insert("lm_head".into(), fill_uniform(&mut rng, h * cfg.vocab, s_h));

        let per_proj = cfg.num_experts * h * cfg.expert_inter;
        let s_f = 1.0 / (cfg.expert_inter as f32).sqrt();
        let experts = (0..cfg.layers)
            .map(|l| {
                let mut erng = Pcg::with_stream(seed, 1000 + l as u64);
                [
                    fill_uniform(&mut erng, per_proj, s_h),
                    fill_uniform(&mut erng, per_proj, s_h),
                    fill_uniform(&mut erng, per_proj, s_f),
                ]
            })
            .collect();
        BaseWeights { cfg: cfg.clone(), named, experts }
    }

    pub fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    /// Non-expert parameter by ABI name (`layer3.wq`, `embed`, ...).
    pub fn named(&self, name: &str) -> Option<&[f32]> {
        self.named.get(name).map(|v| v.as_slice())
    }

    /// Base expert tensor `[M * hidden * inter]` for (layer, proj).
    pub fn experts(&self, layer: usize, proj: usize) -> &[f32] {
        &self.experts[layer][proj]
    }

    /// One base expert's rows for (layer, proj, expert).
    pub fn expert(&self, layer: usize, proj: usize, e: usize) -> &[f32] {
        let per = self.cfg.hidden * self.cfg.expert_inter;
        &self.experts[layer][proj][e * per..(e + 1) * per]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ModelConfig {
        let mut c = ModelConfig::paper16b();
        c.name = "t".into();
        c.vocab = 64;
        c.hidden = 16;
        c.layers = 2;
        c.q_heads = 2;
        c.kv_heads = 1;
        c.head_dim = 8;
        c.num_experts = 4;
        c.expert_inter = 8;
        c.shared_inter = 16;
        c
    }

    #[test]
    fn deterministic_and_named() {
        let c = tiny_cfg();
        let a = BaseWeights::generate(&c, 7);
        let b = BaseWeights::generate(&c, 7);
        assert_eq!(a.named("embed"), b.named("embed"));
        assert_eq!(a.experts(1, 2), b.experts(1, 2));
        let d = BaseWeights::generate(&c, 8);
        assert_ne!(a.named("embed"), d.named("embed"));
    }

    #[test]
    fn shapes() {
        let c = tiny_cfg();
        let w = BaseWeights::generate(&c, 0);
        assert_eq!(w.named("embed").unwrap().len(), 64 * 16);
        assert_eq!(w.named("layer0.wq").unwrap().len(), 16 * 16);
        assert_eq!(w.named("layer1.router").unwrap().len(), 16 * 4);
        assert_eq!(w.experts(0, 0).len(), 4 * 16 * 8);
        assert_eq!(w.expert(0, 1, 3).len(), 16 * 8);
        assert!(w.named("nope").is_none());
        // norms are ones
        assert!(w.named("layer0.ln_attn").unwrap().iter().all(|&x| x == 1.0));
    }

    #[test]
    fn values_bounded_by_scale() {
        let c = tiny_cfg();
        let w = BaseWeights::generate(&c, 0);
        let s = 1.0 / (16f32).sqrt();
        assert!(w.named("embed").unwrap().iter().all(|&x| x.abs() <= s));
    }
}
