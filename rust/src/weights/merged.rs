//! The *vLLM-Ascend (Merged)* baseline: merge an ESFT adapter into the
//! base model offline, producing a standalone `M`-expert checkpoint that
//! is then served in isolation (one engine instance per adapter).
//!
//! Used by Fig. 6 (throughput vs merged instances under skew), Fig. 9
//! (memory scaling: one full model per adapter) and Table 3 (accuracy
//! parity: ExpertWeave output must equal the merged model's).

use crate::adapters::format::Adapter;
use crate::model::ModelConfig;
use crate::weights::base_gen::BaseWeights;
use anyhow::{bail, Result};

/// Build the merged `[M * hidden * inter]` expert tensor for one
/// (layer, projection): base experts with the adapter's fine-tuned rows
/// substituted in place.
pub fn merged_expert_tensor(
    cfg: &ModelConfig,
    base: &BaseWeights,
    adapter: &Adapter,
    layer: usize,
    proj: usize,
) -> Result<Vec<f32>> {
    if adapter.layers.len() != cfg.layers {
        bail!("adapter/model layer mismatch");
    }
    let per = cfg.hidden * cfg.expert_inter;
    let mut out = base.experts(layer, proj).to_vec();
    let la = &adapter.layers[layer];
    for (local, &id) in la.expert_ids.iter().enumerate() {
        let id = id as usize;
        if id >= cfg.num_experts {
            bail!("expert id {id} out of range");
        }
        let w3 = la.expert_weights(local, cfg.hidden, cfg.expert_inter);
        out[id * per..(id + 1) * per].copy_from_slice(&w3[proj * per..(proj + 1) * per]);
    }
    Ok(out)
}

/// Device bytes of one merged-model deployment (full model weights, f32).
/// Each extra adapter costs a whole model in the merged strategy.
pub fn merged_model_bytes(cfg: &ModelConfig) -> usize {
    cfg.base_model_bytes()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapters::format::AdapterLayer;

    fn tiny_cfg() -> ModelConfig {
        let mut c = ModelConfig::paper16b();
        c.hidden = 4;
        c.layers = 1;
        c.num_experts = 3;
        c.expert_inter = 2;
        c.max_adapters = 2;
        c.e_max = 2;
        c
    }

    #[test]
    fn substitutes_only_fine_tuned_rows() {
        let cfg = tiny_cfg();
        let base = BaseWeights::generate(&cfg, 0);
        let per = cfg.hidden * cfg.expert_inter;
        let ad = Adapter {
            name: "a".into(),
            domain: "d".into(),
            hidden: cfg.hidden,
            inter: cfg.expert_inter,
            layers: vec![AdapterLayer {
                expert_ids: vec![1],
                weights: (0..3 * per).map(|i| 100.0 + i as f32).collect(),
            }],
        };
        for proj in 0..3 {
            let merged = merged_expert_tensor(&cfg, &base, &ad, 0, proj).unwrap();
            assert_eq!(&merged[..per], &base.experts(0, proj)[..per]); // expert 0 kept
            assert_eq!(&merged[2 * per..], &base.experts(0, proj)[2 * per..]); // expert 2 kept
            let want: Vec<f32> =
                (0..per).map(|i| 100.0 + (proj * per + i) as f32).collect();
            assert_eq!(&merged[per..2 * per], &want[..]); // expert 1 replaced
        }
    }

    #[test]
    fn bad_adapter_rejected() {
        let cfg = tiny_cfg();
        let base = BaseWeights::generate(&cfg, 0);
        let ad = Adapter {
            name: "a".into(),
            domain: "d".into(),
            hidden: cfg.hidden,
            inter: cfg.expert_inter,
            layers: vec![AdapterLayer {
                expert_ids: vec![7], // out of range
                weights: vec![0.0; 3 * cfg.hidden * cfg.expert_inter],
            }],
        };
        assert!(merged_expert_tensor(&cfg, &base, &ad, 0, 0).is_err());
    }
}
