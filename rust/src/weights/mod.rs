//! Expert weight stores: the paper's **virtual weight tensor** plus the
//! two baselines it is evaluated against.
//!
//! * [`store::WeightStore`] with [`store::StoreMode::Virtual`] — the
//!   ExpertWeave design: virtual span of `G = M + N·E_max` slots per
//!   (layer, projection), physical pages only under loaded experts
//!   (via [`crate::vmm::expert_manager`]).
//! * [`store::StoreMode::Padding`] — the section-3 baseline: the whole
//!   padded tensor is physically committed at initialization.
//! * [`merged`] — the vLLM-Ascend (Merged) baseline: one full standalone
//!   model per adapter.
//! * [`base_gen`] — seeded generation of base-model weights (the stand-in
//!   for the unavailable 16B checkpoint; see DESIGN.md section 7).

pub mod base_gen;
pub mod merged;
pub mod params;
pub mod store;

pub use base_gen::BaseWeights;
pub use params::{BaseOnlyParams, MergedParams, StoreParams};
pub use store::{StoreMode, WeightStore};
