//! [`ParamSource`] implementations bridging weight stores to the PJRT
//! runtime's upload path.

use crate::adapters::format::Adapter;
use crate::model::ModelConfig;
use crate::runtime::engine::ParamSource;
use crate::weights::base_gen::BaseWeights;
use crate::weights::merged::merged_expert_tensor;
use crate::weights::store::WeightStore;
use anyhow::{bail, Result};

/// ExpertWeave deployment: base params + the virtual weight tensor
/// (adapter slots included) from a [`WeightStore`].
pub struct StoreParams<'a> {
    pub base: &'a BaseWeights,
    pub store: &'a WeightStore,
    scratch: Vec<f32>,
}

impl<'a> StoreParams<'a> {
    pub fn new(base: &'a BaseWeights, store: &'a WeightStore) -> Self {
        StoreParams { base, store, scratch: Vec::new() }
    }
}

impl ParamSource for StoreParams<'_> {
    fn named(&self, name: &str) -> Option<&[f32]> {
        self.base.named(name)
    }

    fn expert_tensor(&mut self, layer: usize, proj: usize, len: usize) -> Result<&[f32]> {
        self.store.materialize_proj(layer, proj, &mut self.scratch)?;
        if self.scratch.len() != len {
            bail!(
                "expert tensor (layer {layer}, proj {proj}): {} != {len}",
                self.scratch.len()
            );
        }
        Ok(&self.scratch)
    }
}

/// Base-only deployment (vLLM-Ascend Base-Only): just the M base experts.
pub struct BaseOnlyParams<'a> {
    pub base: &'a BaseWeights,
}

impl ParamSource for BaseOnlyParams<'_> {
    fn named(&self, name: &str) -> Option<&[f32]> {
        self.base.named(name)
    }

    fn expert_tensor(&mut self, layer: usize, proj: usize, len: usize) -> Result<&[f32]> {
        let t = self.base.experts(layer, proj);
        if t.len() != len {
            bail!("base expert tensor (layer {layer}, proj {proj}): {} != {len}", t.len());
        }
        Ok(t)
    }
}

/// Merged deployment (vLLM-Ascend Merged): base experts with one adapter's
/// fine-tuned rows substituted offline.
pub struct MergedParams<'a> {
    pub cfg: &'a ModelConfig,
    pub base: &'a BaseWeights,
    pub adapter: &'a Adapter,
    scratch: Vec<f32>,
}

impl<'a> MergedParams<'a> {
    pub fn new(cfg: &'a ModelConfig, base: &'a BaseWeights, adapter: &'a Adapter) -> Self {
        MergedParams { cfg, base, adapter, scratch: Vec::new() }
    }
}

impl ParamSource for MergedParams<'_> {
    fn named(&self, name: &str) -> Option<&[f32]> {
        self.base.named(name)
    }

    fn expert_tensor(&mut self, layer: usize, proj: usize, len: usize) -> Result<&[f32]> {
        self.scratch = merged_expert_tensor(self.cfg, self.base, self.adapter, layer, proj)?;
        if self.scratch.len() != len {
            bail!(
                "merged expert tensor (layer {layer}, proj {proj}): {} != {len}",
                self.scratch.len()
            );
        }
        Ok(&self.scratch)
    }
}
