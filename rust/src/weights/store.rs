//! The virtual weight tensor store (and its padding baseline).
//!
//! One [`WeightStore`] owns, per (MoE layer, projection), an
//! [`ExpertMemoryManager`] over a `G = M + N·E_max`-slot virtual span:
//!
//! ```text
//! slots:   [0 .. M)                      base-model experts (init time)
//!          [Δ_i .. Δ_i + e_i^(l))        adapter i's fine-tuned experts
//!          [Δ_i + e_i^(l) .. Δ_i+E_max)  padding — never physically backed
//! ```
//!
//! * `StoreMode::Virtual` (ExpertWeave): pages are mapped only under the
//!   loaded sub-ranges; padding costs address space only.
//! * `StoreMode::Padding` (section-3 baseline): loading adapter `i`
//!   commits its full `E_max` window regardless of `e_i^(l)`.
//!
//! A [`DeviceMemory`] ledger tracks simulated device bytes; page-level
//! map/unmap deltas are charged after every operation so KV-capacity
//! accounting (Fig. 9) sees weights and cache from one budget.

use crate::adapters::format::Adapter;
use crate::memsim::DeviceMemory;
use crate::model::ModelConfig;
use crate::vmm::expert_manager::{ExpertMemoryManager, MemStats};
use crate::vmm::page_pool::PagePool;
use crate::weights::base_gen::BaseWeights;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Physical commitment policy for adapter windows.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StoreMode {
    /// ExpertWeave: commit only `e_i^(l)` slots per layer.
    Virtual,
    /// Baseline: commit the full `E_max` window per layer.
    Padding,
}

/// Per-device store of all expert weights behind the GMM operator.
pub struct WeightStore {
    cfg: ModelConfig,
    mode: StoreMode,
    device: Arc<Mutex<DeviceMemory>>,
    /// `[layer * 3 + proj]`
    managers: Vec<ExpertMemoryManager>,
    /// adapter slot -> per-layer fine-tuned expert counts
    loaded: HashMap<usize, Vec<usize>>,
    base_loaded: bool,
    ledger_bytes: usize,
}

impl WeightStore {
    /// Create an empty store; `pool` supplies physical pages (shared by
    /// all managers of this device), `device` is the simulated budget.
    pub fn new(
        cfg: &ModelConfig,
        mode: StoreMode,
        pool: Arc<Mutex<PagePool>>,
        device: Arc<Mutex<DeviceMemory>>,
    ) -> Result<Self> {
        let mut managers = Vec::with_capacity(cfg.layers * 3);
        for _l in 0..cfg.layers {
            for _p in 0..3 {
                managers.push(ExpertMemoryManager::new_real(
                    cfg.expert_proj_bytes(),
                    cfg.total_expert_slots(),
                    pool.clone(),
                )?);
            }
        }
        Ok(WeightStore {
            cfg: cfg.clone(),
            mode,
            device,
            managers,
            loaded: HashMap::new(),
            base_loaded: false,
            ledger_bytes: 0,
        })
    }

    pub fn mode(&self) -> StoreMode {
        self.mode
    }

    pub fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    fn mgr(&mut self, layer: usize, proj: usize) -> &mut ExpertMemoryManager {
        &mut self.managers[layer * 3 + proj]
    }

    fn total_mapped_bytes(&self) -> usize {
        self.managers.iter().map(|m| m.stats().mapped_bytes).sum()
    }

    /// Charge/release the mapped-bytes delta on the device ledger;
    /// on ledger OOM run `rollback` and propagate the error.
    fn settle_ledger(&mut self, rollback: impl FnOnce(&mut Self)) -> Result<()> {
        let now = self.total_mapped_bytes();
        let res = if now > self.ledger_bytes {
            self.device.lock().unwrap().alloc(now - self.ledger_bytes)
        } else {
            self.device.lock().unwrap().release(self.ledger_bytes - now);
            Ok(())
        };
        match res {
            Ok(()) => {
                self.ledger_bytes = now;
                Ok(())
            }
            Err(e) => {
                rollback(self);
                let after = self.total_mapped_bytes();
                debug_assert_eq!(after, self.ledger_bytes);
                Err(e).context("device budget exceeded loading weights")
            }
        }
    }

    /// Load the base model's M experts into slots `[0, M)` of every
    /// (layer, projection) tensor. Done once at engine start.
    pub fn load_base(&mut self, base: &BaseWeights) -> Result<()> {
        if self.base_loaded {
            bail!("base already loaded");
        }
        let m = self.cfg.num_experts;
        let per = self.cfg.hidden * self.cfg.expert_inter;
        for l in 0..self.cfg.layers {
            for p in 0..3 {
                self.mgr(l, p).load_range(0, m)?;
                for e in 0..m {
                    let w = base.expert(l, p, e);
                    let bytes = f32_bytes(w);
                    self.mgr(l, p).write_expert(e, bytes)?;
                    debug_assert_eq!(w.len(), per);
                }
            }
        }
        self.base_loaded = true;
        self.settle_ledger(|s| {
            for l in 0..s.cfg.layers {
                for p in 0..3 {
                    let _ = s.mgr(l, p).unload_range(0);
                }
            }
            s.base_loaded = false;
        })
    }

    /// Load an adapter into slot window `i` (paper: map
    /// `[Δ_i : Δ_i + e_i^(l)]` per layer; padding mode maps the full
    /// `E_max` window). Rolled back completely on OOM.
    pub fn load_adapter(&mut self, slot: usize, adapter: &Adapter) -> Result<()> {
        if slot >= self.cfg.max_adapters {
            bail!("adapter slot {slot} out of range");
        }
        if self.loaded.contains_key(&slot) {
            bail!("slot {slot} already holds an adapter");
        }
        if adapter.layers.len() != self.cfg.layers {
            bail!(
                "adapter layers {} != model layers {}",
                adapter.layers.len(),
                self.cfg.layers
            );
        }
        if adapter.hidden != self.cfg.hidden || adapter.inter != self.cfg.expert_inter {
            bail!("adapter geometry mismatch");
        }
        if adapter.max_experts() > self.cfg.e_max {
            bail!(
                "adapter max experts {} exceeds E_max {}",
                adapter.max_experts(),
                self.cfg.e_max
            );
        }
        let delta = self.cfg.adapter_slot_base(slot);
        let counts: Vec<usize> =
            adapter.layers.iter().map(|la| la.expert_count()).collect();
        let per = self.cfg.hidden * self.cfg.expert_inter;

        // map + write, tracking how far we got for rollback
        let mut done: Vec<(usize, usize)> = Vec::new(); // (layer, proj) ranges loaded
        let mut fail: Option<anyhow::Error> = None;
        'outer: for (l, layer) in adapter.layers.iter().enumerate() {
            let commit = match self.mode {
                StoreMode::Virtual => layer.expert_count(),
                StoreMode::Padding => self.cfg.e_max,
            };
            if commit == 0 {
                continue;
            }
            for p in 0..3 {
                if let Err(e) = self.mgr(l, p).load_range(delta, commit) {
                    fail = Some(e);
                    break 'outer;
                }
                done.push((l, p));
                for (local, _id) in layer.expert_ids.iter().enumerate() {
                    let w3 = layer.expert_weights(local, adapter.hidden, adapter.inter);
                    let w = &w3[p * per..(p + 1) * per];
                    self.mgr(l, p).write_expert(delta + local, f32_bytes(w))?;
                }
            }
        }
        if let Some(e) = fail {
            for (l, p) in done {
                let _ = self.mgr(l, p).unload_range(delta);
            }
            // ledger unchanged since last settle: mapped bytes rolled back
            let _ = self.settle_ledger(|_| {});
            return Err(e).context("loading adapter weights");
        }
        self.loaded.insert(slot, counts);
        let delta_slot = delta;
        self.settle_ledger(move |s| {
            for l in 0..s.cfg.layers {
                for p in 0..3 {
                    let _ = s.mgr(l, p).unload_range(delta_slot);
                }
            }
            s.loaded.remove(&slot);
        })
    }

    /// Evict the adapter in `slot`; its pages return to the pool.
    pub fn unload_adapter(&mut self, slot: usize) -> Result<()> {
        let counts = match self.loaded.remove(&slot) {
            Some(c) => c,
            None => bail!("slot {slot} holds no adapter"),
        };
        let delta = self.cfg.adapter_slot_base(slot);
        for (l, &c) in counts.iter().enumerate() {
            let commit = match self.mode {
                StoreMode::Virtual => c,
                StoreMode::Padding => self.cfg.e_max,
            };
            if commit == 0 {
                continue;
            }
            for p in 0..3 {
                self.mgr(l, p).unload_range(delta)?;
            }
        }
        self.settle_ledger(|_| {})
    }

    /// Materialize the full `[G, hidden, inter]` projection tensor for
    /// upload: loaded slots are copied out of the virtual tensor, padding
    /// holes become zeros (they are unreachable by construction — the
    /// expert map never points at them).
    pub fn materialize_proj(&self, layer: usize, proj: usize, out: &mut Vec<f32>) -> Result<()> {
        let per = self.cfg.hidden * self.cfg.expert_inter;
        let g = self.cfg.total_expert_slots();
        out.clear();
        out.resize(g * per, 0.0);
        let mgr = &self.managers[layer * 3 + proj];
        if self.base_loaded {
            let s = mgr.slice_f32(0, self.cfg.num_experts)?;
            out[..s.len()].copy_from_slice(s);
        }
        for (&slot, counts) in &self.loaded {
            let delta = self.cfg.adapter_slot_base(slot);
            let commit = match self.mode {
                StoreMode::Virtual => counts[layer],
                StoreMode::Padding => self.cfg.e_max,
            };
            if commit == 0 {
                continue;
            }
            let s = mgr.slice_f32(delta, commit)?;
            // only the real experts matter; padding-mode extra slots are
            // whatever the pages hold (zeros), also unreachable
            out[delta * per..delta * per + s.len()].copy_from_slice(s);
        }
        Ok(())
    }

    /// Aggregated memory stats across all (layer, proj) tensors.
    pub fn stats(&self) -> MemStats {
        let mut acc = MemStats {
            mapped_pages: 0,
            mapped_bytes: 0,
            used_bytes: 0,
            reserved_bytes: 0,
        };
        for m in &self.managers {
            let s = m.stats();
            acc.mapped_pages += s.mapped_pages;
            acc.mapped_bytes += s.mapped_bytes;
            acc.used_bytes += s.used_bytes;
            acc.reserved_bytes += s.reserved_bytes;
        }
        acc
    }

    /// Mapped bytes attributable to adapters (beyond the base model).
    pub fn adapter_mapped_bytes(&self) -> usize {
        let base_pages: usize = self
            .managers
            .iter()
            .map(|m| {
                // pages covering slots [0, M)
                if self.base_loaded {
                    (self.cfg.num_experts * m.expert_size()).div_ceil(m.page_size())
                } else {
                    0
                }
            })
            .sum();
        self.stats().mapped_bytes.saturating_sub(
            base_pages * self.managers.first().map(|m| m.page_size()).unwrap_or(0),
        )
    }

    pub fn loaded_slots(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self.loaded.keys().copied().collect();
        v.sort_unstable();
        v
    }
}

fn f32_bytes(v: &[f32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapters::generator::{paper_adapter_profiles, synth_adapter};

    fn tiny_cfg() -> ModelConfig {
        let mut c = ModelConfig::paper16b();
        c.name = "t".into();
        c.vocab = 64;
        // expert_proj_bytes = 64 * 256 * 4 B = 64 KB = exactly one test
        // page, so adapter windows really cost pages (exercises mapping)
        c.hidden = 64;
        c.layers = 2;
        c.q_heads = 2;
        c.kv_heads = 1;
        c.head_dim = 8;
        c.num_experts = 8;
        c.top_k = 2;
        c.expert_inter = 256;
        c.shared_inter = 16;
        c.max_adapters = 3;
        c.e_max = 3;
        c
    }

    const PS: usize = 64 << 10;

    fn mk(mode: StoreMode) -> (WeightStore, BaseWeights, Arc<Mutex<DeviceMemory>>) {
        let cfg = tiny_cfg();
        let pool = Arc::new(Mutex::new(PagePool::new(PS, 4096).unwrap()));
        let device = DeviceMemory::shared(usize::MAX / 2);
        let store = WeightStore::new(&cfg, mode, pool, device.clone()).unwrap();
        let base = BaseWeights::generate(&cfg, 1);
        (store, base, device)
    }

    fn adapter_for(cfg: &ModelConfig, seed: u64) -> Adapter {
        let mut p = paper_adapter_profiles()[0].clone();
        p.max_experts = cfg.e_max;
        p.avg_experts = 2.0;
        synth_adapter(&p, cfg.layers, cfg.num_experts, cfg.hidden, cfg.expert_inter, seed)
    }

    #[test]
    fn base_roundtrip_through_materialize() {
        let (mut store, base, _d) = mk(StoreMode::Virtual);
        store.load_base(&base).unwrap();
        let mut out = Vec::new();
        store.materialize_proj(1, 2, &mut out).unwrap();
        let per = 64 * 256;
        assert_eq!(out.len(), store.cfg.total_expert_slots() * per);
        assert_eq!(&out[..8 * per], base.experts(1, 2));
        // adapter region is zeros
        assert!(out[8 * per..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn adapter_weights_land_in_their_window() {
        let (mut store, base, _d) = mk(StoreMode::Virtual);
        store.load_base(&base).unwrap();
        let cfg = tiny_cfg();
        let ad = adapter_for(&cfg, 3);
        store.load_adapter(1, &ad).unwrap();
        let per = cfg.hidden * cfg.expert_inter;
        for l in 0..cfg.layers {
            let mut out = Vec::new();
            store.materialize_proj(l, 0, &mut out).unwrap();
            let delta = cfg.adapter_slot_base(1);
            for (local, _) in ad.layers[l].expert_ids.iter().enumerate() {
                let w3 = ad.layers[l].expert_weights(local, cfg.hidden, cfg.expert_inter);
                assert_eq!(
                    &out[(delta + local) * per..(delta + local + 1) * per],
                    &w3[..per],
                    "layer {l} local {local}"
                );
            }
        }
    }

    #[test]
    fn virtual_maps_less_than_padding() {
        let cfg = tiny_cfg();
        let (mut v, base, _) = mk(StoreMode::Virtual);
        let (mut p, _, _) = mk(StoreMode::Padding);
        v.load_base(&base).unwrap();
        p.load_base(&base).unwrap();
        let base_mapped = v.stats().mapped_bytes;
        assert_eq!(base_mapped, p.stats().mapped_bytes);
        let ad = adapter_for(&cfg, 5);
        assert!(ad.avg_experts() < cfg.e_max as f64); // sparse adapter
        v.load_adapter(0, &ad).unwrap();
        p.load_adapter(0, &ad).unwrap();
        assert!(
            v.stats().used_bytes < p.stats().reserved_bytes
                || v.stats().mapped_bytes <= p.stats().mapped_bytes,
        );
        assert!(v.adapter_mapped_bytes() <= p.adapter_mapped_bytes());
    }

    #[test]
    fn unload_restores_memory_and_slots() {
        let cfg = tiny_cfg();
        let (mut store, base, dev) = mk(StoreMode::Virtual);
        store.load_base(&base).unwrap();
        let before = dev.lock().unwrap().used();
        let ad = adapter_for(&cfg, 7);
        store.load_adapter(2, &ad).unwrap();
        assert!(dev.lock().unwrap().used() > before);
        store.unload_adapter(2).unwrap();
        assert_eq!(dev.lock().unwrap().used(), before);
        assert!(store.loaded_slots().is_empty());
        // reload into the same slot works
        store.load_adapter(2, &ad).unwrap();
    }

    #[test]
    fn ledger_oom_rolls_back() {
        let cfg = tiny_cfg();
        let pool = Arc::new(Mutex::new(PagePool::new(PS, 4096).unwrap()));
        // budget: base fits, adapter does not
        let base_pages = {
            let per_mgr = (cfg.num_experts * cfg.expert_proj_bytes()).div_ceil(PS);
            per_mgr * cfg.layers * 3
        };
        let device = DeviceMemory::shared(base_pages * PS);
        let mut store =
            WeightStore::new(&cfg, StoreMode::Virtual, pool, device.clone()).unwrap();
        let base = BaseWeights::generate(&cfg, 1);
        store.load_base(&base).unwrap();
        let ad = adapter_for(&cfg, 9);
        let used_before = device.lock().unwrap().used();
        assert!(store.load_adapter(0, &ad).is_err());
        assert_eq!(device.lock().unwrap().used(), used_before);
        assert!(store.loaded_slots().is_empty());
    }

    #[test]
    fn double_load_and_bad_slots_rejected() {
        let cfg = tiny_cfg();
        let (mut store, base, _) = mk(StoreMode::Virtual);
        store.load_base(&base).unwrap();
        let ad = adapter_for(&cfg, 11);
        store.load_adapter(0, &ad).unwrap();
        assert!(store.load_adapter(0, &ad).is_err());
        assert!(store.load_adapter(cfg.max_adapters, &ad).is_err());
        assert!(store.unload_adapter(1).is_err());
    }
}
