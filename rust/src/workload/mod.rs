//! Workload generation: the paper's online-serving experiments drive the
//! system with domain-matched prompts, Poisson arrivals and power-law
//! adapter skew (section 5.2). No datasets are available offline, so
//! prompts are synthetic with per-domain length distributions
//! (DESIGN.md section 7).
//!
//! Two driving modes share the same arrival statistics:
//!
//! * **Trace replay** ([`trace`]) — pre-generate a [`Trace`] (one
//!   Poisson process per adapter, power-law rates), then replay it in
//!   real time through [`crate::server::replay_backend`]. Deterministic
//!   given a seed; the benches' mode.
//! * **Open loop** ([`openloop`]) — draw arrivals on the fly and inject
//!   them on the wall clock whether or not the backend keeps up, against
//!   any [`crate::serving::ServingBackend`] (single engine, in-process
//!   fleet, or a remote NDJSON server). The mode that exposes deadline
//!   misses and queue growth under overload.

pub mod openloop;
pub mod power_law;
pub mod prompts;
pub mod trace;

pub use openloop::{preamble_token, OpenLoopOutcome, OpenLoopSpec, PREAMBLE_POOL};
pub use power_law::power_law_shares;
pub use prompts::PromptGen;
pub use trace::{Trace, TraceEvent, TraceSpec};
