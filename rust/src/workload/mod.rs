//! Workload generation: the paper's online-serving experiments drive the
//! system with domain-matched prompts, Poisson arrivals and power-law
//! adapter skew (section 5.2). No datasets are available offline, so
//! prompts are synthetic with per-domain length distributions
//! (DESIGN.md section 7).

pub mod power_law;
pub mod prompts;
pub mod trace;

pub use power_law::power_law_shares;
pub use prompts::PromptGen;
pub use trace::{Trace, TraceEvent, TraceSpec};
